//! Fig. 6a-c: softmax speedup, latency breakdown and energy over the
//! four kernel configurations and several sequence lengths.
use vexp::energy::power::cluster_energy_pj;
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};

fn rows(r: usize, n: usize) -> Vec<Vec<f32>> {
    (0..r).map(|k| (0..n).map(|i| ((i * 7 + k * 13) % 97) as f32 * 0.15 - 7.0).collect()).collect()
}

fn main() {
    println!("Fig. 6a-c — softmax on one cluster (8 rows per length)");
    for n in [256usize, 512, 1024, 2048] {
        let data = rows(8, n);
        println!("--- seq {n} ---");
        println!("{:24} {:>10} {:>9} {:>12} {:>9}", "variant", "cyc/out", "speedup", "pJ/out", "E-ratio");
        let mut base = (0.0, 0.0);
        for v in SoftmaxVariant::ALL {
            let run = run_softmax(v, &data);
            let ext = v == SoftmaxVariant::SwExpHw;
            let pj = cluster_energy_pj(&run.stats, ext).total() / (8 * n) as f64;
            if v == SoftmaxVariant::Baseline { base = (run.cycles_per_output, pj); }
            println!("{:24} {:>10.2} {:>8.1}x {:>12.1} {:>8.1}x",
                v.label(), run.cycles_per_output, base.0 / run.cycles_per_output,
                pj, base.1 / pj);
        }
    }
    println!("(paper at seq 2048: 162.7x speedup, 74.3x energy)");
}
