//! Fig. 8: end-to-end runtime and energy, baseline vs softmax-optimized,
//! on the 16-cluster Occamy-style system — served through the unified
//! execution engine's `Backend` API (analytic backend).
use vexp::exec::{AnalyticBackend, Backend, Request};
use vexp::model::config::ALL_MODELS;

fn main() {
    let mut backend = AnalyticBackend::new();
    println!("Fig. 8 — 16-cluster end-to-end (non-autoregressive), backend: {}", backend.name());
    println!("{:12} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8}",
        "model", "BL ms", "Optim ms", "speedup", "BL mJ", "Optim mJ", "E-ratio");
    for cfg in ALL_MODELS {
        let b = backend.estimate(&Request::baseline(0, cfg));
        let o = backend.estimate(&Request::new(1, cfg));
        println!("{:12} {:>10.2} {:>10.2} {:>7.1}x {:>10.1} {:>10.1} {:>7.1}x",
            cfg.name, b.latency_ms(), o.latency_ms(), b.cycles / o.cycles,
            b.energy_mj(), o.energy_mj(), b.energy_pj / o.energy_pj);
    }
    println!("(paper: GPT-2 5.8x/3.6x, GPT-3 2.9x/1.7x, ViT-B 1.9x/1.4x, ViT-H 1.4x/1.2x)");
}
