//! Fig. 8: end-to-end runtime and energy, baseline vs softmax-optimized,
//! on the 16-cluster Occamy-style system — served through the unified
//! execution engine's `Backend` API (analytic backend) — plus the
//! beyond-paper serving extension: a prefill+decode sweep (per-token
//! decode cost over KV length) and a continuously-batched serving
//! summary (TTFT / per-token latency / tokens/s).
use vexp::coordinator::CLUSTERS;
use vexp::exec::{AnalyticBackend, Backend, CycleSimBackend, Engine, Request, ServeOptions};
use vexp::model::config::{ALL_MODELS, GPT2_SMALL, GPT3_XL, VIT_BASE};
use vexp::model::Phase;
use vexp::sim::SamplePolicy;

fn main() {
    let mut backend = AnalyticBackend::new();
    println!("Fig. 8 — 16-cluster end-to-end (non-autoregressive), backend: {}", backend.name());
    println!("{:12} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8} {:>7}",
        "model", "BL ms", "Optim ms", "speedup", "BL mJ", "Optim mJ", "E-ratio", "nonlin");
    for cfg in ALL_MODELS {
        let b = backend.estimate(&Request::baseline(0, cfg));
        let o = backend.estimate(&Request::new(1, cfg));
        // nonlin = the GELU+LayerNorm share of optimized end-to-end cycles
        println!("{:12} {:>10.2} {:>10.2} {:>7.1}x {:>10.1} {:>10.1} {:>7.1}x {:>6.1}%",
            cfg.name, b.latency_ms(), o.latency_ms(), b.cycles / o.cycles,
            b.energy_mj(), o.energy_mj(), b.energy_pj / o.energy_pj,
            100.0 * o.nonlin_cycles / o.cycles);
    }
    println!("(paper: GPT-2 5.8x/3.6x, GPT-3 2.9x/1.7x, ViT-B 1.9x/1.4x, ViT-H 1.4x/1.2x)");

    // --- beyond paper: decode-phase per-token cost over KV length --------
    println!();
    println!("Decode sweep (beyond paper) — one-token KV-cache step, optimized kernels:");
    println!("{:12} {:>8} {:>12} {:>10} {:>10} {:>10}",
        "model", "KV len", "cyc/token", "us/token", "tok/s", "uJ/token");
    for cfg in [GPT2_SMALL, GPT3_XL] {
        for kv in [256u32, 1024, 2048] {
            let r = backend.estimate_phase(&Request::new(0, cfg), Phase::Decode { kv_len: kv });
            println!(
                "{:12} {:>8} {:>12.0} {:>10.1} {:>10.1} {:>10.2}",
                cfg.name,
                kv,
                r.cycles,
                r.cycles / 1e3,
                1e9 / r.cycles,
                r.energy_pj / 1e6
            );
        }
    }

    // --- beyond paper: prefill vs decode phase split ---------------------
    println!();
    println!("Phase split at a 512-token prompt (optimized kernels):");
    println!("{:12} {:>12} {:>12} {:>10}", "model", "prefill ms", "decode us", "dma share");
    for cfg in [GPT2_SMALL, GPT3_XL] {
        let p = backend.estimate_phase(&Request::new(0, cfg), Phase::Prefill { prompt: 512 });
        let d = backend.estimate_phase(&Request::new(0, cfg), Phase::Decode { kv_len: 512 });
        println!(
            "{:12} {:>12.2} {:>12.1} {:>9.0}%",
            cfg.name,
            p.latency_ms(),
            d.cycles / 1e3,
            100.0 * d.dma_cycles / d.cycles
        );
    }

    // --- beyond paper: continuously-batched serving summary --------------
    let mut engine = Engine::new();
    let mut gpt2 = GPT2_SMALL;
    gpt2.seq = 256;
    engine.submit_request(Request::new(0, gpt2).with_tokens(16));
    engine.submit_request(Request::new(0, VIT_BASE).arriving_at(1));
    engine.submit_request(Request::new(0, gpt2).with_tokens(8).arriving_at(2));
    let report = engine.serve(&mut backend, None, &ServeOptions::default());
    println!();
    println!(
        "Continuous batching (3 tenants, analytic backend): {} iterations, {} tokens, {:.1} tok/s",
        report.iterations,
        report.total_tokens(),
        report.tokens_per_s()
    );
    for r in &report.per_request {
        println!(
            "  req {:>2} {:12}: TTFT {:>8.3} ms, {:>4} tokens, {:>8.1} us/token, {:>7.3} mJ",
            r.request_id,
            r.model,
            r.ttft_ms(),
            r.tokens,
            r.token_latency_us(),
            r.energy_mj()
        );
    }

    // --- raw-speed tier: GPT-3 prefill+decode on the cycle simulator -----
    // Every instruction of the slice programs actually executes (or
    // replays from the tile memo); remaining repetitions are sampled and
    // extrapolated with a reported cycle error bound (DESIGN.md §11).
    // The committed host wall-clock baseline for this sweep lives in
    // BENCH_sim.json at the repo root.
    println!();
    println!(
        "GPT-3 prefill+decode, cycle simulator raw-speed tier (tile memo + sampled simulation):"
    );
    let t0 = std::time::Instant::now();
    let mut sim = CycleSimBackend::new(CLUSTERS).with_sampling(SamplePolicy::default());
    let mut engine = Engine::new();
    let mut gpt3 = GPT3_XL;
    gpt3.seq = 512;
    engine.submit_request(Request::new(0, gpt3).with_tokens(16));
    let report = engine.serve(&mut sim, None, &ServeOptions::default());
    let wall_s = t0.elapsed().as_secs_f64();
    for r in &report.per_request {
        println!(
            "  req {:>2} {:12}: TTFT {:>8.3} ms, {:>4} tokens, {:>8.1} us/token, \
             sampling error bound {:>6.0} cycles",
            r.request_id,
            r.model,
            r.ttft_ms(),
            r.tokens,
            r.token_latency_us(),
            r.error_bound_cycles
        );
    }
    println!(
        "  {} simulated cycles end-to-end in {:.2} s of host time",
        report.total_cycles, wall_s
    );
}
