//! Table II: accuracy under FP32 / BF16 / BF16+VEXP numerics.
//! The measurement itself is build-time (python/compile/train.py on the
//! synthetic corpus — see DESIGN.md §2 substitution log); this bench
//! renders artifacts/accuracy_table.json next to the paper's numbers.
use vexp::runtime::json::Json;

fn main() {
    println!("Table II — accuracy (tiny-GPT substitution; run `make accuracy`)");
    match std::fs::read_to_string("artifacts/accuracy_table.json") {
        Ok(s) => {
            let j = Json::parse(&s).expect("accuracy_table.json parse");
            println!("  model   : {}", j.get("model").and_then(Json::as_str).unwrap_or("?"));
            println!("  dataset : {}", j.get("dataset").and_then(Json::as_str).unwrap_or("?"));
            let r = j.get("results").expect("results");
            println!("{:10} {:>12}", "config", "perplexity");
            for key in ["FP32", "BF16", "BF16 EXP"] {
                if let Some(row) = r.get(key) {
                    println!("{key:10} {:>12.4}", row.get("perplexity").and_then(Json::as_f64).unwrap_or(f64::NAN));
                }
            }
            println!("(paper GPT-2/WikiText: 37.4 / 37.8 / 37.8 — BF16+VEXP ~ BF16)");
        }
        Err(_) => println!("  artifacts/accuracy_table.json missing — run `make accuracy`"),
    }
}
