//! Table II: accuracy under FP32 / BF16 / BF16+VEXP numerics, plus the
//! kernel-level speed/accuracy frontier (ISSUE 8).
//!
//! Part 1 renders artifacts/accuracy_table.json (the build-time
//! tiny-GPT substitution — see DESIGN.md §2) next to the paper's
//! numbers, when present.
//!
//! Part 2 is the **accuracy gate** CI runs: every nonlinearity kernel
//! is swept against an f64 oracle across its exp-technology ablation
//! axis (Schraudolph bit-trick vs degree-6 Horner polynomial vs the
//! VFEXP hardware unit), and the binary *panics* — failing the CI
//! step — if any kernel's error exceeds the bounds committed below.
//! The bounds are the documented contract of DESIGN.md §13; loosening
//! them is a reviewed change to this file, not a flake.

use vexp::accuracy::{gelu_error_exhaustive, layernorm_error_on, softmax_mse};
use vexp::bf16::Bf16;
use vexp::kernels::gelu::{run_gelu, GeluVariant};
use vexp::kernels::layernorm::{run_layernorm, LayerNormVariant};
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};
use vexp::runtime::json::Json;
use vexp::testkit::Rng;

// ---------------------------------------------------------------------------
// Committed accuracy bounds (the gate). Max relative error per element;
// GELU uses the `GELU_REL_FLOOR` denominator convention of accuracy/,
// LayerNorm floors the denominator at 1 (outputs are standardized).
// ---------------------------------------------------------------------------

/// GELU, software Schraudolph exp: the fast, inaccurate frontier end.
const GELU_SW_SCHRAUDOLPH_MAX_REL: f64 = 0.20;
/// GELU, software degree-6 Horner exp: accurate to ~bf16 resolution.
const GELU_SW_HORNER_MAX_REL: f64 = 0.10;
/// GELU, hardware VFEXP: must match the Horner bound, at SIMD speed.
const GELU_HW_MAX_REL: f64 = 0.10;
/// LayerNorm (both variants) on adversarial high-variance rows.
const LAYERNORM_MAX_REL: f64 = 0.10;
/// Softmax output MSE vs the f64 oracle on bf16-quantized logits:
/// Schraudolph-exp variants (software and the VFEXP hardware unit).
const SOFTMAX_SCHRAUDOLPH_MSE: f64 = 1e-5;
/// Softmax output MSE, degree-6 Horner exp: bf16 rounding only.
const SOFTMAX_HORNER_MSE: f64 = 1e-6;

fn render_table2() {
    println!("Table II — accuracy (tiny-GPT substitution; run `make accuracy`)");
    match std::fs::read_to_string("artifacts/accuracy_table.json") {
        Ok(s) => {
            let j = Json::parse(&s).expect("accuracy_table.json parse");
            println!("  model   : {}", j.get("model").and_then(Json::as_str).unwrap_or("?"));
            println!("  dataset : {}", j.get("dataset").and_then(Json::as_str).unwrap_or("?"));
            let r = j.get("results").expect("results");
            println!("{:10} {:>12}", "config", "perplexity");
            for key in ["FP32", "BF16", "BF16 EXP"] {
                if let Some(row) = r.get(key) {
                    println!("{key:10} {:>12.4}", row.get("perplexity").and_then(Json::as_f64).unwrap_or(f64::NAN));
                }
            }
            println!("(paper GPT-2/WikiText: 37.4 / 37.8 / 37.8 — BF16+VEXP ~ BF16)");
        }
        Err(_) => println!("  artifacts/accuracy_table.json missing — run `make accuracy`"),
    }
}

/// A deterministic activation batch for the cycles/output column.
fn act_rows(r: usize, n: usize) -> Vec<Vec<f32>> {
    (0..r)
        .map(|k| (0..n).map(|i| ((i * 11 + k * 17) % 89) as f32 * 0.09 - 4.0).collect())
        .collect()
}

fn gelu_wall() {
    println!();
    println!("GELU speed/accuracy frontier (exhaustive over all finite bf16)");
    println!("{:22} {:>9} {:>10} {:>10} {:>8}", "variant", "cyc/out", "max-rel", "mean-rel", "n");
    let speed_rows = act_rows(8, 512);
    for v in GeluVariant::ALL {
        let s = gelu_error_exhaustive(v);
        let cpo = run_gelu(v, &speed_rows).cycles_per_output;
        println!(
            "{:22} {:>9.2} {:>10.5} {:>10.6} {:>8}",
            v.label(),
            cpo,
            s.max_rel,
            s.mean_rel,
            s.n
        );
        let bound = match v {
            GeluVariant::Sw(_) => GELU_SW_SCHRAUDOLPH_MAX_REL,
            GeluVariant::SwHorner(_) => GELU_SW_HORNER_MAX_REL,
            GeluVariant::Hw(_) => GELU_HW_MAX_REL,
        };
        assert!(
            s.max_rel < bound,
            "accuracy gate: gelu {v:?} max rel {:.5} exceeds the committed bound {bound}",
            s.max_rel
        );
        assert!(s.n > 60_000, "accuracy gate: gelu sweep covered only {} inputs", s.n);
    }
}

fn layernorm_wall() {
    println!();
    println!("LayerNorm on adversarial high-variance rows (8 x 512, f32 +/-200)");
    println!("{:22} {:>9} {:>10} {:>10}", "variant", "cyc/out", "max-rel", "mean-rel");
    let mut rng = Rng::new(0xAD5E);
    let rows: Vec<Vec<f32>> =
        (0..8).map(|_| (0..512).map(|_| rng.f32(-200.0, 200.0)).collect()).collect();
    for v in LayerNormVariant::ALL {
        let s = layernorm_error_on(v, &rows);
        let cpo = run_layernorm(v, &rows).cycles_per_output;
        println!("{:22} {:>9.2} {:>10.5} {:>10.6}", v.label(), cpo, s.max_rel, s.mean_rel);
        assert!(
            s.max_rel < LAYERNORM_MAX_REL,
            "accuracy gate: layernorm {v:?} max rel {:.5} exceeds {LAYERNORM_MAX_REL}",
            s.max_rel
        );
    }
}

fn softmax_wall() {
    println!();
    println!("Softmax exp-technology ablation (8 x 512, bf16-quantized logits)");
    println!("{:26} {:>9} {:>12}", "variant", "cyc/out", "output MSE");
    // quantize the logits up front so the MSE measures kernel error, not
    // input quantization
    let rows: Vec<Vec<f32>> = act_rows(8, 512)
        .into_iter()
        .map(|r| r.into_iter().map(|v| Bf16::from_f32(v * 2.0).to_f32()).collect())
        .collect();
    for (v, bound) in [
        (SoftmaxVariant::SwExpSw, Some(SOFTMAX_SCHRAUDOLPH_MSE)),
        (SoftmaxVariant::SwExpHorner, Some(SOFTMAX_HORNER_MSE)),
        (SoftmaxVariant::SwExpHw, Some(SOFTMAX_SCHRAUDOLPH_MSE)),
        (SoftmaxVariant::Baseline, None),
        (SoftmaxVariant::SwOptim, None),
    ] {
        let run = run_softmax(v, &rows);
        let mse = softmax_mse(&rows, &run.out);
        println!("{:26} {:>9.2} {:>12.3e}", v.label(), run.cycles_per_output, mse);
        if let Some(bound) = bound {
            assert!(
                mse < bound,
                "accuracy gate: softmax {v:?} MSE {mse:.3e} exceeds the committed bound {bound:.1e}"
            );
        }
    }
}

fn main() {
    render_table2();
    gelu_wall();
    layernorm_wall();
    softmax_wall();
    println!();
    println!("accuracy gate: all kernel error bounds hold");
}
