//! Ablation studies for the design choices DESIGN.md calls out:
//!   A1  scalar FEXP vs 4-lane VFEXP (value of the SIMD ExpOpGroup)
//!   A2  P(x) mantissa correction vs plain Schraudolph (accuracy cost)
//!   A3  FlashAttention-2 K-tile size sweep (SPM/double-buffer choice)
//!   A4  multi-cluster scaling with HBM contention (real programs)
//!   A5  polynomial-exp axis: Schraudolph vs Horner-6 vs VFEXP hardware
use vexp::accuracy::{exp_error_exhaustive, exp_error_in_range, softmax_mse};
use vexp::kernels::flash_attention::{run_flash_attention, FaVariant};
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};
use vexp::sim::System;
use vexp::isa::regs::*;
use vexp::isa::{Asm, SsrPattern};

fn rows(r: usize, n: usize) -> Vec<Vec<f32>> {
    (0..r).map(|k| (0..n).map(|i| ((i * 7 + k * 13) % 97) as f32 * 0.15 - 7.0).collect()).collect()
}

fn mat(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n).map(|_| { s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((s >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32 }).collect()
}

fn main() {
    // --- A1: SIMD width of the ExpOpGroup ------------------------------
    let data = rows(8, 1024);
    let simd = run_softmax(SoftmaxVariant::SwExpHw, &data);
    let scalar = run_softmax(SoftmaxVariant::SwExpHwScalar, &data);
    println!("A1 — ExpOpGroup SIMD ablation (softmax 8x1024)");
    println!("  VFEXP (4 lanes)  : {:>7.2} cyc/out", simd.cycles_per_output);
    println!("  FEXP  (scalar)   : {:>7.2} cyc/out  ({:.1}x slower)",
        scalar.cycles_per_output, scalar.cycles_per_output / simd.cycles_per_output);

    // --- A2: P(x) correction vs plain Schraudolph ----------------------
    let full = exp_error_exhaustive();
    let sw = run_softmax(SoftmaxVariant::SwExpSw, &rows(4, 256));
    let mut sw_err = 0.0f64;
    let mut n = 0u64;
    for (row, out) in rows(4, 256).iter().zip(&sw.out) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f64> = row.iter().map(|&x| ((x - m) as f64).exp()).collect();
        let s: f64 = e.iter().sum();
        for (w, &g) in e.iter().map(|v| v / s).zip(out.iter()) {
            sw_err = sw_err.max(((g as f64) - w).abs());
            let _ = n; n += 1;
        }
    }
    println!("A2 — mantissa correction P(x)");
    println!("  VEXP (exps+P(x)) : mean rel {:.4}%  max rel {:.3}%  (paper: 0.14/0.78)",
        full.mean_rel * 100.0, full.max_rel * 100.0);
    println!("  plain Schraudolph: softmax max abs err {:.4} (vs ~0.003 with P(x))", sw_err);
    println!("  softmax-domain MSE [-20,0]: {:.2e}", exp_error_in_range(-20.0, 0.0).mse);

    // --- A3: FA-2 tile size sweep ----------------------------------------
    println!("A3 — FlashAttention-2 K-tile sweep (Sq=32 Sk=256 d=64)");
    let q = mat(32 * 64, 1);
    let k = mat(256 * 64, 2);
    let v = mat(256 * 64, 3);
    for bk in [16u32, 32, 64, 128, 256] {
        let o = run_flash_attention(FaVariant::Optimized, &q, &k, &v, 32, 256, 64, bk);
        println!("  bk={bk:>4}: {:>8} cycles", o.stats.cycles);
    }

    // --- A4: cluster scaling with HBM contention -------------------------
    println!("A4 — multi-cluster scaling (same per-cluster kernel + 256 KiB DMA)");
    for n_cl in [1usize, 4, 8, 16] {
        let mut sys = System::new(n_cl);
        let workloads = (0..n_cl).map(|_| {
            let progs: Vec<_> = (0..8).map(|c| {
                let mut a = Asm::new();
                a.ssr_cfg(0, SsrPattern::read2d(0x1000 + c * 0x400, 8, 64, 0, 32));
                a.ssr_enable();
                a.li(A1, 2048);
                a.frep(A1, 1);
                a.vfexp_h(FT3, FT0);
                a.ssr_disable();
                a.finish()
            }).collect();
            (progs, 256 * 1024u64)
        }).collect();
        let s = sys.run(workloads);
        println!("  {n_cl:>2} clusters: makespan {:>7} cycles, HBM {:>8} B", s.cycles, s.hbm_bytes);
    }

    // --- A5: polynomial-exp technology in the softmax EXP block ----------
    // The software frontier: Schraudolph's bit-trick (fast, ~2% error)
    // vs the degree-6 Horner polynomial (accurate to bf16 resolution,
    // many more instructions), with the VFEXP hardware unit as the
    // reference point that gets both at once.
    println!("A5 — polynomial-exp axis (softmax 8x512)");
    let data = rows(8, 512);
    for v in [SoftmaxVariant::SwExpSw, SoftmaxVariant::SwExpHorner, SoftmaxVariant::SwExpHw] {
        let run = run_softmax(v, &data);
        let mse = softmax_mse(&data, &run.out);
        println!("  {:26}: {:>7.2} cyc/out  output MSE {:.2e}", v.label(), run.cycles_per_output, mse);
    }
}
