//! Table III: energy per operation for GEMM and EXP, baseline vs
//! ISA-extended cluster.
use vexp::energy::power::{cluster_energy_pj, exp_datapath_pj_per_op};
use vexp::kernels::gemm::run_gemm;
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};

fn mat(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n).map(|_| { s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((s >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32 }).collect()
}

fn main() {
    let g = run_gemm(&mat(48 * 48, 1), &mat(48 * 48, 2), 48, 48, 48);
    let gemm_bl = cluster_energy_pj(&g.stats, false).total() / g.flops as f64;
    let gemm_ext = cluster_energy_pj(&g.stats, true).total() / g.flops as f64;
    let rows: Vec<Vec<f32>> = (0..8).map(|i| mat(64, i + 3)).collect();
    let b = run_softmax(SoftmaxVariant::Baseline, &rows);
    let exp_bl = cluster_energy_pj(&b.stats, false).total() / (8.0 * 64.0);
    let exp_ext = exp_datapath_pj_per_op();
    println!("Table III — energy per operation [pJ/Op]");
    println!("{:8} {:>16} {:>14}", "", "Snitch Baseline", "ISA Extended");
    println!("{:8} {:>16.2} {:>14.2}   (paper: 3.96 / 4.04)", "GEMM", gemm_bl, gemm_ext);
    println!("{:8} {:>16.0} {:>14.2}   (paper: 3433 / 6.39)", "EXP", exp_bl, exp_ext);
}
