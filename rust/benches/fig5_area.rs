//! Fig. 5: GF12 area breakdown, baseline vs EXP-extended cluster.
use vexp::energy::AreaModel;

fn main() {
    let m = AreaModel::default();
    let r = m.report();
    println!("Fig. 5 — area breakdown (GF12, kGE)");
    println!("EXP block/core: {:.0} um^2 = 8 kGE (paper: 968 um^2)", m.exp_block_um2());
    println!("{:16} {:>10} {:>10} {:>10}", "level", "baseline", "extended", "overhead");
    println!("{:16} {:>10.0} {:>10.0} {:>9.1}%  (paper: 2.3%)", "FPU subsystem",
        m.fpu_ss_kge, r.fpu_ss_kge, r.fpu_ss_overhead * 100.0);
    println!("{:16} {:>10.0} {:>10.0} {:>9.1}%  (paper: 1.9%)", "core complex",
        m.core_complex_kge(false), r.core_complex_kge, r.core_complex_overhead * 100.0);
    println!("{:16} {:>10.0} {:>10.0} {:>9.1}%  (paper: 1.0%)", "cluster",
        m.cluster_kge(false), r.cluster_kge, r.cluster_overhead * 100.0);
}
