//! Fig. 6d-f: FlashAttention-2 throughput, softmax latency share and
//! energy, baseline vs optimized partial softmax (head dim 64, GPT-2).
//!
//! Runs through the execution engine: the FA-2 slice programs come from
//! the shared `ProgramCache` (one compile per variant/shape), execute on
//! the cycle-accurate backend's clusters, and the sweep finishes with a
//! batched multi-request run on the full 16-cluster system.
use vexp::coordinator::CLUSTERS;
use vexp::energy::power::cluster_energy_pj;
use vexp::exec::{Backend, CycleSimBackend, Engine, KernelKind, ProgramKey};
use vexp::kernels::flash_attention::{build_fa_program, seed_fa_inputs, FaVariant};
use vexp::model::GPT2_SMALL;
use vexp::sim::{Cluster, CORES_PER_CLUSTER};

fn main() {
    println!("Fig. 6d-f — FlashAttention-2, head dim 64 (GPT-2), one cluster");
    println!("{:>4} {:>10} {:>10} {:>8} {:>8}", "Sk", "BL cyc", "Opt cyc", "speedup", "E-ratio");
    let mut engine = Engine::new();
    let (sq, d, bk) = (32u32, 64u32, 32u32);
    for sk in [64u32, 128, 256] {
        let mut run = |variant: FaVariant| {
            let key = ProgramKey::for_kernel(
                KernelKind::FlashAttention(variant),
                [sq, sk, d, bk, 0, 0],
                CORES_PER_CLUSTER as u32,
            );
            let program = engine
                .cache
                .get_or_build(key, || build_fa_program(variant, sq, sk, d, bk));
            let mut cluster = Cluster::new();
            seed_fa_inputs(&mut cluster.spm, sq, sk, d, bk, sk as u64);
            let stats = cluster.run_program(&program);
            let e = cluster_energy_pj(&stats, variant == FaVariant::Optimized).total();
            (stats.cycles, e)
        };
        let (bc, be) = run(FaVariant::Baseline);
        let (oc, oe) = run(FaVariant::Optimized);
        println!("{sk:>4} {bc:>10} {oc:>10} {:>7.1}x {:>7.1}x",
            bc as f64 / oc as f64, be / oe);
    }
    println!(
        "(paper: up to 8.2x throughput, 4.1x energy; cache: {} programs, {} hits)",
        engine.cache.len(),
        engine.cache.hits
    );

    // --- batched serving slice on the full system -----------------------
    for _ in 0..4 {
        engine.submit(GPT2_SMALL);
    }
    let batch = engine.compile_batch();
    let mut sim = CycleSimBackend::new(CLUSTERS);
    let report = sim.execute(&batch);
    println!(
        "batched: 4x GPT-2 heads on {CLUSTERS} clusters -> makespan {} cycles, \
         {} cache hits this batch",
        report.makespan_cycles, report.cache_hits
    );
    for r in &report.per_request {
        println!(
            "  req {:>2} {:>12}: {:>9.0} cycles on {} clusters, softmax {:.1}%",
            r.request_id,
            r.model,
            r.cycles,
            r.clusters_used,
            r.softmax_share() * 100.0
        );
    }
}
