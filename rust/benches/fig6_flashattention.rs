//! Fig. 6d-f: FlashAttention-2 throughput, softmax latency share and
//! energy, baseline vs optimized partial softmax (head dim 64, GPT-2).
use vexp::energy::power::cluster_energy_pj;
use vexp::isa::Class;
use vexp::kernels::flash_attention::{run_flash_attention, FaVariant};

fn mat(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed | 1;
    (0..n).map(|_| { s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((s >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32 }).collect()
}

fn main() {
    println!("Fig. 6d-f — FlashAttention-2, head dim 64 (GPT-2), one cluster");
    println!("{:>4} {:>10} {:>10} {:>8} {:>9} {:>8}", "Sk", "BL cyc", "Opt cyc", "speedup", "sm-share", "E-ratio");
    let (sq, d, bk) = (32u32, 64u32, 32u32);
    for sk in [64u32, 128, 256] {
        let q = mat((sq * d) as usize, 1);
        let k = mat((sk * d) as usize, 2);
        let v = mat((sk * d) as usize, 3);
        let b = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
        let o = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
        // softmax share in the optimized kernel: exp/sub/reduce work
        let oc = o.stats.combined();
        let sm_instr = oc.count(Class::FpExp) * 4 + oc.count(Class::FpDivH);
        let share = sm_instr as f64 / oc.retired_total() as f64;
        let eb = cluster_energy_pj(&b.stats, false).total();
        let eo = cluster_energy_pj(&o.stats, true).total();
        println!("{sk:>4} {:>10} {:>10} {:>7.1}x {:>8.1}% {:>7.1}x",
            b.stats.cycles, o.stats.cycles,
            b.stats.cycles as f64 / o.stats.cycles as f64,
            share * 100.0, eb / eo);
    }
    println!("(paper: up to 8.2x throughput, softmax share -> 6%, 4.1x energy)");
}
