//! Table IV: our row of the state-of-the-art comparison, measured.
use vexp::bf16::Bf16;
use vexp::energy::power::{cluster_energy_pj, power_mw};
use vexp::energy::AreaModel;
use vexp::kernels::softmax::{run_softmax, softmax_ref, SoftmaxVariant};
use vexp::vexp::exp_unit;

fn main() {
    // softmax MSE over a typical attention-score distribution
    let rows: Vec<Vec<f32>> = (0..8).map(|r| (0..512)
        .map(|i| ((i * 7 + r * 31) % 97) as f32 * 0.15 - 7.0).collect()).collect();
    let run = run_softmax(SoftmaxVariant::SwExpHw, &rows);
    let mut mse = 0.0f64; let mut n = 0u64;
    for (row, out) in rows.iter().zip(&run.out) {
        for (w, g) in softmax_ref(row).iter().zip(out) {
            mse += ((g - w) as f64).powi(2); n += 1;
        }
    }
    mse /= n as f64;
    // exp MSE vs glibc over all bf16 inputs in the softmax range [-20, 0]
    let mut emse = 0.0f64; let mut en = 0u64;
    for bits in 0..=u16::MAX {
        let x = Bf16(bits).to_f32();
        if !(-20.0..=0.0).contains(&x) { continue; }
        let y = exp_unit(Bf16(bits)).to_f32() as f64;
        emse += (y - (x as f64).exp()).powi(2); en += 1;
    }
    emse /= en as f64;
    let core = &run.stats.per_core[0];
    let e = cluster_energy_pj(&run.stats, true);
    let mw_core = power_mw(e.total(), run.stats.cycles) / 8.0;
    let gops = (8.0 * 512.0) / run.stats.cycles as f64; // outputs/cycle @1GHz, per cluster
    let area = AreaModel::default().exp_block_um2();
    println!("Table IV — our row (measured)");
    println!("  precision        : BF16");
    println!("  exp MSE [-20,0]  : {emse:.2e}   (paper softmax MSE: 1.62e-9)");
    println!("  softmax MSE      : {mse:.2e}");
    println!("  tech             : GF12 (modeled)");
    println!("  frequency        : 1 GHz");
    println!("  area (EXP/core)  : {area:.0} um^2   (paper: 968)");
    println!("  power (core avg) : {mw_core:.1} mW   (paper: 7.1)");
    println!("  throughput       : {:.2} GOPS/core   (paper: 0.45)", gops / 8.0 * 8.0 / 8.0);
    let _ = core;
}
