//! Fig. 1: GPT-3 runtime breakdown vs sequence length, unoptimized vs
//! optimized GEMM — softmax share grows from ~30% to ~70% as GEMM gets
//! faster, motivating the whole paper.
use vexp::coordinator::{KernelRates, SystemEstimator};
use vexp::model::GPT3_XL;

fn main() {
    let est = SystemEstimator::new(KernelRates::calibrate());
    println!("Fig. 1 — GPT-3 XL runtime breakdown (softmax share of runtime)");
    println!("{:>6} {:>18} {:>18}", "seq", "unopt-GEMM", "opt-GEMM");
    for seq in [128u32, 256, 512, 1024, 2048] {
        let mut cfg = GPT3_XL;
        cfg.seq = seq;
        let unopt = est.estimate(&cfg, false, false);
        let opt = est.estimate(&cfg, false, true);
        println!(
            "{seq:>6} {:>17.1}% {:>17.1}%",
            unopt.softmax_share() * 100.0,
            opt.softmax_share() * 100.0
        );
    }
    println!("(paper: ~30% -> ~70% at seq 2048)");
}
