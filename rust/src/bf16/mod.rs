//! BF16 (Brain Floating-Point) software arithmetic.
//!
//! The numeric base of the whole Layer-3 stack: the Snitch FPU model, the
//! VEXP block and every simulated kernel operate on this type. Semantics
//! follow the Snitch FPU ([Bertaccini et al., ARITH'22] FPnew lineage):
//! operations compute at full precision and round to nearest-even back to
//! BF16; subnormal results flush to zero (the paper's §IV-A BF16
//! simplification relative to IEEE-754).

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

/// A BF16 value stored as its raw bit pattern.
///
/// `Bf16` is `Copy` + `repr(transparent)` over `u16` so SIMD registers can
/// pack four lanes into a `u64` with plain shifts (see [`crate::vexp`]).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
#[repr(transparent)]
pub struct Bf16(pub u16);

pub const POS_INF: Bf16 = Bf16(0x7F80);
pub const NEG_INF: Bf16 = Bf16(0xFF80);
pub const NAN: Bf16 = Bf16(0x7FC0);
pub const ZERO: Bf16 = Bf16(0x0000);
pub const ONE: Bf16 = Bf16(0x3F80);
/// Most negative finite BF16 (used as the MAX-reduction identity).
pub const MIN_FINITE: Bf16 = Bf16(0xFF7F);

impl Bf16 {
    /// Round a f32 to BF16 with round-to-nearest-even, flushing subnormal
    /// results to zero (sign-preserving).
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet NaN, keep the sign/payload MSB
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // RNE on the low 16 bits: adding 0x7FFF + lsb rounds up exactly
        // when the discarded half exceeds a tie, or ties with an odd
        // keep-bit; a carry into the exponent falls out of the same add
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let mut out = (rounded >> 16) as u16;
        // flush subnormals to signed zero
        if out & 0x7F80 == 0 {
            out &= 0x8000;
        }
        Bf16(out)
    }

    /// Widen to f32 (exact: BF16 is the top half of f32).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    #[inline]
    pub fn sign(self) -> u16 {
        self.0 >> 15
    }

    #[inline]
    pub fn exponent(self) -> u16 {
        (self.0 >> 7) & 0xFF
    }

    #[inline]
    pub fn mantissa(self) -> u16 {
        self.0 & 0x7F
    }

    #[inline]
    pub fn is_nan(self) -> bool {
        self.exponent() == 0xFF && self.mantissa() != 0
    }

    #[inline]
    pub fn is_inf(self) -> bool {
        self.exponent() == 0xFF && self.mantissa() == 0
    }

    #[inline]
    pub fn is_zero_or_subnormal(self) -> bool {
        self.exponent() == 0
    }

    // -- FPU operations (full-precision compute, RNE to BF16) -------------

    #[inline]
    pub fn add(self, rhs: Self) -> Self {
        Self::from_f32(self.to_f32() + rhs.to_f32())
    }

    #[inline]
    pub fn sub(self, rhs: Self) -> Self {
        Self::from_f32(self.to_f32() - rhs.to_f32())
    }

    #[inline]
    pub fn mul(self, rhs: Self) -> Self {
        Self::from_f32(self.to_f32() * rhs.to_f32())
    }

    #[inline]
    pub fn div(self, rhs: Self) -> Self {
        Self::from_f32(self.to_f32() / rhs.to_f32())
    }

    /// Fused multiply-add `self * b + c` with a single final rounding
    /// (the FPU's FMA module).
    #[inline]
    pub fn fma(self, b: Self, c: Self) -> Self {
        Self::from_f32(f64::mul_add(self.to_f32() as f64, b.to_f32() as f64, c.to_f32() as f64) as f32)
    }

    /// RISC-V `fmax.h` semantics: if one operand is NaN, return the other.
    #[inline]
    pub fn max(self, rhs: Self) -> Self {
        match (self.is_nan(), rhs.is_nan()) {
            (true, true) => NAN,
            (true, false) => rhs,
            (false, true) => self,
            _ => {
                if self.to_f32() >= rhs.to_f32() {
                    self
                } else {
                    rhs
                }
            }
        }
    }
}

/// Pack four BF16 lanes into a 64-bit SIMD register (lane 0 = bits 15:0).
#[inline]
pub fn pack4(lanes: [Bf16; 4]) -> u64 {
    (lanes[0].0 as u64)
        | ((lanes[1].0 as u64) << 16)
        | ((lanes[2].0 as u64) << 32)
        | ((lanes[3].0 as u64) << 48)
}

/// Unpack a 64-bit SIMD register into four BF16 lanes.
#[inline]
pub fn unpack4(v: u64) -> [Bf16; 4] {
    [
        Bf16(v as u16),
        Bf16((v >> 16) as u16),
        Bf16((v >> 32) as u16),
        Bf16((v >> 48) as u16),
    ]
}

/// Lane-wise SIMD apply over a packed u64 (the `vf*.h` instruction shape).
#[inline]
pub fn simd2<F: Fn(Bf16, Bf16) -> Bf16>(a: u64, b: u64, f: F) -> u64 {
    let (la, lb) = (unpack4(a), unpack4(b));
    pack4([f(la[0], lb[0]), f(la[1], lb[1]), f(la[2], lb[2]), f(la[3], lb[3])])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for bits in [0x0000u16, 0x3F80, 0xBF80, 0x4000, 0x7F7F, 0xFF7F] {
            let b = Bf16(bits);
            assert_eq!(Bf16::from_f32(b.to_f32()).0, bits);
        }
    }

    #[test]
    fn rne_rounds_to_even() {
        // 1.0 + 2^-9 (exact tie between 1.0 and 1.0+2^-8) -> stays 1.0 (even)
        let x = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(x).0, 0x3F80);
        // 1.0 + 3*2^-9 -> rounds up to 1.0 + 2^-7 mantissa 2 (even)
        let y = f32::from_bits(0x3F81_8000);
        assert_eq!(Bf16::from_f32(y).0, 0x3F82);
        // just above a tie rounds up
        let z = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(z).0, 0x3F81);
    }

    #[test]
    fn rne_carries_into_exponent() {
        // largest mantissa + round up must carry: 1.9921875 * (1+2^-8) -> 2.0
        let x = f32::from_bits(0x3FFF_8001);
        assert_eq!(Bf16::from_f32(x).0, 0x4000); // 2.0
    }

    #[test]
    fn subnormals_flush_to_zero() {
        let tiny = f32::from_bits(0x0001_0000); // subnormal in bf16 range
        assert_eq!(Bf16::from_f32(tiny).0, 0x0000);
        let ntiny = f32::from_bits(0x8001_0000);
        assert_eq!(Bf16::from_f32(ntiny).0, 0x8000);
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(Bf16::from_f32(f32::MAX), POS_INF);
        assert_eq!(Bf16::from_f32(f32::MIN), NEG_INF);
    }

    #[test]
    fn nan_stays_nan() {
        assert!(Bf16::from_f32(f32::NAN).is_nan());
        assert!(NAN.is_nan());
        assert!(!POS_INF.is_nan());
    }

    #[test]
    fn arithmetic_matches_f32_then_rounds() {
        let a = Bf16::from_f32(1.5);
        let b = Bf16::from_f32(2.25);
        assert_eq!(a.add(b).to_f32(), 3.75);
        assert_eq!(a.mul(b).to_f32(), 3.375);
        assert_eq!(b.sub(a).to_f32(), 0.75);
        assert!((a.div(b).to_f32() - 0.66796875).abs() < 1e-6);
    }

    #[test]
    fn fma_single_rounding() {
        // fma(a, b, c) with a*b inexact in bf16 must differ from mul-then-add
        let a = Bf16::from_f32(1.0078125); // 1 + 2^-7
        let c = Bf16::from_f32(-1.015625);
        let fused = a.fma(a, c).to_f32();
        let unfused = a.mul(a).add(c).to_f32();
        let exact = (a.to_f32() as f64 * a.to_f32() as f64 + c.to_f32() as f64) as f32;
        assert!((fused - exact).abs() <= (unfused - exact).abs());
    }

    #[test]
    fn max_riscv_nan_semantics() {
        let x = Bf16::from_f32(3.0);
        assert_eq!(NAN.max(x), x);
        assert_eq!(x.max(NAN), x);
        assert!(NAN.max(NAN).is_nan());
        assert_eq!(x.max(Bf16::from_f32(-5.0)), x);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let lanes = [Bf16(0x1111), Bf16(0x2222), Bf16(0x3333), Bf16(0x4444)];
        assert_eq!(unpack4(pack4(lanes)), lanes);
    }

    #[test]
    fn simd2_lanewise() {
        let a = pack4([ONE, ONE, ZERO, Bf16::from_f32(2.0)]);
        let b = pack4([ONE, ZERO, ONE, Bf16::from_f32(3.0)]);
        let s = unpack4(simd2(a, b, Bf16::add));
        assert_eq!(s[0].to_f32(), 2.0);
        assert_eq!(s[1].to_f32(), 1.0);
        assert_eq!(s[2].to_f32(), 1.0);
        assert_eq!(s[3].to_f32(), 5.0);
    }

    /// Independent round-to-nearest-even reference: explicit three-way
    /// comparison of the discarded half against the tie point, written
    /// deliberately unlike the production magic-add formulation.
    fn reference_rne(x: f32) -> u16 {
        if x.is_nan() {
            return ((x.to_bits() >> 16) as u16) | 0x0040;
        }
        let bits = x.to_bits();
        let hi = (bits >> 16) as u16;
        let rest = bits & 0xFFFF;
        let mut out = match rest.cmp(&0x8000) {
            std::cmp::Ordering::Less => hi,
            std::cmp::Ordering::Greater => hi + 1,
            std::cmp::Ordering::Equal => hi + (hi & 1), // tie: to even
        };
        if out & 0x7F80 == 0 {
            out &= 0x8000; // flush subnormals to signed zero
        }
        out
    }

    #[test]
    fn from_f32_matches_reference_rne_on_sampled_inputs() {
        use crate::testkit::{forall, Rng};
        let check = |x: f32| -> Result<(), String> {
            let got = Bf16::from_f32(x).0;
            let want = reference_rne(x);
            if got != want {
                return Err(format!(
                    "from_f32({x} = {:#010x}): got {got:#06x}, want {want:#06x}",
                    x.to_bits()
                ));
            }
            Ok(())
        };
        forall(4000, |rng: &mut Rng| {
            // arbitrary bit patterns cover specials, subnormals, NaNs…
            check(f32::from_bits(rng.next_u64() as u32))?;
            // …and explicitly constructed near-tie patterns (low half in
            // {0x7FFF, 0x8000, 0x8001} for random high halves) exercise
            // every rounding direction
            let hi = (rng.next_u64() as u32) << 16;
            check(f32::from_bits(hi | 0x7FFF))?;
            check(f32::from_bits(hi | 0x8000))?;
            check(f32::from_bits(hi | 0x8001))?;
            Ok(())
        });
    }

    #[test]
    fn exhaustive_widen_reround_matches_reference_rne() {
        // every BF16 pattern, widened to f32 and re-rounded, must agree
        // with the reference RNE (and be the identity off the flush/NaN
        // cases — covered by exhaustive_f32_roundtrip_is_identity)
        for bits in 0..=u16::MAX {
            let x = Bf16(bits).to_f32();
            if x.is_nan() {
                assert!(Bf16(reference_rne(x)).is_nan());
                assert!(Bf16::from_f32(x).is_nan());
                continue;
            }
            assert_eq!(Bf16::from_f32(x).0, reference_rne(x), "bits {bits:#06x}");
        }
    }

    #[test]
    fn exhaustive_f32_roundtrip_is_identity() {
        // from_f32(to_f32(b)) == b for every non-NaN bf16 (incl. inf)
        for bits in 0..=u16::MAX {
            let b = Bf16(bits);
            if b.is_nan() {
                continue;
            }
            let rt = Bf16::from_f32(b.to_f32());
            if b.is_zero_or_subnormal() {
                // subnormals flush to signed zero
                assert_eq!(rt.0 & 0x7FFF, 0);
            } else {
                assert_eq!(rt, b, "bits {bits:#06x}");
            }
        }
    }
}
