//! Minimal property-testing kit (proptest is unavailable in the offline
//! crate cache): a seeded SplitMix64 generator plus a `forall` driver
//! that reports the failing seed for reproduction.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

/// Deterministic SplitMix64 PRNG.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

/// Run `prop` for `cases` seeded cases; panic with the seed on failure.
pub fn forall<F: Fn(&mut Rng) -> Result<(), String>>(cases: u64, prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(5, 17);
            assert!((5..17).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(10, |rng| {
            if rng.range(0, 4) == 3 {
                Err("hit 3".into())
            } else {
                Ok(())
            }
        });
    }
}
