//! Minimal property-testing kit (proptest is unavailable in the offline
//! crate cache): a seeded SplitMix64 generator plus a `forall` driver
//! that reports the failing seed for reproduction.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

/// Deterministic SplitMix64 PRNG.
#[derive(Clone, Debug)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64 * (hi - lo)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.f64(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Bernoulli draw: true with probability `p` (clamped to [0, 1]).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64(0.0, 1.0) < p
    }

    /// Exponential draw with the given mean (inter-arrival gaps of a
    /// Poisson process). `mean <= 0` returns 0.
    pub fn exp(&mut self, mean: f64) -> f64 {
        if mean <= 0.0 {
            return 0.0;
        }
        let u = self.f64(0.0, 1.0); // in [0, 1) so 1-u is in (0, 1]
        -mean * (1.0 - u).ln()
    }
}

/// Mix two u64 streams into one (SplitMix64 finalizer over the pair):
/// used to derive independent, order-free substreams from a base seed,
/// e.g. per-(epoch, cluster) fault draws.
pub fn mix(a: u64, b: u64) -> u64 {
    let mut z = a
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(b)
        .wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Run `prop` for `cases` seeded cases; panic with the seed on failure.
pub fn forall<F: Fn(&mut Rng) -> Result<(), String>>(cases: u64, prop: F) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(5, 17);
            assert!((5..17).contains(&v));
        }
    }

    #[test]
    fn chance_tracks_probability() {
        let mut r = Rng::new(11);
        let hits = (0..4000).filter(|_| r.chance(0.25)).count();
        assert!((800..1200).contains(&hits), "hits = {hits}");
        let mut r = Rng::new(11);
        assert!((0..100).all(|_| !r.chance(0.0)));
        let mut r = Rng::new(11);
        assert!((0..100).all(|_| r.chance(1.0)));
    }

    #[test]
    fn exp_has_requested_mean_and_is_nonnegative() {
        let mut r = Rng::new(5);
        let n = 8000;
        let sum: f64 = (0..n).map(|_| r.exp(100.0)).sum();
        let mean = sum / n as f64;
        assert!((80.0..120.0).contains(&mean), "mean = {mean}");
        let mut r = Rng::new(5);
        assert!((0..1000).all(|_| r.exp(3.0) >= 0.0));
        assert_eq!(r.exp(0.0), 0.0);
    }

    #[test]
    fn mix_is_deterministic_and_spreads() {
        assert_eq!(mix(1, 2), mix(1, 2));
        assert_ne!(mix(1, 2), mix(2, 1));
        assert_ne!(mix(0, 0), mix(0, 1));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failures() {
        forall(10, |rng| {
            if rng.range(0, 4) == 3 {
                Err("hit 3".into())
            } else {
                Ok(())
            }
        });
    }
}
