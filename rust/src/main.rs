//! `vexp` CLI — the Layer-3 leader binary.
//!
//! Subcommands map onto the paper's experiments plus the serving engine:
//!   info                      system + artifact inventory
//!   exp <x...>                exponentials via the PJRT vexp artifact
//!                             (with `--features pjrt`), cross-checked
//!                             against the bit-exact model
//!   softmax [rows] [cols]     the four kernel configurations (Fig. 6a-c)
//!   flashattention            FA-2 baseline vs optimized (Fig. 6d-f)
//!   e2e [model]               16-cluster end-to-end estimate (Fig. 8),
//!                             through the unified Backend API
//!   serve                     batched multi-request serving demo on the
//!                             cycle-accurate 16-cluster backend
//!   area                      GF12 area report (Fig. 5)

use vexp::bf16::Bf16;
use vexp::coordinator::CLUSTERS;
use vexp::energy::power::{cluster_energy_pj, power_mw};
use vexp::energy::AreaModel;
use vexp::error::Result;
use vexp::exec::{AnalyticBackend, Backend, CycleSimBackend, Engine, Request};
use vexp::kernels::flash_attention::{run_flash_attention, FaVariant};
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};
use vexp::model::config::{ALL_MODELS, GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;
use vexp::vexp::exp_unit;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("exp") => exp_cmd(&args[1..]),
        Some("softmax") => softmax_cmd(&args[1..]),
        Some("flashattention") => flash_cmd(),
        Some("e2e") => e2e_cmd(&args[1..]),
        Some("serve") => serve_cmd(),
        Some("area") => area_cmd(),
        _ => {
            eprintln!(
                "usage: vexp <info|exp|softmax|flashattention|e2e|serve|area> [args]"
            );
            Ok(())
        }
    }
}

fn info() -> Result<()> {
    println!("VEXP reproduction — Snitch cluster + BF16 EXP ISA extension");
    println!("cluster: 8 cores, 128 KiB SPM, FREP+SSR+SIMD, VFEXP @ 2 cycles");
    match Runtime::open("artifacts") {
        Ok(rt) => {
            println!("artifacts ({}):", rt.artifact_dir().display());
            for ep in rt.entry_points() {
                let art = rt.artifact(ep).unwrap();
                println!("  {ep:20} inputs {:?}", art.inputs.len());
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn exp_cmd(args: &[String]) -> Result<()> {
    let xs: Vec<f32> = if args.is_empty() {
        vec![-2.0, -1.0, 0.0, 1.0, 2.0]
    } else {
        args.iter().map(|a| a.parse().unwrap_or(0.0)).collect()
    };
    let mut buf = vec![0.0f32; 4096];
    buf[..xs.len()].copy_from_slice(&xs);
    let pjrt_out = Runtime::open("artifacts")
        .and_then(|mut rt| rt.execute("vexp", &[Input::F32(&buf)]));
    match pjrt_out {
        Ok(out) => {
            println!("{:>10}  {:>12}  {:>12}  {:>12}", "x", "pjrt", "bit-exact", "libm");
            for (i, &x) in xs.iter().enumerate() {
                let bitexact = exp_unit(Bf16::from_f32(x)).to_f32();
                println!("{x:>10.4}  {:>12.6}  {bitexact:>12.6}  {:>12.6}", out[i], x.exp());
                assert_eq!(out[i], bitexact, "PJRT and Rust EXP models disagree!");
            }
            println!("PJRT artifact and bit-exact Rust model agree on all inputs.");
        }
        Err(e) => {
            println!("(PJRT path unavailable: {e})");
            println!("{:>10}  {:>12}  {:>12}", "x", "bit-exact", "libm");
            for &x in &xs {
                let bitexact = exp_unit(Bf16::from_f32(x)).to_f32();
                println!("{x:>10.4}  {bitexact:>12.6}  {:>12.6}", x.exp());
            }
        }
    }
    Ok(())
}

fn softmax_cmd(args: &[String]) -> Result<()> {
    let rows: usize = args.first().and_then(|a| a.parse().ok()).unwrap_or(8);
    let cols: usize = args.get(1).and_then(|a| a.parse().ok()).unwrap_or(1024);
    let data: Vec<Vec<f32>> = (0..rows)
        .map(|r| (0..cols).map(|i| ((i * 7 + r * 13) % 97) as f32 * 0.15 - 7.0).collect())
        .collect();
    println!("softmax {rows}x{cols} on one cluster:");
    println!("{:24} {:>12} {:>10} {:>12} {:>10}", "variant", "cyc/output", "speedup", "energy pJ/o", "power mW");
    let mut base_cyc = 0.0;
    for v in SoftmaxVariant::ALL {
        let run = run_softmax(v, &data);
        if v == SoftmaxVariant::Baseline {
            base_cyc = run.cycles_per_output;
        }
        let ext = v == SoftmaxVariant::SwExpHw;
        let e = cluster_energy_pj(&run.stats, ext);
        let pj = e.total() / (rows * cols) as f64;
        println!(
            "{:24} {:>12.2} {:>9.1}x {:>12.1} {:>10.1}",
            v.label(),
            run.cycles_per_output,
            base_cyc / run.cycles_per_output,
            pj,
            power_mw(e.total(), run.stats.cycles) / 8.0
        );
    }
    Ok(())
}

fn flash_cmd() -> Result<()> {
    let (sq, sk, d, bk) = (32u32, 128u32, 64u32, 32u32);
    let q: Vec<f32> = (0..sq * d).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let k: Vec<f32> = (0..sk * d).map(|i| ((i % 29) as f32 - 14.0) * 0.05).collect();
    let v: Vec<f32> = (0..sk * d).map(|i| ((i % 23) as f32 - 11.0) * 0.05).collect();
    println!("FlashAttention-2, head dim {d} (GPT-2 config), Sq={sq} Sk={sk}:");
    let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
    let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
    let eb = cluster_energy_pj(&base.stats, false).total();
    let eo = cluster_energy_pj(&opt.stats, true).total();
    println!("  baseline : {:>10} cycles  {:>12.0} pJ", base.stats.cycles, eb);
    println!("  optimized: {:>10} cycles  {:>12.0} pJ", opt.stats.cycles, eo);
    println!(
        "  speedup {:.1}x (paper: up to 8.2x), energy {:.1}x (paper: up to 4.1x)",
        base.stats.cycles as f64 / opt.stats.cycles as f64,
        eb / eo
    );
    Ok(())
}

fn e2e_cmd(args: &[String]) -> Result<()> {
    let filter = args.first().map(|s| s.to_lowercase());
    println!("calibrating kernel rates on the simulator...");
    let mut backend = AnalyticBackend::new();
    println!(
        "{:12} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "model", "BL ms", "Optim ms", "speedup", "BL mJ", "Optim mJ", "energy"
    );
    for cfg in ALL_MODELS {
        if let Some(f) = &filter {
            if !cfg.name.to_lowercase().contains(f) {
                continue;
            }
        }
        let b = backend.estimate(&Request::baseline(0, cfg));
        let o = backend.estimate(&Request::new(1, cfg));
        println!(
            "{:12} {:>12.2} {:>12.2} {:>7.1}x {:>12.2} {:>12.2} {:>7.1}x",
            cfg.name,
            b.latency_ms(),
            o.latency_ms(),
            b.cycles / o.cycles,
            b.energy_mj(),
            o.energy_mj(),
            b.energy_pj / o.energy_pj
        );
    }
    Ok(())
}

/// Batched serving demo: six concurrent requests (mixed models, mixed
/// sequence lengths) packed onto the 16 clusters and executed for real
/// on the cycle-accurate backend, with the analytic backend rating the
/// same batch for comparison.
fn serve_cmd() -> Result<()> {
    let mut gpt2_short = GPT2_SMALL;
    gpt2_short.seq = 512;
    let mix = [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE, GPT2_SMALL, gpt2_short];

    let mut engine = Engine::new();
    for cfg in mix {
        engine.submit(cfg);
    }
    println!("serving {} concurrent requests on the {CLUSTERS}-cluster system", mix.len());
    let batch = engine.compile_batch();
    println!(
        "compiled batch: {} programs cached, {} hits / {} misses this batch",
        engine.cache.len(),
        batch.cache_hits,
        batch.cache_misses
    );

    let mut sim = CycleSimBackend::new(CLUSTERS);
    let measured = sim.execute(&batch);
    let mut ana = AnalyticBackend::new();
    let rated = ana.execute(&batch);

    println!(
        "{:>3} {:12} {:>5} {:>7} {:>7} {:>12} {:>12} {:>12} {:>7}",
        "id", "model", "seq", "clstrs", "rounds", "sim cyc", "rated cyc", "energy pJ", "sm%"
    );
    for (cr, (m, a)) in batch
        .requests
        .iter()
        .zip(measured.per_request.iter().zip(&rated.per_request))
    {
        println!(
            "{:>3} {:12} {:>5} {:>7} {:>7} {:>12.0} {:>12.0} {:>12.0} {:>6.1}%",
            cr.req.id,
            cr.req.cfg.name,
            cr.req.cfg.seq,
            cr.clusters.len(),
            cr.rounds,
            m.cycles,
            a.cycles,
            m.energy_pj,
            m.softmax_share() * 100.0
        );
    }
    println!(
        "batch makespan {} cycles, {} HBM bytes; backends: {} vs {}",
        measured.makespan_cycles, measured.hbm_bytes, measured.backend, rated.backend
    );
    Ok(())
}

fn area_cmd() -> Result<()> {
    let m = AreaModel::default();
    let r = m.report();
    println!("GF12 area (Fig. 5):");
    println!("  EXP block / core : {:.0} um^2 ({} kGE)", m.exp_block_um2(), 8);
    println!("  FPU subsystem    : {:>8.0} kGE (+{:.1}%)", r.fpu_ss_kge, r.fpu_ss_overhead * 100.0);
    println!("  core complex     : {:>8.0} kGE (+{:.1}%)", r.core_complex_kge, r.core_complex_overhead * 100.0);
    println!("  cluster          : {:>8.0} kGE (+{:.1}%)", r.cluster_kge, r.cluster_overhead * 100.0);
    Ok(())
}
