//! `vexp` CLI — the Layer-3 leader binary.
//!
//! Subcommands map onto the paper's experiments plus the serving engine:
//!   info                      system + artifact inventory
//!   exp <x...>                exponentials via the PJRT vexp artifact
//!                             (with `--features pjrt`), cross-checked
//!                             against the bit-exact model
//!   softmax [rows] [cols]     the four kernel configurations (Fig. 6a-c)
//!   flashattention            FA-2 baseline vs optimized (Fig. 6d-f)
//!   e2e [model]               16-cluster end-to-end estimate (Fig. 8),
//!                             through the unified Backend API
//!   serve [--tokens N] [--prompt N] [--stagger N] [--iters N] [--analytic]
//!                             multi-tenant continuously-batched decode
//!                             demo: mixed GPT-2 + ViT traffic with
//!                             staggered arrivals on the 16-cluster
//!                             backend; reports TTFT, per-token latency,
//!                             tokens/s and energy per request
//!   serve --trace poisson|burst [--requests N] [--gap CYC] [--seed N]
//!         [--faults SPEC] [--slo TTFT_MS:TOKEN_US] [--deadline MS]
//!                             the resilient serving loop (DESIGN.md
//!                             §12): open-loop arrival trace, seeded
//!                             fault injection, admission control,
//!                             bounded retries around quarantined
//!                             clusters, per-request deadlines and
//!                             graceful degradation; prints the SLO
//!                             report (tail percentiles, attainment,
//!                             shed/retry/quarantine counts, health)
//!   bench [--json <path>] [--small] [--fast-only] [--compare <path>]
//!                             fig6 softmax + FlashAttention sweep with
//!                             simulated cycles AND host wall-clock per
//!                             configuration (fast path vs reference
//!                             interpreter), plus a raw-tier GPT-3
//!                             prefill+decode e2e row (tile memo +
//!                             sampled simulation vs the full fast
//!                             path); written as BENCH_sim.json
//!   area                      GF12 area report (Fig. 5)

use vexp::bf16::Bf16;
use vexp::coordinator::CLUSTERS;
use vexp::energy::power::{cluster_energy_pj, power_mw};
use vexp::energy::AreaModel;
use vexp::error::Result;
use vexp::exec::{
    AnalyticBackend, Backend, CycleSimBackend, Engine, Outcome, PagedKvOptions, Request,
    SchedPolicy, ServeOptions, SpecDecodeOptions, TraceKind, TraceSpec,
};
use vexp::kernels::flash_attention::{run_flash_attention, FaVariant};
use vexp::kernels::softmax::{run_softmax, SoftmaxVariant};
use vexp::model::config::{by_short_name, ALL_MODELS, GPT2_SMALL, GPT3_XL, VIT_BASE};
use vexp::model::TransformerConfig;
use vexp::runtime::pjrt::Input;
use vexp::runtime::Runtime;
use vexp::sim::{FaultPlan, FaultSpec};
use vexp::vexp::exp_unit;

/// The CLI contract, printed on bare invocation and on every usage error.
const USAGE: &str = "usage: vexp <info|exp|softmax|flashattention|e2e|serve|bench|area> [args]\n\
     \n\
     serve options:\n\
       --tokens N     decode-token target per GPT request (default 12)\n\
       --prompt N     GPT-2 prompt length (default 256)\n\
       --stagger N    arrival spacing in iterations (default 2)\n\
       --iters N      iteration safety bound (default 256)\n\
       --analytic     rate the run on the analytic backend\n\
                      instead of the cycle-accurate simulator\n\
       --trace T      open-loop trace mode, T = poisson | burst: runs\n\
                      the resilient serving loop and prints an SLO\n\
                      report instead of the staggered-arrival demo\n\
       --requests N   trace length in requests (default 12)\n\
       --gap CYC      mean inter-arrival gap in cycles (default 100000)\n\
       --seed N       trace + fault-plan PRNG seed (default 1)\n\
       --faults SPEC  off | chaos | zero | \n\
                      slow=P:FACTOR,stall=P:CYCLES,fail=P,offline=N\n\
       --slo T:U      SLO targets, TTFT ms : per-token us (default 5:1000)\n\
       --deadline MS  per-request deadline, ms after arrival (default 25)\n\
       --policy P     scheduling objective stamped on every trace\n\
                      request, P = throughput | latency (default\n\
                      throughput; latency jumps the admission queue,\n\
                      gets a boosted cluster share and is preempted\n\
                      last)\n\
       --kv-block KB  run the paged KV tier (DESIGN.md \u{a7}14) with\n\
                      KB-KiB cache blocks (default 1024 when any\n\
                      paging flag is set)\n\
       --kv-pool KB   total paged KV pool size in KiB (default 65536);\n\
                      small pools force LRU eviction and preemption\n\
       --share-prefix enable radix-tree prefix sharing: same-class\n\
                      requests share prompt-head blocks and skip that\n\
                      much prefill\n\
       --speculative D:K  speculative decoding (DESIGN.md \u{a7}15): draft\n\
                      model D = gpt2|gpt3|vit-base|vit-huge proposes K\n\
                      tokens per decode iteration; the target model\n\
                      verifies them in one prefill-shaped pass (K = 0\n\
                      reduces to plain decode)\n\
       --chunk-prefill N  split prompts into N-token prefill chunks\n\
                      interleaved with decode iterations (rounded up\n\
                      to whole KV blocks on the paged tier)\n\
     bench options:\n\
       --json PATH    write the measured sweep as JSON\n\
       --small        single tiny configuration (CI smoke)\n\
       --fast-only    skip the reference-interpreter timing leg\n\
                      (the fast-vs-reference differential check\n\
                      stays the default)\n\
       --compare PATH gate simulated cycles against a committed\n\
                      baseline; wall-clock is reported, never\n\
                      gated; a \"provisional\": true baseline\n\
                      reports divergences without failing";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("vexp: {e}\n\n{USAGE}");
        std::process::exit(2);
    }
}

/// Dispatch one CLI invocation. Every malformed flag or value comes
/// back as an `Err` (never a panic), which `main` turns into usage +
/// a non-zero exit; the unit tests below drive this directly.
fn run(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("info") => info(),
        Some("exp") => exp_cmd(&args[1..]),
        Some("softmax") => softmax_cmd(&args[1..]),
        Some("flashattention") => flash_cmd(),
        Some("e2e") => e2e_cmd(&args[1..]),
        Some("serve") => serve_cmd(&args[1..]),
        Some("bench") => bench_cmd(&args[1..]),
        Some("area") => area_cmd(),
        Some(other) => vexp::bail!("unknown subcommand {other:?}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

/// The flag's value argument, or a usage error naming the flag.
fn flag_val<'a>(v: Option<&'a String>, flag: &str) -> Result<&'a str> {
    match v {
        Some(s) => Ok(s.as_str()),
        None => vexp::bail!("{flag} requires a value"),
    }
}

/// Parse a flag value as a positive `u32`.
fn flag_u32(v: Option<&String>, flag: &str) -> Result<u32> {
    match flag_val(v, flag)?.parse::<u32>() {
        Ok(n) if n > 0 => Ok(n),
        _ => vexp::bail!("{flag} requires a positive integer"),
    }
}

/// Parse a flag value as a positive `u64`.
fn flag_u64(v: Option<&String>, flag: &str) -> Result<u64> {
    match flag_val(v, flag)?.parse::<u64>() {
        Ok(n) if n > 0 => Ok(n),
        _ => vexp::bail!("{flag} requires a positive integer"),
    }
}

/// Parse a flag value as any `u64` (seeds may be 0).
fn flag_seed(v: Option<&String>, flag: &str) -> Result<u64> {
    flag_val(v, flag)?
        .parse::<u64>()
        .map_err(|_| vexp::err!("{flag} requires an unsigned integer"))
}

/// Parse a flag value as a positive finite float.
fn flag_f64(v: Option<&String>, flag: &str) -> Result<f64> {
    match flag_val(v, flag)?.parse::<f64>() {
        Ok(x) if x > 0.0 && x.is_finite() => Ok(x),
        _ => vexp::bail!("{flag} requires a positive number"),
    }
}

/// Parse `--slo TTFT_MS:TOKEN_US` into its two positive targets.
fn parse_slo(s: &str) -> Result<(f64, f64)> {
    let parsed = s.split_once(':').and_then(|(t, u)| {
        let t = t.parse::<f64>().ok().filter(|x| *x > 0.0 && x.is_finite())?;
        let u = u.parse::<f64>().ok().filter(|x| *x > 0.0 && x.is_finite())?;
        Some((t, u))
    });
    parsed.ok_or_else(|| {
        vexp::err!("--slo wants TTFT_MS:TOKEN_US as positive numbers, got {s:?}")
    })
}

fn info() -> Result<()> {
    println!("VEXP reproduction — Snitch cluster + BF16 EXP ISA extension");
    println!("cluster: 8 cores, 128 KiB SPM, FREP+SSR+SIMD, VFEXP @ 2 cycles");
    match Runtime::open("artifacts") {
        Ok(rt) => {
            println!("artifacts ({}):", rt.artifact_dir().display());
            for ep in rt.entry_points() {
                let art = rt.artifact(ep).unwrap();
                println!("  {ep:20} inputs {:?}", art.inputs.len());
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

fn exp_cmd(args: &[String]) -> Result<()> {
    let xs: Vec<f32> = if args.is_empty() {
        vec![-2.0, -1.0, 0.0, 1.0, 2.0]
    } else {
        let mut xs = Vec::with_capacity(args.len());
        for a in args {
            match a.parse::<f32>() {
                Ok(x) => xs.push(x),
                Err(_) => vexp::bail!("exp: {a:?} is not a number"),
            }
        }
        if xs.len() > 4096 {
            vexp::bail!("exp: at most 4096 inputs per invocation, got {}", xs.len());
        }
        xs
    };
    let mut buf = vec![0.0f32; 4096];
    buf[..xs.len()].copy_from_slice(&xs);
    let pjrt_out = Runtime::open("artifacts")
        .and_then(|mut rt| rt.execute("vexp", &[Input::F32(&buf)]));
    match pjrt_out {
        Ok(out) => {
            println!("{:>10}  {:>12}  {:>12}  {:>12}", "x", "pjrt", "bit-exact", "libm");
            for (i, &x) in xs.iter().enumerate() {
                let bitexact = exp_unit(Bf16::from_f32(x)).to_f32();
                println!("{x:>10.4}  {:>12.6}  {bitexact:>12.6}  {:>12.6}", out[i], x.exp());
                assert_eq!(out[i], bitexact, "PJRT and Rust EXP models disagree!");
            }
            println!("PJRT artifact and bit-exact Rust model agree on all inputs.");
        }
        Err(e) => {
            println!("(PJRT path unavailable: {e})");
            println!("{:>10}  {:>12}  {:>12}", "x", "bit-exact", "libm");
            for &x in &xs {
                let bitexact = exp_unit(Bf16::from_f32(x)).to_f32();
                println!("{x:>10.4}  {bitexact:>12.6}  {:>12.6}", x.exp());
            }
        }
    }
    Ok(())
}

fn softmax_cmd(args: &[String]) -> Result<()> {
    if args.len() > 2 {
        vexp::bail!("softmax: expected at most [rows] [cols], got {} arguments", args.len());
    }
    let dim = |v: Option<&String>, name: &str, default: usize| -> Result<usize> {
        match v {
            None => Ok(default),
            Some(s) => match s.parse::<usize>() {
                Ok(n) if n > 0 => Ok(n),
                _ => vexp::bail!("softmax: {name} must be a positive integer, got {s:?}"),
            },
        }
    };
    let rows = dim(args.first(), "rows", 8)?;
    let cols = dim(args.get(1), "cols", 1024)?;
    let data: Vec<Vec<f32>> = (0..rows)
        .map(|r| (0..cols).map(|i| ((i * 7 + r * 13) % 97) as f32 * 0.15 - 7.0).collect())
        .collect();
    println!("softmax {rows}x{cols} on one cluster:");
    println!("{:24} {:>12} {:>10} {:>12} {:>10}", "variant", "cyc/output", "speedup", "energy pJ/o", "power mW");
    let mut base_cyc = 0.0;
    for v in SoftmaxVariant::ALL {
        let run = run_softmax(v, &data);
        if v == SoftmaxVariant::Baseline {
            base_cyc = run.cycles_per_output;
        }
        let ext = v == SoftmaxVariant::SwExpHw;
        let e = cluster_energy_pj(&run.stats, ext);
        let pj = e.total() / (rows * cols) as f64;
        println!(
            "{:24} {:>12.2} {:>9.1}x {:>12.1} {:>10.1}",
            v.label(),
            run.cycles_per_output,
            base_cyc / run.cycles_per_output,
            pj,
            power_mw(e.total(), run.stats.cycles) / 8.0
        );
    }
    Ok(())
}

fn flash_cmd() -> Result<()> {
    let (sq, sk, d, bk) = (32u32, 128u32, 64u32, 32u32);
    let q: Vec<f32> = (0..sq * d).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let k: Vec<f32> = (0..sk * d).map(|i| ((i % 29) as f32 - 14.0) * 0.05).collect();
    let v: Vec<f32> = (0..sk * d).map(|i| ((i % 23) as f32 - 11.0) * 0.05).collect();
    println!("FlashAttention-2, head dim {d} (GPT-2 config), Sq={sq} Sk={sk}:");
    let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
    let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
    let eb = cluster_energy_pj(&base.stats, false).total();
    let eo = cluster_energy_pj(&opt.stats, true).total();
    println!("  baseline : {:>10} cycles  {:>12.0} pJ", base.stats.cycles, eb);
    println!("  optimized: {:>10} cycles  {:>12.0} pJ", opt.stats.cycles, eo);
    println!(
        "  speedup {:.1}x (paper: up to 8.2x), energy {:.1}x (paper: up to 4.1x)",
        base.stats.cycles as f64 / opt.stats.cycles as f64,
        eb / eo
    );
    Ok(())
}

fn e2e_cmd(args: &[String]) -> Result<()> {
    let filter = args.first().map(|s| s.to_lowercase());
    println!("calibrating kernel rates on the simulator...");
    let mut backend = AnalyticBackend::new();
    println!(
        "{:12} {:>12} {:>12} {:>8} {:>12} {:>12} {:>8}",
        "model", "BL ms", "Optim ms", "speedup", "BL mJ", "Optim mJ", "energy"
    );
    for cfg in ALL_MODELS {
        if let Some(f) = &filter {
            if !cfg.name.to_lowercase().contains(f) {
                continue;
            }
        }
        let b = backend.estimate(&Request::baseline(0, cfg));
        let o = backend.estimate(&Request::new(1, cfg));
        println!(
            "{:12} {:>12.2} {:>12.2} {:>7.1}x {:>12.2} {:>12.2} {:>7.1}x",
            cfg.name,
            b.latency_ms(),
            o.latency_ms(),
            b.cycles / o.cycles,
            b.energy_mj(),
            o.energy_mj(),
            b.energy_pj / o.energy_pj
        );
    }
    Ok(())
}

/// Multi-tenant continuously-batched decode demo: mixed GPT-2 + ViT
/// traffic with staggered arrivals, served through the continuous
/// batching loop (DESIGN.md §10). GPT requests prefill their prompt and
/// then decode against their growing KV-cache one token per iteration;
/// ViT requests are prefill-only tenants that join and retire
/// mid-flight while the cluster shares rebalance.
fn serve_cmd(args: &[String]) -> Result<()> {
    let mut tokens: u32 = 12;
    let mut prompt: u32 = 256;
    let mut stagger: u32 = 2;
    let mut iters: u32 = 256;
    let mut analytic = false;
    let mut trace: Option<TraceKind> = None;
    let mut requests: usize = 12;
    let mut gap: u64 = 100_000;
    let mut seed: u64 = 1;
    let mut faults = FaultSpec::off();
    let mut slo_ttft_ms: f64 = 5.0;
    let mut slo_token_us: f64 = 1000.0;
    let mut deadline_ms: f64 = 25.0;
    let mut policy = SchedPolicy::Throughput;
    let mut share_prefix = false;
    let mut kv_block_kb: Option<u64> = None;
    let mut kv_pool_kb: Option<u64> = None;
    let mut speculative: Option<(TransformerConfig, u32)> = None;
    let mut chunk_prefill: Option<u32> = None;
    // first trace-only flag seen, to reject it if --trace never shows up
    let mut trace_only: Option<&'static str> = None;

    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--tokens" => tokens = flag_u32(it.next(), "serve: --tokens")?,
            "--prompt" => {
                prompt = flag_u32(it.next(), "serve: --prompt")?.clamp(32, 2048)
            }
            "--stagger" => stagger = flag_u32(it.next(), "serve: --stagger")?,
            "--iters" => iters = flag_u32(it.next(), "serve: --iters")?,
            "--analytic" => analytic = true,
            "--trace" => {
                trace = Some(match flag_val(it.next(), "serve: --trace")? {
                    "poisson" => TraceKind::Poisson,
                    "burst" | "bursty" => TraceKind::Bursty,
                    other => {
                        vexp::bail!("serve: --trace must be poisson|burst, got {other:?}")
                    }
                })
            }
            "--requests" => {
                requests = flag_u32(it.next(), "serve: --requests")? as usize;
                trace_only.get_or_insert("--requests");
            }
            "--gap" => {
                gap = flag_u64(it.next(), "serve: --gap")?;
                trace_only.get_or_insert("--gap");
            }
            "--seed" => {
                seed = flag_seed(it.next(), "serve: --seed")?;
                trace_only.get_or_insert("--seed");
            }
            "--faults" => {
                faults = FaultSpec::parse(flag_val(it.next(), "serve: --faults")?)?;
                trace_only.get_or_insert("--faults");
            }
            "--slo" => {
                (slo_ttft_ms, slo_token_us) =
                    parse_slo(flag_val(it.next(), "serve: --slo")?)?;
                trace_only.get_or_insert("--slo");
            }
            "--deadline" => {
                deadline_ms = flag_f64(it.next(), "serve: --deadline")?;
                trace_only.get_or_insert("--deadline");
            }
            "--policy" => {
                policy = match flag_val(it.next(), "serve: --policy")? {
                    "throughput" => SchedPolicy::Throughput,
                    "latency" => SchedPolicy::Latency,
                    other => {
                        vexp::bail!("serve: --policy must be throughput|latency, got {other:?}")
                    }
                };
                trace_only.get_or_insert("--policy");
            }
            "--share-prefix" => {
                share_prefix = true;
                trace_only.get_or_insert("--share-prefix");
            }
            "--kv-block" => {
                kv_block_kb = Some(flag_u64(it.next(), "serve: --kv-block")?);
                trace_only.get_or_insert("--kv-block");
            }
            "--kv-pool" => {
                kv_pool_kb = Some(flag_u64(it.next(), "serve: --kv-pool")?);
                trace_only.get_or_insert("--kv-pool");
            }
            "--speculative" => {
                speculative =
                    Some(parse_speculative(flag_val(it.next(), "serve: --speculative")?)?);
                trace_only.get_or_insert("--speculative");
            }
            "--chunk-prefill" => {
                chunk_prefill = Some(flag_u32(it.next(), "serve: --chunk-prefill")?);
                trace_only.get_or_insert("--chunk-prefill");
            }
            other => vexp::bail!("serve: unknown flag {other}"),
        }
    }

    if let Some(kind) = trace {
        if analytic {
            vexp::bail!(
                "serve: --analytic is not supported with --trace (the fault \
                 layer lives in the cycle simulator; the analytic backend is \
                 the degradation fallback instead)"
            );
        }
        // any paging flag arms the paged KV tier with defaults for the
        // others (1 MiB blocks, 64 MiB pool, sharing off)
        let paging = if kv_block_kb.is_some() || kv_pool_kb.is_some() || share_prefix {
            Some(PagedKvOptions {
                block_bytes: kv_block_kb.unwrap_or(1024) * 1024,
                pool_bytes: kv_pool_kb.unwrap_or(65536) * 1024,
                share_prefix,
            })
        } else {
            None
        };
        return serve_trace_cmd(TraceServeCfg {
            kind,
            requests,
            gap,
            seed,
            faults,
            slo_ttft_ms,
            slo_token_us,
            deadline_ms,
            prompt,
            tokens,
            iters,
            policy,
            paging,
            speculative,
            chunk_prefill,
        });
    }
    if let Some(flag) = trace_only {
        vexp::bail!("serve: {flag} requires --trace poisson|burst");
    }

    let mut gpt2 = GPT2_SMALL;
    gpt2.seq = prompt;
    let mut gpt2_long = GPT2_SMALL;
    gpt2_long.seq = (2 * prompt).min(2048);

    let traffic = [
        Request::new(0, gpt2).with_tokens(tokens),
        Request::new(0, VIT_BASE).arriving_at(1),
        Request::new(0, gpt2_long).with_tokens(tokens / 2 + 1).arriving_at(stagger),
        Request::new(0, gpt2).with_tokens(2 * tokens).arriving_at(2 * stagger),
        Request::new(0, VIT_BASE).arriving_at(2 * stagger),
        Request::baseline(0, gpt2).with_tokens(tokens).arriving_at(3 * stagger),
    ];
    let mut engine = Engine::new();
    let ids: Vec<u64> = traffic.iter().map(|r| engine.submit_request(*r)).collect();

    println!(
        "continuous batching on the {CLUSTERS}-cluster system: {} requests, \
         mixed GPT-2 ({}–{} prompt, {}+ tokens) + ViT-Base traffic, arrivals staggered {stagger} iterations",
        engine.pending(),
        prompt,
        gpt2_long.seq,
        tokens
    );

    let report = if analytic {
        let mut backend = AnalyticBackend::new();
        engine.serve(&mut backend, None, &ServeOptions::legacy(iters))
    } else {
        let mut backend = CycleSimBackend::new(CLUSTERS);
        engine.serve(&mut backend, None, &ServeOptions::legacy(iters))
    };

    println!(
        "{:>3} {:12} {:>7} {:>7} {:>7} {:>10} {:>12} {:>10} {:>10}",
        "id", "model", "prompt", "arrive", "tokens", "TTFT ms", "tok lat us", "tok/s", "energy mJ"
    );
    for r in &report.per_request {
        let sub = ids
            .iter()
            .position(|&id| id == r.request_id)
            .map(|i| traffic[i])
            .expect("report id matches a submitted request");
        println!(
            "{:>3} {:12} {:>7} {:>7} {:>7} {:>10.3} {:>12.1} {:>10.1} {:>10.3}",
            r.request_id,
            r.model,
            sub.prompt_len(),
            sub.arrival_iter,
            r.tokens,
            r.ttft_ms(),
            r.token_latency_us(),
            r.tokens_per_s(),
            r.energy_mj()
        );
    }
    println!(
        "{} iterations, {} cycles ({:.3} ms) end-to-end; {} tokens total -> {:.1} tok/s aggregate; \
         {:.3} mJ; backend: {}; program cache: {} entries, {} hits / {} misses",
        report.iterations,
        report.total_cycles,
        report.total_cycles as f64 / 1e6,
        report.total_tokens(),
        report.tokens_per_s(),
        report.total_energy_pj() / 1e9,
        report.backend,
        engine.cache.len(),
        engine.cache.hits,
        engine.cache.misses
    );
    Ok(())
}

/// Parse `--speculative DRAFT:K`: a draft-model short name and the
/// per-iteration draft depth (`K = 0` is allowed — it reduces to plain
/// decode, which is exactly what the reduction tests pin down).
fn parse_speculative(s: &str) -> Result<(TransformerConfig, u32)> {
    let Some((model, k)) = s.split_once(':') else {
        vexp::bail!("serve: --speculative wants DRAFT:K (e.g. gpt2:4), got {s:?}")
    };
    let Some(cfg) = by_short_name(model) else {
        vexp::bail!(
            "serve: --speculative draft model must be gpt2|gpt3|vit-base|vit-huge, got {model:?}"
        )
    };
    match k.parse::<u32>() {
        Ok(k) => Ok((cfg, k)),
        Err(_) => vexp::bail!("serve: --speculative K must be an unsigned integer, got {k:?}"),
    }
}

/// Parsed configuration of `vexp serve --trace ...`.
struct TraceServeCfg {
    kind: TraceKind,
    requests: usize,
    gap: u64,
    seed: u64,
    faults: FaultSpec,
    slo_ttft_ms: f64,
    slo_token_us: f64,
    deadline_ms: f64,
    prompt: u32,
    tokens: u32,
    iters: u32,
    policy: SchedPolicy,
    paging: Option<PagedKvOptions>,
    speculative: Option<(TransformerConfig, u32)>,
    chunk_prefill: Option<u32>,
}

/// Trace-driven resilient serving (DESIGN.md §12): seeded open-loop
/// arrivals + seeded fault injection on the cycle-accurate backend with
/// the analytic backend as degradation fallback, then the SLO report.
/// Every printed number derives from simulated cycles only — the same
/// seed reproduces the output byte-for-byte (the CI smoke diffs two
/// invocations).
fn serve_trace_cmd(cfg: TraceServeCfg) -> Result<()> {
    let ttft_slo = (cfg.slo_ttft_ms * 1e6) as u64; // 1 GHz: 1 ms = 1e6 cycles
    let token_slo = (cfg.slo_token_us * 1e3) as u64;
    let deadline = (cfg.deadline_ms * 1e6) as u64;
    let spec = match cfg.kind {
        TraceKind::Poisson => TraceSpec::poisson(cfg.requests, cfg.gap as f64, cfg.seed),
        TraceKind::Bursty => TraceSpec::bursty(cfg.requests, cfg.gap as f64, cfg.seed),
    };

    let arrivals = spec.arrivals();
    let mut engine = Engine::new();
    // the paged tier gets the prefix-shareable, policy-stamped stream
    // (DESIGN.md §14); the legacy tier keeps the plain mix
    let latency_every = if cfg.policy == SchedPolicy::Latency { 1 } else { 0 };
    let traffic = if cfg.paging.is_some() {
        spec.mixed_traffic_paged(cfg.prompt, cfg.tokens, Some(deadline), latency_every)
    } else {
        let mut t = spec.mixed_traffic(cfg.prompt, cfg.tokens, Some(deadline));
        if cfg.policy == SchedPolicy::Latency {
            for r in &mut t {
                *r = r.with_policy(SchedPolicy::Latency);
            }
        }
        t
    };
    for r in traffic {
        engine.submit_request(r); // ids are 0..requests, in trace order
    }

    let mut opts = ServeOptions::new()
        .max_iters(cfg.iters)
        .max_live(6)
        .max_queue(4)
        .ttft_slo(ttft_slo)
        .token_slo(token_slo)
        .deadline(deadline)
        .shed_over_projected_ttft(true)
        .degrade_at(4, 10);
    if let Some(p) = cfg.paging {
        opts = opts.paging(p);
    }
    if let Some((draft, k)) = cfg.speculative {
        // the acceptance stream shares the trace seed, so one --seed
        // reproduces the whole run (trace, faults, acceptance)
        opts = opts.speculative(SpecDecodeOptions::new(draft, k).seed(cfg.seed));
    }
    if let Some(n) = cfg.chunk_prefill {
        opts = opts.chunked_prefill(n);
    }

    let armed = cfg.faults != FaultSpec::off();
    let mut primary = CycleSimBackend::new(CLUSTERS);
    if armed {
        primary.system.faults = Some(FaultPlan::new(cfg.faults, cfg.seed, CLUSTERS));
    }
    let mut fallback = AnalyticBackend::new();

    println!(
        "resilient serving on the {CLUSTERS}-cluster system: {} requests, \
         {:?} trace (mean gap {} cycles), seed {}, faults {}",
        cfg.requests,
        cfg.kind,
        cfg.gap,
        cfg.seed,
        if armed { format!("{:?}", cfg.faults) } else { "off".to_string() }
    );
    let report = engine.serve(&mut primary, Some(&mut fallback), &opts);

    println!(
        "{:>3} {:12} {:>12} {:>7} {:>10} {:>10} {:>12} {:>8}",
        "id", "model", "arrive cyc", "tokens", "outcome", "TTFT ms", "tok lat us", "retries"
    );
    for r in &report.per_request {
        let outcome = match r.outcome {
            Outcome::Completed => "completed",
            Outcome::Shed => "shed",
            Outcome::TimedOut => "timed-out",
            Outcome::Unfinished => "unfinished",
        };
        println!(
            "{:>3} {:12} {:>12} {:>7} {:>10} {:>10.3} {:>12.1} {:>8}",
            r.request_id,
            r.model,
            arrivals.get(r.request_id as usize).copied().unwrap_or(0),
            r.tokens,
            outcome,
            r.ttft_ms(),
            r.token_latency_us(),
            r.retries
        );
    }

    let s = &report.slo;
    println!("SLO report (targets: TTFT {} ms, token {} us, deadline {} ms):",
        cfg.slo_ttft_ms, cfg.slo_token_us, cfg.deadline_ms);
    println!(
        "  TTFT  ms: p50 {:.3}  p95 {:.3}  p99 {:.3}",
        s.ttft_p50_cycles / 1e6,
        s.ttft_p95_cycles / 1e6,
        s.ttft_p99_cycles / 1e6
    );
    println!(
        "  token us: p50 {:.1}  p95 {:.1}  p99 {:.1}",
        s.token_p50_cycles / 1e3,
        s.token_p95_cycles / 1e3,
        s.token_p99_cycles / 1e3
    );
    println!("  attainment {:.1}% of all requests", s.attainment * 100.0);
    println!(
        "  attainment by policy: throughput {:.1}%, latency {:.1}%",
        s.attainment_throughput * 100.0,
        s.attainment_latency * 100.0
    );
    println!(
        "  outcomes: {} completed, {} shed, {} timed out, {} unfinished",
        s.completed, s.shed, s.timed_out, s.unfinished
    );
    println!(
        "  resilience: retries {}, faults injected {}, quarantine events {}",
        s.retries, s.faults_injected, s.quarantine_events
    );
    let d = &report.decode;
    if cfg.speculative.is_some() || d.spec_rounds > 0 {
        println!(
            "  speculative: rounds {}, drafted {}, accepted {} ({:.1}% acceptance), \
             draft/verify cycles {:.0}/{:.0}",
            d.spec_rounds,
            d.drafted_tokens,
            d.accepted_tokens,
            d.acceptance_rate * 100.0,
            d.draft_cycles,
            d.verify_cycles
        );
    }
    if cfg.chunk_prefill.is_some() || d.prefill_chunks > 0 {
        println!(
            "  chunked prefill: {} chunks across {} requests",
            d.prefill_chunks, d.chunked_requests
        );
    }
    println!(
        "  iterations: {} full, {} sampled, {} analytic ({} total, {} cycles)",
        s.full_iters, s.sampled_iters, s.analytic_iters, report.iterations, report.total_cycles
    );
    if let Some(p) = &report.pool {
        report.assert_consistent(); // paged books must balance on every run
        println!(
            "paged KV pool: {} blocks x {} KiB (peak in use {}, resident at exit {})",
            p.capacity_blocks,
            p.block_bytes / 1024,
            p.peak_blocks_in_use,
            p.resident
        );
        println!(
            "  blocks: {} allocated, {} freed, evictions {}, cow copies {}",
            p.allocated, p.freed, p.evictions, p.cow_copies
        );
        println!("  prefix hits {} ({} tokens saved)", p.prefix_hits, p.prefix_hit_tokens);
        println!(
            "  preemptions {} ({} resumed), shed unfittable {}, deferrals {}",
            p.preemptions, p.resumes, p.shed_unfittable, p.deferrals
        );
    }
    for h in &report.health {
        if h.failures > 0 || h.offline || h.quarantined_iters > 0 {
            println!(
                "  cluster {:>2}: {} failures, {} iterations quarantined{}",
                h.cluster,
                h.failures,
                h.quarantined_iters,
                if h.offline { ", offline" } else { "" }
            );
        }
    }
    Ok(())
}

/// One benchmark configuration's measured row.
struct BenchRow {
    kernel: &'static str,
    variant: &'static str,
    dims: Vec<(&'static str, u64)>,
    cycles: u64,
    wall_ms_fast: f64,
    wall_ms_reference: f64,
}

impl BenchRow {
    fn speedup(&self) -> f64 {
        self.wall_ms_reference / self.wall_ms_fast.max(1e-9)
    }

    fn json(&self) -> String {
        let dims: Vec<String> =
            self.dims.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!(
            "{{\"kernel\": \"{}\", \"variant\": \"{}\", {}, \"cycles\": {}, \
             \"wall_ms_fast\": {:.4}, \"wall_ms_reference\": {:.4}, \
             \"host_speedup\": {:.2}}}",
            self.kernel,
            self.variant,
            dims.join(", "),
            self.cycles,
            self.wall_ms_fast,
            self.wall_ms_reference,
            self.speedup()
        )
    }
}

/// Best-of-`reps` wall-clock of `f` in milliseconds, plus the cluster
/// stats of the first run (the sim is deterministic; reps only steady
/// the host timing).
fn time_best<F: FnMut() -> vexp::sim::ClusterStats>(
    reps: u32,
    mut f: F,
) -> (vexp::sim::ClusterStats, f64) {
    let mut best = f64::INFINITY;
    let mut stats = None;
    for _ in 0..reps.max(1) {
        let t0 = std::time::Instant::now();
        let s = f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
        stats.get_or_insert(s);
    }
    (stats.expect("reps >= 1"), best)
}

/// Assert two cluster runs are bit-identical: makespan, per-core count,
/// and every aggregated counter (retired per class, FLOPs, EXPs, SSR
/// beats, memory traffic).
fn assert_stats_identical(
    fast: &vexp::sim::ClusterStats,
    reference: &vexp::sim::ClusterStats,
    what: &str,
) {
    assert_eq!(fast.cycles, reference.cycles, "{what}: cycles diverge");
    assert_eq!(fast.per_core.len(), reference.per_core.len(), "{what}: core count");
    let f = fast.combined();
    let r = reference.combined();
    assert_eq!(f.flops, r.flops, "{what}: flops diverge");
    assert_eq!(f.exp_ops, r.exp_ops, "{what}: exp_ops diverge");
    assert_eq!(f.ssr_beats, r.ssr_beats, "{what}: ssr_beats diverge");
    assert_eq!(f.mem_bytes, r.mem_bytes, "{what}: mem_bytes diverge");
    for c in vexp::sim::stats::CLASSES {
        assert_eq!(f.count(c), r.count(c), "{what}: retired {c:?} diverge");
    }
}

/// `vexp bench [--json <path>] [--small]`: fig6 kernel configurations
/// with simulated cycles and host wall-clock for both executors. The
/// fast path's stats are asserted bit-identical to the reference before
/// a row is reported, so the bench doubles as a differential check.
fn bench_cmd(args: &[String]) -> Result<()> {
    use vexp::kernels::flash_attention::{build_fa_program, seed_fa_inputs};
    use vexp::kernels::softmax::{build_softmax_program, seed_softmax_inputs};
    use vexp::sim::Cluster;

    let mut json_path: Option<String> = None;
    let mut compare_path: Option<String> = None;
    let mut small = false;
    let mut fast_only = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => match it.next() {
                Some(p) if !p.starts_with("--") => json_path = Some(p.clone()),
                _ => vexp::bail!("bench: --json requires a path argument"),
            },
            "--compare" => match it.next() {
                Some(p) if !p.starts_with("--") => compare_path = Some(p.clone()),
                _ => vexp::bail!("bench: --compare requires a path argument"),
            },
            "--small" => small = true,
            "--fast-only" => fast_only = true,
            other => vexp::bail!("bench: unknown flag {other}"),
        }
    }
    let reps: u32 = if small { 1 } else { 3 };
    let mut rows: Vec<BenchRow> = Vec::new();

    // --- fig6a-c softmax sweep -------------------------------------------
    let seqs: &[u32] = if small { &[64] } else { &[256, 1024, 2048] };
    const SM_ROWS: u32 = 8;
    for &n in seqs {
        for variant in SoftmaxVariant::ALL {
            let program = build_softmax_program(variant, SM_ROWS, n);
            let (fast_stats, fast_ms) = time_best(reps, || {
                let mut cl = Cluster::new();
                seed_softmax_inputs(&mut cl.spm, SM_ROWS, n, 0xBE7C ^ n as u64);
                cl.run_decoded(program.decoded())
            });
            let ref_ms = if fast_only {
                0.0
            } else {
                let (ref_stats, ref_ms) = time_best(reps, || {
                    let mut cl = Cluster::new();
                    seed_softmax_inputs(&mut cl.spm, SM_ROWS, n, 0xBE7C ^ n as u64);
                    cl.run(program.per_core())
                });
                assert_stats_identical(
                    &fast_stats,
                    &ref_stats,
                    &format!("softmax {variant:?} n={n}"),
                );
                ref_ms
            };
            rows.push(BenchRow {
                kernel: "softmax",
                variant: variant.label(),
                dims: vec![("rows", SM_ROWS as u64), ("seq", n as u64)],
                cycles: fast_stats.cycles,
                wall_ms_fast: fast_ms,
                wall_ms_reference: ref_ms,
            });
        }
    }

    // --- fig6d-f FlashAttention sweep ------------------------------------
    let fa_shapes: &[(u32, u32, u32, u32)] = if small {
        &[(16, 64, 64, 32)]
    } else {
        &[(32, 128, 64, 32), (32, 256, 64, 32)]
    };
    for &(sq, sk, d, bk) in fa_shapes {
        for variant in [FaVariant::Baseline, FaVariant::Optimized] {
            let program = build_fa_program(variant, sq, sk, d, bk);
            let (fast_stats, fast_ms) = time_best(reps, || {
                let mut cl = Cluster::new();
                seed_fa_inputs(&mut cl.spm, sq, sk, d, bk, 0xFA ^ sk as u64);
                cl.run_decoded(program.decoded())
            });
            let ref_ms = if fast_only {
                0.0
            } else {
                let (ref_stats, ref_ms) = time_best(reps, || {
                    let mut cl = Cluster::new();
                    seed_fa_inputs(&mut cl.spm, sq, sk, d, bk, 0xFA ^ sk as u64);
                    cl.run(program.per_core())
                });
                assert_stats_identical(
                    &fast_stats,
                    &ref_stats,
                    &format!("fa {variant:?} sk={sk}"),
                );
                ref_ms
            };
            rows.push(BenchRow {
                kernel: "flashattention",
                variant: match variant {
                    FaVariant::Baseline => "Baseline",
                    FaVariant::Optimized => "Optimized",
                },
                dims: vec![
                    ("sq", sq as u64),
                    ("sk", sk as u64),
                    ("d", d as u64),
                    ("bk", bk as u64),
                ],
                cycles: fast_stats.cycles,
                wall_ms_fast: fast_ms,
                wall_ms_reference: ref_ms,
            });
        }
    }

    // --- fig8 e2e: GPT-3 prefill + decode on the raw-speed tier -----------
    // The "fast" leg is the raw tier (tile memo + sampled simulation,
    // DESIGN.md §11); the "reference" leg here is the *full fast path*
    // (memo off, every repetition simulated), not the reference
    // interpreter — this row is what the order-of-magnitude host
    // wall-clock claim in BENCH_sim.json is measured on.
    {
        use vexp::sim::SamplePolicy;
        let (prompt, toks): (u32, u32) = if small { (128, 4) } else { (512, 16) };
        let mut gpt3 = GPT3_XL;
        gpt3.seq = prompt;
        let run_e2e = |backend: &mut dyn Backend| -> (u64, f64, f64) {
            let mut engine = Engine::new();
            engine.submit_request(Request::new(0, gpt3).with_tokens(toks));
            let t0 = std::time::Instant::now();
            let report = engine.serve(backend, None, &ServeOptions::default());
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            let bound: f64 =
                report.per_request.iter().map(|r| r.error_bound_cycles).sum();
            (report.total_cycles, wall_ms, bound)
        };
        let mut raw =
            CycleSimBackend::new(CLUSTERS).with_sampling(SamplePolicy::default());
        let (raw_cycles, raw_ms, bound) = run_e2e(&mut raw);
        let full_ms = if fast_only {
            0.0
        } else {
            let mut full = CycleSimBackend::new(CLUSTERS).without_memo();
            let (full_cycles, full_ms, _) = run_e2e(&mut full);
            // sampling's own accuracy contract, checked end to end: the
            // raw tier's clock may differ from the fully simulated fast
            // path only within the bound it itself reported
            assert!(
                raw_cycles.abs_diff(full_cycles) as f64 <= bound,
                "e2e raw tier diverged beyond its reported bound: \
                 raw {raw_cycles} vs full {full_cycles} (bound {bound})"
            );
            full_ms
        };
        println!(
            "e2e gpt3 prompt={prompt} tokens={toks}: raw tier {raw_cycles} cycles \
             (error bound {bound:.0}), host {raw_ms:.1} ms"
        );
        rows.push(BenchRow {
            kernel: "e2e",
            variant: "gpt3-raw-tier",
            dims: vec![
                ("prompt", prompt as u64),
                ("tokens", toks as u64),
                ("clusters", CLUSTERS as u64),
            ],
            cycles: raw_cycles,
            wall_ms_fast: raw_ms,
            wall_ms_reference: full_ms,
        });
    }

    // --- paged KV serving under memory pressure (DESIGN.md §14) -----------
    // A bursty shared-prefix trace on a pool sized to force evictions;
    // the "reference" leg is the same serve on the reference
    // interpreter, asserted cycle-identical to the fast decoded path.
    {
        let (requests, prompt, toks, pool_kb): (usize, u32, u32, u64) =
            if small { (6, 32, 4, 4096) } else { (12, 64, 8, 8192) };
        let block_kb: u64 = 256;
        let spec = TraceSpec::bursty(requests, 50_000.0, 9);
        let run_paged = |reference: bool| -> (vexp::exec::ServeReport, f64) {
            let mut engine = Engine::new();
            for r in spec.mixed_traffic_paged(prompt, toks, None, 3) {
                engine.submit_request(r);
            }
            let opts = ServeOptions::new().max_iters(512).paging(PagedKvOptions {
                block_bytes: block_kb * 1024,
                pool_bytes: pool_kb * 1024,
                share_prefix: true,
            });
            let mut backend = CycleSimBackend::new(CLUSTERS);
            backend.system.reference_interp = reference;
            let t0 = std::time::Instant::now();
            let report = engine.serve(&mut backend, None, &opts);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            report.assert_consistent();
            (report, wall_ms)
        };
        let (fast, fast_ms) = run_paged(false);
        let ref_ms = if fast_only {
            0.0
        } else {
            let (reference, ref_ms) = run_paged(true);
            assert_eq!(
                fast.total_cycles, reference.total_cycles,
                "paged serve: decoded vs reference interpreter cycles diverge"
            );
            for (f, r) in fast.per_request.iter().zip(&reference.per_request) {
                assert_eq!(
                    (f.request_id, f.tokens, f.outcome),
                    (r.request_id, r.tokens, r.outcome),
                    "paged serve: per-request books diverge across executors"
                );
            }
            ref_ms
        };
        let pool = fast.pool.as_ref().expect("paged run must report its pool");
        println!(
            "paged serve requests={requests} prompt={prompt} tokens={toks}: \
             {} cycles, evictions {}, prefix hits {} ({} tokens saved), \
             preemptions {}",
            fast.total_cycles,
            pool.evictions,
            pool.prefix_hits,
            pool.prefix_hit_tokens,
            pool.preemptions
        );
        rows.push(BenchRow {
            kernel: "paged-serve",
            variant: "burst-shared-prefix",
            dims: vec![
                ("requests", requests as u64),
                ("prompt", prompt as u64),
                ("tokens", toks as u64),
                ("kv_block_kb", block_kb),
                ("pool_kb", pool_kb),
            ],
            cycles: fast.total_cycles,
            wall_ms_fast: fast_ms,
            wall_ms_reference: ref_ms,
        });
    }

    // --- §15 decode-scenario matrix: {GPT-2, GPT-3} x {plain, spec, chunked}
    // Every cell serves the same request mix through the unified
    // `Engine::serve` API under one decode scenario; the "reference" leg
    // re-runs the cell on the reference interpreter and must stay
    // cycle-identical (the §15 differential contract).
    {
        let (requests, prompt, toks): (u64, u32, u32) =
            if small { (2, 32, 6) } else { (3, 64, 8) };
        let mut matrix_cycles = 0u64;
        let (mut matrix_fast_ms, mut matrix_ref_ms) = (0.0f64, 0.0f64);
        let (mut drafted, mut accepted, mut chunks) = (0u64, 0u64, 0u64);
        for (mname, model) in [("gpt2", GPT2_SMALL), ("gpt3", GPT3_XL)] {
            for scenario in ["plain", "speculative", "chunked"] {
                let run_cell = |reference: bool| -> (vexp::exec::ServeReport, f64) {
                    let mut cfg = model;
                    cfg.seq = prompt;
                    let mut engine = Engine::new();
                    for i in 0..requests {
                        engine.submit_request(Request::new(i, cfg).with_tokens(toks));
                    }
                    let mut opts = ServeOptions::new().max_iters(256);
                    match scenario {
                        "speculative" => {
                            opts = opts
                                .speculative(SpecDecodeOptions::new(GPT2_SMALL, 3).seed(15));
                        }
                        "chunked" => opts = opts.chunked_prefill(prompt / 2),
                        _ => {}
                    }
                    let mut backend = CycleSimBackend::new(CLUSTERS);
                    backend.system.reference_interp = reference;
                    let t0 = std::time::Instant::now();
                    let report = engine.serve(&mut backend, None, &opts);
                    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                    report.assert_consistent();
                    (report, wall_ms)
                };
                let (fast, fast_ms) = run_cell(false);
                assert!(
                    fast.per_request.iter().all(|r| r.outcome == Outcome::Completed),
                    "scenario {mname}/{scenario}: every request must complete"
                );
                match scenario {
                    "speculative" => assert!(
                        fast.decode.drafted_tokens > 0,
                        "scenario {mname}/{scenario}: no tokens drafted"
                    ),
                    "chunked" => assert!(
                        fast.decode.prefill_chunks >= 2 * requests,
                        "scenario {mname}/{scenario}: prompts were not split"
                    ),
                    _ => {}
                }
                if !fast_only {
                    let (reference, ref_ms) = run_cell(true);
                    assert_eq!(
                        fast.total_cycles, reference.total_cycles,
                        "scenario {mname}/{scenario}: decoded vs reference \
                         interpreter cycles diverge"
                    );
                    for (f, r) in fast.per_request.iter().zip(&reference.per_request) {
                        assert_eq!(
                            (f.request_id, f.tokens, f.drafted_tokens, f.accepted_tokens),
                            (r.request_id, r.tokens, r.drafted_tokens, r.accepted_tokens),
                            "scenario {mname}/{scenario}: per-request books \
                             diverge across executors"
                        );
                    }
                    matrix_ref_ms += ref_ms;
                }
                matrix_cycles += fast.total_cycles;
                matrix_fast_ms += fast_ms;
                drafted += fast.decode.drafted_tokens;
                accepted += fast.decode.accepted_tokens;
                chunks += fast.decode.prefill_chunks;
            }
        }
        println!(
            "serve scenarios 2 models x 3 scenarios, prompt={prompt} tokens={toks}: \
             {matrix_cycles} cycles, drafted {drafted}, accepted {accepted}, \
             prefill chunks {chunks}"
        );
        rows.push(BenchRow {
            kernel: "serve-scenarios",
            variant: "matrix",
            dims: vec![
                ("models", 2),
                ("scenarios", 3),
                ("requests", requests),
                ("prompt", prompt as u64),
                ("tokens", toks as u64),
            ],
            cycles: matrix_cycles,
            wall_ms_fast: matrix_fast_ms,
            wall_ms_reference: matrix_ref_ms,
        });
    }

    // --- report -----------------------------------------------------------
    println!(
        "{:16} {:26} {:>12} {:>12} {:>12} {:>9}",
        "kernel", "variant/dims", "sim cycles", "fast ms", "ref ms", "speedup"
    );
    let (mut tot_fast, mut tot_ref) = (0.0f64, 0.0f64);
    for r in &rows {
        let dims: Vec<String> = r.dims.iter().map(|(k, v)| format!("{k}={v}")).collect();
        let label = format!("{} {}", r.variant, dims.join(","));
        println!(
            "{:16} {:26} {:>12} {:>12.3} {:>12.3} {:>8.1}x",
            r.kernel,
            label,
            r.cycles,
            r.wall_ms_fast,
            r.wall_ms_reference,
            r.speedup()
        );
        tot_fast += r.wall_ms_fast;
        tot_ref += r.wall_ms_reference;
    }
    let total_speedup = tot_ref / tot_fast.max(1e-9);
    println!(
        "total: fast {tot_fast:.2} ms vs reference {tot_ref:.2} ms -> {total_speedup:.1}x"
    );

    if let Some(path) = json_path {
        let body: Vec<String> = rows.iter().map(|r| format!("    {}", r.json())).collect();
        let json = format!(
            "{{\n  \"bench\": \"vexp-sim\",\n  \"provisional\": false,\n  \
             \"mode\": \"{}\",\n  \"host_reps\": {},\n  \
             \"configs\": [\n{}\n  ],\n  \"total_wall_ms_fast\": {:.4},\n  \
             \"total_wall_ms_reference\": {:.4},\n  \"total_host_speedup\": {:.2}\n}}\n",
            if small { "small" } else { "full" },
            reps,
            body.join(",\n"),
            tot_fast,
            tot_ref,
            total_speedup
        );
        std::fs::write(&path, json)?;
        println!("wrote {path}");
    }
    if let Some(path) = compare_path {
        compare_against_baseline(&rows, &path, small)?;
    }
    Ok(())
}

/// Gate the measured rows against a committed baseline (BENCH_sim.json):
/// simulated cycles must match row-for-row **exactly** — the simulator
/// is deterministic, so any divergence is a timing-model change that
/// needs the baseline re-pinned. Host wall-clock is machine-dependent
/// and is reported but never gates. A baseline marked
/// `"provisional": true` reports divergences without failing, so the
/// gate can be committed before real numbers are pinned; a baseline
/// recorded in a different mode (`--small` vs full) is shape-disjoint
/// and skips the row comparison with a notice.
fn compare_against_baseline(rows: &[BenchRow], path: &str, small: bool) -> Result<()> {
    use vexp::error::Context;
    use vexp::runtime::json::Json;

    let text = std::fs::read_to_string(path)
        .with_context(|| format!("bench: reading baseline {path}"))?;
    let doc =
        Json::parse(&text).map_err(|e| vexp::err!("bench: parsing {path}: {e}"))?;
    let provisional = matches!(doc.get("provisional"), Some(Json::Bool(true)));
    let mode = if small { "small" } else { "full" };
    let base_mode = doc.get("mode").and_then(Json::as_str).unwrap_or("full");
    if base_mode != mode {
        println!(
            "compare: baseline {path} is mode \"{base_mode}\", this run is \
             \"{mode}\" — configurations are disjoint, nothing to gate"
        );
        return Ok(());
    }
    let configs = doc
        .get("configs")
        .and_then(Json::as_arr)
        .context("bench: baseline has no configs array")?;

    let mut divergent: Vec<String> = Vec::new();
    let mut matched = 0usize;
    for row in rows {
        let found = configs.iter().find(|c| {
            c.get("kernel").and_then(Json::as_str) == Some(row.kernel)
                && c.get("variant").and_then(Json::as_str) == Some(row.variant)
                && row
                    .dims
                    .iter()
                    .all(|(k, v)| c.get(k).and_then(Json::as_f64) == Some(*v as f64))
        });
        let Some(base) = found else {
            println!(
                "compare: {} {} has no baseline row (new configuration?)",
                row.kernel, row.variant
            );
            continue;
        };
        let base_cycles = base.get("cycles").and_then(Json::as_f64).unwrap_or(-1.0);
        if base_cycles == row.cycles as f64 {
            matched += 1;
        } else {
            divergent.push(format!(
                "{} {}: {} cycles, baseline has {}",
                row.kernel, row.variant, row.cycles, base_cycles
            ));
        }
        // wall-clock: informational only, never a gate
        if let Some(w) = base.get("wall_ms_fast").and_then(Json::as_f64) {
            println!(
                "compare: {} {} host {:.3} ms (baseline {:.3} ms)",
                row.kernel, row.variant, row.wall_ms_fast, w
            );
        }
    }
    if divergent.is_empty() {
        println!("compare: {matched} configurations match {path} exactly");
        return Ok(());
    }
    for d in &divergent {
        println!("compare: CYCLE DIVERGENCE {d}");
    }
    if provisional {
        println!(
            "compare: baseline is provisional — {} divergences reported, not \
             gating (re-run `vexp bench --json` on a reference machine and \
             commit the result to pin real numbers)",
            divergent.len()
        );
        Ok(())
    } else {
        vexp::bail!(
            "bench: {} configurations diverge from {path} in simulated cycles",
            divergent.len()
        )
    }
}

fn area_cmd() -> Result<()> {
    let m = AreaModel::default();
    let r = m.report();
    println!("GF12 area (Fig. 5):");
    println!("  EXP block / core : {:.0} um^2 ({} kGE)", m.exp_block_um2(), 8);
    println!("  FPU subsystem    : {:>8.0} kGE (+{:.1}%)", r.fpu_ss_kge, r.fpu_ss_overhead * 100.0);
    println!("  core complex     : {:>8.0} kGE (+{:.1}%)", r.core_complex_kge, r.core_complex_overhead * 100.0);
    println!("  cluster          : {:>8.0} kGE (+{:.1}%)", r.cluster_kge, r.cluster_overhead * 100.0);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::run;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    /// Every malformed invocation must come back as a clean `Err` (which
    /// `main` turns into usage + exit 2) — never a panic, never silent
    /// acceptance. All of these fail during argument parsing, before any
    /// simulation work starts.
    #[test]
    fn malformed_invocations_error_instead_of_panicking() {
        let bad: &[&[&str]] = &[
            &["frobnicate"],
            &["exp", "not-a-number"],
            &["exp", "1.0", "nan?"],
            &["softmax", "abc"],
            &["softmax", "0"],
            &["softmax", "8", "-3"],
            &["softmax", "8", "1024", "extra"],
            &["serve", "--tokens"],
            &["serve", "--tokens", "0"],
            &["serve", "--tokens", "many"],
            &["serve", "--prompt", "-1"],
            &["serve", "--frobnicate"],
            &["serve", "--trace"],
            &["serve", "--trace", "weird"],
            &["serve", "--faults", "slow=2:0.5"],
            &["serve", "--faults", "wat=1"],
            &["serve", "--slo", "5"],
            &["serve", "--slo", "0:1000"],
            &["serve", "--deadline", "0"],
            &["serve", "--requests", "10"], // trace-only flag without --trace
            &["serve", "--seed", "-7"],
            &["serve", "--policy"],
            &["serve", "--policy", "wat"],
            &["serve", "--trace", "burst", "--policy", "wat"],
            &["serve", "--kv-block"],
            &["serve", "--trace", "burst", "--kv-block", "0"],
            &["serve", "--trace", "burst", "--kv-pool", "0"],
            &["serve", "--share-prefix"], // trace-only flag without --trace
            &["serve", "--speculative"],
            &["serve", "--trace", "burst", "--speculative", "gpt2"], // missing :K
            &["serve", "--trace", "burst", "--speculative", "nope:3"],
            &["serve", "--trace", "burst", "--speculative", "gpt2:many"],
            &["serve", "--speculative", "gpt2:2"], // trace-only flag without --trace
            &["serve", "--trace", "burst", "--chunk-prefill", "0"],
            &["serve", "--chunk-prefill", "64"], // trace-only flag without --trace
            &["bench", "--json"],
            &["bench", "--wat"],
        ];
        for case in bad {
            let a = args(case);
            assert!(run(&a).is_err(), "expected a usage error for {case:?}");
        }
    }

    #[test]
    fn bare_invocation_prints_usage_and_succeeds() {
        assert!(run(&[]).is_ok());
    }
}
