//! The analytic backend: `coordinator::estimate` behind the [`Backend`]
//! trait.
//!
//! `estimate` / `estimate_phase` are the existing calibrated-rate model
//! (Fig. 1/Fig. 8, extended to prefill/decode phases). `execute` rates
//! a [`CompiledBatch`]'s slice workload with the same kernel rates and
//! DMA/HBM-contention model the estimator uses, so a serving layer can
//! admission-control a batch in microseconds and then validate the
//! decision against the cycle-accurate backend.

use super::batch::CompiledBatch;
use super::report::{BatchReport, RunReport};
use super::{Backend, ExecMode, Request};
use crate::coordinator::{KernelRates, SystemEstimator};
use crate::energy::power::DMA_PJ_PER_BYTE;
use crate::model::{Phase, WorkloadOps};

/// Rate-model backend: microsecond-cost estimates and batch ratings.
pub struct AnalyticBackend {
    /// The calibrated estimator this backend wraps.
    pub est: SystemEstimator,
}

impl AnalyticBackend {
    /// Calibrate kernel rates on the simulator, then build the backend.
    pub fn new() -> Self {
        Self::with_rates(KernelRates::calibrate())
    }

    /// Build the backend from explicit (e.g. cached) kernel rates.
    pub fn with_rates(rates: KernelRates) -> Self {
        AnalyticBackend { est: SystemEstimator::new(rates) }
    }
}

impl Default for AnalyticBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl Backend for AnalyticBackend {
    fn name(&self) -> &'static str {
        "analytic"
    }

    fn estimate(&mut self, req: &Request) -> RunReport {
        let e = self.est.estimate(&req.cfg, req.softmax_optimized, req.gemm_optimized);
        RunReport {
            backend: self.name(),
            request_id: req.id,
            model: req.cfg.name,
            cycles: e.cycles,
            energy_pj: e.energy_pj,
            softmax_cycles: e.softmax_cycles,
            gemm_cycles: e.gemm_cycles,
            attn_cycles: e.attn_cycles,
            dma_cycles: e.dma_cycles,
            nonlin_cycles: e.nonlin_cycles,
            clusters_used: self.est.clusters,
            ..Default::default()
        }
    }

    fn estimate_phase(&mut self, req: &Request, phase: Phase) -> RunReport {
        let ops = WorkloadOps::for_phase(&req.cfg, phase);
        let e = self
            .est
            .estimate_ops(&req.cfg, &ops, req.softmax_optimized, req.gemm_optimized);
        let tokens = if phase.is_decode() { 1 } else { 0 };
        RunReport {
            backend: self.name(),
            request_id: req.id,
            model: req.cfg.name,
            cycles: e.cycles,
            energy_pj: e.energy_pj,
            softmax_cycles: e.softmax_cycles,
            gemm_cycles: e.gemm_cycles,
            attn_cycles: e.attn_cycles,
            dma_cycles: e.dma_cycles,
            nonlin_cycles: e.nonlin_cycles,
            clusters_used: self.est.clusters,
            tokens,
            decode_token_cycles: if phase.is_decode() { e.cycles } else { 0.0 },
            ..Default::default()
        }
    }

    fn execute(&mut self, batch: &CompiledBatch) -> BatchReport {
        let active = batch.active_clusters();
        let contention = self
            .est
            .hbm
            .contention_factor(active.max(1), self.est.dma.bytes_per_cycle);
        let r = self.est.rates;
        let mut per_request = Vec::with_capacity(batch.requests.len());
        let mut makespan = 0u64;
        let mut hbm_bytes = 0u64;
        for cr in &batch.requests {
            let gemm_rate = if cr.req.gemm_optimized {
                r.gemm_cyc_per_flop
            } else {
                r.gemm_unopt_cyc_per_flop
            };
            let (sm_cyc, sm_pj) = if cr.req.softmax_optimized {
                (r.softmax_opt_cyc, r.softmax_opt_pj)
            } else {
                (r.softmax_base_cyc, r.softmax_base_pj)
            };
            let (gelu_cyc, gelu_pj, ln_cyc, ln_pj) = if cr.req.softmax_optimized {
                (r.gelu_opt_cyc, r.gelu_opt_pj, r.ln_opt_cyc, r.ln_opt_pj)
            } else {
                (r.gelu_base_cyc, r.gelu_base_pj, r.ln_base_cyc, r.ln_base_pj)
            };
            let reps = cr.reps as f64;
            let proj = cr.proj_flops_per_cluster as f64;
            let gemm_cycles = (reps * cr.cal.attn_flops() as f64 + proj) * gemm_rate;
            let softmax_cycles = reps * cr.cal.softmax_elems() as f64 * sm_cyc;
            let nonlin_cycles = cr.gelu_elems_per_cluster as f64 * gelu_cyc
                + cr.layernorm_elems_per_cluster as f64 * ln_cyc;
            // attention scope excludes the projection leg (RunReport
            // contract: attn_cycles is the FlashAttention slice work)
            let attn_cycles =
                reps * cr.cal.attn_flops() as f64 * gemm_rate + softmax_cycles;
            let compute = gemm_cycles + softmax_cycles + nonlin_cycles;
            let dma =
                self.est.dma.cycles(cr.hbm_bytes_per_cluster) as f64 * contention;
            let cycles = compute.max(dma) + self.est.dma.startup as f64;
            let n_cl = cr.clusters.len() as f64;
            let gemm_pj = if cr.req.gemm_optimized {
                r.gemm_pj_per_flop
            } else {
                r.gemm_pj_per_flop * 4.0
            };
            let energy_pj = n_cl
                * ((reps * cr.cal.attn_flops() as f64 + proj) * gemm_pj
                    + reps * cr.cal.softmax_elems() as f64 * sm_pj
                    + cr.gelu_elems_per_cluster as f64 * gelu_pj
                    + cr.layernorm_elems_per_cluster as f64 * ln_pj
                    + cr.hbm_bytes_per_cluster as f64 * DMA_PJ_PER_BYTE);
            makespan = makespan.max(cycles as u64);
            hbm_bytes += cr.hbm_bytes_per_cluster * cr.clusters.len() as u64;
            per_request.push(RunReport {
                backend: self.name(),
                request_id: cr.req.id,
                model: cr.req.cfg.name,
                cycles,
                energy_pj,
                softmax_cycles,
                gemm_cycles,
                attn_cycles,
                dma_cycles: dma,
                nonlin_cycles,
                clusters_used: cr.clusters.len(),
                ..Default::default()
            });
        }
        BatchReport {
            backend: self.name(),
            per_request,
            makespan_cycles: makespan,
            hbm_bytes,
            cache_hits: batch.cache_hits,
            cache_misses: batch.cache_misses,
            faults_injected: 0,
            failed_clusters: Vec::new(),
            offline_clusters: Vec::new(),
        }
    }

    fn set_mode(&mut self, mode: ExecMode) -> bool {
        // The rate model has no cheaper tier below itself: it *is* the
        // bottom of the degradation ladder, so it accepts only Analytic.
        matches!(mode, ExecMode::Analytic)
    }
}
