//! The unified result type every backend returns.

use crate::sim::ClusterStats;

/// One request's execution/estimation result, in a backend-independent
/// shape: cycles + energy + the paper's breakdown axes, plus per-cluster
/// stats when the backend actually ran cluster programs.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Which backend produced this report (`"analytic"` / `"cycle-sim"`).
    pub backend: &'static str,
    pub request_id: u64,
    pub model: &'static str,
    /// Total cycles for the request's workload scope (full forward pass
    /// for `estimate`, the packed batch slice for `execute`).
    pub cycles: f64,
    pub energy_pj: f64,
    /// Cycles attributed to softmax work.
    pub softmax_cycles: f64,
    /// Cycles attributed to GEMM work (projections + attention products).
    pub gemm_cycles: f64,
    /// Cycles attributed to the attention kernel (QK^T + partial softmax
    /// + P·V), the FlashAttention-2 scope of Fig. 6d-f.
    pub attn_cycles: f64,
    pub dma_cycles: f64,
    /// Clusters this request occupied.
    pub clusters_used: usize,
    /// Per-cluster statistics (empty for the analytic backend).
    pub per_cluster: Vec<ClusterStats>,
}

impl RunReport {
    /// Milliseconds at the 1 GHz cluster clock.
    pub fn latency_ms(&self) -> f64 {
        self.cycles / 1e6
    }

    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1e9
    }

    pub fn softmax_share(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.softmax_cycles / self.cycles
        }
    }
}

/// Result of executing a [`super::CompiledBatch`]: one report per
/// request (in submission order) plus batch-level accounting.
#[derive(Clone, Debug, Default)]
pub struct BatchReport {
    pub backend: &'static str,
    pub per_request: Vec<RunReport>,
    /// System makespan across all clusters for the batch.
    pub makespan_cycles: u64,
    /// Total bytes streamed from HBM across the batch.
    pub hbm_bytes: u64,
    /// Program-cache hits/misses recorded while compiling this batch.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl BatchReport {
    /// Aggregate energy over all requests.
    pub fn total_energy_pj(&self) -> f64 {
        self.per_request.iter().map(|r| r.energy_pj).sum()
    }
}
