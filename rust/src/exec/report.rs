//! The unified result types every backend returns.

use super::SchedPolicy;
use crate::sim::{ClusterStats, CLOCK_HZ};

/// How a served request left the system (continuous-batching scope;
/// requests outside the serve loop are always `Completed`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Outcome {
    /// Reached its token target and retired normally.
    #[default]
    Completed,
    /// Rejected by the admission controller; never executed. Shed
    /// requests appear in counts but contribute no tokens or energy.
    Shed,
    /// Missed its deadline and was retired with partial progress.
    TimedOut,
    /// Still in flight when the run ended (iteration bound, or every
    /// cluster offline); partial progress is reported.
    Unfinished,
}

/// One request's execution/estimation result, in a backend-independent
/// shape: cycles + energy + the paper's breakdown axes, plus per-cluster
/// stats when the backend actually ran cluster programs, plus the
/// serving metrics of the continuous-batching path (zero elsewhere).
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    /// Which backend produced this report (`"analytic"` / `"cycle-sim"`).
    pub backend: &'static str,
    /// The request this report belongs to.
    pub request_id: u64,
    /// Model name of the request.
    pub model: &'static str,
    /// Total cycles for the request's workload scope (full forward pass
    /// for `estimate`, the packed batch slice for `execute`, admission
    /// to retirement for the continuous-batching path).
    pub cycles: f64,
    /// Total energy in pJ.
    pub energy_pj: f64,
    /// Cycles attributed to softmax work.
    pub softmax_cycles: f64,
    /// Cycles attributed to GEMM work (projections + attention products).
    pub gemm_cycles: f64,
    /// Cycles attributed to the attention kernel (QK^T + partial softmax
    /// + P·V), the FlashAttention-2 scope of Fig. 6d-f.
    pub attn_cycles: f64,
    /// Cycles attributed to DMA streaming.
    pub dma_cycles: f64,
    /// Cycles attributed to the GELU + LayerNorm nonlinearities.
    pub nonlin_cycles: f64,
    /// Clusters this request occupied (last assignment for the
    /// continuous-batching path, which rebalances every iteration).
    pub clusters_used: usize,
    /// Time-to-first-token in cycles: admission to the end of the
    /// prefill iteration (continuous-batching scope only).
    pub ttft_cycles: f64,
    /// Tokens the request produced (continuous-batching scope only;
    /// prefill-only requests report 0 generated tokens).
    pub tokens: u32,
    /// Mean *observed* cycles per decode-phase token — iteration-barrier
    /// time under co-scheduling, on the same clock as `ttft_cycles` and
    /// [`RunReport::tokens_per_s`] (continuous-batching scope only).
    pub decode_token_cycles: f64,
    /// Per-cluster statistics (empty for the analytic backend).
    pub per_cluster: Vec<ClusterStats>,
    /// Upper bound on the cycle error introduced by sampled-simulation
    /// extrapolation (DESIGN.md §11). Zero unless the cycle-sim backend
    /// ran with a [`crate::sim::SamplePolicy`] and actually skipped
    /// repetitions; `cycles` is then accurate to within this bound of
    /// the fully simulated fast-path run.
    pub error_bound_cycles: f64,
    /// How the request left the serve loop (always `Completed` outside
    /// the continuous-batching scope).
    pub outcome: Outcome,
    /// Iteration attempts that had to be repeated for this request
    /// because a cluster it ran on failed (continuous-batching scope).
    pub retries: u32,
    /// A cluster this request ran on failed in the *last* attempt, so
    /// this report's results are untrusted (batch-execute scope; the
    /// serve loop retries instead of surfacing this).
    pub failed: bool,
    /// Scheduling objective the request was served under.
    pub policy: SchedPolicy,
    /// The request's decode-token target (0 for prefill-only), so the
    /// token books are auditable from the report alone.
    pub token_target: u32,
    /// Prompt tokens whose prefill this request skipped via paged
    /// prefix hits (cumulative over resumes; zero off the paged path).
    pub prefix_hit_tokens: u32,
    /// Times the paged loop preempted this request (evict-and-requeue).
    pub preemptions: u32,
    /// Speculative draft/verify rounds this request ran (DESIGN.md §15;
    /// zero outside speculative serving).
    pub spec_rounds: u32,
    /// Draft tokens proposed for this request across all rounds.
    pub drafted_tokens: u32,
    /// Draft tokens verify passes committed for this request (each
    /// pass's own guaranteed token is not counted here).
    pub accepted_tokens: u32,
    /// This request's own cycles across draft-model sub-iterations.
    pub draft_cycles: f64,
    /// This request's own cycles across target-model verify passes.
    pub verify_cycles: f64,
    /// Prefill chunks this request ran under an active chunked-prefill
    /// option (DESIGN.md §15; zero otherwise, 1 for an unsplit prompt).
    pub prefill_chunks: u32,
}

impl RunReport {
    /// Milliseconds at the 1 GHz cluster clock.
    pub fn latency_ms(&self) -> f64 {
        self.cycles / 1e6
    }

    /// Energy in millijoules.
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1e9
    }

    /// Fraction of cycles attributed to softmax.
    pub fn softmax_share(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.softmax_cycles / self.cycles
        }
    }

    /// Time-to-first-token in milliseconds.
    pub fn ttft_ms(&self) -> f64 {
        self.ttft_cycles / 1e6
    }

    /// Mean per-token decode latency in microseconds.
    pub fn token_latency_us(&self) -> f64 {
        self.decode_token_cycles / 1e3
    }

    /// Generation throughput over the request's residence time.
    pub fn tokens_per_s(&self) -> f64 {
        if self.cycles <= 0.0 || self.tokens == 0 {
            0.0
        } else {
            self.tokens as f64 / (self.cycles / CLOCK_HZ)
        }
    }
}

/// Page-pool section of a paged serve run's report (DESIGN.md §14):
/// the block pool's lifetime books plus the sharing/eviction/preemption
/// counters. Present on [`super::ServeReport`] only when the run used
/// the paged KV tier; `ServeReport::assert_consistent` re-checks the
/// books (`allocated == freed + resident`) on every run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolReport {
    /// Total blocks in the pool.
    pub capacity_blocks: usize,
    /// Bytes per block.
    pub block_bytes: u64,
    /// Blocks allocated over the run.
    pub allocated: u64,
    /// Blocks returned to the free list (discarded or evicted).
    pub freed: u64,
    /// Blocks still resident at the end (in use + prefix-cached).
    pub resident: u64,
    /// Cached blocks reclaimed by LRU eviction under pressure.
    pub evictions: u64,
    /// Copy-on-write tail duplications.
    pub cow_copies: u64,
    /// Whole-request preemptions (evict-and-requeue).
    pub preemptions: u32,
    /// Preempted requests re-admitted with their token books intact.
    pub resumes: u32,
    /// Prefix-index hits (whole blocks reused across requests).
    pub prefix_hits: u64,
    /// Prompt tokens whose prefill was skipped through those hits.
    pub prefix_hit_tokens: u64,
    /// High-water mark of blocks referenced by live requests.
    pub peak_blocks_in_use: usize,
    /// Requests shed at admission because their lifetime block need
    /// exceeds the whole pool (they could never run to completion).
    pub shed_unfittable: u32,
    /// Admissions deferred because the pool was exhausted by live
    /// requests (retried on a later iteration).
    pub deferrals: u32,
}

/// Result of executing a [`super::CompiledBatch`]: one report per
/// request (in submission order) plus batch-level accounting.
#[derive(Clone, Debug, Default)]
pub struct BatchReport {
    /// Which backend executed the batch.
    pub backend: &'static str,
    /// One report per request, in batch order.
    pub per_request: Vec<RunReport>,
    /// System makespan across all clusters for the batch.
    pub makespan_cycles: u64,
    /// Total bytes streamed from HBM across the batch.
    pub hbm_bytes: u64,
    /// Program-cache hits recorded while compiling this batch.
    pub cache_hits: u64,
    /// Program-cache misses recorded while compiling this batch.
    pub cache_misses: u64,
    /// Effective faults the simulator injected into this batch (zero on
    /// the analytic backend or with no [`crate::sim::FaultPlan`] armed).
    pub faults_injected: u32,
    /// Clusters whose job transiently failed during this batch.
    pub failed_clusters: Vec<usize>,
    /// Clusters that were offline during this batch.
    pub offline_clusters: Vec<usize>,
}

impl BatchReport {
    /// Aggregate energy over all requests.
    pub fn total_energy_pj(&self) -> f64 {
        self.per_request.iter().map(|r| r.energy_pj).sum()
    }
}
