//! Multi-request batching: pack concurrent inference requests onto the
//! 16-cluster system.
//!
//! The scheduler partitions the clusters among requests proportionally
//! to their attention work (each request gets a disjoint, contiguous
//! cluster set, at least one cluster each), maps each request's heads
//! onto its clusters with [`HeadMap`] rounds, and compiles — through the
//! shared [`ProgramCache`] — one FlashAttention-2 *head-tile slice*
//! program per request at its [`TilePlan`]'s tile sizes. Executing the
//! resulting [`CompiledBatch`] on a backend overlaps one request's DMA
//! with another's compute through the existing HBM-contention model:
//! every active cluster streams its own K/V tiles while all of them
//! share the group crossbar.
//!
//! The batch workload scope is deliberately a *slice* (one Q-block over
//! two K/V tiles per head round): it is the unit both backends can honor
//! — the cycle-accurate simulator by actually running it, the analytic
//! backend by rating it — and the unit the cache can share across
//! requests of the same model shape.

use super::program::{KernelKind, Program, ProgramCache, ProgramKey};
use super::Request;
use crate::coordinator::{HeadMap, TilePlan, CLUSTERS};
use crate::kernels::flash_attention::build_fa_program;
use crate::model::WorkloadOps;
use crate::sim::CORES_PER_CLUSTER;

/// The calibration slice shape one batched head round executes: a
/// `sq × sk` FlashAttention-2 forward with K/V tile length `bk`.
#[derive(Clone, Copy, Debug)]
pub struct CalShape {
    pub sq: u32,
    pub sk: u32,
    pub d: u32,
    pub bk: u32,
}

impl CalShape {
    /// Derive the slice shape from a request's tile plan: a small Q
    /// block (16 rows — two per core) over two double-buffered K/V
    /// tiles, at the request's head dimension.
    pub fn for_plan(plan: &TilePlan) -> Self {
        let bk = plan.bk;
        CalShape { sq: 16.min(plan.bq), sk: 2 * bk, d: plan.d, bk }
    }

    /// GEMM FLOPs in the slice (QK^T + P·V, 2 FLOPs per MAC).
    pub fn attn_flops(&self) -> u64 {
        2 * 2 * self.sq as u64 * self.sk as u64 * self.d as u64
    }

    /// Softmax elements in the slice.
    pub fn softmax_elems(&self) -> u64 {
        self.sq as u64 * self.sk as u64
    }

    /// HBM bytes streamed per slice (Q block + K and V tiles, BF16).
    pub fn hbm_bytes(&self) -> u64 {
        2 * (self.sq as u64 * self.d as u64) + 2 * 2 * (self.sk as u64 * self.d as u64)
    }
}

/// One request, compiled and placed: its cluster set, head rounds, the
/// cached slice program, and the DMA bytes each of its clusters streams.
#[derive(Clone, Debug)]
pub struct CompiledRequest {
    pub req: Request,
    pub plan: TilePlan,
    pub cal: CalShape,
    /// Cluster indices owned by this request (disjoint across requests).
    pub clusters: Vec<usize>,
    /// Sequential head rounds each owned cluster executes.
    pub rounds: u32,
    pub program: Program,
    /// HBM bytes one owned cluster streams over all its rounds.
    pub hbm_bytes_per_cluster: u64,
}

/// A scheduled, compiled batch ready for any [`super::Backend`].
#[derive(Clone, Debug)]
pub struct CompiledBatch {
    pub requests: Vec<CompiledRequest>,
    /// Total clusters in the target system.
    pub n_clusters: usize,
    /// Cache hits/misses incurred compiling this batch.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl CompiledBatch {
    /// Clusters owned by any request.
    pub fn active_clusters(&self) -> usize {
        self.requests.iter().map(|r| r.clusters.len()).sum()
    }
}

/// Packs concurrent requests onto the cluster grid.
#[derive(Clone, Copy, Debug)]
pub struct BatchScheduler {
    pub clusters: usize,
}

impl Default for BatchScheduler {
    fn default() -> Self {
        BatchScheduler { clusters: CLUSTERS }
    }
}

impl BatchScheduler {
    pub fn new(clusters: usize) -> Self {
        assert!(clusters > 0);
        BatchScheduler { clusters }
    }

    /// Partition the clusters among the requests proportionally to their
    /// total attention FLOPs: every request gets at least one cluster
    /// (and at most `heads` — more would idle), remaining clusters go
    /// greedily to the request with the highest work-per-cluster.
    pub fn assign(&self, reqs: &[Request]) -> Vec<Vec<usize>> {
        assert!(!reqs.is_empty(), "empty batch");
        assert!(
            reqs.len() <= self.clusters,
            "{} requests exceed {} clusters; split the batch",
            reqs.len(),
            self.clusters
        );
        let work: Vec<f64> = reqs
            .iter()
            .map(|r| WorkloadOps::of(&r.cfg).total().attn_flops as f64)
            .collect();
        let mut counts = vec![1usize; reqs.len()];
        for _ in reqs.len()..self.clusters {
            // highest remaining per-cluster work, capped at head count
            let mut best: Option<usize> = None;
            for (i, req) in reqs.iter().enumerate() {
                if counts[i] >= req.cfg.heads as usize {
                    continue;
                }
                let density = work[i] / counts[i] as f64;
                let better = match best {
                    None => true,
                    Some(b) => density > work[b] / counts[b] as f64,
                };
                if better {
                    best = Some(i);
                }
            }
            match best {
                Some(i) => counts[i] += 1,
                None => break, // every request saturated at its head count
            }
        }
        let mut next = 0usize;
        counts
            .iter()
            .map(|&n| {
                let ids = (next..next + n).collect();
                next += n;
                ids
            })
            .collect()
    }

    /// Compile every request's slice program through `cache` and place
    /// the batch. Hit/miss deltas are recorded on the returned batch.
    pub fn compile(&self, reqs: &[Request], cache: &mut ProgramCache) -> CompiledBatch {
        let assignment = self.assign(reqs);
        let (h0, m0) = (cache.hits, cache.misses);
        let requests = reqs
            .iter()
            .zip(assignment)
            .map(|(req, clusters)| {
                let plan = TilePlan::plan(&req.cfg);
                let cal = CalShape::for_plan(&plan);
                let variant = req.fa_variant();
                let key = ProgramKey::for_request(
                    KernelKind::FlashAttention(variant),
                    &req.cfg,
                    &plan,
                    CORES_PER_CLUSTER as u32,
                );
                let program =
                    cache.get_or_build(key, || build_fa_program(variant, cal.sq, cal.sk, cal.d, cal.bk));
                let rounds = HeadMap::new(req.cfg.heads, clusters.len() as u32).rounds();
                let hbm_bytes_per_cluster = rounds as u64 * cal.hbm_bytes();
                CompiledRequest {
                    req: *req,
                    plan,
                    cal,
                    clusters,
                    rounds,
                    program,
                    hbm_bytes_per_cluster,
                }
            })
            .collect();
        CompiledBatch {
            requests,
            n_clusters: self.clusters,
            cache_hits: cache.hits - h0,
            cache_misses: cache.misses - m0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};

    fn mixed() -> Vec<Request> {
        vec![
            Request::new(0, GPT2_SMALL),
            Request::new(1, GPT3_XL),
            Request::new(2, VIT_BASE),
            Request::new(3, VIT_HUGE),
        ]
    }

    #[test]
    fn assignment_is_a_disjoint_cover() {
        let sched = BatchScheduler::default();
        let assignment = sched.assign(&mixed());
        let mut seen = vec![false; CLUSTERS];
        for ids in &assignment {
            assert!(!ids.is_empty(), "every request needs a cluster");
            for &c in ids {
                assert!(c < CLUSTERS);
                assert!(!seen[c], "cluster {c} assigned twice");
                seen[c] = true;
            }
        }
    }

    #[test]
    fn heavier_requests_get_more_clusters() {
        let sched = BatchScheduler::default();
        let reqs = mixed();
        let assignment = sched.assign(&reqs);
        // GPT-3 XL (seq 2048, d_model 2048) dwarfs ViT-Base (seq 197)
        assert!(
            assignment[1].len() > assignment[2].len(),
            "GPT-3 {} vs ViT-B {}",
            assignment[1].len(),
            assignment[2].len()
        );
    }

    #[test]
    fn cluster_counts_capped_at_heads() {
        let sched = BatchScheduler::new(16);
        let reqs = vec![Request::new(0, GPT2_SMALL)]; // 12 heads
        let assignment = sched.assign(&reqs);
        assert_eq!(assignment[0].len(), 12);
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn too_many_requests_panic() {
        let sched = BatchScheduler::new(2);
        sched.assign(&[
            Request::new(0, VIT_BASE),
            Request::new(1, VIT_BASE),
            Request::new(2, VIT_BASE),
        ]);
    }

    #[test]
    fn compile_reuses_programs_across_same_shape_requests() {
        let sched = BatchScheduler::default();
        let mut cache = ProgramCache::new();
        let reqs = vec![
            Request::new(0, GPT2_SMALL),
            Request::new(1, VIT_BASE),
            Request::new(2, GPT2_SMALL), // same shape as request 0
        ];
        let batch = sched.compile(&reqs, &mut cache);
        assert_eq!(batch.requests.len(), 3);
        assert!(batch.cache_hits >= 1, "duplicate GPT-2 must hit the cache");
        assert!(batch.requests[0]
            .program
            .shares_storage_with(&batch.requests[2].program));
        assert!(!batch.requests[0]
            .program
            .shares_storage_with(&batch.requests[1].program));
    }

    #[test]
    fn cal_shape_is_simulable() {
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE] {
            let plan = TilePlan::plan(&cfg);
            let cal = CalShape::for_plan(&plan);
            assert!(cal.sq >= 8 && cal.sq <= 64);
            assert_eq!(cal.sk % cal.bk, 0);
            assert!(cal.attn_flops() > 0 && cal.hbm_bytes() > 0);
        }
    }
}
