//! Multi-request batching: pack concurrent inference requests onto the
//! 16-cluster system.
//!
//! The scheduler partitions the clusters among requests proportionally
//! to their work (each request gets a disjoint, contiguous cluster set,
//! at least one cluster each), maps each request's heads onto its
//! clusters with [`HeadMap`] rounds, and compiles — through the shared
//! [`ProgramCache`] — one slice program per request: a FlashAttention-2
//! *head-tile slice* for prefill, the single-query *decode slice* for
//! KV-cache decode. Executing the resulting [`CompiledBatch`] on a
//! backend overlaps one request's DMA with another's compute through
//! the existing HBM-contention model: every active cluster streams its
//! own K/V tiles while all of them share the group crossbar.
//!
//! Two compilation scopes share this machinery:
//!
//! - [`BatchScheduler::compile`] — the *calibration slice* scope (one
//!   Q-block over two K/V tiles per head round), the unit both backends
//!   can honor directly and the unit the cache shares across requests
//!   of the same model shape;
//! - [`BatchScheduler::compile_phased`] — the *serving iteration* scope
//!   used by the continuous-batching loop: `reps` scales the cached
//!   slice to the full per-iteration work of the request's phase (all
//!   layers, all head rounds, the whole prompt or KV-cache), and the
//!   per-cluster HBM bytes follow the phase's weight/activation/KV
//!   traffic with the [`KvResidency`] placement rule.

use super::program::{KernelKind, Program, ProgramCache, ProgramKey};
use super::{Request, SchedPolicy};
use crate::coordinator::{DecodePlan, HeadMap, KvResidency, PagedResidency, TilePlan, CLUSTERS};
use crate::kernels::flash_attention::{build_fa_decode_program, build_fa_program};
use crate::model::{Phase, WorkloadOps};
use crate::sim::CORES_PER_CLUSTER;

/// The calibration slice shape one batched head round executes: a
/// `sq × sk` FlashAttention forward with K/V tile length `bk`. The
/// decode slice is the single-query case (`sq == 1`).
#[derive(Clone, Copy, Debug)]
pub struct CalShape {
    /// Query rows in the slice (1 for decode).
    pub sq: u32,
    /// KV positions the slice covers.
    pub sk: u32,
    /// Head dimension.
    pub d: u32,
    /// K/V tile length.
    pub bk: u32,
}

impl CalShape {
    /// Derive the prefill slice shape from a request's tile plan: a
    /// small Q block (16 rows — two per core) over two double-buffered
    /// K/V tiles, at the request's head dimension.
    pub fn for_plan(plan: &TilePlan) -> Self {
        let bk = plan.bk;
        CalShape { sq: 16.min(plan.bq), sk: 2 * bk, d: plan.d, bk }
    }

    /// The decode slice shape of a decode plan: one query row over the
    /// plan's KV window.
    pub fn for_decode(plan: &DecodePlan) -> Self {
        CalShape { sq: 1, sk: plan.sk_slice, d: plan.d, bk: plan.bk }
    }

    /// GEMM FLOPs in the slice (QK^T + P·V, 2 FLOPs per MAC).
    pub fn attn_flops(&self) -> u64 {
        2 * 2 * self.sq as u64 * self.sk as u64 * self.d as u64
    }

    /// Softmax elements in the slice.
    pub fn softmax_elems(&self) -> u64 {
        self.sq as u64 * self.sk as u64
    }

    /// HBM bytes streamed per slice (Q block + K and V tiles, BF16).
    pub fn hbm_bytes(&self) -> u64 {
        2 * (self.sq as u64 * self.d as u64) + 2 * 2 * (self.sk as u64 * self.d as u64)
    }
}

/// One live request's slot in a serving-iteration compilation: the
/// request, the phase it runs this iteration, and — when the serve loop
/// runs the paged KV tier — the token capacity of its cache blocks,
/// which switches decode KV pricing from the all-or-nothing
/// [`KvResidency`] rule to the block-granular [`PagedResidency`] one.
#[derive(Clone, Copy, Debug)]
pub struct ServeEntry {
    /// The live request.
    pub req: Request,
    /// The phase it runs this iteration.
    pub phase: Phase,
    /// Tokens per KV block (`None` = legacy unpaged pricing).
    pub kv_block_tokens: Option<u32>,
}

/// Work-weight boost a latency-policy request receives in the
/// cluster-share rebalance: its phase work counts this many times over
/// before the proportional split. Uniform-policy batches are unaffected
/// (scaling every weight equally preserves the assignment exactly).
const LATENCY_WORK_BOOST: f64 = 4.0;

/// One request, compiled and placed: its phase, cluster set, head
/// rounds, slice repetitions, the cached slice program, and the DMA
/// bytes each of its clusters streams.
#[derive(Clone, Debug)]
pub struct CompiledRequest {
    /// The scheduled request.
    pub req: Request,
    /// Which inference phase this compilation covers.
    pub phase: Phase,
    /// The prefill head tiling the slice was derived from (at the
    /// phase's prompt length for prefill compilations; the model-shape
    /// plan for decode, where it is informational only).
    pub plan: TilePlan,
    /// The slice shape the cached program implements.
    pub cal: CalShape,
    /// Cluster indices owned by this request (disjoint across requests).
    pub clusters: Vec<usize>,
    /// Sequential head rounds each owned cluster executes.
    pub rounds: u32,
    /// Total slice repetitions per owned cluster for this batch scope
    /// (`rounds` in the calibration scope; `layers × rounds × tiles`
    /// in the serving scope).
    pub reps: u32,
    /// The cached slice program.
    pub program: Program,
    /// HBM bytes one owned cluster streams over the batch scope.
    pub hbm_bytes_per_cluster: u64,
    /// Projection-GEMM FLOPs per owned cluster, priced by the backends
    /// at their measured/calibrated GEMM rate (serving scope only;
    /// zero in the calibration scope).
    pub proj_flops_per_cluster: u64,
    /// GELU activations per owned cluster, priced by the backends at
    /// their measured/calibrated GELU rate (serving scope only; zero in
    /// the calibration scope).
    pub gelu_elems_per_cluster: u64,
    /// LayerNorm elements per owned cluster (serving scope only; zero
    /// in the calibration scope).
    pub layernorm_elems_per_cluster: u64,
    /// Decode-phase KV tokens priced hot (SPM-pinned; append-only
    /// traffic). Zero outside the decode serving scope.
    pub kv_hot_tokens: u32,
    /// Decode-phase KV tokens priced cold (restreamed from HBM every
    /// step). Zero outside the decode serving scope.
    pub kv_cold_tokens: u32,
}

/// A scheduled, compiled batch ready for any [`super::Backend`].
#[derive(Clone, Debug)]
pub struct CompiledBatch {
    /// Compiled requests in submission order.
    pub requests: Vec<CompiledRequest>,
    /// Total clusters in the target system.
    pub n_clusters: usize,
    /// Cache hits incurred compiling this batch.
    pub cache_hits: u64,
    /// Cache misses incurred compiling this batch.
    pub cache_misses: u64,
}

impl CompiledBatch {
    /// Clusters owned by any request.
    pub fn active_clusters(&self) -> usize {
        self.requests.iter().map(|r| r.clusters.len()).sum()
    }

    /// The empty batch for a system of `n_clusters`.
    pub fn empty(n_clusters: usize) -> Self {
        CompiledBatch { requests: vec![], n_clusters, cache_hits: 0, cache_misses: 0 }
    }
}

/// Packs concurrent requests onto the cluster grid.
#[derive(Clone, Copy, Debug)]
pub struct BatchScheduler {
    /// Clusters in the target system.
    pub clusters: usize,
}

impl Default for BatchScheduler {
    fn default() -> Self {
        BatchScheduler { clusters: CLUSTERS }
    }
}

impl BatchScheduler {
    /// Scheduler for a system of `clusters` clusters.
    pub fn new(clusters: usize) -> Self {
        assert!(clusters > 0);
        BatchScheduler { clusters }
    }

    /// Partition the clusters among the requests proportionally to their
    /// total attention FLOPs: every request gets at least one cluster
    /// (and at most `heads` — more would idle), remaining clusters go
    /// greedily to the request with the highest work-per-cluster.
    pub fn assign(&self, reqs: &[Request]) -> Vec<Vec<usize>> {
        let work: Vec<f64> = reqs
            .iter()
            .map(|r| WorkloadOps::of(&r.cfg).total().attn_flops as f64)
            .collect();
        let caps: Vec<usize> = reqs.iter().map(|r| r.cfg.heads as usize).collect();
        self.assign_by_work(&work, &caps)
    }

    /// Proportional cluster assignment over explicit work weights with
    /// per-request cluster caps — the shared core of [`Self::assign`]
    /// and the phase-aware serving scheduler. Requests receive disjoint
    /// contiguous cluster index ranges, each at least one cluster.
    pub fn assign_by_work(&self, work: &[f64], caps: &[usize]) -> Vec<Vec<usize>> {
        let all: Vec<usize> = (0..self.clusters).collect();
        self.assign_by_work_on(work, caps, &all)
    }

    /// [`Self::assign_by_work`] restricted to an explicit set of
    /// `available` cluster indices — the resilient serve loop re-plans
    /// around quarantined/offline clusters by shrinking this set.
    /// Requests receive disjoint contiguous *ranges of `available`*
    /// (which need not be contiguous cluster indices), each at least
    /// one cluster.
    pub fn assign_by_work_on(
        &self,
        work: &[f64],
        caps: &[usize],
        available: &[usize],
    ) -> Vec<Vec<usize>> {
        assert!(!work.is_empty(), "empty batch");
        assert_eq!(work.len(), caps.len());
        assert!(
            work.len() <= available.len(),
            "{} requests exceed {} available clusters; split the batch",
            work.len(),
            available.len()
        );
        debug_assert!(available.iter().all(|&c| c < self.clusters));
        let mut counts = vec![1usize; work.len()];
        for _ in work.len()..available.len() {
            // highest remaining per-cluster work, capped per request
            let mut best: Option<usize> = None;
            for i in 0..work.len() {
                if counts[i] >= caps[i].max(1) {
                    continue;
                }
                let density = work[i] / counts[i] as f64;
                let better = match best {
                    None => true,
                    Some(b) => density > work[b] / counts[b] as f64,
                };
                if better {
                    best = Some(i);
                }
            }
            match best {
                Some(i) => counts[i] += 1,
                None => break, // every request saturated at its cap
            }
        }
        let mut next = 0usize;
        counts
            .iter()
            .map(|&n| {
                let ids = available[next..next + n].to_vec();
                next += n;
                ids
            })
            .collect()
    }

    /// Compile every request's calibration slice through `cache` and
    /// place the batch (the DESIGN.md §8 slice scope). Hit/miss deltas
    /// are recorded on the returned batch. An empty request list
    /// compiles to the empty batch.
    pub fn compile(&self, reqs: &[Request], cache: &mut ProgramCache) -> CompiledBatch {
        if reqs.is_empty() {
            return CompiledBatch::empty(self.clusters);
        }
        let assignment = self.assign(reqs);
        let (h0, m0) = (cache.hits, cache.misses);
        let requests = reqs
            .iter()
            .zip(assignment)
            .map(|(req, clusters)| {
                let plan = TilePlan::plan(&req.cfg);
                let cal = CalShape::for_plan(&plan);
                let variant = req.fa_variant();
                let key = ProgramKey::for_request(
                    KernelKind::FlashAttention(variant),
                    &req.cfg,
                    &plan,
                    CORES_PER_CLUSTER as u32,
                );
                let program =
                    cache.get_or_build(key, || build_fa_program(variant, cal.sq, cal.sk, cal.d, cal.bk));
                let rounds = HeadMap::new(req.cfg.heads, clusters.len() as u32).rounds();
                let hbm_bytes_per_cluster = rounds as u64 * cal.hbm_bytes();
                CompiledRequest {
                    req: *req,
                    phase: Phase::Prefill { prompt: req.cfg.seq },
                    plan,
                    cal,
                    clusters,
                    rounds,
                    reps: rounds,
                    program,
                    hbm_bytes_per_cluster,
                    proj_flops_per_cluster: 0,
                    gelu_elems_per_cluster: 0,
                    layernorm_elems_per_cluster: 0,
                    kv_hot_tokens: 0,
                    kv_cold_tokens: 0,
                }
            })
            .collect();
        CompiledBatch {
            requests,
            n_clusters: self.clusters,
            cache_hits: cache.hits - h0,
            cache_misses: cache.misses - m0,
        }
    }

    /// Compile one continuous-batching *iteration*: each live request at
    /// its current phase, clusters rebalanced by per-iteration work,
    /// slice repetitions scaled to the full phase work, HBM bytes per
    /// the phase's traffic and the KV residency rule (DESIGN.md §10).
    pub fn compile_phased(
        &self,
        entries: &[(Request, Phase)],
        cache: &mut ProgramCache,
    ) -> CompiledBatch {
        let all: Vec<usize> = (0..self.clusters).collect();
        self.compile_phased_on(entries, cache, &all)
    }

    /// [`Self::compile_phased`] restricted to an explicit set of
    /// `available` cluster indices: the resilient serve loop compiles
    /// each retry attempt around the clusters currently quarantined or
    /// offline (DESIGN.md §12). Cluster shares, head rounds, reps and
    /// per-cluster HBM bytes all follow the shrunken set.
    pub fn compile_phased_on(
        &self,
        entries: &[(Request, Phase)],
        cache: &mut ProgramCache,
        available: &[usize],
    ) -> CompiledBatch {
        let entries: Vec<ServeEntry> = entries
            .iter()
            .map(|&(req, phase)| ServeEntry { req, phase, kv_block_tokens: None })
            .collect();
        self.compile_entries_on(&entries, cache, available)
    }

    /// The full serving-iteration compiler: [`Self::compile_phased_on`]
    /// plus the paged-KV and policy dimensions (DESIGN.md §14). An
    /// entry carrying `kv_block_tokens` prices its decode KV traffic
    /// with the block-granular [`PagedResidency`] rule (hot tail
    /// appends, cold prefix restreams); latency-policy requests weigh
    /// [`LATENCY_WORK_BOOST`]× in the proportional cluster split.
    pub fn compile_entries_on(
        &self,
        entries: &[ServeEntry],
        cache: &mut ProgramCache,
        available: &[usize],
    ) -> CompiledBatch {
        if entries.is_empty() {
            return CompiledBatch::empty(self.clusters);
        }
        let work: Vec<f64> = entries
            .iter()
            .map(|e| {
                let w = WorkloadOps::for_phase(&e.req.cfg, e.phase).total().total_flops() as f64;
                if e.req.policy == SchedPolicy::Latency {
                    w * LATENCY_WORK_BOOST
                } else {
                    w
                }
            })
            .collect();
        let caps: Vec<usize> = entries.iter().map(|e| e.req.cfg.heads as usize).collect();
        let assignment = self.assign_by_work_on(&work, &caps, available);
        let (h0, m0) = (cache.hits, cache.misses);
        let requests = entries
            .iter()
            .zip(assignment)
            .map(|(entry, clusters)| {
                let (req, phase) = (&entry.req, &entry.phase);
                let n_cl = clusters.len() as u32;
                let rounds = HeadMap::new(req.cfg.heads, n_cl).rounds();
                let ops = WorkloadOps::for_phase(&req.cfg, *phase).total();
                let variant = req.fa_variant();
                let layers = req.cfg.layers as u64;
                let proj_flops_per_cluster = ops.proj_flops / n_cl as u64;
                let mut kv_hot_tokens = 0u32;
                let mut kv_cold_tokens = 0u32;
                let (plan, cal, program, slice_factor, hbm_bytes_per_cluster) = match *phase {
                    Phase::Prefill { prompt } => {
                        let prompt = prompt.max(1);
                        let mut pcfg = req.cfg;
                        pcfg.seq = prompt;
                        let plan = TilePlan::plan(&pcfg);
                        let cal = CalShape::for_plan(&plan);
                        let key = ProgramKey::for_request(
                            KernelKind::FlashAttention(variant),
                            &pcfg,
                            &plan,
                            CORES_PER_CLUSTER as u32,
                        );
                        let program = cache.get_or_build(key, || {
                            build_fa_program(variant, cal.sq, cal.sk, cal.d, cal.bk)
                        });
                        // slices tiling one full S×S head
                        let slices =
                            prompt.div_ceil(cal.sq) as u64 * prompt.div_ceil(cal.sk) as u64;
                        let bytes = (ops.weight_bytes + ops.act_bytes) / n_cl as u64;
                        (plan, cal, program, slices, bytes)
                    }
                    Phase::Decode { kv_len } => {
                        let dplan = DecodePlan::plan(&req.cfg);
                        let cal = CalShape::for_decode(&dplan);
                        let key = ProgramKey::for_decode(
                            KernelKind::FlashDecode(variant),
                            &req.cfg,
                            dplan.sk_slice,
                            dplan.bk,
                            CORES_PER_CLUSTER as u32,
                        );
                        let program = cache.get_or_build(key, || {
                            build_fa_decode_program(variant, dplan.sk_slice, dplan.d, dplan.bk)
                        });
                        // the whole weight set streams once per token;
                        // whole-model KV traffic follows the placement
                        // rule: block-granular when the entry carries a
                        // paged geometry, the legacy all-or-nothing
                        // KvResidency verdict otherwise
                        let kv_bytes = match entry.kv_block_tokens {
                            Some(bt) => {
                                let paged =
                                    PagedResidency::analyze(&req.cfg, kv_len, n_cl, bt);
                                kv_hot_tokens = paged.hot_tokens;
                                kv_cold_tokens = paged.cold_tokens;
                                paged.hbm_bytes_per_step(&req.cfg)
                            }
                            None => {
                                let residency = KvResidency::analyze(&req.cfg, kv_len, n_cl);
                                match residency.placement {
                                    crate::coordinator::KvPlacement::SpmResident => {
                                        kv_hot_tokens = kv_len
                                    }
                                    crate::coordinator::KvPlacement::HbmSpill => {
                                        kv_cold_tokens = kv_len
                                    }
                                }
                                residency.hbm_bytes_per_step(&req.cfg)
                            }
                        };
                        let bytes = ops.weight_bytes / n_cl as u64 + kv_bytes;
                        (
                            TilePlan::plan(&req.cfg),
                            cal,
                            program,
                            dplan.kv_tile_factor(kv_len) as u64,
                            bytes,
                        )
                    }
                };
                let reps_total = layers * rounds as u64 * slice_factor;
                let reps = reps_total.min(u32::MAX as u64) as u32;
                CompiledRequest {
                    req: *req,
                    phase: *phase,
                    plan,
                    cal,
                    clusters,
                    rounds,
                    reps,
                    program,
                    hbm_bytes_per_cluster,
                    proj_flops_per_cluster,
                    gelu_elems_per_cluster: ops.gelu_elems / n_cl as u64,
                    layernorm_elems_per_cluster: ops.layernorm_elems / n_cl as u64,
                    kv_hot_tokens,
                    kv_cold_tokens,
                }
            })
            .collect();
        CompiledBatch {
            requests,
            n_clusters: self.clusters,
            cache_hits: cache.hits - h0,
            cache_misses: cache.misses - m0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};

    fn mixed() -> Vec<Request> {
        vec![
            Request::new(0, GPT2_SMALL),
            Request::new(1, GPT3_XL),
            Request::new(2, VIT_BASE),
            Request::new(3, VIT_HUGE),
        ]
    }

    #[test]
    fn assignment_is_a_disjoint_cover() {
        let sched = BatchScheduler::default();
        let assignment = sched.assign(&mixed());
        let mut seen = vec![false; CLUSTERS];
        for ids in &assignment {
            assert!(!ids.is_empty(), "every request needs a cluster");
            for &c in ids {
                assert!(c < CLUSTERS);
                assert!(!seen[c], "cluster {c} assigned twice");
                seen[c] = true;
            }
        }
    }

    #[test]
    fn heavier_requests_get_more_clusters() {
        let sched = BatchScheduler::default();
        let reqs = mixed();
        let assignment = sched.assign(&reqs);
        // GPT-3 XL (seq 2048, d_model 2048) dwarfs ViT-Base (seq 197)
        assert!(
            assignment[1].len() > assignment[2].len(),
            "GPT-3 {} vs ViT-B {}",
            assignment[1].len(),
            assignment[2].len()
        );
    }

    #[test]
    fn cluster_counts_capped_at_heads() {
        let sched = BatchScheduler::new(16);
        let reqs = vec![Request::new(0, GPT2_SMALL)]; // 12 heads
        let assignment = sched.assign(&reqs);
        assert_eq!(assignment[0].len(), 12);
    }

    #[test]
    #[should_panic(expected = "exceed")]
    fn too_many_requests_panic() {
        let sched = BatchScheduler::new(2);
        sched.assign(&[
            Request::new(0, VIT_BASE),
            Request::new(1, VIT_BASE),
            Request::new(2, VIT_BASE),
        ]);
    }

    #[test]
    fn assignment_on_a_restricted_set_covers_exactly_that_set() {
        let sched = BatchScheduler::new(8);
        // clusters 2 and 5 quarantined
        let available = vec![0, 1, 3, 4, 6, 7];
        let work = [100.0, 50.0];
        let caps = [16, 16];
        let assignment = sched.assign_by_work_on(&work, &caps, &available);
        let mut got: Vec<usize> = assignment.iter().flatten().copied().collect();
        got.sort_unstable();
        assert_eq!(got, available, "assignment must cover exactly the available set");
        assert!(assignment.iter().all(|ids| !ids.is_empty()));
        assert!(assignment[0].len() >= assignment[1].len());
    }

    #[test]
    fn compile_phased_on_respects_the_available_set() {
        let sched = BatchScheduler::new(4);
        let mut cache = ProgramCache::new();
        let req = Request::new(0, GPT2_SMALL);
        let batch = sched.compile_phased_on(
            &[(req, Phase::Decode { kv_len: 256 })],
            &mut cache,
            &[1, 3],
        );
        assert_eq!(batch.requests[0].clusters, vec![1, 3]);
        assert_eq!(batch.n_clusters, 4);
    }

    #[test]
    fn empty_batch_compiles_to_empty() {
        let sched = BatchScheduler::default();
        let mut cache = ProgramCache::new();
        let batch = sched.compile(&[], &mut cache);
        assert!(batch.requests.is_empty());
        assert_eq!(batch.n_clusters, CLUSTERS);
        assert_eq!((batch.cache_hits, batch.cache_misses), (0, 0));
        let phased = sched.compile_phased(&[], &mut cache);
        assert!(phased.requests.is_empty());
        assert_eq!(phased.active_clusters(), 0);
    }

    #[test]
    fn compile_reuses_programs_across_same_shape_requests() {
        let sched = BatchScheduler::default();
        let mut cache = ProgramCache::new();
        let reqs = vec![
            Request::new(0, GPT2_SMALL),
            Request::new(1, VIT_BASE),
            Request::new(2, GPT2_SMALL), // same shape as request 0
        ];
        let batch = sched.compile(&reqs, &mut cache);
        assert_eq!(batch.requests.len(), 3);
        assert!(batch.cache_hits >= 1, "duplicate GPT-2 must hit the cache");
        assert!(batch.requests[0]
            .program
            .shares_storage_with(&batch.requests[2].program));
        assert!(!batch.requests[0]
            .program
            .shares_storage_with(&batch.requests[1].program));
    }

    #[test]
    fn cal_shape_is_simulable() {
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE] {
            let plan = TilePlan::plan(&cfg);
            let cal = CalShape::for_plan(&plan);
            assert!(cal.sq >= 8 && cal.sq <= 64);
            assert_eq!(cal.sk % cal.bk, 0);
            assert!(cal.attn_flops() > 0 && cal.hbm_bytes() > 0);
        }
    }

    #[test]
    fn phased_compile_decode_reps_scale_with_kv_but_reuse_the_program() {
        let sched = BatchScheduler::default();
        let mut cache = ProgramCache::new();
        let req = Request::new(0, GPT2_SMALL);
        let short = sched.compile_phased(&[(req, Phase::Decode { kv_len: 256 })], &mut cache);
        let long = sched.compile_phased(&[(req, Phase::Decode { kv_len: 2048 })], &mut cache);
        assert_eq!(short.requests.len(), 1);
        let (s, l) = (&short.requests[0], &long.requests[0]);
        assert!(s.phase.is_decode() && l.phase.is_decode());
        // the cached program is shared: KV growth scales reps, not code
        assert!(s.program.shares_storage_with(&l.program));
        assert_eq!(long.cache_misses, 0, "longer cache must not recompile");
        assert!(l.reps > s.reps, "reps {} !> {}", l.reps, s.reps);
        assert_eq!(s.cal.sq, 1, "decode slice is single-query");
        assert!(s.proj_flops_per_cluster > 0);
    }

    #[test]
    fn phased_compile_prefill_dominates_decode_in_cluster_share() {
        let sched = BatchScheduler::default();
        let mut cache = ProgramCache::new();
        let a = Request::new(0, GPT2_SMALL);
        let b = Request::new(1, GPT2_SMALL);
        let batch = sched.compile_phased(
            &[
                (a, Phase::Prefill { prompt: 2048 }),
                (b, Phase::Decode { kv_len: 2048 }),
            ],
            &mut cache,
        );
        assert!(
            batch.requests[0].clusters.len() > batch.requests[1].clusters.len(),
            "prefill {} clusters !> decode {}",
            batch.requests[0].clusters.len(),
            batch.requests[1].clusters.len()
        );
        // disjoint ownership still holds in the phased scope
        let mut owned = vec![false; CLUSTERS];
        for cr in &batch.requests {
            for &c in &cr.clusters {
                assert!(!owned[c], "cluster {c} double-assigned");
                owned[c] = true;
            }
        }
    }
}
