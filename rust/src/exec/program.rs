//! Compiled kernel programs and the program cache.
//!
//! Kernel builders (`kernels::{softmax, flash_attention, gemm}`) emit
//! per-core instruction streams. Building them is pure but not free —
//! a FlashAttention-2 head program is thousands of instructions — and
//! before this module every call site rebuilt the raw `Vec<Instr>` from
//! scratch. A [`Program`] wraps the streams in an `Arc` so a compiled
//! kernel is cloned by reference, and a [`ProgramCache`] memoizes builds
//! keyed by [`ProgramKey`] (kernel kind + model/tile identity + core
//! count), so the batched serving path compiles each distinct kernel
//! exactly once.

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::TilePlan;
use crate::isa::Instr;
use crate::kernels::flash_attention::FaVariant;
use crate::kernels::gelu::GeluVariant;
use crate::kernels::layernorm::LayerNormVariant;
use crate::kernels::softmax::{SoftmaxBwdVariant, SoftmaxVariant};
use crate::model::TransformerConfig;
use crate::sim::decode::{decode, DecodedProgram};

/// Which kernel a [`Program`] implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Row-parallel softmax in one of the paper's four configurations.
    Softmax(SoftmaxVariant),
    /// Softmax backward (training step): `dx = y ⊙ (g − ⟨g, y⟩)`.
    SoftmaxBwd(SoftmaxBwdVariant),
    /// Row-parallel GELU in one of the nine form × exp-technology
    /// configurations.
    Gelu(GeluVariant),
    /// Row-parallel two-pass LayerNorm.
    LayerNorm(LayerNormVariant),
    /// FlashAttention-2 prefill head (query rows over the cores).
    FlashAttention(FaVariant),
    /// Single-query FlashAttention decode slice (KV tiles over the
    /// cores, flash-decoding style — DESIGN.md §10).
    FlashDecode(FaVariant),
    /// The dot-product GEMM kernel.
    Gemm,
    /// Ad-hoc instruction streams (e.g. hand-written micro-benchmarks)
    /// routed through the same [`crate::sim::System`] entry points.
    Raw,
}

/// A compiled, immutable, cheaply-cloneable kernel program: one
/// instruction stream per cluster core (empty streams for idle cores).
///
/// Compilation also lowers every stream into its pre-decoded micro-op
/// form ([`DecodedProgram`]) once, so the simulator fast path never
/// re-derives per-instruction facts at execution time; cache-cloned
/// handles share both representations.
#[derive(Clone, Debug)]
pub struct Program {
    /// Which kernel this program implements.
    pub kind: KernelKind,
    per_core: Arc<Vec<Vec<Instr>>>,
    decoded: Arc<Vec<DecodedProgram>>,
}

impl Program {
    /// Compile per-core instruction streams into a shared handle,
    /// lowering each stream to its decoded micro-op form once.
    pub fn new(kind: KernelKind, per_core: Vec<Vec<Instr>>) -> Self {
        let decoded = per_core.iter().map(|s| decode(s)).collect();
        Program { kind, per_core: Arc::new(per_core), decoded: Arc::new(decoded) }
    }

    /// The per-core instruction streams (reference-interpreter form).
    pub fn per_core(&self) -> &[Vec<Instr>] {
        &self.per_core
    }

    /// The per-core pre-decoded micro-op streams (fast-path form).
    pub fn decoded(&self) -> &[DecodedProgram] {
        &self.decoded
    }

    /// The shared decoded-stream handle itself. Its pointer identity is
    /// the tile memo's program key ([`crate::sim::memo`]): cache-cloned
    /// handles compare equal, rebuilt ones don't.
    pub fn decoded_arc(&self) -> &Arc<Vec<DecodedProgram>> {
        &self.decoded
    }

    /// Total instructions across all cores (static count, not dynamic).
    pub fn instr_count(&self) -> usize {
        self.per_core.iter().map(Vec::len).sum()
    }

    /// Cores with a non-empty stream.
    pub fn active_cores(&self) -> usize {
        self.per_core.iter().filter(|p| !p.is_empty()).count()
    }

    /// True when `self` and `other` share the same underlying storage —
    /// i.e. one is a cache-clone of the other, not a rebuild.
    pub fn shares_storage_with(&self, other: &Program) -> bool {
        Arc::ptr_eq(&self.per_core, &other.per_core)
    }
}

/// Cache key: kernel kind, the identifying dimensions of the
/// `TransformerConfig` + `TilePlan` pair (or raw kernel dims), and the
/// core count the program was partitioned for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProgramKey {
    /// Kernel kind the cached program implements.
    pub kind: KernelKind,
    /// Model name for request-derived programs, `"kernel"` for ad-hoc.
    pub model: &'static str,
    /// Core count the program was partitioned for.
    pub n_cores: u32,
    /// Shape identity. For request-derived programs:
    /// `[seq, heads, d_head, bq, bk, 0]`; for ad-hoc kernel calls the
    /// caller packs its own dimensions.
    pub dims: [u32; 6],
}

impl ProgramKey {
    /// Key for a program derived from a request's model + tile plan.
    pub fn for_request(
        kind: KernelKind,
        cfg: &TransformerConfig,
        plan: &TilePlan,
        n_cores: u32,
    ) -> Self {
        ProgramKey {
            kind,
            model: cfg.name,
            n_cores,
            dims: [cfg.seq, cfg.heads, cfg.d_head(), plan.bq, plan.bk, 0],
        }
    }

    /// Key for an ad-hoc kernel invocation (benches, calibration runs).
    pub fn for_kernel(kind: KernelKind, dims: [u32; 6], n_cores: u32) -> Self {
        ProgramKey { kind, model: "kernel", n_cores, dims }
    }

    /// Key for a decode-slice program. Deliberately independent of the
    /// KV-cache length: the slice window (`sk_slice`, `bk`) is fixed per
    /// model shape, and a growing cache only scales the *repetitions* of
    /// the cached program — so every decode step of a request hits the
    /// same entry.
    pub fn for_decode(
        kind: KernelKind,
        cfg: &TransformerConfig,
        sk_slice: u32,
        bk: u32,
        n_cores: u32,
    ) -> Self {
        ProgramKey {
            kind,
            model: cfg.name,
            n_cores,
            dims: [sk_slice, cfg.heads, cfg.d_head(), 1, bk, 1],
        }
    }
}

/// Memoizing store of compiled programs with hit/miss accounting.
#[derive(Debug, Default)]
pub struct ProgramCache {
    map: HashMap<ProgramKey, Program>,
    /// Lookups served from the cache since construction.
    pub hits: u64,
    /// Lookups that had to run the kernel builder.
    pub misses: u64,
}

impl ProgramCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the program for `key`, invoking `build` only on a miss.
    pub fn get_or_build(&mut self, key: ProgramKey, build: impl FnOnce() -> Program) -> Program {
        if let Some(p) = self.map.get(&key) {
            self.hits += 1;
            return p.clone();
        }
        self.misses += 1;
        let p = build();
        self.map.insert(key, p.clone());
        p
    }

    /// Number of distinct compiled programs held.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no program has been compiled yet.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Instr;

    fn tiny_program() -> Program {
        Program::new(KernelKind::Raw, vec![vec![Instr::Nop], vec![]])
    }

    #[test]
    fn cache_hits_share_storage_and_skip_builder() {
        let mut cache = ProgramCache::new();
        let key = ProgramKey::for_kernel(KernelKind::Raw, [1, 2, 3, 4, 5, 6], 8);
        let mut builds = 0u32;
        let a = cache.get_or_build(key, || {
            builds += 1;
            tiny_program()
        });
        let b = cache.get_or_build(key, || {
            builds += 1;
            tiny_program()
        });
        assert_eq!(builds, 1, "second lookup must not re-run the builder");
        assert!(a.shares_storage_with(&b));
        assert_eq!((cache.hits, cache.misses), (1, 1));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_keys_miss() {
        let mut cache = ProgramCache::new();
        let k1 = ProgramKey::for_kernel(KernelKind::Raw, [1, 0, 0, 0, 0, 0], 8);
        let k2 = ProgramKey::for_kernel(KernelKind::Raw, [2, 0, 0, 0, 0, 0], 8);
        let a = cache.get_or_build(k1, tiny_program);
        let b = cache.get_or_build(k2, tiny_program);
        assert!(!a.shares_storage_with(&b));
        assert_eq!((cache.hits, cache.misses), (0, 2));
    }

    #[test]
    fn request_keys_separate_models_and_plans() {
        use crate::model::{GPT2_SMALL, GPT3_XL};
        let p2 = TilePlan::plan(&GPT2_SMALL);
        let p3 = TilePlan::plan(&GPT3_XL);
        let k_a = ProgramKey::for_request(KernelKind::Gemm, &GPT2_SMALL, &p2, 8);
        let k_b = ProgramKey::for_request(KernelKind::Gemm, &GPT2_SMALL, &p2, 8);
        let k_c = ProgramKey::for_request(KernelKind::Gemm, &GPT3_XL, &p3, 8);
        assert_eq!(k_a, k_b);
        assert_ne!(k_a, k_c);
    }

    #[test]
    fn program_counts() {
        let p = tiny_program();
        assert_eq!(p.instr_count(), 1);
        assert_eq!(p.active_cores(), 1);
    }

    #[test]
    fn programs_carry_decoded_streams() {
        let p = tiny_program();
        assert_eq!(p.decoded().len(), p.per_core().len());
        assert_eq!(p.decoded()[0].len(), 1);
        assert!(p.decoded()[1].is_empty());
        // cache clones share the decoded lowering too
        let q = p.clone();
        assert!(std::ptr::eq(p.decoded().as_ptr(), q.decoded().as_ptr()));
    }
}
