//! Continuous batching (DESIGN.md §10): the autoregressive serving loop
//! over the unified [`Backend`] API.
//!
//! The engine steps in **iterations**. Each iteration:
//!
//! 1. **Admit** — waiting requests whose `arrival_iter` has come join
//!    the live set, as long as a cluster is free for them (at most one
//!    live request per cluster).
//! 2. **Rebalance** — the cluster grid is repartitioned among the live
//!    requests proportionally to their *current-phase* work (a prefill
//!    outweighs a decode by orders of magnitude), every live request
//!    keeping at least one cluster and cluster sets staying disjoint.
//! 3. **Execute** — each request runs one phase step: its whole prompt
//!    prefill (first scheduled iteration), or one decode token against
//!    its KV-cache (subsequent iterations). The backend executes the
//!    compiled iteration; the global clock advances by the iteration
//!    makespan (a synchronous iteration barrier — requests that finish
//!    their step early idle until the barrier).
//! 4. **Retire** — requests that produced their token target leave the
//!    live set; their clusters are rebalanced next iteration.
//!
//! The prefill iteration produces the request's first token (the last
//! prompt position predicts it), so time-to-first-token is admission →
//! end of the prefill iteration. Each decode iteration produces one
//! more token at KV length `prompt + generated`.

use super::batch::BatchScheduler;
use super::program::ProgramCache;
use super::report::RunReport;
use super::{Backend, Request};
use crate::model::Phase;

/// One live request's share of an iteration, for the record log.
#[derive(Clone, Debug)]
pub struct IterationEntry {
    /// Request id.
    pub id: u64,
    /// Phase the request ran this iteration.
    pub phase: Phase,
    /// Clusters the request owned this iteration.
    pub clusters: Vec<usize>,
    /// The request's own cycles for its iteration step.
    pub cycles: f64,
}

/// One continuous-batching iteration, for introspection and tests.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// Iteration index.
    pub iter: u32,
    /// Global clock (cycles) after this iteration's barrier.
    pub clock_cycles: u64,
    /// Per-live-request shares.
    pub entries: Vec<IterationEntry>,
}

/// Result of a continuous-batching run: per-request serving reports
/// (TTFT, tokens, per-token latency, energy) plus the iteration log.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Which backend executed the run.
    pub backend: &'static str,
    /// Iterations actually executed (gaps in the arrival schedule are
    /// fast-forwarded and do not count).
    pub iterations: u32,
    /// Global clock at the end of the run (cycles).
    pub total_cycles: u64,
    /// One report per request, in retirement order. `cycles` is
    /// admission→retirement residence time; the serving metrics
    /// (`ttft_cycles`, `tokens`, `decode_token_cycles`) are filled in.
    /// Requests the iteration bound cut off are included with their
    /// partial — possibly zero — progress; nothing submitted vanishes.
    pub per_request: Vec<RunReport>,
    /// The per-iteration schedule, for introspection and invariants.
    pub log: Vec<IterationRecord>,
}

impl ServeReport {
    /// Total tokens generated across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.per_request.iter().map(|r| r.tokens as u64).sum()
    }

    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.total_tokens() as f64 / (self.total_cycles as f64 / crate::sim::CLOCK_HZ)
        }
    }

    /// Aggregate energy across all requests (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.per_request.iter().map(|r| r.energy_pj).sum()
    }
}

/// A request in flight through the continuous batch.
struct LiveReq {
    req: Request,
    /// Set once the prefill iteration has run.
    prefilled: bool,
    /// Tokens produced so far (the prefill's first token included).
    generated: u32,
    admit_clock: u64,
    ttft_cycles: f64,
    /// Sum of the iteration-barrier cycles over this request's decode
    /// iterations — the *observed* inter-token time under
    /// co-scheduling, on the same clock as TTFT and tokens/s.
    decode_cycles: f64,
    decode_iters: u32,
    energy_pj: f64,
    softmax_cycles: f64,
    gemm_cycles: f64,
    attn_cycles: f64,
    dma_cycles: f64,
    /// Accumulated sampled-simulation error bound over this request's
    /// iterations (zero unless the backend sampled).
    error_bound_cycles: f64,
    last_clusters: usize,
}

impl LiveReq {
    fn new(req: Request, admit_clock: u64) -> Self {
        LiveReq {
            req,
            prefilled: false,
            generated: 0,
            admit_clock,
            ttft_cycles: 0.0,
            decode_cycles: 0.0,
            decode_iters: 0,
            energy_pj: 0.0,
            softmax_cycles: 0.0,
            gemm_cycles: 0.0,
            attn_cycles: 0.0,
            dma_cycles: 0.0,
            error_bound_cycles: 0.0,
            last_clusters: 0,
        }
    }

    /// Phase this request runs next.
    fn phase(&self) -> Phase {
        if !self.prefilled {
            Phase::Prefill { prompt: self.req.cfg.seq }
        } else {
            Phase::Decode { kv_len: self.req.cfg.seq + self.generated }
        }
    }

    /// Done once prefill ran and the token target is met. A target of
    /// zero (prefill-only request, e.g. ViT) retires after prefill.
    fn done(&self) -> bool {
        self.prefilled && self.generated >= self.req.decode_tokens
    }

    fn retire(self, finish_clock: u64, backend: &'static str) -> RunReport {
        let decode_token_cycles = if self.decode_iters > 0 {
            self.decode_cycles / self.decode_iters as f64
        } else {
            0.0
        };
        RunReport {
            backend,
            request_id: self.req.id,
            model: self.req.cfg.name,
            cycles: (finish_clock - self.admit_clock) as f64,
            energy_pj: self.energy_pj,
            softmax_cycles: self.softmax_cycles,
            gemm_cycles: self.gemm_cycles,
            attn_cycles: self.attn_cycles,
            dma_cycles: self.dma_cycles,
            clusters_used: self.last_clusters,
            error_bound_cycles: self.error_bound_cycles,
            ttft_cycles: self.ttft_cycles,
            tokens: self.generated,
            decode_token_cycles,
            ..Default::default()
        }
    }
}

/// Drive the continuous-batching loop until every request retires (or
/// `max_iters` is hit — a safety bound for misconfigured traffic).
/// `requests` is the admission queue, ordered by engine submission;
/// arrival iterations stagger admission within it.
pub(crate) fn run_continuous(
    scheduler: BatchScheduler,
    cache: &mut ProgramCache,
    mut waiting: Vec<Request>,
    backend: &mut dyn Backend,
    max_iters: u32,
) -> ServeReport {
    // admit in arrival order, stable by submission id
    waiting.sort_by_key(|r| (r.arrival_iter, r.id));
    let mut waiting = std::collections::VecDeque::from(waiting);
    let mut live: Vec<LiveReq> = Vec::new();
    let mut report = ServeReport { backend: backend.name(), ..Default::default() };
    let mut clock: u64 = 0;
    let mut iter: u32 = 0;
    let mut executed: u32 = 0;

    while iter < max_iters {
        // ---- admit --------------------------------------------------------
        while live.len() < scheduler.clusters {
            match waiting.front() {
                Some(r) if r.arrival_iter <= iter => {
                    let r = waiting.pop_front().expect("front checked");
                    live.push(LiveReq::new(r, clock));
                }
                _ => break,
            }
        }
        if live.is_empty() {
            match waiting.front() {
                // idle gap in the arrival schedule: fast-forward
                Some(r) => {
                    iter = r.arrival_iter;
                    continue;
                }
                None => break,
            }
        }

        // ---- rebalance + compile this iteration ---------------------------
        let entries: Vec<(Request, Phase)> =
            live.iter().map(|lr| (lr.req, lr.phase())).collect();
        let batch = scheduler.compile_phased(&entries, cache);
        let exec = backend.execute(&batch);

        // ---- advance the synchronous iteration barrier --------------------
        let makespan = exec
            .per_request
            .iter()
            .map(|r| r.cycles)
            .fold(0.0f64, f64::max);
        clock += makespan as u64;

        // ---- account per request ------------------------------------------
        let mut entries_log = Vec::with_capacity(live.len());
        for ((lr, cr), r) in live
            .iter_mut()
            .zip(&batch.requests)
            .zip(&exec.per_request)
        {
            lr.energy_pj += r.energy_pj;
            lr.softmax_cycles += r.softmax_cycles;
            lr.gemm_cycles += r.gemm_cycles;
            lr.attn_cycles += r.attn_cycles;
            lr.dma_cycles += r.dma_cycles;
            lr.error_bound_cycles += r.error_bound_cycles;
            lr.last_clusters = cr.clusters.len();
            entries_log.push(IterationEntry {
                id: lr.req.id,
                phase: cr.phase,
                clusters: cr.clusters.clone(),
                cycles: r.cycles,
            });
            if !lr.prefilled {
                lr.prefilled = true;
                lr.ttft_cycles = (clock - lr.admit_clock) as f64;
                if lr.req.decode_tokens > 0 {
                    lr.generated = 1; // the prefill's first token
                }
            } else {
                lr.generated += 1;
                // observed inter-token time is the iteration barrier,
                // not the request's own compute — consistent with the
                // clock that tokens_per_s and TTFT are measured on
                lr.decode_cycles += makespan;
                lr.decode_iters += 1;
            }
        }
        report.log.push(IterationRecord {
            iter,
            clock_cycles: clock,
            entries: entries_log,
        });

        // ---- retire -------------------------------------------------------
        let backend_name = report.backend;
        let mut still_live = Vec::with_capacity(live.len());
        for lr in live {
            if lr.done() {
                report.per_request.push(lr.retire(clock, backend_name));
            } else {
                still_live.push(lr);
            }
        }
        live = still_live;

        iter += 1;
        executed += 1;
    }

    // safety bound hit: report unfinished requests as-is, and requests
    // the bound prevented from ever being admitted with zero progress —
    // nothing submitted may vanish from the report
    let backend_name = report.backend;
    for lr in live {
        report.per_request.push(lr.retire(clock, backend_name));
    }
    for r in waiting {
        report.per_request.push(LiveReq::new(r, clock).retire(clock, backend_name));
    }
    report.iterations = executed;
    report.total_cycles = clock;
    report
}
