//! Continuous batching (DESIGN.md §10) and the resilient serving tier
//! on top of it (DESIGN.md §12): the autoregressive serving loop over
//! the unified [`Backend`] API.
//!
//! The engine steps in **iterations**. Each iteration:
//!
//! 1. **Admit** — waiting requests whose `arrival_iter` and
//!    `arrival_cycles` have come join the live set, as long as a
//!    healthy cluster is free for them and the admission controller's
//!    live-set bound allows it. Ready requests the controller cannot
//!    take may be **shed** (bounded queue depth, projected-TTFT bound)
//!    or expire against their deadline while waiting.
//! 2. **Rebalance** — the *healthy* cluster grid is repartitioned among
//!    the live requests proportionally to their *current-phase* work (a
//!    prefill outweighs a decode by orders of magnitude), every live
//!    request keeping at least one cluster and cluster sets staying
//!    disjoint. Quarantined and offline clusters are planned around.
//! 3. **Execute** — each request runs one phase step: its whole prompt
//!    prefill (first scheduled iteration), or one decode token against
//!    its KV-cache (subsequent iterations). The backend executes the
//!    compiled iteration; the global clock advances by the iteration
//!    makespan (a synchronous iteration barrier). If a cluster's job
//!    **failed** (injected fault), the iteration re-plans around the
//!    now-quarantined cluster and retries, up to a bounded number of
//!    attempts; failed attempts cost time and energy but grant no
//!    progress, so tokens are never double-counted.
//! 4. **Retire** — requests that produced their token target leave the
//!    live set ([`Outcome::Completed`]); requests past their deadline
//!    are retired with partial progress ([`Outcome::TimedOut`]).
//!
//! Under overload (ready backlog above configurable thresholds) the
//! loop walks the graceful-degradation ladder ([`ExecMode`]): full
//! cycle simulation → sampled simulation → analytic estimates, and
//! records the level per iteration.
//!
//! The prefill iteration produces the request's first token (the last
//! prompt position predicts it), so time-to-first-token is arrival →
//! end of the prefill iteration. Each decode iteration produces one
//! more token at KV length `prompt + generated`.

use super::batch::BatchScheduler;
use super::program::ProgramCache;
use super::report::{Outcome, RunReport};
use super::{Backend, ExecMode, Request};
use crate::model::Phase;

/// One live request's share of an iteration, for the record log.
#[derive(Clone, Debug)]
pub struct IterationEntry {
    /// Request id.
    pub id: u64,
    /// Phase the request ran this iteration.
    pub phase: Phase,
    /// Clusters the request owned this iteration.
    pub clusters: Vec<usize>,
    /// The request's own cycles for its iteration step.
    pub cycles: f64,
}

/// One continuous-batching iteration, for introspection and tests.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// Iteration index.
    pub iter: u32,
    /// Global clock (cycles) after this iteration's barrier.
    pub clock_cycles: u64,
    /// Per-live-request shares.
    pub entries: Vec<IterationEntry>,
    /// Degradation level the iteration ran at ([`ExecMode::Full`]
    /// unless overload pushed the loop down the ladder).
    pub mode: ExecMode,
    /// Execution attempts this iteration took (1 = no retry).
    pub attempts: u32,
    /// Clusters quarantined or offline while this iteration planned.
    pub quarantined: Vec<usize>,
}

/// Admission, deadline, retry and degradation policy for the resilient
/// serve loop. [`ServeOptions::default`] turns every resilience knob
/// off (unbounded admission, no deadlines, no degradation), which makes
/// a fault-free run bit-identical to the plain continuous-batching
/// loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Iteration safety bound.
    pub max_iters: u32,
    /// Admission controller: max concurrently live requests (further
    /// bounded by the number of healthy clusters).
    pub max_live: usize,
    /// Admission controller: max *ready* requests allowed to wait in
    /// the queue; newest arrivals beyond it are shed.
    pub max_queue: usize,
    /// TTFT service-level objective in cycles (used by projected-TTFT
    /// shedding and SLO attainment).
    pub ttft_slo_cycles: Option<u64>,
    /// Per-token latency SLO in cycles (SLO attainment).
    pub token_slo_cycles: Option<u64>,
    /// Default per-request deadline (cycles after arrival) applied when
    /// a request carries none of its own.
    pub deadline_cycles: Option<u64>,
    /// Shed a ready waiting request when its projected TTFT — time
    /// already waited plus the last iteration's makespan — exceeds the
    /// TTFT SLO (it could no longer meet it anyway).
    pub shed_over_projected_ttft: bool,
    /// Bounded retry: max execution attempts per iteration.
    pub max_attempts: u32,
    /// Iterations a transiently-failed cluster sits out before being
    /// planned on again.
    pub quarantine_iters: u32,
    /// Ready-backlog pressure at which the loop degrades to sampled
    /// simulation ([`ExecMode::Sampled`]).
    pub degrade_sampled_at: usize,
    /// Ready-backlog pressure at which the loop degrades to analytic
    /// estimates ([`ExecMode::Analytic`]).
    pub degrade_analytic_at: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_iters: 4096,
            max_live: usize::MAX,
            max_queue: usize::MAX,
            ttft_slo_cycles: None,
            token_slo_cycles: None,
            deadline_cycles: None,
            shed_over_projected_ttft: false,
            max_attempts: 3,
            quarantine_iters: 3,
            degrade_sampled_at: usize::MAX,
            degrade_analytic_at: usize::MAX,
        }
    }
}

impl ServeOptions {
    /// The plain continuous-batching policy (every resilience knob
    /// off) with an explicit iteration bound.
    pub fn legacy(max_iters: u32) -> Self {
        ServeOptions { max_iters, ..Default::default() }
    }
}

/// Tail-latency and robustness summary of a serve run (DESIGN.md §12).
/// Percentiles are over requests that reached the respective milestone
/// (TTFT: produced a first token; token latency: decoded ≥ 1 step);
/// shed requests appear only in the outcome counts.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloSummary {
    /// Median time-to-first-token (cycles).
    pub ttft_p50_cycles: f64,
    /// 95th-percentile TTFT (cycles).
    pub ttft_p95_cycles: f64,
    /// 99th-percentile TTFT (cycles).
    pub ttft_p99_cycles: f64,
    /// Median per-token decode latency (cycles).
    pub token_p50_cycles: f64,
    /// 95th-percentile per-token decode latency (cycles).
    pub token_p95_cycles: f64,
    /// 99th-percentile per-token decode latency (cycles).
    pub token_p99_cycles: f64,
    /// Fraction of submitted requests that completed within the SLO
    /// targets (completed fraction when no targets are set).
    pub attainment: f64,
    /// Requests that retired normally.
    pub completed: u32,
    /// Requests the admission controller shed.
    pub shed: u32,
    /// Requests retired at their deadline with partial progress.
    pub timed_out: u32,
    /// Requests still in flight when the run ended.
    pub unfinished: u32,
    /// Iteration attempts that had to be re-executed after a cluster
    /// failure.
    pub retries: u32,
    /// Effective faults the simulator injected over the whole run.
    pub faults_injected: u32,
    /// Times a cluster entered quarantine.
    pub quarantine_events: u32,
    /// Iterations executed at full cycle-sim fidelity.
    pub full_iters: u32,
    /// Iterations executed at sampled fidelity.
    pub sampled_iters: u32,
    /// Iterations executed on analytic estimates.
    pub analytic_iters: u32,
}

/// One cluster's health history over a serve run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterHealth {
    /// Cluster index.
    pub cluster: usize,
    /// Transient failures observed on this cluster.
    pub failures: u32,
    /// Iterations the cluster spent quarantined.
    pub quarantined_iters: u32,
    /// The cluster ended the run offline.
    pub offline: bool,
}

/// Result of a continuous-batching run: per-request serving reports
/// (TTFT, tokens, per-token latency, energy) plus the iteration log
/// and — for the resilient path — the SLO summary and per-cluster
/// health history.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Which backend executed the run.
    pub backend: &'static str,
    /// Iterations actually executed (gaps in the arrival schedule are
    /// fast-forwarded and do not count).
    pub iterations: u32,
    /// Global clock at the end of the run (cycles).
    pub total_cycles: u64,
    /// One report per request, in retirement order. `cycles` is
    /// admission→retirement residence time; the serving metrics
    /// (`ttft_cycles`, `tokens`, `decode_token_cycles`) are filled in.
    /// Requests the iteration bound cut off are included with their
    /// partial — possibly zero — progress; nothing submitted vanishes.
    pub per_request: Vec<RunReport>,
    /// The per-iteration schedule, for introspection and invariants.
    pub log: Vec<IterationRecord>,
    /// Tail-latency / robustness summary.
    pub slo: SloSummary,
    /// Per-cluster health history (failures, quarantine, offline).
    pub health: Vec<ClusterHealth>,
}

impl ServeReport {
    /// Total tokens generated across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.per_request.iter().map(|r| r.tokens as u64).sum()
    }

    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.total_tokens() as f64 / (self.total_cycles as f64 / crate::sim::CLOCK_HZ)
        }
    }

    /// Aggregate energy across all requests (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.per_request.iter().map(|r| r.energy_pj).sum()
    }

    /// Accounting invariants every serve run upholds (checked at the
    /// end of each run; also directly testable):
    ///
    /// - every submitted request appears exactly once, so the outcome
    ///   counts sum to `per_request.len()`;
    /// - shed requests never executed: zero tokens, energy, TTFT and
    ///   decode latency — they appear in counts but not throughput;
    /// - retried work grants no extra tokens: every request's `tokens`
    ///   is bounded by prefill + its decode target.
    pub fn assert_consistent(&self) {
        let by_outcome = |o: Outcome| {
            self.per_request.iter().filter(|r| r.outcome == o).count() as u32
        };
        assert_eq!(
            by_outcome(Outcome::Completed),
            self.slo.completed,
            "completed count mismatch"
        );
        assert_eq!(by_outcome(Outcome::Shed), self.slo.shed, "shed count mismatch");
        assert_eq!(
            by_outcome(Outcome::TimedOut),
            self.slo.timed_out,
            "timed-out count mismatch"
        );
        assert_eq!(
            by_outcome(Outcome::Unfinished),
            self.slo.unfinished,
            "unfinished count mismatch"
        );
        assert_eq!(
            (self.slo.completed + self.slo.shed + self.slo.timed_out + self.slo.unfinished)
                as usize,
            self.per_request.len(),
            "outcome counts must cover every submitted request"
        );
        for r in &self.per_request {
            if r.outcome == Outcome::Shed {
                assert_eq!(r.tokens, 0, "shed request {} has tokens", r.request_id);
                assert_eq!(r.energy_pj, 0.0, "shed request {} has energy", r.request_id);
                assert_eq!(r.ttft_cycles, 0.0, "shed request {} has TTFT", r.request_id);
                assert_eq!(
                    r.decode_token_cycles, 0.0,
                    "shed request {} has decode latency",
                    r.request_id
                );
            }
        }
    }
}

/// A request in flight through the continuous batch.
struct LiveReq {
    req: Request,
    /// Set once the prefill iteration has run.
    prefilled: bool,
    /// Tokens produced so far (the prefill's first token included).
    generated: u32,
    admit_clock: u64,
    /// TTFT/deadline reference: the open-loop arrival clock when the
    /// request carries one, else the admission clock (legacy traffic).
    arrival_ref: u64,
    /// Effective deadline clock (arrival + deadline), if any.
    deadline_clock: Option<u64>,
    ttft_cycles: f64,
    /// Sum of the iteration-barrier cycles over this request's decode
    /// iterations — the *observed* inter-token time under
    /// co-scheduling, on the same clock as TTFT and tokens/s.
    decode_cycles: f64,
    decode_iters: u32,
    retries: u32,
    energy_pj: f64,
    softmax_cycles: f64,
    gemm_cycles: f64,
    attn_cycles: f64,
    dma_cycles: f64,
    /// Accumulated sampled-simulation error bound over this request's
    /// iterations (zero unless the backend sampled).
    error_bound_cycles: f64,
    last_clusters: usize,
}

impl LiveReq {
    fn new(req: Request, admit_clock: u64, default_deadline: Option<u64>) -> Self {
        let arrival_ref =
            if req.arrival_cycles > 0 { req.arrival_cycles } else { admit_clock };
        let deadline_clock = req
            .deadline_cycles
            .or(default_deadline)
            .map(|d| arrival_ref.saturating_add(d));
        LiveReq {
            req,
            prefilled: false,
            generated: 0,
            admit_clock,
            arrival_ref,
            deadline_clock,
            ttft_cycles: 0.0,
            decode_cycles: 0.0,
            decode_iters: 0,
            retries: 0,
            energy_pj: 0.0,
            softmax_cycles: 0.0,
            gemm_cycles: 0.0,
            attn_cycles: 0.0,
            dma_cycles: 0.0,
            error_bound_cycles: 0.0,
            last_clusters: 0,
        }
    }

    /// Phase this request runs next.
    fn phase(&self) -> Phase {
        if !self.prefilled {
            Phase::Prefill { prompt: self.req.cfg.seq }
        } else {
            Phase::Decode { kv_len: self.req.cfg.seq + self.generated }
        }
    }

    /// Done once prefill ran and the token target is met. A target of
    /// zero (prefill-only request, e.g. ViT) retires after prefill.
    fn done(&self) -> bool {
        self.prefilled && self.generated >= self.req.decode_tokens
    }

    /// Past the effective deadline at `clock`?
    fn expired(&self, clock: u64) -> bool {
        self.deadline_clock.is_some_and(|d| clock >= d)
    }

    fn retire(self, finish_clock: u64, backend: &'static str, outcome: Outcome) -> RunReport {
        let decode_token_cycles = if self.decode_iters > 0 {
            self.decode_cycles / self.decode_iters as f64
        } else {
            0.0
        };
        RunReport {
            backend,
            request_id: self.req.id,
            model: self.req.cfg.name,
            cycles: (finish_clock - self.admit_clock) as f64,
            energy_pj: self.energy_pj,
            softmax_cycles: self.softmax_cycles,
            gemm_cycles: self.gemm_cycles,
            attn_cycles: self.attn_cycles,
            dma_cycles: self.dma_cycles,
            clusters_used: self.last_clusters,
            error_bound_cycles: self.error_bound_cycles,
            ttft_cycles: self.ttft_cycles,
            tokens: self.generated,
            decode_token_cycles,
            outcome,
            retries: self.retries,
            ..Default::default()
        }
    }
}

/// Per-cluster health bookkeeping of the resilient loop.
#[derive(Clone, Copy, Debug, Default)]
struct Health {
    failures: u32,
    /// Iteration index at which quarantine lifts.
    quarantined_until: Option<u32>,
    quarantined_iters: u32,
    offline: bool,
}

impl Health {
    fn available(&self, iter: u32) -> bool {
        !self.offline && self.quarantined_until.is_none_or(|u| iter >= u)
    }
}

/// Plain continuous batching: the resilient loop with every resilience
/// knob off (bit-identical to the pre-robustness behavior).
pub(crate) fn run_continuous(
    scheduler: BatchScheduler,
    cache: &mut ProgramCache,
    waiting: Vec<Request>,
    backend: &mut dyn Backend,
    max_iters: u32,
) -> ServeReport {
    run_resilient(scheduler, cache, waiting, backend, None, &ServeOptions::legacy(max_iters))
}

/// Drive the resilient continuous-batching loop until every request
/// retires, is shed, or times out (or `max_iters` is hit — a safety
/// bound for misconfigured traffic). `waiting` is the admission queue;
/// arrival iterations/cycles stagger admission within it. `fallback`
/// executes iterations once the degradation ladder reaches
/// [`ExecMode::Analytic`] and `primary` cannot switch itself.
pub(crate) fn run_resilient(
    scheduler: BatchScheduler,
    cache: &mut ProgramCache,
    mut waiting: Vec<Request>,
    primary: &mut dyn Backend,
    mut fallback: Option<&mut dyn Backend>,
    opts: &ServeOptions,
) -> ServeReport {
    // admit in arrival order, stable by submission id
    waiting.sort_by_key(|r| (r.arrival_iter, r.arrival_cycles, r.id));
    let mut waiting = std::collections::VecDeque::from(waiting);
    let mut live: Vec<LiveReq> = Vec::new();
    let mut report = ServeReport { backend: primary.name(), ..Default::default() };
    let mut health = vec![Health::default(); scheduler.clusters];
    let mut clock: u64 = 0;
    let mut iter: u32 = 0;
    let mut executed: u32 = 0;
    // degradation-ladder state: the level the loop currently runs at,
    // and whether `primary` was ever switched off Full (so a backend
    // that never degrades never sees a set_mode call)
    let mut level = ExecMode::Full;
    let mut primary_switched = false;

    while iter < opts.max_iters {
        let backend_name = report.backend;
        // ---- cluster health ----------------------------------------------
        if health.iter().all(|h| h.offline) {
            break; // nothing left to run on
        }
        let healthy: Vec<usize> =
            (0..scheduler.clusters).filter(|&c| health[c].available(iter)).collect();
        for h in health.iter_mut() {
            if !h.offline && !h.available(iter) {
                h.quarantined_iters += 1;
            }
        }

        // ---- deadlines of waiting requests --------------------------------
        waiting.retain(|r| {
            let lr = LiveReq::new(*r, clock, opts.deadline_cycles);
            if lr.expired(clock) {
                report.slo.timed_out += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
                false
            } else {
                true
            }
        });

        // ---- admit --------------------------------------------------------
        let cap = opts.max_live.max(1).min(healthy.len().max(1));
        while live.len() < cap {
            match waiting.front() {
                Some(r) if r.arrival_iter <= iter && r.arrival_cycles <= clock => {
                    let r = waiting.pop_front().expect("front checked");
                    live.push(LiveReq::new(r, clock, opts.deadline_cycles));
                }
                _ => break,
            }
        }

        // ---- shed ---------------------------------------------------------
        // ready requests the admission loop could not take
        let ready = |r: &Request| r.arrival_iter <= iter && r.arrival_cycles <= clock;
        if opts.shed_over_projected_ttft {
            if let Some(slo) = opts.ttft_slo_cycles {
                let last_makespan = report
                    .log
                    .last()
                    .map_or(0, |l| l.entries.iter().map(|e| e.cycles as u64).max().unwrap_or(0));
                while let Some(idx) = waiting.iter().position(|r| {
                    ready(r)
                        && clock.saturating_sub(r.arrival_cycles) + last_makespan > slo
                }) {
                    let r = waiting.remove(idx).expect("position checked");
                    report.slo.shed += 1;
                    report.per_request.push(
                        LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Shed),
                    );
                }
            }
        }
        loop {
            let ready_waiting = waiting.iter().filter(|r| ready(r)).count();
            if ready_waiting <= opts.max_queue {
                break;
            }
            // shed the newest ready arrival (back of the queue)
            let idx = waiting
                .iter()
                .rposition(|r| ready(r))
                .expect("ready_waiting > 0 implies a ready entry");
            let r = waiting.remove(idx).expect("rposition checked");
            report.slo.shed += 1;
            report
                .per_request
                .push(LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Shed));
        }

        if live.is_empty() {
            match waiting.front() {
                // idle gap in the arrival schedule: fast-forward
                Some(r) => {
                    iter = iter.max(r.arrival_iter);
                    if r.arrival_cycles > clock {
                        clock = r.arrival_cycles;
                    }
                    if r.arrival_iter <= iter && r.arrival_cycles <= clock && !healthy.is_empty()
                    {
                        continue;
                    }
                    iter += 1; // every cluster quarantined: sit the iteration out
                    continue;
                }
                None => break,
            }
        }
        if healthy.is_empty() {
            // every cluster quarantined (none offline, or we'd have
            // broken above): sit this iteration out until one returns
            iter += 1;
            continue;
        }

        // ---- degradation ladder -------------------------------------------
        let pressure = live.len() + waiting.iter().filter(|r| ready(r)).count();
        let desired = if pressure >= opts.degrade_analytic_at {
            ExecMode::Analytic
        } else if pressure >= opts.degrade_sampled_at {
            ExecMode::Sampled
        } else {
            ExecMode::Full
        };
        if desired != level {
            match desired {
                ExecMode::Full => {
                    // only un-degrade a backend this loop degraded; a
                    // backend configured by its owner is never touched
                    if primary_switched && primary.set_mode(ExecMode::Full) {
                        primary_switched = false;
                    }
                    level = ExecMode::Full;
                }
                ExecMode::Sampled => {
                    if primary.set_mode(ExecMode::Sampled) {
                        primary_switched = true;
                        level = ExecMode::Sampled;
                    } else {
                        level = ExecMode::Full; // backend cannot degrade
                    }
                }
                ExecMode::Analytic => {
                    if fallback.is_some() {
                        level = ExecMode::Analytic;
                    } else if primary.set_mode(ExecMode::Analytic) {
                        primary_switched = true;
                        level = ExecMode::Analytic;
                    } else if primary.set_mode(ExecMode::Sampled) {
                        // no separate estimator: sampled mode is the
                        // deepest the primary can degrade to
                        primary_switched = true;
                        level = ExecMode::Sampled;
                    } else {
                        level = ExecMode::Full;
                    }
                }
            }
        }
        let use_fallback = level == ExecMode::Analytic && fallback.is_some();

        // ---- execute with bounded retries ---------------------------------
        let mut attempts = 0u32;
        let mut iter_cycles_total = 0.0f64;
        let (batch, exec) = loop {
            attempts += 1;
            let avail: Vec<usize> =
                (0..scheduler.clusters).filter(|&c| health[c].available(iter)).collect();
            if avail.is_empty() {
                break (None, None); // everything failed into quarantine
            }
            let runnable = live.len().min(avail.len());
            let entries: Vec<(Request, Phase)> =
                live[..runnable].iter().map(|lr| (lr.req, lr.phase())).collect();
            let batch = scheduler.compile_phased_on(&entries, cache, &avail);
            let exec = match fallback {
                Some(ref mut fb) if use_fallback => fb.execute(&batch),
                _ => primary.execute(&batch),
            };

            // barrier: the attempt costs wall-clock whether it failed
            // or not
            let makespan = exec.per_request.iter().map(|r| r.cycles).fold(0.0f64, f64::max);
            clock += makespan as u64;
            iter_cycles_total += makespan;
            report.slo.faults_injected += exec.faults_injected;

            // energy and breakdowns accrue on every attempt — wasted
            // work burns real energy and time
            for (lr, r) in live[..runnable].iter_mut().zip(&exec.per_request) {
                lr.energy_pj += r.energy_pj;
                lr.softmax_cycles += r.softmax_cycles;
                lr.gemm_cycles += r.gemm_cycles;
                lr.attn_cycles += r.attn_cycles;
                lr.dma_cycles += r.dma_cycles;
                lr.error_bound_cycles += r.error_bound_cycles;
            }

            // health bookkeeping from the attempt's fault surface
            for &c in &exec.offline_clusters {
                if !health[c].offline {
                    health[c].offline = true;
                }
            }
            let failed = !exec.failed_clusters.is_empty();
            for &c in &exec.failed_clusters {
                if !health[c].offline {
                    health[c].failures += 1;
                    health[c].quarantined_until = Some(iter + 1 + opts.quarantine_iters);
                    report.slo.quarantine_events += 1;
                }
            }
            if !failed {
                break (Some(batch), Some(exec));
            }
            // per-request retry accounting: the requests whose reports
            // are untrusted pay the retry
            for (lr, r) in live[..runnable].iter_mut().zip(&exec.per_request) {
                if r.failed {
                    lr.retries += 1;
                }
            }
            if attempts >= opts.max_attempts {
                break (Some(batch), Some(exec));
            }
            report.slo.retries += 1;
        };

        // ---- account per request ------------------------------------------
        let quarantined: Vec<usize> =
            (0..scheduler.clusters).filter(|&c| !health[c].available(iter)).collect();
        if let (Some(batch), Some(exec)) = (batch, exec) {
            let mut entries_log = Vec::with_capacity(batch.requests.len());
            for ((lr, cr), r) in live
                .iter_mut()
                .zip(&batch.requests)
                .zip(&exec.per_request)
            {
                lr.last_clusters = cr.clusters.len();
                entries_log.push(IterationEntry {
                    id: lr.req.id,
                    phase: cr.phase,
                    clusters: cr.clusters.clone(),
                    cycles: r.cycles,
                });
                if r.failed {
                    continue; // attempts exhausted: no progress granted
                }
                if !lr.prefilled {
                    lr.prefilled = true;
                    lr.ttft_cycles = (clock - lr.arrival_ref) as f64;
                    if lr.req.decode_tokens > 0 {
                        lr.generated = 1; // the prefill's first token
                    }
                } else {
                    lr.generated += 1;
                    // observed inter-token time is the iteration barrier
                    // (including failed attempts), not the request's own
                    // compute — consistent with the clock that
                    // tokens_per_s and TTFT are measured on
                    lr.decode_cycles += iter_cycles_total;
                    lr.decode_iters += 1;
                }
            }
            match level {
                ExecMode::Full => report.slo.full_iters += 1,
                ExecMode::Sampled => report.slo.sampled_iters += 1,
                ExecMode::Analytic => report.slo.analytic_iters += 1,
            }
            report.log.push(IterationRecord {
                iter,
                clock_cycles: clock,
                entries: entries_log,
                mode: level,
                attempts,
                quarantined,
            });
            executed += 1;
        }

        // ---- retire -------------------------------------------------------
        let mut still_live = Vec::with_capacity(live.len());
        for lr in live {
            if lr.done() {
                report.slo.completed += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::Completed));
            } else if lr.expired(clock) {
                report.slo.timed_out += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
            } else {
                still_live.push(lr);
            }
        }
        live = still_live;

        iter += 1;
    }

    // safety bound (or total cluster loss) hit: report unfinished
    // requests as-is, and requests never admitted with zero progress —
    // nothing submitted may vanish from the report
    let backend_name = report.backend;
    for lr in live {
        report.slo.unfinished += 1;
        report.per_request.push(lr.retire(clock, backend_name, Outcome::Unfinished));
    }
    for r in waiting {
        report.slo.unfinished += 1;
        report.per_request.push(
            LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Unfinished),
        );
    }
    report.iterations = executed;
    report.total_cycles = clock;
    report.health = (0..scheduler.clusters)
        .map(|c| ClusterHealth {
            cluster: c,
            failures: health[c].failures,
            quarantined_iters: health[c].quarantined_iters,
            offline: health[c].offline,
        })
        .collect();
    finish_slo(&mut report, opts);
    report.assert_consistent();
    report
}

/// Percentile over an unsorted sample (nearest-rank on the sorted
/// order); 0 for an empty sample.
fn percentile(values: &mut [f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let idx = ((values.len() - 1) as f64 * p).round() as usize;
    values[idx]
}

/// Fill the percentile and attainment fields from the per-request
/// reports.
fn finish_slo(report: &mut ServeReport, opts: &ServeOptions) {
    let mut ttft: Vec<f64> = report
        .per_request
        .iter()
        .filter(|r| r.outcome != Outcome::Shed && r.ttft_cycles > 0.0)
        .map(|r| r.ttft_cycles)
        .collect();
    let mut tok: Vec<f64> = report
        .per_request
        .iter()
        .filter(|r| r.outcome != Outcome::Shed && r.decode_token_cycles > 0.0)
        .map(|r| r.decode_token_cycles)
        .collect();
    report.slo.ttft_p50_cycles = percentile(&mut ttft, 0.50);
    report.slo.ttft_p95_cycles = percentile(&mut ttft, 0.95);
    report.slo.ttft_p99_cycles = percentile(&mut ttft, 0.99);
    report.slo.token_p50_cycles = percentile(&mut tok, 0.50);
    report.slo.token_p95_cycles = percentile(&mut tok, 0.95);
    report.slo.token_p99_cycles = percentile(&mut tok, 0.99);
    let total = report.per_request.len();
    if total == 0 {
        report.slo.attainment = 1.0;
        return;
    }
    let attained = report
        .per_request
        .iter()
        .filter(|r| {
            r.outcome == Outcome::Completed
                && opts
                    .ttft_slo_cycles
                    .is_none_or(|s| r.ttft_cycles <= s as f64 || r.ttft_cycles == 0.0)
                && opts
                    .token_slo_cycles
                    .is_none_or(|s| r.decode_token_cycles <= s as f64)
        })
        .count();
    report.slo.attainment = attained as f64 / total as f64;
}
