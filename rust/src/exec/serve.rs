//! Continuous batching (DESIGN.md §10) and the resilient serving tier
//! on top of it (DESIGN.md §12): the autoregressive serving loop over
//! the unified [`Backend`] API.
//!
//! The engine steps in **iterations**. Each iteration:
//!
//! 1. **Admit** — waiting requests whose `arrival_iter` and
//!    `arrival_cycles` have come join the live set, as long as a
//!    healthy cluster is free for them and the admission controller's
//!    live-set bound allows it. Ready requests the controller cannot
//!    take may be **shed** (bounded queue depth, projected-TTFT bound)
//!    or expire against their deadline while waiting.
//! 2. **Rebalance** — the *healthy* cluster grid is repartitioned among
//!    the live requests proportionally to their *current-phase* work (a
//!    prefill outweighs a decode by orders of magnitude), every live
//!    request keeping at least one cluster and cluster sets staying
//!    disjoint. Quarantined and offline clusters are planned around.
//! 3. **Execute** — each request runs one phase step: its whole prompt
//!    prefill (first scheduled iteration), or one decode token against
//!    its KV-cache (subsequent iterations). The backend executes the
//!    compiled iteration; the global clock advances by the iteration
//!    makespan (a synchronous iteration barrier). If a cluster's job
//!    **failed** (injected fault), the iteration re-plans around the
//!    now-quarantined cluster and retries, up to a bounded number of
//!    attempts; failed attempts cost time and energy but grant no
//!    progress, so tokens are never double-counted.
//! 4. **Retire** — requests that produced their token target leave the
//!    live set ([`Outcome::Completed`]); requests past their deadline
//!    are retired with partial progress ([`Outcome::TimedOut`]).
//!
//! Under overload (ready backlog above configurable thresholds) the
//! loop walks the graceful-degradation ladder ([`ExecMode`]): full
//! cycle simulation → sampled simulation → analytic estimates, and
//! records the level per iteration.
//!
//! The prefill iteration produces the request's first token (the last
//! prompt position predicts it), so time-to-first-token is arrival →
//! end of the prefill iteration. Each decode iteration produces one
//! more token at KV length `prompt + generated`.
//!
//! Two decode scenarios (DESIGN.md §15) reshape the per-iteration work
//! without touching the books above: **speculative decoding**
//! ([`SpecDecodeOptions`]) runs `k` draft-model sub-iterations against
//! a fork of the request's KV table, then verifies in one
//! prefill-shaped target pass that commits the accepted run (rejected
//! tails roll back by releasing the fork); **chunked prefill**
//! ([`ServeOptions::chunked_prefill`]) splits a long prompt across
//! several iterations so co-scheduled requests' barriers — and with
//! them TTFT — stay short. Both reduce bit-identically to the plain
//! loop at `k == 0` / chunk ≥ prompt.

use super::batch::{BatchScheduler, ServeEntry};
use super::kvpool::{AppendNeed, BlockId, BlockPool, BlockTable};
use super::prefix::{chunk_fingerprints, PrefixIndex};
use super::program::ProgramCache;
use super::report::{Outcome, PoolReport, RunReport};
use super::{Backend, ExecMode, Request, SchedPolicy};
use crate::coordinator::BlockGeometry;
use crate::model::{Phase, TransformerConfig};
use crate::testkit::{mix, Rng};
use std::collections::VecDeque;

/// One live request's share of an iteration, for the record log.
#[derive(Clone, Debug)]
pub struct IterationEntry {
    /// Request id.
    pub id: u64,
    /// Phase the request ran this iteration.
    pub phase: Phase,
    /// Clusters the request owned this iteration.
    pub clusters: Vec<usize>,
    /// The request's own cycles for its iteration step.
    pub cycles: f64,
}

/// One continuous-batching iteration, for introspection and tests.
#[derive(Clone, Debug)]
pub struct IterationRecord {
    /// Iteration index.
    pub iter: u32,
    /// Global clock (cycles) after this iteration's barrier.
    pub clock_cycles: u64,
    /// Per-live-request shares.
    pub entries: Vec<IterationEntry>,
    /// Degradation level the iteration ran at ([`ExecMode::Full`]
    /// unless overload pushed the loop down the ladder).
    pub mode: ExecMode,
    /// Execution attempts this iteration took (1 = no retry).
    pub attempts: u32,
    /// Clusters quarantined or offline while this iteration planned.
    pub quarantined: Vec<usize>,
}

/// Configuration of the paged KV-cache tier (DESIGN.md §14): a shared
/// pool of fixed-size byte blocks replaces the legacy per-request
/// all-or-nothing KV residency. `None` on [`ServeOptions::paging`]
/// keeps the legacy unpaged path bit-identical to before.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PagedKvOptions {
    /// Bytes per pool block (whole-model K+V cache; each model converts
    /// this into its own token capacity).
    pub block_bytes: u64,
    /// Total pool bytes; `pool_bytes / block_bytes` blocks are shared
    /// by every live request.
    pub pool_bytes: u64,
    /// Enable radix-tree prefix sharing: requests whose prompts share a
    /// head reuse each other's cached blocks and skip that much
    /// prefill.
    pub share_prefix: bool,
}

impl Default for PagedKvOptions {
    fn default() -> Self {
        PagedKvOptions {
            block_bytes: 1 << 20,
            pool_bytes: 64 << 20,
            share_prefix: false,
        }
    }
}

impl PagedKvOptions {
    /// Blocks in the pool.
    pub fn capacity_blocks(&self) -> usize {
        (self.pool_bytes / self.block_bytes.max(1)).max(1) as usize
    }

    /// The differential-oracle configuration: blocks so large every
    /// request's whole lifetime cache is one block, a pool deep enough
    /// to never evict or defer, and no sharing. A run under this
    /// configuration must be bit-identical to the legacy unpaged path.
    pub fn unbounded() -> Self {
        PagedKvOptions { block_bytes: 1 << 30, pool_bytes: 1 << 40, share_prefix: false }
    }
}

/// Speculative-decoding configuration (DESIGN.md §15): a small draft
/// model proposes `k` tokens per decode iteration against a fork of the
/// request's KV table; the target model then verifies them in one
/// prefill-shaped pass. Acceptance is decided by a seeded deterministic
/// model — one stream per (request, round) — so a run is a pure
/// function of (trace, seed), independent of the backend, and
/// differential-testable across simulator paths. `k == 0` reduces
/// bit-identically to plain one-token-per-iteration decode.
#[derive(Clone, Copy, Debug)]
pub struct SpecDecodeOptions {
    /// The draft model. Its sequence capacity is overridden per request
    /// by the target request's prompt length.
    pub draft: TransformerConfig,
    /// Draft depth: tokens proposed per decode iteration.
    pub k: u32,
    /// Seed of the deterministic acceptance model.
    pub seed: u64,
    /// Per-token acceptance probability of the seeded model.
    pub accept: f64,
}

impl SpecDecodeOptions {
    /// Speculate with `draft` proposing `k` tokens per iteration, under
    /// the default acceptance model (seeded, p = 0.7).
    pub fn new(draft: TransformerConfig, k: u32) -> Self {
        SpecDecodeOptions { draft, k, seed: 0x5bec, accept: 0.7 }
    }

    /// Re-seed the acceptance model.
    #[must_use]
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Set the per-token acceptance probability.
    #[must_use]
    pub fn accept(mut self, p: f64) -> Self {
        self.accept = p;
        self
    }
}

/// Admission, deadline, retry and degradation policy for the resilient
/// serve loop. [`ServeOptions::default`] turns every resilience knob
/// off (unbounded admission, no deadlines, no degradation), which makes
/// a fault-free run bit-identical to the plain continuous-batching
/// loop.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Iteration safety bound.
    pub max_iters: u32,
    /// Admission controller: max concurrently live requests (further
    /// bounded by the number of healthy clusters).
    pub max_live: usize,
    /// Admission controller: max *ready* requests allowed to wait in
    /// the queue; newest arrivals beyond it are shed.
    pub max_queue: usize,
    /// TTFT service-level objective in cycles (used by projected-TTFT
    /// shedding and SLO attainment).
    pub ttft_slo_cycles: Option<u64>,
    /// Per-token latency SLO in cycles (SLO attainment).
    pub token_slo_cycles: Option<u64>,
    /// Default per-request deadline (cycles after arrival) applied when
    /// a request carries none of its own.
    pub deadline_cycles: Option<u64>,
    /// Shed a ready waiting request when its projected TTFT — time
    /// already waited plus the last iteration's makespan — exceeds the
    /// TTFT SLO (it could no longer meet it anyway).
    pub shed_over_projected_ttft: bool,
    /// Bounded retry: max execution attempts per iteration.
    pub max_attempts: u32,
    /// Iterations a transiently-failed cluster sits out before being
    /// planned on again.
    pub quarantine_iters: u32,
    /// Ready-backlog pressure at which the loop degrades to sampled
    /// simulation ([`ExecMode::Sampled`]).
    pub degrade_sampled_at: usize,
    /// Ready-backlog pressure at which the loop degrades to analytic
    /// estimates ([`ExecMode::Analytic`]).
    pub degrade_analytic_at: usize,
    /// Paged KV-cache tier (DESIGN.md §14): `Some` runs decode requests
    /// against the shared block pool with prefix sharing, LRU eviction
    /// and preemption; `None` keeps the legacy unpaged KV path.
    pub paging: Option<PagedKvOptions>,
    /// Speculative decoding (DESIGN.md §15): `Some` drafts and verifies
    /// `k` tokens per decode iteration; `None` keeps plain decode.
    pub speculative: Option<SpecDecodeOptions>,
    /// Chunked prefill (DESIGN.md §15): split prompts into chunks of at
    /// most this many tokens (rounded up to whole KV blocks on the
    /// paged path), interleaved with decode iterations so one long
    /// prompt no longer stalls every co-scheduled request's TTFT for a
    /// full prefill barrier; `None` prefills whole prompts at once.
    pub chunk_tokens: Option<u32>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_iters: 4096,
            max_live: usize::MAX,
            max_queue: usize::MAX,
            ttft_slo_cycles: None,
            token_slo_cycles: None,
            deadline_cycles: None,
            shed_over_projected_ttft: false,
            max_attempts: 3,
            quarantine_iters: 3,
            degrade_sampled_at: usize::MAX,
            degrade_analytic_at: usize::MAX,
            paging: None,
            speculative: None,
            chunk_tokens: None,
        }
    }
}

impl ServeOptions {
    /// The plain continuous-batching policy (every resilience knob
    /// off) with an explicit iteration bound.
    pub fn legacy(max_iters: u32) -> Self {
        ServeOptions { max_iters, ..Default::default() }
    }

    /// Builder entry point: the [`Default`] policy, refined through the
    /// chained setters below instead of a ~15-field struct literal.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the iteration safety bound.
    #[must_use]
    pub fn max_iters(mut self, n: u32) -> Self {
        self.max_iters = n;
        self
    }

    /// Bound the concurrently live request set.
    #[must_use]
    pub fn max_live(mut self, n: usize) -> Self {
        self.max_live = n;
        self
    }

    /// Bound the ready waiting queue (newest arrivals beyond it shed).
    #[must_use]
    pub fn max_queue(mut self, n: usize) -> Self {
        self.max_queue = n;
        self
    }

    /// Set the TTFT service-level objective in cycles.
    #[must_use]
    pub fn ttft_slo(mut self, cycles: u64) -> Self {
        self.ttft_slo_cycles = Some(cycles);
        self
    }

    /// Set the per-token latency SLO in cycles.
    #[must_use]
    pub fn token_slo(mut self, cycles: u64) -> Self {
        self.token_slo_cycles = Some(cycles);
        self
    }

    /// Set the default per-request deadline (cycles after arrival).
    #[must_use]
    pub fn deadline(mut self, cycles: u64) -> Self {
        self.deadline_cycles = Some(cycles);
        self
    }

    /// Shed ready waiting requests whose projected TTFT already
    /// exceeds the TTFT SLO.
    #[must_use]
    pub fn shed_over_projected_ttft(mut self, shed: bool) -> Self {
        self.shed_over_projected_ttft = shed;
        self
    }

    /// Bound execution attempts per iteration.
    #[must_use]
    pub fn max_attempts(mut self, n: u32) -> Self {
        self.max_attempts = n;
        self
    }

    /// Set how many iterations a transiently-failed cluster sits out.
    #[must_use]
    pub fn quarantine_iters(mut self, n: u32) -> Self {
        self.quarantine_iters = n;
        self
    }

    /// Set the ready-backlog pressure thresholds of the degradation
    /// ladder (sampled simulation, then analytic estimates).
    #[must_use]
    pub fn degrade_at(mut self, sampled: usize, analytic: usize) -> Self {
        self.degrade_sampled_at = sampled;
        self.degrade_analytic_at = analytic;
        self
    }

    /// Run the paged KV-cache tier (DESIGN.md §14).
    #[must_use]
    pub fn paging(mut self, paging: PagedKvOptions) -> Self {
        self.paging = Some(paging);
        self
    }

    /// Run speculative decoding (DESIGN.md §15).
    #[must_use]
    pub fn speculative(mut self, spec: SpecDecodeOptions) -> Self {
        self.speculative = Some(spec);
        self
    }

    /// Split prefills into chunks of at most `tokens` tokens
    /// (DESIGN.md §15).
    #[must_use]
    pub fn chunked_prefill(mut self, tokens: u32) -> Self {
        self.chunk_tokens = Some(tokens);
        self
    }
}

/// Tail-latency and robustness summary of a serve run (DESIGN.md §12).
/// Percentiles are over requests that reached the respective milestone
/// (TTFT: produced a first token; token latency: decoded ≥ 1 step);
/// shed requests appear only in the outcome counts.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloSummary {
    /// Median time-to-first-token (cycles).
    pub ttft_p50_cycles: f64,
    /// 95th-percentile TTFT (cycles).
    pub ttft_p95_cycles: f64,
    /// 99th-percentile TTFT (cycles).
    pub ttft_p99_cycles: f64,
    /// Median per-token decode latency (cycles).
    pub token_p50_cycles: f64,
    /// 95th-percentile per-token decode latency (cycles).
    pub token_p95_cycles: f64,
    /// 99th-percentile per-token decode latency (cycles).
    pub token_p99_cycles: f64,
    /// Fraction of submitted requests that completed within the SLO
    /// targets (completed fraction when no targets are set).
    pub attainment: f64,
    /// SLO attainment over throughput-policy requests only (1.0 when
    /// the run had none).
    pub attainment_throughput: f64,
    /// SLO attainment over latency-policy requests only (1.0 when the
    /// run had none).
    pub attainment_latency: f64,
    /// Requests that retired normally.
    pub completed: u32,
    /// Requests the admission controller shed.
    pub shed: u32,
    /// Requests retired at their deadline with partial progress.
    pub timed_out: u32,
    /// Requests still in flight when the run ended.
    pub unfinished: u32,
    /// Iteration attempts that had to be re-executed after a cluster
    /// failure.
    pub retries: u32,
    /// Effective faults the simulator injected over the whole run.
    pub faults_injected: u32,
    /// Times a cluster entered quarantine.
    pub quarantine_events: u32,
    /// Iterations executed at full cycle-sim fidelity.
    pub full_iters: u32,
    /// Iterations executed at sampled fidelity.
    pub sampled_iters: u32,
    /// Iterations executed on analytic estimates.
    pub analytic_iters: u32,
}

/// Decode-scenario summary of a serve run (DESIGN.md §15): speculative
/// draft/verify books and chunked-prefill counts, aggregated from the
/// per-request reports. All-zero for a plain run.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeSummary {
    /// Speculative draft/verify rounds executed.
    pub spec_rounds: u64,
    /// Draft tokens proposed across all rounds.
    pub drafted_tokens: u64,
    /// Draft tokens committed by verify passes (beyond each pass's own
    /// guaranteed token).
    pub accepted_tokens: u64,
    /// `accepted_tokens / drafted_tokens` (0 when nothing was drafted).
    pub acceptance_rate: f64,
    /// Cycles spent in draft-model sub-iterations (per-request shares).
    pub draft_cycles: f64,
    /// Cycles spent in target-model verify passes (per-request shares).
    pub verify_cycles: f64,
    /// Prefill chunks executed under an active chunk option.
    pub prefill_chunks: u64,
    /// Requests whose prefill ran in more than one chunk.
    pub chunked_requests: u32,
}

/// One cluster's health history over a serve run.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterHealth {
    /// Cluster index.
    pub cluster: usize,
    /// Transient failures observed on this cluster.
    pub failures: u32,
    /// Iterations the cluster spent quarantined.
    pub quarantined_iters: u32,
    /// The cluster ended the run offline.
    pub offline: bool,
}

/// Result of a continuous-batching run: per-request serving reports
/// (TTFT, tokens, per-token latency, energy) plus the iteration log
/// and — for the resilient path — the SLO summary and per-cluster
/// health history.
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Which backend executed the run.
    pub backend: &'static str,
    /// Iterations actually executed (gaps in the arrival schedule are
    /// fast-forwarded and do not count).
    pub iterations: u32,
    /// Global clock at the end of the run (cycles).
    pub total_cycles: u64,
    /// One report per request, in retirement order. `cycles` is
    /// admission→retirement residence time; the serving metrics
    /// (`ttft_cycles`, `tokens`, `decode_token_cycles`) are filled in.
    /// Requests the iteration bound cut off are included with their
    /// partial — possibly zero — progress; nothing submitted vanishes.
    pub per_request: Vec<RunReport>,
    /// The per-iteration schedule, for introspection and invariants.
    pub log: Vec<IterationRecord>,
    /// Tail-latency / robustness summary.
    pub slo: SloSummary,
    /// Decode-scenario (speculative / chunked-prefill) summary.
    pub decode: DecodeSummary,
    /// Per-cluster health history (failures, quarantine, offline).
    pub health: Vec<ClusterHealth>,
    /// Page-pool books and sharing/eviction/preemption counters; `None`
    /// off the paged path.
    pub pool: Option<PoolReport>,
}

impl ServeReport {
    /// Total tokens generated across all requests.
    pub fn total_tokens(&self) -> u64 {
        self.per_request.iter().map(|r| r.tokens as u64).sum()
    }

    /// Aggregate generation throughput over the whole run.
    pub fn tokens_per_s(&self) -> f64 {
        if self.total_cycles == 0 {
            0.0
        } else {
            self.total_tokens() as f64 / (self.total_cycles as f64 / crate::sim::CLOCK_HZ)
        }
    }

    /// Aggregate energy across all requests (pJ).
    pub fn total_energy_pj(&self) -> f64 {
        self.per_request.iter().map(|r| r.energy_pj).sum()
    }

    /// Accounting invariants every serve run upholds (checked at the
    /// end of each run; also directly testable):
    ///
    /// - every submitted request appears exactly once, so the outcome
    ///   counts sum to `per_request.len()`;
    /// - shed requests never executed: zero tokens, energy, TTFT and
    ///   decode latency — they appear in counts but not throughput;
    /// - retried, preempted or prefix-shared work grants no extra
    ///   tokens: every request's `tokens` is bounded by its decode
    ///   target (so prefix-shared prompt tokens are never
    ///   double-counted in tokens/s), and a completed request produced
    ///   exactly its target;
    /// - paged runs balance the page-pool books: blocks allocated =
    ///   freed + resident, every resume had a preemption, and the
    ///   pool's prefix/preemption counters are attributed to requests
    ///   exactly once.
    pub fn assert_consistent(&self) {
        let by_outcome = |o: Outcome| {
            self.per_request.iter().filter(|r| r.outcome == o).count() as u32
        };
        assert_eq!(
            by_outcome(Outcome::Completed),
            self.slo.completed,
            "completed count mismatch"
        );
        assert_eq!(by_outcome(Outcome::Shed), self.slo.shed, "shed count mismatch");
        assert_eq!(
            by_outcome(Outcome::TimedOut),
            self.slo.timed_out,
            "timed-out count mismatch"
        );
        assert_eq!(
            by_outcome(Outcome::Unfinished),
            self.slo.unfinished,
            "unfinished count mismatch"
        );
        assert_eq!(
            (self.slo.completed + self.slo.shed + self.slo.timed_out + self.slo.unfinished)
                as usize,
            self.per_request.len(),
            "outcome counts must cover every submitted request"
        );
        for r in &self.per_request {
            if r.outcome == Outcome::Shed {
                assert_eq!(r.tokens, 0, "shed request {} has tokens", r.request_id);
                assert_eq!(r.energy_pj, 0.0, "shed request {} has energy", r.request_id);
                assert_eq!(r.ttft_cycles, 0.0, "shed request {} has TTFT", r.request_id);
                assert_eq!(
                    r.decode_token_cycles, 0.0,
                    "shed request {} has decode latency",
                    r.request_id
                );
            }
            assert!(
                r.tokens <= r.token_target,
                "request {} produced {} tokens past its target {}",
                r.request_id,
                r.tokens,
                r.token_target
            );
            if r.outcome == Outcome::Completed {
                assert_eq!(
                    r.tokens, r.token_target,
                    "completed request {} must produce exactly its target",
                    r.request_id
                );
            }
            assert!(
                r.accepted_tokens <= r.drafted_tokens,
                "request {} accepted more draft tokens than it drafted",
                r.request_id
            );
            if r.drafted_tokens > 0 {
                assert!(
                    r.spec_rounds > 0,
                    "request {} drafted tokens outside a speculative round",
                    r.request_id
                );
            }
        }
        // decode-scenario books are attributed to requests exactly once
        let sum = |f: fn(&RunReport) -> u64| self.per_request.iter().map(f).sum::<u64>();
        assert_eq!(
            sum(|r| r.spec_rounds as u64),
            self.decode.spec_rounds,
            "speculative rounds must sum to the aggregate"
        );
        assert_eq!(
            sum(|r| r.drafted_tokens as u64),
            self.decode.drafted_tokens,
            "drafted tokens must sum to the aggregate"
        );
        assert_eq!(
            sum(|r| r.accepted_tokens as u64),
            self.decode.accepted_tokens,
            "accepted tokens must sum to the aggregate"
        );
        assert_eq!(
            sum(|r| r.prefill_chunks as u64),
            self.decode.prefill_chunks,
            "prefill chunks must sum to the aggregate"
        );
        assert!(
            self.decode.accepted_tokens <= self.decode.drafted_tokens,
            "aggregate acceptance cannot exceed drafting"
        );
        if let Some(p) = &self.pool {
            assert_eq!(
                p.allocated,
                p.freed + p.resident,
                "pool books: blocks allocated must equal freed + resident"
            );
            assert!(p.evictions <= p.freed, "evictions are a subset of frees");
            assert!(
                p.resumes <= p.preemptions,
                "every resume must follow a preemption"
            );
            let hit_tokens: u64 =
                self.per_request.iter().map(|r| r.prefix_hit_tokens as u64).sum();
            assert_eq!(
                hit_tokens, p.prefix_hit_tokens,
                "prefix-hit savings must be attributed to requests exactly once"
            );
            let preemptions: u64 =
                self.per_request.iter().map(|r| r.preemptions as u64).sum();
            assert_eq!(
                preemptions, p.preemptions as u64,
                "preemptions must be attributed to requests exactly once"
            );
        } else {
            assert!(
                self.per_request
                    .iter()
                    .all(|r| r.prefix_hit_tokens == 0 && r.preemptions == 0),
                "unpaged runs cannot report prefix hits or preemptions"
            );
        }
    }
}

/// A request in flight through the continuous batch.
struct LiveReq {
    req: Request,
    /// Set once the prefill iteration has run.
    prefilled: bool,
    /// Tokens produced so far (the prefill's first token included).
    generated: u32,
    /// The request has completed a prefill at least once (stays set
    /// across preemptions, so a resume's re-prefill never re-grants the
    /// first token or resets TTFT).
    ever_prefilled: bool,
    /// Paged KV block table (decode requests on the paged path only).
    table: Option<BlockTable>,
    /// Prompt tokens skipped in the *current* prefill via prefix hits.
    skip_tokens: u32,
    /// Generated-KV tokens a resumed prefill must rebuild (set at
    /// preemption to the tokens generated so far; zero otherwise).
    restore_tokens: u32,
    /// This iteration preempted the request: its table is already
    /// freed; it moves to the preempted queue instead of retiring.
    preempt_pending: bool,
    /// Times the request was preempted.
    preemptions: u32,
    /// Cumulative prompt tokens skipped via prefix hits (over resumes).
    prefix_hit_tokens: u32,
    /// Prompt-span tokens covered by earlier chunk iterations of the
    /// current prefill (chunked prefill only; reset on completion and
    /// at preemption, whose resume restarts the prefill).
    prefill_done: u32,
    /// Cumulative prefill chunks executed under an active chunk option.
    chunks: u32,
    /// Phase planned for this iteration (a prefill chunk or a
    /// speculative verify pass); `None` falls back to [`LiveReq::phase`].
    planned: Option<Phase>,
    /// Draft depth planned this iteration (0 = plain decode).
    spec_drafted: u32,
    /// Tokens this iteration's verify pass commits: the accepted draft
    /// prefix plus the pass's own token, bounded by the target.
    spec_commit: u32,
    /// KV table forked for this iteration's drafts (paged path only;
    /// always released — the rejected-tail rollback — before commit
    /// appends apply allocation pressure).
    spec_fork: Option<BlockTable>,
    /// Cumulative speculative rounds.
    spec_rounds: u32,
    /// Cumulative draft tokens proposed for this request.
    drafted_tokens: u32,
    /// Cumulative draft tokens committed for this request.
    accepted_tokens: u32,
    /// This request's own cycles across draft sub-iterations.
    draft_cycles: f64,
    /// This request's own cycles across verify passes.
    verify_cycles: f64,
    admit_clock: u64,
    /// TTFT/deadline reference: the open-loop arrival clock when the
    /// request carries one, else the admission clock (legacy traffic).
    arrival_ref: u64,
    /// Effective deadline clock (arrival + deadline), if any.
    deadline_clock: Option<u64>,
    ttft_cycles: f64,
    /// Sum of the iteration-barrier cycles over this request's decode
    /// iterations — the *observed* inter-token time under
    /// co-scheduling, on the same clock as TTFT and tokens/s.
    decode_cycles: f64,
    decode_iters: u32,
    retries: u32,
    energy_pj: f64,
    softmax_cycles: f64,
    gemm_cycles: f64,
    attn_cycles: f64,
    dma_cycles: f64,
    /// Accumulated sampled-simulation error bound over this request's
    /// iterations (zero unless the backend sampled).
    error_bound_cycles: f64,
    last_clusters: usize,
}

impl LiveReq {
    fn new(req: Request, admit_clock: u64, default_deadline: Option<u64>) -> Self {
        let arrival_ref =
            if req.arrival_cycles > 0 { req.arrival_cycles } else { admit_clock };
        let deadline_clock = req
            .deadline_cycles
            .or(default_deadline)
            .map(|d| arrival_ref.saturating_add(d));
        LiveReq {
            req,
            prefilled: false,
            generated: 0,
            ever_prefilled: false,
            table: None,
            skip_tokens: 0,
            restore_tokens: 0,
            preempt_pending: false,
            preemptions: 0,
            prefix_hit_tokens: 0,
            prefill_done: 0,
            chunks: 0,
            planned: None,
            spec_drafted: 0,
            spec_commit: 0,
            spec_fork: None,
            spec_rounds: 0,
            drafted_tokens: 0,
            accepted_tokens: 0,
            draft_cycles: 0.0,
            verify_cycles: 0.0,
            admit_clock,
            arrival_ref,
            deadline_clock,
            ttft_cycles: 0.0,
            decode_cycles: 0.0,
            decode_iters: 0,
            retries: 0,
            energy_pj: 0.0,
            softmax_cycles: 0.0,
            gemm_cycles: 0.0,
            attn_cycles: 0.0,
            dma_cycles: 0.0,
            error_bound_cycles: 0.0,
            last_clusters: 0,
        }
    }

    /// Phase this request runs next. A prefill spans the prompt plus
    /// any generated KV a preemption discarded (`restore_tokens`),
    /// minus the head prefix sharing let it skip (`skip_tokens`); on
    /// the legacy path both are zero and this is the plain prompt.
    fn phase(&self) -> Phase {
        if !self.prefilled {
            let span = (self.req.cfg.seq + self.restore_tokens)
                .saturating_sub(self.skip_tokens);
            Phase::Prefill { prompt: span.max(1) }
        } else {
            Phase::Decode { kv_len: self.req.cfg.seq + self.generated }
        }
    }

    /// Done once prefill ran and the token target is met. A target of
    /// zero (prefill-only request, e.g. ViT) retires after prefill.
    fn done(&self) -> bool {
        self.prefilled && self.generated >= self.req.decode_tokens
    }

    /// Past the effective deadline at `clock`?
    fn expired(&self, clock: u64) -> bool {
        self.deadline_clock.is_some_and(|d| clock >= d)
    }

    fn retire(self, finish_clock: u64, backend: &'static str, outcome: Outcome) -> RunReport {
        let decode_token_cycles = if self.decode_iters > 0 {
            self.decode_cycles / self.decode_iters as f64
        } else {
            0.0
        };
        RunReport {
            backend,
            request_id: self.req.id,
            model: self.req.cfg.name,
            cycles: (finish_clock - self.admit_clock) as f64,
            energy_pj: self.energy_pj,
            softmax_cycles: self.softmax_cycles,
            gemm_cycles: self.gemm_cycles,
            attn_cycles: self.attn_cycles,
            dma_cycles: self.dma_cycles,
            clusters_used: self.last_clusters,
            error_bound_cycles: self.error_bound_cycles,
            ttft_cycles: self.ttft_cycles,
            tokens: self.generated,
            decode_token_cycles,
            outcome,
            retries: self.retries,
            policy: self.req.policy,
            token_target: self.req.decode_tokens,
            prefix_hit_tokens: self.prefix_hit_tokens,
            preemptions: self.preemptions,
            spec_rounds: self.spec_rounds,
            drafted_tokens: self.drafted_tokens,
            accepted_tokens: self.accepted_tokens,
            draft_cycles: self.draft_cycles,
            verify_cycles: self.verify_cycles,
            prefill_chunks: self.chunks,
            ..Default::default()
        }
    }
}

/// Per-cluster health bookkeeping of the resilient loop.
#[derive(Clone, Copy, Debug, Default)]
struct Health {
    failures: u32,
    /// Iteration index at which quarantine lifts.
    quarantined_until: Option<u32>,
    quarantined_iters: u32,
    offline: bool,
}

impl Health {
    fn available(&self, iter: u32) -> bool {
        !self.offline && self.quarantined_until.is_none_or(|u| iter >= u)
    }
}

/// Runtime state of the paged KV tier inside one resilient serve run.
struct PagedState {
    pool: BlockPool,
    index: PrefixIndex,
    geom: BlockGeometry,
    share_prefix: bool,
    block_bytes: u64,
    preemptions: u32,
    resumes: u32,
    prefix_hits: u64,
    prefix_hit_tokens: u64,
    shed_unfittable: u32,
    deferrals: u32,
}

/// Outcome of a paged admission attempt.
enum Admit {
    /// Blocks reserved (or none needed); the request may go live.
    Ok,
    /// The pool is exhausted by live requests; retry next iteration.
    Defer,
    /// The request's lifetime block need exceeds the whole pool — it
    /// could never complete and is shed.
    Unfittable,
}

impl PagedState {
    fn new(opts: &PagedKvOptions) -> Self {
        PagedState {
            pool: BlockPool::new(opts.capacity_blocks()),
            index: PrefixIndex::new(),
            geom: BlockGeometry::new(opts.block_bytes),
            share_prefix: opts.share_prefix,
            block_bytes: opts.block_bytes,
            preemptions: 0,
            resumes: 0,
            prefix_hits: 0,
            prefix_hit_tokens: 0,
            shed_unfittable: 0,
            deferrals: 0,
        }
    }

    /// Reserve the blocks `lr` needs to (re)enter the live set: a
    /// prefix-index lookup first (shared head blocks join the table for
    /// free and shrink the prefill), then fresh blocks from the free
    /// list, evicting cached blocks LRU as needed. On `Defer`
    /// everything is rolled back. Prefill-only requests hold no table.
    fn try_admit(&mut self, lr: &mut LiveReq) -> Admit {
        if lr.req.decode_tokens == 0 {
            return Admit::Ok;
        }
        let cfg = &lr.req.cfg;
        let bt = self.geom.block_tokens(cfg);
        let lifetime = self.geom.blocks_for(cfg, cfg.seq + lr.req.decode_tokens);
        if lifetime > self.pool.capacity() as u64 {
            self.shed_unfittable += 1;
            return Admit::Unfittable;
        }
        let total_tokens = cfg.seq + lr.restore_tokens;
        let need_total = (total_tokens as u64).div_ceil(bt as u64) as usize;
        let mut matched: Vec<BlockId> = Vec::new();
        if self.share_prefix {
            matched = self.index.lookup(&chunk_fingerprints(&lr.req, bt));
            // at least one token always prefills (the last prompt
            // position predicts the first output token)
            let max_match = ((total_tokens - 1) / bt) as usize;
            matched.truncate(max_match.min(need_total));
        }
        // remember each matched block's cached-list revival position so
        // a Defer rollback can restore the LRU order exactly
        let revived: Vec<(BlockId, Option<usize>)> =
            matched.iter().map(|&b| (b, self.pool.retain(b))).collect();
        let mut fresh: Vec<BlockId> = Vec::new();
        while matched.len() + fresh.len() < need_total {
            if let Some(b) = self.pool.try_alloc() {
                fresh.push(b);
            } else if let Some(evicted) = self.pool.evict_lru() {
                self.index.remove_block(evicted);
            } else {
                // exhausted by live tables: roll back and defer. The
                // retains are undone in reverse order, reinserting
                // revived blocks at their recorded positions, so a
                // deferred admission leaves the cached LRU order — and
                // with it the future eviction order — untouched.
                for b in fresh {
                    self.pool.release(b, false);
                }
                for &(b, pos) in revived.iter().rev() {
                    match pos {
                        Some(p) => self.pool.release_revived(b, p),
                        None => self.pool.release(b, self.index.contains_block(b)),
                    }
                }
                self.deferrals += 1;
                return Admit::Defer;
            }
        }
        let skip = matched.len() as u32 * bt;
        self.prefix_hits += matched.len() as u64;
        self.prefix_hit_tokens += skip as u64;
        lr.prefix_hit_tokens += skip;
        lr.skip_tokens = skip;
        let mut table = BlockTable::new(bt);
        table.blocks = matched.iter().copied().chain(fresh.iter().copied()).collect();
        // prefill fills the fresh blocks' accounting up front (their
        // contents land during the prefill iteration)
        for (pos, &b) in table.blocks.iter().enumerate().skip(matched.len()) {
            let fill = if pos + 1 == need_total {
                total_tokens - (need_total as u32 - 1) * bt
            } else {
                bt
            };
            self.pool.fill(b, fill);
        }
        table.tokens = total_tokens as u64;
        lr.table = Some(table);
        Admit::Ok
    }

    /// Drop every reference `table` holds; blocks still backing a
    /// prefix-index entry stay resident on the LRU cached list, the
    /// rest return to the free list.
    fn release_table(&mut self, table: &BlockTable) {
        for &b in &table.blocks {
            let cacheable = self.index.contains_block(b);
            self.pool.release(b, cacheable);
        }
    }

    /// Evict-and-requeue `lr`: free its whole table (prompt blocks stay
    /// prefix-cached, so a resume can re-match them), remember how much
    /// generated KV the resume must rebuild, and flag it for the
    /// preempted queue. Token books are preserved verbatim.
    fn preempt(&mut self, lr: &mut LiveReq) {
        debug_assert!(
            lr.spec_fork.is_none(),
            "forks are released before any preemption pressure"
        );
        if let Some(table) = lr.table.take() {
            self.release_table(&table);
        }
        lr.restore_tokens = lr.generated;
        lr.skip_tokens = 0;
        lr.prefilled = false;
        // a resume restarts the prefill from scratch: mid-prompt chunk
        // progress is discarded with the table
        lr.prefill_done = 0;
        lr.preempt_pending = true;
        lr.preemptions += 1;
        self.preemptions += 1;
    }
}

/// Preemption victim among `live`, excluding `me` and anything already
/// finished, tableless or preempted: throughput-policy requests first
/// (latency requests are preempted only when no other victim exists),
/// latest-admitted first within a policy class (LIFO keeps the oldest
/// investments running).
fn pick_victim(live: &[LiveReq], me: usize) -> Option<usize> {
    let candidate = |policy: SchedPolicy| {
        live.iter()
            .enumerate()
            .rev()
            .find(|(i, lr)| {
                *i != me
                    && lr.table.is_some()
                    && !lr.preempt_pending
                    && !lr.done()
                    && lr.req.policy == policy
            })
            .map(|(i, _)| i)
    };
    candidate(SchedPolicy::Throughput).or_else(|| candidate(SchedPolicy::Latency))
}

/// Acquire one block for a mid-decode append, applying pressure in
/// order: free list → LRU eviction of prefix-cached blocks → preempt a
/// victim request (whose released blocks then feed the next round).
/// Admission's lifetime bound guarantees this terminates with a block:
/// the appender's total need fits the pool, completed requests released
/// their tables before the append pass (and never append themselves),
/// so every block outside the appender's own table is free, evictable,
/// or held by a preemptable live request.
fn acquire_block(pg: &mut PagedState, live: &mut [LiveReq], me: usize) -> BlockId {
    loop {
        if let Some(b) = pg.pool.try_alloc() {
            return b;
        }
        if let Some(evicted) = pg.pool.evict_lru() {
            pg.index.remove_block(evicted);
            continue;
        }
        let victim = pick_victim(live, me)
            .expect("lifetime admission bound guarantees an acquirable block");
        pg.preempt(&mut live[victim]);
    }
}

/// Drive the resilient continuous-batching loop until every request
/// retires, is shed, or times out (or `max_iters` is hit — a safety
/// bound for misconfigured traffic). `waiting` is the admission queue;
/// arrival iterations/cycles stagger admission within it. `fallback`
/// executes iterations once the degradation ladder reaches
/// [`ExecMode::Analytic`] and `primary` cannot switch itself.
pub(crate) fn run_resilient(
    scheduler: BatchScheduler,
    cache: &mut ProgramCache,
    mut waiting: Vec<Request>,
    primary: &mut dyn Backend,
    mut fallback: Option<&mut dyn Backend>,
    opts: &ServeOptions,
) -> ServeReport {
    // admit in arrival order, stable by submission id
    waiting.sort_by_key(|r| (r.arrival_iter, r.arrival_cycles, r.id));
    let mut waiting = VecDeque::from(waiting);
    let mut live: Vec<LiveReq> = Vec::new();
    // evict-and-requeued requests, awaiting re-admission with their
    // token books intact (paged path only)
    let mut preempted: VecDeque<LiveReq> = VecDeque::new();
    let mut paging: Option<PagedState> = opts.paging.as_ref().map(PagedState::new);
    let mut report = ServeReport { backend: primary.name(), ..Default::default() };
    let mut health = vec![Health::default(); scheduler.clusters];
    let mut clock: u64 = 0;
    let mut iter: u32 = 0;
    let mut executed: u32 = 0;
    // degradation-ladder state: the level the loop currently runs at,
    // and whether `primary` was ever switched off Full (so a backend
    // that never degrades never sees a set_mode call)
    let mut level = ExecMode::Full;
    let mut primary_switched = false;

    while iter < opts.max_iters {
        let backend_name = report.backend;
        // ---- cluster health ----------------------------------------------
        if health.iter().all(|h| h.offline) {
            break; // nothing left to run on
        }
        let healthy: Vec<usize> =
            (0..scheduler.clusters).filter(|&c| health[c].available(iter)).collect();
        for h in health.iter_mut() {
            if !h.offline && !h.available(iter) {
                h.quarantined_iters += 1;
            }
        }

        // ---- deadlines of waiting requests --------------------------------
        waiting.retain(|r| {
            let lr = LiveReq::new(*r, clock, opts.deadline_cycles);
            if lr.expired(clock) {
                report.slo.timed_out += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
                false
            } else {
                true
            }
        });

        // preempted requests expire against their deadlines while queued
        let mut pi = 0;
        while pi < preempted.len() {
            if preempted[pi].expired(clock) {
                let lr = preempted.remove(pi).expect("index checked");
                report.slo.timed_out += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
            } else {
                pi += 1;
            }
        }

        // ---- admit --------------------------------------------------------
        // no healthy cluster means no admission at all: a request
        // admitted now could not execute, yet its TTFT clock would
        // start and its pool blocks would sit reserved
        let cap = if healthy.is_empty() {
            0
        } else {
            opts.max_live.max(1).min(healthy.len())
        };
        // preempted requests re-enter ahead of new arrivals (their
        // progress is already paid for); latency-policy ones jump the
        // preempted queue itself
        while live.len() < cap && !preempted.is_empty() {
            let pos = preempted
                .iter()
                .position(|lr| lr.req.policy == SchedPolicy::Latency)
                .unwrap_or(0);
            let mut lr = preempted.remove(pos).expect("position checked");
            let pg = paging.as_mut().expect("preemption only exists on the paged path");
            match pg.try_admit(&mut lr) {
                Admit::Ok => {
                    lr.preempt_pending = false;
                    pg.resumes += 1;
                    live.push(lr);
                }
                Admit::Defer => {
                    preempted.insert(pos, lr);
                    break;
                }
                // a resume is never unfittable: its lifetime block need
                // was bounded at first admission and never grows
                Admit::Unfittable => unreachable!("resume lifetime check cannot fail"),
            }
        }
        while live.len() < cap {
            // policy-aware pick: the first ready latency-policy request
            // jumps the queue; otherwise strict arrival order (so a
            // uniformly throughput-policy run admits exactly like the
            // pre-policy loop)
            let ready_at = |r: &Request| r.arrival_iter <= iter && r.arrival_cycles <= clock;
            let pick = waiting
                .iter()
                .position(|r| ready_at(r) && r.policy == SchedPolicy::Latency)
                .or_else(|| match waiting.front() {
                    Some(r) if ready_at(r) => Some(0),
                    _ => None,
                });
            let Some(pick) = pick else { break };
            let r = waiting.remove(pick).expect("position checked");
            let mut lr = LiveReq::new(r, clock, opts.deadline_cycles);
            match paging.as_mut() {
                Some(pg) => match pg.try_admit(&mut lr) {
                    Admit::Ok => live.push(lr),
                    Admit::Defer => {
                        // pool exhausted by live tables: put it back and
                        // retry once the live set drains
                        waiting.insert(pick, r);
                        break;
                    }
                    Admit::Unfittable => {
                        report.slo.shed += 1;
                        report.per_request.push(lr.retire(clock, backend_name, Outcome::Shed));
                    }
                },
                None => live.push(lr),
            }
        }

        // ---- shed ---------------------------------------------------------
        // ready requests the admission loop could not take
        let ready = |r: &Request| r.arrival_iter <= iter && r.arrival_cycles <= clock;
        if opts.shed_over_projected_ttft {
            if let Some(slo) = opts.ttft_slo_cycles {
                let last_makespan = report
                    .log
                    .last()
                    .map_or(0, |l| l.entries.iter().map(|e| e.cycles as u64).max().unwrap_or(0));
                while let Some(idx) = waiting.iter().position(|r| {
                    ready(r)
                        && clock.saturating_sub(r.arrival_cycles) + last_makespan > slo
                }) {
                    let r = waiting.remove(idx).expect("position checked");
                    report.slo.shed += 1;
                    report.per_request.push(
                        LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Shed),
                    );
                }
            }
        }
        loop {
            let ready_waiting = waiting.iter().filter(|r| ready(r)).count();
            if ready_waiting <= opts.max_queue {
                break;
            }
            // shed the newest ready arrival (back of the queue)
            let idx = waiting
                .iter()
                .rposition(|r| ready(r))
                .expect("ready_waiting > 0 implies a ready entry");
            let r = waiting.remove(idx).expect("rposition checked");
            report.slo.shed += 1;
            report
                .per_request
                .push(LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Shed));
        }

        if live.is_empty() && !preempted.is_empty() {
            // every request is parked in the preempted queue and none
            // could resume this iteration: sit it out (bounded by
            // max_iters; the final drain reports them if never resumed)
            iter += 1;
            continue;
        }
        if live.is_empty() {
            match waiting.front() {
                // idle gap in the arrival schedule: fast-forward
                Some(r) => {
                    iter = iter.max(r.arrival_iter);
                    if r.arrival_cycles > clock {
                        clock = r.arrival_cycles;
                    }
                    if r.arrival_iter <= iter && r.arrival_cycles <= clock && !healthy.is_empty()
                    {
                        continue;
                    }
                    iter += 1; // every cluster quarantined: sit the iteration out
                    continue;
                }
                None => break,
            }
        }
        if healthy.is_empty() {
            // every cluster quarantined (none offline, or we'd have
            // broken above): sit this iteration out until one returns
            iter += 1;
            continue;
        }

        // ---- degradation ladder -------------------------------------------
        let pressure =
            live.len() + preempted.len() + waiting.iter().filter(|r| ready(r)).count();
        let desired = if pressure >= opts.degrade_analytic_at {
            ExecMode::Analytic
        } else if pressure >= opts.degrade_sampled_at {
            ExecMode::Sampled
        } else {
            ExecMode::Full
        };
        if desired != level {
            match desired {
                ExecMode::Full => {
                    // only un-degrade a backend this loop degraded; a
                    // backend configured by its owner is never touched
                    if primary_switched && primary.set_mode(ExecMode::Full) {
                        primary_switched = false;
                    }
                    level = ExecMode::Full;
                }
                ExecMode::Sampled => {
                    if primary.set_mode(ExecMode::Sampled) {
                        primary_switched = true;
                        level = ExecMode::Sampled;
                    } else {
                        level = ExecMode::Full; // backend cannot degrade
                    }
                }
                ExecMode::Analytic => {
                    if fallback.is_some() {
                        level = ExecMode::Analytic;
                    } else if primary.set_mode(ExecMode::Analytic) {
                        primary_switched = true;
                        level = ExecMode::Analytic;
                    } else if primary.set_mode(ExecMode::Sampled) {
                        // no separate estimator: sampled mode is the
                        // deepest the primary can degrade to
                        primary_switched = true;
                        level = ExecMode::Sampled;
                    } else {
                        level = ExecMode::Full;
                    }
                }
            }
        }
        let use_fallback = level == ExecMode::Analytic && fallback.is_some();

        // ---- plan decode scenarios (DESIGN.md §15) ------------------------
        // Per-iteration plans: the phase each runnable request executes
        // this iteration (a prefill chunk, a speculative verify pass,
        // or — planned `None` — its plain phase), the draft depth of
        // speculating requests, and, on the paged path, the forked
        // table their drafts append against.
        let mut iter_cycles_total = 0.0f64;
        let runnable_planned = live.len().min(healthy.len());
        for lr in live.iter_mut() {
            lr.planned = None;
            lr.spec_drafted = 0;
            lr.spec_commit = 0;
        }
        for lr in live[..runnable_planned].iter_mut() {
            if !lr.prefilled {
                let Some(ct) = opts.chunk_tokens else { continue };
                let span = (lr.req.cfg.seq + lr.restore_tokens)
                    .saturating_sub(lr.skip_tokens)
                    .max(1);
                // chunk boundaries align up to whole KV blocks on the
                // paged path, so prefix-index insertion after the last
                // chunk still fingerprints whole blocks
                let unit = match paging.as_ref() {
                    Some(pg) => {
                        let bt = pg.geom.block_tokens(&lr.req.cfg);
                        ct.max(1).div_ceil(bt) * bt
                    }
                    None => ct.max(1),
                };
                let left = span - lr.prefill_done;
                if left > unit {
                    lr.planned = Some(Phase::Prefill { prompt: unit });
                } else if lr.prefill_done > 0 {
                    // final chunk of a split prefill; an unsplit prompt
                    // (prefill_done == 0) keeps its default phase
                    lr.planned = Some(Phase::Prefill { prompt: left });
                }
            } else if let Some(spec) = &opts.speculative {
                let remaining = lr.req.decode_tokens.saturating_sub(lr.generated);
                // depth caps one short of the remaining target: the
                // verify pass itself yields a token, so drafting the
                // final token would be dead work
                if spec.k == 0 || remaining < 2 {
                    continue;
                }
                let d = spec.k.min(remaining - 1);
                // seeded acceptance: one stream per (request, round),
                // independent of the backend. Accepted tokens are the
                // leading run of successes — as in real speculative
                // decoding, the first mismatch voids the drafted tail.
                let mut draw =
                    Rng::new(mix(mix(spec.seed, lr.req.id), lr.spec_rounds as u64));
                let mut accepted = 0u32;
                for _ in 0..d {
                    if draw.chance(spec.accept) {
                        accepted += 1;
                    } else {
                        break;
                    }
                }
                lr.spec_rounds += 1;
                // paged path: drafts append against a fork of the live
                // table (copy-on-write isolates its shared tail). If
                // the free list cannot back the fork, skip speculation
                // this iteration — plain decode, deterministically —
                // rather than apply eviction or preemption pressure
                // for discardable draft state.
                let mut forked_ok = true;
                if let Some(pg) = paging.as_mut() {
                    match lr.table.as_ref() {
                        Some(table) => {
                            let mut fork = pg.pool.fork(table);
                            for _ in 0..d {
                                let ok = match pg.pool.append_need(&fork) {
                                    AppendNeed::InPlace => {
                                        pg.pool.append_in_place(&mut fork);
                                        true
                                    }
                                    AppendNeed::NewBlock => match pg.pool.try_alloc() {
                                        Some(b) => {
                                            pg.pool.push_tail(&mut fork, b);
                                            true
                                        }
                                        None => false,
                                    },
                                    AppendNeed::CopyOnWrite => match pg.pool.try_alloc() {
                                        Some(b) => {
                                            let tail = *fork
                                                .blocks
                                                .last()
                                                .expect("COW implies a tail");
                                            let keep = pg.index.contains_block(tail);
                                            pg.pool.cow_tail(&mut fork, b, keep);
                                            true
                                        }
                                        None => false,
                                    },
                                };
                                if !ok {
                                    forked_ok = false;
                                    break;
                                }
                            }
                            if forked_ok {
                                lr.spec_fork = Some(fork);
                            } else {
                                pg.release_table(&fork);
                            }
                        }
                        None => forked_ok = false,
                    }
                }
                if !forked_ok {
                    continue;
                }
                lr.spec_drafted = d;
                lr.drafted_tokens += d;
                lr.spec_commit = (accepted + 1).min(remaining);
                // the target re-scores the drafted positions in one
                // prefill-shaped sweep
                lr.planned = Some(Phase::Prefill { prompt: d });
            }
        }

        // ---- speculative draft sub-iterations -----------------------------
        // Each draft step is one batched execution of the draft model
        // over the speculating requests — real barrier time, energy and
        // fault surface, but no progress books of its own: progress is
        // granted only by the verify pass below.
        if let Some(spec) = &opts.speculative {
            let max_d = live[..runnable_planned]
                .iter()
                .map(|lr| lr.spec_drafted)
                .max()
                .unwrap_or(0);
            for step in 0..max_d {
                let avail: Vec<usize> = (0..scheduler.clusters)
                    .filter(|&c| health[c].available(iter))
                    .collect();
                if avail.is_empty() {
                    break;
                }
                let drafting: Vec<usize> = live[..runnable_planned]
                    .iter()
                    .enumerate()
                    .filter(|(_, lr)| step < lr.spec_drafted)
                    .map(|(i, _)| i)
                    .collect();
                if drafting.is_empty() {
                    break;
                }
                let entries: Vec<ServeEntry> = drafting
                    .iter()
                    .map(|&i| {
                        let lr = &live[i];
                        let mut req = lr.req;
                        req.cfg = spec.draft;
                        req.cfg.seq = lr.req.cfg.seq;
                        ServeEntry {
                            req,
                            phase: Phase::Decode {
                                kv_len: lr.req.cfg.seq + lr.generated + step,
                            },
                            // the draft's own KV is sized by the draft
                            // model, not carved from the target's block
                            // table: price it with the legacy rule
                            kv_block_tokens: None,
                        }
                    })
                    .collect();
                let batch = scheduler.compile_entries_on(&entries, cache, &avail);
                let exec = match fallback {
                    Some(ref mut fb) if use_fallback => fb.execute(&batch),
                    _ => primary.execute(&batch),
                };
                let makespan =
                    exec.per_request.iter().map(|r| r.cycles).fold(0.0f64, f64::max);
                clock += makespan as u64;
                iter_cycles_total += makespan;
                report.slo.faults_injected += exec.faults_injected;
                for (&i, r) in drafting.iter().zip(&exec.per_request) {
                    let lr = &mut live[i];
                    lr.energy_pj += r.energy_pj;
                    lr.softmax_cycles += r.softmax_cycles;
                    lr.gemm_cycles += r.gemm_cycles;
                    lr.attn_cycles += r.attn_cycles;
                    lr.dma_cycles += r.dma_cycles;
                    lr.error_bound_cycles += r.error_bound_cycles;
                    lr.draft_cycles += r.cycles;
                }
                // draft faults feed the same health machinery; there is
                // no draft retry — a failed step simply cost time, and
                // the verify pass never trusts draft output anyway
                for &c in &exec.offline_clusters {
                    if !health[c].offline {
                        health[c].offline = true;
                    }
                }
                for &c in &exec.failed_clusters {
                    if !health[c].offline {
                        health[c].failures += 1;
                        health[c].quarantined_until =
                            Some(iter + 1 + opts.quarantine_iters);
                        report.slo.quarantine_events += 1;
                    }
                }
            }
        }

        // ---- execute with bounded retries ---------------------------------
        let mut attempts = 0u32;
        let (batch, exec) = loop {
            attempts += 1;
            let avail: Vec<usize> =
                (0..scheduler.clusters).filter(|&c| health[c].available(iter)).collect();
            if avail.is_empty() {
                break (None, None); // everything failed into quarantine
            }
            let runnable = live.len().min(avail.len());
            let entries: Vec<ServeEntry> = live[..runnable]
                .iter()
                .map(|lr| ServeEntry {
                    req: lr.req,
                    phase: lr.planned.unwrap_or_else(|| lr.phase()),
                    kv_block_tokens: lr.table.as_ref().map(|t| t.block_tokens),
                })
                .collect();
            let batch = scheduler.compile_entries_on(&entries, cache, &avail);
            let exec = match fallback {
                Some(ref mut fb) if use_fallback => fb.execute(&batch),
                _ => primary.execute(&batch),
            };

            // barrier: the attempt costs wall-clock whether it failed
            // or not
            let makespan = exec.per_request.iter().map(|r| r.cycles).fold(0.0f64, f64::max);
            clock += makespan as u64;
            iter_cycles_total += makespan;
            report.slo.faults_injected += exec.faults_injected;

            // energy and breakdowns accrue on every attempt — wasted
            // work burns real energy and time
            for (lr, r) in live[..runnable].iter_mut().zip(&exec.per_request) {
                lr.energy_pj += r.energy_pj;
                lr.softmax_cycles += r.softmax_cycles;
                lr.gemm_cycles += r.gemm_cycles;
                lr.attn_cycles += r.attn_cycles;
                lr.dma_cycles += r.dma_cycles;
                lr.error_bound_cycles += r.error_bound_cycles;
            }

            // health bookkeeping from the attempt's fault surface
            for &c in &exec.offline_clusters {
                if !health[c].offline {
                    health[c].offline = true;
                }
            }
            let failed = !exec.failed_clusters.is_empty();
            for &c in &exec.failed_clusters {
                if !health[c].offline {
                    health[c].failures += 1;
                    health[c].quarantined_until = Some(iter + 1 + opts.quarantine_iters);
                    report.slo.quarantine_events += 1;
                }
            }
            if !failed {
                break (Some(batch), Some(exec));
            }
            // per-request retry accounting: the requests whose reports
            // are untrusted pay the retry
            for (lr, r) in live[..runnable].iter_mut().zip(&exec.per_request) {
                if r.failed {
                    lr.retries += 1;
                }
            }
            if attempts >= opts.max_attempts {
                break (Some(batch), Some(exec));
            }
            report.slo.retries += 1;
        };

        // ---- speculative rollback -----------------------------------------
        // Forks are iteration-scoped: every fork is released before any
        // commit append applies allocation pressure. Rejected draft
        // tails return to the pool here — a copy-on-write tail frees,
        // shared blocks drop a reference — and the accepted prefix
        // re-lands in the *original* table through the ordinary append
        // path below. Releasing first also preserves acquire_block's
        // termination guarantee: no block is held by discardable draft
        // state when eviction/preemption pressure is applied.
        if let Some(pg) = paging.as_mut() {
            for lr in live.iter_mut() {
                if let Some(fork) = lr.spec_fork.take() {
                    pg.release_table(&fork);
                }
            }
        }

        // ---- account per request ------------------------------------------
        let quarantined: Vec<usize> =
            (0..scheduler.clusters).filter(|&c| !health[c].available(iter)).collect();
        if let (Some(batch), Some(exec)) = (batch, exec) {
            let mut entries_log = Vec::with_capacity(batch.requests.len());
            // live indices that produced decode tokens this iteration
            // and hold a block table, with how many KV rows to append:
            // one for plain decode, the committed run for a verified
            // speculative round
            let mut appended: Vec<(usize, u32)> = Vec::new();
            for (idx, ((lr, cr), r)) in live
                .iter_mut()
                .zip(&batch.requests)
                .zip(&exec.per_request)
                .enumerate()
            {
                lr.last_clusters = cr.clusters.len();
                entries_log.push(IterationEntry {
                    id: lr.req.id,
                    phase: cr.phase,
                    clusters: cr.clusters.clone(),
                    cycles: r.cycles,
                });
                if r.failed {
                    continue; // attempts exhausted: no progress granted
                }
                if !lr.prefilled {
                    // the executed phase says how much of the prompt
                    // span this iteration covered: the whole remainder
                    // on the plain path, one chunk under chunked
                    // prefill
                    let span = (lr.req.cfg.seq + lr.restore_tokens)
                        .saturating_sub(lr.skip_tokens)
                        .max(1);
                    let step = match cr.phase {
                        Phase::Prefill { prompt } => prompt,
                        Phase::Decode { .. } => {
                            unreachable!("unprefilled requests run prefill phases")
                        }
                    };
                    if opts.chunk_tokens.is_some() {
                        lr.chunks += 1;
                    }
                    lr.prefill_done += step;
                    if lr.prefill_done < span {
                        // mid-prompt chunk: no first token yet, TTFT
                        // keeps running, the decode entry below waits
                        continue;
                    }
                    lr.prefill_done = 0;
                    lr.prefilled = true;
                    if !lr.ever_prefilled {
                        lr.ever_prefilled = true;
                        lr.ttft_cycles = (clock - lr.arrival_ref) as f64;
                        if lr.req.decode_tokens > 0 {
                            lr.generated = 1; // the prefill's first token
                        }
                    }
                    // a resume's re-prefill rebuilds discarded KV only:
                    // TTFT stays, no token is re-granted
                    lr.restore_tokens = 0;
                    // register the prompt's whole blocks so later
                    // same-head arrivals can share them (first insert
                    // wins; a loser's duplicate simply stays unindexed)
                    if let Some(pg) = paging.as_mut() {
                        if pg.share_prefix {
                            if let Some(table) = lr.table.as_ref() {
                                let fps = chunk_fingerprints(&lr.req, table.block_tokens);
                                let n = fps.len().min(table.blocks.len());
                                pg.index.insert(&fps[..n], &table.blocks[..n]);
                            }
                        }
                    }
                } else if lr.spec_drafted > 0 {
                    // speculative verify pass: commit the accepted
                    // draft prefix plus the pass's own token. Observed
                    // per-token latency spreads the whole iteration
                    // barrier (drafts + verify attempts) over the
                    // committed run — that ratio *is* the speculative
                    // speedup, on the same clock TTFT is measured on.
                    let committed = lr.spec_commit.max(1);
                    lr.generated += committed;
                    lr.accepted_tokens += committed - 1;
                    lr.verify_cycles += r.cycles;
                    lr.decode_cycles += iter_cycles_total;
                    lr.decode_iters += committed;
                    // the final token never appends (its KV is never
                    // read again), but every committed token before it
                    // must land in the table
                    let grow = if lr.done() { committed - 1 } else { committed };
                    if lr.table.is_some() && grow > 0 {
                        appended.push((idx, grow));
                    }
                } else {
                    lr.generated += 1;
                    // observed inter-token time is the iteration barrier
                    // (including failed attempts), not the request's own
                    // compute — consistent with the clock that
                    // tokens_per_s and TTFT are measured on
                    lr.decode_cycles += iter_cycles_total;
                    lr.decode_iters += 1;
                    // a request that just produced its final token never
                    // appends: its KV is never read again, so a dead
                    // append must not consume blocks, evict cached
                    // prefixes or preempt live requests
                    if lr.table.is_some() && !lr.done() {
                        appended.push((idx, 1));
                    }
                }
            }

            // ---- paged append: each decode token extends its table ----
            if let Some(pg) = paging.as_mut() {
                // completed requests release their tables before any
                // append applies pressure: their KV is never read
                // again, and since pick_victim excludes them, holding
                // on would strand their blocks until the retire phase
                // — under a full pool with nothing cached that left
                // acquire_block without a victim and panicked
                for lr in live.iter_mut().filter(|lr| lr.done()) {
                    if let Some(table) = lr.table.take() {
                        pg.release_table(&table);
                    }
                }
                for &(idx, grow) in &appended {
                    // take the table out so acquire_block may preempt
                    // other live entries without aliasing it
                    let Some(mut table) = live[idx].table.take() else { continue };
                    for _ in 0..grow {
                        match pg.pool.append_need(&table) {
                            AppendNeed::InPlace => pg.pool.append_in_place(&mut table),
                            AppendNeed::NewBlock => {
                                let fresh = acquire_block(pg, &mut live, idx);
                                pg.pool.push_tail(&mut table, fresh);
                            }
                            // structurally unreachable from this loop:
                            // only whole, full blocks are ever shared
                            // (a full tail classifies as NewBlock), and
                            // draft forks — whose first append CoWs a
                            // partial shared tail on the *fork* side —
                            // are all released above, so the original
                            // tail is back to one reference by now.
                            // Kept live as the safety path regardless.
                            AppendNeed::CopyOnWrite => {
                                let fresh = acquire_block(pg, &mut live, idx);
                                let tail =
                                    *table.blocks.last().expect("COW implies a tail");
                                let keep = pg.index.contains_block(tail);
                                pg.pool.cow_tail(&mut table, fresh, keep);
                            }
                        }
                    }
                    live[idx].table = Some(table);
                }
            }
            match level {
                ExecMode::Full => report.slo.full_iters += 1,
                ExecMode::Sampled => report.slo.sampled_iters += 1,
                ExecMode::Analytic => report.slo.analytic_iters += 1,
            }
            report.log.push(IterationRecord {
                iter,
                clock_cycles: clock,
                entries: entries_log,
                mode: level,
                attempts,
                quarantined,
            });
            executed += 1;
        }

        // ---- retire -------------------------------------------------------
        let mut still_live = Vec::with_capacity(live.len());
        for mut lr in live {
            if lr.preempt_pending {
                // evicted-and-requeued this iteration; its table is
                // already freed. Expired ones retire instead of queuing.
                if lr.expired(clock) {
                    report.slo.timed_out += 1;
                    report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
                } else {
                    preempted.push_back(lr);
                }
            } else if lr.done() {
                if let (Some(pg), Some(table)) = (paging.as_mut(), lr.table.take()) {
                    pg.release_table(&table);
                }
                report.slo.completed += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::Completed));
            } else if lr.expired(clock) {
                if let (Some(pg), Some(table)) = (paging.as_mut(), lr.table.take()) {
                    pg.release_table(&table);
                }
                report.slo.timed_out += 1;
                report.per_request.push(lr.retire(clock, backend_name, Outcome::TimedOut));
            } else {
                still_live.push(lr);
            }
        }
        live = still_live;

        iter += 1;
    }

    // safety bound (or total cluster loss) hit: report unfinished
    // requests as-is, and requests never admitted with zero progress —
    // nothing submitted may vanish from the report
    let backend_name = report.backend;
    for mut lr in live {
        if let (Some(pg), Some(table)) = (paging.as_mut(), lr.table.take()) {
            pg.release_table(&table);
        }
        report.slo.unfinished += 1;
        report.per_request.push(lr.retire(clock, backend_name, Outcome::Unfinished));
    }
    for lr in preempted {
        // never resumed before the bound hit; tables were freed at
        // preemption, progress is reported as-is
        report.slo.unfinished += 1;
        report.per_request.push(lr.retire(clock, backend_name, Outcome::Unfinished));
    }
    for r in waiting {
        report.slo.unfinished += 1;
        report.per_request.push(
            LiveReq::new(r, clock, None).retire(clock, backend_name, Outcome::Unfinished),
        );
    }
    if let Some(pg) = &paging {
        pg.pool.assert_books();
        report.pool = Some(PoolReport {
            capacity_blocks: pg.pool.capacity(),
            block_bytes: pg.block_bytes,
            allocated: pg.pool.stats.allocated,
            freed: pg.pool.stats.freed,
            resident: (pg.pool.in_use() + pg.pool.cached_count()) as u64,
            evictions: pg.pool.stats.evictions,
            cow_copies: pg.pool.stats.cow_copies,
            preemptions: pg.preemptions,
            resumes: pg.resumes,
            prefix_hits: pg.prefix_hits,
            prefix_hit_tokens: pg.prefix_hit_tokens,
            peak_blocks_in_use: pg.pool.stats.peak_in_use,
            shed_unfittable: pg.shed_unfittable,
            deferrals: pg.deferrals,
        });
    }
    // decode-scenario aggregate: sum the per-request books (keeping
    // them attributable to requests exactly once, like the pool books)
    for r in &report.per_request {
        report.decode.spec_rounds += r.spec_rounds as u64;
        report.decode.drafted_tokens += r.drafted_tokens as u64;
        report.decode.accepted_tokens += r.accepted_tokens as u64;
        report.decode.draft_cycles += r.draft_cycles;
        report.decode.verify_cycles += r.verify_cycles;
        report.decode.prefill_chunks += r.prefill_chunks as u64;
        if r.prefill_chunks > 1 {
            report.decode.chunked_requests += 1;
        }
    }
    report.decode.acceptance_rate = if report.decode.drafted_tokens == 0 {
        0.0
    } else {
        report.decode.accepted_tokens as f64 / report.decode.drafted_tokens as f64
    };
    report.iterations = executed;
    report.total_cycles = clock;
    report.health = (0..scheduler.clusters)
        .map(|c| ClusterHealth {
            cluster: c,
            failures: health[c].failures,
            quarantined_iters: health[c].quarantined_iters,
            offline: health[c].offline,
        })
        .collect();
    finish_slo(&mut report, opts);
    report.assert_consistent();
    report
}

/// Percentile over an unsorted sample (nearest-rank on the sorted
/// order); 0 for an empty sample.
fn percentile(values: &mut [f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    let idx = ((values.len() - 1) as f64 * p).round() as usize;
    values[idx]
}

/// Fill the percentile and attainment fields from the per-request
/// reports.
fn finish_slo(report: &mut ServeReport, opts: &ServeOptions) {
    let mut ttft: Vec<f64> = report
        .per_request
        .iter()
        .filter(|r| r.outcome != Outcome::Shed && r.ttft_cycles > 0.0)
        .map(|r| r.ttft_cycles)
        .collect();
    let mut tok: Vec<f64> = report
        .per_request
        .iter()
        .filter(|r| r.outcome != Outcome::Shed && r.decode_token_cycles > 0.0)
        .map(|r| r.decode_token_cycles)
        .collect();
    report.slo.ttft_p50_cycles = percentile(&mut ttft, 0.50);
    report.slo.ttft_p95_cycles = percentile(&mut ttft, 0.95);
    report.slo.ttft_p99_cycles = percentile(&mut ttft, 0.99);
    report.slo.token_p50_cycles = percentile(&mut tok, 0.50);
    report.slo.token_p95_cycles = percentile(&mut tok, 0.95);
    report.slo.token_p99_cycles = percentile(&mut tok, 0.99);
    let total = report.per_request.len();
    if total == 0 {
        report.slo.attainment = 1.0;
        report.slo.attainment_throughput = 1.0;
        report.slo.attainment_latency = 1.0;
        return;
    }
    let meets = |r: &RunReport| {
        r.outcome == Outcome::Completed
            && opts
                .ttft_slo_cycles
                .is_none_or(|s| r.ttft_cycles <= s as f64 || r.ttft_cycles == 0.0)
            && opts
                .token_slo_cycles
                .is_none_or(|s| r.decode_token_cycles <= s as f64)
    };
    let attained = report.per_request.iter().filter(|r| meets(r)).count();
    report.slo.attainment = attained as f64 / total as f64;
    // per-policy attainment: how each scheduling class fared (1.0 for a
    // class the run had no requests in)
    let class = |policy: SchedPolicy| {
        let (mut n, mut ok) = (0usize, 0usize);
        for r in report.per_request.iter().filter(|r| r.policy == policy) {
            n += 1;
            if meets(r) {
                ok += 1;
            }
        }
        if n == 0 {
            1.0
        } else {
            ok as f64 / n as f64
        }
    };
    report.slo.attainment_throughput = class(SchedPolicy::Throughput);
    report.slo.attainment_latency = class(SchedPolicy::Latency);
}
