//! The serving-engine facade: request queue + shared program cache +
//! batch scheduler, independent of which [`Backend`] executes.

use super::batch::{BatchScheduler, CompiledBatch};
use super::program::ProgramCache;
use super::report::BatchReport;
use super::serve::{run_resilient, ServeOptions, ServeReport};
use super::{Backend, Request};
use crate::coordinator::CLUSTERS;
use crate::model::TransformerConfig;

/// Default iteration safety bound of [`ServeOptions::default`].
pub const DEFAULT_MAX_ITERS: u32 = 4096;

/// Collects concurrent requests, compiles them once through the shared
/// [`ProgramCache`], and hands the packed batch to a backend — either
/// as one drained batch ([`Engine::execute_batch`]) or as a
/// continuously batched autoregressive run ([`Engine::serve`]).
///
/// ```
/// use vexp::exec::Engine;
/// use vexp::model::{GPT2_SMALL, VIT_BASE};
///
/// let mut engine = Engine::new();
/// let a = engine.submit(GPT2_SMALL);
/// let b = engine.submit(VIT_BASE);
/// assert_eq!((a, b), (0, 1)); // ids are engine-monotonic
///
/// let batch = engine.compile_batch(); // drains the queue
/// assert_eq!(batch.requests.len(), 2);
/// assert_eq!(engine.pending(), 0);
/// // `batch` is ready for any Backend::execute — analytic or cycle-sim.
/// ```
pub struct Engine {
    /// Shared compiled-program cache (persists across batches).
    pub cache: ProgramCache,
    /// The cluster-partitioning scheduler.
    pub scheduler: BatchScheduler,
    queue: Vec<Request>,
    next_id: u64,
}

impl Engine {
    /// Engine for the paper's 16-cluster Occamy-style system.
    pub fn new() -> Self {
        Self::with_clusters(CLUSTERS)
    }

    /// Engine for a system of `clusters` clusters.
    pub fn with_clusters(clusters: usize) -> Self {
        Engine {
            cache: ProgramCache::new(),
            scheduler: BatchScheduler::new(clusters),
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Enqueue a fully-optimized inference request; returns its id.
    pub fn submit(&mut self, cfg: TransformerConfig) -> u64 {
        let id = self.next_id;
        self.submit_request(Request::new(id, cfg))
    }

    /// Enqueue an explicit request (the id field is overwritten with the
    /// engine's monotonic counter).
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        req.id = self.next_id;
        self.next_id += 1;
        self.queue.push(req);
        req.id
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue into a scheduled, compiled batch (empty queue →
    /// empty batch).
    pub fn compile_batch(&mut self) -> CompiledBatch {
        let reqs = std::mem::take(&mut self.queue);
        self.scheduler.compile(&reqs, &mut self.cache)
    }

    /// Compile the pending requests and execute them on `backend` as
    /// one batch (the calibration-slice scope; formerly the batch-mode
    /// `serve`, renamed when [`Engine::serve`] became the serving-loop
    /// entry point).
    pub fn execute_batch(&mut self, backend: &mut dyn Backend) -> BatchReport {
        let batch = self.compile_batch();
        backend.execute(&batch)
    }

    /// Drain the queue into a **continuously batched** autoregressive
    /// serving run — the single entry point for every serving scenario
    /// (DESIGN.md §10/§12/§14/§15). Requests join at their arrival
    /// iteration, prefill once (or chunk by chunk), decode against
    /// their growing KV-cache (one token per iteration, or a
    /// draft/verify round under speculative decoding), and retire at
    /// their token target while the cluster shares rebalance every
    /// iteration.
    ///
    /// Everything beyond the plain loop is opted into through `opts`
    /// (see the [`ServeOptions`] builder): admission control, deadlines
    /// and degradation (§12), the paged KV block pool with prefix
    /// sharing and preemption (§14), speculative decoding and chunked
    /// prefill (§15). `ServeOptions::default()` reproduces the plain
    /// continuous-batching loop bit-identically; `fallback` (used once
    /// the degradation ladder reaches [`super::ExecMode::Analytic`] and
    /// the primary cannot switch itself) may be `None`.
    ///
    /// When the backend runs the raw-speed simulation tier (tile memo +
    /// [`crate::sim::SamplePolicy`], DESIGN.md §11), each retired
    /// report's `error_bound_cycles` accumulates the per-iteration
    /// sampling bounds, so end-to-end serving numbers stay auditable.
    pub fn serve(
        &mut self,
        primary: &mut dyn Backend,
        fallback: Option<&mut dyn Backend>,
        opts: &ServeOptions,
    ) -> ServeReport {
        let reqs = std::mem::take(&mut self.queue);
        run_resilient(self.scheduler, &mut self.cache, reqs, primary, fallback, opts)
    }

    /// Plain continuous batching at the default iteration bound.
    #[deprecated(note = "use `serve(backend, None, &ServeOptions::default())`")]
    pub fn serve_continuous(&mut self, backend: &mut dyn Backend) -> ServeReport {
        self.serve(backend, None, &ServeOptions::default())
    }

    /// Plain continuous batching with an explicit iteration bound.
    #[deprecated(note = "use `serve(backend, None, &ServeOptions::legacy(max_iters))`")]
    pub fn serve_continuous_bounded(
        &mut self,
        backend: &mut dyn Backend,
        max_iters: u32,
    ) -> ServeReport {
        self.serve(backend, None, &ServeOptions::legacy(max_iters))
    }

    /// The resilient serving loop, now the behavior of [`Engine::serve`]
    /// itself (same signature).
    #[deprecated(note = "use `serve` — identical signature and behavior")]
    pub fn serve_resilient(
        &mut self,
        primary: &mut dyn Backend,
        fallback: Option<&mut dyn Backend>,
        opts: &ServeOptions,
    ) -> ServeReport {
        self.serve(primary, fallback, opts)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, VIT_BASE};

    #[test]
    fn submit_assigns_monotonic_ids() {
        let mut e = Engine::new();
        let a = e.submit(GPT2_SMALL);
        let b = e.submit(VIT_BASE);
        assert_eq!((a, b), (0, 1));
        assert_eq!(e.pending(), 2);
        let batch = e.compile_batch();
        assert_eq!(e.pending(), 0);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.requests[1].req.id, 1);
    }

    #[test]
    fn ids_stay_monotonic_across_submit_styles_and_batches() {
        let mut e = Engine::new();
        let a = e.submit(GPT2_SMALL);
        let b = e.submit_request(Request::new(999, VIT_BASE).with_tokens(4));
        let _ = e.compile_batch();
        let c = e.submit_request(Request::baseline(7, VIT_BASE));
        assert_eq!((a, b, c), (0, 1, 2), "explicit ids are overwritten");
    }

    #[test]
    fn empty_queue_compiles_to_empty_batch() {
        let mut e = Engine::new();
        let batch = e.compile_batch();
        assert!(batch.requests.is_empty());
        assert_eq!(batch.active_clusters(), 0);
    }

    #[test]
    fn repeated_batches_reuse_the_cache() {
        let mut e = Engine::new();
        e.submit(GPT2_SMALL);
        let _ = e.compile_batch();
        e.submit(GPT2_SMALL);
        let batch = e.compile_batch();
        assert_eq!(batch.cache_hits, 1, "second batch must reuse the program");
        assert_eq!(batch.cache_misses, 0);
    }
}
