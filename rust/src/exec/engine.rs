//! The serving-engine facade: request queue + shared program cache +
//! batch scheduler, independent of which [`Backend`] executes.

use super::batch::{BatchScheduler, CompiledBatch};
use super::program::ProgramCache;
use super::report::BatchReport;
use super::serve::{run_continuous, run_resilient, ServeOptions, ServeReport};
use super::{Backend, Request};
use crate::coordinator::CLUSTERS;
use crate::model::TransformerConfig;

/// Default iteration safety bound for [`Engine::serve_continuous`].
pub const DEFAULT_MAX_ITERS: u32 = 4096;

/// Collects concurrent requests, compiles them once through the shared
/// [`ProgramCache`], and hands the packed batch to a backend — either
/// as one drained batch ([`Engine::serve`]) or as a continuously
/// batched autoregressive run ([`Engine::serve_continuous`]).
///
/// ```
/// use vexp::exec::Engine;
/// use vexp::model::{GPT2_SMALL, VIT_BASE};
///
/// let mut engine = Engine::new();
/// let a = engine.submit(GPT2_SMALL);
/// let b = engine.submit(VIT_BASE);
/// assert_eq!((a, b), (0, 1)); // ids are engine-monotonic
///
/// let batch = engine.compile_batch(); // drains the queue
/// assert_eq!(batch.requests.len(), 2);
/// assert_eq!(engine.pending(), 0);
/// // `batch` is ready for any Backend::execute — analytic or cycle-sim.
/// ```
pub struct Engine {
    /// Shared compiled-program cache (persists across batches).
    pub cache: ProgramCache,
    /// The cluster-partitioning scheduler.
    pub scheduler: BatchScheduler,
    queue: Vec<Request>,
    next_id: u64,
}

impl Engine {
    /// Engine for the paper's 16-cluster Occamy-style system.
    pub fn new() -> Self {
        Self::with_clusters(CLUSTERS)
    }

    /// Engine for a system of `clusters` clusters.
    pub fn with_clusters(clusters: usize) -> Self {
        Engine {
            cache: ProgramCache::new(),
            scheduler: BatchScheduler::new(clusters),
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Enqueue a fully-optimized inference request; returns its id.
    pub fn submit(&mut self, cfg: TransformerConfig) -> u64 {
        let id = self.next_id;
        self.submit_request(Request::new(id, cfg))
    }

    /// Enqueue an explicit request (the id field is overwritten with the
    /// engine's monotonic counter).
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        req.id = self.next_id;
        self.next_id += 1;
        self.queue.push(req);
        req.id
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue into a scheduled, compiled batch (empty queue →
    /// empty batch).
    pub fn compile_batch(&mut self) -> CompiledBatch {
        let reqs = std::mem::take(&mut self.queue);
        self.scheduler.compile(&reqs, &mut self.cache)
    }

    /// Compile the pending requests and execute them on `backend` as
    /// one batch (the calibration-slice scope).
    pub fn serve(&mut self, backend: &mut dyn Backend) -> BatchReport {
        let batch = self.compile_batch();
        backend.execute(&batch)
    }

    /// Drain the queue into a **continuously batched** autoregressive
    /// run (DESIGN.md §10): requests join at their arrival iteration,
    /// prefill once, decode one token per iteration against their
    /// growing KV-cache, and retire at their token target while the
    /// cluster shares rebalance every iteration. Returns per-request
    /// time-to-first-token, per-token latency, tokens/s and energy.
    ///
    /// When the backend runs the raw-speed simulation tier (tile memo +
    /// [`crate::sim::SamplePolicy`], DESIGN.md §11), each retired
    /// report's `error_bound_cycles` accumulates the per-iteration
    /// sampling bounds, so end-to-end serving numbers stay auditable.
    pub fn serve_continuous(&mut self, backend: &mut dyn Backend) -> ServeReport {
        self.serve_continuous_bounded(backend, DEFAULT_MAX_ITERS)
    }

    /// [`Engine::serve_continuous`] with an explicit iteration bound.
    pub fn serve_continuous_bounded(
        &mut self,
        backend: &mut dyn Backend,
        max_iters: u32,
    ) -> ServeReport {
        let reqs = std::mem::take(&mut self.queue);
        run_continuous(self.scheduler, &mut self.cache, reqs, backend, max_iters)
    }

    /// The **resilient** serving loop (DESIGN.md §12): continuous
    /// batching plus bounded retries with re-planning around
    /// quarantined/offline clusters, admission control (live-set and
    /// queue-depth bounds, projected-TTFT shedding), per-request
    /// deadlines, and graceful degradation under overload. `fallback`
    /// executes iterations once the degradation ladder reaches
    /// [`super::ExecMode::Analytic`] and the primary backend cannot
    /// switch itself. The returned [`ServeReport`] carries the SLO
    /// summary (tail percentiles, attainment, shed/retry counts) and
    /// per-cluster health history.
    ///
    /// With [`super::serve::ServeOptions::paging`] set, decode KV runs
    /// on the paged block-pool tier (DESIGN.md §14): admission reserves
    /// block tables from a shared fixed pool (deferring or shedding
    /// unfittable requests), prompt heads shared via
    /// [`super::PromptSig`] skip prefill through the radix prefix
    /// index, allocation pressure walks LRU eviction → whole-request
    /// preemption (evict-and-requeue, token books preserved), and each
    /// request's [`super::SchedPolicy`] steers admission order, cluster
    /// shares and victim choice. The report then carries a
    /// [`super::PoolReport`] and per-policy SLO attainment.
    pub fn serve_resilient(
        &mut self,
        primary: &mut dyn Backend,
        fallback: Option<&mut dyn Backend>,
        opts: &ServeOptions,
    ) -> ServeReport {
        let reqs = std::mem::take(&mut self.queue);
        run_resilient(self.scheduler, &mut self.cache, reqs, primary, fallback, opts)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, VIT_BASE};

    #[test]
    fn submit_assigns_monotonic_ids() {
        let mut e = Engine::new();
        let a = e.submit(GPT2_SMALL);
        let b = e.submit(VIT_BASE);
        assert_eq!((a, b), (0, 1));
        assert_eq!(e.pending(), 2);
        let batch = e.compile_batch();
        assert_eq!(e.pending(), 0);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.requests[1].req.id, 1);
    }

    #[test]
    fn ids_stay_monotonic_across_submit_styles_and_batches() {
        let mut e = Engine::new();
        let a = e.submit(GPT2_SMALL);
        let b = e.submit_request(Request::new(999, VIT_BASE).with_tokens(4));
        let _ = e.compile_batch();
        let c = e.submit_request(Request::baseline(7, VIT_BASE));
        assert_eq!((a, b, c), (0, 1, 2), "explicit ids are overwritten");
    }

    #[test]
    fn empty_queue_compiles_to_empty_batch() {
        let mut e = Engine::new();
        let batch = e.compile_batch();
        assert!(batch.requests.is_empty());
        assert_eq!(batch.active_clusters(), 0);
    }

    #[test]
    fn repeated_batches_reuse_the_cache() {
        let mut e = Engine::new();
        e.submit(GPT2_SMALL);
        let _ = e.compile_batch();
        e.submit(GPT2_SMALL);
        let batch = e.compile_batch();
        assert_eq!(batch.cache_hits, 1, "second batch must reuse the program");
        assert_eq!(batch.cache_misses, 0);
    }
}
