//! The serving-engine facade: request queue + shared program cache +
//! batch scheduler, independent of which [`Backend`] executes.

use super::batch::{BatchScheduler, CompiledBatch};
use super::program::ProgramCache;
use super::report::BatchReport;
use super::{Backend, Request};
use crate::coordinator::CLUSTERS;
use crate::model::TransformerConfig;

/// Collects concurrent requests, compiles them once through the shared
/// [`ProgramCache`], and hands the packed batch to a backend.
pub struct Engine {
    pub cache: ProgramCache,
    pub scheduler: BatchScheduler,
    queue: Vec<Request>,
    next_id: u64,
}

impl Engine {
    /// Engine for the paper's 16-cluster Occamy-style system.
    pub fn new() -> Self {
        Self::with_clusters(CLUSTERS)
    }

    pub fn with_clusters(clusters: usize) -> Self {
        Engine {
            cache: ProgramCache::new(),
            scheduler: BatchScheduler::new(clusters),
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Enqueue a fully-optimized inference request; returns its id.
    pub fn submit(&mut self, cfg: TransformerConfig) -> u64 {
        let id = self.next_id;
        self.submit_request(Request::new(id, cfg))
    }

    /// Enqueue an explicit request (the id field is overwritten with the
    /// engine's monotonic counter).
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        req.id = self.next_id;
        self.next_id += 1;
        self.queue.push(req);
        req.id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the queue into a scheduled, compiled batch.
    pub fn compile_batch(&mut self) -> CompiledBatch {
        let reqs = std::mem::take(&mut self.queue);
        self.scheduler.compile(&reqs, &mut self.cache)
    }

    /// Compile the pending requests and execute them on `backend`.
    pub fn serve(&mut self, backend: &mut dyn Backend) -> BatchReport {
        let batch = self.compile_batch();
        backend.execute(&batch)
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, VIT_BASE};

    #[test]
    fn submit_assigns_monotonic_ids() {
        let mut e = Engine::new();
        let a = e.submit(GPT2_SMALL);
        let b = e.submit(VIT_BASE);
        assert_eq!((a, b), (0, 1));
        assert_eq!(e.pending(), 2);
        let batch = e.compile_batch();
        assert_eq!(e.pending(), 0);
        assert_eq!(batch.requests.len(), 2);
        assert_eq!(batch.requests[1].req.id, 1);
    }

    #[test]
    fn repeated_batches_reuse_the_cache() {
        let mut e = Engine::new();
        e.submit(GPT2_SMALL);
        let _ = e.compile_batch();
        e.submit(GPT2_SMALL);
        let batch = e.compile_batch();
        assert_eq!(batch.cache_hits, 1, "second batch must reuse the program");
        assert_eq!(batch.cache_misses, 0);
    }
}
