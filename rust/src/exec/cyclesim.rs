//! The cycle-accurate backend: `sim::System` behind the [`Backend`]
//! trait.
//!
//! `estimate` measures the request's kernels — softmax at the request's
//! row length, the dot-product GEMM, and a real FlashAttention-2 head
//! slice at the request's [`TilePlan`] tile sizes — by *running their
//! instruction streams* on a cluster (compiled once through the program
//! cache), then scales the measured rates over the model's operation
//! counts with the same head-mapping / double-buffered-DMA composition
//! the analytic estimator uses. `estimate_phase` does the same for an
//! explicit prefill or decode phase, the decode side measured on the
//! real single-query decode slice. The two backends therefore
//! cross-check each other: same composition, independently obtained
//! rates.
//!
//! `execute` runs a [`CompiledBatch`] for real on the multi-cluster
//! system: every request's clusters execute its cached slice program —
//! repeated up to [`MAX_SIM_REPS`] times and extrapolated *exactly* to
//! the batch's `reps` count (repetitions of a cached kernel are
//! cycle-identical; see `sim/system.rs` and DESIGN.md §10) — while all
//! active clusters share HBM bandwidth. Projection GEMMs of the serving
//! scope are priced at the backend's own measured GEMM rate and folded
//! into the compute leg before the compute/DMA overlap.

use super::batch::CompiledBatch;
use super::program::{KernelKind, ProgramCache, ProgramKey};
use super::report::{BatchReport, RunReport};
use super::{Backend, ExecMode, Request};
use crate::coordinator::{DecodePlan, HeadMap, TilePlan};
use crate::energy::power::{cluster_energy_pj, DMA_PJ_PER_BYTE};
use crate::isa::Class;
use crate::kernels::flash_attention::{
    build_fa_decode_program, build_fa_program, seed_fa_decode_inputs, seed_fa_inputs,
};
use crate::kernels::gelu::{build_gelu_program, seed_gelu_inputs, GeluForm, GeluVariant};
use crate::kernels::gemm::build_gemm_program;
use crate::kernels::layernorm::{
    build_layernorm_program, seed_layernorm_inputs, LayerNormVariant,
};
use crate::kernels::softmax::{build_softmax_program, seed_softmax_inputs};
use crate::model::{Phase, WorkloadOps};
use crate::sim::{
    shared_memo, Cluster, ClusterJob, ClusterStats, SamplePolicy, System, CORES_PER_CLUSTER,
};

/// Rows used for the softmax rate measurement (one per core).
const SM_ROWS: u32 = 8;

/// Slice repetitions actually simulated per cluster in `execute`; the
/// remainder is extrapolated by linear scaling. Exact for the optimized
/// kernels (no data-dependent timing); for `Baseline` kernels the libm
/// exponential takes its special path once per row on the first
/// repetition only (the running max starts at −inf), so the scaling
/// error is bounded by one libm-call delta per row — see DESIGN.md §10.
pub const MAX_SIM_REPS: u32 = 2;

/// Measured-rate backend running real instruction streams.
pub struct CycleSimBackend {
    /// The multi-cluster system programs execute on.
    pub system: System,
    /// Calibration programs compiled by `estimate` are cached here, so
    /// repeated estimates for the same model shape skip the builders.
    pub cache: ProgramCache,
    /// Memoized optimized-GEMM rate (cycles/FLOP, pJ/FLOP) for pricing
    /// the serving scope's projection legs.
    gemm_cal: Option<(f64, f64)>,
    /// Memoized nonlinearity rates (GELU cyc/elem, GELU pJ/elem,
    /// LayerNorm cyc/elem, LayerNorm pJ/elem), one slot per
    /// optimization level (`[baseline, optimized]`).
    nonlin_cal: [Option<(f64, f64, f64, f64)>; 2],
}

impl CycleSimBackend {
    /// Backend over a fresh system of `n_clusters` clusters. The tile
    /// memo is on by default — replayed tiles are bit-identical to
    /// re-executed ones by construction (DESIGN.md §11), so the memo is
    /// a pure host-speed win; [`Self::without_memo`] turns it off.
    pub fn new(n_clusters: usize) -> Self {
        let mut system = System::new(n_clusters);
        system.memo = Some(shared_memo());
        CycleSimBackend {
            system,
            cache: ProgramCache::new(),
            gemm_cal: None,
            nonlin_cal: [None, None],
        }
    }

    /// Disable the tile memo (e.g. to time the raw unmemoized fast path
    /// or A/B the two in the differential tests).
    pub fn without_memo(mut self) -> Self {
        self.system.memo = None;
        self
    }

    /// Enable sampled simulation (the raw-speed tier): `execute` then
    /// simulates a warm-up plus a strided sample of each request's slice
    /// repetitions and extrapolates the rest, reporting the cycle error
    /// bound in [`RunReport::error_bound_cycles`].
    pub fn with_sampling(mut self, policy: SamplePolicy) -> Self {
        self.system.sampling = Some(policy);
        self
    }

    /// Measured cluster-scope softmax cycles and energy per element at
    /// row length `n`.
    fn softmax_rate(&mut self, req: &Request, n: u32) -> (f64, f64, ClusterStats) {
        let variant = req.softmax_variant();
        let key = ProgramKey::for_kernel(
            KernelKind::Softmax(variant),
            [SM_ROWS, n, 0, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let prog = self
            .cache
            .get_or_build(key, || build_softmax_program(variant, SM_ROWS, n));
        let mut cluster = Cluster::new();
        seed_softmax_inputs(&mut cluster.spm, SM_ROWS, n, 0x50F7);
        let stats = cluster.run_program_memo(&prog, self.system.memo.as_ref());
        let elems = (SM_ROWS * n) as f64;
        let cyc = stats.cycles as f64 / elems;
        let pj = cluster_energy_pj(&stats, req.softmax_optimized).total() / elems;
        (cyc, pj, stats)
    }

    /// Run the 64³ GEMM calibration on a fresh cluster; memoizes the
    /// optimized rate pair and returns it with the run's stats.
    fn gemm_measure(&mut self) -> (f64, f64, ClusterStats) {
        let (m, k, n) = (64u32, 64u32, 64u32);
        let key = ProgramKey::for_kernel(
            KernelKind::Gemm,
            [m, k, n, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let prog = self.cache.get_or_build(key, || build_gemm_program(m, k, n).1);
        let mut cluster = Cluster::new();
        let stats = cluster.run_program_memo(&prog, self.system.memo.as_ref());
        let flops = (2 * m as u64 * n as u64 * k as u64) as f64;
        let cal = (
            stats.cycles as f64 / flops,
            cluster_energy_pj(&stats, true).total() / flops,
        );
        self.gemm_cal = Some(cal);
        (cal.0, cal.1, stats)
    }

    /// Measured optimized-GEMM rate (cycles/FLOP, pJ/FLOP), memoized.
    fn gemm_cal(&mut self) -> (f64, f64) {
        if let Some(cal) = self.gemm_cal {
            return cal;
        }
        let (cyc, pj, _) = self.gemm_measure();
        (cyc, pj)
    }

    /// Measured nonlinearity rates at the requested optimization level:
    /// (GELU cyc/elem, GELU pJ/elem, LayerNorm cyc/elem, LayerNorm
    /// pJ/elem). Runs the real GELU and LayerNorm programs once per
    /// level and memoizes the result.
    fn nonlin_cal(&mut self, optimized: bool) -> (f64, f64, f64, f64) {
        let idx = optimized as usize;
        if let Some(cal) = self.nonlin_cal[idx] {
            return cal;
        }
        let (rows, n) = (SM_ROWS, 512u32);
        let gv = if optimized {
            GeluVariant::Hw(GeluForm::Tanh)
        } else {
            GeluVariant::Sw(GeluForm::Tanh)
        };
        let gkey = ProgramKey::for_kernel(
            KernelKind::Gelu(gv),
            [rows, n, 0, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let gprog = self.cache.get_or_build(gkey, || build_gelu_program(gv, rows, n));
        let mut cluster = Cluster::new();
        seed_gelu_inputs(&mut cluster.spm, rows, n, 0x6E10);
        let gstats = cluster.run_program_memo(&gprog, self.system.memo.as_ref());

        let lv = if optimized {
            LayerNormVariant::Optimized
        } else {
            LayerNormVariant::Baseline
        };
        let lkey = ProgramKey::for_kernel(
            KernelKind::LayerNorm(lv),
            [rows, n, 0, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let lprog = self.cache.get_or_build(lkey, || build_layernorm_program(lv, rows, n));
        let mut cluster = Cluster::new();
        seed_layernorm_inputs(&mut cluster.spm, rows, n, 0x1A7E);
        let lstats = cluster.run_program_memo(&lprog, self.system.memo.as_ref());

        let elems = (rows * n) as f64;
        let cal = (
            gstats.cycles as f64 / elems,
            cluster_energy_pj(&gstats, optimized).total() / elems,
            lstats.cycles as f64 / elems,
            cluster_energy_pj(&lstats, optimized).total() / elems,
        );
        self.nonlin_cal[idx] = Some(cal);
        cal
    }

    /// Measured cluster-scope GEMM cycles and energy per FLOP, derated
    /// for scalar-GEMM requests (the Fig. 1 anchor).
    fn gemm_rate(&mut self, req: &Request) -> (f64, f64, ClusterStats) {
        let (opt_cyc, opt_pj, stats) = self.gemm_measure();
        let (cyc, pj) = derate_gemm(opt_cyc, opt_pj, req.gemm_optimized);
        (cyc, pj, stats)
    }

    /// Run one real FlashAttention-2 head slice at the request's tile
    /// plan; returns (cycles, energy_pj) for the slice and the stats.
    fn fa_slice(
        &mut self,
        req: &Request,
        plan: &TilePlan,
    ) -> (f64, f64, ClusterStats, super::batch::CalShape) {
        let cal = super::batch::CalShape::for_plan(plan);
        let variant = req.fa_variant();
        let key = ProgramKey::for_request(
            KernelKind::FlashAttention(variant),
            &req.cfg,
            plan,
            CORES_PER_CLUSTER as u32,
        );
        let prog = self
            .cache
            .get_or_build(key, || build_fa_program(variant, cal.sq, cal.sk, cal.d, cal.bk));
        let mut cluster = Cluster::new();
        seed_fa_inputs(&mut cluster.spm, cal.sq, cal.sk, cal.d, cal.bk, 0xFA ^ req.id);
        let stats = cluster.run_program_memo(&prog, self.system.memo.as_ref());
        let e = cluster_energy_pj(&stats, req.softmax_optimized).total();
        (stats.cycles as f64, e, stats, cal)
    }

    /// Run one real single-query decode slice at the request's decode
    /// plan; returns (cycles, energy_pj, stats).
    fn decode_slice(&mut self, req: &Request, plan: &DecodePlan) -> (f64, f64, ClusterStats) {
        let variant = req.fa_variant();
        let key = ProgramKey::for_decode(
            KernelKind::FlashDecode(variant),
            &req.cfg,
            plan.sk_slice,
            plan.bk,
            CORES_PER_CLUSTER as u32,
        );
        let prog = self.cache.get_or_build(key, || {
            build_fa_decode_program(variant, plan.sk_slice, plan.d, plan.bk)
        });
        let mut cluster = Cluster::new();
        seed_fa_decode_inputs(&mut cluster.spm, plan.sk_slice, plan.d, plan.bk, 0xDEC0 ^ req.id);
        let stats = cluster.run_program_memo(&prog, self.system.memo.as_ref());
        let e = cluster_energy_pj(&stats, req.softmax_optimized).total();
        (stats.cycles as f64, e, stats)
    }

    /// Softmax-phase share of a run's retired instructions: hardware
    /// exponentials, the per-row divisions, and the FP64 libm code of
    /// the baseline variant are softmax-phase work.
    fn softmax_fraction(stats: &[ClusterStats]) -> f64 {
        let mut sm_instr = 0u64;
        let mut retired = 0u64;
        for s in stats {
            let c = s.combined();
            sm_instr +=
                c.count(Class::FpExp) + c.count(Class::FpDivH) + c.count(Class::FpScalarD);
            retired += c.retired_total();
        }
        sm_instr as f64 / retired.max(1) as f64
    }
}

impl Backend for CycleSimBackend {
    fn name(&self) -> &'static str {
        "cycle-sim"
    }

    fn estimate(&mut self, req: &Request) -> RunReport {
        let cfg = &req.cfg;
        let plan = TilePlan::plan(cfg);
        // softmax rows at (a tiling of) the request's sequence length
        let n = (cfg.seq.min(1024) / 16 * 16).max(16);
        let (sm_cyc, sm_pj, sm_stats) = self.softmax_rate(req, n);
        let (gemm_rate, gemm_pj, gemm_stats) = self.gemm_rate(req);
        let (fa_cycles, fa_pj, fa_stats, cal) = self.fa_slice(req, &plan);

        // scale the slice to one full S×S head
        let scale = (cfg.seq as f64 / cal.sq as f64) * (cfg.seq as f64 / cal.sk as f64);
        let head_attn = fa_cycles * scale;

        // same composition as coordinator::estimate, measured rates
        let ops = WorkloadOps::of(cfg);
        let l = ops.per_layer;
        let clusters = self.system.len().max(1) as f64;
        let proj_cycles = l.proj_flops as f64 * gemm_rate / clusters;
        let map = HeadMap::new(cfg.heads, self.system.len().max(1) as u32);
        let rounds = map.rounds() as f64;
        let attn_cycles = rounds * head_attn;
        let per_head_sm = l.softmax_elems as f64 / cfg.heads as f64;
        let softmax_cycles = rounds * per_head_sm * sm_cyc;

        // nonlinearities at measured rates, element-parallel
        let (g_cyc, g_pj, ln_cyc, ln_pj) = self.nonlin_cal(req.softmax_optimized);
        let nonlin_cycles =
            (l.gelu_elems as f64 * g_cyc + l.layernorm_elems as f64 * ln_cyc) / clusters;

        let contention = self
            .system
            .hbm
            .contention_factor(self.system.len().max(1), self.system.dma.bytes_per_cycle);
        let bytes = (l.weight_bytes + l.act_bytes) as f64;
        let dma_cycles =
            self.system.dma.cycles((bytes / clusters) as u64) as f64 * contention;
        let compute = proj_cycles + attn_cycles + nonlin_cycles;
        let layer_cycles = compute.max(dma_cycles) + dma_cycles.min(compute) * 0.05;
        let layers = ops.layers as f64;

        // energy is a total, not a makespan: every head's attention
        // executes (heads ×), regardless of how many sequential rounds
        // the cluster mapping needs
        let energy = layers
            * (l.proj_flops as f64 * gemm_pj
                + cfg.heads as f64 * fa_pj * scale
                + l.gelu_elems as f64 * g_pj
                + l.layernorm_elems as f64 * ln_pj
                + bytes * DMA_PJ_PER_BYTE);

        RunReport {
            backend: self.name(),
            request_id: req.id,
            model: cfg.name,
            cycles: layer_cycles * layers,
            energy_pj: energy,
            softmax_cycles: softmax_cycles * layers,
            gemm_cycles: (proj_cycles + attn_cycles - softmax_cycles) * layers,
            attn_cycles: attn_cycles * layers,
            dma_cycles: dma_cycles * layers,
            nonlin_cycles: nonlin_cycles * layers,
            clusters_used: self.system.len(),
            per_cluster: vec![sm_stats, gemm_stats, fa_stats],
            ..Default::default()
        }
    }

    fn estimate_phase(&mut self, req: &Request, phase: Phase) -> RunReport {
        match phase {
            Phase::Prefill { prompt } => {
                let mut r2 = *req;
                r2.cfg.seq = prompt.max(1);
                let mut report = self.estimate(&r2);
                report.request_id = req.id;
                report.model = req.cfg.name;
                report
            }
            Phase::Decode { kv_len } => {
                let cfg = &req.cfg;
                let dplan = DecodePlan::plan(cfg);
                let (slice_cycles, slice_pj, slice_stats) = self.decode_slice(req, &dplan);
                let (gemm_rate, gemm_pj, gemm_stats) = self.gemm_rate(req);

                // compose one decode step with measured rates
                let ops = WorkloadOps::decode(cfg, kv_len);
                let l = ops.per_layer;
                let clusters = self.system.len().max(1) as f64;
                let map = HeadMap::new(cfg.heads, self.system.len().max(1) as u32);
                let rounds = map.rounds() as f64;
                let factor = dplan.kv_tile_factor(kv_len) as f64;
                let attn_cycles = rounds * factor * slice_cycles;
                let proj_cycles = l.proj_flops as f64 * gemm_rate / clusters;

                // decode-step nonlinearities at measured rates
                let (g_cyc, g_pj, ln_cyc, ln_pj) = self.nonlin_cal(req.softmax_optimized);
                let nonlin_cycles = (l.gelu_elems as f64 * g_cyc
                    + l.layernorm_elems as f64 * ln_cyc)
                    / clusters;

                let contention = self.system.hbm.contention_factor(
                    self.system.len().max(1),
                    self.system.dma.bytes_per_cycle,
                );
                let bytes = (l.weight_bytes + l.act_bytes) as f64;
                let dma_cycles =
                    self.system.dma.cycles((bytes / clusters) as u64) as f64 * contention;
                let compute = proj_cycles + attn_cycles + nonlin_cycles;
                let layer_cycles = compute.max(dma_cycles) + dma_cycles.min(compute) * 0.05;
                let layers = ops.layers as f64;

                let sm_frac = Self::softmax_fraction(std::slice::from_ref(&slice_stats));
                let cycles = layer_cycles * layers;
                let energy = layers
                    * (l.proj_flops as f64 * gemm_pj
                        + cfg.heads as f64 * factor * slice_pj
                        + l.gelu_elems as f64 * g_pj
                        + l.layernorm_elems as f64 * ln_pj
                        + bytes * DMA_PJ_PER_BYTE);

                RunReport {
                    backend: self.name(),
                    request_id: req.id,
                    model: cfg.name,
                    cycles,
                    energy_pj: energy,
                    softmax_cycles: attn_cycles * layers * sm_frac,
                    gemm_cycles: (proj_cycles + attn_cycles * (1.0 - sm_frac)) * layers,
                    attn_cycles: attn_cycles * layers,
                    dma_cycles: dma_cycles * layers,
                    nonlin_cycles: nonlin_cycles * layers,
                    clusters_used: self.system.len(),
                    tokens: 1,
                    decode_token_cycles: cycles,
                    per_cluster: vec![slice_stats, gemm_stats],
                    ..Default::default()
                }
            }
        }
    }

    fn execute(&mut self, batch: &CompiledBatch) -> BatchReport {
        assert!(
            batch.n_clusters <= self.system.len(),
            "batch scheduled for {} clusters, system has {}",
            batch.n_clusters,
            self.system.len()
        );
        // price the serving scope's projection legs at the measured rate
        let needs_proj = batch.requests.iter().any(|r| r.proj_flops_per_cluster > 0);
        let (proj_cyc_rate, proj_pj_rate) =
            if needs_proj { self.gemm_cal() } else { (0.0, 0.0) };

        let mut jobs: Vec<ClusterJob> =
            (0..self.system.len()).map(|_| ClusterJob::idle()).collect();
        // sampled mode hands *all* repetitions to the system (which
        // simulates a sample of them and extrapolates with a bound)
        // instead of the MAX_SIM_REPS-then-scale-exactly default
        let sampling = self.system.sampling.is_some();
        let mut scales = Vec::with_capacity(batch.requests.len());
        let mut extras = Vec::with_capacity(batch.requests.len());
        let mut nonlin_legs = Vec::with_capacity(batch.requests.len());
        for cr in &batch.requests {
            let reps = cr.reps.max(1);
            let (sim_reps, scale) = if sampling {
                (reps, 1.0)
            } else {
                let s = reps.min(MAX_SIM_REPS);
                (s, reps as f64 / s as f64)
            };
            scales.push(scale);
            let (proj_rate, _) = derate_gemm(proj_cyc_rate, proj_pj_rate, cr.req.gemm_optimized);
            // nonlinearity legs of the serving scope, at measured rates
            let (nonlin_cyc, nonlin_pj) =
                if cr.gelu_elems_per_cluster > 0 || cr.layernorm_elems_per_cluster > 0 {
                    let (g_cyc, g_pj, ln_cyc, ln_pj) = self.nonlin_cal(cr.req.softmax_optimized);
                    (
                        cr.gelu_elems_per_cluster as f64 * g_cyc
                            + cr.layernorm_elems_per_cluster as f64 * ln_cyc,
                        cr.gelu_elems_per_cluster as f64 * g_pj
                            + cr.layernorm_elems_per_cluster as f64 * ln_pj,
                    )
                } else {
                    (0.0, 0.0)
                };
            nonlin_legs.push((nonlin_cyc, nonlin_pj));
            let extra = (cr.proj_flops_per_cluster as f64 * proj_rate + nonlin_cyc) as u64;
            extras.push(extra);
            for &c in &cr.clusters {
                match cr.phase {
                    Phase::Decode { .. } => seed_fa_decode_inputs(
                        &mut self.system.clusters[c].spm,
                        cr.cal.sk,
                        cr.cal.d,
                        cr.cal.bk,
                        cr.req.id ^ c as u64,
                    ),
                    Phase::Prefill { .. } => seed_fa_inputs(
                        &mut self.system.clusters[c].spm,
                        cr.cal.sq,
                        cr.cal.sk,
                        cr.cal.d,
                        cr.cal.bk,
                        cr.req.id ^ c as u64,
                    ),
                }
                jobs[c] = if sampling {
                    ClusterJob::repeated(
                        cr.program.clone(),
                        sim_reps as u64,
                        cr.hbm_bytes_per_cluster,
                    )
                    .with_scaling(scale, extra)
                } else {
                    ClusterJob::new(
                        vec![cr.program.clone(); sim_reps as usize],
                        cr.hbm_bytes_per_cluster,
                    )
                    .with_scaling(scale, extra)
                };
            }
        }
        let stats = self.system.run_jobs(jobs);

        let mut per_request = Vec::with_capacity(batch.requests.len());
        for (((cr, &scale), &extra), &(nonlin_cyc, nonlin_pj)) in
            batch.requests.iter().zip(&scales).zip(&extras).zip(&nonlin_legs)
        {
            let mine: Vec<ClusterStats> = cr
                .clusters
                .iter()
                .map(|&c| stats.per_cluster[c].clone())
                .collect();
            let cycles = mine.iter().map(|s| s.cycles).max().unwrap_or(0) as f64;
            let dma_cycles = mine.iter().map(|s| s.dma_cycles).max().unwrap_or(0) as f64;
            let error_bound_cycles =
                mine.iter().map(|s| s.sampled_error_cycles).max().unwrap_or(0) as f64;
            let (_, proj_pj) = derate_gemm(proj_cyc_rate, proj_pj_rate, cr.req.gemm_optimized);
            // Energy composition: per-core instr/SSR energy covers only
            // the simulated repetitions, so it extrapolates by `scale`;
            // static/shared burn is proportional to the cluster cycles
            // run_jobs already extrapolated, and the DMA term is
            // already full-scope (dma_bytes) — neither scales again.
            let mut instr_ssr = 0.0f64;
            let mut rest = 0.0f64;
            for s in &mine {
                let e = cluster_energy_pj(s, cr.req.softmax_optimized);
                instr_ssr += e.instr + e.ssr;
                rest += e.static_core + e.shared + e.dma;
            }
            let n_cl = cr.clusters.len() as f64;
            let energy_pj = instr_ssr * scale
                + rest
                + n_cl * (cr.proj_flops_per_cluster as f64 * proj_pj + nonlin_pj);
            // attribute the softmax share from retired-instruction classes
            let sm_frac = Self::softmax_fraction(&mine);
            let failed = mine.iter().any(|s| s.failed);
            per_request.push(RunReport {
                backend: self.name(),
                request_id: cr.req.id,
                model: cr.req.cfg.name,
                cycles,
                energy_pj,
                softmax_cycles: cycles * sm_frac,
                gemm_cycles: cycles * (1.0 - sm_frac),
                // attention scope excludes the rated projection leg
                // (exact in the compute-bound case; when DMA bounds the
                // makespan this is the residual attributable window)
                attn_cycles: (cycles - extra as f64).max(0.0),
                dma_cycles,
                nonlin_cycles: nonlin_cyc,
                clusters_used: cr.clusters.len(),
                per_cluster: mine,
                error_bound_cycles,
                failed,
                ..Default::default()
            });
        }
        BatchReport {
            backend: self.name(),
            per_request,
            makespan_cycles: stats.cycles,
            hbm_bytes: stats.hbm_bytes,
            cache_hits: batch.cache_hits,
            cache_misses: batch.cache_misses,
            faults_injected: stats.faults_injected,
            failed_clusters: stats.failed_clusters,
            offline_clusters: stats.offline_clusters,
        }
    }

    fn set_mode(&mut self, mode: ExecMode) -> bool {
        match mode {
            ExecMode::Full => {
                self.system.sampling = None;
                true
            }
            ExecMode::Sampled => {
                self.system.sampling = Some(SamplePolicy::default());
                true
            }
            ExecMode::Analytic => false,
        }
    }
}

/// Apply the Fig. 1 scalar-GEMM derating to a measured optimized rate.
fn derate_gemm(cyc: f64, pj: f64, optimized: bool) -> (f64, f64) {
    if optimized {
        (cyc, pj)
    } else {
        (cyc * 3.0, pj * 4.0)
    }
}
