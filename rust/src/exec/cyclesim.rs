//! The cycle-accurate backend: `sim::System` behind the [`Backend`]
//! trait.
//!
//! `estimate` measures the request's kernels — softmax at the request's
//! row length, the dot-product GEMM, and a real FlashAttention-2 head
//! slice at the request's [`TilePlan`] tile sizes — by *running their
//! instruction streams* on a cluster (compiled once through the program
//! cache), then scales the measured rates over the model's operation
//! counts with the same head-mapping / double-buffered-DMA composition
//! the analytic estimator uses. The two backends therefore cross-check
//! each other: same composition, independently obtained rates.
//!
//! `execute` runs a [`CompiledBatch`] for real on the multi-cluster
//! system: every request's clusters execute its cached slice program
//! for its head rounds while all active clusters share HBM bandwidth.

use super::batch::CompiledBatch;
use super::program::{KernelKind, ProgramCache, ProgramKey};
use super::report::{BatchReport, RunReport};
use super::{Backend, Request};
use crate::coordinator::{HeadMap, TilePlan};
use crate::energy::power::{cluster_energy_pj, DMA_PJ_PER_BYTE};
use crate::isa::Class;
use crate::kernels::flash_attention::{build_fa_program, seed_fa_inputs};
use crate::kernels::gemm::build_gemm_program;
use crate::kernels::softmax::{build_softmax_program, seed_softmax_inputs};
use crate::model::WorkloadOps;
use crate::sim::{Cluster, ClusterJob, ClusterStats, System, CORES_PER_CLUSTER};

/// Rows used for the softmax rate measurement (one per core).
const SM_ROWS: u32 = 8;

pub struct CycleSimBackend {
    pub system: System,
    /// Calibration programs compiled by `estimate` are cached here, so
    /// repeated estimates for the same model shape skip the builders.
    pub cache: ProgramCache,
}

impl CycleSimBackend {
    pub fn new(n_clusters: usize) -> Self {
        CycleSimBackend { system: System::new(n_clusters), cache: ProgramCache::new() }
    }

    /// Measured cluster-scope softmax cycles and energy per element at
    /// row length `n`.
    fn softmax_rate(&mut self, req: &Request, n: u32) -> (f64, f64, ClusterStats) {
        let variant = req.softmax_variant();
        let key = ProgramKey::for_kernel(
            KernelKind::Softmax(variant),
            [SM_ROWS, n, 0, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let prog = self
            .cache
            .get_or_build(key, || build_softmax_program(variant, SM_ROWS, n));
        let mut cluster = Cluster::new();
        seed_softmax_inputs(&mut cluster.spm, SM_ROWS, n, 0x50F7);
        let stats = cluster.run_program(&prog);
        let elems = (SM_ROWS * n) as f64;
        let cyc = stats.cycles as f64 / elems;
        let pj = cluster_energy_pj(&stats, req.softmax_optimized).total() / elems;
        (cyc, pj, stats)
    }

    /// Measured cluster-scope GEMM cycles and energy per FLOP.
    fn gemm_rate(&mut self, req: &Request) -> (f64, f64, ClusterStats) {
        let (m, k, n) = (64u32, 64u32, 64u32);
        let key = ProgramKey::for_kernel(
            KernelKind::Gemm,
            [m, k, n, 0, 0, 0],
            CORES_PER_CLUSTER as u32,
        );
        let prog = self.cache.get_or_build(key, || build_gemm_program(m, k, n).1);
        let mut cluster = Cluster::new();
        let stats = cluster.run_program(&prog);
        let flops = (2 * m as u64 * n as u64 * k as u64) as f64;
        let opt_cyc = stats.cycles as f64 / flops;
        let opt_pj = cluster_energy_pj(&stats, true).total() / flops;
        // plain scalar GEMM: same 3x (cycles) / 4x (energy) derating the
        // analytic calibration uses (Fig. 1 anchor)
        if req.gemm_optimized {
            (opt_cyc, opt_pj, stats)
        } else {
            (opt_cyc * 3.0, opt_pj * 4.0, stats)
        }
    }

    /// Run one real FlashAttention-2 head slice at the request's tile
    /// plan; returns (cycles, energy_pj) for the slice and the stats.
    fn fa_slice(&mut self, req: &Request, plan: &TilePlan) -> (f64, f64, ClusterStats, super::batch::CalShape) {
        let cal = super::batch::CalShape::for_plan(plan);
        let variant = req.fa_variant();
        let key = ProgramKey::for_request(
            KernelKind::FlashAttention(variant),
            &req.cfg,
            plan,
            CORES_PER_CLUSTER as u32,
        );
        let prog = self
            .cache
            .get_or_build(key, || build_fa_program(variant, cal.sq, cal.sk, cal.d, cal.bk));
        let mut cluster = Cluster::new();
        seed_fa_inputs(&mut cluster.spm, cal.sq, cal.sk, cal.d, cal.bk, 0xFA ^ req.id);
        let stats = cluster.run_program(&prog);
        let e = cluster_energy_pj(&stats, req.softmax_optimized).total();
        (stats.cycles as f64, e, stats, cal)
    }
}

impl Backend for CycleSimBackend {
    fn name(&self) -> &'static str {
        "cycle-sim"
    }

    fn estimate(&mut self, req: &Request) -> RunReport {
        let cfg = &req.cfg;
        let plan = TilePlan::plan(cfg);
        // softmax rows at (a tiling of) the request's sequence length
        let n = (cfg.seq.min(1024) / 16 * 16).max(16);
        let (sm_cyc, sm_pj, sm_stats) = self.softmax_rate(req, n);
        let (gemm_rate, gemm_pj, gemm_stats) = self.gemm_rate(req);
        let (fa_cycles, fa_pj, fa_stats, cal) = self.fa_slice(req, &plan);

        // scale the slice to one full S×S head
        let scale = (cfg.seq as f64 / cal.sq as f64) * (cfg.seq as f64 / cal.sk as f64);
        let head_attn = fa_cycles * scale;

        // same composition as coordinator::estimate, measured rates
        let ops = WorkloadOps::of(cfg);
        let l = ops.per_layer;
        let clusters = self.system.len().max(1) as f64;
        let proj_cycles = l.proj_flops as f64 * gemm_rate / clusters;
        let map = HeadMap::new(cfg.heads, self.system.len().max(1) as u32);
        let rounds = map.rounds() as f64;
        let attn_cycles = rounds * head_attn;
        let per_head_sm = l.softmax_elems as f64 / cfg.heads as f64;
        let softmax_cycles = rounds * per_head_sm * sm_cyc;

        let contention = self
            .system
            .hbm
            .contention_factor(self.system.len().max(1), self.system.dma.bytes_per_cycle);
        let bytes = (l.weight_bytes + l.act_bytes) as f64;
        let dma_cycles =
            self.system.dma.cycles((bytes / clusters) as u64) as f64 * contention;
        let compute = proj_cycles + attn_cycles;
        let layer_cycles = compute.max(dma_cycles) + dma_cycles.min(compute) * 0.05;
        let layers = ops.layers as f64;

        // energy is a total, not a makespan: every head's attention
        // executes (heads ×), regardless of how many sequential rounds
        // the cluster mapping needs
        let energy = layers
            * (l.proj_flops as f64 * gemm_pj
                + cfg.heads as f64 * fa_pj * scale
                + bytes * DMA_PJ_PER_BYTE);

        RunReport {
            backend: self.name(),
            request_id: req.id,
            model: cfg.name,
            cycles: layer_cycles * layers,
            energy_pj: energy,
            softmax_cycles: softmax_cycles * layers,
            gemm_cycles: (proj_cycles + attn_cycles - softmax_cycles) * layers,
            attn_cycles: attn_cycles * layers,
            dma_cycles: dma_cycles * layers,
            clusters_used: self.system.len(),
            per_cluster: vec![sm_stats, gemm_stats, fa_stats],
        }
    }

    fn execute(&mut self, batch: &CompiledBatch) -> BatchReport {
        assert!(
            batch.n_clusters <= self.system.len(),
            "batch scheduled for {} clusters, system has {}",
            batch.n_clusters,
            self.system.len()
        );
        let mut jobs: Vec<ClusterJob> =
            (0..self.system.len()).map(|_| ClusterJob::idle()).collect();
        for cr in &batch.requests {
            for &c in &cr.clusters {
                seed_fa_inputs(
                    &mut self.system.clusters[c].spm,
                    cr.cal.sq,
                    cr.cal.sk,
                    cr.cal.d,
                    cr.cal.bk,
                    cr.req.id ^ c as u64,
                );
                jobs[c] = ClusterJob::new(
                    vec![cr.program.clone(); cr.rounds as usize],
                    cr.hbm_bytes_per_cluster,
                );
            }
        }
        let stats = self.system.run_jobs(jobs);

        let mut per_request = Vec::with_capacity(batch.requests.len());
        for cr in &batch.requests {
            let mine: Vec<ClusterStats> = cr
                .clusters
                .iter()
                .map(|&c| stats.per_cluster[c].clone())
                .collect();
            let cycles = mine.iter().map(|s| s.cycles).max().unwrap_or(0) as f64;
            let dma_cycles = mine.iter().map(|s| s.dma_cycles).max().unwrap_or(0) as f64;
            let energy_pj: f64 = mine
                .iter()
                .map(|s| cluster_energy_pj(s, cr.req.softmax_optimized).total())
                .sum();
            // attribute the softmax share from retired-instruction classes:
            // hardware exponentials, the per-row divisions, and the FP64
            // libm code of the baseline variant are softmax-phase work
            let mut sm_instr = 0u64;
            let mut retired = 0u64;
            for s in &mine {
                let c = s.combined();
                sm_instr += c.count(Class::FpExp)
                    + c.count(Class::FpDivH)
                    + c.count(Class::FpScalarD);
                retired += c.retired_total();
            }
            let sm_frac = sm_instr as f64 / retired.max(1) as f64;
            per_request.push(RunReport {
                backend: self.name(),
                request_id: cr.req.id,
                model: cr.req.cfg.name,
                cycles,
                energy_pj,
                softmax_cycles: cycles * sm_frac,
                gemm_cycles: cycles * (1.0 - sm_frac),
                attn_cycles: cycles,
                dma_cycles,
                clusters_used: cr.clusters.len(),
                per_cluster: mine,
            });
        }
        BatchReport {
            backend: self.name(),
            per_request,
            makespan_cycles: stats.cycles,
            hbm_bytes: stats.hbm_bytes,
            cache_hits: batch.cache_hits,
            cache_misses: batch.cache_misses,
        }
    }
}
