//! Prefix sharing for the paged KV tier (DESIGN.md §14): deterministic
//! prompt-token materialization and the radix-tree index that lets
//! requests with a common prompt head share physical cache blocks and
//! skip the redundant part of prefill.
//!
//! Requests carry no literal token arrays (they stay `Copy`); instead a
//! [`super::PromptSig`] names a deterministic token *stream*: position
//! `i` of the prompt is a pure hash of `(head_seed, i)` inside the
//! shared head and of `(request id, i)` beyond it. Two requests whose
//! signatures share a `head_seed` therefore materialize byte-identical
//! head tokens — shareable — while their tails are unique by id.
//!
//! The index keys whole blocks only: a chunk of `block_tokens` tokens
//! hashes to one fingerprint (salted by model and block geometry, so
//! unrelated models never collide), and a lookup walks the tree chunk
//! by chunk, returning the physical blocks of the longest fully-matched
//! chunk path. Partial-block sharing is deliberately out of scope — a
//! shared block is immutable while shared, which is what keeps the
//! serve loop's appends copy-free (copy-on-write remains at the pool
//! level for forked tables, e.g. future speculative decoding).

use super::kvpool::BlockId;
use super::Request;
use crate::testkit::mix;

/// Domain separation for unique (non-shared) prompt tail tokens.
const UNIQ_STREAM: u64 = 0x9E37_79B9_7F4A_7C15;

/// Token at position `i` of `req`'s prompt: from the shared-head stream
/// while `i < head_len`, from the request-unique stream beyond.
pub fn prompt_token(req: &Request, i: u32) -> u32 {
    let sig = req.prompt_sig;
    if i < sig.head_len {
        mix(sig.head_seed, i as u64) as u32
    } else {
        mix(mix(UNIQ_STREAM, req.id), i as u64) as u32
    }
}

/// Materialize the first `len` prompt tokens of `req`.
pub fn prompt_tokens(req: &Request, len: u32) -> Vec<u32> {
    (0..len).map(|i| prompt_token(req, i)).collect()
}

/// Fingerprint of one whole-block token chunk (FNV-1a over the model
/// name, the block geometry, and the chunk's tokens). Salting with the
/// geometry keeps indexes of different block sizes disjoint.
fn chunk_fp(model: &str, block_tokens: u32, chunk: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for b in model.bytes() {
        eat(b);
    }
    for b in block_tokens.to_le_bytes() {
        eat(b);
    }
    for &t in chunk {
        for b in t.to_le_bytes() {
            eat(b);
        }
    }
    h
}

/// Fingerprints of every *full* block-sized chunk of `req`'s prompt
/// (the partial tail chunk is never shareable and never indexed).
pub fn chunk_fingerprints(req: &Request, block_tokens: u32) -> Vec<u64> {
    let bt = block_tokens.max(1);
    let full = req.cfg.seq / bt;
    (0..full)
        .map(|c| {
            let toks = (c * bt..(c + 1) * bt)
                .map(|i| prompt_token(req, i))
                .collect::<Vec<_>>();
            chunk_fp(req.cfg.name, bt, &toks)
        })
        .collect()
}

/// One radix-tree node: a chunk fingerprint, the physical block that
/// holds the chunk, and the continuations seen after it.
#[derive(Clone, Debug)]
struct Node {
    fp: u64,
    block: BlockId,
    children: Vec<Node>,
}

/// The prefix index: a radix tree over whole-block chunk fingerprints,
/// mapping every indexed prompt head to the physical blocks that hold
/// it. First insert wins per path position — concurrent identical
/// prompts register one canonical block per chunk; a loser's duplicate
/// block simply stays unindexed and is discarded when its table frees.
#[derive(Clone, Debug, Default)]
pub struct PrefixIndex {
    roots: Vec<Node>,
}

impl PrefixIndex {
    /// An empty index.
    pub fn new() -> Self {
        Self::default()
    }

    /// Longest indexed chunk-path matching `fps`, as the physical
    /// blocks along it (in prompt order). The caller must `retain`
    /// every returned block before using it.
    pub fn lookup(&self, fps: &[u64]) -> Vec<BlockId> {
        let mut out = Vec::new();
        let mut level = &self.roots;
        for fp in fps {
            match level.iter().find(|n| n.fp == *fp) {
                Some(n) => {
                    out.push(n.block);
                    level = &n.children;
                }
                None => break,
            }
        }
        out
    }

    /// Register `blocks` as the physical home of the chunk path `fps`.
    /// Existing nodes keep their canonical block; the returned vector
    /// holds the canonical block per position (callers use it to learn
    /// which of their own blocks actually joined the index).
    pub fn insert(&mut self, fps: &[u64], blocks: &[BlockId]) -> Vec<BlockId> {
        assert_eq!(fps.len(), blocks.len(), "one block per chunk");
        let mut canonical = Vec::with_capacity(fps.len());
        let mut level = &mut self.roots;
        for (fp, &block) in fps.iter().zip(blocks) {
            let pos = match level.iter().position(|n| n.fp == *fp) {
                Some(p) => p,
                None => {
                    level.push(Node { fp: *fp, block, children: Vec::new() });
                    level.len() - 1
                }
            };
            canonical.push(level[pos].block);
            level = &mut level[pos].children;
        }
        canonical
    }

    /// Purge every subtree rooted at a node holding `block` — called
    /// when the pool evicts the block, so the index never points at
    /// reclaimed storage. Descendant chunks become unreachable (their
    /// prefix is gone) and their blocks age out of the pool's LRU list.
    pub fn remove_block(&mut self, block: BlockId) {
        fn prune(nodes: &mut Vec<Node>, block: BlockId) {
            nodes.retain(|n| n.block != block);
            for n in nodes {
                prune(&mut n.children, block);
            }
        }
        prune(&mut self.roots, block);
    }

    /// Is `block` currently the canonical home of any indexed chunk?
    /// (Release-time cacheability: only indexed blocks stay resident.)
    pub fn contains_block(&self, block: BlockId) -> bool {
        fn walk(nodes: &[Node], block: BlockId) -> bool {
            nodes.iter().any(|n| n.block == block || walk(&n.children, block))
        }
        walk(&self.roots, block)
    }

    /// Total indexed chunks (tree nodes).
    pub fn len(&self) -> usize {
        fn count(nodes: &[Node]) -> usize {
            nodes.iter().map(|n| 1 + count(&n.children)).sum()
        }
        count(&self.roots)
    }

    /// True when nothing is indexed.
    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::PromptSig;
    use crate::model::GPT2_SMALL;

    fn req_with(id: u64, seq: u32, sig: PromptSig) -> Request {
        let mut cfg = GPT2_SMALL;
        cfg.seq = seq;
        let mut r = Request::new(id, cfg);
        r.prompt_sig = sig;
        r
    }

    #[test]
    fn shared_heads_materialize_identical_tokens_and_unique_tails() {
        let sig = PromptSig { head_seed: 77, head_len: 32 };
        let a = req_with(1, 64, sig);
        let b = req_with(2, 64, sig);
        let (ta, tb) = (prompt_tokens(&a, 64), prompt_tokens(&b, 64));
        assert_eq!(ta[..32], tb[..32], "shared head must be byte-identical");
        assert_ne!(ta[32..], tb[32..], "tails must be request-unique");
        // fingerprints agree exactly on the shared whole blocks
        let (fa, fb) = (chunk_fingerprints(&a, 16), chunk_fingerprints(&b, 16));
        assert_eq!(fa.len(), 4);
        assert_eq!(fa[..2], fb[..2]);
        assert_ne!(fa[2..], fb[2..]);
    }

    #[test]
    fn lookup_returns_the_longest_indexed_path() {
        let mut idx = PrefixIndex::new();
        idx.insert(&[10, 20, 30], &[0, 1, 2]);
        assert_eq!(idx.lookup(&[10, 20, 30, 40]), vec![0, 1, 2]);
        assert_eq!(idx.lookup(&[10, 99]), vec![0]);
        assert_eq!(idx.lookup(&[99]), Vec::<BlockId>::new());
        assert_eq!(idx.len(), 3);
    }

    #[test]
    fn first_insert_wins_and_reports_the_canonical_blocks() {
        let mut idx = PrefixIndex::new();
        idx.insert(&[10, 20], &[0, 1]);
        let canonical = idx.insert(&[10, 20, 30], &[5, 6, 7]);
        assert_eq!(canonical, vec![0, 1, 7], "existing nodes keep their block");
        assert_eq!(idx.lookup(&[10, 20, 30]), vec![0, 1, 7]);
        assert!(idx.contains_block(7) && !idx.contains_block(5));
    }

    #[test]
    fn remove_block_prunes_the_whole_subtree() {
        let mut idx = PrefixIndex::new();
        idx.insert(&[10, 20, 30], &[0, 1, 2]);
        idx.insert(&[10, 21], &[0, 3]);
        idx.remove_block(1);
        assert_eq!(idx.lookup(&[10, 20, 30]), vec![0], "subtree under 1 is gone");
        assert_eq!(idx.lookup(&[10, 21]), vec![0, 3], "sibling branch survives");
        assert!(!idx.contains_block(2), "descendants unreachable");
    }
}
