//! The paged KV-cache block pool (DESIGN.md §14): a fixed population of
//! refcounted, fixed-size cache blocks shared by every live request of
//! the serve loop.
//!
//! Blocks move through three states:
//!
//! - **free** — unallocated, ready for [`BlockPool::try_alloc`];
//! - **in use** — referenced by at least one [`BlockTable`]; prefix
//!   sharing holds a block in several tables at once (refcount > 1);
//! - **cached** — refcount dropped to zero but the block was released
//!   as *cacheable* (it backs a prefix-index entry), so its contents
//!   stay resident for future prefix hits until LRU eviction reclaims
//!   it under allocation pressure.
//!
//! The pool's books are exact and checked: every allocation is matched
//! by exactly one free (`allocated == freed + resident`, resident =
//! in-use + cached), refcounts never underflow, and eviction only ever
//! takes zero-reference cached blocks — the invariants the serve
//! report's [`super::report::PoolReport`] carries outward and
//! `ServeReport::assert_consistent` re-checks after every run.
//! Everything is deterministic: LRU order is release order, with no
//! wall-clock involved.

/// Index of a block inside its [`BlockPool`].
pub type BlockId = u32;

/// Append classification for [`BlockPool::append_need`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppendNeed {
    /// The tail block is exclusively owned and has room: fill in place.
    InPlace,
    /// The table is empty or its tail block is full: a fresh block must
    /// be allocated and pushed.
    NewBlock,
    /// The tail block has room but is shared (refcount > 1): appending
    /// requires a copy-on-write duplicate so the sharer's view stays
    /// immutable.
    CopyOnWrite,
}

/// Lifetime counters of a [`BlockPool`] (monotonic; reported as the
/// pool section of the serve report).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Blocks handed out by [`BlockPool::try_alloc`].
    pub allocated: u64,
    /// Blocks returned to the free list (discard-released or evicted).
    pub freed: u64,
    /// Cached blocks reclaimed by [`BlockPool::evict_lru`].
    pub evictions: u64,
    /// Copy-on-write tail duplications.
    pub cow_copies: u64,
    /// High-water mark of blocks referenced by at least one table.
    pub peak_in_use: usize,
}

/// A request's ordered view of its KV cache: the physical blocks
/// holding it (shared prefixes first) and the logical token count.
#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    /// Physical blocks, oldest KV positions first.
    pub blocks: Vec<BlockId>,
    /// Tokens per block for this table's model.
    pub block_tokens: u32,
    /// Logical tokens the table covers.
    pub tokens: u64,
}

impl BlockTable {
    /// An empty table for a model whose blocks hold `block_tokens`
    /// tokens each.
    pub fn new(block_tokens: u32) -> Self {
        BlockTable { blocks: Vec::new(), block_tokens: block_tokens.max(1), tokens: 0 }
    }
}

/// Per-block pool state.
#[derive(Clone, Copy, Debug, Default)]
struct BlockState {
    refs: u32,
    filled: u32,
}

/// The fixed-capacity, refcounted KV block pool (see module docs).
#[derive(Clone, Debug)]
pub struct BlockPool {
    states: Vec<BlockState>,
    /// Free block ids; allocation pops from the back.
    free: Vec<BlockId>,
    /// Zero-reference cacheable blocks in release order (front = least
    /// recently released = next eviction victim).
    cached: std::collections::VecDeque<BlockId>,
    /// Lifetime counters.
    pub stats: PoolStats,
}

impl BlockPool {
    /// A pool of `capacity` blocks, all free.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "pool needs at least one block");
        BlockPool {
            states: vec![BlockState::default(); capacity],
            free: (0..capacity as BlockId).rev().collect(),
            cached: std::collections::VecDeque::new(),
            stats: PoolStats::default(),
        }
    }

    /// Total blocks in the pool.
    pub fn capacity(&self) -> usize {
        self.states.len()
    }

    /// Blocks currently referenced by at least one table.
    pub fn in_use(&self) -> usize {
        self.states.len() - self.free.len() - self.cached.len()
    }

    /// Zero-reference blocks kept resident for prefix reuse.
    pub fn cached_count(&self) -> usize {
        self.cached.len()
    }

    /// Unallocated blocks.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Tokens filled into `id` so far.
    pub fn filled(&self, id: BlockId) -> u32 {
        self.states[id as usize].filled
    }

    /// Current reference count of `id`.
    pub fn refs(&self, id: BlockId) -> u32 {
        self.states[id as usize].refs
    }

    /// Allocate a free block (refcount 1, empty), or `None` if the free
    /// list is exhausted — the caller then evicts or preempts.
    pub fn try_alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        self.states[id as usize] = BlockState { refs: 1, filled: 0 };
        self.stats.allocated += 1;
        self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use());
        Some(id)
    }

    /// Reclaim the least-recently-released cached block, returning its
    /// id so the caller can purge it from the prefix index. `None` when
    /// nothing is evictable (every block is free or actively shared).
    pub fn evict_lru(&mut self) -> Option<BlockId> {
        let id = self.cached.pop_front()?;
        debug_assert_eq!(self.states[id as usize].refs, 0, "cached block has refs");
        self.states[id as usize].filled = 0;
        self.free.push(id);
        self.stats.evictions += 1;
        self.stats.freed += 1;
        Some(id)
    }

    /// Take an additional reference on `id` — a prefix hit pulling a
    /// cached (or already shared) block into another table. Returns the
    /// cached-list position the block was revived from, or `None` if it
    /// was already referenced; a caller rolling an admission back can
    /// hand the position to [`BlockPool::release_revived`] to restore
    /// the LRU order exactly.
    pub fn retain(&mut self, id: BlockId) -> Option<usize> {
        let revived = if self.states[id as usize].refs == 0 {
            // revive from the cached list
            let pos = self
                .cached
                .iter()
                .position(|&b| b == id)
                .expect("zero-ref retained block must be cached");
            self.cached.remove(pos);
            // in_use is derived from the free/cached lists, so the
            // revived block is already counted
            self.stats.peak_in_use = self.stats.peak_in_use.max(self.in_use());
            Some(pos)
        } else {
            None
        };
        self.states[id as usize].refs += 1;
        revived
    }

    /// Undo a reviving [`BlockPool::retain`]: drop the sole reference
    /// and reinsert the block into the cached LRU list at the position
    /// it was revived from (clamped to the list's current length).
    /// Undoing a sequence of retains in reverse order restores the
    /// pre-retain LRU order exactly, so a rolled-back admission leaves
    /// no eviction-order side effects.
    pub fn release_revived(&mut self, id: BlockId, pos: usize) {
        let st = &mut self.states[id as usize];
        assert_eq!(st.refs, 1, "release_revived undoes a sole reviving retain");
        st.refs = 0;
        let pos = pos.min(self.cached.len());
        self.cached.insert(pos, id);
    }

    /// Drop one reference on `id`. At zero references the block either
    /// stays resident on the cached LRU list (`cacheable`, i.e. a
    /// prefix-index entry still points at it) or returns to the free
    /// list immediately.
    pub fn release(&mut self, id: BlockId, cacheable: bool) {
        let st = &mut self.states[id as usize];
        assert!(st.refs > 0, "double free of block {id}");
        st.refs -= 1;
        if st.refs == 0 {
            if cacheable {
                self.cached.push_back(id);
            } else {
                st.filled = 0;
                self.free.push(id);
                self.stats.freed += 1;
            }
        }
    }

    /// Record `tokens` tokens as filled into `id` (prefill lands whole
    /// blocks at once; decode appends one row per step).
    pub fn fill(&mut self, id: BlockId, tokens: u32) {
        self.states[id as usize].filled = tokens;
    }

    /// How the next single-token append to `table` must proceed.
    pub fn append_need(&self, table: &BlockTable) -> AppendNeed {
        match table.blocks.last() {
            None => AppendNeed::NewBlock,
            Some(&tail) => {
                let st = &self.states[tail as usize];
                if st.filled >= table.block_tokens {
                    AppendNeed::NewBlock
                } else if st.refs > 1 {
                    AppendNeed::CopyOnWrite
                } else {
                    AppendNeed::InPlace
                }
            }
        }
    }

    /// Append one token into the exclusively-owned tail block.
    pub fn append_in_place(&mut self, table: &mut BlockTable) {
        let tail = *table.blocks.last().expect("in-place append needs a tail");
        let st = &mut self.states[tail as usize];
        debug_assert_eq!(st.refs, 1, "in-place append into a shared block");
        debug_assert!(st.filled < table.block_tokens);
        st.filled += 1;
        table.tokens += 1;
    }

    /// Push a freshly allocated block as the new tail and fill its
    /// first token.
    pub fn push_tail(&mut self, table: &mut BlockTable, id: BlockId) {
        debug_assert_eq!(self.states[id as usize].refs, 1);
        self.states[id as usize].filled = 1;
        table.blocks.push(id);
        table.tokens += 1;
    }

    /// Copy-on-write append: duplicate the shared tail's contents into
    /// the freshly allocated `id`, append the token there, and drop
    /// this table's reference on the shared original (which stays
    /// `keep_cacheable` for its remaining sharers).
    pub fn cow_tail(&mut self, table: &mut BlockTable, id: BlockId, keep_cacheable: bool) {
        let old = *table.blocks.last().expect("COW append needs a tail");
        debug_assert!(self.states[old as usize].refs > 1, "COW of an exclusive block");
        let copied = self.states[old as usize].filled;
        debug_assert!(copied < table.block_tokens);
        self.states[id as usize].filled = copied + 1;
        *table.blocks.last_mut().expect("tail checked") = id;
        table.tokens += 1;
        self.release(old, keep_cacheable);
        self.stats.cow_copies += 1;
    }

    /// Duplicate a table, sharing every block (the branch point of
    /// speculative decoding): refcounts rise, no bytes move. Appends
    /// through either table then trigger [`AppendNeed::CopyOnWrite`].
    pub fn fork(&mut self, table: &BlockTable) -> BlockTable {
        for &b in &table.blocks {
            self.retain(b);
        }
        table.clone()
    }

    /// Check the pool's books; panics with the failed invariant.
    /// `allocated == freed + resident` with resident = in-use + cached,
    /// and the three state populations exactly tile the capacity.
    pub fn assert_books(&self) {
        let resident = self.in_use() + self.cached.len();
        assert_eq!(
            self.stats.allocated,
            self.stats.freed + resident as u64,
            "pool books: allocated != freed + resident"
        );
        assert_eq!(
            self.free.len() + self.cached.len() + self.in_use(),
            self.capacity(),
            "pool states must tile the capacity"
        );
        for &b in &self.cached {
            assert_eq!(self.states[b as usize].refs, 0, "cached block {b} has refs");
        }
        for &b in &self.free {
            assert_eq!(self.states[b as usize].refs, 0, "free block {b} has refs");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_books_balance() {
        let mut pool = BlockPool::new(4);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        assert_eq!(pool.in_use(), 2);
        pool.release(a, false);
        pool.release(b, true); // stays cached
        assert_eq!((pool.in_use(), pool.cached_count(), pool.free_count()), (0, 1, 3));
        pool.assert_books();
        assert_eq!(pool.stats.allocated, 2);
        assert_eq!(pool.stats.freed, 1);
    }

    #[test]
    fn eviction_takes_the_least_recently_released_block() {
        let mut pool = BlockPool::new(3);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        pool.release(b, true);
        pool.release(a, true);
        pool.release(c, true);
        assert_eq!(pool.evict_lru(), Some(b), "b was released first");
        assert_eq!(pool.evict_lru(), Some(a));
        // retain revives c off the cached list; nothing evictable left
        pool.retain(c);
        assert_eq!(pool.evict_lru(), None);
        pool.release(c, false);
        pool.assert_books();
    }

    #[test]
    fn shared_tail_append_goes_copy_on_write() {
        let mut pool = BlockPool::new(4);
        let mut t = BlockTable::new(4);
        let b = pool.try_alloc().unwrap();
        pool.push_tail(&mut t, b);
        pool.append_in_place(&mut t);
        assert_eq!((t.tokens, pool.filled(b)), (2, 2));

        let mut fork = pool.fork(&t);
        assert_eq!(pool.refs(b), 2);
        assert_eq!(pool.append_need(&fork), AppendNeed::CopyOnWrite);
        let fresh = pool.try_alloc().unwrap();
        pool.cow_tail(&mut fork, fresh, false);
        assert_eq!(pool.stats.cow_copies, 1);
        assert_eq!(pool.filled(fresh), 3, "copied fill plus the append");
        assert_eq!(pool.refs(b), 1, "the fork dropped its shared ref");
        // the original's view is untouched
        assert_eq!((t.tokens, pool.filled(b)), (2, 2));
        assert_eq!(pool.append_need(&t), AppendNeed::InPlace);
        assert_eq!(fork.tokens, 3);
        pool.assert_books();
    }

    #[test]
    fn defer_rollback_restores_cached_lru_order() {
        let mut pool = BlockPool::new(3);
        let a = pool.try_alloc().unwrap();
        let b = pool.try_alloc().unwrap();
        let c = pool.try_alloc().unwrap();
        pool.release(a, true);
        pool.release(b, true);
        pool.release(c, true); // cached LRU: [a, b, c]

        // an admission revives b then a; a second retain of an
        // already-referenced block reports no position
        let pb = pool.retain(b);
        let pa = pool.retain(a);
        assert_eq!((pb, pa), (Some(1), Some(0)));
        assert_eq!(pool.retain(a), None);
        pool.release(a, true); // drop the extra reference again

        // rollback in reverse retain order restores [a, b, c] exactly
        pool.release_revived(a, 0);
        pool.release_revived(b, 1);
        assert_eq!(pool.evict_lru(), Some(a), "a must still be the LRU victim");
        assert_eq!(pool.evict_lru(), Some(b));
        assert_eq!(pool.evict_lru(), Some(c));
        pool.assert_books();
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_release_panics() {
        let mut pool = BlockPool::new(2);
        let a = pool.try_alloc().unwrap();
        pool.release(a, false);
        pool.release(a, false);
    }
}
