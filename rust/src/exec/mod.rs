//! Unified execution engine (DESIGN.md §8/§10): one API over the
//! analytic estimator and the cycle-accurate multi-cluster simulator.
//!
//! Before this module, the paper-figure reproducers talked to two
//! disconnected code paths — `coordinator::estimate` for the Fig. 1/8
//! numbers and `sim::System` for real instruction streams — and every
//! bench, example and the CLI hand-rolled its own plumbing. The engine
//! replaces that with:
//!
//! - [`Backend`]: `estimate(&Request)` / `estimate_phase` /
//!   `execute(&CompiledBatch)` returning one unified [`RunReport`],
//!   implemented by [`AnalyticBackend`] (calibrated rates, microsecond
//!   cost) and [`CycleSimBackend`] (real instruction streams on the
//!   C-cluster system);
//! - [`Program`] / [`ProgramCache`]: kernel instruction streams compiled
//!   once into shared handles instead of rebuilt per call;
//! - [`BatchScheduler`] / [`Engine`]: multiple concurrent transformer
//!   requests (mixed models, mixed sequence lengths, mixed phases)
//!   packed onto the 16 clusters, one request's DMA overlapping
//!   another's compute through the HBM-contention model;
//! - [`serve`]: the continuous-batching loop — requests with prompt and
//!   token targets join mid-flight, decode one token per iteration
//!   against their KV-cache, retire when done, and report
//!   time-to-first-token / per-token latency / tokens-per-second.

pub mod analytic;
pub mod batch;
pub mod cyclesim;
pub mod engine;
pub mod kvpool;
pub mod prefix;
pub mod program;
pub mod report;
pub mod serve;
pub mod trace;

pub use analytic::AnalyticBackend;
pub use batch::{BatchScheduler, CalShape, CompiledBatch, CompiledRequest, ServeEntry};
pub use cyclesim::CycleSimBackend;
pub use engine::Engine;
pub use kvpool::{AppendNeed, BlockId, BlockPool, BlockTable, PoolStats};
pub use prefix::PrefixIndex;
pub use program::{KernelKind, Program, ProgramCache, ProgramKey};
pub use report::{BatchReport, Outcome, PoolReport, RunReport};
pub use serve::{
    ClusterHealth, DecodeSummary, IterationEntry, IterationRecord, PagedKvOptions, ServeOptions,
    ServeReport, SloSummary, SpecDecodeOptions,
};
pub use trace::{TraceKind, TraceSpec};

use crate::kernels::flash_attention::FaVariant;
use crate::kernels::softmax::SoftmaxVariant;
use crate::model::{Phase, TransformerConfig};

/// Per-request scheduling objective of the paged serve loop (DESIGN.md
/// §14): what the admission controller and the per-iteration batch
/// composer optimize this request for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Maximize aggregate tokens/s: FIFO admission, work-proportional
    /// cluster shares, first in line as a preemption victim. The
    /// default — a uniformly throughput-policy run schedules exactly
    /// like the pre-policy loop.
    #[default]
    Throughput,
    /// Minimize this request's latency: jumps the admission queue ahead
    /// of ready throughput traffic, gets a boosted cluster share, and
    /// is preempted only when no throughput victim exists.
    Latency,
}

/// Deterministic prompt-content signature (DESIGN.md §14). Requests
/// stay `Copy` and carry no token arrays; instead the signature names a
/// pure token stream: positions below `head_len` hash from `head_seed`
/// (shared by every request carrying the same seed — the shareable
/// prompt head), positions beyond hash from the request id (unique
/// tail). The default signature (`head_len == 0`) makes the whole
/// prompt request-unique, i.e. nothing is shareable.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PromptSig {
    /// Seed of the shared head stream.
    pub head_seed: u64,
    /// Prompt positions drawn from the shared stream.
    pub head_len: u32,
}

/// One inference request: a model configuration, which kernel
/// optimizations its deployment enables (the paper's baseline/optimized
/// axes), and — for the serving path — how many tokens to generate and
/// when the request arrives.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Engine-assigned id (monotonic per engine).
    pub id: u64,
    /// Model configuration; `cfg.seq` is the prompt length.
    pub cfg: TransformerConfig,
    /// VFEXP-optimized softmax vs the scalar libm baseline.
    pub softmax_optimized: bool,
    /// [5]-style GEMM vs plain scalar code (Fig. 1 axis).
    pub gemm_optimized: bool,
    /// Tokens to generate autoregressively after prefill. `0` means a
    /// prefill-only request (e.g. a ViT classification pass).
    pub decode_tokens: u32,
    /// Continuous-batching iteration at which the request arrives; the
    /// engine admits it no earlier (staggered-arrival traffic).
    pub arrival_iter: u32,
    /// Open-loop arrival time in cycles (trace-driven serving). The
    /// resilient serve loop admits the request no earlier than this
    /// clock; TTFT and deadlines are measured from it.
    pub arrival_cycles: u64,
    /// Deadline in cycles after `arrival_cycles`: the request is retired
    /// as [`Outcome::TimedOut`] (keeping partial progress) once the
    /// clock passes `arrival_cycles + deadline`. `None` = no deadline.
    pub deadline_cycles: Option<u64>,
    /// Scheduling objective in the paged serve loop (admission order,
    /// cluster-share boost, preemption-victim order).
    pub policy: SchedPolicy,
    /// Prompt-content signature for paged prefix sharing.
    pub prompt_sig: PromptSig,
}

impl Request {
    /// A fully-optimized request (the deployment configuration).
    pub fn new(id: u64, cfg: TransformerConfig) -> Self {
        Request {
            id,
            cfg,
            softmax_optimized: true,
            gemm_optimized: true,
            decode_tokens: 0,
            arrival_iter: 0,
            arrival_cycles: 0,
            deadline_cycles: None,
            policy: SchedPolicy::default(),
            prompt_sig: PromptSig::default(),
        }
    }

    /// The Fig. 8 baseline: optimized GEMM, baseline softmax.
    pub fn baseline(id: u64, cfg: TransformerConfig) -> Self {
        Request { softmax_optimized: false, ..Self::new(id, cfg) }
    }

    /// Set the autoregressive generation target.
    pub fn with_tokens(mut self, tokens: u32) -> Self {
        self.decode_tokens = tokens;
        self
    }

    /// Set the arrival iteration for staggered serving traffic.
    pub fn arriving_at(mut self, iter: u32) -> Self {
        self.arrival_iter = iter;
        self
    }

    /// Set the open-loop arrival clock for trace-driven serving.
    pub fn arriving_at_cycles(mut self, cycles: u64) -> Self {
        self.arrival_cycles = cycles;
        self
    }

    /// Set a completion deadline, in cycles after arrival.
    pub fn with_deadline(mut self, cycles: u64) -> Self {
        self.deadline_cycles = Some(cycles);
        self
    }

    /// Set the scheduling objective for the paged serve loop.
    pub fn with_policy(mut self, policy: SchedPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Mark the first `head_len` prompt tokens as drawn from the shared
    /// stream `head_seed` (prefix-shareable with same-seed requests).
    pub fn with_shared_head(mut self, head_seed: u64, head_len: u32) -> Self {
        self.prompt_sig = PromptSig { head_seed, head_len: head_len.min(self.cfg.seq) };
        self
    }

    /// Prompt length in tokens (the model's configured sequence).
    pub fn prompt_len(&self) -> u32 {
        self.cfg.seq
    }

    /// Softmax kernel configuration this request runs.
    pub fn softmax_variant(&self) -> SoftmaxVariant {
        if self.softmax_optimized {
            SoftmaxVariant::SwExpHw
        } else {
            SoftmaxVariant::Baseline
        }
    }

    /// FlashAttention kernel configuration this request runs.
    pub fn fa_variant(&self) -> FaVariant {
        if self.softmax_optimized {
            FaVariant::Optimized
        } else {
            FaVariant::Baseline
        }
    }
}

/// Simulation fidelity level of a backend — the graceful-degradation
/// ladder the resilient serve loop walks under overload (DESIGN.md
/// §12): full cycle simulation → sampled simulation (cheaper, with an
/// error bound) → analytic rate estimates (cheapest, coarsest).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Simulate every repetition (bit-exact fast path).
    #[default]
    Full,
    /// Sampled simulation: [`crate::sim::SamplePolicy`] elides
    /// repetitions and reports an error bound (DESIGN.md §11).
    Sampled,
    /// Analytic rate estimates; no instruction stream executes.
    Analytic,
}

/// A unified execution backend over the 16-cluster system.
///
/// `estimate` answers "what does this request cost end-to-end" for one
/// full forward pass; `estimate_phase` answers the same for an explicit
/// inference [`Phase`] (prompt prefill or one-token KV-cache decode);
/// `execute` runs a scheduled multi-request batch (its slice workload —
/// see [`batch`]) and reports per request. All return [`RunReport`]s so
/// callers can swap backends freely.
pub trait Backend {
    /// Stable backend name for reports.
    fn name(&self) -> &'static str;

    /// Full forward-pass cost of a single request.
    fn estimate(&mut self, req: &Request) -> RunReport;

    /// Cost of one phase of a request: a prefill pass over the prompt,
    /// or one decode step against a KV-cache of the phase's length.
    fn estimate_phase(&mut self, req: &Request, phase: Phase) -> RunReport;

    /// Run a compiled batch; one report per request, in batch order.
    fn execute(&mut self, batch: &CompiledBatch) -> BatchReport;

    /// Ask the backend to run at a fidelity level (the degradation
    /// ladder). Returns `true` if the backend now runs at `mode`;
    /// backends that cannot switch (the default) return `false` and the
    /// caller falls back — e.g. to a separate [`AnalyticBackend`].
    fn set_mode(&mut self, mode: ExecMode) -> bool {
        let _ = mode;
        false
    }
}
