//! Unified execution engine (DESIGN.md §8): one API over the analytic
//! estimator and the cycle-accurate multi-cluster simulator.
//!
//! Before this module, the paper-figure reproducers talked to two
//! disconnected code paths — `coordinator::estimate` for the Fig. 1/8
//! numbers and `sim::System` for real instruction streams — and every
//! bench, example and the CLI hand-rolled its own plumbing. The engine
//! replaces that with:
//!
//! - [`Backend`]: `estimate(&Request)` / `execute(&CompiledBatch)`
//!   returning one unified [`RunReport`], implemented by
//!   [`AnalyticBackend`] (calibrated rates, microsecond cost) and
//!   [`CycleSimBackend`] (real instruction streams on the C-cluster
//!   system);
//! - [`Program`] / [`ProgramCache`]: kernel instruction streams compiled
//!   once into shared handles instead of rebuilt per call;
//! - [`BatchScheduler`] / [`Engine`]: multiple concurrent transformer
//!   requests (mixed models, mixed sequence lengths) packed onto the 16
//!   clusters, one request's DMA overlapping another's compute through
//!   the HBM-contention model.

pub mod analytic;
pub mod batch;
pub mod cyclesim;
pub mod engine;
pub mod program;
pub mod report;

pub use analytic::AnalyticBackend;
pub use batch::{BatchScheduler, CalShape, CompiledBatch, CompiledRequest};
pub use cyclesim::CycleSimBackend;
pub use engine::Engine;
pub use program::{KernelKind, Program, ProgramCache, ProgramKey};
pub use report::{BatchReport, RunReport};

use crate::kernels::flash_attention::FaVariant;
use crate::kernels::softmax::SoftmaxVariant;
use crate::model::TransformerConfig;

/// One inference request: a model configuration plus which kernel
/// optimizations its deployment enables (the paper's baseline/optimized
/// axes).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    pub cfg: TransformerConfig,
    /// VFEXP-optimized softmax vs the scalar libm baseline.
    pub softmax_optimized: bool,
    /// [5]-style GEMM vs plain scalar code (Fig. 1 axis).
    pub gemm_optimized: bool,
}

impl Request {
    /// A fully-optimized request (the deployment configuration).
    pub fn new(id: u64, cfg: TransformerConfig) -> Self {
        Request { id, cfg, softmax_optimized: true, gemm_optimized: true }
    }

    /// The Fig. 8 baseline: optimized GEMM, baseline softmax.
    pub fn baseline(id: u64, cfg: TransformerConfig) -> Self {
        Request { id, cfg, softmax_optimized: false, gemm_optimized: true }
    }

    pub fn softmax_variant(&self) -> SoftmaxVariant {
        if self.softmax_optimized {
            SoftmaxVariant::SwExpHw
        } else {
            SoftmaxVariant::Baseline
        }
    }

    pub fn fa_variant(&self) -> FaVariant {
        if self.softmax_optimized {
            FaVariant::Optimized
        } else {
            FaVariant::Baseline
        }
    }
}

/// A unified execution backend over the 16-cluster system.
///
/// `estimate` answers "what does this request cost end-to-end" for one
/// full forward pass; `execute` runs a scheduled multi-request batch
/// (its slice workload — see [`batch`]) and reports per request. Both
/// return [`RunReport`]s so callers can swap backends freely.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Full forward-pass cost of a single request.
    fn estimate(&mut self, req: &Request) -> RunReport;

    /// Run a compiled batch; one report per request, in batch order.
    fn execute(&mut self, batch: &CompiledBatch) -> BatchReport;
}
