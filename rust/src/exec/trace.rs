//! Open-loop arrival traces for trace-driven serving (DESIGN.md §12).
//!
//! The serve loop ([`super::Engine::serve`]) admits
//! requests no earlier than their `arrival_cycles`, so serving
//! experiments need an *open-loop* arrival process — one whose timing
//! does not depend on how fast the server happens to drain its queue.
//! This module generates two such processes from the in-tree seeded
//! PRNG ([`crate::testkit::Rng`]), reproducible from a single `--seed`:
//!
//! - [`TraceKind::Poisson`]: independent exponential gaps with a
//!   configurable mean — the classic memoryless arrival model;
//! - [`TraceKind::Bursty`]: the same Poisson baseline, but every
//!   `burst_every`-th arrival brings `burst_len - 1` simultaneous
//!   companions. Bursts are what exercise admission control, shedding
//!   and the graceful-degradation ladder.
//!
//! [`TraceSpec::mixed_traffic`] turns a trace into the benchmark's
//! request mix: short-prompt GPT-2 decode, long-prompt GPT-2 decode,
//! and prefill-only ViT classification, round-robin.

use super::Request;
use crate::model::{GPT2_SMALL, VIT_BASE};
use crate::testkit::{mix, Rng};

/// Domain-separation constant for the arrival-gap PRNG stream (keeps
/// trace draws independent of fault-plan draws at the same seed).
const TRACE_STREAM: u64 = 0x7214_CE00_A221_7A15;

/// The arrival-process family of a [`TraceSpec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Independent exponential inter-arrival gaps (memoryless).
    Poisson,
    /// Poisson baseline plus periodic simultaneous-arrival bursts.
    Bursty,
}

/// A seeded open-loop arrival trace: how many requests arrive, how they
/// are spaced, and the PRNG seed that makes the trace reproducible.
#[derive(Clone, Copy, Debug)]
pub struct TraceSpec {
    /// Arrival-process family.
    pub kind: TraceKind,
    /// Total requests in the trace.
    pub requests: usize,
    /// Mean inter-arrival gap in cycles (the exponential's mean).
    pub mean_gap_cycles: f64,
    /// For [`TraceKind::Bursty`]: every `burst_every`-th arrival starts
    /// a burst (ignored for Poisson).
    pub burst_every: usize,
    /// For [`TraceKind::Bursty`]: total arrivals sharing the burst's
    /// clock, including the one that started it (ignored for Poisson).
    pub burst_len: usize,
    /// PRNG seed; the whole trace is a pure function of the spec.
    pub seed: u64,
}

impl TraceSpec {
    /// A Poisson trace of `requests` arrivals with the given mean gap.
    pub fn poisson(requests: usize, mean_gap_cycles: f64, seed: u64) -> Self {
        TraceSpec {
            kind: TraceKind::Poisson,
            requests,
            mean_gap_cycles,
            burst_every: 0,
            burst_len: 0,
            seed,
        }
    }

    /// A bursty trace: Poisson gaps, but every 4th arrival brings two
    /// simultaneous companions (burst length 3).
    pub fn bursty(requests: usize, mean_gap_cycles: f64, seed: u64) -> Self {
        TraceSpec {
            kind: TraceKind::Bursty,
            requests,
            mean_gap_cycles,
            burst_every: 4,
            burst_len: 3,
            seed,
        }
    }

    /// The arrival clock of every request, in cycles, non-decreasing.
    /// Deterministic: the same spec always yields the same trace.
    pub fn arrivals(&self) -> Vec<u64> {
        let mut rng = Rng::new(mix(self.seed, TRACE_STREAM));
        let mut out = Vec::with_capacity(self.requests);
        let mut clock = 0u64;
        let mut lead = 0usize; // burst-leading arrivals drawn so far
        while out.len() < self.requests {
            clock += rng.exp(self.mean_gap_cycles).round() as u64;
            out.push(clock);
            lead += 1;
            if self.kind == TraceKind::Bursty
                && self.burst_every > 0
                && lead % self.burst_every == 0
            {
                for _ in 1..self.burst_len {
                    if out.len() >= self.requests {
                        break;
                    }
                    out.push(clock);
                }
            }
        }
        out
    }

    /// Instantiate the trace as the benchmark's mixed request stream:
    /// round-robin over short-prompt GPT-2 decode (`prompt` tokens),
    /// long-prompt GPT-2 decode (`2 * prompt`), and prefill-only
    /// ViT-Base, each stamped with its arrival clock and, if given, a
    /// deadline of `deadline_cycles` after arrival. Ids are trace-local;
    /// [`super::Engine::submit_request`] overwrites them.
    pub fn mixed_traffic(
        &self,
        prompt: u32,
        tokens: u32,
        deadline_cycles: Option<u64>,
    ) -> Vec<Request> {
        let prompt = prompt.max(8);
        let mut out = Vec::with_capacity(self.requests);
        for (i, &at) in self.arrivals().iter().enumerate() {
            let mut req = match i % 3 {
                0 => {
                    let mut cfg = GPT2_SMALL;
                    cfg.seq = prompt;
                    Request::new(i as u64, cfg).with_tokens(tokens)
                }
                1 => {
                    let mut cfg = GPT2_SMALL;
                    cfg.seq = prompt * 2;
                    Request::new(i as u64, cfg).with_tokens(tokens)
                }
                _ => {
                    let mut cfg = VIT_BASE;
                    cfg.seq = prompt.min(VIT_BASE.seq);
                    Request::new(i as u64, cfg)
                }
            };
            req = req.arriving_at_cycles(at);
            if let Some(d) = deadline_cycles {
                req = req.with_deadline(d);
            }
            out.push(req);
        }
        out
    }

    /// [`Self::mixed_traffic`] dressed for the paged KV tier (DESIGN.md
    /// §14): the same request stream (models, prompts, arrival clocks,
    /// deadlines — byte-identical apart from the added stamps), plus
    ///
    /// - a shared prompt head per GPT-2 class (half the prompt, seeded
    ///   per class from the trace seed), so same-class requests have
    ///   real whole-block prefix hits while their tails stay unique;
    /// - every `latency_every`-th request stamped
    ///   [`super::SchedPolicy::Latency`] (0 = never), so SLO attainment
    ///   under pressure is reportable per policy class.
    ///
    /// ViT requests are left unstamped: prefill-only, no KV to page.
    pub fn mixed_traffic_paged(
        &self,
        prompt: u32,
        tokens: u32,
        deadline_cycles: Option<u64>,
        latency_every: usize,
    ) -> Vec<Request> {
        let mut out = self.mixed_traffic(prompt, tokens, deadline_cycles);
        for (i, req) in out.iter_mut().enumerate() {
            match i % 3 {
                0 => *req = req.with_shared_head(mix(self.seed, 1), req.cfg.seq / 2),
                1 => *req = req.with_shared_head(mix(self.seed, 2), req.cfg.seq / 2),
                _ => {}
            }
            if latency_every > 0 && (i + 1) % latency_every == 0 {
                *req = req.with_policy(super::SchedPolicy::Latency);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_deterministic_and_seed_sensitive() {
        let a = TraceSpec::poisson(50, 10_000.0, 7).arrivals();
        let b = TraceSpec::poisson(50, 10_000.0, 7).arrivals();
        let c = TraceSpec::poisson(50, 10_000.0, 8).arrivals();
        assert_eq!(a, b, "same seed must reproduce the trace");
        assert_ne!(a, c, "different seeds must diverge");
        assert_eq!(a.len(), 50);
    }

    #[test]
    fn arrivals_are_monotone_nondecreasing() {
        for spec in [
            TraceSpec::poisson(100, 5_000.0, 3),
            TraceSpec::bursty(100, 5_000.0, 3),
        ] {
            let at = spec.arrivals();
            assert!(at.windows(2).all(|w| w[0] <= w[1]), "{:?}", spec.kind);
        }
    }

    #[test]
    fn poisson_mean_gap_tracks_the_spec() {
        let at = TraceSpec::poisson(4000, 10_000.0, 11).arrivals();
        let mean = at.last().copied().unwrap() as f64 / at.len() as f64;
        assert!(
            (8_000.0..12_000.0).contains(&mean),
            "empirical mean gap {mean} should track 10000"
        );
    }

    #[test]
    fn bursty_trace_contains_simultaneous_arrivals() {
        let at = TraceSpec::bursty(30, 50_000.0, 5).arrivals();
        let dup = at.windows(2).filter(|w| w[0] == w[1]).count();
        // every 4th lead arrival adds 2 companions at the same clock
        assert!(dup >= 8, "expected burst duplicates, got {dup}");
        // a Poisson trace at the same seed has (almost surely) none
        let p = TraceSpec::poisson(30, 50_000.0, 5).arrivals();
        let pdup = p.windows(2).filter(|w| w[0] == w[1]).count();
        assert!(pdup < dup);
    }

    #[test]
    fn mixed_traffic_round_robins_models_and_stamps_fields() {
        let spec = TraceSpec::bursty(9, 20_000.0, 2);
        let reqs = spec.mixed_traffic(64, 4, Some(1_000_000));
        let at = spec.arrivals();
        assert_eq!(reqs.len(), 9);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.arrival_cycles, at[i]);
            assert_eq!(r.deadline_cycles, Some(1_000_000));
            match i % 3 {
                0 => {
                    assert_eq!(r.cfg.name, "GPT-2 Small");
                    assert_eq!((r.cfg.seq, r.decode_tokens), (64, 4));
                }
                1 => {
                    assert_eq!(r.cfg.name, "GPT-2 Small");
                    assert_eq!((r.cfg.seq, r.decode_tokens), (128, 4));
                }
                _ => {
                    assert_eq!(r.cfg.name, "ViT-Base");
                    assert_eq!(r.decode_tokens, 0, "ViT is prefill-only");
                }
            }
        }
    }

    #[test]
    fn mixed_traffic_without_deadline_leaves_requests_open() {
        let reqs = TraceSpec::poisson(3, 1_000.0, 1).mixed_traffic(32, 2, None);
        assert!(reqs.iter().all(|r| r.deadline_cycles.is_none()));
    }

    #[test]
    fn paged_traffic_shares_heads_per_class_and_stamps_policy() {
        use crate::exec::SchedPolicy;
        let spec = TraceSpec::bursty(12, 20_000.0, 2);
        let base = spec.mixed_traffic(64, 4, None);
        let paged = spec.mixed_traffic_paged(64, 4, None, 4);
        assert_eq!(base.len(), paged.len());
        for (b, p) in base.iter().zip(&paged) {
            assert_eq!(b.arrival_cycles, p.arrival_cycles, "stream timing unchanged");
            assert_eq!((b.cfg.name, b.cfg.seq, b.decode_tokens), (p.cfg.name, p.cfg.seq, p.decode_tokens));
        }
        // each GPT-2 class shares one head seed; classes differ
        assert_eq!(paged[0].prompt_sig.head_seed, paged[3].prompt_sig.head_seed);
        assert_eq!(paged[1].prompt_sig.head_seed, paged[4].prompt_sig.head_seed);
        assert_ne!(paged[0].prompt_sig.head_seed, paged[1].prompt_sig.head_seed);
        assert_eq!(paged[0].prompt_sig.head_len, 32, "half the short prompt");
        assert_eq!(paged[1].prompt_sig.head_len, 64, "half the long prompt");
        assert_eq!(paged[2].prompt_sig.head_len, 0, "ViT stays unshared");
        // every 4th request runs latency-policy, the rest throughput
        assert_eq!(paged[3].policy, SchedPolicy::Latency);
        assert_eq!(paged[7].policy, SchedPolicy::Latency);
        assert_eq!(paged[0].policy, SchedPolicy::Throughput);
        assert!(spec
            .mixed_traffic_paged(64, 4, None, 0)
            .iter()
            .all(|r| r.policy == SchedPolicy::Throughput));
    }
}
