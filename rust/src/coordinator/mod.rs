//! Multi-cluster coordinator (paper §V-D, Fig. 7): head→cluster mapping,
//! K/V tile planning for prefill and decode, the KV-cache residency
//! model, and the end-to-end runtime/energy estimator driving the
//! Fig. 1 and Fig. 8 benches.

pub mod estimate;
pub mod paging;
pub mod schedule;

pub use estimate::{E2eEstimate, KernelRates, SystemEstimator};
pub use paging::{BlockGeometry, PagedResidency};
pub use schedule::{DecodePlan, HeadMap, KvPlacement, KvResidency, TilePlan, CLUSTERS};
