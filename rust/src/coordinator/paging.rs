//! Page-aware KV-cache placement (DESIGN.md §14): the block-granular
//! successor of the all-or-nothing [`super::KvResidency`] rule.
//!
//! The serve loop's paged KV tier slices every request's cache into
//! fixed-size **blocks** of [`BlockGeometry::block_tokens`] tokens.
//! Placement then prices *fractions* of a cache instead of the whole
//! share: the hottest suffix of blocks — the tail the decode step
//! actually appends into — is pinned in the SPM budget left after the
//! decode working set, and only the cold prefix restreams from HBM
//! every step. With a single unbounded block the model collapses to the
//! legacy rule exactly (the whole cache is one "tail block"), which is
//! what keeps the unpaged serve path usable as a differential oracle
//! for the paged one.

use super::schedule::{DecodePlan, HeadMap, KvPlacement};
use crate::kernels::flash_attention::fa_decode_footprint;
use crate::model::TransformerConfig;
use crate::sim::SPM_BYTES;

/// Geometry of the paged KV pool: a fixed block size in **bytes** of
/// whole-model K+V cache (BF16, all layers, all heads). Bytes — not
/// tokens — because the pool is shared between models whose per-token
/// cache footprints differ; each model converts the byte block into its
/// own token capacity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockGeometry {
    /// Size of one pool block in bytes.
    pub block_bytes: u64,
}

impl BlockGeometry {
    /// Geometry with the given block size (must be nonzero).
    pub fn new(block_bytes: u64) -> Self {
        assert!(block_bytes > 0, "KV block size must be nonzero");
        BlockGeometry { block_bytes }
    }

    /// Whole-model K+V bytes one token occupies: `layers × heads ×
    /// d_head × 2 (K and V) × 2 (BF16)`.
    pub fn bytes_per_token(cfg: &TransformerConfig) -> u64 {
        cfg.layers as u64 * cfg.heads as u64 * cfg.d_head() as u64 * 2 * 2
    }

    /// Tokens of `cfg`'s cache one block holds (at least 1: a block
    /// smaller than a token row still advances one token at a time).
    pub fn block_tokens(&self, cfg: &TransformerConfig) -> u32 {
        (self.block_bytes / Self::bytes_per_token(cfg)).clamp(1, u32::MAX as u64) as u32
    }

    /// Blocks needed to hold `tokens` tokens of `cfg`'s cache.
    pub fn blocks_for(&self, cfg: &TransformerConfig, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_tokens(cfg) as u64)
    }
}

/// Page-aware KV-cache placement for one request's cluster share
/// (DESIGN.md §14). Supersedes [`super::KvResidency`]'s binary verdict:
/// the cache is split into blocks of `block_tokens` tokens, the tail
/// suffix whose filled bytes fit the post-working-set SPM budget stays
/// **hot** (append-only traffic), and the cold prefix restreams from
/// HBM every decode step.
///
/// Legacy equivalence: with `block_tokens >= kv_len` there is exactly
/// one block, filled to `kv_len`; it is hot iff the whole share fits
/// the budget — the [`super::KvResidency`] rule verbatim (which now
/// delegates here).
#[derive(Clone, Copy, Debug)]
pub struct PagedResidency {
    /// Heads whose cache one cluster holds (= head rounds).
    pub heads_per_cluster: u32,
    /// Tokens per block for this model.
    pub block_tokens: u32,
    /// Blocks the cache occupies at the analyzed length.
    pub blocks: u32,
    /// Tail blocks pinned in the SPM budget.
    pub hot_blocks: u32,
    /// Tokens in the hot (SPM-pinned) suffix.
    pub hot_tokens: u32,
    /// Tokens in the cold (HBM-restreamed) prefix.
    pub cold_tokens: u32,
    /// Per-cluster cache bytes of one token (all layers, this share).
    pub bytes_per_token_per_cluster: u64,
    /// SPM bytes left after the decode slice working set.
    pub spm_budget: u64,
}

impl PagedResidency {
    /// Analyze placement for `cfg` at KV length `kv_len` on a share of
    /// `clusters` clusters with `block_tokens`-token blocks. Blocks are
    /// pinned hot from the **tail** (newest first, by *filled* bytes —
    /// a partially filled tail block only charges what it holds) while
    /// the cumulative footprint fits the SPM budget.
    pub fn analyze(
        cfg: &TransformerConfig,
        kv_len: u32,
        clusters: u32,
        block_tokens: u32,
    ) -> Self {
        let block_tokens = block_tokens.max(1);
        let d = cfg.d_head();
        let heads_per_cluster = HeadMap::new(cfg.heads, clusters.max(1)).rounds();
        let bytes_per_token_per_cluster =
            cfg.layers as u64 * heads_per_cluster as u64 * d as u64 * 2 * 2;
        let plan = DecodePlan::plan(cfg);
        let spm_budget =
            SPM_BYTES as u64 - fa_decode_footprint(plan.sk_slice, plan.d, plan.bk) as u64;
        let blocks = kv_len.div_ceil(block_tokens);
        let tail_fill = if blocks == 0 { 0 } else { kv_len - (blocks - 1) * block_tokens };
        let mut hot_blocks = 0u32;
        let mut hot_tokens = 0u32;
        let mut bytes = 0u64;
        for i in 0..blocks {
            // i-th block from the tail: the tail itself is partial,
            // every earlier block is full
            let fill = if i == 0 { tail_fill } else { block_tokens };
            bytes += fill as u64 * bytes_per_token_per_cluster;
            if bytes > spm_budget {
                break;
            }
            hot_blocks += 1;
            hot_tokens += fill;
        }
        PagedResidency {
            heads_per_cluster,
            block_tokens,
            blocks,
            hot_blocks,
            hot_tokens,
            cold_tokens: kv_len - hot_tokens,
            bytes_per_token_per_cluster,
            spm_budget,
        }
    }

    /// The legacy binary verdict this placement collapses to: resident
    /// when nothing restreams, spilled otherwise.
    pub fn placement(&self) -> KvPlacement {
        if self.cold_tokens == 0 {
            KvPlacement::SpmResident
        } else {
            KvPlacement::HbmSpill
        }
    }

    /// HBM bytes this cluster streams per decode step for KV traffic,
    /// over all layers: the cold prefix restreams in full; the appended
    /// K/V rows stream once when the tail block is hot (when it is
    /// cold, the append is part of the restream — matching the legacy
    /// spill pricing, which charges the whole share and nothing more).
    pub fn hbm_bytes_per_step(&self, cfg: &TransformerConfig) -> u64 {
        let append = cfg.layers as u64
            * self.heads_per_cluster as u64
            * 2
            * 2
            * cfg.d_head() as u64;
        if self.cold_tokens == 0 {
            append
        } else {
            let restream = self.cold_tokens as u64 * self.bytes_per_token_per_cluster;
            if self.hot_blocks > 0 {
                restream + append
            } else {
                restream
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::KvResidency;
    use crate::model::{GPT2_SMALL, GPT3_XL};
    use crate::testkit::{forall, Rng};

    #[test]
    fn block_tokens_follow_the_model_footprint() {
        let geom = BlockGeometry::new(256 * 1024);
        // GPT-2 Small: 12 layers x 12 heads x 64 d x 4 B = 36864 B/token
        assert_eq!(BlockGeometry::bytes_per_token(&GPT2_SMALL), 36_864);
        assert_eq!(geom.block_tokens(&GPT2_SMALL), 7);
        assert_eq!(geom.blocks_for(&GPT2_SMALL, 64), 10);
        // a block smaller than one token row still holds one token
        assert_eq!(BlockGeometry::new(16).block_tokens(&GPT3_XL), 1);
    }

    #[test]
    fn giant_block_reduces_to_the_legacy_residency_rule() {
        forall(200, |rng: &mut Rng| {
            let cfg = if rng.range(0, 2) == 0 { GPT2_SMALL } else { GPT3_XL };
            let kv_len = rng.range(1, 4097) as u32;
            let clusters = rng.range(1, 17) as u32;
            let legacy = KvResidency::analyze(&cfg, kv_len, clusters);
            let paged = PagedResidency::analyze(&cfg, kv_len, clusters, kv_len);
            if paged.placement() != legacy.placement {
                return Err(format!(
                    "placement diverged at kv={kv_len} cl={clusters}: {:?} vs {:?}",
                    paged.placement(),
                    legacy.placement
                ));
            }
            let (a, b) = (paged.hbm_bytes_per_step(&cfg), legacy.hbm_bytes_per_step(&cfg));
            if a != b {
                return Err(format!("bytes diverged at kv={kv_len} cl={clusters}: {a} vs {b}"));
            }
            Ok(())
        });
    }

    #[test]
    fn paged_placement_pins_a_hot_tail_between_the_extremes() {
        // 16-way GPT-2 at 128 tokens: the whole share (384 KiB) spills
        // under the legacy rule, but 16-token blocks keep a hot tail
        let paged = PagedResidency::analyze(&GPT2_SMALL, 128, 16, 16);
        assert!(paged.hot_blocks > 0, "a tail block must fit the budget");
        assert!(paged.cold_tokens > 0, "the full share must not fit");
        assert_eq!(paged.hot_tokens + paged.cold_tokens, 128);
        assert_eq!(paged.blocks, 8);
        // pricing sits strictly between pure-append and full-restream
        let bytes = paged.hbm_bytes_per_step(&GPT2_SMALL);
        let legacy = KvResidency::analyze(&GPT2_SMALL, 128, 16);
        let append = 12 * 1 * 4 * 64;
        assert!(bytes > append);
        assert!(bytes < legacy.hbm_bytes_per_step(&GPT2_SMALL));
    }

    #[test]
    fn hot_tokens_never_exceed_the_budget_and_partial_tails_charge_fill() {
        forall(200, |rng: &mut Rng| {
            let kv_len = rng.range(1, 2049) as u32;
            let clusters = rng.range(1, 17) as u32;
            let bt = rng.range(1, 257) as u32;
            let p = PagedResidency::analyze(&GPT2_SMALL, kv_len, clusters, bt);
            if p.hot_tokens + p.cold_tokens != kv_len {
                return Err("token split must cover the cache".into());
            }
            if p.hot_tokens as u64 * p.bytes_per_token_per_cluster > p.spm_budget {
                return Err(format!(
                    "hot set {} tokens overflows the budget {}",
                    p.hot_tokens, p.spm_budget
                ));
            }
            if p.blocks != kv_len.div_ceil(bt.max(1)) {
                return Err("block count mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn smaller_blocks_never_lose_hot_tokens() {
        // halving the block size can only refine the hot boundary:
        // the pinned tail never shrinks when blocks get finer
        let coarse = PagedResidency::analyze(&GPT2_SMALL, 512, 16, 64);
        let fine = PagedResidency::analyze(&GPT2_SMALL, 512, 16, 8);
        assert!(fine.hot_tokens >= coarse.hot_tokens);
        assert!(
            fine.hbm_bytes_per_step(&GPT2_SMALL) <= coarse.hbm_bytes_per_step(&GPT2_SMALL)
        );
    }
}
