//! End-to-end runtime & energy estimation for the 16-cluster system.
//!
//! Kernel rates are **measured on the simulator** (not assumed): small
//! calibration runs of the GEMM and softmax kernels yield cycles/flop,
//! cycles/softmax-element and pJ figures; the estimator then scales them
//! over the per-layer operation counts of `model::WorkloadOps`, with
//! head→cluster scheduling, double-buffered DMA and HBM contention.
//!
//! The estimator is the rate model behind
//! [`crate::exec::AnalyticBackend`]; benches and the CLI reach it
//! through the unified `Backend` API rather than directly.

use super::schedule::{HeadMap, TilePlan, CLUSTERS};
use crate::energy::power::{cluster_energy_pj, DMA_PJ_PER_BYTE};
use crate::kernels::gelu::{run_gelu, GeluForm, GeluVariant};
use crate::kernels::gemm::run_gemm;
use crate::kernels::layernorm::{run_layernorm, LayerNormVariant};
use crate::kernels::softmax::{run_softmax, SoftmaxVariant};
use crate::model::{TransformerConfig, WorkloadOps};
use crate::sim::{DmaModel, HbmModel};

/// Measured per-kernel rates (cluster scope).
#[derive(Clone, Copy, Debug)]
pub struct KernelRates {
    /// Cluster cycles per GEMM FLOP, optimized [5]-style kernel.
    pub gemm_cyc_per_flop: f64,
    /// Cluster cycles per GEMM FLOP, plain scalar code (Fig. 1 left bars).
    pub gemm_unopt_cyc_per_flop: f64,
    /// Cluster cycles per softmax element, baseline variant.
    pub softmax_base_cyc: f64,
    /// Cluster cycles per softmax element, VFEXP-optimized variant.
    pub softmax_opt_cyc: f64,
    /// Cluster energy per GEMM FLOP (pJ).
    pub gemm_pj_per_flop: f64,
    /// Cluster energy per softmax element (pJ), baseline variant.
    pub softmax_base_pj: f64,
    /// Cluster energy per softmax element (pJ), optimized variant.
    pub softmax_opt_pj: f64,
    /// Cluster cycles per GELU element, scalar software variant.
    pub gelu_base_cyc: f64,
    /// Cluster cycles per GELU element, VFEXP+SIMD variant.
    pub gelu_opt_cyc: f64,
    /// Cluster cycles per LayerNorm element, scalar baseline.
    pub ln_base_cyc: f64,
    /// Cluster cycles per LayerNorm element, FREP+SSR+SIMD variant.
    pub ln_opt_cyc: f64,
    /// Cluster energy per GELU element (pJ), scalar software variant.
    pub gelu_base_pj: f64,
    /// Cluster energy per GELU element (pJ), VFEXP+SIMD variant.
    pub gelu_opt_pj: f64,
    /// Cluster energy per LayerNorm element (pJ), scalar baseline.
    pub ln_base_pj: f64,
    /// Cluster energy per LayerNorm element (pJ), optimized variant.
    pub ln_opt_pj: f64,
}

impl KernelRates {
    /// Run the calibration micro-benchmarks on the simulator.
    pub fn calibrate() -> Self {
        // -- optimized GEMM: 64x64x64 tile on one cluster ----------------
        let m = 64u32;
        let a: Vec<f32> = (0..m * m).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
        let g = run_gemm(&a, &a, m, m, m);
        let gemm_cyc_per_flop = g.stats.cycles as f64 / g.flops as f64;
        let gemm_pj_per_flop = cluster_energy_pj(&g.stats, true).total() / g.flops as f64;

        // -- scalar GEMM estimate: the same dot products without
        //    FREP/SSR/SIMD pay the scalar-issue cost per MAC. From the
        //    core model: flh+flh+fmadd+2×addi+bnez ≈ 2 MACs... measured
        //    "unoptimized GEMM" in Fig. 1 means the pre-[5] tiling/layout
        //    (still SIMD), measured there at ~3x the optimized kernel.
        let gemm_unopt_cyc_per_flop = gemm_cyc_per_flop * 3.0;

        // -- softmax variants: 8 rows x 512 on one cluster ----------------
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|r| (0..512).map(|i| ((i * 7 + r * 31) % 97) as f32 * 0.15 - 7.0).collect())
            .collect();
        let base = run_softmax(SoftmaxVariant::Baseline, &rows);
        let opt = run_softmax(SoftmaxVariant::SwExpHw, &rows);
        let n = (8 * 512) as f64;

        // -- nonlinearities: same 8 rows x 512 shape ----------------------
        let acts: Vec<Vec<f32>> = (0..8)
            .map(|r| (0..512).map(|i| ((i * 11 + r * 17) % 89) as f32 * 0.09 - 4.0).collect())
            .collect();
        let gelu_base = run_gelu(GeluVariant::Sw(GeluForm::Tanh), &acts);
        let gelu_opt = run_gelu(GeluVariant::Hw(GeluForm::Tanh), &acts);
        let ln_base = run_layernorm(LayerNormVariant::Baseline, &acts);
        let ln_opt = run_layernorm(LayerNormVariant::Optimized, &acts);

        KernelRates {
            gemm_cyc_per_flop,
            gemm_unopt_cyc_per_flop,
            softmax_base_cyc: base.stats.cycles as f64 / n * 8.0 / 8.0,
            softmax_opt_cyc: opt.stats.cycles as f64 / n,
            gemm_pj_per_flop,
            softmax_base_pj: cluster_energy_pj(&base.stats, false).total() / n,
            softmax_opt_pj: cluster_energy_pj(&opt.stats, true).total() / n,
            gelu_base_cyc: gelu_base.stats.cycles as f64 / n,
            gelu_opt_cyc: gelu_opt.stats.cycles as f64 / n,
            ln_base_cyc: ln_base.stats.cycles as f64 / n,
            ln_opt_cyc: ln_opt.stats.cycles as f64 / n,
            gelu_base_pj: cluster_energy_pj(&gelu_base.stats, false).total() / n,
            gelu_opt_pj: cluster_energy_pj(&gelu_opt.stats, true).total() / n,
            ln_base_pj: cluster_energy_pj(&ln_base.stats, false).total() / n,
            ln_opt_pj: cluster_energy_pj(&ln_opt.stats, true).total() / n,
        }
    }
}

/// End-to-end estimate for one model configuration.
#[derive(Clone, Copy, Debug)]
pub struct E2eEstimate {
    /// Total cycles of the estimated pass.
    pub cycles: f64,
    /// Total energy in pJ.
    pub energy_pj: f64,
    /// Cycles attributed to softmax work.
    pub softmax_cycles: f64,
    /// Cycles attributed to GEMM work.
    pub gemm_cycles: f64,
    /// Attention-kernel cycles (QK^T + partial softmax + P·V) — the
    /// FlashAttention-2 scope the cycle-sim backend cross-checks.
    pub attn_cycles: f64,
    /// Cycles attributed to DMA streaming.
    pub dma_cycles: f64,
    /// Cycles attributed to the GELU + LayerNorm nonlinearities.
    pub nonlin_cycles: f64,
}

impl E2eEstimate {
    /// Latency in milliseconds at the 1 GHz cluster clock.
    pub fn latency_ms(&self) -> f64 {
        self.cycles / 1e6
    }

    /// Energy in millijoules.
    pub fn energy_mj(&self) -> f64 {
        self.energy_pj / 1e9
    }

    /// Fraction of cycles spent in softmax.
    pub fn softmax_share(&self) -> f64 {
        self.softmax_cycles / self.cycles
    }
}

/// The 16-cluster Occamy-style estimator.
pub struct SystemEstimator {
    /// Calibrated kernel rates.
    pub rates: KernelRates,
    /// Clusters in the target system.
    pub clusters: usize,
    /// Per-cluster DMA timing model.
    pub dma: DmaModel,
    /// Shared HBM bandwidth model.
    pub hbm: HbmModel,
}

impl SystemEstimator {
    /// Estimator for the paper's 16-cluster system at the given rates.
    pub fn new(rates: KernelRates) -> Self {
        SystemEstimator {
            rates,
            clusters: CLUSTERS,
            dma: DmaModel::default(),
            hbm: HbmModel::default(),
        }
    }

    /// Estimate one full non-autoregressive forward pass.
    ///
    /// `softmax_optimized`: VFEXP softmax vs baseline softmax;
    /// `gemm_optimized`: [5]-style GEMM vs plain scalar GEMM (Fig. 1).
    pub fn estimate(
        &self,
        cfg: &TransformerConfig,
        softmax_optimized: bool,
        gemm_optimized: bool,
    ) -> E2eEstimate {
        self.estimate_ops(cfg, &WorkloadOps::of(cfg), softmax_optimized, gemm_optimized)
    }

    /// Rate an explicit workload (any inference phase) with the same
    /// head-mapping / double-buffered-DMA composition as
    /// [`SystemEstimator::estimate`]. The decode phase flows through
    /// here with its GEMV-shaped counts, where the `max(compute, dma)`
    /// term exposes the bandwidth-bound regime.
    pub fn estimate_ops(
        &self,
        cfg: &TransformerConfig,
        ops: &WorkloadOps,
        softmax_optimized: bool,
        gemm_optimized: bool,
    ) -> E2eEstimate {
        let l = ops.per_layer;
        let r = &self.rates;
        let gemm_rate = if gemm_optimized { r.gemm_cyc_per_flop } else { r.gemm_unopt_cyc_per_flop };
        let (sm_cyc, sm_pj) = if softmax_optimized {
            (r.softmax_opt_cyc, r.softmax_opt_pj)
        } else {
            (r.softmax_base_cyc, r.softmax_base_pj)
        };
        // the nonlinearities ride the same FREP/SSR/SIMD (+VFEXP for
        // GELU) optimization axis as softmax
        let (gelu_cyc, gelu_pj, ln_cyc, ln_pj) = if softmax_optimized {
            (r.gelu_opt_cyc, r.gelu_opt_pj, r.ln_opt_cyc, r.ln_opt_pj)
        } else {
            (r.gelu_base_cyc, r.gelu_base_pj, r.ln_base_cyc, r.ln_base_pj)
        };

        // projections: all clusters cooperate
        let proj_cycles = l.proj_flops as f64 * gemm_rate / self.clusters as f64;

        // attention: one head per cluster, ceil(H/C) sequential rounds
        let map = HeadMap::new(cfg.heads, self.clusters as u32);
        let per_head_flops = l.attn_flops as f64 / cfg.heads as f64;
        let per_head_sm = l.softmax_elems as f64 / cfg.heads as f64;
        let head_gemm = per_head_flops * gemm_rate;
        let head_sm = per_head_sm * sm_cyc;
        let attn_cycles = map.rounds() as f64 * (head_gemm + head_sm);
        let softmax_cycles = map.rounds() as f64 * head_sm;

        // nonlinearities: element-parallel, all clusters cooperate
        let nonlin_cycles = (l.gelu_elems as f64 * gelu_cyc + l.layernorm_elems as f64 * ln_cyc)
            / self.clusters as f64;

        // DMA: weights + activations streamed per layer, double-buffered
        // against compute; HBM contention when all clusters stream
        let contention = self.hbm.contention_factor(self.clusters, self.dma.bytes_per_cycle);
        let bytes = (l.weight_bytes + l.act_bytes) as f64;
        let dma_cycles = self.dma.cycles((bytes / self.clusters as f64) as u64) as f64 * contention;
        let compute = proj_cycles + attn_cycles + nonlin_cycles;
        let layer_cycles = compute.max(dma_cycles) + dma_cycles.min(compute) * 0.05;

        let layers = ops.layers as f64;
        let gemm_cycles = (proj_cycles + attn_cycles - softmax_cycles) * layers;
        let total_cycles = layer_cycles * layers;

        // energy
        let gemm_pj = if gemm_optimized { r.gemm_pj_per_flop } else { r.gemm_pj_per_flop * 4.0 };
        let energy = layers
            * (l.total_flops() as f64 * gemm_pj
                + l.softmax_elems as f64 * sm_pj
                + l.gelu_elems as f64 * gelu_pj
                + l.layernorm_elems as f64 * ln_pj
                + bytes * DMA_PJ_PER_BYTE);

        E2eEstimate {
            cycles: total_cycles,
            energy_pj: energy,
            softmax_cycles: softmax_cycles * layers,
            gemm_cycles,
            attn_cycles: attn_cycles * layers,
            dma_cycles: dma_cycles * layers,
            nonlin_cycles: nonlin_cycles * layers,
        }
    }

    /// Convenience: the Fig. 8 pair (baseline vs softmax-optimized).
    pub fn fig8_pair(&self, cfg: &TransformerConfig) -> (E2eEstimate, E2eEstimate) {
        (self.estimate(cfg, false, true), self.estimate(cfg, true, true))
    }

    /// FlashAttention tile plan sanity (exposed for the e2e example).
    pub fn tile_plan(&self, cfg: &TransformerConfig) -> TilePlan {
        TilePlan::plan(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};

    fn rates() -> KernelRates {
        KernelRates::calibrate()
    }

    #[test]
    fn calibration_is_sane() {
        let r = rates();
        assert!(r.gemm_cyc_per_flop < 0.06, "gemm {0} cyc/flop", r.gemm_cyc_per_flop);
        assert!(r.softmax_base_cyc / r.softmax_opt_cyc > 50.0);
        assert!(r.softmax_base_pj / r.softmax_opt_pj > 20.0);
    }

    #[test]
    fn nonlinearities_are_priced() {
        let r = rates();
        assert!(r.gelu_base_cyc / r.gelu_opt_cyc > 4.0, "gelu {} / {}", r.gelu_base_cyc, r.gelu_opt_cyc);
        assert!(r.ln_base_cyc / r.ln_opt_cyc > 3.0, "ln {} / {}", r.ln_base_cyc, r.ln_opt_cyc);
        let est = SystemEstimator::new(r);
        let e = est.estimate(&GPT2_SMALL, true, true);
        assert!(e.nonlin_cycles > 0.0);
        // the nonlinearities are real but must never dominate a forward
        // pass — the GEMMs do
        assert!(e.nonlin_cycles < 0.5 * e.cycles, "nonlin share {}", e.nonlin_cycles / e.cycles);
    }

    #[test]
    fn fig8_speedups_match_paper_ordering() {
        let est = SystemEstimator::new(rates());
        // paper Fig. 8: GPT-2 5.8x, GPT-3 2.9x, ViT-B 1.9x, ViT-H 1.4x
        let mut speedups = vec![];
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE] {
            let (base, opt) = est.fig8_pair(&cfg);
            speedups.push(base.cycles / opt.cycles);
        }
        // GPT-2 benefits most; ViT-H least — the paper's ordering
        assert!(speedups[0] > speedups[1], "{speedups:?}");
        assert!(speedups[1] > speedups[2] || speedups[1] > speedups[3], "{speedups:?}");
        assert!(speedups[3] < speedups[0], "{speedups:?}");
        assert!(speedups[0] > 2.5 && speedups[0] < 12.0, "GPT-2 speedup {}", speedups[0]);
        assert!(speedups[3] > 1.0 && speedups[3] < 3.0, "ViT-H speedup {}", speedups[3]);
    }

    #[test]
    fn fig1_softmax_share_grows_then_shrinks() {
        // Fig. 1: softmax ~30% with unoptimized GEMM, ~70% with optimized
        // GEMM at S=2048; optimizing softmax removes the bottleneck.
        let est = SystemEstimator::new(rates());
        let unopt = est.estimate(&GPT3_XL, false, false);
        let opt_gemm = est.estimate(&GPT3_XL, false, true);
        let all_opt = est.estimate(&GPT3_XL, true, true);
        assert!(
            opt_gemm.softmax_share() > unopt.softmax_share(),
            "GEMM acceleration must raise the softmax share: {} vs {}",
            opt_gemm.softmax_share(),
            unopt.softmax_share()
        );
        assert!(
            opt_gemm.softmax_share() > 1.5 * unopt.softmax_share(),
            "share growth {} -> {}",
            unopt.softmax_share(),
            opt_gemm.softmax_share()
        );
        assert!(opt_gemm.softmax_share() > 0.2, "share {}", opt_gemm.softmax_share());
        assert!(all_opt.softmax_share() < 0.1, "share {}", all_opt.softmax_share());
    }

    #[test]
    fn decode_dma_share_dwarfs_prefill_dma_share() {
        // The decode phase streams the full weight set for one token of
        // compute: its DMA share must sit far above prefill's.
        let est = SystemEstimator::new(rates());
        let pre = est.estimate_ops(
            &GPT2_SMALL,
            &WorkloadOps::prefill(&GPT2_SMALL, 2048),
            true,
            true,
        );
        let dec = est.estimate_ops(
            &GPT2_SMALL,
            &WorkloadOps::decode(&GPT2_SMALL, 2048),
            true,
            true,
        );
        let pre_share = pre.dma_cycles / pre.cycles;
        let dec_share = dec.dma_cycles / dec.cycles;
        assert!(
            dec_share > 10.0 * pre_share,
            "decode DMA share {dec_share:.4} vs prefill {pre_share:.4}"
        );
        // and a decode step is orders of magnitude cheaper than prefill
        assert!(dec.cycles * 100.0 < pre.cycles);
    }

    #[test]
    fn energy_reductions_match_fig8_ordering() {
        let est = SystemEstimator::new(rates());
        let (b_gpt2, o_gpt2) = est.fig8_pair(&GPT2_SMALL);
        let (b_vith, o_vith) = est.fig8_pair(&VIT_HUGE);
        let e_gpt2 = b_gpt2.energy_pj / o_gpt2.energy_pj;
        let e_vith = b_vith.energy_pj / o_vith.energy_pj;
        // paper: 3.6x for GPT-2, 1.2x for ViT-H
        assert!(e_gpt2 > e_vith, "{e_gpt2} vs {e_vith}");
        assert!(e_gpt2 > 1.8 && e_gpt2 < 8.0, "GPT-2 energy ratio {e_gpt2}");
    }
}
