//! Scheduling primitives: head→cluster assignment and SPM tile planning.

use crate::model::TransformerConfig;
use crate::sim::SPM_BYTES;

/// Compute clusters in the evaluated Occamy-style system (paper §V-D).
pub const CLUSTERS: usize = 16;

/// Assignment of attention heads to clusters, in rounds: the paper maps
/// one head per cluster; with H heads and C clusters the schedule takes
/// ceil(H/C) rounds per layer.
#[derive(Clone, Debug)]
pub struct HeadMap {
    pub heads: u32,
    pub clusters: u32,
}

impl HeadMap {
    pub fn new(heads: u32, clusters: u32) -> Self {
        assert!(heads > 0 && clusters > 0);
        HeadMap { heads, clusters }
    }

    /// Cluster index executing head `h`.
    pub fn cluster_of(&self, h: u32) -> u32 {
        assert!(h < self.heads);
        h % self.clusters
    }

    /// Round (sequential wave) in which head `h` executes.
    pub fn round_of(&self, h: u32) -> u32 {
        h / self.clusters
    }

    pub fn rounds(&self) -> u32 {
        self.heads.div_ceil(self.clusters)
    }

    /// Heads assigned to a given cluster.
    pub fn heads_of(&self, cluster: u32) -> Vec<u32> {
        (0..self.heads).filter(|h| h % self.clusters == cluster).collect()
    }
}

/// K/V tile plan for FlashAttention-2 on one cluster: picks the largest
/// power-of-two tile length that fits the double-buffered working set in
/// the 128 KiB SPM (paper §III-C: "tile size optimized based on SPM
/// capacity under double buffering constraints").
#[derive(Clone, Copy, Debug)]
pub struct TilePlan {
    pub sq: u32,
    pub sk: u32,
    pub d: u32,
    pub bq: u32,
    pub bk: u32,
}

impl TilePlan {
    pub fn plan(cfg: &TransformerConfig) -> Self {
        let d = cfg.d_head();
        let sq = cfg.seq;
        let sk = cfg.seq;
        // Q block of bq rows stays resident; K/V tiles double-buffered.
        let mut bq = 64u32.min(sq);
        let mut bk = 64u32;
        while Self::working_set(bq, bk, d) > SPM_BYTES as u32 && bq > 16 {
            bq /= 2;
        }
        // bq bottomed out at 16: shrink the K/V tile below 64 before
        // giving up (large head dimensions need it)
        while Self::working_set(bq, bk, d) > SPM_BYTES as u32 && bk > 16 {
            bk /= 2;
        }
        assert!(
            Self::working_set(bq, bk, d) <= SPM_BYTES as u32,
            "TilePlan: FA-2 working set for d_head={d} exceeds the {SPM_BYTES}-byte SPM \
             even at bq={bq}, bk={bk}; this head dimension cannot be tiled on one cluster",
        );
        while Self::working_set(bq, bk * 2, d) <= SPM_BYTES as u32 && bk * 2 <= sk {
            bk *= 2;
        }
        TilePlan { sq, sk, d, bq, bk }
    }

    /// Bytes resident in SPM: Q block, 2×(K tile + V tile) for double
    /// buffering, S/P tile, O accumulator, statistics.
    pub fn working_set(bq: u32, bk: u32, d: u32) -> u32 {
        let q = 2 * bq * d;
        let kv = 2 * 2 * (2 * bk * d); // double-buffered K and V tiles
        let s = 2 * bq * bk;
        let o = 2 * bq * d + 2 * bq * d; // O + T
        let stats = 3 * 2 * bq;
        q + kv + s + o + stats + 0x1400 // + constant pool / scratch
    }

    pub fn tiles(&self) -> u32 {
        self.sk.div_ceil(self.bk)
    }

    /// Bytes DMA'd per K/V tile (K tile + V tile, BF16).
    pub fn tile_bytes(&self) -> u64 {
        2 * (2 * self.bk as u64 * self.d as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, GPT3_XL, VIT_BASE};
    use crate::testkit::{forall, Rng};

    #[test]
    fn every_head_assigned_exactly_once() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            let mut seen = vec![0u32; heads as usize];
            for c in 0..clusters {
                for h in map.heads_of(c) {
                    seen[h as usize] += 1;
                    if map.cluster_of(h) != c {
                        return Err(format!("head {h} maps to wrong cluster"));
                    }
                }
            }
            if seen.iter().any(|&n| n != 1) {
                return Err(format!("assignment counts {seen:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn load_is_balanced_within_one() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            let loads: Vec<usize> = (0..clusters).map(|c| map.heads_of(c).len()).collect();
            let (lo, hi) = (*loads.iter().min().unwrap(), *loads.iter().max().unwrap());
            if hi - lo > 1 {
                return Err(format!("imbalanced loads {loads:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rounds_bound_head_waves() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            for h in 0..heads {
                if map.round_of(h) >= map.rounds() {
                    return Err(format!("head {h} beyond round count"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tile_plans_fit_spm() {
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE] {
            let plan = TilePlan::plan(&cfg);
            assert!(
                TilePlan::working_set(plan.bq, plan.bk, plan.d) <= SPM_BYTES as u32,
                "{}: working set exceeds SPM",
                cfg.name
            );
            assert!(plan.bk >= 16 && plan.bk <= plan.sk);
            assert_eq!(plan.tiles() * plan.bk >= plan.sk, true);
        }
    }

    #[test]
    fn bigger_head_dim_means_smaller_tiles() {
        let p_small = TilePlan::plan(&GPT2_SMALL); // d_head 64
        let p_big = TilePlan::plan(&GPT3_XL); // d_head 128
        assert!(p_big.bk <= p_small.bk);
    }

    #[test]
    fn over_budget_plan_shrinks_bk_instead_of_lying() {
        // d_head 256: at bq=16 a bk=64 double-buffered working set is
        // ~158 KiB — the seed planner returned it anyway. The fix must
        // shrink bk until the plan actually fits.
        let cfg = TransformerConfig {
            name: "wide-head",
            layers: 1,
            d_model: 2048,
            heads: 8,
            d_ff: 2048,
            seq: 2048,
        };
        let plan = TilePlan::plan(&cfg);
        assert!(
            TilePlan::working_set(plan.bq, plan.bk, plan.d) <= SPM_BYTES as u32,
            "plan must fit the SPM"
        );
        assert!(plan.bk < 64, "bk must shrink below 64, got {}", plan.bk);
        assert!(plan.bk >= 16);
    }

    #[test]
    #[should_panic(expected = "exceeds the")]
    fn untileable_head_dim_panics_with_clear_message() {
        let cfg = TransformerConfig {
            name: "impossible",
            layers: 1,
            d_model: 8192,
            heads: 4, // d_head 2048: K/V tiles cannot fit even at bk=16
            d_ff: 8192,
            seq: 2048,
        };
        TilePlan::plan(&cfg);
    }
}
