//! Scheduling primitives: head→cluster assignment, SPM tile planning
//! for both inference phases, and the KV-cache residency model.

use crate::kernels::flash_attention::fa_decode_footprint;
use crate::model::TransformerConfig;
use crate::sim::SPM_BYTES;

/// Compute clusters in the evaluated Occamy-style system (paper §V-D).
pub const CLUSTERS: usize = 16;

/// Assignment of attention heads to clusters, in rounds: the paper maps
/// one head per cluster; with H heads and C clusters the schedule takes
/// ceil(H/C) rounds per layer.
#[derive(Clone, Debug)]
pub struct HeadMap {
    /// Attention heads per layer.
    pub heads: u32,
    /// Clusters available to the request.
    pub clusters: u32,
}

impl HeadMap {
    /// Map `heads` attention heads onto `clusters` clusters.
    pub fn new(heads: u32, clusters: u32) -> Self {
        assert!(heads > 0 && clusters > 0);
        HeadMap { heads, clusters }
    }

    /// Cluster index executing head `h`.
    pub fn cluster_of(&self, h: u32) -> u32 {
        assert!(h < self.heads);
        h % self.clusters
    }

    /// Round (sequential wave) in which head `h` executes.
    pub fn round_of(&self, h: u32) -> u32 {
        h / self.clusters
    }

    /// Sequential head waves per layer (`ceil(heads / clusters)`).
    pub fn rounds(&self) -> u32 {
        self.heads.div_ceil(self.clusters)
    }

    /// Heads assigned to a given cluster.
    pub fn heads_of(&self, cluster: u32) -> Vec<u32> {
        (0..self.heads).filter(|h| h % self.clusters == cluster).collect()
    }
}

/// K/V tile plan for FlashAttention-2 on one cluster: picks the largest
/// power-of-two tile length that fits the double-buffered working set in
/// the 128 KiB SPM (paper §III-C: "tile size optimized based on SPM
/// capacity under double buffering constraints").
#[derive(Clone, Copy, Debug)]
pub struct TilePlan {
    /// Query sequence length (rows of the head).
    pub sq: u32,
    /// Key/value sequence length (columns of the head).
    pub sk: u32,
    /// Head dimension.
    pub d: u32,
    /// Resident query-block rows.
    pub bq: u32,
    /// K/V tile length (double-buffered pairs stream through SPM).
    pub bk: u32,
}

impl TilePlan {
    /// Plan the prefill head tiling for a model configuration.
    pub fn plan(cfg: &TransformerConfig) -> Self {
        let d = cfg.d_head();
        let sq = cfg.seq;
        let sk = cfg.seq;
        // Q block of bq rows stays resident; K/V tiles double-buffered.
        let mut bq = 64u32.min(sq);
        let mut bk = 64u32;
        while Self::working_set(bq, bk, d) > SPM_BYTES as u32 && bq > 16 {
            bq /= 2;
        }
        // bq bottomed out at 16: shrink the K/V tile below 64 before
        // giving up (large head dimensions need it)
        while Self::working_set(bq, bk, d) > SPM_BYTES as u32 && bk > 16 {
            bk /= 2;
        }
        assert!(
            Self::working_set(bq, bk, d) <= SPM_BYTES as u32,
            "TilePlan: FA-2 working set for d_head={d} exceeds the {SPM_BYTES}-byte SPM \
             even at bq={bq}, bk={bk}; this head dimension cannot be tiled on one cluster",
        );
        while Self::working_set(bq, bk * 2, d) <= SPM_BYTES as u32 && bk * 2 <= sk {
            bk *= 2;
        }
        TilePlan { sq, sk, d, bq, bk }
    }

    /// Bytes resident in SPM: Q block, 2×(K tile + V tile) for double
    /// buffering, S/P tile, O accumulator, statistics.
    pub fn working_set(bq: u32, bk: u32, d: u32) -> u32 {
        let q = 2 * bq * d;
        let kv = 2 * 2 * (2 * bk * d); // double-buffered K and V tiles
        let s = 2 * bq * bk;
        let o = 2 * bq * d + 2 * bq * d; // O + T
        let stats = 3 * 2 * bq;
        q + kv + s + o + stats + 0x1400 // + constant pool / scratch
    }

    /// Number of K/V tiles per head pass.
    pub fn tiles(&self) -> u32 {
        self.sk.div_ceil(self.bk)
    }

    /// Bytes DMA'd per K/V tile (K tile + V tile, BF16).
    pub fn tile_bytes(&self) -> u64 {
        2 * (2 * self.bk as u64 * self.d as u64)
    }
}

/// Tile plan for the single-query decode slice (DESIGN.md §10): the KV
/// window one cluster processes per cached-program run.
///
/// The slice shape is a function of the model's head dimension only —
/// *not* of the current KV-cache length — so a request's decode program
/// is compiled once and a growing cache merely scales how many times
/// the slice repeats per token ([`DecodePlan::kv_tile_factor`]).
#[derive(Clone, Copy, Debug)]
pub struct DecodePlan {
    /// Head dimension.
    pub d: u32,
    /// K/V tile length inside the slice (one tile per core wave).
    pub bk: u32,
    /// KV positions covered by one slice run.
    pub sk_slice: u32,
    /// Tiles per slice (`sk_slice / bk`), split across the eight cores.
    pub tiles: u32,
}

impl DecodePlan {
    /// Plan the decode slice for a model: start from two tiles per core
    /// (the double-buffered pair each core streams) and halve the window
    /// until the split-KV working set fits the SPM.
    pub fn plan(cfg: &TransformerConfig) -> Self {
        let d = cfg.d_head();
        let bk = 16u32;
        let mut tiles = 16u32;
        while tiles > 1 && fa_decode_footprint(tiles * bk, d, bk) > SPM_BYTES as u32 {
            tiles /= 2;
        }
        assert!(
            fa_decode_footprint(tiles * bk, d, bk) <= SPM_BYTES as u32,
            "DecodePlan: decode slice for d_head={d} exceeds the {SPM_BYTES}-byte SPM \
             even at a single {bk}-long tile",
        );
        DecodePlan { d, bk, sk_slice: tiles * bk, tiles }
    }

    /// Slice repetitions needed to cover a KV-cache of length `kv_len`.
    pub fn kv_tile_factor(&self, kv_len: u32) -> u32 {
        kv_len.max(1).div_ceil(self.sk_slice)
    }

    /// HBM bytes of K plus V covered by one slice run (BF16).
    pub fn slice_kv_bytes(&self) -> u64 {
        2 * 2 * self.sk_slice as u64 * self.d as u64
    }
}

/// Where a request's KV-cache lives between decode steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvPlacement {
    /// The cluster's share of the cache fits in SPM alongside the
    /// decode working set: only the newly appended K/V row streams per
    /// token.
    SpmResident,
    /// The cache spilled to HBM: the cluster restreams its whole share
    /// every decode step (the bandwidth-bound regime).
    HbmSpill,
}

/// KV-cache residency decision for one request on its cluster share
/// (DESIGN.md §10). A cluster serves `ceil(heads/clusters)` heads and
/// must hold K and V (BF16) of length `kv_len` for each of them **in
/// every layer** to avoid restreaming between decode steps — each
/// layer's cache is distinct, so the whole-model share is what
/// competes for the SPM budget.
///
/// Superseded by the block-granular [`super::PagedResidency`]
/// (DESIGN.md §14): this rule is its single-unbounded-block special
/// case, and [`KvResidency::analyze`] now delegates there. It is kept
/// as the legacy pricing of the unpaged serve path, which doubles as
/// the differential oracle for the paged one.
#[derive(Clone, Copy, Debug)]
pub struct KvResidency {
    /// Heads whose cache one cluster holds (= head rounds).
    pub heads_per_cluster: u32,
    /// Bytes of K+V cache per cluster at the analyzed length, summed
    /// over all layers.
    pub kv_bytes_per_cluster: u64,
    /// SPM bytes left after the decode slice working set.
    pub spm_budget: u64,
    /// The placement verdict.
    pub placement: KvPlacement,
}

impl KvResidency {
    /// Analyze residency for `cfg` at KV length `kv_len` on a share of
    /// `clusters` clusters: the single-unbounded-block case of the
    /// page-aware rule — the whole cache is one tail block, hot iff the
    /// full share fits the post-working-set SPM budget.
    pub fn analyze(cfg: &TransformerConfig, kv_len: u32, clusters: u32) -> Self {
        let paged =
            super::PagedResidency::analyze(cfg, kv_len, clusters, kv_len.max(1));
        let kv_bytes_per_cluster = kv_len as u64 * paged.bytes_per_token_per_cluster;
        KvResidency {
            heads_per_cluster: paged.heads_per_cluster,
            kv_bytes_per_cluster,
            spm_budget: paged.spm_budget,
            placement: paged.placement(),
        }
    }

    /// HBM bytes this cluster streams per decode step for KV traffic,
    /// over all layers: the appended K/V rows when resident, the whole
    /// share when spilled.
    pub fn hbm_bytes_per_step(&self, cfg: &TransformerConfig) -> u64 {
        match self.placement {
            KvPlacement::SpmResident => {
                cfg.layers as u64
                    * self.heads_per_cluster as u64
                    * 2
                    * 2
                    * cfg.d_head() as u64
            }
            KvPlacement::HbmSpill => self.kv_bytes_per_cluster,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GPT2_SMALL, GPT3_XL, VIT_BASE};
    use crate::testkit::{forall, Rng};

    #[test]
    fn every_head_assigned_exactly_once() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            let mut seen = vec![0u32; heads as usize];
            for c in 0..clusters {
                for h in map.heads_of(c) {
                    seen[h as usize] += 1;
                    if map.cluster_of(h) != c {
                        return Err(format!("head {h} maps to wrong cluster"));
                    }
                }
            }
            if seen.iter().any(|&n| n != 1) {
                return Err(format!("assignment counts {seen:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn load_is_balanced_within_one() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            let loads: Vec<usize> = (0..clusters).map(|c| map.heads_of(c).len()).collect();
            let (lo, hi) = (*loads.iter().min().unwrap(), *loads.iter().max().unwrap());
            if hi - lo > 1 {
                return Err(format!("imbalanced loads {loads:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rounds_bound_head_waves() {
        forall(50, |rng: &mut Rng| {
            let heads = rng.range(1, 65) as u32;
            let clusters = rng.range(1, 33) as u32;
            let map = HeadMap::new(heads, clusters);
            for h in 0..heads {
                if map.round_of(h) >= map.rounds() {
                    return Err(format!("head {h} beyond round count"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tile_plans_fit_spm() {
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE] {
            let plan = TilePlan::plan(&cfg);
            assert!(
                TilePlan::working_set(plan.bq, plan.bk, plan.d) <= SPM_BYTES as u32,
                "{}: working set exceeds SPM",
                cfg.name
            );
            assert!(plan.bk >= 16 && plan.bk <= plan.sk);
            assert_eq!(plan.tiles() * plan.bk >= plan.sk, true);
        }
    }

    #[test]
    fn bigger_head_dim_means_smaller_tiles() {
        let p_small = TilePlan::plan(&GPT2_SMALL); // d_head 64
        let p_big = TilePlan::plan(&GPT3_XL); // d_head 128
        assert!(p_big.bk <= p_small.bk);
    }

    #[test]
    fn over_budget_plan_shrinks_bk_instead_of_lying() {
        // d_head 256: at bq=16 a bk=64 double-buffered working set is
        // ~158 KiB — the seed planner returned it anyway. The fix must
        // shrink bk until the plan actually fits.
        let cfg = TransformerConfig {
            name: "wide-head",
            layers: 1,
            d_model: 2048,
            heads: 8,
            d_ff: 2048,
            seq: 2048,
        };
        let plan = TilePlan::plan(&cfg);
        assert!(
            TilePlan::working_set(plan.bq, plan.bk, plan.d) <= SPM_BYTES as u32,
            "plan must fit the SPM"
        );
        assert!(plan.bk < 64, "bk must shrink below 64, got {}", plan.bk);
        assert!(plan.bk >= 16);
    }

    #[test]
    fn decode_plans_fit_spm_and_shrink_with_head_dim() {
        use crate::kernels::flash_attention::fa_decode_footprint;
        for cfg in [GPT2_SMALL, GPT3_XL, VIT_BASE] {
            let plan = DecodePlan::plan(&cfg);
            assert!(
                fa_decode_footprint(plan.sk_slice, plan.d, plan.bk) <= SPM_BYTES as u32,
                "{}: decode slice exceeds SPM",
                cfg.name
            );
            assert_eq!(plan.sk_slice, plan.tiles * plan.bk);
            assert!(plan.tiles >= 1);
        }
        // d_head 128 needs a smaller window than d_head 64
        let small = DecodePlan::plan(&GPT2_SMALL);
        let big = DecodePlan::plan(&GPT3_XL);
        assert!(big.sk_slice <= small.sk_slice);
    }

    #[test]
    fn kv_tile_factor_scales_with_cache_length() {
        let plan = DecodePlan::plan(&GPT2_SMALL);
        assert_eq!(plan.kv_tile_factor(1), 1);
        assert_eq!(
            plan.kv_tile_factor(4 * plan.sk_slice),
            4,
            "four windows for a 4x cache"
        );
    }

    #[test]
    fn kv_residency_spills_once_the_whole_model_share_outgrows_spm() {
        // 16-way GPT-2, 16-token context: 12 layers x 1 head x 16 x 64
        // x 4 B = 48 KiB fits the post-working-set budget — resident
        let short = KvResidency::analyze(&GPT2_SMALL, 16, 16);
        assert_eq!(short.placement, KvPlacement::SpmResident);
        // at 128 tokens the whole-model share is 384 KiB > 128 KiB SPM:
        // the wall hits early because every layer's cache is distinct
        let medium = KvResidency::analyze(&GPT2_SMALL, 128, 16);
        assert_eq!(medium.placement, KvPlacement::HbmSpill);
        // one cluster holding all 12 heads at 4096 tokens: 144 MiB
        let long = KvResidency::analyze(&GPT2_SMALL, 4096, 1);
        assert_eq!(long.placement, KvPlacement::HbmSpill);
        assert!(
            long.hbm_bytes_per_step(&GPT2_SMALL) > short.hbm_bytes_per_step(&GPT2_SMALL),
            "spilled caches restream, resident caches append"
        );
        assert_eq!(
            long.hbm_bytes_per_step(&GPT2_SMALL),
            long.kv_bytes_per_cluster
        );
        // resident append traffic covers every layer's K/V row
        assert_eq!(
            short.hbm_bytes_per_step(&GPT2_SMALL),
            12 * 1 * 4 * 64,
            "layers x heads x (K+V) x d bytes"
        );
    }

    #[test]
    #[should_panic(expected = "exceeds the")]
    fn untileable_head_dim_panics_with_clear_message() {
        let cfg = TransformerConfig {
            name: "impossible",
            layers: 1,
            d_model: 8192,
            heads: 4, // d_head 2048: K/V tiles cannot fit even at bk=16
            d_ff: 8192,
            seq: 2048,
        };
        TilePlan::plan(&cfg);
    }
}
