//! # VEXP — accelerated Softmax for Transformers on RISC-V
//!
//! Full-system reproduction of *"VEXP: A Low-Cost RISC-V ISA Extension
//! for Accelerated Softmax Computation in Transformers"* (Wang et al.,
//! 2025), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1/2 (build time)**: the VEXP approximation and the paper's
//!   kernels in Pallas/JAX, AOT-lowered to HLO text (`python/compile`);
//! - **Layer 3 (this crate)**: the bit-exact EXP-block model ([`vexp`]),
//!   the Snitch-cluster simulator ([`sim`]), the paper's software kernels
//!   ([`kernels`]), the area/energy models ([`energy`]), transformer
//!   workload models ([`model`]), the multi-cluster coordinator
//!   ([`coordinator`]) and the PJRT runtime ([`runtime`]) that executes
//!   the AOT artifacts with Python fully out of the request path, and
//!   the unified execution engine ([`exec`]) that serves batched
//!   multi-request inference through one `Backend` API over both the
//!   analytic estimator and the cycle-accurate simulator.
//!
//! See DESIGN.md for the experiment index (every paper table/figure →
//! bench target) and EXPERIMENTS.md for measured results.

pub mod accuracy;
pub mod bf16;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod exec;
pub mod isa;
pub mod kernels;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod vexp;
