//! # VEXP — accelerated Softmax for Transformers on RISC-V
//!
//! Full-system reproduction of *"VEXP: A Low-Cost RISC-V ISA Extension
//! for Accelerated Softmax Computation in Transformers"* (Wang et al.,
//! 2025), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 1/2 (build time)**: the VEXP approximation and the paper's
//!   kernels in Pallas/JAX, AOT-lowered to HLO text (`python/compile`);
//! - **Layer 3 (this crate)**: the bit-exact EXP-block model ([`vexp`]),
//!   the Snitch-cluster simulator ([`sim`]), the paper's software kernels
//!   ([`kernels`]), the area/energy models ([`energy`]), phase-aware
//!   transformer workload models ([`model`]), the multi-cluster
//!   coordinator with prefill/decode tile planning and the KV-cache
//!   residency rule ([`coordinator`]), the PJRT runtime ([`runtime`])
//!   that executes the AOT artifacts with Python fully out of the
//!   request path, and the unified execution engine ([`exec`]) that
//!   serves batched multi-request inference — including the
//!   continuously batched autoregressive decode path ([`exec::serve`])
//!   — through one `Backend` API over both the analytic estimator and
//!   the cycle-accurate simulator.
//!
//! ## Module layers
//!
//! Dependency direction is bottom-up:
//!
//! 1. numerics — [`bf16`], [`vexp`], [`accuracy`];
//! 2. machine — [`isa`], [`sim`] (reference interpreter + decoded fast
//!    path, differential-tested bit-identical);
//! 3. workloads — [`kernels`], [`model`], [`energy`];
//! 4. orchestration — [`coordinator`], [`exec`], [`runtime`].
//!
//! See DESIGN.md for the locked contracts (§2 substitution rule, §6
//! VEXP datapath, §8 execution engine, §9 simulator performance, §10
//! serving & decode architecture), README.md for the quickstart and the
//! paper-figure → bench index, and EXPERIMENTS.md for measured results.

#![warn(missing_docs)]

pub mod accuracy;
pub mod bf16;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod exec;
pub mod isa;
pub mod kernels;
pub mod model;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod vexp;
