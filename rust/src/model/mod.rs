//! Transformer workload models: the paper's evaluated configurations
//! (GPT-2 Small, GPT-3 XL, ViT-Base, ViT-Huge), their per-layer
//! operation counts, and the inference [`Phase`] model (prompt prefill
//! vs KV-cache decode) the serving engine schedules around.

pub mod config;
pub mod workload;

pub use config::{by_short_name, TransformerConfig, GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};
pub use workload::{LayerOps, Phase, WorkloadOps};
