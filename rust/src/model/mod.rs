//! Transformer workload models: the paper's evaluated configurations
//! (GPT-2 Small, GPT-3 XL, ViT-Base, ViT-Huge) and their per-layer
//! operation counts, used by the coordinator to schedule and by the
//! Fig. 1 / Fig. 8 benches to estimate end-to-end runtime and energy.

pub mod config;
pub mod workload;

pub use config::{TransformerConfig, GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE};
pub use workload::{LayerOps, WorkloadOps};
