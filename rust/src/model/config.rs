//! The paper's evaluated model configurations (§V-D).

/// A decoder/encoder transformer configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    /// Human-readable model name (also the program-cache identity).
    pub name: &'static str,
    /// Number of transformer blocks.
    pub layers: u32,
    /// Model (embedding) dimension.
    pub d_model: u32,
    /// Attention heads per layer.
    pub heads: u32,
    /// Feed-forward hidden dimension.
    pub d_ff: u32,
    /// Evaluation sequence length (non-autoregressive, §V-D); doubles
    /// as the prompt length of a serving request.
    pub seq: u32,
}

impl TransformerConfig {
    /// Per-head dimension (`d_model / heads`).
    pub fn d_head(&self) -> u32 {
        self.d_model / self.heads
    }
}

/// GPT-2 Small (124M parameters), evaluated at S = 2048.
pub const GPT2_SMALL: TransformerConfig = TransformerConfig {
    name: "GPT-2 Small",
    layers: 12,
    d_model: 768,
    heads: 12,
    d_ff: 3072,
    seq: 2048,
};

/// GPT-3 XL (1.3B parameters), evaluated at S = 2048.
pub const GPT3_XL: TransformerConfig = TransformerConfig {
    name: "GPT-3 XL",
    layers: 24,
    d_model: 2048,
    heads: 16,
    d_ff: 8192,
    seq: 2048,
};

/// ViT-Base (86M parameters), 197 patch tokens.
pub const VIT_BASE: TransformerConfig = TransformerConfig {
    name: "ViT-Base",
    layers: 12,
    d_model: 768,
    heads: 12,
    d_ff: 3072,
    seq: 197,
};

/// ViT-Huge (632M parameters), 197 patch tokens.
pub const VIT_HUGE: TransformerConfig = TransformerConfig {
    name: "ViT-Huge",
    layers: 32,
    d_model: 1280,
    heads: 16,
    d_ff: 5120,
    seq: 197,
};

/// The four model configurations the paper evaluates (§V-D).
pub const ALL_MODELS: [TransformerConfig; 4] = [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE];

/// Look up an evaluated configuration by CLI-friendly short name
/// (case-insensitive): `gpt2`, `gpt3`, `vit-base`, `vit-huge` (plus
/// the obvious aliases). `None` for anything else.
pub fn by_short_name(name: &str) -> Option<TransformerConfig> {
    match name.to_ascii_lowercase().as_str() {
        "gpt2" | "gpt-2" | "gpt2-small" => Some(GPT2_SMALL),
        "gpt3" | "gpt-3" | "gpt3-xl" => Some(GPT3_XL),
        "vit" | "vit-base" | "vit-b" => Some(VIT_BASE),
        "vit-huge" | "vit-h" => Some(VIT_HUGE),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dims_are_sane() {
        assert_eq!(GPT2_SMALL.d_head(), 64); // paper: head dim 64
        assert_eq!(GPT3_XL.d_head(), 128);
        assert_eq!(VIT_BASE.d_head(), 64);
        assert_eq!(VIT_HUGE.d_head(), 80);
    }

    #[test]
    fn sequence_lengths_match_paper() {
        assert_eq!(GPT2_SMALL.seq, 2048);
        assert_eq!(VIT_BASE.seq, 197);
    }
}
