//! The paper's evaluated model configurations (§V-D).

/// A decoder/encoder transformer configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformerConfig {
    pub name: &'static str,
    pub layers: u32,
    pub d_model: u32,
    pub heads: u32,
    pub d_ff: u32,
    /// Evaluation sequence length (non-autoregressive, §V-D).
    pub seq: u32,
}

impl TransformerConfig {
    pub fn d_head(&self) -> u32 {
        self.d_model / self.heads
    }
}

pub const GPT2_SMALL: TransformerConfig = TransformerConfig {
    name: "GPT-2 Small",
    layers: 12,
    d_model: 768,
    heads: 12,
    d_ff: 3072,
    seq: 2048,
};

pub const GPT3_XL: TransformerConfig = TransformerConfig {
    name: "GPT-3 XL",
    layers: 24,
    d_model: 2048,
    heads: 16,
    d_ff: 8192,
    seq: 2048,
};

pub const VIT_BASE: TransformerConfig = TransformerConfig {
    name: "ViT-Base",
    layers: 12,
    d_model: 768,
    heads: 12,
    d_ff: 3072,
    seq: 197,
};

pub const VIT_HUGE: TransformerConfig = TransformerConfig {
    name: "ViT-Huge",
    layers: 32,
    d_model: 1280,
    heads: 16,
    d_ff: 5120,
    seq: 197,
};

pub const ALL_MODELS: [TransformerConfig; 4] = [GPT2_SMALL, GPT3_XL, VIT_BASE, VIT_HUGE];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_dims_are_sane() {
        assert_eq!(GPT2_SMALL.d_head(), 64); // paper: head dim 64
        assert_eq!(GPT3_XL.d_head(), 128);
        assert_eq!(VIT_BASE.d_head(), 64);
        assert_eq!(VIT_HUGE.d_head(), 80);
    }

    #[test]
    fn sequence_lengths_match_paper() {
        assert_eq!(GPT2_SMALL.seq, 2048);
        assert_eq!(VIT_BASE.seq, 197);
    }
}
