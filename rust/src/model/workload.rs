//! Per-layer operation counts for non-autoregressive transformer
//! inference (the Fig. 1 / Fig. 8 workload model).

use super::config::TransformerConfig;

/// Operation counts of one transformer block at sequence length S.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerOps {
    /// GEMM FLOPs in projections (QKV, output, both FFN matrices).
    pub proj_flops: u64,
    /// GEMM FLOPs in attention score/value products (QK^T and P·V).
    pub attn_flops: u64,
    /// Softmax elements (S² per head — each needs max/exp/norm).
    pub softmax_elems: u64,
    /// Bytes streamed from HBM for weights (BF16).
    pub weight_bytes: u64,
    /// Bytes streamed for activations and KV tiles (BF16).
    pub act_bytes: u64,
}

impl LayerOps {
    pub fn total_flops(&self) -> u64 {
        self.proj_flops + self.attn_flops
    }
}

/// Whole-model operation counts.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadOps {
    pub per_layer: LayerOps,
    pub layers: u32,
}

impl WorkloadOps {
    /// Build from a model configuration (one full non-autoregressive
    /// forward pass over `cfg.seq` tokens).
    pub fn of(cfg: &TransformerConfig) -> Self {
        let s = cfg.seq as u64;
        let d = cfg.d_model as u64;
        let h = cfg.heads as u64;
        let dh = cfg.d_head() as u64;
        let ff = cfg.d_ff as u64;

        // projections: QKV (3·d·d), attn out (d·d), FFN (2·d·ff); ×2 MAC
        let proj_flops = 2 * s * (3 * d * d + d * d + 2 * d * ff);
        // attention: QK^T (S²·dh per head) + P·V (S²·dh per head); ×2 MAC
        let attn_flops = 2 * h * (s * s * dh) * 2;
        let softmax_elems = h * s * s;
        let weight_bytes = 2 * (4 * d * d + 2 * d * ff);
        let act_bytes = 2 * (s * d * 8 + h * s * dh * 4);

        WorkloadOps {
            per_layer: LayerOps { proj_flops, attn_flops, softmax_elems, weight_bytes, act_bytes },
            layers: cfg.layers,
        }
    }

    pub fn total(&self) -> LayerOps {
        let l = self.layers as u64;
        LayerOps {
            proj_flops: self.per_layer.proj_flops * l,
            attn_flops: self.per_layer.attn_flops * l,
            softmax_elems: self.per_layer.softmax_elems * l,
            weight_bytes: self.per_layer.weight_bytes * l,
            act_bytes: self.per_layer.act_bytes * l,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::*;

    #[test]
    fn gpt2_small_magnitudes() {
        let w = WorkloadOps::of(&GPT2_SMALL).total();
        // ~ 2 * 124M params * 2048 tokens ≈ 3.5e11 proj FLOPs + attention
        assert!(w.proj_flops > 2e11 as u64 && w.proj_flops < 2e12 as u64);
        // softmax: 12 layers * 12 heads * 2048^2 = 6.04e8 elements
        assert_eq!(w.softmax_elems, 12 * 12 * 2048 * 2048);
    }

    #[test]
    fn softmax_share_grows_with_sequence() {
        // Fig. 1's driving effect: softmax elements scale with S² while
        // projection FLOPs scale with S — the share grows linearly in S.
        let mut cfg = GPT3_XL;
        cfg.seq = 128;
        let short = WorkloadOps::of(&cfg).total();
        cfg.seq = 2048;
        let long = WorkloadOps::of(&cfg).total();
        let share_short = short.softmax_elems as f64 / short.total_flops() as f64;
        let share_long = long.softmax_elems as f64 / long.total_flops() as f64;
        assert!(share_long > 4.0 * share_short);
    }

    #[test]
    fn vit_much_smaller_than_gpt() {
        let vit = WorkloadOps::of(&VIT_BASE).total();
        let gpt = WorkloadOps::of(&GPT2_SMALL).total();
        assert!(gpt.softmax_elems > 50 * vit.softmax_elems);
    }
}
