//! Per-layer operation counts for transformer inference, phase-aware:
//! non-autoregressive forward passes (the Fig. 1 / Fig. 8 workload
//! model), prompt prefill, and single-token KV-cache decode.

use super::config::TransformerConfig;

/// Inference phase of an autoregressive request.
///
/// Prefill processes the whole prompt in one pass (compute-bound,
/// softmax S² per head); decode extends the sequence by one token
/// against a KV-cache of length `kv_len` (GEMV-shaped attention,
/// softmax `kv_len` elements per head, bandwidth-bound — the regime
/// Potocnik et al. identify on the same class of hardware).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// One forward pass over a prompt of `prompt` tokens.
    Prefill {
        /// Prompt length in tokens.
        prompt: u32,
    },
    /// One new token attending over a KV-cache of `kv_len` entries.
    Decode {
        /// KV-cache length (prompt + previously generated tokens).
        kv_len: u32,
    },
}

impl Phase {
    /// True for the decode (single-token) phase.
    pub fn is_decode(&self) -> bool {
        matches!(self, Phase::Decode { .. })
    }

    /// Short display label.
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Prefill { .. } => "prefill",
            Phase::Decode { .. } => "decode",
        }
    }
}

/// Operation counts of one transformer block at sequence length S.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerOps {
    /// GEMM FLOPs in projections (QKV, output, both FFN matrices).
    pub proj_flops: u64,
    /// GEMM FLOPs in attention score/value products (QK^T and P·V).
    pub attn_flops: u64,
    /// Softmax elements (S² per head — each needs max/exp/norm).
    pub softmax_elems: u64,
    /// Bytes streamed from HBM for weights (BF16).
    pub weight_bytes: u64,
    /// Bytes streamed for activations and KV tiles (BF16).
    pub act_bytes: u64,
    /// GELU activations in the FFN (one per hidden unit per token).
    pub gelu_elems: u64,
    /// LayerNorm elements (two norms per block, `d_model` per token).
    pub layernorm_elems: u64,
}

impl LayerOps {
    /// All GEMM FLOPs of the layer (projections + attention products).
    pub fn total_flops(&self) -> u64 {
        self.proj_flops + self.attn_flops
    }
}

/// Whole-model operation counts.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadOps {
    /// Operation counts of a single transformer block.
    pub per_layer: LayerOps,
    /// Number of identical blocks in the model.
    pub layers: u32,
}

impl WorkloadOps {
    /// Build from a model configuration (one full non-autoregressive
    /// forward pass over `cfg.seq` tokens).
    ///
    /// ```
    /// use vexp::model::{Phase, WorkloadOps, GPT2_SMALL};
    ///
    /// let prefill = WorkloadOps::of(&GPT2_SMALL).total();
    /// // decoding ONE token against the same context is GEMV-shaped:
    /// let decode = WorkloadOps::for_phase(&GPT2_SMALL, Phase::Decode { kv_len: 2048 }).total();
    /// assert!(decode.attn_flops < prefill.attn_flops / 1000);
    /// assert_eq!(decode.softmax_elems * 2048, prefill.softmax_elems);
    /// ```
    pub fn of(cfg: &TransformerConfig) -> Self {
        let s = cfg.seq as u64;
        let d = cfg.d_model as u64;
        let h = cfg.heads as u64;
        let dh = cfg.d_head() as u64;
        let ff = cfg.d_ff as u64;

        // projections: QKV (3·d·d), attn out (d·d), FFN (2·d·ff); ×2 MAC
        let proj_flops = 2 * s * (3 * d * d + d * d + 2 * d * ff);
        // attention: QK^T (S²·dh per head) + P·V (S²·dh per head); ×2 MAC
        let attn_flops = 2 * h * (s * s * dh) * 2;
        let softmax_elems = h * s * s;
        let weight_bytes = 2 * (4 * d * d + 2 * d * ff);
        let act_bytes = 2 * (s * d * 8 + h * s * dh * 4);
        // nonlinearities: one GELU per FFN hidden unit per token, two
        // LayerNorms (pre-attention, pre-FFN) of d elements per token
        let gelu_elems = s * ff;
        let layernorm_elems = 2 * s * d;

        WorkloadOps {
            per_layer: LayerOps {
                proj_flops,
                attn_flops,
                softmax_elems,
                weight_bytes,
                act_bytes,
                gelu_elems,
                layernorm_elems,
            },
            layers: cfg.layers,
        }
    }

    /// Prefill over a prompt of `prompt` tokens: the non-autoregressive
    /// pass of [`WorkloadOps::of`] at sequence length `prompt`.
    pub fn prefill(cfg: &TransformerConfig, prompt: u32) -> Self {
        let mut c = *cfg;
        c.seq = prompt.max(1);
        Self::of(&c)
    }

    /// Decode of one token against a KV-cache of length `kv_len`.
    ///
    /// Attention degenerates to two GEMVs per head (q·K^T over `kv_len`
    /// keys, then p·V), softmax is `kv_len` elements per head, and the
    /// byte counts reflect the decode regime: the full weight set plus
    /// both KV-cache matrices stream per token, so the phase is
    /// bandwidth-bound long before it is compute-bound.
    pub fn decode(cfg: &TransformerConfig, kv_len: u32) -> Self {
        let t = kv_len.max(1) as u64;
        let d = cfg.d_model as u64;
        let h = cfg.heads as u64;
        let dh = cfg.d_head() as u64;
        let ff = cfg.d_ff as u64;

        // one token through the projections: GEMV, ×2 MAC
        let proj_flops = 2 * (3 * d * d + d * d + 2 * d * ff);
        // q·K^T (t·dh per head) + p·V (t·dh per head); ×2 MAC
        let attn_flops = 2 * h * (t * dh) * 2;
        let softmax_elems = h * t;
        let weight_bytes = 2 * (4 * d * d + 2 * d * ff);
        // K and V caches (t·dh per head each) + the token's activations
        let act_bytes = 2 * (2 * h * t * dh + 8 * d);
        // one token through the nonlinearities
        let gelu_elems = ff;
        let layernorm_elems = 2 * d;

        WorkloadOps {
            per_layer: LayerOps {
                proj_flops,
                attn_flops,
                softmax_elems,
                weight_bytes,
                act_bytes,
                gelu_elems,
                layernorm_elems,
            },
            layers: cfg.layers,
        }
    }

    /// Operation counts for an explicit inference [`Phase`].
    pub fn for_phase(cfg: &TransformerConfig, phase: Phase) -> Self {
        match phase {
            Phase::Prefill { prompt } => Self::prefill(cfg, prompt),
            Phase::Decode { kv_len } => Self::decode(cfg, kv_len),
        }
    }

    /// Whole-model totals (per-layer counts × layer count).
    pub fn total(&self) -> LayerOps {
        let l = self.layers as u64;
        LayerOps {
            proj_flops: self.per_layer.proj_flops * l,
            attn_flops: self.per_layer.attn_flops * l,
            softmax_elems: self.per_layer.softmax_elems * l,
            weight_bytes: self.per_layer.weight_bytes * l,
            act_bytes: self.per_layer.act_bytes * l,
            gelu_elems: self.per_layer.gelu_elems * l,
            layernorm_elems: self.per_layer.layernorm_elems * l,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::*;

    #[test]
    fn gpt2_small_magnitudes() {
        let w = WorkloadOps::of(&GPT2_SMALL).total();
        // ~ 2 * 124M params * 2048 tokens ≈ 3.5e11 proj FLOPs + attention
        assert!(w.proj_flops > 2e11 as u64 && w.proj_flops < 2e12 as u64);
        // softmax: 12 layers * 12 heads * 2048^2 = 6.04e8 elements
        assert_eq!(w.softmax_elems, 12 * 12 * 2048 * 2048);
    }

    #[test]
    fn softmax_share_grows_with_sequence() {
        // Fig. 1's driving effect: softmax elements scale with S² while
        // projection FLOPs scale with S — the share grows linearly in S.
        let mut cfg = GPT3_XL;
        cfg.seq = 128;
        let short = WorkloadOps::of(&cfg).total();
        cfg.seq = 2048;
        let long = WorkloadOps::of(&cfg).total();
        let share_short = short.softmax_elems as f64 / short.total_flops() as f64;
        let share_long = long.softmax_elems as f64 / long.total_flops() as f64;
        assert!(share_long > 4.0 * share_short);
    }

    #[test]
    fn vit_much_smaller_than_gpt() {
        let vit = WorkloadOps::of(&VIT_BASE).total();
        let gpt = WorkloadOps::of(&GPT2_SMALL).total();
        assert!(gpt.softmax_elems > 50 * vit.softmax_elems);
    }

    #[test]
    fn prefill_matches_of_at_prompt_length() {
        let mut cfg = GPT2_SMALL;
        cfg.seq = 512;
        let via_of = WorkloadOps::of(&cfg).total();
        let via_prefill = WorkloadOps::prefill(&GPT2_SMALL, 512).total();
        assert_eq!(via_of.attn_flops, via_prefill.attn_flops);
        assert_eq!(via_of.softmax_elems, via_prefill.softmax_elems);
        assert_eq!(via_of.proj_flops, via_prefill.proj_flops);
    }

    #[test]
    fn decode_is_gemv_shaped() {
        let cfg = GPT2_SMALL;
        let t = 1024u32;
        let dec = WorkloadOps::decode(&cfg, t).per_layer;
        let h = cfg.heads as u64;
        let dh = cfg.d_head() as u64;
        assert_eq!(dec.attn_flops, 4 * h * t as u64 * dh);
        assert_eq!(dec.softmax_elems, h * t as u64);
        // one token through the projections, not `seq` tokens
        let pre = WorkloadOps::prefill(&cfg, cfg.seq).per_layer;
        assert_eq!(dec.proj_flops * cfg.seq as u64, pre.proj_flops);
    }

    #[test]
    fn decode_is_bandwidth_bound_relative_to_prefill() {
        // bytes-per-FLOP must be far higher in decode than prefill: the
        // whole weight set streams for a single token of compute.
        let cfg = GPT3_XL;
        let pre = WorkloadOps::prefill(&cfg, 2048).total();
        let dec = WorkloadOps::decode(&cfg, 2048).total();
        let pre_intensity = pre.total_flops() as f64 / (pre.weight_bytes + pre.act_bytes) as f64;
        let dec_intensity = dec.total_flops() as f64 / (dec.weight_bytes + dec.act_bytes) as f64;
        assert!(
            pre_intensity > 100.0 * dec_intensity,
            "prefill {pre_intensity:.1} flop/B vs decode {dec_intensity:.3} flop/B"
        );
    }

    #[test]
    fn decode_softmax_grows_linearly_with_kv() {
        let a = WorkloadOps::decode(&GPT2_SMALL, 256).total();
        let b = WorkloadOps::decode(&GPT2_SMALL, 1024).total();
        assert_eq!(b.softmax_elems, 4 * a.softmax_elems);
        assert_eq!(b.attn_flops, 4 * a.attn_flops);
    }

    #[test]
    fn nonlinearities_are_counted() {
        let cfg = GPT2_SMALL;
        let pre = WorkloadOps::of(&cfg).per_layer;
        assert_eq!(pre.gelu_elems, cfg.seq as u64 * cfg.d_ff as u64);
        assert_eq!(pre.layernorm_elems, 2 * cfg.seq as u64 * cfg.d_model as u64);
        // decode is one token's worth
        let dec = WorkloadOps::decode(&cfg, 1024).per_layer;
        assert_eq!(dec.gelu_elems, cfg.d_ff as u64);
        assert_eq!(dec.layernorm_elems, 2 * cfg.d_model as u64);
        // totals scale by layer count
        let tot = WorkloadOps::of(&cfg).total();
        assert_eq!(tot.gelu_elems, pre.gelu_elems * cfg.layers as u64);
        assert_eq!(tot.layernorm_elems, pre.layernorm_elems * cfg.layers as u64);
    }

    #[test]
    fn phase_labels() {
        assert!(Phase::Decode { kv_len: 1 }.is_decode());
        assert!(!Phase::Prefill { prompt: 1 }.is_decode());
        assert_eq!(Phase::Prefill { prompt: 8 }.label(), "prefill");
        assert_eq!(Phase::Decode { kv_len: 8 }.label(), "decode");
    }
}
