//! Activity-based energy model (GF12, 0.8 V, 1 GHz typical corner).

use crate::isa::Class;
use crate::sim::{ClusterStats, CoreStats};

/// Per-instruction datapath + issue energy in pJ.
pub fn instr_pj(class: Class) -> f64 {
    match class {
        Class::IntAlu => 1.2,
        Class::Branch => 1.5,
        Class::FpLoad => 3.5,
        Class::FpStore => 3.5,
        Class::FpScalarH => 3.0,
        // FP64 path of the multi-format FMA: wide operands, wide writeback
        Class::FpScalarD => 7.0,
        // iterative DIVSQRT: many internal cycles per op
        Class::FpDivH => 18.0,
        // 4-lane SIMD on the shared FMA datapath (vfmac dominates)
        Class::FpSimd => 9.0,
        // the ExpOpGroup: 4 ExpUnit lanes + input segmentation; fitted so
        // Table III's 6.39 pJ per exponential emerges (25.6 pJ / 4 lanes)
        Class::FpExp => 25.6,
        Class::Ssr => 2.0,
        Class::Frep => 1.0,
        Class::Misc => 0.5,
    }
}

/// TCDM access energy per 64-bit SSR beat.
pub const SSR_BEAT_PJ: f64 = 2.0;

/// Core static + clock-tree energy per active cycle.
pub const CORE_STATIC_PJ: f64 = 3.0;

/// Cluster-shared energy (I$, interconnect, DMA idle, CVA6 share) per
/// core-cycle at cluster scope.
pub const SHARED_PJ: f64 = 5.0;

/// Additional cluster-shared leakage of the EXP-extended design (the
/// paper's +1.8 % average power on EXP-less workloads).
pub const EXP_BLOCK_LEAKAGE_PJ: f64 = 0.55;

/// DMA energy per byte moved between SPM and HBM.
pub const DMA_PJ_PER_BYTE: f64 = 4.0;

/// Energy breakdown of a run, in pJ.
#[derive(Clone, Debug, Default)]
pub struct EnergyBreakdown {
    pub instr: f64,
    pub ssr: f64,
    pub static_core: f64,
    pub shared: f64,
    pub dma: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.instr + self.ssr + self.static_core + self.shared + self.dma
    }
}

/// Core-scope energy (one core's datapath + its static share).
pub fn core_energy_pj(stats: &CoreStats) -> EnergyBreakdown {
    let mut instr = 0.0;
    for (c, n) in stats.retired() {
        instr += instr_pj(c) * n as f64;
    }
    EnergyBreakdown {
        instr,
        ssr: SSR_BEAT_PJ * stats.ssr_beats as f64,
        static_core: CORE_STATIC_PJ * stats.cycles as f64,
        shared: 0.0,
        dma: 0.0,
    }
}

/// Cluster-scope energy: all cores + shared fabric over the makespan.
///
/// `extended` adds the EXP block's leakage (present even when unused).
pub fn cluster_energy_pj(stats: &ClusterStats, extended: bool) -> EnergyBreakdown {
    let mut e = EnergyBreakdown::default();
    for core in &stats.per_core {
        let c = core_energy_pj(core);
        e.instr += c.instr;
        e.ssr += c.ssr;
    }
    // static + shared burn for the full makespan on all eight cores
    let core_cycles = stats.cycles as f64 * crate::sim::CORES_PER_CLUSTER as f64;
    e.static_core = CORE_STATIC_PJ * core_cycles;
    let shared = if extended { SHARED_PJ + EXP_BLOCK_LEAKAGE_PJ } else { SHARED_PJ };
    e.shared = shared * core_cycles;
    e.dma = DMA_PJ_PER_BYTE * stats.dma_bytes as f64;
    e
}

/// Table III footnote-6 scope for the extended design: energy per
/// exponential seen by the ExpOpGroup datapath (pJ/op).
pub fn exp_datapath_pj_per_op() -> f64 {
    instr_pj(Class::FpExp) / 4.0
}

/// Average power in mW given energy (pJ) and cycles at 1 GHz.
pub fn power_mw(energy_pj: f64, cycles: u64) -> f64 {
    if cycles == 0 {
        0.0
    } else {
        energy_pj / cycles as f64 // pJ/ns = mW at 1 GHz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gemm::run_gemm;
    use crate::kernels::softmax::{run_softmax, SoftmaxVariant};

    fn mat(n: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32
            })
            .collect()
    }

    /// Table III row 1: GEMM at ~3.96 pJ/op (baseline cluster scope).
    #[test]
    fn gemm_energy_per_op_matches_table3() {
        let (m, k, n) = (48u32, 48u32, 48u32);
        let run = run_gemm(&mat((m * k) as usize, 1), &mat((n * k) as usize, 2), m, k, n);
        let e = cluster_energy_pj(&run.stats, false);
        let pj_per_op = e.total() / run.flops as f64;
        assert!(
            (3.0..5.5).contains(&pj_per_op),
            "GEMM at {pj_per_op:.2} pJ/op (paper: 3.96)"
        );
        // extended cluster: ~2% more (the paper's 4.04)
        let e2 = cluster_energy_pj(&run.stats, true);
        let ratio = e2.total() / e.total();
        assert!((1.005..1.06).contains(&ratio), "EXP leakage ratio {ratio:.3}");
    }

    /// Table III row 2: EXP 3433 pJ/op baseline vs 6.39 pJ/op extended.
    #[test]
    fn exp_energy_per_op_matches_table3() {
        // baseline: one full softmax EXP phase per element ≈ libm cost;
        // measure on the baseline kernel and subtract nothing — exp
        // dominates (319 of ~360 cycles).
        let rows: Vec<Vec<f32>> = (0..8).map(|i| mat(64, i as u64 + 3)).collect();
        let run = run_softmax(SoftmaxVariant::Baseline, &rows);
        let e = cluster_energy_pj(&run.stats, false);
        let per_exp = e.total() / (8.0 * 64.0);
        assert!(
            (2000.0..5200.0).contains(&per_exp),
            "baseline exp at {per_exp:.0} pJ/op (paper: 3433)"
        );
        // extended: the ExpOpGroup datapath energy per op
        let hw = exp_datapath_pj_per_op();
        assert!((5.0..8.0).contains(&hw), "hw exp at {hw:.2} pJ/op (paper: 6.39)");
        // two-orders-of-magnitude reduction (paper's headline)
        assert!(per_exp / hw > 100.0);
    }

    /// Fig. 6c: softmax energy ratio baseline/optimized ~74x.
    #[test]
    fn softmax_energy_ratio_matches_fig6c() {
        let rows: Vec<Vec<f32>> = (0..8).map(|i| mat(128, i as u64 + 7)).collect();
        let base = run_softmax(SoftmaxVariant::Baseline, &rows);
        let opt = run_softmax(SoftmaxVariant::SwExpHw, &rows);
        let eb = cluster_energy_pj(&base.stats, false).total();
        let eo = cluster_energy_pj(&opt.stats, true).total();
        let ratio = eb / eo;
        assert!(
            (30.0..160.0).contains(&ratio),
            "softmax energy ratio {ratio:.1}x (paper: 74.3x)"
        );
    }

    /// Table IV "our" row: ~7.1 mW per core averaged over softmax.
    #[test]
    fn softmax_core_power_matches_table4() {
        let rows: Vec<Vec<f32>> = (0..8).map(|i| mat(1024, i as u64 + 11)).collect();
        let opt = run_softmax(SoftmaxVariant::SwExpHw, &rows);
        let core = &opt.stats.per_core[0];
        let e = core_energy_pj(core);
        let mw = power_mw(e.total() + SHARED_PJ * core.cycles as f64, core.cycles);
        // our activity model puts the optimized-softmax core at the upper
        // end of the paper\u{2019}s Table III\u{2013}IV power window (7.1 mW Table IV vs
        // the 2.4\u{d7} increase of Table III \u{2248} 26 mW); accept the window
        assert!((4.0..30.0).contains(&mw), "core power {mw:.1} mW (paper: 7.1-26)");
    }

    #[test]
    fn power_conversion() {
        assert!((power_mw(1000.0, 100) - 10.0).abs() < 1e-9);
        assert_eq!(power_mw(1.0, 0), 0.0);
    }
}
