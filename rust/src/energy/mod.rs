//! Area, power and energy models of the (extended) Snitch cluster,
//! calibrated to the paper's GF12 measurements (DESIGN.md §5).
//!
//! The energy model is *activity-based*: the simulator reports retired
//! instructions per class, SSR beats and DMA bytes; this module turns
//! them into picojoules. Constants are fitted so the paper's anchors
//! emerge from simulation (Table III: GEMM 3.96→4.04 pJ/op, EXP
//! 3433→6.39 pJ/op), rather than hard-coding the headline ratios.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod area;
pub mod power;

pub use area::{AreaModel, AreaReport};
pub use power::{cluster_energy_pj, core_energy_pj, exp_datapath_pj_per_op, EnergyBreakdown};
