//! GF12 area model (paper §V-B, Fig. 5 and Table IV).
//!
//! One Gate Equivalent (GE) = 0.121 µm² in GF12 (paper footnote 1).

/// GE → µm² in GF12.
pub const UM2_PER_KGE: f64 = 0.121 * 1000.0;

/// Area of one EXP block per core (paper: 8 kGE ≈ 968 µm²).
pub const EXP_BLOCK_KGE: f64 = 8.0;

/// Component areas in kGE, fitted to the paper's percentages:
/// EXP is +2.3 % of the FPU subsystem, +1.9 % of the core complex and
/// +1.0 % of the cluster.
#[derive(Clone, Copy, Debug)]
pub struct AreaModel {
    /// FPU subsystem per core, without the EXP block.
    pub fpu_ss_kge: f64,
    /// Integer core + L0 I$ per core.
    pub int_core_kge: f64,
    /// Cluster-shared logic + SPM (TCDM, interconnect, DMA, I$).
    pub shared_kge: f64,
    pub cores: usize,
}

impl Default for AreaModel {
    fn default() -> Self {
        // fitted: 8/348 = 2.3% of FPU SS; 8/(348+73) = 1.9% of core
        // complex; 8*8/(8*421 + 3032) = 1.0% of the cluster
        AreaModel { fpu_ss_kge: 348.0, int_core_kge: 73.0, shared_kge: 3032.0, cores: 8 }
    }
}

/// Area report for baseline vs EXP-extended design.
#[derive(Clone, Debug)]
pub struct AreaReport {
    pub fpu_ss_kge: f64,
    pub core_complex_kge: f64,
    pub cluster_kge: f64,
    pub fpu_ss_overhead: f64,
    pub core_complex_overhead: f64,
    pub cluster_overhead: f64,
}

impl AreaModel {
    pub fn core_complex_kge(&self, extended: bool) -> f64 {
        self.int_core_kge + self.fpu_ss_kge + if extended { EXP_BLOCK_KGE } else { 0.0 }
    }

    pub fn cluster_kge(&self, extended: bool) -> f64 {
        self.cores as f64 * self.core_complex_kge(extended) + self.shared_kge
    }

    /// The Fig. 5 comparison: overheads of the extended design.
    pub fn report(&self) -> AreaReport {
        let f0 = self.fpu_ss_kge;
        let f1 = self.fpu_ss_kge + EXP_BLOCK_KGE;
        let c0 = self.core_complex_kge(false);
        let c1 = self.core_complex_kge(true);
        let k0 = self.cluster_kge(false);
        let k1 = self.cluster_kge(true);
        AreaReport {
            fpu_ss_kge: f1,
            core_complex_kge: c1,
            cluster_kge: k1,
            fpu_ss_overhead: f1 / f0 - 1.0,
            core_complex_overhead: c1 / c0 - 1.0,
            cluster_overhead: k1 / k0 - 1.0,
        }
    }

    /// Per-core EXP block area in µm² (Table IV "our" row: 968 µm²).
    pub fn exp_block_um2(&self) -> f64 {
        EXP_BLOCK_KGE * UM2_PER_KGE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_block_is_968_um2() {
        let m = AreaModel::default();
        assert!((m.exp_block_um2() - 968.0).abs() < 1.0);
    }

    #[test]
    fn overheads_match_fig5() {
        let r = AreaModel::default().report();
        assert!(
            (r.fpu_ss_overhead - 0.023).abs() < 0.004,
            "FPU SS overhead {:.3} (paper: 2.3%)",
            r.fpu_ss_overhead
        );
        assert!(
            (r.core_complex_overhead - 0.019).abs() < 0.004,
            "core complex overhead {:.3} (paper: 1.9%)",
            r.core_complex_overhead
        );
        assert!(
            (r.cluster_overhead - 0.010).abs() < 0.003,
            "cluster overhead {:.3} (paper: 1.0%)",
            r.cluster_overhead
        );
    }

    #[test]
    fn cluster_is_mostly_shared_and_fpus() {
        let m = AreaModel::default();
        let cl = m.cluster_kge(true);
        assert!(m.shared_kge / cl > 0.3, "SPM+interconnect dominate shared area");
        assert!(m.cores as f64 * EXP_BLOCK_KGE / cl < 0.02);
    }
}
