//! The paper's core contribution: the VEXP custom arithmetic block for
//! BF16 exponentiation (Fig. 3), as a bit-exact software model.
//!
//! Structure mirrors the hardware:
//! - [`exps`]: the Schraudolph stage (scale by log2 e, int/frac split);
//! - [`poly`]: the `P(x)` mantissa-correction stage;
//! - [`unit`]: one `ExpUnit` lane (combinational fn + pipeline model);
//! - [`opgroup`]: the SIMD `ExpOpGroup` implementing FEXP / VFEXP.
//!
//! The same fixed-point pipeline is implemented in the Pallas kernel
//! (`python/compile/kernels/vexp.py`); `tests/vexp_golden.rs` asserts
//! bit-equality over all 65536 BF16 inputs via the AOT-dumped golden
//! table — the hardware-correctness invariant of this reproduction.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod consts;
pub mod exps;
pub mod poly;
pub mod unit;
pub mod opgroup;

pub use consts::{EXP_LANES, EXP_UNIT_LATENCY};
pub use exps::{exps, ExpsOut};
pub use opgroup::{fexp, vfexp, vfexp_slice};
pub use poly::poly_q7;
pub use unit::{exp_unit, ExpUnitPipe};
