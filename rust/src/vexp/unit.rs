//! One `ExpUnit` lane (paper Fig. 3c): exps(x) → P(x) → reassembly,
//! plus a small pipeline model used by the FPU timing simulator.

use super::consts::EXP_UNIT_LATENCY;
use super::exps::{exps, ExpsOut};
use super::poly::poly_q7;
use crate::bf16::Bf16;

/// Combinational function of one ExpUnit: BF16 in, BF16 `exp(x)` out.
///
/// This is the bit-exact ground truth cross-checked against the Pallas
/// kernel over all 2^16 inputs (see `tests/vexp_golden.rs`).
#[inline]
pub fn exp_unit(x: Bf16) -> Bf16 {
    match exps(x) {
        ExpsOut::Nan(bits) => Bf16(bits),
        ExpsOut::Overflow => crate::bf16::POS_INF,
        ExpsOut::Underflow => crate::bf16::ZERO,
        ExpsOut::Normal { eo, frac } => {
            let mant = poly_q7(frac as u32) as u16;
            Bf16((eo << 7) | mant)
        }
    }
}

/// Cycle-level pipeline model of one ExpUnit (1 register level → 2-cycle
/// latency, full throughput). Used by `sim::fpu` to retire VFEXP results
/// at the right cycle while accepting a new operand every cycle.
#[derive(Debug, Default)]
pub struct ExpUnitPipe {
    stages: Vec<Option<Bf16>>,
}

impl ExpUnitPipe {
    pub fn new() -> Self {
        Self { stages: vec![None; EXP_UNIT_LATENCY as usize - 1] }
    }

    /// Advance one cycle: push `input` into the pipe, return the value
    /// retiring this cycle (if any).
    pub fn tick(&mut self, input: Option<Bf16>) -> Option<Bf16> {
        let out = self.stages.pop().flatten().map(exp_unit);
        self.stages.insert(0, input);
        out
    }

    pub fn latency(&self) -> u32 {
        EXP_UNIT_LATENCY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(x: f32) -> f32 {
        exp_unit(Bf16::from_f32(x)).to_f32()
    }

    #[test]
    fn known_values() {
        assert_eq!(f(0.0), 1.0);
        assert!((f(1.0) - std::f32::consts::E).abs() / std::f32::consts::E < 0.01);
        assert!((f(-1.0) - (-1.0f32).exp()).abs() / (-1.0f32).exp() < 0.01);
        assert!((f(10.0) - 22026.46).abs() / 22026.46 < 0.01);
        assert!((f(-10.0) - 4.54e-5) / 4.54e-5 < 0.01);
    }

    #[test]
    fn error_bounds_exhaustive() {
        // DESIGN.md §6: mean rel err < 0.2%, max < 1.1% over all finite
        // inputs whose exact exp is a normal BF16.
        let (mut sum, mut max, mut n) = (0.0f64, 0.0f64, 0u64);
        for bits in 0..=u16::MAX {
            let x = Bf16(bits);
            if x.is_nan() || x.is_inf() {
                continue;
            }
            let t = (x.to_f32() as f64).exp();
            if !t.is_finite() || t < 1e-38 || t > 3.38e38 {
                continue;
            }
            let y = exp_unit(x).to_f32() as f64;
            let rel = (y - t).abs() / t;
            sum += rel;
            max = max.max(rel);
            n += 1;
        }
        let mean = sum / n as f64;
        assert!(mean < 0.002, "mean rel err {mean}");
        assert!(max < 0.011, "max rel err {max}");
    }

    #[test]
    fn monotone_over_positive_reals() {
        // walking up the positive bf16 grid, exp must not decrease
        let mut prev = 0.0f32;
        for e in 1..0xFFu16 {
            for m in 0..0x80u16 {
                let x = Bf16((e << 7) | m);
                if x.to_f32() > 88.0 {
                    continue;
                }
                let y = exp_unit(x).to_f32();
                assert!(y >= prev, "non-monotone at {}", x.to_f32());
                prev = y;
            }
        }
    }

    #[test]
    fn pipeline_latency_and_throughput() {
        let mut pipe = ExpUnitPipe::new();
        // issue back-to-back operands; first result after LATENCY ticks
        let a = Bf16::from_f32(1.0);
        let b = Bf16::from_f32(2.0);
        assert_eq!(pipe.tick(Some(a)), None); // cycle 1: in flight
        let r1 = pipe.tick(Some(b));          // cycle 2: a retires
        assert_eq!(r1, Some(exp_unit(a)));
        let r2 = pipe.tick(None);             // cycle 3: b retires
        assert_eq!(r2, Some(exp_unit(b)));
        assert_eq!(pipe.tick(None), None);
    }
}
