//! `ExpOpGroup` (paper Fig. 3b): the multi-format FPU's new operation
//! group — k = N/16 ExpUnit lanes behind input segmentation logic.
//!
//! For Snitch's 64-bit datapath k = 4, giving the packed-SIMD `VFEXP`
//! a peak throughput of four BF16 exponentials per cycle.

use super::consts::EXP_LANES;
use super::unit::exp_unit;
use crate::bf16::{pack4, unpack4, Bf16};

/// Scalar `FEXP rd, rs1`: one lane active, upper lanes pass through zero.
#[inline]
pub fn fexp(rs1: u64) -> u64 {
    exp_unit(Bf16(rs1 as u16)).0 as u64
}

/// Packed-SIMD `VFEXP rd, rs1`: all four lanes in parallel.
#[inline]
pub fn vfexp(rs1: u64) -> u64 {
    let lanes = unpack4(rs1);
    pack4([
        exp_unit(lanes[0]),
        exp_unit(lanes[1]),
        exp_unit(lanes[2]),
        exp_unit(lanes[3]),
    ])
}

/// Apply VFEXP over a BF16 slice (convenience for host-level kernels;
/// the tail shorter than [`EXP_LANES`] falls back to scalar FEXP).
pub fn vfexp_slice(xs: &[Bf16], out: &mut [Bf16]) {
    assert_eq!(xs.len(), out.len());
    let chunks = xs.len() / EXP_LANES;
    for i in 0..chunks {
        let v = pack4([
            xs[4 * i],
            xs[4 * i + 1],
            xs[4 * i + 2],
            xs[4 * i + 3],
        ]);
        let r = unpack4(vfexp(v));
        out[4 * i..4 * i + 4].copy_from_slice(&r);
    }
    for i in chunks * EXP_LANES..xs.len() {
        out[i] = Bf16(fexp(xs[i].0 as u64) as u16);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vfexp_matches_four_scalar_fexp() {
        let xs = [0.5f32, -1.25, 3.0, -7.5];
        let packed = pack4([
            Bf16::from_f32(xs[0]),
            Bf16::from_f32(xs[1]),
            Bf16::from_f32(xs[2]),
            Bf16::from_f32(xs[3]),
        ]);
        let v = unpack4(vfexp(packed));
        for (i, &x) in xs.iter().enumerate() {
            let scalar = fexp(Bf16::from_f32(x).0 as u64) as u16;
            assert_eq!(v[i].0, scalar, "lane {i}");
        }
    }

    #[test]
    fn scalar_fexp_only_low_lane() {
        // upper 48 bits of rs1 must not affect the scalar result
        let x = Bf16::from_f32(2.0);
        let noisy = (0xDEAD_BEEF_0000_0000u64) | x.0 as u64;
        assert_eq!(fexp(noisy), fexp(x.0 as u64));
    }

    #[test]
    fn slice_with_ragged_tail() {
        let xs: Vec<Bf16> = (0..7).map(|i| Bf16::from_f32(i as f32 * 0.5 - 2.0)).collect();
        let mut out = vec![Bf16(0); 7];
        vfexp_slice(&xs, &mut out);
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(out[i], exp_unit(*x), "index {i}");
        }
    }
}
