//! Fixed-point constants of the VEXP datapath (locked spec, DESIGN.md §6).
//!
//! These mirror `python/compile/kernels/vexp.py` exactly; any change must
//! be made in both places and re-validated against the exhaustive golden
//! table (`artifacts/vexp_golden.bin`).

/// `round(log2(e) * 2^15)` — the Q1.15 scaling constant of the exps stage.
pub const LOG2E_Q15: u32 = 47274;

/// Polynomial coefficient α = 0.21875 in Q0.7 (first branch, Fig. 3e).
pub const ALPHA_Q7: u32 = 28;

/// Polynomial coefficient β = 0.4375 in Q0.7 (second branch).
pub const BETA_Q7: u32 = 56;

/// γ₁ = 3.296875 in Q2.7 (first branch offset).
pub const GAMMA1_Q7: u32 = 422;

/// γ₂ = 2.171875 in Q2.7 (second branch offset).
pub const GAMMA2_Q7: u32 = 278;

/// Q2.22 → Q8.7 alignment: right-shift amount is `SHIFT_BIAS - exponent`.
/// Derived from the paper's "difference to the maximum exponent after
/// which exp overflows" (133 for BF16) plus the product's 22 fraction bits
/// minus the 7 kept: 133 + 16 − 7 = 142.
pub const SHIFT_BIAS: i32 = 142;

/// Shifts beyond this empty the product entirely (result = exp(0) = 1).
pub const MAX_SHIFT: i32 = 40;

/// Pipeline depth of one ExpUnit (paper §IV-B: one register level →
/// 2-cycle latency, 1-per-cycle throughput).
pub const EXP_UNIT_LATENCY: u32 = 2;

/// SIMD lanes in the ExpOpGroup for Snitch's 64-bit FPU datapath.
pub const EXP_LANES: usize = 4;
