//! `exps(x)` — the hardware Schraudolph stage (paper Fig. 3d).
//!
//! Decomposes a BF16 input into sign/exponent/mantissa, multiplies the
//! significand by log2(e) in fixed point, aligns the product into a Q8.7
//! integer/fraction split with a single shift + round, and produces the
//! result exponent plus the uncorrected 7-bit fraction that feeds `P(x)`.

use super::consts::{LOG2E_Q15, MAX_SHIFT, SHIFT_BIAS};
use crate::bf16::Bf16;

/// Output of the exps stage: either a resolved special value or a
/// (result-exponent, fraction) pair for the `P(x)` stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpsOut {
    /// NaN in → quiet NaN out.
    Nan(u16),
    /// Overflow (or exp(+inf)) → +inf.
    Overflow,
    /// Underflow (or exp(−inf)) → 0 (BF16 flush-to-zero).
    Underflow,
    /// `exp(x) = 2^(eo-127) * (1 + P(frac/128))`; `frac` is Q0.7.
    Normal { eo: u16, frac: u8 },
}

/// Run the exps stage on a BF16 bit pattern.
pub fn exps(x: Bf16) -> ExpsOut {
    let s = x.sign();
    let e = x.exponent() as i32;
    let m = x.mantissa() as u32;

    if x.is_nan() {
        return ExpsOut::Nan(x.0 | 0x40);
    }
    if x.is_inf() {
        return if s == 0 { ExpsOut::Overflow } else { ExpsOut::Underflow };
    }
    if e == 0 {
        // zero / subnormal input flushes to zero → exp(0) = 1.0
        return ExpsOut::Normal { eo: 127, frac: 0 };
    }

    // x' = x * log2(e) as a Q8.7 fixed-point magnitude
    let sig = 0x80 | m; // Q1.7 significand with implicit one
    let prod = (sig as u64) * (LOG2E_Q15 as u64); // Q2.22
    let shift = SHIFT_BIAS - e;
    let r: u32 = if shift <= 0 {
        // guaranteed overflow magnitude (paper: e beyond 133 always
        // saturates; SHIFT_BIAS folds in the fixed-point alignment)
        1 << 20
    } else if shift > MAX_SHIFT {
        0
    } else {
        ((prod + (1u64 << (shift - 1))) >> shift) as u32 // round-half-up
    };

    let (ri, rf) = if s == 0 {
        (r >> 7, r & 0x7F)
    } else {
        // negative argument: floor crosses down one, fraction complements
        let ri = (r >> 7) + u32::from(r & 0x7F != 0);
        let rf = if r & 0x7F != 0 { (128 - (r & 0x7F)) & 0x7F } else { 0 };
        (ri, rf)
    };

    let eo: i32 = if s == 0 { 127 + ri as i32 } else { 127 - ri as i32 };
    if eo >= 255 {
        ExpsOut::Overflow
    } else if eo <= 0 {
        ExpsOut::Underflow
    } else {
        ExpsOut::Normal { eo: eo as u16, frac: rf as u8 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_maps_to_one() {
        assert_eq!(exps(Bf16(0x0000)), ExpsOut::Normal { eo: 127, frac: 0 });
        assert_eq!(exps(Bf16(0x8000)), ExpsOut::Normal { eo: 127, frac: 0 });
    }

    #[test]
    fn subnormal_flushes_to_one() {
        assert_eq!(exps(Bf16(0x0001)), ExpsOut::Normal { eo: 127, frac: 0 });
    }

    #[test]
    fn infinities() {
        assert_eq!(exps(Bf16(0x7F80)), ExpsOut::Overflow);
        assert_eq!(exps(Bf16(0xFF80)), ExpsOut::Underflow);
    }

    #[test]
    fn nan_quiets() {
        match exps(Bf16(0x7F81)) {
            ExpsOut::Nan(bits) => assert_eq!(bits & 0x40, 0x40),
            other => panic!("want NaN, got {other:?}"),
        }
    }

    #[test]
    fn ln2_lands_on_exact_power() {
        // exp(ln 2) = 2: x' = 1.0 exactly-ish; int = 1, frac ≈ 0
        let x = Bf16::from_f32(std::f32::consts::LN_2);
        match exps(x) {
            ExpsOut::Normal { eo, frac } => {
                assert_eq!(eo, 128, "exponent of 2.0");
                assert!(frac < 4 || frac > 124, "frac near 0, got {frac}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn negative_int_frac_split() {
        // exp(-ln 2) = 0.5 → eo = 126, frac ≈ 0
        let x = Bf16::from_f32(-std::f32::consts::LN_2);
        match exps(x) {
            ExpsOut::Normal { eo, frac } => {
                assert!((125..=127).contains(&eo));
                assert!(frac < 6 || frac > 122);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn large_positive_overflows() {
        assert_eq!(exps(Bf16::from_f32(128.0)), ExpsOut::Overflow);
        assert_eq!(exps(Bf16::from_f32(1e30)), ExpsOut::Overflow);
    }

    #[test]
    fn large_negative_underflows() {
        assert_eq!(exps(Bf16::from_f32(-128.0)), ExpsOut::Underflow);
        assert_eq!(exps(Bf16::from_f32(-1e30)), ExpsOut::Underflow);
    }

    #[test]
    fn tiny_arguments_round_to_one() {
        // |x| < 2^-9: x' rounds to 0 → exp ≈ 1.0
        assert_eq!(exps(Bf16::from_f32(1e-4)), ExpsOut::Normal { eo: 127, frac: 0 });
    }
}
