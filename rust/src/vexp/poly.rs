//! `P(x)` — the mantissa-correction stage (paper Fig. 3e).
//!
//! Approximates `2^x - 1` on x ∈ [0,1) with two fixed-point quadratics
//! selected by the MSB of the 7-bit fraction; `1 - x` is realized as a
//! bitwise complement (`not(x)`) for hardware efficiency.

use super::consts::{ALPHA_Q7, BETA_Q7, GAMMA1_Q7, GAMMA2_Q7};

/// Evaluate the correction polynomial on a Q0.7 fraction.
///
/// Input and output are 7-bit values (0..128). The result is the mantissa
/// field of the final BF16: `exp(x) ≈ 2^int · (1 + P(frac)/128)`.
#[inline]
pub fn poly_q7(f: u32) -> u32 {
    debug_assert!(f < 128);
    let p = if f < 64 {
        // α·x·(x + γ1), all Q-format: Q0.7 × Q2.7 × Q0.7 → Q2.21
        let t = f * (f + GAMMA1_Q7) * ALPHA_Q7;
        (t + (1 << 13)) >> 14 // round-half-up to Q0.7
    } else {
        // not(β·not(x)·(x + γ2))
        let t = (127 - f) * (f + GAMMA2_Q7) * BETA_Q7;
        127 - ((t + (1 << 13)) >> 14)
    };
    p.min(127)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        // P(0) = 0 (exp of an exact power of two has a clean mantissa)
        assert_eq!(poly_q7(0), 0);
        // P(127/128) ≈ 2^(127/128) - 1 ≈ 0.9829 → ≈ 126
        let p = poly_q7(127);
        assert!((124..=127).contains(&p), "P(127) = {p}");
    }

    #[test]
    fn midpoint_continuity() {
        // the two branches must agree closely at the 0.5 seam
        let lo = poly_q7(63) as i32;
        let hi = poly_q7(64) as i32;
        assert!((hi - lo).abs() <= 2, "seam jump {lo} -> {hi}");
    }

    #[test]
    fn approximates_pow2_minus_one() {
        // |P(f)/128 - (2^(f/128) - 1)| small everywhere
        let mut max_err = 0.0f64;
        for f in 0..128u32 {
            let x = f as f64 / 128.0;
            let truth = x.exp2() - 1.0;
            let err = (poly_q7(f) as f64 / 128.0 - truth).abs();
            max_err = max_err.max(err);
        }
        // paper: max relative error 0.78% on exp ⇒ ~0.008 absolute here
        assert!(max_err < 0.01, "max poly err {max_err}");
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut prev = 0;
        for f in 0..128u32 {
            let p = poly_q7(f);
            assert!(p >= prev, "P not monotone at f={f}: {prev} -> {p}");
            prev = p;
        }
    }

    #[test]
    fn output_always_fits_mantissa() {
        for f in 0..128u32 {
            assert!(poly_q7(f) < 128);
        }
    }
}
