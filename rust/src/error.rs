//! Minimal in-tree error type (the offline crate cache has no `anyhow`):
//! a string-backed error, a `Result` alias, and `err!`/`bail!` macros plus
//! a `Context` extension trait covering the handful of patterns the
//! runtime and CLI need.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

use std::fmt;

/// A string-backed error with an optional cause chain (flattened).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// `anyhow::Context`-style adapters for results and options.
pub trait Context<T> {
    /// Attach a static message, keeping the underlying cause.
    fn context(self, msg: &str) -> Result<T>;
    /// Attach a lazily-built message, keeping the underlying cause.
    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: &str) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: &str) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context<F: FnOnce() -> String>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_wraps_causes() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("opening artifact").unwrap_err();
        assert!(e.0.contains("opening artifact"));
        assert!(e.0.contains("gone"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_macro_formats() {
        fn f(x: u32) -> Result<()> {
            if x > 2 {
                bail!("x too large: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().0, "x too large: 9");
    }
}
