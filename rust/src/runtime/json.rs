//! Dependency-free JSON reader (serde is absent from the offline crate
//! cache). Supports the subset the artifact manifest uses: objects,
//! arrays, strings, numbers, booleans, null.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(m) => m.keys().map(|s| s.as_str()).collect(),
            _ => vec![],
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.skip_ws();
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end".into())
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        self.skip_ws();
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.skip_ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    out.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'/' => '/',
                        b'\\' => '\\',
                        b'"' => '"',
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            char::from_u32(
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?,
                            )
                            .ok_or("bad \\u")?
                        }
                        other => return Err(format!("unknown escape \\{}", other as char)),
                    });
                }
                other => out.push(other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = vec![];
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                c => return Err(format!("expected , or ] got {:?}", c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.eat(b':')?;
            map.insert(key, self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => return Err(format!("expected , or }} got {:?}", c as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shapes() {
        let j = Json::parse(
            r#"{"entry_points": {"vexp": {"file": "vexp.hlo.txt",
                "inputs": [{"shape": [4096], "dtype": "float32"}]}},
                "n": -1.5e2, "ok": true, "z": null}"#,
        )
        .unwrap();
        let ep = j.get("entry_points").unwrap().get("vexp").unwrap();
        assert_eq!(ep.get("file").unwrap().as_str().unwrap(), "vexp.hlo.txt");
        let shape = ep.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize().unwrap(), 4096);
        assert_eq!(j.get("n").unwrap().as_f64().unwrap(), -150.0);
        assert_eq!(j.get("ok").unwrap(), &Json::Bool(true));
        assert_eq!(j.get("z").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"c\" A");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
