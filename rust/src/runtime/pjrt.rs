//! XLA/PJRT execution of the AOT artifacts.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::json::Json;

/// Input/output description of one artifact entry point.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    /// (shape, dtype) per input, from the manifest.
    pub inputs: Vec<(Vec<usize>, String)>,
}

/// A compiled-on-load PJRT runtime over the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    artifacts: HashMap<String, Artifact>,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("missing manifest in {dir:?} — run `make artifacts`"))?;
        let json = Json::parse(&manifest).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let eps = json
            .get("entry_points")
            .ok_or_else(|| anyhow!("manifest lacks entry_points"))?;
        let mut artifacts = HashMap::new();
        for name in eps.keys() {
            let ep = eps.get(name).unwrap();
            let file = dir.join(
                ep.get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry {name} lacks file"))?,
            );
            let mut inputs = vec![];
            for inp in ep.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push((shape, dtype));
            }
            artifacts.insert(name.to_string(), Artifact { name: name.to_string(), file, inputs });
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, artifacts, compiled: HashMap::new(), dir })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry_points(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }

    /// Compile an entry point (idempotent; compiled executables cached).
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let art = self
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown entry point {name}"))?;
        let path = art
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {:?}", art.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute with mixed f32/i32 inputs; returns the flattened f32
    /// outputs of the (single-tuple) result.
    pub fn execute(&mut self, name: &str, inputs: &[Input]) -> Result<Vec<f32>> {
        self.compile(name)?;
        let art = &self.artifacts[name];
        if inputs.len() != art.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                art.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, (shape, dtype)) in inputs.iter().zip(&art.inputs) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = match (inp, dtype.as_str()) {
                (Input::F32(data), "float32") => {
                    let n: usize = shape.iter().product();
                    if data.len() != n {
                        bail!("{name}: input length {} != shape {:?}", data.len(), shape);
                    }
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                (Input::I32(data), "int32") => {
                    let n: usize = shape.iter().product();
                    if data.len() != n {
                        bail!("{name}: input length {} != shape {:?}", data.len(), shape);
                    }
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                (got, want) => bail!("{name}: input kind {got:?} vs dtype {want}"),
            };
            literals.push(lit);
        }
        let exe = &self.compiled[name];
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // jax lowering uses return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A runtime input buffer.
#[derive(Debug)]
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}
