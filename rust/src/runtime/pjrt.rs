//! XLA/PJRT execution of the AOT artifacts.
//!
//! Manifest parsing and artifact indexing are always available and
//! dependency-free. The actual XLA execution path needs the xla-rs
//! bindings plus a local XLA install, so it sits behind the `pjrt`
//! cargo feature; without it, `compile`/`execute` return a clear error
//! and callers (CLI, examples) fall back to the bit-exact Rust models.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::{bail, err};

use super::json::Json;

/// Input/output description of one artifact entry point.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    /// (shape, dtype) per input, from the manifest.
    pub inputs: Vec<(Vec<usize>, String)>,
}

/// A compiled-on-load PJRT runtime over the artifact directory.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    artifacts: HashMap<String, Artifact>,
    dir: PathBuf,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("missing manifest in {dir:?} — run `make artifacts`"))?;
        let json = Json::parse(&manifest).map_err(|e| err!("manifest parse: {e}"))?;
        let eps = json
            .get("entry_points")
            .context("manifest lacks entry_points")?;
        let mut artifacts = HashMap::new();
        for name in eps.keys() {
            let ep = eps.get(name).unwrap();
            let file = dir.join(
                ep.get("file")
                    .and_then(Json::as_str)
                    .with_context(|| format!("entry {name} lacks file"))?,
            );
            let mut inputs = vec![];
            for inp in ep.get("inputs").and_then(Json::as_arr).unwrap_or(&[]) {
                let shape: Vec<usize> = inp
                    .get("shape")
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(Json::as_usize)
                    .collect();
                let dtype = inp
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string();
                inputs.push((shape, dtype));
            }
            artifacts.insert(name.to_string(), Artifact { name: name.to_string(), file, inputs });
        }
        Ok(Runtime {
            #[cfg(feature = "pjrt")]
            client: xla::PjRtClient::cpu().map_err(|e| err!("PJRT CPU client: {e:?}"))?,
            #[cfg(feature = "pjrt")]
            compiled: HashMap::new(),
            artifacts,
            dir,
        })
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn entry_points(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn artifact(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.get(name)
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Compile an entry point (idempotent; compiled executables cached).
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let art = self
            .artifacts
            .get(name)
            .with_context(|| format!("unknown entry point {name}"))?;
        let path = art
            .file
            .to_str()
            .with_context(|| format!("non-utf8 path {:?}", art.file))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| err!("loading HLO text {path}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err!("compiling {name}: {e:?}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute with mixed f32/i32 inputs; returns the flattened f32
    /// outputs of the (single-tuple) result.
    pub fn execute(&mut self, name: &str, inputs: &[Input]) -> Result<Vec<f32>> {
        self.compile(name)?;
        let art = &self.artifacts[name];
        if inputs.len() != art.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest wants {}",
                inputs.len(),
                art.inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, (shape, dtype)) in inputs.iter().zip(&art.inputs) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = match (inp, dtype.as_str()) {
                (Input::F32(data), "float32") => {
                    let n: usize = shape.iter().product();
                    if data.len() != n {
                        bail!("{name}: input length {} != shape {:?}", data.len(), shape);
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| err!("{name}: reshape: {e:?}"))?
                }
                (Input::I32(data), "int32") => {
                    let n: usize = shape.iter().product();
                    if data.len() != n {
                        bail!("{name}: input length {} != shape {:?}", data.len(), shape);
                    }
                    xla::Literal::vec1(data)
                        .reshape(&dims)
                        .map_err(|e| err!("{name}: reshape: {e:?}"))?
                }
                (got, want) => bail!("{name}: input kind {got:?} vs dtype {want}"),
            };
            literals.push(lit);
        }
        let exe = &self.compiled[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err!("{name}: execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| err!("{name}: sync: {e:?}"))?;
        // jax lowering uses return_tuple=True → unwrap the 1-tuple
        let out = result.to_tuple1().map_err(|e| err!("{name}: tuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| err!("{name}: to_vec: {e:?}"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Without the `pjrt` feature there is no XLA client to compile on.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if !self.artifacts.contains_key(name) {
            bail!("unknown entry point {name}");
        }
        bail!("PJRT execution requires the `pjrt` cargo feature (xla-rs bindings)")
    }

    /// Without the `pjrt` feature execution always errors; callers fall
    /// back to the bit-exact Rust models.
    pub fn execute(&mut self, name: &str, _inputs: &[Input]) -> Result<Vec<f32>> {
        self.compile(name).map(|_| vec![])
    }
}

/// A runtime input buffer.
#[derive(Debug)]
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}
