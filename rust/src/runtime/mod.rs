//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the XLA CPU client. Python never runs here — this is
//! the request path.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO **text** in,
//! `HloModuleProto::from_text_file` → `XlaComputation` → compile once →
//! execute many. Artifacts are indexed by `manifest.json`, read with the
//! dependency-free mini JSON reader in [`json`].
//!
//! The XLA execution path is gated behind the `pjrt` cargo feature (the
//! bindings need a local XLA install); manifest indexing always works.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod json;
pub mod pjrt;

pub use pjrt::{Artifact, Runtime};
