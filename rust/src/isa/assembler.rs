//! A tiny structured assembler for building simulator programs.
//!
//! Kernels (rust/src/kernels) construct their instruction streams through
//! this builder, which handles forward-label resolution and FREP body
//! validation, so the listings read close to the paper's Fig. 4 assembly.

use super::instr::{Instr, SsrPattern};
use super::regs::{FReg, IReg};

/// An unresolved branch target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Label(usize);

/// Program builder.
#[derive(Default)]
pub struct Asm {
    instrs: Vec<Instr>,
    labels: Vec<Option<usize>>,
    patches: Vec<(usize, Label)>,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a label to be bound later (forward branches).
    pub fn label(&mut self) -> Label {
        self.labels.push(None);
        Label(self.labels.len() - 1)
    }

    /// Bind `label` to the current position.
    pub fn bind(&mut self, label: Label) {
        assert!(self.labels[label.0].is_none(), "label bound twice");
        self.labels[label.0] = Some(self.instrs.len());
    }

    pub fn push(&mut self, i: Instr) -> &mut Self {
        self.instrs.push(i);
        self
    }

    pub fn len(&self) -> usize {
        self.instrs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.instrs.is_empty()
    }

    /// Resolve all labels and return the finished program.
    ///
    /// Panics on unbound labels or FREP bodies containing non-FP
    /// instructions (both are programming errors in a kernel builder).
    pub fn finish(mut self) -> Vec<Instr> {
        for (pos, label) in std::mem::take(&mut self.patches) {
            let target = self.labels[label.0].expect("unbound label");
            match &mut self.instrs[pos] {
                Instr::Bnez { target: t, .. }
                | Instr::Bgeu { target: t, .. }
                | Instr::Blt { target: t, .. }
                | Instr::J { target: t } => *t = target,
                other => panic!("patch on non-branch {other:?}"),
            }
        }
        // validate FREP bodies
        for (i, instr) in self.instrs.iter().enumerate() {
            if let Instr::Frep { n_instr, .. } = instr {
                for k in 0..*n_instr as usize {
                    let body = self
                        .instrs
                        .get(i + 1 + k)
                        .unwrap_or_else(|| panic!("FREP body runs past end at {i}"));
                    assert!(body.is_fp(), "non-FP instr {body:?} in FREP body");
                }
            }
        }
        self.instrs
    }

    // --- integer ------------------------------------------------------------
    pub fn li(&mut self, rd: IReg, imm: i64) -> &mut Self {
        self.push(Instr::Li { rd, imm })
    }
    pub fn addi(&mut self, rd: IReg, rs1: IReg, imm: i32) -> &mut Self {
        self.push(Instr::Addi { rd, rs1, imm })
    }
    pub fn add(&mut self, rd: IReg, rs1: IReg, rs2: IReg) -> &mut Self {
        self.push(Instr::Add { rd, rs1, rs2 })
    }
    pub fn sub(&mut self, rd: IReg, rs1: IReg, rs2: IReg) -> &mut Self {
        self.push(Instr::Sub { rd, rs1, rs2 })
    }
    pub fn slli(&mut self, rd: IReg, rs1: IReg, imm: u32) -> &mut Self {
        self.push(Instr::Slli { rd, rs1, imm })
    }
    pub fn srli(&mut self, rd: IReg, rs1: IReg, imm: u32) -> &mut Self {
        self.push(Instr::Srli { rd, rs1, imm })
    }
    pub fn srai(&mut self, rd: IReg, rs1: IReg, imm: u32) -> &mut Self {
        self.push(Instr::Srai { rd, rs1, imm })
    }
    pub fn j(&mut self, label: Label) -> &mut Self {
        self.patches.push((self.instrs.len(), label));
        self.push(Instr::J { target: usize::MAX })
    }
    pub fn andi(&mut self, rd: IReg, rs1: IReg, imm: i32) -> &mut Self {
        self.push(Instr::Andi { rd, rs1, imm })
    }
    pub fn bnez(&mut self, rs1: IReg, label: Label) -> &mut Self {
        self.patches.push((self.instrs.len(), label));
        self.push(Instr::Bnez { rs1, target: usize::MAX })
    }
    pub fn bgeu(&mut self, rs1: IReg, rs2: IReg, label: Label) -> &mut Self {
        self.patches.push((self.instrs.len(), label));
        self.push(Instr::Bgeu { rs1, rs2, target: usize::MAX })
    }
    pub fn blt(&mut self, rs1: IReg, rs2: IReg, label: Label) -> &mut Self {
        self.patches.push((self.instrs.len(), label));
        self.push(Instr::Blt { rs1, rs2, target: usize::MAX })
    }

    // --- memory ---------------------------------------------------------------
    pub fn flh(&mut self, fd: FReg, base: IReg, offset: i32) -> &mut Self {
        self.push(Instr::Flh { fd, base, offset })
    }
    pub fn fsh(&mut self, fs: FReg, base: IReg, offset: i32) -> &mut Self {
        self.push(Instr::Fsh { fs, base, offset })
    }
    pub fn fld(&mut self, fd: FReg, base: IReg, offset: i32) -> &mut Self {
        self.push(Instr::Fld { fd, base, offset })
    }
    pub fn fsd(&mut self, fs: FReg, base: IReg, offset: i32) -> &mut Self {
        self.push(Instr::Fsd { fs, base, offset })
    }

    // --- scalar BF16 ------------------------------------------------------------
    pub fn fadd_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FaddH { fd, fs1: a, fs2: b })
    }
    pub fn fsub_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FsubH { fd, fs1: a, fs2: b })
    }
    pub fn fmul_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FmulH { fd, fs1: a, fs2: b })
    }
    pub fn fmax_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FmaxH { fd, fs1: a, fs2: b })
    }
    pub fn fdiv_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FdivH { fd, fs1: a, fs2: b })
    }
    pub fn fmadd_h(&mut self, fd: FReg, a: FReg, b: FReg, c: FReg) -> &mut Self {
        self.push(Instr::FmaddH { fd, fs1: a, fs2: b, fs3: c })
    }

    // --- FP64 ------------------------------------------------------------------
    pub fn fadd_d(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FaddD { fd, fs1: a, fs2: b })
    }
    pub fn fsub_d(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FsubD { fd, fs1: a, fs2: b })
    }
    pub fn fmv_x_d(&mut self, rd: IReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FmvXD { rd, fs1 })
    }
    pub fn fmul_d(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::FmulD { fd, fs1: a, fs2: b })
    }
    pub fn fmadd_d(&mut self, fd: FReg, a: FReg, b: FReg, c: FReg) -> &mut Self {
        self.push(Instr::FmaddD { fd, fs1: a, fs2: b, fs3: c })
    }
    pub fn fcvt_d_h(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtDH { fd, fs1 })
    }
    pub fn fcvt_h_d(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtHD { fd, fs1 })
    }
    pub fn fcvt_s_h(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtSH { fd, fs1 })
    }
    pub fn fcvt_d_s(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtDS { fd, fs1 })
    }
    pub fn fcvt_s_d(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtSD { fd, fs1 })
    }
    pub fn fcvt_h_s(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FcvtHS { fd, fs1 })
    }

    // --- SIMD --------------------------------------------------------------------
    pub fn vfadd_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfaddH { fd, fs1: a, fs2: b })
    }
    pub fn vfsub_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfsubH { fd, fs1: a, fs2: b })
    }
    pub fn vfmul_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfmulH { fd, fs1: a, fs2: b })
    }
    pub fn vfmax_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfmaxH { fd, fs1: a, fs2: b })
    }
    pub fn vfmac_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfmacH { fd, fs1: a, fs2: b })
    }
    pub fn vfsgnj_h(&mut self, fd: FReg, a: FReg, b: FReg) -> &mut Self {
        self.push(Instr::VfsgnjH { fd, fs1: a, fs2: b })
    }
    pub fn vfsum_h(&mut self, fd: FReg, a: FReg) -> &mut Self {
        self.push(Instr::VfsumH { fd, fs1: a })
    }
    pub fn vfmaxred_h(&mut self, fd: FReg, a: FReg) -> &mut Self {
        self.push(Instr::VfmaxRedH { fd, fs1: a })
    }
    pub fn vfrep_h(&mut self, fd: FReg, a: FReg) -> &mut Self {
        self.push(Instr::VfrepH { fd, fs1: a })
    }
    pub fn fmv_x_w(&mut self, rd: IReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FmvXW { rd, fs1 })
    }
    pub fn fmv_w_x(&mut self, fd: FReg, rs1: IReg) -> &mut Self {
        self.push(Instr::FmvWX { fd, rs1 })
    }
    pub fn fmv_d_x(&mut self, fd: FReg, rs1: IReg) -> &mut Self {
        self.push(Instr::FmvDX { fd, rs1 })
    }

    // --- EXP extension --------------------------------------------------------------
    pub fn fexp_h(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::FexpH { fd, fs1 })
    }
    pub fn vfexp_h(&mut self, fd: FReg, fs1: FReg) -> &mut Self {
        self.push(Instr::VfexpH { fd, fs1 })
    }

    // --- FREP / SSR -----------------------------------------------------------------
    pub fn frep(&mut self, n_iter: IReg, n_instr: u32) -> &mut Self {
        self.push(Instr::Frep { n_iter, n_instr })
    }
    pub fn ssr_cfg(&mut self, ssr: u8, cfg: SsrPattern) -> &mut Self {
        self.push(Instr::SsrCfg { ssr, cfg })
    }
    pub fn ssr_enable(&mut self) -> &mut Self {
        self.push(Instr::SsrEnable)
    }
    pub fn ssr_disable(&mut self) -> &mut Self {
        self.push(Instr::SsrDisable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;

    #[test]
    fn forward_and_backward_labels_resolve() {
        let mut a = Asm::new();
        let top = a.label();
        let out = a.label();
        a.li(A0, 4);
        a.bind(top);
        a.addi(A0, A0, -1);
        a.bgeu(ZERO, A0, out); // exit when a0 == 0
        a.bnez(A0, top);
        a.bind(out);
        let prog = a.finish();
        match prog[2] {
            Instr::Bgeu { target, .. } => assert_eq!(target, 4),
            ref other => panic!("{other:?}"),
        }
        match prog[3] {
            Instr::Bnez { target, .. } => assert_eq!(target, 1),
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "unbound label")]
    fn unbound_label_panics() {
        let mut a = Asm::new();
        let l = a.label();
        a.bnez(A0, l);
        a.finish();
    }

    #[test]
    #[should_panic(expected = "non-FP instr")]
    fn frep_body_must_be_fp() {
        let mut a = Asm::new();
        a.li(A0, 2);
        a.frep(A0, 2);
        a.vfadd_h(FT3, FT3, FT0);
        a.addi(A0, A0, 1); // illegal inside FREP
        a.finish();
    }

    #[test]
    fn frep_body_validates_ok() {
        let mut a = Asm::new();
        a.li(A0, 2);
        a.frep(A0, 2);
        a.vfadd_h(FT3, FT3, FT0);
        a.vfexp_h(FT4, FT3);
        assert_eq!(a.finish().len(), 4);
    }
}
