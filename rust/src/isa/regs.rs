//! Register names for the Snitch core model (RV32 integer + 64-bit FP).

/// Integer register (x0..x31). `x0` is hardwired to zero.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct IReg(pub u8);

/// Floating-point register (f0..f31), 64 bits wide; holds an FP64 value,
/// a packed 4×BF16 SIMD vector, or a scalar BF16 in the low lane.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct FReg(pub u8);

impl IReg {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl FReg {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

// Conventional ABI-ish names used by the kernel builders.
pub const ZERO: IReg = IReg(0);
pub const RA: IReg = IReg(1);
pub const SP: IReg = IReg(2);
pub const A0: IReg = IReg(10);
pub const A1: IReg = IReg(11);
pub const A2: IReg = IReg(12);
pub const A3: IReg = IReg(13);
pub const A4: IReg = IReg(14);
pub const A5: IReg = IReg(15);
pub const T0: IReg = IReg(5);
pub const T1: IReg = IReg(6);
pub const T2: IReg = IReg(7);
pub const T3: IReg = IReg(28);
pub const T4: IReg = IReg(29);
pub const T5: IReg = IReg(30);
pub const T6: IReg = IReg(31);

/// SSR-mapped FP registers: reads/writes of ft0..ft2 stream memory when
/// SSRs are enabled (paper §II / [24]).
pub const FT0: FReg = FReg(0);
pub const FT1: FReg = FReg(1);
pub const FT2: FReg = FReg(2);
pub const FT3: FReg = FReg(3);
pub const FT4: FReg = FReg(4);
pub const FT5: FReg = FReg(5);
pub const FT6: FReg = FReg(6);
pub const FT7: FReg = FReg(7);
pub const FS0: FReg = FReg(8);
pub const FS1: FReg = FReg(9);
pub const FS2: FReg = FReg(18);
pub const FS3: FReg = FReg(19);
pub const FS4: FReg = FReg(20);
pub const FS5: FReg = FReg(21);
/// ft8..ft11 (f28..f31): clobbered by the modeled libm ABI spills.
pub const FT8: FReg = FReg(28);
pub const FT9: FReg = FReg(29);
pub const FT10: FReg = FReg(30);
pub const FT11: FReg = FReg(31);
pub const FA0: FReg = FReg(10);
pub const FA1: FReg = FReg(11);
pub const FA2: FReg = FReg(12);
pub const FA3: FReg = FReg(13);
pub const FA4: FReg = FReg(14);
pub const FA5: FReg = FReg(15);
