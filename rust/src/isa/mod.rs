//! Instruction-set layer: Table-I encodings for FEXP/VFEXP, the simulator
//! instruction enum, register names, and a structured assembler used by
//! the kernel builders.

pub mod assembler;
pub mod encoding;
pub mod instr;
pub mod regs;

pub use assembler::{Asm, Label};
pub use encoding::{decode, encode_fexp, encode_vfexp, ExpInstr};
pub use instr::{Class, Instr, SsrPattern};
pub use regs::{FReg, IReg};
