//! Instruction-set layer: Table-I encodings for FEXP/VFEXP, the simulator
//! instruction enum, register names, and a structured assembler used by
//! the kernel builders.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod assembler;
pub mod encoding;
pub mod instr;
pub mod regs;

pub use assembler::{Asm, Label};
pub use encoding::{decode, encode_fexp, encode_vfexp, ExpInstr};
pub use instr::{Class, Instr, SsrPattern};
pub use regs::{FReg, IReg};
