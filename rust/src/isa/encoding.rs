//! Binary encodings of the FEXP / VFEXP custom instructions (paper Table I).
//!
//! ```text
//! FEXP  rd, rs1:  0011111 00000 {rs1} 000 {rd} 1010011
//! VFEXP rd, rs1:  1011111 00000 {rs1} 000 {rd} 1010011
//! ```
//!
//! Both live in the OP-FP major opcode (0x53); the MSB of the instruction
//! word distinguishes scalar from packed-SIMD. rd/rs1 are 5-bit indices
//! into the 32×64-bit FP register file.

use super::regs::FReg;

/// RISC-V OP-FP major opcode.
pub const OPCODE_OP_FP: u32 = 0b101_0011;

/// funct7 for the scalar FEXP (0011111).
pub const FUNCT7_FEXP: u32 = 0b001_1111;

/// funct7 for the packed-SIMD VFEXP (1011111 — MSB set).
pub const FUNCT7_VFEXP: u32 = 0b101_1111;

/// Encode `FEXP rd, rs1`.
pub fn encode_fexp(rd: FReg, rs1: FReg) -> u32 {
    encode_r(FUNCT7_FEXP, 0, rs1.0 as u32, 0b000, rd.0 as u32)
}

/// Encode `VFEXP rd, rs1`.
pub fn encode_vfexp(rd: FReg, rs1: FReg) -> u32 {
    encode_r(FUNCT7_VFEXP, 0, rs1.0 as u32, 0b000, rd.0 as u32)
}

fn encode_r(funct7: u32, rs2: u32, rs1: u32, funct3: u32, rd: u32) -> u32 {
    (funct7 << 25) | (rs2 << 20) | (rs1 << 15) | (funct3 << 12) | (rd << 7) | OPCODE_OP_FP
}

/// A decoded EXP-extension instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExpInstr {
    Fexp { rd: FReg, rs1: FReg },
    Vfexp { rd: FReg, rs1: FReg },
}

/// Decode a 32-bit word; `None` if it is not FEXP/VFEXP.
pub fn decode(word: u32) -> Option<ExpInstr> {
    if word & 0x7F != OPCODE_OP_FP {
        return None;
    }
    let funct7 = word >> 25;
    let funct3 = (word >> 12) & 0x7;
    let rs2 = (word >> 20) & 0x1F;
    if funct3 != 0 || rs2 != 0 {
        return None;
    }
    let rd = FReg(((word >> 7) & 0x1F) as u8);
    let rs1 = FReg(((word >> 15) & 0x1F) as u8);
    match funct7 {
        FUNCT7_FEXP => Some(ExpInstr::Fexp { rd, rs1 }),
        FUNCT7_VFEXP => Some(ExpInstr::Vfexp { rd, rs1 }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table I, written out bit-for-bit.
    #[test]
    fn table1_bit_patterns() {
        // FEXP f1, f2: 0011111 00000 00010 000 00001 1010011
        let w = encode_fexp(FReg(1), FReg(2));
        assert_eq!(w, 0b0011111_00000_00010_000_00001_1010011);
        // VFEXP f3, f4: 1011111 00000 00100 000 00011 1010011
        let v = encode_vfexp(FReg(3), FReg(4));
        assert_eq!(v, 0b1011111_00000_00100_000_00011_1010011);
    }

    #[test]
    fn msb_distinguishes_simd() {
        let s = encode_fexp(FReg(0), FReg(0));
        let v = encode_vfexp(FReg(0), FReg(0));
        assert_eq!(s >> 31, 0);
        assert_eq!(v >> 31, 1);
        assert_eq!(s & 0x7FFF_FFFF, v & 0x7FFF_FFFF);
    }

    #[test]
    fn roundtrip_all_registers() {
        for rd in 0..32u8 {
            for rs1 in 0..32u8 {
                let f = encode_fexp(FReg(rd), FReg(rs1));
                assert_eq!(
                    decode(f),
                    Some(ExpInstr::Fexp { rd: FReg(rd), rs1: FReg(rs1) })
                );
                let v = encode_vfexp(FReg(rd), FReg(rs1));
                assert_eq!(
                    decode(v),
                    Some(ExpInstr::Vfexp { rd: FReg(rd), rs1: FReg(rs1) })
                );
            }
        }
    }

    #[test]
    fn rejects_foreign_words() {
        assert_eq!(decode(0x0000_0013), None); // addi x0,x0,0
        assert_eq!(decode(0x0000_0053), None); // fadd.s with funct7=0
        // right funct7, wrong funct3
        let w = (FUNCT7_FEXP << 25) | (1 << 12) | OPCODE_OP_FP;
        assert_eq!(decode(w), None);
        // right funct7, rs2 != 0
        let w = (FUNCT7_FEXP << 25) | (3 << 20) | OPCODE_OP_FP;
        assert_eq!(decode(w), None);
    }

    #[test]
    fn base_opcode_is_op_fp() {
        assert_eq!(encode_fexp(FReg(31), FReg(31)) & 0x7F, 0x53);
    }
}
