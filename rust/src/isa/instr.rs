//! The simulator's instruction set: the subset of Snitch's RV32IMAFD +
//! SIMD + FREP/SSR + EXP extensions that the paper's kernels use.
//!
//! `H` suffix = BF16 ("half" in the paper's listings is BF16 throughout);
//! `D` suffix = FP64 (used by the baseline software exponential);
//! `Vf*` = packed-SIMD over 4 BF16 lanes in a 64-bit FP register.

use super::regs::{FReg, IReg};

/// Instruction-class tag used by the timing and energy models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Class {
    IntAlu,
    Branch,
    FpLoad,
    FpStore,
    FpScalarH,
    FpScalarD,
    FpDivH,
    FpSimd,
    FpExp,
    Ssr,
    Frep,
    Misc,
}

/// One simulated instruction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Instr {
    // --- integer core -----------------------------------------------------
    /// rd = rs1 + imm
    Addi { rd: IReg, rs1: IReg, imm: i32 },
    /// rd = rs1 + rs2
    Add { rd: IReg, rs1: IReg, rs2: IReg },
    /// rd = rs1 - rs2
    Sub { rd: IReg, rs1: IReg, rs2: IReg },
    /// rd = rs1 << imm
    Slli { rd: IReg, rs1: IReg, imm: u32 },
    /// rd = rs1 & imm
    Andi { rd: IReg, rs1: IReg, imm: i32 },
    /// rd = rs1 >> imm (logical)
    Srli { rd: IReg, rs1: IReg, imm: u32 },
    /// rd = rs1 >> imm (arithmetic)
    Srai { rd: IReg, rs1: IReg, imm: u32 },
    /// unconditional jump
    J { target: usize },
    /// load immediate (li pseudo-instruction)
    Li { rd: IReg, imm: i64 },
    /// branch to `target` (program index) if rs1 != 0
    Bnez { rs1: IReg, target: usize },
    /// branch if rs1 >= rs2 (unsigned)
    Bgeu { rs1: IReg, rs2: IReg, target: usize },
    /// branch if rs1 < rs2 (signed)
    Blt { rs1: IReg, rs2: IReg, target: usize },

    // --- FP loads/stores (SPM) ---------------------------------------------
    /// load BF16 into the low lane of fd
    Flh { fd: FReg, base: IReg, offset: i32 },
    /// store low-lane BF16
    Fsh { fs: FReg, base: IReg, offset: i32 },
    /// load 64-bit (packed 4×BF16 or FP64)
    Fld { fd: FReg, base: IReg, offset: i32 },
    /// store 64-bit
    Fsd { fs: FReg, base: IReg, offset: i32 },

    // --- scalar BF16 -------------------------------------------------------
    FaddH { fd: FReg, fs1: FReg, fs2: FReg },
    FsubH { fd: FReg, fs1: FReg, fs2: FReg },
    FmulH { fd: FReg, fs1: FReg, fs2: FReg },
    FmaxH { fd: FReg, fs1: FReg, fs2: FReg },
    /// fd = fs1 / fs2 (the FPU's iterative DIVSQRT block)
    FdivH { fd: FReg, fs1: FReg, fs2: FReg },
    /// fd = fs1 * fs2 + fs3
    FmaddH { fd: FReg, fs1: FReg, fs2: FReg, fs3: FReg },

    // --- scalar FP64 (baseline software exp path) ---------------------------
    FaddD { fd: FReg, fs1: FReg, fs2: FReg },
    FsubD { fd: FReg, fs1: FReg, fs2: FReg },
    FmulD { fd: FReg, fs1: FReg, fs2: FReg },
    FmaddD { fd: FReg, fs1: FReg, fs2: FReg, fs3: FReg },
    /// convert BF16 (low lane) -> FP64
    FcvtDH { fd: FReg, fs1: FReg },
    /// convert FP64 -> BF16 (low lane), RNE
    FcvtHD { fd: FReg, fs1: FReg },
    /// convert BF16 (low lane) -> FP32 (low 32 bits)
    FcvtSH { fd: FReg, fs1: FReg },
    /// convert FP32 (low 32 bits) -> FP64
    FcvtDS { fd: FReg, fs1: FReg },
    /// convert FP64 -> FP32 (low 32 bits), RNE
    FcvtSD { fd: FReg, fs1: FReg },
    /// convert FP32 (low 32 bits) -> BF16 (low lane), RNE
    FcvtHS { fd: FReg, fs1: FReg },
    /// move FP bits to integer register (low 32, sign-extended)
    FmvXW { rd: IReg, fs1: FReg },
    /// move full 64 FP bits to integer register
    FmvXD { rd: IReg, fs1: FReg },
    /// move integer bits into FP register (low 32, upper bits cleared)
    FmvWX { fd: FReg, rs1: IReg },
    /// move full 64 integer bits into FP register
    FmvDX { fd: FReg, rs1: IReg },

    // --- packed SIMD (4×BF16) ------------------------------------------------
    VfaddH { fd: FReg, fs1: FReg, fs2: FReg },
    VfsubH { fd: FReg, fs1: FReg, fs2: FReg },
    VfmulH { fd: FReg, fs1: FReg, fs2: FReg },
    VfmaxH { fd: FReg, fs1: FReg, fs2: FReg },
    /// fd += fs1 * fs2 (SIMD MAC, the GEMM workhorse `vfmac.h`)
    VfmacH { fd: FReg, fs1: FReg, fs2: FReg },
    /// sign-inject copy (used as a lane move in Fig. 4 listings)
    VfsgnjH { fd: FReg, fs1: FReg, fs2: FReg },
    /// horizontal reduce: low lane of fd = sum of 4 lanes of fs1 (vfsum)
    VfsumH { fd: FReg, fs1: FReg },
    /// horizontal reduce: low lane of fd = max of 4 lanes of fs1
    VfmaxRedH { fd: FReg, fs1: FReg },
    /// broadcast the low lane of fs1 to all 4 lanes (vfcpka-style)
    VfrepH { fd: FReg, fs1: FReg },

    // --- EXP extension (this paper) -----------------------------------------
    /// scalar BF16 exponential, 2-cycle latency
    FexpH { fd: FReg, fs1: FReg },
    /// packed-SIMD BF16 exponential, 4 lanes, 2-cycle latency
    VfexpH { fd: FReg, fs1: FReg },

    // --- FREP / SSR ----------------------------------------------------------
    /// hardware loop: repeat the next `n_instr` FP instructions `n_iter`
    /// times (n_iter read from an integer register)
    Frep { n_iter: IReg, n_instr: u32 },
    /// configure SSR `ssr` as a 2D affine read/write stream
    SsrCfg { ssr: u8, cfg: SsrPattern },
    /// enable/disable SSR register mapping on ft0..ft2
    SsrEnable,
    SsrDisable,

    Nop,
}

/// A 3D affine address pattern for one stream semantic register
/// (the SSR hardware supports up to 4 nested dimensions [24]).
///
/// The stream yields `reps2 × reps1 × reps0` 64-bit beats at
/// `addr = base + i2*stride2 + i1*stride1 + i0*stride0` (byte strides).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SsrPattern {
    pub base: u32,
    pub stride0: i32,
    pub reps0: u32,
    pub stride1: i32,
    pub reps1: u32,
    pub stride2: i32,
    pub reps2: u32,
    pub write: bool,
}

impl SsrPattern {
    /// Contiguous 1D read of `n` 64-bit beats starting at `base`.
    pub fn read1d(base: u32, n: u32) -> Self {
        SsrPattern {
            base, stride0: 8, reps0: n,
            stride1: 0, reps1: 1, stride2: 0, reps2: 1, write: false,
        }
    }

    /// Contiguous 1D write of `n` 64-bit beats starting at `base`.
    pub fn write1d(base: u32, n: u32) -> Self {
        SsrPattern {
            base, stride0: 8, reps0: n,
            stride1: 0, reps1: 1, stride2: 0, reps2: 1, write: true,
        }
    }

    /// 2D read: `reps1` blocks of `reps0` beats.
    pub fn read2d(base: u32, stride0: i32, reps0: u32, stride1: i32, reps1: u32) -> Self {
        SsrPattern { base, stride0, reps0, stride1, reps1, stride2: 0, reps2: 1, write: false }
    }

    /// 3D read: `reps2` planes of `reps1` blocks of `reps0` beats.
    #[allow(clippy::too_many_arguments)]
    pub fn read3d(
        base: u32, stride0: i32, reps0: u32, stride1: i32, reps1: u32,
        stride2: i32, reps2: u32,
    ) -> Self {
        SsrPattern { base, stride0, reps0, stride1, reps1, stride2, reps2, write: false }
    }

    /// Total number of 64-bit beats in the pattern.
    pub fn beats(&self) -> u64 {
        self.reps0 as u64 * self.reps1 as u64 * self.reps2 as u64
    }
}

impl Instr {
    /// Timing/energy class of this instruction.
    pub fn class(&self) -> Class {
        use Instr::*;
        match self {
            Addi { .. } | Add { .. } | Sub { .. } | Slli { .. } | Andi { .. }
            | Srli { .. } | Srai { .. } | Li { .. } => Class::IntAlu,
            Bnez { .. } | Bgeu { .. } | Blt { .. } | J { .. } => Class::Branch,
            Flh { .. } | Fld { .. } => Class::FpLoad,
            Fsh { .. } | Fsd { .. } => Class::FpStore,
            FaddH { .. } | FsubH { .. } | FmulH { .. } | FmaxH { .. }
            | FmaddH { .. } => Class::FpScalarH,
            FdivH { .. } => Class::FpDivH,
            FaddD { .. } | FsubD { .. } | FmulD { .. } | FmaddD { .. } | FcvtDH { .. }
            | FcvtHD { .. } | FcvtSH { .. } | FcvtDS { .. } | FcvtSD { .. }
            | FcvtHS { .. } | FmvXW { .. } | FmvXD { .. } | FmvWX { .. }
            | FmvDX { .. } => Class::FpScalarD,
            VfaddH { .. } | VfsubH { .. } | VfmulH { .. } | VfmaxH { .. }
            | VfmacH { .. } | VfsgnjH { .. } | VfsumH { .. } | VfmaxRedH { .. }
            | VfrepH { .. } => Class::FpSimd,
            FexpH { .. } | VfexpH { .. } => Class::FpExp,
            SsrCfg { .. } | SsrEnable | SsrDisable => Class::Ssr,
            Frep { .. } => Class::Frep,
            Nop => Class::Misc,
        }
    }

    /// Is this an FPU-sequencer instruction (legal inside an FREP body)?
    pub fn is_fp(&self) -> bool {
        matches!(
            self.class(),
            Class::FpScalarH | Class::FpScalarD | Class::FpDivH | Class::FpSimd | Class::FpExp
        )
    }
}
