//! Tile-level memoization for the fast path (DESIGN.md §11).
//!
//! A "tile" is one cluster-level [`Program`](crate::exec::program::Program)
//! execution: the serving/bench layers re-run the *same* decoded micro-op
//! stream against the *same* SPM image thousands of times (decode steps,
//! layer repeats, steady-state slices). The memo caches the complete
//! effect of such an execution — the [`ClusterStats`] delta and the SPM
//! after-image — and replays it with a hash probe + byte compare +
//! byte copy instead of re-executing the micro-ops.
//!
//! Cache key: `(decoded-stream identity, FNV-1a hash of SPM bytes)`.
//! Stream identity is the address of the shared `Arc<Vec<DecodedProgram>>`
//! inside `Program` — programs built through `ProgramCache` share storage,
//! so identical kernels compare equal by pointer. Each entry pins its Arc,
//! so an address can never be recycled by a different program while the
//! entry lives (no ABA). Hash collisions are resolved by an exact
//! before-image compare, so replay is *bit-exact by construction*:
//! a replayed result is only ever the recording of an identical
//! (program, SPM) pair. Values differ → compare fails → miss →
//! re-execute (the invalidation rule).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::decode::DecodedProgram;
use super::mem::Mem;
use super::stats::ClusterStats;

/// Cap on live entries; each entry holds two SPM images (~256 KiB), so
/// the default cap bounds the memo at ~64 MiB. When full, new tiles
/// simply execute unmemoized — correctness never depends on capacity.
pub const MEMO_CAP: usize = 256;

/// One recorded tile execution.
struct MemoEntry {
    /// Pins the decoded stream so its address stays unique (see module docs).
    _prog: Arc<Vec<DecodedProgram>>,
    /// Full SPM image the execution started from.
    before: Vec<u8>,
    /// Full SPM image the execution ended with.
    after: Vec<u8>,
    /// Stats delta produced by the execution.
    stats: ClusterStats,
}

/// The tile memo. Shared across clusters via [`SharedMemo`]; the lock is
/// held only for the probe/record itself, never across an execution.
#[derive(Default)]
pub struct TileMemo {
    entries: HashMap<(usize, u64), Vec<MemoEntry>>,
    len: usize,
    /// Successful replays.
    pub hits: u64,
    /// Probes that fell through to real execution.
    pub misses: u64,
}

/// FNV-1a over the SPM image: cheap prefilter for the exact compare
/// (also the SPM checksum the fault layer uses to detect corruption).
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl TileMemo {
    /// An empty memo.
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of cached tile executions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn key(prog: &Arc<Vec<DecodedProgram>>, spm_hash: u64) -> (usize, u64) {
        (Arc::as_ptr(prog) as *const u8 as usize, spm_hash)
    }

    /// Try to replay a cached execution of `prog` against the current
    /// contents of `spm`. On a hit, writes the after-image into `spm`
    /// and returns the recorded stats delta; on a miss returns `None`
    /// (the caller executes for real and should [`record`](Self::record)).
    pub fn replay(
        &mut self,
        prog: &Arc<Vec<DecodedProgram>>,
        spm: &mut Mem,
    ) -> Option<ClusterStats> {
        let image = spm.read_bytes(0, spm.len());
        let key = Self::key(prog, fnv1a(image));
        if let Some(cands) = self.entries.get(&key) {
            for e in cands {
                if e.before == image {
                    let after = e.after.clone();
                    let stats = e.stats.clone();
                    spm.load_bytes(0, &after);
                    self.hits += 1;
                    return Some(stats);
                }
            }
        }
        self.misses += 1;
        None
    }

    /// Record an execution: `before` is the SPM image the run started
    /// from (captured by the caller pre-execution), `spm` holds the
    /// post-execution state, `stats` is the delta the run produced.
    /// Silently drops the entry once [`MEMO_CAP`] is reached.
    pub fn record(
        &mut self,
        prog: &Arc<Vec<DecodedProgram>>,
        before: Vec<u8>,
        spm: &Mem,
        stats: &ClusterStats,
    ) {
        if self.len >= MEMO_CAP {
            return;
        }
        let key = Self::key(prog, fnv1a(&before));
        let cands = self.entries.entry(key).or_default();
        // A concurrent cluster may have recorded the same tile between
        // our probe and this record; keep the first copy only.
        if cands.iter().any(|e| e.before == before) {
            return;
        }
        cands.push(MemoEntry {
            _prog: Arc::clone(prog),
            before,
            after: spm.read_bytes(0, spm.len()).to_vec(),
            stats: stats.clone(),
        });
        self.len += 1;
    }
}

/// A memo shared across clusters (and across the threaded cluster pool).
pub type SharedMemo = Arc<Mutex<TileMemo>>;

/// Construct an empty [`SharedMemo`].
pub fn shared_memo() -> SharedMemo {
    Arc::new(Mutex::new(TileMemo::new()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::stats::CoreStats;

    fn stats_with_cycles(cycles: u64) -> ClusterStats {
        ClusterStats {
            per_core: vec![CoreStats::default()],
            cycles,
            ..Default::default()
        }
    }

    fn dummy_prog() -> Arc<Vec<DecodedProgram>> {
        Arc::new(vec![])
    }

    #[test]
    fn miss_then_hit_roundtrip() {
        let mut memo = TileMemo::new();
        let prog = dummy_prog();
        let mut spm = Mem::new(64);
        spm.write_u64(0, 0x1111);
        assert!(memo.replay(&prog, &mut spm).is_none());
        let before = spm.read_bytes(0, spm.len()).to_vec();

        // "Execute": mutate the SPM, produce stats.
        spm.write_u64(8, 0x2222);
        let stats = stats_with_cycles(42);
        memo.record(&prog, before, &spm, &stats);
        assert_eq!(memo.len(), 1);

        // Fresh SPM with the same starting image replays the effect.
        let mut spm2 = Mem::new(64);
        spm2.write_u64(0, 0x1111);
        let replayed = memo.replay(&prog, &mut spm2).expect("hit");
        assert_eq!(replayed.cycles, 42);
        assert_eq!(spm2.read_u64(8), 0x2222);
        assert_eq!(memo.hits, 1);
        assert_eq!(memo.misses, 1);
    }

    #[test]
    fn different_values_miss() {
        let mut memo = TileMemo::new();
        let prog = dummy_prog();
        let mut spm = Mem::new(64);
        spm.write_u64(0, 0xAAAA);
        let before = spm.read_bytes(0, spm.len()).to_vec();
        spm.write_u64(8, 1);
        memo.record(&prog, before, &spm, &stats_with_cycles(7));

        let mut other = Mem::new(64);
        other.write_u64(0, 0xBBBB); // different input values
        assert!(memo.replay(&prog, &mut other).is_none());
        // The miss must not have touched the SPM.
        assert_eq!(other.read_u64(8), 0);
    }

    #[test]
    fn different_program_identity_misses() {
        let mut memo = TileMemo::new();
        let p1 = dummy_prog();
        let p2 = dummy_prog();
        let mut spm = Mem::new(64);
        let before = spm.read_bytes(0, spm.len()).to_vec();
        memo.record(&p1, before, &spm, &stats_with_cycles(1));
        assert!(memo.replay(&p2, &mut spm).is_none());
        assert!(memo.replay(&p1, &mut spm).is_some());
    }

    #[test]
    fn cap_stops_growth() {
        let mut memo = TileMemo::new();
        let prog = dummy_prog();
        for i in 0..(MEMO_CAP as u64 + 10) {
            let mut spm = Mem::new(16);
            spm.write_u64(0, i);
            let before = spm.read_bytes(0, spm.len()).to_vec();
            memo.record(&prog, before, &spm, &stats_with_cycles(i));
        }
        assert_eq!(memo.len(), MEMO_CAP);
    }

    #[test]
    fn duplicate_record_is_dropped() {
        let mut memo = TileMemo::new();
        let prog = dummy_prog();
        let spm = Mem::new(16);
        let before = spm.read_bytes(0, spm.len()).to_vec();
        memo.record(&prog, before.clone(), &spm, &stats_with_cycles(1));
        memo.record(&prog, before, &spm, &stats_with_cycles(1));
        assert_eq!(memo.len(), 1);
    }
}
