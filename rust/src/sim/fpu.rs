//! FPU timing parameters for the extended Snitch FPU (paper §IV-B).
//!
//! Latencies are result latencies in cycles; the FPU is fully pipelined
//! (one issue per cycle) for everything except the iterative DIVSQRT
//! block. Scalar (non-FREP) FP code additionally pays the integer-core
//! offload handshake per instruction — calibrated against the paper's own
//! anchor: the baseline softmax measures 56 instr/output at 360
//! cycles/output (§IV-C), i.e. ~6.4 cycles per scalar instruction, while
//! FREP+SSR streams reach ~1 instr/cycle.

use crate::isa::Class;

/// Result latency of an instruction class.
pub fn latency(class: Class) -> u32 {
    match class {
        Class::FpScalarH => 2,
        Class::FpSimd => 2,
        // the paper's ExpUnit: one pipeline register -> 2-cycle latency
        Class::FpExp => 2,
        // FP64 path of the multi-format FMA (deeper pipeline)
        Class::FpScalarD => 5,
        // iterative division on the DIVSQRT block (BF16 mantissa)
        Class::FpDivH => 14,
        Class::FpLoad => 3,
        _ => 1,
    }
}

/// Cycles the DIVSQRT block blocks issue per division (unpipelined).
pub const FDIV_OCCUPANCY: u32 = 12;

/// Extra core cycles to hand a non-FREP FP instruction to the FPU
/// sequencer and retire it through the shared writeback (the pseudo
/// dual-issue core has no renaming; scalar FP code is handshake-bound).
/// Calibrated so the baseline softmax reproduces the paper's measured
/// 56 instr/output at 360 cycles/output and the libm exponential its
/// 319 cycles/call.
pub const FP_OFFLOAD_OVERHEAD: u32 = 7;

/// Pipeline refill penalty for a taken branch (no branch predictor).
pub const BRANCH_TAKEN_PENALTY: u32 = 2;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_latency_matches_paper() {
        assert_eq!(latency(Class::FpExp), 2);
    }

    #[test]
    fn div_is_iterative() {
        assert!(latency(Class::FpDivH) > 4 * latency(Class::FpScalarH));
    }

    #[test]
    #[allow(clippy::assertions_on_constants)]
    fn scalar_code_is_handshake_bound() {
        // the paper's baseline anchor needs >= 5 cycles per scalar FP op
        assert!(1 + FP_OFFLOAD_OVERHEAD >= 5);
    }
}
