//! Deterministic fault injection for the cluster system (DESIGN.md §12).
//!
//! A [`FaultPlan`] decides, per *fault epoch* (one [`super::System::run_jobs`]
//! call) and per cluster, which [`ClusterFault`] applies:
//!
//! - **slowdown** — the cluster's compute cycles are multiplied by a
//!   factor ≥ 1 (thermal throttling, a straggler core);
//! - **stall** — a fixed number of extra cycles is added to the
//!   cluster's makespan (an interconnect hiccup);
//! - **transient failure** — the job "completes" but its SPM image is
//!   corrupted (one byte flipped), detectable via checksum mismatch;
//!   the cluster reports `failed` and callers are expected to retry;
//! - **offline** — from some epoch on the cluster accepts no jobs at
//!   all (a hard fault); it reports `offline` permanently.
//!
//! Sampling is *stateless*: each (seed, epoch, cluster) triple derives
//! its own SplitMix64 stream, so draws are independent of execution
//! order, thread interleaving, and how many other clusters ran — the
//! same plan replayed against the same jobs yields bit-identical runs.

use crate::testkit::{mix, Rng};

use super::mem::Mem;

/// The fault applied to one cluster for one epoch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterFault {
    /// Multiplier on the cluster's compute cycles (1.0 = none).
    pub slow_factor: f64,
    /// Extra cycles added to the cluster's makespan.
    pub stall_cycles: u64,
    /// Corrupt the cluster's SPM after the job (transient failure).
    pub fail: bool,
    /// The cluster is offline and executes nothing.
    pub offline: bool,
}

impl ClusterFault {
    /// The no-fault identity.
    pub fn none() -> Self {
        ClusterFault { slow_factor: 1.0, stall_cycles: 0, fail: false, offline: false }
    }

    /// Does this fault change anything observable? A slowdown of exactly
    /// 1.0 and a stall of 0 cycles are identities (IEEE `x * 1.0 == x`),
    /// so a "zero-impact" plan leaves runs bit-identical.
    pub fn is_effective(&self) -> bool {
        self.slow_factor != 1.0 || self.stall_cycles != 0 || self.fail || self.offline
    }

    /// Merge another fault into this one (scripted events compose):
    /// factors multiply, stalls add, flags OR.
    fn merge(&mut self, other: &ClusterFault) {
        self.slow_factor *= other.slow_factor;
        self.stall_cycles += other.stall_cycles;
        self.fail |= other.fail;
        self.offline |= other.offline;
    }
}

impl Default for ClusterFault {
    fn default() -> Self {
        Self::none()
    }
}

/// Random fault rates, sampled independently per (epoch, cluster).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Probability a cluster is slowed this epoch.
    pub p_slow: f64,
    /// Slowdown factor applied when slowed (≥ 1).
    pub slow_factor: f64,
    /// Probability a cluster stalls this epoch.
    pub p_stall: f64,
    /// Stall length in cycles when stalled.
    pub stall_cycles: u64,
    /// Probability a cluster's job transiently fails this epoch.
    pub p_fail: f64,
    /// Number of clusters taken permanently offline at a random epoch
    /// in [1, 8) (never epoch 0, so every run makes some progress).
    pub offline: u32,
}

impl FaultSpec {
    /// No faults at all.
    pub fn off() -> Self {
        FaultSpec {
            p_slow: 0.0,
            slow_factor: 1.0,
            p_stall: 0.0,
            stall_cycles: 0,
            p_fail: 0.0,
            offline: 0,
        }
    }

    /// A lively mixed-fault preset for demos and CI smoke: frequent
    /// transient failures (so retries are statistically certain over a
    /// run), occasional slowdowns and stalls, one cluster lost.
    pub fn chaos() -> Self {
        FaultSpec {
            p_slow: 0.15,
            slow_factor: 2.0,
            p_stall: 0.10,
            stall_cycles: 5_000,
            p_fail: 0.25,
            offline: 1,
        }
    }

    /// Faults that fire constantly but change nothing: slowdown factor
    /// exactly 1.0 and stalls of 0 cycles, no failures, no offlining.
    /// Exercises the whole injection arithmetic while provably leaving
    /// stats and SPM bytes bit-identical (the differential test).
    pub fn zero_impact() -> Self {
        FaultSpec {
            p_slow: 1.0,
            slow_factor: 1.0,
            p_stall: 1.0,
            stall_cycles: 0,
            p_fail: 0.0,
            offline: 0,
        }
    }

    /// Parse a `key=value,...` spec: `slow=P:FACTOR`, `stall=P:CYCLES`,
    /// `fail=P`, `offline=N`. Omitted keys default to off. The strings
    /// `off` and `none` yield [`FaultSpec::off`]; `chaos` the preset.
    pub fn parse(s: &str) -> crate::error::Result<Self> {
        match s {
            "off" | "none" => return Ok(Self::off()),
            "chaos" => return Ok(Self::chaos()),
            "zero" => return Ok(Self::zero_impact()),
            _ => {}
        }
        let mut spec = Self::off();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| crate::err!("fault spec `{part}`: expected key=value"))?;
            match key {
                "slow" => {
                    let (p, f) = val
                        .split_once(':')
                        .ok_or_else(|| crate::err!("slow=`{val}`: expected P:FACTOR"))?;
                    spec.p_slow = parse_prob(p, "slow probability")?;
                    spec.slow_factor = f
                        .parse::<f64>()
                        .map_err(|_| crate::err!("slow factor `{f}` is not a number"))?;
                    if spec.slow_factor < 1.0 || !spec.slow_factor.is_finite() {
                        crate::bail!("slow factor {} must be a finite value >= 1", spec.slow_factor);
                    }
                }
                "stall" => {
                    let (p, c) = val
                        .split_once(':')
                        .ok_or_else(|| crate::err!("stall=`{val}`: expected P:CYCLES"))?;
                    spec.p_stall = parse_prob(p, "stall probability")?;
                    spec.stall_cycles = c
                        .parse::<u64>()
                        .map_err(|_| crate::err!("stall cycles `{c}` is not an integer"))?;
                }
                "fail" => spec.p_fail = parse_prob(val, "fail probability")?,
                "offline" => {
                    spec.offline = val
                        .parse::<u32>()
                        .map_err(|_| crate::err!("offline count `{val}` is not an integer"))?;
                }
                _ => crate::bail!(
                    "unknown fault key `{key}` (expected slow/stall/fail/offline)"
                ),
            }
        }
        Ok(spec)
    }
}

fn parse_prob(s: &str, what: &str) -> crate::error::Result<f64> {
    let p = s
        .parse::<f64>()
        .map_err(|_| crate::err!("{what} `{s}` is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        crate::bail!("{what} {p} must be in [0, 1]");
    }
    Ok(p)
}

/// A scripted fault: `fault` applies to `cluster` for epochs in
/// `[from_epoch, until_epoch)`. Used by tests for exact scenarios.
#[derive(Clone, Copy, Debug)]
pub struct FaultEvent {
    /// Target cluster index.
    pub cluster: usize,
    /// First epoch the fault applies (inclusive).
    pub from_epoch: u64,
    /// First epoch the fault no longer applies (exclusive).
    pub until_epoch: u64,
    /// The fault itself.
    pub fault: ClusterFault,
}

/// A seeded, deterministic fault schedule over a cluster system.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    spec: FaultSpec,
    /// Per cluster: the epoch from which it is permanently offline.
    offline_from: Vec<Option<u64>>,
    /// Scripted events, merged on top of the sampled spec.
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan from random rates. The offline schedule (which
    /// clusters die, and when) is drawn once here from the seed.
    pub fn new(spec: FaultSpec, seed: u64, n_clusters: usize) -> Self {
        let mut offline_from = vec![None; n_clusters];
        let mut rng = Rng::new(mix(seed, 0x0FF1_1BAD));
        let victims = (spec.offline as usize).min(n_clusters);
        for _ in 0..victims {
            // pick a not-yet-offline cluster and a death epoch >= 1
            let mut c = rng.range(0, n_clusters as u64) as usize;
            while offline_from[c].is_some() {
                c = (c + 1) % n_clusters;
            }
            offline_from[c] = Some(rng.range(1, 8));
        }
        FaultPlan { seed, spec, offline_from, events: Vec::new() }
    }

    /// A plan made only of scripted events (tests): no random component.
    pub fn scripted(n_clusters: usize, events: Vec<FaultEvent>) -> Self {
        FaultPlan {
            seed: 0,
            spec: FaultSpec::off(),
            offline_from: vec![None; n_clusters],
            events,
        }
    }

    /// The fault for `cluster` at `epoch`. Stateless: derives a fresh
    /// stream from (seed, epoch, cluster), so calls commute.
    pub fn fault_at(&self, epoch: u64, cluster: usize) -> ClusterFault {
        let mut fault = ClusterFault::none();
        if let Some(from) = self.offline_from.get(cluster).copied().flatten() {
            if epoch >= from {
                fault.offline = true;
            }
        }
        let mut rng = Rng::new(mix(self.seed, mix(epoch, cluster as u64)));
        if self.spec.p_slow > 0.0 && rng.chance(self.spec.p_slow) {
            fault.slow_factor *= self.spec.slow_factor;
        }
        if self.spec.p_stall > 0.0 && rng.chance(self.spec.p_stall) {
            fault.stall_cycles += self.spec.stall_cycles;
        }
        if self.spec.p_fail > 0.0 && rng.chance(self.spec.p_fail) {
            fault.fail = true;
        }
        for ev in &self.events {
            if ev.cluster == cluster && (ev.from_epoch..ev.until_epoch).contains(&epoch) {
                fault.merge(&ev.fault);
            }
        }
        fault
    }

    /// Deterministic byte offset to corrupt for a transient failure.
    pub fn corruption_offset(&self, epoch: u64, cluster: usize, len: usize) -> usize {
        if len == 0 {
            return 0;
        }
        (mix(self.seed ^ 0xC0DE_FA11, mix(epoch, cluster as u64)) % len as u64) as usize
    }
}

/// Checksum of a memory's SPM image (FNV-1a). A job's post-run checksum
/// differing from the fault-free run of the same program is how
/// transient corruption is detected.
pub fn spm_checksum(mem: &Mem) -> u64 {
    super::memo::fnv1a(mem.read_bytes(0, mem.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_at_is_deterministic_and_order_free() {
        let plan = FaultPlan::new(FaultSpec::chaos(), 42, 16);
        let a: Vec<_> = (0..16).map(|c| plan.fault_at(3, c)).collect();
        let b: Vec<_> = (0..16).rev().map(|c| plan.fault_at(3, c)).collect();
        for (c, f) in a.iter().enumerate() {
            assert_eq!(*f, b[15 - c]);
        }
        let plan2 = FaultPlan::new(FaultSpec::chaos(), 42, 16);
        assert_eq!(plan.fault_at(7, 5), plan2.fault_at(7, 5));
    }

    #[test]
    fn different_seeds_differ_somewhere() {
        let a = FaultPlan::new(FaultSpec::chaos(), 1, 8);
        let b = FaultPlan::new(FaultSpec::chaos(), 2, 8);
        let differs = (0..64).any(|e| (0..8).any(|c| a.fault_at(e, c) != b.fault_at(e, c)));
        assert!(differs);
    }

    #[test]
    fn zero_impact_faults_fire_but_change_nothing() {
        let plan = FaultPlan::new(FaultSpec::zero_impact(), 9, 8);
        for epoch in 0..32 {
            for c in 0..8 {
                let f = plan.fault_at(epoch, c);
                assert_eq!(f.slow_factor, 1.0);
                assert_eq!(f.stall_cycles, 0);
                assert!(!f.fail && !f.offline);
                assert!(!f.is_effective());
            }
        }
    }

    #[test]
    fn chaos_produces_failures_at_roughly_the_requested_rate() {
        let plan = FaultPlan::new(FaultSpec::chaos(), 1234, 16);
        let n = 64 * 16;
        let fails: usize = (0..64)
            .flat_map(|e| (0..16).map(move |c| (e, c)))
            .filter(|&(e, c)| plan.fault_at(e, c).fail)
            .count();
        let rate = fails as f64 / n as f64;
        assert!((0.15..0.35).contains(&rate), "fail rate = {rate}");
    }

    #[test]
    fn offline_is_permanent_once_hit() {
        let plan = FaultPlan::new(
            FaultSpec { offline: 3, ..FaultSpec::off() },
            7,
            8,
        );
        let dead: Vec<usize> =
            (0..8).filter(|&c| plan.fault_at(100, c).offline).collect();
        assert_eq!(dead.len(), 3);
        for &c in &dead {
            let from = (0..100).find(|&e| plan.fault_at(e, c).offline).unwrap();
            assert!(from >= 1, "never offline at epoch 0");
            assert!((from..100).all(|e| plan.fault_at(e, c).offline));
        }
    }

    #[test]
    fn scripted_events_apply_in_their_window_only() {
        let f = ClusterFault { slow_factor: 2.0, stall_cycles: 10, fail: true, offline: false };
        let plan = FaultPlan::scripted(
            4,
            vec![FaultEvent { cluster: 2, from_epoch: 1, until_epoch: 3, fault: f }],
        );
        assert!(!plan.fault_at(0, 2).is_effective());
        assert_eq!(plan.fault_at(1, 2), f);
        assert_eq!(plan.fault_at(2, 2), f);
        assert!(!plan.fault_at(3, 2).is_effective());
        assert!(!plan.fault_at(1, 1).is_effective());
    }

    #[test]
    fn parse_round_trips_the_grammar() {
        let s = FaultSpec::parse("slow=0.1:2.5,stall=0.2:500,fail=0.05,offline=2").unwrap();
        assert_eq!(s.p_slow, 0.1);
        assert_eq!(s.slow_factor, 2.5);
        assert_eq!(s.p_stall, 0.2);
        assert_eq!(s.stall_cycles, 500);
        assert_eq!(s.p_fail, 0.05);
        assert_eq!(s.offline, 2);
        assert_eq!(FaultSpec::parse("off").unwrap(), FaultSpec::off());
        assert_eq!(FaultSpec::parse("chaos").unwrap(), FaultSpec::chaos());
        assert_eq!(FaultSpec::parse("zero").unwrap(), FaultSpec::zero_impact());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "slow=2",            // missing factor
            "slow=1.5:2.0",      // probability out of range
            "slow=0.5:0.5",      // factor below 1
            "stall=0.1:abc",     // non-integer cycles
            "fail=nope",         // non-numeric probability
            "fail=-0.1",         // negative probability
            "offline=x",         // non-integer count
            "warp=0.1",          // unknown key
            "noequals",          // missing '='
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "`{bad}` should fail");
        }
    }

    #[test]
    fn corruption_offset_is_in_bounds_and_deterministic() {
        let plan = FaultPlan::new(FaultSpec::chaos(), 3, 4);
        for epoch in 0..8 {
            for c in 0..4 {
                let o = plan.corruption_offset(epoch, c, 1024);
                assert!(o < 1024);
                assert_eq!(o, plan.corruption_offset(epoch, c, 1024));
            }
        }
        assert_eq!(plan.corruption_offset(0, 0, 0), 0);
    }
}
