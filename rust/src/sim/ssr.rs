//! SSR stream address generation: the reference 3D affine walker and the
//! decode-time bulk fast path.
//!
//! [`SsrState`] is the architectural model — one address per 64-bit beat,
//! multi-dimension carry logic exactly as the hardware's nested counters
//! work. [`SsrStream`] is what the fast executor uses: patterns that are
//! provably equivalent to a contiguous `base + 8·k` walk are serviced as
//! a flat descriptor (no per-beat multiply/carry chain), everything else
//! falls back to the reference walker. The two are differential-tested
//! against each other in `tests/sim_properties.rs`.
//!
//! Deliberately *not* done: prefetching a whole stream as one `Mem`
//! slice. Kernels alias read and write streams over the same region
//! (e.g. the softmax NORM phase reads and rewrites the output row in
//! place), so beat-by-beat interleaving with FP execution is part of the
//! functional semantics; only the *address generation* is bulk-resolved.

use crate::isa::instr::SsrPattern;

/// Reference 3D affine stream walker (one nested counter per dimension).
#[derive(Clone, Copy, Debug)]
pub struct SsrState {
    pub pat: SsrPattern,
    pub i0: u32,
    pub i1: u32,
    pub i2: u32,
}

impl SsrState {
    pub fn new(pat: SsrPattern) -> Self {
        SsrState { pat, i0: 0, i1: 0, i2: 0 }
    }

    /// Address of the next beat; panics when the pattern is exhausted.
    pub fn next_addr(&mut self) -> u32 {
        assert!(
            self.i2 < self.pat.reps2,
            "SSR stream exhausted (pattern {:?})",
            self.pat
        );
        let addr = (self.pat.base as i64
            + self.i2 as i64 * self.pat.stride2 as i64
            + self.i1 as i64 * self.pat.stride1 as i64
            + self.i0 as i64 * self.pat.stride0 as i64) as u32;
        self.i0 += 1;
        if self.i0 == self.pat.reps0 {
            self.i0 = 0;
            self.i1 += 1;
            if self.i1 == self.pat.reps1 {
                self.i1 = 0;
                self.i2 += 1;
            }
        }
        addr
    }
}

/// True when every beat of `pat` lands at `base + 8·k` for beat index
/// `k` — i.e. the nested dimensions fold into one contiguous stream.
/// Dimensions with a single repetition never advance their stride, so
/// their stride is unconstrained. Degenerate patterns (any reps == 0,
/// where the reference walker's wrap counters never fold) stay on the
/// reference walker so the two paths agree on them too.
pub fn is_contiguous(pat: &SsrPattern) -> bool {
    let r0 = pat.reps0 as i64;
    let r1 = pat.reps1 as i64;
    pat.reps0 >= 1
        && pat.reps1 >= 1
        && pat.reps2 >= 1
        && (pat.reps0 == 1 || pat.stride0 as i64 == 8)
        && (pat.reps1 == 1 || pat.stride1 as i64 == 8 * r0)
        && (pat.reps2 == 1 || pat.stride2 as i64 == 8 * r0 * r1)
        && pat.beats() <= (u32::MAX / 8) as u64
}

/// Decode-time stream descriptor: flat fast path or reference walker.
#[derive(Clone, Copy, Debug)]
pub enum SsrStream {
    /// Contiguous: beat `k` reads/writes `base + 8·k`.
    Flat { pat: SsrPattern, pos: u32, len: u32 },
    /// General affine pattern through the reference walker.
    Walk(SsrState),
}

impl SsrStream {
    pub fn new(pat: SsrPattern) -> Self {
        if is_contiguous(&pat) {
            SsrStream::Flat { pat, pos: 0, len: pat.beats() as u32 }
        } else {
            SsrStream::Walk(SsrState::new(pat))
        }
    }

    #[inline]
    pub fn is_write(&self) -> bool {
        match self {
            SsrStream::Flat { pat, .. } => pat.write,
            SsrStream::Walk(st) => st.pat.write,
        }
    }

    /// Address of the next beat; panics when the pattern is exhausted
    /// (same message as the reference walker).
    #[inline]
    pub fn next_addr(&mut self) -> u32 {
        match self {
            SsrStream::Flat { pat, pos, len } => {
                assert!(*pos < *len, "SSR stream exhausted (pattern {:?})", pat);
                let addr = pat.base.wrapping_add(*pos * 8);
                *pos += 1;
                addr
            }
            SsrStream::Walk(st) => st.next_addr(),
        }
    }

    /// Beats left before the stream exhausts. Exact for flat streams;
    /// for the reference walker it is derived from the counter state.
    pub fn remaining(&self) -> u64 {
        match self {
            SsrStream::Flat { pos, len, .. } => (*len - *pos) as u64,
            SsrStream::Walk(st) => {
                let p = &st.pat;
                let consumed = st.i2 as u64 * p.reps1 as u64 * p.reps0 as u64
                    + st.i1 as u64 * p.reps0 as u64
                    + st.i0 as u64;
                p.beats().saturating_sub(consumed)
            }
        }
    }

    /// Address the next `next_addr` call would return, without consuming
    /// a beat; `None` when the stream is exhausted. Only flat streams
    /// answer — the batched executor uses this to seed its local cursor
    /// and falls back to per-beat pops for walker streams.
    pub fn peek_addr(&self) -> Option<u32> {
        match self {
            SsrStream::Flat { pat, pos, len } if pos < len => {
                Some(pat.base.wrapping_add(*pos * 8))
            }
            _ => None,
        }
    }

    /// Consume `n` beats at once (the batched executor resolves the
    /// addresses itself from [`peek_addr`](Self::peek_addr) for flat
    /// streams). Panics with the reference walker's message if fewer
    /// than `n` beats remain.
    pub fn advance(&mut self, n: u64) {
        match self {
            SsrStream::Flat { pat, pos, len } => {
                assert!(
                    (*pos as u64 + n) <= *len as u64,
                    "SSR stream exhausted (pattern {:?})",
                    pat
                );
                *pos += n as u32;
            }
            SsrStream::Walk(st) => {
                for _ in 0..n {
                    st.next_addr();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read1d_is_contiguous() {
        assert!(is_contiguous(&SsrPattern::read1d(0x100, 8)));
        assert!(is_contiguous(&SsrPattern::write1d(0x100, 8)));
    }

    #[test]
    fn repeat_beat_pattern_is_not_contiguous() {
        // the GEMM A-row pattern repeats each beat (stride0 = 0)
        let pat = SsrPattern::read3d(0x100, 0, 8, 8, 4, 0, 2);
        assert!(!is_contiguous(&pat));
    }

    #[test]
    fn folded_2d_pattern_is_contiguous() {
        // 4 blocks of 8 beats, block stride = 8 beats -> flat 32 beats
        let pat = SsrPattern::read2d(0x100, 8, 8, 64, 4);
        assert!(is_contiguous(&pat));
        let mut fast = SsrStream::new(pat);
        let mut slow = SsrState::new(pat);
        for _ in 0..32 {
            assert_eq!(fast.next_addr(), slow.next_addr());
        }
    }

    #[test]
    fn single_rep_dims_ignore_strides() {
        let pat = SsrPattern::read2d(0x100, 8, 16, -4096, 1);
        assert!(is_contiguous(&pat));
    }

    #[test]
    fn zero_rep_patterns_stay_on_the_walker() {
        // reps == 0 never folds: the reference walker's counters don't
        // wrap, so the flat path must not claim these
        let pat = SsrPattern::read1d(0x100, 0);
        assert!(!is_contiguous(&pat));
        assert!(matches!(SsrStream::new(pat), SsrStream::Walk(_)));
    }

    #[test]
    #[should_panic(expected = "SSR stream exhausted")]
    fn flat_stream_panics_on_overrun() {
        let mut s = SsrStream::new(SsrPattern::read1d(0x0, 2));
        s.next_addr();
        s.next_addr();
        s.next_addr();
    }

    #[test]
    fn peek_advance_matches_next_addr() {
        let pat = SsrPattern::read1d(0x100, 8);
        let mut popped = SsrStream::new(pat);
        let mut bulk = SsrStream::new(pat);
        assert_eq!(bulk.remaining(), 8);
        // consume 3 beats each way, checking the peeked cursor walk
        let mut cursor = bulk.peek_addr().unwrap();
        for _ in 0..3 {
            assert_eq!(cursor, popped.next_addr());
            cursor = cursor.wrapping_add(8);
        }
        bulk.advance(3);
        assert_eq!(bulk.remaining(), 5);
        assert_eq!(bulk.peek_addr(), Some(cursor));
        assert_eq!(bulk.next_addr(), popped.next_addr());
    }

    #[test]
    fn walker_remaining_counts_down() {
        // repeat-beat pattern stays on the walker
        let pat = SsrPattern::read3d(0x100, 0, 8, 8, 4, 0, 2);
        let mut s = SsrStream::new(pat);
        assert!(matches!(s, SsrStream::Walk(_)));
        let total = s.remaining();
        assert_eq!(total, pat.beats());
        s.next_addr();
        assert_eq!(s.remaining(), total - 1);
        s.advance(2);
        assert_eq!(s.remaining(), total - 3);
        assert_eq!(s.peek_addr(), None);
    }

    #[test]
    #[should_panic(expected = "SSR stream exhausted")]
    fn flat_bulk_advance_past_end_panics() {
        let mut s = SsrStream::new(SsrPattern::read1d(0x0, 2));
        s.advance(3);
    }
}
