//! Fast-path Snitch core executor over pre-decoded micro-ops.
//!
//! Timing-equivalent to the reference interpreter in [`super::core`] —
//! same scoreboard, same FPU issue/occupancy/latency recurrence, same
//! branch and offload penalties — but executing [`MicroOp`]s whose
//! latencies, classes and work counts were resolved at decode time, and
//! with two structural fast paths:
//!
//! 1. **FREP steady-state fast-forward.** Inside an FREP body the timing
//!    recurrence (`issue = max(fpu_free, operand-ready)`, `fpu_free =
//!    issue + occupancy`, `done = issue + latency`) is data-independent
//!    and *translation-invariant*: shifting every timestamp by a
//!    constant shifts the whole future evolution by that constant. The
//!    executor therefore times iterations normally only until the
//!    scoreboard state **relative to `fpu_free`** repeats across two
//!    consecutive iteration boundaries (with equal `fpu_free` deltas);
//!    from that point every remaining iteration advances the timeline by
//!    exactly that delta, so timing is applied arithmetically while the
//!    functional work (SSR pops, arithmetic, stores, statistics) runs
//!    through a tight per-body loop with no per-op timing bookkeeping.
//!    Bodies containing `FdivH` are excluded (conservatively, per the
//!    divider's long occupancy) and fully timed, as are bodies that have
//!    not converged within the warm-up cap. See DESIGN.md §9 for the
//!    proof obligations (registers whose ready time has fallen behind
//!    `fpu_free` are clamped in the snapshot: they can no longer
//!    influence any future `max`, in or after the loop).
//! 2. **Bulk SSR streams.** Contiguous affine patterns are serviced by a
//!    flat `base + 8·k` descriptor ([`SsrStream`]) instead of the
//!    per-beat nested-counter walk.
//!
//! `tests/sim_differential.rs` holds this executor bit-identical to the
//! reference interpreter on every kernel the crate ships.

use super::decode::{
    f_vfadd_h, f_vfexp_h, f_vfmac_h, f_vfmax_h, f_vfmul_h, DecodedProgram, FpOp, FpShape,
    FrepInfo, HotOp, MicroOp,
};
use super::fpu::{latency, BRANCH_TAKEN_PENALTY, FP_OFFLOAD_OVERHEAD};
use super::mem::Mem;
use super::ssr::SsrStream;
use super::stats::CoreStats;
use crate::isa::instr::Class;

/// Iterations timed in full while watching for steady state before
/// giving up and timing the remainder op-by-op.
const WARMUP_CAP: u64 = 8;

/// Remainders shorter than this run through the simple per-op functional
/// loop — building a batch plan costs more than it saves.
const BATCH_MIN_ITERS: u64 = 4;

/// Where one batched operand comes from. Resolved once per steady-state
/// entry: SSR mappings and integer registers cannot change inside an
/// FREP body (all body ops are FP), so the per-op stream/register
/// decision `read_freg` makes every iteration is loop-invariant.
#[derive(Clone, Copy)]
enum Src {
    /// Plain FP register read.
    Reg(u8),
    /// Pop from SSR read stream `r` (r < 3, mapped, read direction).
    Pop(u8),
    /// Loop-invariant immediate (`FromInt` reads an integer register).
    Imm(u64),
}

/// Where one batched result goes.
#[derive(Clone, Copy)]
enum Dst {
    Reg(u8),
    /// Push to SSR write stream `r`.
    Push(u8),
}

/// One body op with operands/destination pre-resolved for the batch loop.
#[derive(Clone, Copy)]
struct BatchOp {
    shape: FpShape,
    hot: HotOp,
    a: Src,
    b: Src,
    c: Src,
    dst: Dst,
    class_idx: u8,
    flops: u8,
    exps: u8,
}

/// One Snitch core executing decoded micro-ops.
pub struct FastCore {
    pub iregs: [i64; 32],
    pub fregs: [u64; 32],
    freg_ready: [u64; 32],
    ssr: [Option<SsrStream>; 3],
    ssr_enabled: bool,
    core_cycle: u64,
    fpu_free: u64,
    last_retire: u64,
    stats: CoreStats,
}

impl Default for FastCore {
    fn default() -> Self {
        Self::new()
    }
}

impl FastCore {
    pub fn new() -> Self {
        FastCore {
            iregs: [0; 32],
            fregs: [0; 32],
            freg_ready: [0; 32],
            ssr: [None, None, None],
            ssr_enabled: false,
            core_cycle: 0,
            fpu_free: 0,
            last_retire: 0,
            stats: CoreStats::default(),
        }
    }

    /// Run a decoded program to completion against `spm`.
    pub fn run(&mut self, spm: &mut Mem, prog: &DecodedProgram) -> CoreStats {
        let ops = prog.ops();
        let mut pc = 0usize;
        let mut guard = 0u64;
        while pc < ops.len() {
            guard += 1;
            assert!(guard < 500_000_000, "runaway program");
            pc = self.step(spm, ops, pc);
        }
        let mut s = self.stats.clone();
        s.cycles = self.core_cycle.max(self.last_retire);
        s
    }

    #[inline]
    fn ireg(&self, r: u8) -> i64 {
        if r == 0 {
            0
        } else {
            self.iregs[r as usize]
        }
    }

    #[inline]
    fn set_ireg(&mut self, r: u8, v: i64) {
        if r != 0 {
            self.iregs[r as usize] = v;
        }
    }

    /// Read an FP operand, popping from an SSR stream when mapped.
    /// Returns (value, ready_cycle).
    #[inline]
    fn read_freg(&mut self, spm: &mut Mem, r: u8) -> (u64, u64) {
        if self.ssr_enabled && r < 3 {
            if let Some(st) = self.ssr[r as usize].as_mut() {
                if !st.is_write() {
                    let addr = st.next_addr();
                    self.stats.ssr_beats += 1;
                    return (spm.read_u64(addr), 0);
                }
            }
        }
        (self.fregs[r as usize], self.freg_ready[r as usize])
    }

    /// Write an FP destination with its ready cycle, pushing to an SSR
    /// write stream when mapped.
    #[inline]
    fn write_freg(&mut self, spm: &mut Mem, r: u8, v: u64, ready: u64) {
        if self.ssr_enabled && r < 3 {
            if let Some(st) = self.ssr[r as usize].as_mut() {
                if st.is_write() {
                    let addr = st.next_addr();
                    self.stats.ssr_beats += 1;
                    spm.write_u64(addr, v);
                    self.last_retire = self.last_retire.max(ready);
                    return;
                }
            }
        }
        self.fregs[r as usize] = v;
        self.freg_ready[r as usize] = ready;
        self.last_retire = self.last_retire.max(ready);
    }

    /// Value-only FP write for the steady-state functional loop: the
    /// scoreboard is advanced arithmetically by the caller.
    #[inline]
    fn write_freg_value(&mut self, spm: &mut Mem, r: u8, v: u64) {
        if self.ssr_enabled && r < 3 {
            if let Some(st) = self.ssr[r as usize].as_mut() {
                if st.is_write() {
                    let addr = st.next_addr();
                    self.stats.ssr_beats += 1;
                    spm.write_u64(addr, v);
                    return;
                }
            }
        }
        self.fregs[r as usize] = v;
    }

    /// Operand fetch + arithmetic of one FP op: (result, max operand
    /// ready cycle). Pops SSR read streams exactly like the reference.
    #[inline]
    fn eval_fp(&mut self, spm: &mut Mem, op: &FpOp) -> (u64, u64) {
        match op.shape {
            FpShape::Un(f) => {
                let (v, r) = self.read_freg(spm, op.a);
                (f(v), r)
            }
            FpShape::Bin(f) => {
                let (va, ra) = self.read_freg(spm, op.a);
                let (vb, rb) = self.read_freg(spm, op.b);
                (f(va, vb), ra.max(rb))
            }
            FpShape::Tri(f) => {
                let (va, ra) = self.read_freg(spm, op.a);
                let (vb, rb) = self.read_freg(spm, op.b);
                let (vc, rc) = self.read_freg(spm, op.c);
                (f(va, vb, vc), ra.max(rb).max(rc))
            }
            FpShape::FromInt { wide } => {
                let v = self.ireg(op.a) as u64;
                (if wide { v } else { v & 0xFFFF_FFFF }, 0)
            }
        }
    }

    /// Fully-timed FP execution (the reference recurrence, pre-decoded
    /// constants). `seq` = issued from the FREP sequencer.
    #[inline]
    fn exec_fp(&mut self, spm: &mut Mem, op: &FpOp, seq: bool) {
        if !seq {
            self.core_cycle += 1 + FP_OFFLOAD_OVERHEAD as u64;
        }
        let (result, ready_in) = self.eval_fp(spm, op);
        let issue = self
            .fpu_free
            .max(ready_in)
            .max(if seq { 0 } else { self.core_cycle });
        self.fpu_free = issue + op.occupancy as u64;
        let done = issue + op.latency as u64;
        self.write_freg(spm, op.dst, result, done);
        self.last_retire = self.last_retire.max(done);
        self.count_fp(op);
    }

    /// Functional-only FP execution for the steady-state loop: values,
    /// SSR traffic and statistics advance; the timeline does not.
    #[inline]
    fn exec_fp_functional(&mut self, spm: &mut Mem, op: &FpOp) {
        let (result, _) = self.eval_fp(spm, op);
        self.write_freg_value(spm, op.dst, result);
        self.count_fp(op);
    }

    #[inline]
    fn count_fp(&mut self, op: &FpOp) {
        self.stats.bump_idx(op.class_idx as usize);
        self.stats.flops += op.flops as u64;
        self.stats.exp_ops += op.exps as u64;
    }

    #[inline]
    fn run_body_timed(&mut self, spm: &mut Mem, body: &[MicroOp]) {
        for op in body {
            match op {
                MicroOp::Fp(fp) => self.exec_fp(spm, fp, true),
                other => unreachable!("non-FP micro-op {other:?} in FREP body"),
            }
        }
    }

    #[inline]
    fn run_body_functional(&mut self, spm: &mut Mem, body: &[MicroOp]) {
        for op in body {
            match op {
                MicroOp::Fp(fp) => self.exec_fp_functional(spm, fp),
                other => unreachable!("non-FP micro-op {other:?} in FREP body"),
            }
        }
    }

    /// Resolve one FP source operand the way `read_freg` would decide it
    /// on every single iteration.
    fn batch_src(&self, r: u8) -> Src {
        if self.ssr_enabled && r < 3 {
            if let Some(st) = &self.ssr[r as usize] {
                if !st.is_write() {
                    return Src::Pop(r);
                }
            }
        }
        Src::Reg(r)
    }

    /// Resolve one FP destination the way `write_freg_value` would.
    fn batch_dst(&self, r: u8) -> Dst {
        if self.ssr_enabled && r < 3 {
            if let Some(st) = &self.ssr[r as usize] {
                if st.is_write() {
                    return Dst::Push(r);
                }
            }
        }
        Dst::Reg(r)
    }

    /// Batched replacement for `n` runs of [`Self::run_body_functional`]:
    /// same op order, same operand read order (a, b, c — each read pops
    /// its stream exactly when the per-op path would), same value-only
    /// write semantics, same statistics totals. When every used stream
    /// is a flat descriptor with enough beats, addresses become local
    /// `+8` cursors and the hot SIMD ops dispatch statically, giving the
    /// host compiler a tight, autovectorizable inner loop; otherwise
    /// beats pop one-by-one so mid-loop stream exhaustion still panics
    /// at exactly the reference beat.
    fn run_body_batch(&mut self, spm: &mut Mem, body: &[MicroOp], n: u64) {
        if n < BATCH_MIN_ITERS {
            for _ in 0..n {
                self.run_body_functional(spm, body);
            }
            return;
        }
        // Plan: resolve operands/destinations once, count per-iteration
        // stream uses.
        let mut plan: Vec<BatchOp> = Vec::with_capacity(body.len());
        let mut uses = [0u64; 3];
        for op in body {
            let fp = match op {
                MicroOp::Fp(fp) => fp,
                other => unreachable!("non-FP micro-op {other:?} in FREP body"),
            };
            let (a, b, c) = match fp.shape {
                FpShape::Un(_) => (self.batch_src(fp.a), Src::Reg(0), Src::Reg(0)),
                FpShape::Bin(_) => (self.batch_src(fp.a), self.batch_src(fp.b), Src::Reg(0)),
                FpShape::Tri(_) => {
                    (self.batch_src(fp.a), self.batch_src(fp.b), self.batch_src(fp.c))
                }
                FpShape::FromInt { wide } => {
                    let v = self.ireg(fp.a) as u64;
                    (Src::Imm(if wide { v } else { v & 0xFFFF_FFFF }), Src::Reg(0), Src::Reg(0))
                }
            };
            let dst = self.batch_dst(fp.dst);
            for s in [a, b, c] {
                if let Src::Pop(r) = s {
                    uses[r as usize] += 1;
                }
            }
            if let Dst::Push(r) = dst {
                uses[r as usize] += 1;
            }
            plan.push(BatchOp {
                shape: fp.shape,
                hot: fp.hot,
                a,
                b,
                c,
                dst,
                class_idx: fp.class_idx,
                flops: fp.flops,
                exps: fp.exps,
            });
        }
        // Cursor mode needs every used stream flat with >= n iterations
        // of beats left; anything less falls back to per-beat pops (so
        // an overrun panics at the exact beat the reference would).
        let mut cursor_mode = true;
        for r in 0..3usize {
            if uses[r] > 0 {
                let st = self.ssr[r].as_ref().expect("planned stream must exist");
                if !matches!(st, SsrStream::Flat { .. }) || st.remaining() < n * uses[r] {
                    cursor_mode = false;
                }
            }
        }
        if cursor_mode {
            let mut cursors = [0u32; 3];
            for r in 0..3usize {
                if uses[r] > 0 {
                    cursors[r] = self.ssr[r].as_ref().unwrap().peek_addr().unwrap();
                }
            }
            macro_rules! fetch {
                ($s:expr) => {
                    match $s {
                        Src::Reg(r) => self.fregs[r as usize],
                        Src::Pop(r) => {
                            let addr = cursors[r as usize];
                            cursors[r as usize] = addr.wrapping_add(8);
                            spm.read_u64(addr)
                        }
                        Src::Imm(v) => v,
                    }
                };
            }
            for _ in 0..n {
                for bo in &plan {
                    let result = match bo.shape {
                        FpShape::Un(f) => {
                            let va = fetch!(bo.a);
                            if bo.hot == HotOp::VfexpH { f_vfexp_h(va) } else { f(va) }
                        }
                        FpShape::Bin(f) => {
                            let va = fetch!(bo.a);
                            let vb = fetch!(bo.b);
                            match bo.hot {
                                HotOp::VfaddH => f_vfadd_h(va, vb),
                                HotOp::VfmulH => f_vfmul_h(va, vb),
                                HotOp::VfmaxH => f_vfmax_h(va, vb),
                                _ => f(va, vb),
                            }
                        }
                        FpShape::Tri(f) => {
                            let va = fetch!(bo.a);
                            let vb = fetch!(bo.b);
                            let vc = fetch!(bo.c);
                            if bo.hot == HotOp::VfmacH {
                                f_vfmac_h(va, vb, vc)
                            } else {
                                f(va, vb, vc)
                            }
                        }
                        FpShape::FromInt { .. } => fetch!(bo.a),
                    };
                    match bo.dst {
                        Dst::Reg(r) => self.fregs[r as usize] = result,
                        Dst::Push(r) => {
                            let addr = cursors[r as usize];
                            cursors[r as usize] = addr.wrapping_add(8);
                            spm.write_u64(addr, result);
                        }
                    }
                }
            }
            for r in 0..3usize {
                if uses[r] > 0 {
                    self.ssr[r].as_mut().unwrap().advance(n * uses[r]);
                }
            }
        } else {
            macro_rules! fetch {
                ($s:expr) => {
                    match $s {
                        Src::Reg(r) => self.fregs[r as usize],
                        Src::Pop(r) => {
                            let addr = self.ssr[r as usize].as_mut().unwrap().next_addr();
                            spm.read_u64(addr)
                        }
                        Src::Imm(v) => v,
                    }
                };
            }
            for _ in 0..n {
                for bo in &plan {
                    let result = match bo.shape {
                        FpShape::Un(f) => f(fetch!(bo.a)),
                        FpShape::Bin(f) => {
                            let va = fetch!(bo.a);
                            let vb = fetch!(bo.b);
                            f(va, vb)
                        }
                        FpShape::Tri(f) => {
                            let va = fetch!(bo.a);
                            let vb = fetch!(bo.b);
                            let vc = fetch!(bo.c);
                            f(va, vb, vc)
                        }
                        FpShape::FromInt { .. } => fetch!(bo.a),
                    };
                    match bo.dst {
                        Dst::Reg(r) => self.fregs[r as usize] = result,
                        Dst::Push(r) => {
                            let addr = self.ssr[r as usize].as_mut().unwrap().next_addr();
                            spm.write_u64(addr, result);
                        }
                    }
                }
            }
        }
        // Bulk statistics: identical totals to n per-op executions.
        for bo in &plan {
            self.stats.bump_idx_n(bo.class_idx as usize, n);
            self.stats.flops += bo.flops as u64 * n;
            self.stats.exp_ops += bo.exps as u64 * n;
        }
        self.stats.ssr_beats += (uses[0] + uses[1] + uses[2]) * n;
    }

    /// Scoreboard state relative to `fpu_free` at an iteration boundary.
    /// Ready times at or behind `fpu_free` are clamped to -1: they can
    /// never bind a future `max` against the (monotone) `fpu_free`, nor
    /// any post-loop use (every such use first maxes with a quantity
    /// ≥ `last_retire` ≥ every clamped value), so distinct stale values
    /// are equivalent states.
    fn frep_snapshot(&self, fp_mask: u32) -> Vec<i64> {
        let free = self.fpu_free;
        let mut snap = Vec::with_capacity(fp_mask.count_ones() as usize + 1);
        snap.push(self.last_retire.saturating_sub(free) as i64);
        let mut m = fp_mask;
        while m != 0 {
            let r = m.trailing_zeros() as usize;
            m &= m - 1;
            let ready = self.freg_ready[r];
            snap.push(if ready >= free { (ready - free) as i64 } else { -1 });
        }
        snap
    }

    /// Execute `iters` repetitions of `body` under the FREP sequencer.
    fn run_frep(&mut self, spm: &mut Mem, body: &[MicroOp], iters: u64, info: FrepInfo) {
        if info.has_div || iters <= 2 {
            for _ in 0..iters {
                self.run_body_timed(spm, body);
            }
            return;
        }
        // warm-up: full timing until the relative scoreboard state and
        // the per-iteration fpu_free delta both repeat
        let mut prev_free = self.fpu_free;
        let mut prev: Option<(u64, Vec<i64>)> = None;
        let mut executed = 0u64;
        let mut steady: Option<u64> = None;
        while executed < iters {
            self.run_body_timed(spm, body);
            executed += 1;
            let delta = self.fpu_free - prev_free;
            prev_free = self.fpu_free;
            let snap = self.frep_snapshot(info.fp_mask);
            if let Some((pd, ps)) = &prev {
                if *pd == delta && *ps == snap {
                    steady = Some(delta);
                    break;
                }
            }
            prev = Some((delta, snap));
            if executed >= WARMUP_CAP {
                break;
            }
        }
        let remaining = iters - executed;
        if remaining == 0 {
            return;
        }
        match steady {
            Some(delta) => {
                // capture exact relative offsets at the boundary; stale
                // registers (ready < fpu_free) keep their values — they
                // are dominated by every future comparison point
                let free0 = self.fpu_free;
                let lr_rel = self.last_retire.saturating_sub(free0);
                let mut live: Vec<(usize, u64)> = Vec::new();
                let mut m = info.fp_mask;
                while m != 0 {
                    let r = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if self.freg_ready[r] >= free0 {
                        live.push((r, self.freg_ready[r] - free0));
                    }
                }
                self.run_body_batch(spm, body, remaining);
                self.fpu_free = free0 + delta * remaining;
                self.last_retire = self.last_retire.max(self.fpu_free + lr_rel);
                for (r, off) in live {
                    self.freg_ready[r] = self.fpu_free + off;
                }
            }
            None => {
                for _ in 0..remaining {
                    self.run_body_timed(spm, body);
                }
            }
        }
    }

    /// Execute the micro-op at `pc`; return the next pc.
    fn step(&mut self, spm: &mut Mem, ops: &[MicroOp], pc: usize) -> usize {
        match &ops[pc] {
            MicroOp::Addi { rd, rs1, imm } => {
                let v = self.ireg(*rs1) + imm;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Add { rd, rs1, rs2 } => {
                let v = self.ireg(*rs1) + self.ireg(*rs2);
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Sub { rd, rs1, rs2 } => {
                let v = self.ireg(*rs1) - self.ireg(*rs2);
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Slli { rd, rs1, sh } => {
                let v = self.ireg(*rs1) << sh;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Srli { rd, rs1, sh } => {
                let v = ((self.ireg(*rs1) as u64) >> sh) as i64;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Srai { rd, rs1, sh } => {
                let v = self.ireg(*rs1) >> sh;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Andi { rd, rs1, imm } => {
                let v = self.ireg(*rs1) & imm;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::Li { rd, imm } => {
                self.set_ireg(*rd, *imm);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            MicroOp::J { target } => {
                self.core_cycle += 1 + BRANCH_TAKEN_PENALTY as u64;
                self.stats.bump(Class::Branch);
                return *target as usize;
            }
            MicroOp::Bnez { rs1, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if self.ireg(*rs1) != 0 {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target as usize;
                }
            }
            MicroOp::Bgeu { rs1, rs2, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if (self.ireg(*rs1) as u64) >= (self.ireg(*rs2) as u64) {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target as usize;
                }
            }
            MicroOp::Blt { rs1, rs2, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if self.ireg(*rs1) < self.ireg(*rs2) {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target as usize;
                }
            }
            MicroOp::FmvXW { rd, fs1 } => {
                // int pipeline consumes an FP value: wait for the scoreboard
                self.core_cycle = self.core_cycle.max(self.freg_ready[*fs1 as usize]) + 1;
                self.set_ireg(*rd, self.fregs[*fs1 as usize] as u32 as i32 as i64);
                self.stats.bump(Class::FpScalarD);
            }
            MicroOp::FmvXD { rd, fs1 } => {
                self.core_cycle = self.core_cycle.max(self.freg_ready[*fs1 as usize]) + 1;
                self.set_ireg(*rd, self.fregs[*fs1 as usize] as i64);
                self.stats.bump(Class::FpScalarD);
            }
            MicroOp::Flh { fd, base, offset } => {
                let addr = (self.ireg(*base) + offset) as u32;
                let v = spm.read_u16(addr) as u64;
                self.core_cycle += 1;
                let ready = self.core_cycle + latency(Class::FpLoad) as u64;
                self.write_freg(spm, *fd, v, ready);
                self.stats.bump(Class::FpLoad);
                self.stats.mem_bytes += 2;
            }
            MicroOp::Fld { fd, base, offset } => {
                let addr = (self.ireg(*base) + offset) as u32;
                let v = spm.read_u64(addr);
                self.core_cycle += 1;
                let ready = self.core_cycle + latency(Class::FpLoad) as u64;
                self.write_freg(spm, *fd, v, ready);
                self.stats.bump(Class::FpLoad);
                self.stats.mem_bytes += 8;
            }
            MicroOp::Fsh { fs, base, offset } => {
                let addr = (self.ireg(*base) + offset) as u32;
                self.core_cycle = self.core_cycle.max(self.freg_ready[*fs as usize]) + 1;
                spm.write_u16(addr, self.fregs[*fs as usize] as u16);
                self.stats.bump(Class::FpStore);
                self.stats.mem_bytes += 2;
            }
            MicroOp::Fsd { fs, base, offset } => {
                let addr = (self.ireg(*base) + offset) as u32;
                self.core_cycle = self.core_cycle.max(self.freg_ready[*fs as usize]) + 1;
                spm.write_u64(addr, self.fregs[*fs as usize]);
                self.stats.bump(Class::FpStore);
                self.stats.mem_bytes += 8;
            }
            MicroOp::Frep { n_iter, n_instr, info } => {
                let iters = self.ireg(*n_iter).max(0) as u64;
                let body = &ops[pc + 1..pc + 1 + *n_instr as usize];
                self.core_cycle += 1; // frep issue
                self.stats.bump(Class::Frep);
                // sequencer start: body instructions already offloaded
                self.fpu_free = self.fpu_free.max(self.core_cycle);
                self.run_frep(spm, body, iters, *info);
                // the core does not stall on the sequencer, but our kernels
                // always need the results, so join the timelines here
                self.core_cycle = self.core_cycle.max(self.last_retire);
                return pc + 1 + *n_instr as usize;
            }
            MicroOp::SsrCfg { ssr, pat } => {
                self.ssr[*ssr as usize] = Some(SsrStream::new(*pat));
                // a handful of CSR writes on real hardware
                self.core_cycle += 3;
                self.stats.bump(Class::Ssr);
            }
            MicroOp::SsrEnable => {
                self.ssr_enabled = true;
                self.core_cycle += 1;
                self.stats.bump(Class::Ssr);
            }
            MicroOp::SsrDisable => {
                self.ssr_enabled = false;
                // wait for in-flight FP work before handing regs back
                self.core_cycle = self.core_cycle.max(self.last_retire) + 1;
                self.stats.bump(Class::Ssr);
            }
            MicroOp::Nop => {
                self.core_cycle += 1;
                self.stats.bump(Class::Misc);
            }
            MicroOp::Fp(op) => self.exec_fp(spm, op, false),
        }
        pc + 1
    }
}

#[cfg(test)]
mod tests {
    use super::super::core::Core;
    use super::super::decode::decode;
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::{Asm, Instr, SsrPattern};

    /// Run `prog` through both executors on identically-seeded SPMs and
    /// assert bit-identical stats and memory.
    fn differential(prog: Vec<Instr>, setup: impl Fn(&mut Mem)) -> CoreStats {
        let mut spm_ref = Mem::spm();
        setup(&mut spm_ref);
        let mut spm_fast = spm_ref.clone();
        let ref_stats = Core::new().run(&mut spm_ref, &prog);
        let dec = decode(&prog);
        let fast_stats = FastCore::new().run(&mut spm_fast, &dec);
        assert_eq!(ref_stats.cycles, fast_stats.cycles, "cycles diverge");
        assert_eq!(ref_stats.flops, fast_stats.flops);
        assert_eq!(ref_stats.exp_ops, fast_stats.exp_ops);
        assert_eq!(ref_stats.ssr_beats, fast_stats.ssr_beats);
        assert_eq!(ref_stats.mem_bytes, fast_stats.mem_bytes);
        assert_eq!(ref_stats.retired_total(), fast_stats.retired_total());
        for (c, n) in ref_stats.retired() {
            assert_eq!(n, fast_stats.count(c), "class {c:?} diverges");
        }
        assert_eq!(
            spm_ref.read_bytes(0, spm_ref.len()),
            spm_fast.read_bytes(0, spm_fast.len()),
            "memory diverges"
        );
        fast_stats
    }

    #[test]
    fn integer_loop_matches_reference() {
        let mut a = Asm::new();
        a.li(A0, 10);
        let top = a.label();
        a.bind(top);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        differential(a.finish(), |_| {});
    }

    #[test]
    fn frep_ssr_stream_matches_reference() {
        let n = 64u32;
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x300, n / 4));
        a.ssr_cfg(1, SsrPattern::write1d(0x900, n / 4));
        a.ssr_enable();
        a.li(A1, (n / 4) as i64);
        a.frep(A1, 1);
        a.vfexp_h(FT1, FT0);
        a.ssr_disable();
        let stats = differential(a.finish(), |m| {
            m.write_f32_as_bf16(0x300, &(0..64).map(|i| i as f32 * 0.05 - 1.0).collect::<Vec<_>>());
        });
        assert_eq!(stats.exp_ops, 4 * (n / 4) as u64);
    }

    #[test]
    fn dependent_chain_matches_reference() {
        // self-dependent body: steady state with a latency-bound delta
        let mut a = Asm::new();
        a.li(A1, 200);
        a.frep(A1, 1);
        a.vfmul_h(FT3, FT3, FT3);
        differential(a.finish(), |_| {});
    }

    #[test]
    fn multi_accumulator_body_matches_reference() {
        let iters = 300i64;
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x0, 4 * iters as u32));
        a.ssr_enable();
        a.li(A1, iters);
        a.frep(A1, 4);
        a.vfmax_h(FT3, FT3, FT0);
        a.vfmax_h(FT4, FT4, FT0);
        a.vfmax_h(FT5, FT5, FT0);
        a.vfmax_h(FT6, FT6, FT0);
        a.ssr_disable();
        a.vfmax_h(FT3, FT3, FT4);
        a.li(A0, 0x9000);
        a.fsd(FT3, A0, 0);
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(0, &(0..16 * iters as usize).map(|i| (i % 97) as f32).collect::<Vec<_>>());
        });
    }

    #[test]
    fn div_body_bypasses_steady_state_and_matches() {
        let mut a = Asm::new();
        a.li(A0, 0x100);
        a.flh(FT3, A0, 0);
        a.flh(FT4, A0, 2);
        a.li(A1, 20);
        a.frep(A1, 1);
        a.fdiv_h(FT5, FT3, FT4);
        a.fsh(FT5, A0, 4);
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(0x100, &[1.0, 3.0]);
        });
    }

    #[test]
    fn mixed_latency_body_matches_reference() {
        // exp (lat 2) + fp64 (lat 5) + simd in one body, non-trivial
        // cross-iteration dependencies through FT5
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x400, 128));
        a.ssr_enable();
        a.li(A1, 128);
        a.frep(A1, 3);
        a.vfexp_h(FT3, FT0);
        a.fmadd_d(FT4, FT4, FT4, FT4);
        a.vfadd_h(FT5, FT5, FT3);
        a.ssr_disable();
        a.li(A0, 0x9000);
        a.fsd(FT5, A0, 0);
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(0x400, &(0..512).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect::<Vec<_>>());
        });
    }

    #[test]
    fn post_frep_scoreboard_uses_match_reference() {
        // an Fsh right after the loop exercises the reconstructed
        // freg_ready values
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x200, 64));
        a.ssr_enable();
        a.li(A1, 64);
        a.frep(A1, 2);
        a.vfadd_h(FT3, FT3, FT0);
        a.vfmul_h(FT4, FT4, FT4);
        a.ssr_disable();
        a.li(A0, 0x8000);
        a.fsh(FT3, A0, 0);
        a.fsh(FT4, A0, 2);
        // …and a second FREP reusing the same accumulators
        a.ssr_cfg(0, SsrPattern::read1d(0x200, 32));
        a.ssr_enable();
        a.li(A1, 32);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        a.ssr_disable();
        a.fsh(FT3, A0, 4);
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(0x200, &(0..256).map(|i| (i % 7) as f32 * 0.25).collect::<Vec<_>>());
        });
    }

    #[test]
    fn walker_stream_body_matches_reference() {
        // repeat-beat pattern (stride0 = 0) stays on the reference
        // walker, forcing the batched executor's per-beat pop fallback
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read3d(0x100, 0, 4, 8, 50, 0, 1));
        a.ssr_enable();
        a.li(A1, 200);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        a.ssr_disable();
        a.li(A0, 0x8000);
        a.fsd(FT3, A0, 0);
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(
                0x100,
                &(0..200).map(|i| (i % 11) as f32 * 0.125).collect::<Vec<_>>(),
            );
        });
    }

    #[test]
    fn long_aliased_stream_matches_reference() {
        // read and write streams over the same region (the softmax NORM
        // aliasing pattern) with a long steady state: the batch loop's
        // cursor interleaving must read each beat before rewriting it
        let n = 256u32;
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x300, n));
        a.ssr_cfg(1, SsrPattern::write1d(0x300, n));
        a.ssr_enable();
        a.li(A1, n as i64);
        a.frep(A1, 1);
        a.vfmul_h(FT1, FT0, FT0);
        a.ssr_disable();
        differential(a.finish(), |m| {
            m.write_f32_as_bf16(
                0x300,
                &(0..4 * n as usize).map(|i| (i % 17) as f32 * 0.2 - 1.5).collect::<Vec<_>>(),
            );
        });
    }

    #[test]
    #[should_panic(expected = "SSR stream exhausted")]
    fn ssr_overrun_panics_like_reference() {
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x0, 1));
        a.ssr_enable();
        a.li(A1, 2);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        let dec = decode(&a.finish());
        let mut spm = Mem::spm();
        FastCore::new().run(&mut spm, &dec);
    }
}
