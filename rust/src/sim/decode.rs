//! Pre-decoded micro-op programs for the simulator fast path.
//!
//! The reference interpreter ([`super::core::Core`]) re-discovers the
//! same facts about every dynamic instruction on every execution: which
//! enum variant it is (one ~45-arm `match` in `step`, a second one in
//! `compute_fp`), its timing class, its result latency, its FPU
//! occupancy, and its FLOP/EXP work counts. [`decode`] resolves all of
//! that once per *static* instruction, lowering `Vec<Instr>` into a flat
//! [`MicroOp`] array where
//!
//! - every FP instruction becomes an [`FpOp`]: an operand *shape*
//!   (unary/binary/ternary) plus a plain function pointer for the
//!   arithmetic, raw `u8` register indices, and pre-computed latency /
//!   occupancy / class-index / flops / exp-ops constants;
//! - every FREP carries a [`FrepInfo`] with the decode-time facts the
//!   steady-state timing fast-forward needs (divider-free body, mask of
//!   FP registers the body touches);
//! - branch targets stay positional (`Instr` and `MicroOp` streams are
//!   index-for-index identical), so control flow needs no relocation.
//!
//! The arithmetic function pointers below are transcriptions of the
//! corresponding `compute_fp` arms in `core.rs` — including its quirks
//! (scalar BF16 ops preserve the upper 48 bits of operand *a*; `FmaddH`
//! does not; `FsubD` counts zero FLOPs). `tests/sim_differential.rs`
//! holds the two paths bit-identical.

use super::fpu::{latency, FDIV_OCCUPANCY};
use super::stats::class_idx;
use crate::bf16::{pack4, simd2, unpack4, Bf16};
use crate::isa::instr::{Class, Instr, SsrPattern};
use crate::vexp::{exp_unit, vfexp};

/// Operand shape + arithmetic of a decoded FP instruction. All operands
/// and results are raw 64-bit FP register images.
#[derive(Clone, Copy, Debug)]
pub enum FpShape {
    /// `dst = f(a)`
    Un(fn(u64) -> u64),
    /// `dst = f(a, b)`
    Bin(fn(u64, u64) -> u64),
    /// `dst = f(a, b, c)` (FMA family; `VfmacH` decodes with `c = dst`)
    Tri(fn(u64, u64, u64) -> u64),
    /// `dst = ireg[a]` bits (`FmvDX`), masked to 32 bits for `FmvWX`
    FromInt { wide: bool },
}

/// Identity of the handful of SIMD ops that dominate FREP steady-state
/// bodies. The batched executor dispatches on this instead of the
/// [`FpShape`] function pointer so the compiler can inline (and
/// autovectorize) the hot lane arithmetic; `Other` falls back to the
/// pointer call and covers everything else.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HotOp {
    VfmacH,
    VfaddH,
    VfmulH,
    VfmaxH,
    VfexpH,
    Other,
}

/// A fully pre-decoded FP instruction.
#[derive(Clone, Copy, Debug)]
pub struct FpOp {
    pub shape: FpShape,
    /// Static identity for the batched executor's inline dispatch.
    pub hot: HotOp,
    pub dst: u8,
    pub a: u8,
    pub b: u8,
    pub c: u8,
    /// Index into the flat `CoreStats` class counters.
    pub class_idx: u8,
    /// Result latency in cycles.
    pub latency: u8,
    /// Cycles the FPU issue port is blocked (1, or the divider occupancy).
    pub occupancy: u8,
    /// BF16 FLOPs retired per execution.
    pub flops: u8,
    /// BF16 exponentials computed per execution.
    pub exps: u8,
}

/// Decode-time facts about one FREP body.
#[derive(Clone, Copy, Debug)]
pub struct FrepInfo {
    /// Body contains an `FdivH` (divider occupancy ≠ 1): the
    /// steady-state detector is skipped and every iteration is timed.
    pub has_div: bool,
    /// Bitmask of FP registers the body reads or writes — the registers
    /// whose scoreboard state the steady-state snapshot must watch.
    pub fp_mask: u32,
}

/// One pre-decoded instruction. Index-for-index positional with the
/// source `Instr` stream.
#[derive(Clone, Copy, Debug)]
pub enum MicroOp {
    Addi { rd: u8, rs1: u8, imm: i64 },
    Add { rd: u8, rs1: u8, rs2: u8 },
    Sub { rd: u8, rs1: u8, rs2: u8 },
    Slli { rd: u8, rs1: u8, sh: u32 },
    Srli { rd: u8, rs1: u8, sh: u32 },
    Srai { rd: u8, rs1: u8, sh: u32 },
    Andi { rd: u8, rs1: u8, imm: i64 },
    Li { rd: u8, imm: i64 },
    J { target: u32 },
    Bnez { rs1: u8, target: u32 },
    Bgeu { rs1: u8, rs2: u8, target: u32 },
    Blt { rs1: u8, rs2: u8, target: u32 },
    FmvXW { rd: u8, fs1: u8 },
    FmvXD { rd: u8, fs1: u8 },
    Flh { fd: u8, base: u8, offset: i64 },
    Fld { fd: u8, base: u8, offset: i64 },
    Fsh { fs: u8, base: u8, offset: i64 },
    Fsd { fs: u8, base: u8, offset: i64 },
    Frep { n_iter: u8, n_instr: u32, info: FrepInfo },
    SsrCfg { ssr: u8, pat: SsrPattern },
    SsrEnable,
    SsrDisable,
    Nop,
    Fp(FpOp),
}

/// A compiled-and-decoded per-core instruction stream.
#[derive(Clone, Debug, Default)]
pub struct DecodedProgram {
    ops: Vec<MicroOp>,
}

impl DecodedProgram {
    pub fn ops(&self) -> &[MicroOp] {
        &self.ops
    }

    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Arithmetic transcriptions of `core.rs::compute_fp` (bit-identical).
// ---------------------------------------------------------------------------

#[inline]
fn h(v: u64) -> Bf16 {
    Bf16(v as u16)
}

// scalar BF16: low-lane result, upper 48 bits of operand `a` preserved
fn f_fadd_h(a: u64, b: u64) -> u64 { (h(a).add(h(b)).0 as u64) | (a & !0xFFFF) }
fn f_fsub_h(a: u64, b: u64) -> u64 { (h(a).sub(h(b)).0 as u64) | (a & !0xFFFF) }
fn f_fmul_h(a: u64, b: u64) -> u64 { (h(a).mul(h(b)).0 as u64) | (a & !0xFFFF) }
fn f_fmax_h(a: u64, b: u64) -> u64 { (h(a).max(h(b)).0 as u64) | (a & !0xFFFF) }
fn f_fdiv_h(a: u64, b: u64) -> u64 { (h(a).div(h(b)).0 as u64) | (a & !0xFFFF) }
// scalar FMA: low lane only (no upper-bit preservation in the reference)
fn f_fmadd_h(a: u64, b: u64, c: u64) -> u64 { h(a).fma(h(b), h(c)).0 as u64 }

// scalar FP64
fn f_fadd_d(a: u64, b: u64) -> u64 { (f64::from_bits(a) + f64::from_bits(b)).to_bits() }
fn f_fsub_d(a: u64, b: u64) -> u64 { (f64::from_bits(a) - f64::from_bits(b)).to_bits() }
fn f_fmul_d(a: u64, b: u64) -> u64 { (f64::from_bits(a) * f64::from_bits(b)).to_bits() }
fn f_fmadd_d(a: u64, b: u64, c: u64) -> u64 {
    f64::mul_add(f64::from_bits(a), f64::from_bits(b), f64::from_bits(c)).to_bits()
}

// conversions
fn f_cvt_d_h(v: u64) -> u64 { (h(v).to_f32() as f64).to_bits() }
fn f_cvt_h_d(v: u64) -> u64 { Bf16::from_f32(f64::from_bits(v) as f32).0 as u64 }
fn f_cvt_s_h(v: u64) -> u64 { h(v).to_f32().to_bits() as u64 }
fn f_cvt_d_s(v: u64) -> u64 { (f32::from_bits(v as u32) as f64).to_bits() }
fn f_cvt_s_d(v: u64) -> u64 { (f64::from_bits(v) as f32).to_bits() as u64 }
fn f_cvt_h_s(v: u64) -> u64 { Bf16::from_f32(f32::from_bits(v as u32)).0 as u64 }

// packed SIMD (4 × BF16); the five `pub(crate)` ones are also dispatched
// statically by the batched executor (`fastcore::run_body_batch`)
pub(crate) fn f_vfadd_h(a: u64, b: u64) -> u64 { simd2(a, b, Bf16::add) }
fn f_vfsub_h(a: u64, b: u64) -> u64 { simd2(a, b, Bf16::sub) }
pub(crate) fn f_vfmul_h(a: u64, b: u64) -> u64 { simd2(a, b, Bf16::mul) }
pub(crate) fn f_vfmax_h(a: u64, b: u64) -> u64 { simd2(a, b, Bf16::max) }
fn f_vfsgnj_h(a: u64, b: u64) -> u64 {
    let sgn = 0x8000_8000_8000_8000u64;
    (a & !sgn) | (b & sgn)
}
pub(crate) fn f_vfmac_h(a: u64, b: u64, c: u64) -> u64 {
    let la = unpack4(a);
    let lb = unpack4(b);
    let lc = unpack4(c);
    pack4([
        la[0].fma(lb[0], lc[0]),
        la[1].fma(lb[1], lc[1]),
        la[2].fma(lb[2], lc[2]),
        la[3].fma(lb[3], lc[3]),
    ])
}
fn f_vfsum_h(v: u64) -> u64 {
    let l = unpack4(v);
    l[0].add(l[1]).add(l[2].add(l[3])).0 as u64
}
fn f_vfmaxred_h(v: u64) -> u64 {
    let l = unpack4(v);
    l[0].max(l[1]).max(l[2].max(l[3])).0 as u64
}
fn f_vfrep_h(v: u64) -> u64 {
    let lane = v & 0xFFFF;
    lane | (lane << 16) | (lane << 32) | (lane << 48)
}

// EXP extension
fn f_fexp_h(v: u64) -> u64 { exp_unit(h(v)).0 as u64 }
pub(crate) fn f_vfexp_h(v: u64) -> u64 { vfexp(v) }

// ---------------------------------------------------------------------------
// Decoder
// ---------------------------------------------------------------------------

/// BF16 FLOPs per execution — the `count_work` table from `core.rs`,
/// quirks included (`FsubD` counts zero).
fn flop_count(i: &Instr) -> u8 {
    use Instr::*;
    match i {
        VfmacH { .. } => 8,
        VfaddH { .. } | VfsubH { .. } | VfmulH { .. } | VfmaxH { .. } => 4,
        VfsumH { .. } => 3,
        FmaddH { .. } | FmaddD { .. } => 2,
        FaddH { .. } | FsubH { .. } | FmulH { .. } | FmaxH { .. } | FdivH { .. }
        | FaddD { .. } | FmulD { .. } => 1,
        _ => 0,
    }
}

/// Decode one FP instruction into its [`FpOp`].
fn decode_fp(i: &Instr) -> FpOp {
    use Instr::*;
    let (shape, dst, a, b, c, exps) = match i {
        FaddH { fd, fs1, fs2 } => (FpShape::Bin(f_fadd_h), fd.0, fs1.0, fs2.0, 0, 0),
        FsubH { fd, fs1, fs2 } => (FpShape::Bin(f_fsub_h), fd.0, fs1.0, fs2.0, 0, 0),
        FmulH { fd, fs1, fs2 } => (FpShape::Bin(f_fmul_h), fd.0, fs1.0, fs2.0, 0, 0),
        FmaxH { fd, fs1, fs2 } => (FpShape::Bin(f_fmax_h), fd.0, fs1.0, fs2.0, 0, 0),
        FdivH { fd, fs1, fs2 } => (FpShape::Bin(f_fdiv_h), fd.0, fs1.0, fs2.0, 0, 0),
        FmaddH { fd, fs1, fs2, fs3 } => (FpShape::Tri(f_fmadd_h), fd.0, fs1.0, fs2.0, fs3.0, 0),
        FaddD { fd, fs1, fs2 } => (FpShape::Bin(f_fadd_d), fd.0, fs1.0, fs2.0, 0, 0),
        FsubD { fd, fs1, fs2 } => (FpShape::Bin(f_fsub_d), fd.0, fs1.0, fs2.0, 0, 0),
        FmulD { fd, fs1, fs2 } => (FpShape::Bin(f_fmul_d), fd.0, fs1.0, fs2.0, 0, 0),
        FmaddD { fd, fs1, fs2, fs3 } => (FpShape::Tri(f_fmadd_d), fd.0, fs1.0, fs2.0, fs3.0, 0),
        FcvtDH { fd, fs1 } => (FpShape::Un(f_cvt_d_h), fd.0, fs1.0, 0, 0, 0),
        FcvtHD { fd, fs1 } => (FpShape::Un(f_cvt_h_d), fd.0, fs1.0, 0, 0, 0),
        FcvtSH { fd, fs1 } => (FpShape::Un(f_cvt_s_h), fd.0, fs1.0, 0, 0, 0),
        FcvtDS { fd, fs1 } => (FpShape::Un(f_cvt_d_s), fd.0, fs1.0, 0, 0, 0),
        FcvtSD { fd, fs1 } => (FpShape::Un(f_cvt_s_d), fd.0, fs1.0, 0, 0, 0),
        FcvtHS { fd, fs1 } => (FpShape::Un(f_cvt_h_s), fd.0, fs1.0, 0, 0, 0),
        VfaddH { fd, fs1, fs2 } => (FpShape::Bin(f_vfadd_h), fd.0, fs1.0, fs2.0, 0, 0),
        VfsubH { fd, fs1, fs2 } => (FpShape::Bin(f_vfsub_h), fd.0, fs1.0, fs2.0, 0, 0),
        VfmulH { fd, fs1, fs2 } => (FpShape::Bin(f_vfmul_h), fd.0, fs1.0, fs2.0, 0, 0),
        VfmaxH { fd, fs1, fs2 } => (FpShape::Bin(f_vfmax_h), fd.0, fs1.0, fs2.0, 0, 0),
        VfsgnjH { fd, fs1, fs2 } => (FpShape::Bin(f_vfsgnj_h), fd.0, fs1.0, fs2.0, 0, 0),
        // the accumulator is the third operand *and* the destination;
        // operand read order (fs1, fs2, fd) matches the reference's SSR
        // pop order
        VfmacH { fd, fs1, fs2 } => (FpShape::Tri(f_vfmac_h), fd.0, fs1.0, fs2.0, fd.0, 0),
        VfsumH { fd, fs1 } => (FpShape::Un(f_vfsum_h), fd.0, fs1.0, 0, 0, 0),
        VfmaxRedH { fd, fs1 } => (FpShape::Un(f_vfmaxred_h), fd.0, fs1.0, 0, 0, 0),
        VfrepH { fd, fs1 } => (FpShape::Un(f_vfrep_h), fd.0, fs1.0, 0, 0, 0),
        FmvWX { fd, rs1 } => (FpShape::FromInt { wide: false }, fd.0, rs1.0, 0, 0, 0),
        FmvDX { fd, rs1 } => (FpShape::FromInt { wide: true }, fd.0, rs1.0, 0, 0, 0),
        FexpH { fd, fs1 } => (FpShape::Un(f_fexp_h), fd.0, fs1.0, 0, 0, 1),
        VfexpH { fd, fs1 } => (FpShape::Un(f_vfexp_h), fd.0, fs1.0, 0, 0, 4),
        other => unreachable!("not an FPU instruction: {other:?}"),
    };
    let hot = match i {
        VfmacH { .. } => HotOp::VfmacH,
        VfaddH { .. } => HotOp::VfaddH,
        VfmulH { .. } => HotOp::VfmulH,
        VfmaxH { .. } => HotOp::VfmaxH,
        VfexpH { .. } => HotOp::VfexpH,
        _ => HotOp::Other,
    };
    let class = i.class();
    FpOp {
        shape,
        hot,
        dst,
        a,
        b,
        c,
        class_idx: class_idx(class) as u8,
        latency: latency(class) as u8,
        occupancy: if class == Class::FpDivH { FDIV_OCCUPANCY as u8 } else { 1 },
        flops: flop_count(i),
        exps,
    }
}

/// FP registers an [`FpOp`] reads or writes, as a bitmask.
fn fp_op_mask(op: &FpOp) -> u32 {
    let bit = |r: u8| 1u32 << (r & 31);
    let mut m = bit(op.dst);
    match op.shape {
        FpShape::Un(_) => m |= bit(op.a),
        FpShape::Bin(_) => m |= bit(op.a) | bit(op.b),
        FpShape::Tri(_) => m |= bit(op.a) | bit(op.b) | bit(op.c),
        FpShape::FromInt { .. } => {} // `a` is an integer register
    }
    m
}

/// Lower an instruction stream into its positional micro-op array.
///
/// Panics on malformed programs (FREP bodies containing non-FP
/// instructions or running past the end) — the same conditions
/// [`crate::isa::Asm::finish`] validates at build time.
pub fn decode(prog: &[Instr]) -> DecodedProgram {
    use Instr::*;
    let mut ops = Vec::with_capacity(prog.len());
    for (pos, i) in prog.iter().enumerate() {
        let op = match i {
            Addi { rd, rs1, imm } => MicroOp::Addi { rd: rd.0, rs1: rs1.0, imm: *imm as i64 },
            Add { rd, rs1, rs2 } => MicroOp::Add { rd: rd.0, rs1: rs1.0, rs2: rs2.0 },
            Sub { rd, rs1, rs2 } => MicroOp::Sub { rd: rd.0, rs1: rs1.0, rs2: rs2.0 },
            Slli { rd, rs1, imm } => MicroOp::Slli { rd: rd.0, rs1: rs1.0, sh: *imm },
            Srli { rd, rs1, imm } => MicroOp::Srli { rd: rd.0, rs1: rs1.0, sh: *imm },
            Srai { rd, rs1, imm } => MicroOp::Srai { rd: rd.0, rs1: rs1.0, sh: *imm },
            Andi { rd, rs1, imm } => MicroOp::Andi { rd: rd.0, rs1: rs1.0, imm: *imm as i64 },
            Li { rd, imm } => MicroOp::Li { rd: rd.0, imm: *imm },
            J { target } => MicroOp::J { target: *target as u32 },
            Bnez { rs1, target } => MicroOp::Bnez { rs1: rs1.0, target: *target as u32 },
            Bgeu { rs1, rs2, target } => {
                MicroOp::Bgeu { rs1: rs1.0, rs2: rs2.0, target: *target as u32 }
            }
            Blt { rs1, rs2, target } => {
                MicroOp::Blt { rs1: rs1.0, rs2: rs2.0, target: *target as u32 }
            }
            FmvXW { rd, fs1 } => MicroOp::FmvXW { rd: rd.0, fs1: fs1.0 },
            FmvXD { rd, fs1 } => MicroOp::FmvXD { rd: rd.0, fs1: fs1.0 },
            Flh { fd, base, offset } => {
                MicroOp::Flh { fd: fd.0, base: base.0, offset: *offset as i64 }
            }
            Fld { fd, base, offset } => {
                MicroOp::Fld { fd: fd.0, base: base.0, offset: *offset as i64 }
            }
            Fsh { fs, base, offset } => {
                MicroOp::Fsh { fs: fs.0, base: base.0, offset: *offset as i64 }
            }
            Fsd { fs, base, offset } => {
                MicroOp::Fsd { fs: fs.0, base: base.0, offset: *offset as i64 }
            }
            Frep { n_iter, n_instr } => {
                let mut has_div = false;
                let mut fp_mask = 0u32;
                for k in 0..*n_instr as usize {
                    let body = prog
                        .get(pos + 1 + k)
                        .unwrap_or_else(|| panic!("FREP body runs past end at {pos}"));
                    assert!(body.is_fp(), "non-FP instr {body:?} in FREP body");
                    let fp = decode_fp(body);
                    has_div = has_div || body.class() == Class::FpDivH;
                    fp_mask |= fp_op_mask(&fp);
                }
                MicroOp::Frep {
                    n_iter: n_iter.0,
                    n_instr: *n_instr,
                    info: FrepInfo { has_div, fp_mask },
                }
            }
            SsrCfg { ssr, cfg } => MicroOp::SsrCfg { ssr: *ssr, pat: *cfg },
            SsrEnable => MicroOp::SsrEnable,
            SsrDisable => MicroOp::SsrDisable,
            Nop => MicroOp::Nop,
            fp => {
                debug_assert!(fp.is_fp(), "unhandled instruction {fp:?}");
                MicroOp::Fp(decode_fp(fp))
            }
        };
        ops.push(op);
    }
    DecodedProgram { ops }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::Asm;

    #[test]
    fn decode_is_positional() {
        let mut a = Asm::new();
        a.li(A0, 4);
        let top = a.label();
        a.bind(top);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        let prog = a.finish();
        let dec = decode(&prog);
        assert_eq!(dec.len(), prog.len());
        match dec.ops()[2] {
            MicroOp::Bnez { target, .. } => assert_eq!(target, 1),
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn frep_info_collects_body_facts() {
        let mut a = Asm::new();
        a.li(A1, 4);
        a.frep(A1, 2);
        a.vfmax_h(FT3, FT3, FT0);
        a.vfexp_h(FT4, FT3);
        let dec = decode(&a.finish());
        match dec.ops()[1] {
            MicroOp::Frep { info, .. } => {
                assert!(!info.has_div);
                assert_eq!(info.fp_mask, (1 << 0) | (1 << 3) | (1 << 4));
            }
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn div_body_is_flagged() {
        let mut a = Asm::new();
        a.li(A1, 4);
        a.frep(A1, 1);
        a.fdiv_h(FT3, FT3, FT4);
        let dec = decode(&a.finish());
        match dec.ops()[1] {
            MicroOp::Frep { info, .. } => assert!(info.has_div),
            ref other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fp_constants_match_reference_tables() {
        use crate::isa::instr::Instr;
        let op = decode_fp(&Instr::FdivH { fd: FT3, fs1: FT4, fs2: FT5 });
        assert_eq!(op.occupancy, FDIV_OCCUPANCY as u8);
        assert_eq!(op.latency, latency(Class::FpDivH) as u8);
        assert_eq!(op.flops, 1);
        assert_eq!(op.hot, HotOp::Other);
        let op = decode_fp(&Instr::VfexpH { fd: FT3, fs1: FT4 });
        assert_eq!(op.exps, 4);
        assert_eq!(op.latency, 2);
        assert_eq!(op.hot, HotOp::VfexpH);
        let op = decode_fp(&Instr::VfmacH { fd: FT3, fs1: FT0, fs2: FT1 });
        assert_eq!((op.a, op.b, op.c, op.dst), (0, 1, 3, 3));
        assert_eq!(op.flops, 8);
        assert_eq!(op.hot, HotOp::VfmacH);
    }
}
