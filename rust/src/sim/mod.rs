//! Cycle-approximate Snitch-cluster simulator (the paper's evaluation
//! substrate, built per DESIGN.md §2's substitution rule).
//!
//! - [`mem`]: SPM/HBM functional memories;
//! - [`core`]: reference interpreter — pseudo dual-issue core +
//!   pipelined FPU + FREP/SSR timing, executed straight off `Instr`;
//! - [`decode`]: `Instr` → flat micro-op lowering for the fast path;
//! - [`fastcore`]: micro-op executor with FREP steady-state timing —
//!   differential-tested bit-identical to [`core`];
//! - [`memo`]: tile-level memoization of whole program executions for
//!   the raw-speed tier (DESIGN.md §11);
//! - [`ssr`]: SSR stream address generation (reference walk + bulk flat
//!   descriptors);
//! - [`fpu`]: latency table of the extended FPU;
//! - [`dma`]: DMA/double-buffer/HBM-contention timing;
//! - [`cluster`]: the 8-core cluster;
//! - [`stats`]: retired-instruction statistics feeding the energy model;
//! - [`fault`]: seeded, deterministic fault injection (slowdowns,
//!   stalls, transient SPM corruption, offline clusters) for the
//!   robustness tier (DESIGN.md §12).

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod cluster;
pub mod core;
pub mod decode;
pub mod dma;
pub mod fastcore;
pub mod fault;
pub mod fpu;
pub mod mem;
pub mod memo;
pub mod ssr;
pub mod stats;
pub mod system;

pub use cluster::{Cluster, CORES_PER_CLUSTER};
pub use core::Core;
pub use decode::{decode, DecodedProgram, MicroOp};
pub use dma::{DmaModel, HbmModel};
pub use fastcore::FastCore;
pub use fault::{spm_checksum, ClusterFault, FaultEvent, FaultPlan, FaultSpec};
pub use mem::{Mem, SPM_BANKS, SPM_BYTES};
pub use memo::{shared_memo, SharedMemo, TileMemo};
pub use ssr::{SsrState, SsrStream};
pub use stats::{ClusterStats, CoreStats};
pub use system::{ClusterJob, SamplePolicy, System, SystemStats};

/// Cluster clock in Hz (paper: 1 GHz operating point).
pub const CLOCK_HZ: f64 = 1.0e9;

/// Convert cycles to seconds at the cluster clock.
pub fn cycles_to_s(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_HZ
}
