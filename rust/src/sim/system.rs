//! Multi-cluster Occamy-style system simulation (paper Fig. 7): C
//! clusters run real kernel programs concurrently while sharing HBM
//! bandwidth through the group crossbar.
//!
//! Unlike the analytic estimator in `coordinator::estimate`, this runs
//! the actual instruction streams per cluster and composes makespans:
//! cluster compute is independent (max), DMA streams contend.

use super::cluster::Cluster;
use super::dma::{DmaModel, HbmModel};
use super::stats::ClusterStats;
use crate::exec::program::{KernelKind, Program};
use crate::isa::Instr;

/// A multi-cluster run result.
#[derive(Clone, Debug, Default)]
pub struct SystemStats {
    pub per_cluster: Vec<ClusterStats>,
    /// System makespan in cycles (compute max + contention-scaled DMA).
    pub cycles: u64,
    /// Total bytes streamed from HBM across all clusters.
    pub hbm_bytes: u64,
}

/// One cluster's workload in a system run: a list of cached
/// [`Program`]s executed back-to-back (e.g. one per head round of a
/// batched request) plus the HBM bytes the cluster streams in.
#[derive(Clone, Debug)]
pub struct ClusterJob {
    /// Cached programs the cluster executes back-to-back.
    pub programs: Vec<Program>,
    /// HBM bytes the cluster streams (double-buffered against compute).
    pub hbm_bytes: u64,
    /// Steady-state repetition scaling applied to the simulated compute
    /// leg. The serving path simulates a capped number of identical
    /// slice repetitions and scales to the full count; repeated runs of
    /// a cached *optimized* kernel are cycle-identical (no
    /// data-dependent timing), so the scaling is exact for them. The
    /// `Baseline` kernels' libm exponential diverges once per row on
    /// the first repetition only (running max starts at −inf), bounding
    /// the scaling error to one libm-call delta per row — DESIGN.md §10.
    pub compute_scale: f64,
    /// Rated (not simulated) compute cycles appended to the compute
    /// leg, e.g. the projection GEMMs of a serving iteration priced at
    /// the measured GEMM rate.
    pub compute_extra: u64,
}

impl Default for ClusterJob {
    fn default() -> Self {
        ClusterJob { programs: vec![], hbm_bytes: 0, compute_scale: 1.0, compute_extra: 0 }
    }
}

impl ClusterJob {
    /// A job executing `programs` once, streaming `hbm_bytes`.
    pub fn new(programs: Vec<Program>, hbm_bytes: u64) -> Self {
        ClusterJob { programs, hbm_bytes, ..Default::default() }
    }

    /// Attach steady-state scaling and rated extra compute cycles.
    pub fn with_scaling(mut self, compute_scale: f64, compute_extra: u64) -> Self {
        assert!(compute_scale >= 1.0, "scale must extrapolate, not discount");
        self.compute_scale = compute_scale;
        self.compute_extra = compute_extra;
        self
    }

    /// A cluster that neither computes nor streams this run.
    pub fn idle() -> Self {
        ClusterJob::default()
    }

    /// Idle clusters take no part in the run: no DMA fill is charged
    /// and they do not contend for HBM bandwidth.
    pub fn is_idle(&self) -> bool {
        self.programs.is_empty() && self.hbm_bytes == 0 && self.compute_extra == 0
    }
}

/// The C-cluster compute system.
pub struct System {
    pub clusters: Vec<Cluster>,
    pub hbm: HbmModel,
    pub dma: DmaModel,
    /// Route cluster execution through the reference interpreter
    /// (serial, `Instr`-level) instead of the threaded micro-op fast
    /// path. The differential tests run both and require bit-identical
    /// [`SystemStats`]; the `reference-interp` cargo feature forces this
    /// on for a whole build.
    pub reference_interp: bool,
}

impl System {
    pub fn new(n_clusters: usize) -> Self {
        System {
            clusters: (0..n_clusters).map(|_| Cluster::new()).collect(),
            hbm: HbmModel::default(),
            dma: DmaModel::default(),
            reference_interp: cfg!(feature = "reference-interp"),
        }
    }

    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// Run one workload per cluster: `(programs, hbm_bytes)` — the
    /// programs execute on the cluster's cores, `hbm_bytes` is streamed
    /// in beforehand (double-buffered in steady state, so only the
    /// contended transfer time that exceeds compute is exposed).
    ///
    /// Thin wrapper over [`System::run_jobs`] for ad-hoc instruction
    /// streams; cached kernels should build [`ClusterJob`]s directly.
    pub fn run(&mut self, workloads: Vec<(Vec<Vec<Instr>>, u64)>) -> SystemStats {
        let jobs = workloads
            .into_iter()
            .map(|(streams, bytes)| {
                let programs = if streams.is_empty() {
                    vec![]
                } else {
                    vec![Program::new(KernelKind::Raw, streams)]
                };
                ClusterJob::new(programs, bytes)
            })
            .collect();
        self.run_jobs(jobs)
    }

    /// Run one [`ClusterJob`] per cluster. Each cluster executes its
    /// programs back-to-back; DMA streams of *active* clusters contend
    /// for the shared HBM bandwidth. Idle clusters (no programs, no
    /// bytes) report zero cycles — in particular they are not charged
    /// the DMA fill startup.
    ///
    /// Active clusters execute concurrently under `std::thread::scope`:
    /// they share only the read-only compiled programs (`Arc`ed inside
    /// [`Program`]), each owns its SPM, and the HBM-contention/DMA
    /// post-processing below runs serially in cluster order, so the
    /// result is deterministic and identical to the serial reference
    /// (`reference_interp = true`).
    pub fn run_jobs(&mut self, jobs: Vec<ClusterJob>) -> SystemStats {
        assert_eq!(jobs.len(), self.clusters.len(), "one job per cluster");
        let active = jobs.iter().filter(|j| !j.is_idle()).count();
        // only clusters that actually stream contend for HBM: a
        // compute-only job (no bytes) must not slow other clusters' DMA
        let streaming = jobs.iter().filter(|j| j.hbm_bytes > 0).count();
        let contention =
            self.hbm.contention_factor(streaming.max(1), self.dma.bytes_per_cycle);

        let reference = self.reference_interp;
        let raw: Vec<Option<ClusterStats>> = if reference || active <= 1 {
            self.clusters
                .iter_mut()
                .zip(&jobs)
                .map(|(cluster, job)| {
                    if job.is_idle() {
                        None
                    } else {
                        Some(run_cluster_job(cluster, job, reference))
                    }
                })
                .collect()
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .clusters
                    .iter_mut()
                    .zip(&jobs)
                    .map(|(cluster, job)| {
                        if job.is_idle() {
                            None
                        } else {
                            Some(s.spawn(move || run_cluster_job(cluster, job, false)))
                        }
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.map(|h| h.join().expect("cluster thread panicked")))
                    .collect()
            })
        };

        let mut per_cluster = Vec::with_capacity(jobs.len());
        let mut makespan = 0u64;
        let mut hbm_bytes = 0u64;
        for (job, stats) in jobs.iter().zip(raw) {
            let mut stats = match stats {
                None => {
                    per_cluster.push(ClusterStats::default());
                    continue;
                }
                Some(s) => s,
            };
            hbm_bytes += job.hbm_bytes;
            let dma = (self.dma.cycles(job.hbm_bytes) as f64 * contention) as u64;
            stats.dma_bytes = job.hbm_bytes;
            stats.dma_cycles = dma;
            // double buffering: only the slower of compute/DMA is the
            // steady-state bound; the fill transfer is exposed once.
            // The compute leg is extrapolated by the job's exact
            // repetition scale plus any rated extra cycles before the
            // max — so DMA that a longer compute leg would hide stays
            // hidden, and DMA that exceeds it stays exposed.
            let compute =
                (stats.cycles as f64 * job.compute_scale).round() as u64 + job.compute_extra;
            let fill = self.dma.startup as u64;
            let total = compute.max(dma) + fill;
            makespan = makespan.max(total);
            stats.cycles = total;
            per_cluster.push(stats);
        }
        SystemStats { per_cluster, cycles: makespan, hbm_bytes }
    }
}

/// One cluster's compute leg of a system run: its programs back-to-back
/// through the fast path (or the reference interpreter as oracle).
fn run_cluster_job(cluster: &mut Cluster, job: &ClusterJob, reference: bool) -> ClusterStats {
    let mut stats = ClusterStats::default();
    for program in &job.programs {
        let run = if reference {
            cluster.run(program.per_core())
        } else {
            cluster.run_decoded(program.decoded())
        };
        stats.append_sequential(&run);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::{Asm, SsrPattern};

    /// A small FREP workload for one cluster's cores (the SSR re-walks a
    /// 512 B window so any iteration count fits the SPM).
    fn cluster_programs(iters: i64) -> Vec<Vec<Instr>> {
        (0..8)
            .map(|c| {
                let base = 0x1000 + c as u32 * 0x1000;
                let n = iters as u32;
                let mut a = Asm::new();
                a.ssr_cfg(0, SsrPattern::read2d(base, 8, n.min(64), 0, n.div_ceil(n.min(64))));
                a.ssr_enable();
                a.li(A1, iters);
                a.frep(A1, 1);
                a.vfadd_h(FT3, FT3, FT0);
                a.ssr_disable();
                a.finish()
            })
            .collect()
    }

    #[test]
    fn makespan_is_max_over_clusters() {
        let mut sys = System::new(4);
        let workloads: Vec<_> = (0..4)
            .map(|i| (cluster_programs(100 * (i as i64 + 1)), 0u64))
            .collect();
        let stats = sys.run(workloads);
        assert_eq!(stats.per_cluster.len(), 4);
        let max = stats.per_cluster.iter().map(|c| c.cycles).max().unwrap();
        assert_eq!(stats.cycles, max);
        // cluster 3 (4x work) dominates
        assert!(stats.per_cluster[3].cycles > stats.per_cluster[0].cycles);
    }

    #[test]
    fn hbm_contention_slows_dma_bound_clusters() {
        // 16 clusters each streaming: demand 16*64 B/cyc > 512 ceiling
        let bytes = 1_000_000u64;
        let mut sys16 = System::new(16);
        let s16 = sys16.run((0..16).map(|_| (cluster_programs(10), bytes)).collect());
        let mut sys8 = System::new(8);
        let s8 = sys8.run((0..8).map(|_| (cluster_programs(10), bytes)).collect());
        // DMA-bound: 16-cluster contention doubles per-cluster DMA time
        assert!(
            s16.cycles as f64 > 1.8 * s8.cycles as f64,
            "16cl {} vs 8cl {}",
            s16.cycles,
            s8.cycles
        );
        assert_eq!(s16.hbm_bytes, 16 * bytes);
    }

    #[test]
    fn compute_bound_clusters_hide_dma() {
        // heavy compute, light DMA: makespan ≈ compute
        let mut sys = System::new(2);
        let s = sys.run(vec![
            (cluster_programs(20_000), 1024),
            (cluster_programs(20_000), 1024),
        ]);
        let compute = s.per_cluster[0].cycles;
        assert!(compute >= 20_000);
        // exposed DMA is only the fill latency
        assert!(s.cycles < compute + 2 * 128);
    }

    #[test]
    fn idle_clusters_dont_contend() {
        let mut sys = System::new(16);
        let mut workloads: Vec<(Vec<Vec<Instr>>, u64)> =
            (0..16).map(|_| (vec![], 0u64)).collect();
        workloads[0] = (cluster_programs(100), 100_000);
        let s = sys.run(workloads);
        // single active cluster: no contention factor applied
        let solo_dma = DmaModel::default().cycles(100_000);
        assert!(s.per_cluster[0].dma_cycles <= solo_dma + 1);
    }

    #[test]
    fn idle_clusters_report_zero_cycles() {
        // regression: idle clusters used to be charged the DMA fill
        // startup, skewing per-cluster stats
        let mut sys = System::new(4);
        let mut workloads: Vec<(Vec<Vec<Instr>>, u64)> =
            (0..4).map(|_| (vec![], 0u64)).collect();
        workloads[0] = (cluster_programs(50), 4096);
        let s = sys.run(workloads);
        assert!(s.per_cluster[0].cycles > 0);
        for c in 1..4 {
            assert_eq!(s.per_cluster[c].cycles, 0, "idle cluster {c} charged cycles");
            assert_eq!(s.per_cluster[c].dma_cycles, 0);
            assert!(s.per_cluster[c].per_core.is_empty());
        }
    }

    #[test]
    fn compute_scaling_extrapolates_exactly() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(200));
        // simulating one repetition scaled 3x must equal simulating three
        let mut sys_scaled = System::new(1);
        let scaled = sys_scaled
            .run_jobs(vec![ClusterJob::new(vec![one.clone()], 0).with_scaling(3.0, 0)]);
        let mut sys_full = System::new(1);
        let full = sys_full.run_jobs(vec![ClusterJob::new(vec![one.clone(); 3], 0)]);
        assert_eq!(scaled.cycles, full.cycles, "steady-state scaling must be exact");
        // rated extra compute shifts a compute-bound makespan 1:1
        let mut sys_base = System::new(1);
        let base = sys_base.run_jobs(vec![ClusterJob::new(vec![one.clone()], 0)]);
        let mut sys_extra = System::new(1);
        let extra =
            sys_extra.run_jobs(vec![ClusterJob::new(vec![one], 0).with_scaling(1.0, 5000)]);
        assert_eq!(extra.cycles, base.cycles + 5000);
    }

    #[test]
    fn multi_program_jobs_compose_sequentially() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(200));
        let mut sys1 = System::new(1);
        let single = sys1.run_jobs(vec![super::ClusterJob::new(vec![one.clone()], 0)]);
        let mut sys2 = System::new(1);
        let double =
            sys2.run_jobs(vec![super::ClusterJob::new(vec![one.clone(), one.clone()], 0)]);
        let fill = DmaModel::default().startup as u64;
        let compute1 = single.cycles - fill;
        let compute2 = double.cycles - fill;
        assert_eq!(compute2, 2 * compute1, "two rounds of the same cached program");
        // counters accumulate across rounds
        let r1 = single.per_cluster[0].combined().retired_total();
        let r2 = double.per_cluster[0].combined().retired_total();
        assert_eq!(r2, 2 * r1);
    }
}
