//! Multi-cluster Occamy-style system simulation (paper Fig. 7): C
//! clusters run real kernel programs concurrently while sharing HBM
//! bandwidth through the group crossbar.
//!
//! Unlike the analytic estimator in `coordinator::estimate`, this runs
//! the actual instruction streams per cluster and composes makespans:
//! cluster compute is independent (max), DMA streams contend.

use super::cluster::Cluster;
use super::dma::{DmaModel, HbmModel};
use super::fault::{ClusterFault, FaultPlan};
use super::memo::SharedMemo;
use super::stats::ClusterStats;
use crate::exec::program::{KernelKind, Program};
use crate::isa::Instr;

/// A multi-cluster run result.
#[derive(Clone, Debug, Default)]
pub struct SystemStats {
    pub per_cluster: Vec<ClusterStats>,
    /// System makespan in cycles (compute max + contention-scaled DMA).
    pub cycles: u64,
    /// Total bytes streamed from HBM across all clusters.
    pub hbm_bytes: u64,
    /// Upper bound on the makespan error introduced by sampled-mode
    /// extrapolation: the max over the per-cluster bounds (the makespan
    /// is a max over clusters, so its error cannot exceed any single
    /// cluster's). Zero for fully simulated runs.
    pub error_bound_cycles: u64,
    /// Effective faults injected this run, summed over clusters
    /// (DESIGN.md §12). Zero when no plan is armed or every sampled
    /// fault was a no-op.
    pub faults_injected: u32,
    /// Extra makespan cycles the fault layer added, summed over
    /// clusters (slowdowns + stalls).
    pub injected_cycles: u64,
    /// Clusters whose job transiently failed (corrupted SPM) this run.
    pub failed_clusters: Vec<usize>,
    /// Clusters that were offline this run (hard faults).
    pub offline_clusters: Vec<usize>,
}

/// Sampled-simulation policy (DESIGN.md §11): cycle-simulate the first
/// `warmup` repetitions of a repeated [`ClusterJob`] plus every
/// `stride`-th of the rest (up to `max_samples` samples), and
/// extrapolate the skipped repetitions from the sampled ones. The
/// spread of the sampled cycle counts bounds the extrapolation error,
/// reported in [`ClusterStats::sampled_error_cycles`] /
/// [`SystemStats::error_bound_cycles`].
#[derive(Clone, Copy, Debug)]
pub struct SamplePolicy {
    /// Repetitions always simulated up front (covers first-iteration
    /// effects like running-max initialization).
    pub warmup: u32,
    /// After warm-up, simulate every `stride`-th repetition.
    pub stride: u32,
    /// Cap on post-warm-up samples.
    pub max_samples: u32,
}

impl Default for SamplePolicy {
    fn default() -> Self {
        SamplePolicy { warmup: 2, stride: 16, max_samples: 6 }
    }
}

/// One cluster's workload in a system run: a list of cached
/// [`Program`]s executed back-to-back (e.g. one per head round of a
/// batched request) plus the HBM bytes the cluster streams in.
#[derive(Clone, Debug)]
pub struct ClusterJob {
    /// Cached programs the cluster executes back-to-back.
    pub programs: Vec<Program>,
    /// HBM bytes the cluster streams (double-buffered against compute).
    pub hbm_bytes: u64,
    /// Steady-state repetition scaling applied to the simulated compute
    /// leg. The serving path simulates a capped number of identical
    /// slice repetitions and scales to the full count; repeated runs of
    /// a cached *optimized* kernel are cycle-identical (no
    /// data-dependent timing), so the scaling is exact for them. The
    /// `Baseline` kernels' libm exponential diverges once per row on
    /// the first repetition only (running max starts at −inf), bounding
    /// the scaling error to one libm-call delta per row — DESIGN.md §10.
    pub compute_scale: f64,
    /// Rated (not simulated) compute cycles appended to the compute
    /// leg, e.g. the projection GEMMs of a serving iteration priced at
    /// the measured GEMM rate.
    pub compute_extra: u64,
    /// Back-to-back repetitions of the whole program list. Unlike
    /// `compute_scale` (which prices repeats analytically) every
    /// repetition here really executes — unless sampled mode elides
    /// some of them with an error bound.
    pub reps: u64,
}

impl Default for ClusterJob {
    fn default() -> Self {
        ClusterJob {
            programs: vec![],
            hbm_bytes: 0,
            compute_scale: 1.0,
            compute_extra: 0,
            reps: 1,
        }
    }
}

impl ClusterJob {
    /// A job executing `programs` once, streaming `hbm_bytes`.
    pub fn new(programs: Vec<Program>, hbm_bytes: u64) -> Self {
        ClusterJob { programs, hbm_bytes, ..Default::default() }
    }

    /// A job executing one program `reps` times back-to-back — the shape
    /// sampled-simulation mode understands.
    pub fn repeated(program: Program, reps: u64, hbm_bytes: u64) -> Self {
        assert!(reps >= 1, "a repeated job runs at least once");
        ClusterJob { programs: vec![program], hbm_bytes, reps, ..Default::default() }
    }

    /// Attach steady-state scaling and rated extra compute cycles.
    pub fn with_scaling(mut self, compute_scale: f64, compute_extra: u64) -> Self {
        assert!(compute_scale >= 1.0, "scale must extrapolate, not discount");
        self.compute_scale = compute_scale;
        self.compute_extra = compute_extra;
        self
    }

    /// A cluster that neither computes nor streams this run.
    pub fn idle() -> Self {
        ClusterJob::default()
    }

    /// Idle clusters take no part in the run: no DMA fill is charged
    /// and they do not contend for HBM bandwidth.
    pub fn is_idle(&self) -> bool {
        self.programs.is_empty() && self.hbm_bytes == 0 && self.compute_extra == 0
    }
}

/// The C-cluster compute system.
pub struct System {
    pub clusters: Vec<Cluster>,
    pub hbm: HbmModel,
    pub dma: DmaModel,
    /// Route cluster execution through the reference interpreter
    /// (serial, `Instr`-level) instead of the threaded micro-op fast
    /// path. The differential tests run both and require bit-identical
    /// [`SystemStats`]; the `reference-interp` cargo feature forces this
    /// on for a whole build.
    pub reference_interp: bool,
    /// Tile memo shared by all clusters (fast path only; the reference
    /// interpreter never consults it). `None` disables memoization.
    pub memo: Option<SharedMemo>,
    /// Sampled-simulation policy for repeated jobs. `None` (the
    /// default) simulates every repetition.
    pub sampling: Option<SamplePolicy>,
    /// Armed fault plan (DESIGN.md §12). `None` (the default) injects
    /// nothing and leaves runs bit-identical to a fault-free system.
    pub faults: Option<FaultPlan>,
    /// Fault epoch: increments once per [`System::run_jobs`] call while
    /// a plan is armed, so every run samples fresh faults.
    pub fault_epoch: u64,
}

impl System {
    pub fn new(n_clusters: usize) -> Self {
        System {
            clusters: (0..n_clusters).map(|_| Cluster::new()).collect(),
            hbm: HbmModel::default(),
            dma: DmaModel::default(),
            reference_interp: cfg!(feature = "reference-interp"),
            memo: None,
            sampling: None,
            faults: None,
            fault_epoch: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// Run one workload per cluster: `(programs, hbm_bytes)` — the
    /// programs execute on the cluster's cores, `hbm_bytes` is streamed
    /// in beforehand (double-buffered in steady state, so only the
    /// contended transfer time that exceeds compute is exposed).
    ///
    /// Thin wrapper over [`System::run_jobs`] for ad-hoc instruction
    /// streams; cached kernels should build [`ClusterJob`]s directly.
    pub fn run(&mut self, workloads: Vec<(Vec<Vec<Instr>>, u64)>) -> SystemStats {
        let jobs = workloads
            .into_iter()
            .map(|(streams, bytes)| {
                let programs = if streams.is_empty() {
                    vec![]
                } else {
                    vec![Program::new(KernelKind::Raw, streams)]
                };
                ClusterJob::new(programs, bytes)
            })
            .collect();
        self.run_jobs(jobs)
    }

    /// Run one [`ClusterJob`] per cluster. Each cluster executes its
    /// programs back-to-back; DMA streams of *active* clusters contend
    /// for the shared HBM bandwidth. Idle clusters (no programs, no
    /// bytes) report zero cycles — in particular they are not charged
    /// the DMA fill startup.
    ///
    /// Active clusters execute concurrently under `std::thread::scope`:
    /// they share only the read-only compiled programs (`Arc`ed inside
    /// [`Program`]), each owns its SPM, and the HBM-contention/DMA
    /// post-processing below runs serially in cluster order, so the
    /// result is deterministic and identical to the serial reference
    /// (`reference_interp = true`).
    pub fn run_jobs(&mut self, jobs: Vec<ClusterJob>) -> SystemStats {
        assert_eq!(jobs.len(), self.clusters.len(), "one job per cluster");
        // sample this run's faults up front (one epoch per call). With
        // no plan armed the identity fault applies everywhere and every
        // expression below reduces to the fault-free arithmetic
        // bit-for-bit (x * 1.0 == x, + 0).
        let epoch = self.fault_epoch;
        let faults: Vec<ClusterFault> = match &self.faults {
            Some(plan) => {
                self.fault_epoch += 1;
                (0..jobs.len()).map(|c| plan.fault_at(epoch, c)).collect()
            }
            None => vec![ClusterFault::none(); jobs.len()],
        };
        // offline clusters take no part in the run at all
        let active = jobs
            .iter()
            .zip(&faults)
            .filter(|(j, f)| !j.is_idle() && !f.offline)
            .count();
        // only clusters that actually stream contend for HBM: a
        // compute-only job (no bytes) must not slow other clusters' DMA
        let streaming = jobs
            .iter()
            .zip(&faults)
            .filter(|(j, f)| j.hbm_bytes > 0 && !f.offline)
            .count();
        let contention =
            self.hbm.contention_factor(streaming.max(1), self.dma.bytes_per_cycle);

        let reference = self.reference_interp;
        let memo = self.memo.clone();
        let memo_ref = memo.as_ref();
        let sampling = self.sampling;
        let raw: Vec<Option<ClusterStats>> = if reference || active <= 1 {
            self.clusters
                .iter_mut()
                .zip(jobs.iter().zip(&faults))
                .map(|(cluster, (job, fault))| {
                    if job.is_idle() || fault.offline {
                        None
                    } else {
                        Some(run_cluster_job(cluster, job, reference, memo_ref, sampling))
                    }
                })
                .collect()
        } else {
            std::thread::scope(|s| {
                let handles: Vec<_> = self
                    .clusters
                    .iter_mut()
                    .zip(jobs.iter().zip(&faults))
                    .map(|(cluster, (job, fault))| {
                        if job.is_idle() || fault.offline {
                            None
                        } else {
                            Some(
                                s.spawn(move || {
                                    run_cluster_job(cluster, job, false, memo_ref, sampling)
                                }),
                            )
                        }
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.map(|h| h.join().expect("cluster thread panicked")))
                    .collect()
            })
        };

        let mut per_cluster = Vec::with_capacity(jobs.len());
        let mut makespan = 0u64;
        let mut hbm_bytes = 0u64;
        let mut error_bound = 0u64;
        let mut faults_injected = 0u32;
        let mut injected_cycles = 0u64;
        let mut failed_clusters = Vec::new();
        let mut offline_clusters = Vec::new();
        for (i, (job, stats)) in jobs.iter().zip(raw).enumerate() {
            let fault = faults[i];
            if fault.offline {
                offline_clusters.push(i);
            }
            let mut stats = match stats {
                None => {
                    // offline cluster holding real work: the job did
                    // not run, so it counts as failed for retry logic
                    let dropped = fault.offline && !job.is_idle();
                    if dropped {
                        failed_clusters.push(i);
                        faults_injected += 1;
                    }
                    per_cluster.push(ClusterStats {
                        offline: fault.offline,
                        failed: dropped,
                        faults_injected: dropped as u32,
                        ..ClusterStats::default()
                    });
                    continue;
                }
                Some(s) => s,
            };
            hbm_bytes += job.hbm_bytes;
            let dma = (self.dma.cycles(job.hbm_bytes) as f64 * contention) as u64;
            stats.dma_bytes = job.hbm_bytes;
            stats.dma_cycles = dma;
            // double buffering: only the slower of compute/DMA is the
            // steady-state bound; the fill transfer is exposed once.
            // The compute leg is extrapolated by the job's exact
            // repetition scale plus any rated extra cycles before the
            // max — so DMA that a longer compute leg would hide stays
            // hidden, and DMA that exceeds it stays exposed. The fault
            // slowdown multiplies the compute leg; the stall lands on
            // the makespan after the overlap max (it models a global
            // hiccup nothing can hide behind).
            let clean = (stats.cycles as f64 * job.compute_scale).round() as u64
                + job.compute_extra;
            let compute = (stats.cycles as f64 * job.compute_scale * fault.slow_factor)
                .round() as u64
                + job.compute_extra;
            let fill = self.dma.startup as u64;
            let clean_total = clean.max(dma) + fill;
            let total = compute.max(dma) + fill + fault.stall_cycles;
            makespan = makespan.max(total);
            stats.cycles = total;
            stats.injected_cycles = total.saturating_sub(clean_total);
            // sampled-mode error passes through the same compute scaling
            // (an off-by-e compute leg scales to off-by-scale·e at most)
            stats.sampled_error_cycles =
                (stats.sampled_error_cycles as f64 * job.compute_scale).ceil() as u64;
            error_bound = error_bound.max(stats.sampled_error_cycles);
            // transient failure: corrupt one SPM byte post-run. The tile
            // memo recorded the clean image during execution, so the
            // corruption never pollutes the cache; a retry re-runs clean.
            if fault.fail {
                let spm = &mut self.clusters[i].spm;
                let off = self
                    .faults
                    .as_ref()
                    .expect("fail faults only come from a plan")
                    .corruption_offset(epoch, i, spm.len());
                let byte = spm.read_bytes(off as u32, 1)[0] ^ 0x5A;
                spm.load_bytes(off as u32, &[byte]);
                stats.failed = true;
                failed_clusters.push(i);
            }
            let n_eff = (stats.cycles != clean_total) as u32 + fault.fail as u32;
            stats.faults_injected = n_eff;
            faults_injected += n_eff;
            injected_cycles += stats.injected_cycles;
            per_cluster.push(stats);
        }
        SystemStats {
            per_cluster,
            cycles: makespan,
            hbm_bytes,
            error_bound_cycles: error_bound,
            faults_injected,
            injected_cycles,
            failed_clusters,
            offline_clusters,
        }
    }
}

/// One cluster's compute leg of a system run: its programs back-to-back
/// through the fast path (or the reference interpreter as oracle),
/// repeated `job.reps` times. Sampled mode elides eligible repetitions
/// (never under the reference interpreter — it stays the exact oracle).
fn run_cluster_job(
    cluster: &mut Cluster,
    job: &ClusterJob,
    reference: bool,
    memo: Option<&SharedMemo>,
    sampling: Option<SamplePolicy>,
) -> ClusterStats {
    if !reference {
        if let Some(policy) = sampling {
            if job.programs.len() == 1 && job.reps > policy.warmup as u64 + 1 {
                return run_sampled(cluster, job, policy, memo);
            }
        }
    }
    let mut stats = ClusterStats::default();
    for _ in 0..job.reps {
        for program in &job.programs {
            let run = if reference {
                cluster.run(program.per_core())
            } else {
                cluster.run_decoded_memo(program, memo)
            };
            stats.append_sequential(&run);
        }
    }
    stats
}

/// Sampled execution of a repeated single-program job: simulate the
/// warm-up and a strided sample of the rest, extrapolate the skipped
/// repetitions from the sampled ones, and bound the cycle error by the
/// observed sample spread (plus one rounding cycle).
fn run_sampled(
    cluster: &mut Cluster,
    job: &ClusterJob,
    policy: SamplePolicy,
    memo: Option<&SharedMemo>,
) -> ClusterStats {
    let program = &job.programs[0];
    let total = job.reps;
    let warmup = (policy.warmup as u64).min(total);
    let stride = (policy.stride as u64).max(1);
    let max_samples = (policy.max_samples as u64).max(1);

    let mut stats = ClusterStats::default();
    let mut sample_cycles: Vec<u64> = Vec::new();
    let mut representative: Option<ClusterStats> = None;
    let mut skipped = 0u64;
    for r in 0..total {
        let simulate = r < warmup
            || (sample_cycles.len() < max_samples as usize && (r - warmup) % stride == 0);
        if simulate {
            let run = cluster.run_decoded_memo(program, memo);
            if r >= warmup {
                sample_cycles.push(run.cycles);
                representative = Some(run.clone());
            }
            stats.append_sequential(&run);
        } else {
            skipped += 1;
        }
    }
    if skipped > 0 {
        let rep = representative.expect("eligibility guarantees a post-warm-up sample");
        let lo = *sample_cycles.iter().min().unwrap();
        let hi = *sample_cycles.iter().max().unwrap();
        let mean = sample_cycles.iter().sum::<u64>() as f64 / sample_cycles.len() as f64;
        let mut extra = rep.scaled(skipped);
        extra.cycles = (mean * skipped as f64).round() as u64;
        extra.sampled_error_cycles = skipped * (hi - lo) + 1;
        extra.sampled_reps = skipped;
        stats.append_sequential(&extra);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::{Asm, SsrPattern};

    /// A small FREP workload for one cluster's cores (the SSR re-walks a
    /// 512 B window so any iteration count fits the SPM).
    fn cluster_programs(iters: i64) -> Vec<Vec<Instr>> {
        (0..8)
            .map(|c| {
                let base = 0x1000 + c as u32 * 0x1000;
                let n = iters as u32;
                let mut a = Asm::new();
                a.ssr_cfg(0, SsrPattern::read2d(base, 8, n.min(64), 0, n.div_ceil(n.min(64))));
                a.ssr_enable();
                a.li(A1, iters);
                a.frep(A1, 1);
                a.vfadd_h(FT3, FT3, FT0);
                a.ssr_disable();
                a.finish()
            })
            .collect()
    }

    #[test]
    fn makespan_is_max_over_clusters() {
        let mut sys = System::new(4);
        let workloads: Vec<_> = (0..4)
            .map(|i| (cluster_programs(100 * (i as i64 + 1)), 0u64))
            .collect();
        let stats = sys.run(workloads);
        assert_eq!(stats.per_cluster.len(), 4);
        let max = stats.per_cluster.iter().map(|c| c.cycles).max().unwrap();
        assert_eq!(stats.cycles, max);
        // cluster 3 (4x work) dominates
        assert!(stats.per_cluster[3].cycles > stats.per_cluster[0].cycles);
    }

    #[test]
    fn hbm_contention_slows_dma_bound_clusters() {
        // 16 clusters each streaming: demand 16*64 B/cyc > 512 ceiling
        let bytes = 1_000_000u64;
        let mut sys16 = System::new(16);
        let s16 = sys16.run((0..16).map(|_| (cluster_programs(10), bytes)).collect());
        let mut sys8 = System::new(8);
        let s8 = sys8.run((0..8).map(|_| (cluster_programs(10), bytes)).collect());
        // DMA-bound: 16-cluster contention doubles per-cluster DMA time
        assert!(
            s16.cycles as f64 > 1.8 * s8.cycles as f64,
            "16cl {} vs 8cl {}",
            s16.cycles,
            s8.cycles
        );
        assert_eq!(s16.hbm_bytes, 16 * bytes);
    }

    #[test]
    fn compute_bound_clusters_hide_dma() {
        // heavy compute, light DMA: makespan ≈ compute
        let mut sys = System::new(2);
        let s = sys.run(vec![
            (cluster_programs(20_000), 1024),
            (cluster_programs(20_000), 1024),
        ]);
        let compute = s.per_cluster[0].cycles;
        assert!(compute >= 20_000);
        // exposed DMA is only the fill latency
        assert!(s.cycles < compute + 2 * 128);
    }

    #[test]
    fn idle_clusters_dont_contend() {
        let mut sys = System::new(16);
        let mut workloads: Vec<(Vec<Vec<Instr>>, u64)> =
            (0..16).map(|_| (vec![], 0u64)).collect();
        workloads[0] = (cluster_programs(100), 100_000);
        let s = sys.run(workloads);
        // single active cluster: no contention factor applied
        let solo_dma = DmaModel::default().cycles(100_000);
        assert!(s.per_cluster[0].dma_cycles <= solo_dma + 1);
    }

    #[test]
    fn idle_clusters_report_zero_cycles() {
        // regression: idle clusters used to be charged the DMA fill
        // startup, skewing per-cluster stats
        let mut sys = System::new(4);
        let mut workloads: Vec<(Vec<Vec<Instr>>, u64)> =
            (0..4).map(|_| (vec![], 0u64)).collect();
        workloads[0] = (cluster_programs(50), 4096);
        let s = sys.run(workloads);
        assert!(s.per_cluster[0].cycles > 0);
        for c in 1..4 {
            assert_eq!(s.per_cluster[c].cycles, 0, "idle cluster {c} charged cycles");
            assert_eq!(s.per_cluster[c].dma_cycles, 0);
            assert!(s.per_cluster[c].per_core.is_empty());
        }
    }

    #[test]
    fn compute_scaling_extrapolates_exactly() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(200));
        // simulating one repetition scaled 3x must equal simulating three
        let mut sys_scaled = System::new(1);
        let scaled = sys_scaled
            .run_jobs(vec![ClusterJob::new(vec![one.clone()], 0).with_scaling(3.0, 0)]);
        let mut sys_full = System::new(1);
        let full = sys_full.run_jobs(vec![ClusterJob::new(vec![one.clone(); 3], 0)]);
        assert_eq!(scaled.cycles, full.cycles, "steady-state scaling must be exact");
        // rated extra compute shifts a compute-bound makespan 1:1
        let mut sys_base = System::new(1);
        let base = sys_base.run_jobs(vec![ClusterJob::new(vec![one.clone()], 0)]);
        let mut sys_extra = System::new(1);
        let extra =
            sys_extra.run_jobs(vec![ClusterJob::new(vec![one], 0).with_scaling(1.0, 5000)]);
        assert_eq!(extra.cycles, base.cycles + 5000);
    }

    #[test]
    fn multi_program_jobs_compose_sequentially() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(200));
        let mut sys1 = System::new(1);
        let single = sys1.run_jobs(vec![super::ClusterJob::new(vec![one.clone()], 0)]);
        let mut sys2 = System::new(1);
        let double =
            sys2.run_jobs(vec![super::ClusterJob::new(vec![one.clone(), one.clone()], 0)]);
        let fill = DmaModel::default().startup as u64;
        let compute1 = single.cycles - fill;
        let compute2 = double.cycles - fill;
        assert_eq!(compute2, 2 * compute1, "two rounds of the same cached program");
        // counters accumulate across rounds
        let r1 = single.per_cluster[0].combined().retired_total();
        let r2 = double.per_cluster[0].combined().retired_total();
        assert_eq!(r2, 2 * r1);
    }

    #[test]
    fn repeated_job_equals_program_list() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(150));
        let mut sys_list = System::new(1);
        let list = sys_list.run_jobs(vec![ClusterJob::new(vec![one.clone(); 3], 0)]);
        let mut sys_reps = System::new(1);
        let reps = sys_reps.run_jobs(vec![ClusterJob::repeated(one, 3, 0)]);
        assert_eq!(list.cycles, reps.cycles);
        assert_eq!(
            list.per_cluster[0].combined().retired_total(),
            reps.per_cluster[0].combined().retired_total()
        );
    }

    #[test]
    fn sampled_mode_honors_its_error_bound() {
        use crate::exec::program::{KernelKind, Program};
        let one = Program::new(KernelKind::Raw, cluster_programs(200));
        let reps = 40u64;
        let mut full_sys = System::new(1);
        let full = full_sys.run_jobs(vec![ClusterJob::repeated(one.clone(), reps, 0)]);
        assert_eq!(full.error_bound_cycles, 0, "full runs report no error");

        let mut s_sys = System::new(1);
        s_sys.sampling = Some(SamplePolicy::default());
        let sampled = s_sys.run_jobs(vec![ClusterJob::repeated(one, reps, 0)]);
        let bound = sampled.error_bound_cycles;
        assert!(bound > 0, "extrapolated run must report a bound");
        let diff = sampled.cycles.abs_diff(full.cycles);
        assert!(diff <= bound, "diff {diff} exceeds reported bound {bound}");
        assert!(sampled.per_cluster[0].sampled_reps > 0);
        // identical repetitions extrapolate counters exactly
        assert_eq!(
            sampled.per_cluster[0].combined().retired_total(),
            full.per_cluster[0].combined().retired_total()
        );
    }

    #[test]
    fn memoized_run_is_bit_identical_and_hits() {
        use crate::exec::program::{KernelKind, Program};
        use crate::sim::memo::shared_memo;
        let one = Program::new(KernelKind::Raw, cluster_programs(100));
        let job = || vec![ClusterJob::repeated(one.clone(), 4, 0)];

        let mut plain_sys = System::new(1);
        let plain = plain_sys.run_jobs(job());

        let memo = shared_memo();
        let mut memo_sys = System::new(1);
        memo_sys.memo = Some(memo.clone());
        let memoized = memo_sys.run_jobs(job());

        assert_eq!(plain.cycles, memoized.cycles);
        assert_eq!(
            plain.per_cluster[0].combined().retired_total(),
            memoized.per_cluster[0].combined().retired_total()
        );
        let m = memo.lock().unwrap();
        assert!(m.hits > 0, "repeated identical tiles must replay from the memo");
    }
}
