//! Multi-cluster Occamy-style system simulation (paper Fig. 7): C
//! clusters run real kernel programs concurrently while sharing HBM
//! bandwidth through the group crossbar.
//!
//! Unlike the analytic estimator in `coordinator::estimate`, this runs
//! the actual instruction streams per cluster and composes makespans:
//! cluster compute is independent (max), DMA streams contend.

use super::cluster::Cluster;
use super::dma::{DmaModel, HbmModel};
use super::stats::ClusterStats;
use crate::isa::Instr;

/// A multi-cluster run result.
#[derive(Clone, Debug, Default)]
pub struct SystemStats {
    pub per_cluster: Vec<ClusterStats>,
    /// System makespan in cycles (compute max + contention-scaled DMA).
    pub cycles: u64,
    /// Total bytes streamed from HBM across all clusters.
    pub hbm_bytes: u64,
}

/// The C-cluster compute system.
pub struct System {
    pub clusters: Vec<Cluster>,
    pub hbm: HbmModel,
    pub dma: DmaModel,
}

impl System {
    pub fn new(n_clusters: usize) -> Self {
        System {
            clusters: (0..n_clusters).map(|_| Cluster::new()).collect(),
            hbm: HbmModel::default(),
            dma: DmaModel::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    /// Run one workload per cluster: `(programs, hbm_bytes)` — the
    /// programs execute on the cluster's cores, `hbm_bytes` is streamed
    /// in beforehand (double-buffered in steady state, so only the
    /// contended transfer time that exceeds compute is exposed).
    pub fn run(&mut self, workloads: Vec<(Vec<Vec<Instr>>, u64)>) -> SystemStats {
        assert_eq!(workloads.len(), self.clusters.len(), "one workload per cluster");
        let active = workloads.iter().filter(|(p, _)| !p.is_empty()).count();
        let contention = self.hbm.contention_factor(active.max(1), self.dma.bytes_per_cycle);

        let mut per_cluster = Vec::with_capacity(workloads.len());
        let mut makespan = 0u64;
        let mut hbm_bytes = 0u64;
        for (cluster, (programs, bytes)) in self.clusters.iter_mut().zip(workloads) {
            let mut stats = cluster.run(&programs);
            hbm_bytes += bytes;
            let dma = (self.dma.cycles(bytes) as f64 * contention) as u64;
            stats.dma_bytes = bytes;
            stats.dma_cycles = dma;
            // double buffering: only the slower of compute/DMA is the
            // steady-state bound; the fill transfer is exposed once
            let fill = self.dma.startup as u64;
            let total = stats.cycles.max(dma) + fill;
            makespan = makespan.max(total);
            stats.cycles = total;
            per_cluster.push(stats);
        }
        SystemStats { per_cluster, cycles: makespan, hbm_bytes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::{Asm, SsrPattern};

    /// A small FREP workload for one cluster's cores (the SSR re-walks a
    /// 512 B window so any iteration count fits the SPM).
    fn cluster_programs(iters: i64) -> Vec<Vec<Instr>> {
        (0..8)
            .map(|c| {
                let base = 0x1000 + c as u32 * 0x1000;
                let n = iters as u32;
                let mut a = Asm::new();
                a.ssr_cfg(0, SsrPattern::read2d(base, 8, n.min(64), 0, n.div_ceil(n.min(64))));
                a.ssr_enable();
                a.li(A1, iters);
                a.frep(A1, 1);
                a.vfadd_h(FT3, FT3, FT0);
                a.ssr_disable();
                a.finish()
            })
            .collect()
    }

    #[test]
    fn makespan_is_max_over_clusters() {
        let mut sys = System::new(4);
        let workloads: Vec<_> = (0..4)
            .map(|i| (cluster_programs(100 * (i as i64 + 1)), 0u64))
            .collect();
        let stats = sys.run(workloads);
        assert_eq!(stats.per_cluster.len(), 4);
        let max = stats.per_cluster.iter().map(|c| c.cycles).max().unwrap();
        assert_eq!(stats.cycles, max);
        // cluster 3 (4x work) dominates
        assert!(stats.per_cluster[3].cycles > stats.per_cluster[0].cycles);
    }

    #[test]
    fn hbm_contention_slows_dma_bound_clusters() {
        // 16 clusters each streaming: demand 16*64 B/cyc > 512 ceiling
        let bytes = 1_000_000u64;
        let mut sys16 = System::new(16);
        let s16 = sys16.run((0..16).map(|_| (cluster_programs(10), bytes)).collect());
        let mut sys8 = System::new(8);
        let s8 = sys8.run((0..8).map(|_| (cluster_programs(10), bytes)).collect());
        // DMA-bound: 16-cluster contention doubles per-cluster DMA time
        assert!(
            s16.cycles as f64 > 1.8 * s8.cycles as f64,
            "16cl {} vs 8cl {}",
            s16.cycles,
            s8.cycles
        );
        assert_eq!(s16.hbm_bytes, 16 * bytes);
    }

    #[test]
    fn compute_bound_clusters_hide_dma() {
        // heavy compute, light DMA: makespan ≈ compute
        let mut sys = System::new(2);
        let s = sys.run(vec![
            (cluster_programs(20_000), 1024),
            (cluster_programs(20_000), 1024),
        ]);
        let compute = s.per_cluster[0].cycles;
        assert!(compute >= 20_000);
        // exposed DMA is only the fill latency
        assert!(s.cycles < compute + 2 * 128);
    }

    #[test]
    fn idle_clusters_dont_contend() {
        let mut sys = System::new(16);
        let mut workloads: Vec<(Vec<Vec<Instr>>, u64)> =
            (0..16).map(|_| (vec![], 0u64)).collect();
        workloads[0] = (cluster_programs(100), 100_000);
        let s = sys.run(workloads);
        // single active cluster: no contention factor applied
        let solo_dma = DmaModel::default().cycles(100_000);
        assert!(s.per_cluster[0].dma_cycles <= solo_dma + 1);
    }
}
