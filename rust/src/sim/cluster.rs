//! The 8-core Snitch compute cluster: shared SPM + parallel cores + DMA.
//!
//! Cores execute disjoint partitions of the data (the paper parallelizes
//! softmax rows and GEMM tiles across the eight cores), so functional
//! execution runs the cores sequentially against the shared SPM while the
//! timing model takes the makespan.

use super::core::Core;
use super::decode::DecodedProgram;
use super::dma::DmaModel;
use super::fastcore::FastCore;
use super::mem::Mem;
use super::memo::SharedMemo;
use super::stats::{ClusterStats, CoreStats};
use crate::exec::program::Program;
use crate::isa::Instr;

/// Cores per cluster (paper §III-A).
pub const CORES_PER_CLUSTER: usize = 8;

/// One compute cluster.
pub struct Cluster {
    pub spm: Mem,
    pub dma: DmaModel,
}

impl Default for Cluster {
    fn default() -> Self {
        Self::new()
    }
}

impl Cluster {
    pub fn new() -> Self {
        Cluster { spm: Mem::spm(), dma: DmaModel::default() }
    }

    /// Run one program per core (up to eight) through the *reference
    /// interpreter*; returns per-core stats and the cluster makespan.
    /// Programs must touch disjoint SPM outputs. This is the oracle the
    /// decoded fast path ([`Cluster::run_decoded`]) is differential-
    /// tested against.
    pub fn run(&mut self, programs: &[Vec<Instr>]) -> ClusterStats {
        assert!(
            programs.len() <= CORES_PER_CLUSTER,
            "{} programs > {CORES_PER_CLUSTER} cores",
            programs.len()
        );
        let mut per_core = Vec::with_capacity(programs.len());
        for prog in programs {
            let mut core = Core::new();
            per_core.push(core.run(&mut self.spm, prog));
        }
        let cycles = per_core.iter().map(|s: &CoreStats| s.cycles).max().unwrap_or(0);
        ClusterStats { per_core, cycles, ..Default::default() }
    }

    /// Run one pre-decoded program per core through the micro-op fast
    /// path. Semantics (cores sequential against the shared SPM, timing
    /// makespan) are identical to [`Cluster::run`].
    pub fn run_decoded(&mut self, programs: &[DecodedProgram]) -> ClusterStats {
        assert!(
            programs.len() <= CORES_PER_CLUSTER,
            "{} programs > {CORES_PER_CLUSTER} cores",
            programs.len()
        );
        let mut per_core = Vec::with_capacity(programs.len());
        for prog in programs {
            let mut core = FastCore::new();
            per_core.push(core.run(&mut self.spm, prog));
        }
        let cycles = per_core.iter().map(|s: &CoreStats| s.cycles).max().unwrap_or(0);
        ClusterStats { per_core, cycles, ..Default::default() }
    }

    /// Fast-path execution of a compiled [`Program`] through the tile
    /// memo: an identical (decoded stream, SPM image) pair replays the
    /// recorded stats and SPM effect instead of re-executing. The lock
    /// is held only for the probe and the record, never across the
    /// execution itself, so concurrently running clusters don't
    /// serialize on the memo.
    pub fn run_decoded_memo(
        &mut self,
        program: &Program,
        memo: Option<&SharedMemo>,
    ) -> ClusterStats {
        let Some(memo) = memo else {
            return self.run_decoded(program.decoded());
        };
        let key = program.decoded_arc();
        if let Some(stats) = memo.lock().unwrap().replay(key, &mut self.spm) {
            return stats;
        }
        let before = self.spm.read_bytes(0, self.spm.len()).to_vec();
        let stats = self.run_decoded(program.decoded());
        memo.lock().unwrap().record(key, before, &self.spm, &stats);
        stats
    }

    /// Run a compiled [`Program`] on this cluster: the decoded fast path
    /// by default, or the reference interpreter when the crate is built
    /// with the `reference-interp` feature.
    pub fn run_program(&mut self, program: &Program) -> ClusterStats {
        if cfg!(feature = "reference-interp") {
            self.run(program.per_core())
        } else {
            self.run_decoded(program.decoded())
        }
    }

    /// [`Cluster::run_program`] with a tile memo on the fast path (the
    /// reference-interp build ignores the memo and stays the oracle).
    pub fn run_program_memo(
        &mut self,
        program: &Program,
        memo: Option<&SharedMemo>,
    ) -> ClusterStats {
        if cfg!(feature = "reference-interp") {
            self.run(program.per_core())
        } else {
            self.run_decoded_memo(program, memo)
        }
    }

    /// Run the same kernel-builder on all eight cores with the core index
    /// passed in (the SPMD pattern every paper kernel uses).
    pub fn run_spmd<F>(&mut self, build: F) -> ClusterStats
    where
        F: Fn(usize) -> Vec<Instr>,
    {
        let programs: Vec<_> = (0..CORES_PER_CLUSTER).map(build).collect();
        self.run(&programs)
    }

    /// Account a DMA transfer that is *not* overlapped with compute
    /// (e.g. the initial tile load).
    pub fn dma_transfer(&mut self, stats: &mut ClusterStats, bytes: u64) {
        stats.dma_bytes += bytes;
        let c = self.dma.cycles(bytes);
        stats.dma_cycles += c;
        stats.cycles += c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bf16::Bf16;
    use crate::isa::regs::*;
    use crate::isa::{Asm, SsrPattern};

    /// Each core scales its own 64-element row by 2.0 via FREP+SSR.
    #[test]
    fn spmd_rows_are_disjoint_and_parallel() {
        let n = 64u32;
        let mut cluster = Cluster::new();
        let data: Vec<f32> = (0..8 * n).map(|i| i as f32 * 0.125).collect();
        cluster.spm.write_f32_as_bf16(0, &data);
        // constant 2.0 broadcast at 0x1F000
        cluster.spm.write_f32_as_bf16(0x1F000, &[2.0, 2.0, 2.0, 2.0]);

        let stats = cluster.run_spmd(|core| {
            let row = 2 * n * core as u32; // byte offset of this core's row
            let mut a = Asm::new();
            a.li(A0, 0x1F000);
            a.fld(FT3, A0, 0);
            a.ssr_cfg(0, SsrPattern::read1d(row, n / 4));
            a.ssr_cfg(1, SsrPattern::write1d(0x8000 + row, n / 4));
            a.ssr_enable();
            a.li(A1, (n / 4) as i64);
            a.frep(A1, 1);
            a.vfmul_h(FT1, FT3, FT0);
            a.ssr_disable();
            a.finish()
        });

        assert_eq!(stats.per_core.len(), 8);
        for core in 0..8 {
            let out = cluster.spm.read_bf16_as_f32(0x8000 + 2 * n * core as u32, n as usize);
            for (i, &y) in out.iter().enumerate() {
                let x = Bf16::from_f32((core as u32 * n + i as u32) as f32 * 0.125).to_f32();
                assert_eq!(y, x * 2.0, "core {core} elem {i}");
            }
        }
        // cores are balanced: makespan == every core's cycles
        let c0 = stats.per_core[0].cycles;
        assert!(stats.per_core.iter().all(|s| s.cycles.abs_diff(c0) < 4));
    }

    #[test]
    #[should_panic(expected = "programs > 8 cores")]
    fn too_many_programs_panics() {
        let mut cluster = Cluster::new();
        let progs = vec![vec![Instr::Nop]; 9];
        cluster.run(&progs);
    }

    #[test]
    fn dma_adds_unoverlapped_cycles() {
        let mut cluster = Cluster::new();
        let mut stats = ClusterStats::default();
        cluster.dma_transfer(&mut stats, 64 * 100);
        assert_eq!(stats.dma_bytes, 6400);
        assert_eq!(stats.cycles, 100 + 100);
    }
}
