//! Execution statistics collected by the core model; input to the energy
//! model and the benchmark reports.

use crate::isa::Class;

/// All instruction classes, in the order of the flat counter array.
pub const CLASSES: [Class; 12] = [
    Class::IntAlu, Class::Branch, Class::FpLoad, Class::FpStore,
    Class::FpScalarH, Class::FpScalarD, Class::FpDivH, Class::FpSimd,
    Class::FpExp, Class::Ssr, Class::Frep, Class::Misc,
];

#[inline]
fn class_idx(c: Class) -> usize {
    match c {
        Class::IntAlu => 0, Class::Branch => 1, Class::FpLoad => 2,
        Class::FpStore => 3, Class::FpScalarH => 4, Class::FpScalarD => 5,
        Class::FpDivH => 6, Class::FpSimd => 7, Class::FpExp => 8,
        Class::Ssr => 9, Class::Frep => 10, Class::Misc => 11,
    }
}

/// Per-core run statistics.
#[derive(Clone, Debug, Default)]
pub struct CoreStats {
    /// Total cycles from first issue to last retire.
    pub cycles: u64,
    /// Retired instruction count per class (FREP bodies counted per
    /// dynamic iteration), indexed by [`CLASSES`] order.
    retired_arr: [u64; 12],
    /// 64-bit SSR beats streamed (reads + writes).
    pub ssr_beats: u64,
    /// Bytes moved by explicit FP loads/stores.
    pub mem_bytes: u64,
    /// BF16 exponentials computed (scalar = 1, SIMD = 4 per instr).
    pub exp_ops: u64,
    /// BF16 FLOPs (SIMD MAC = 8, SIMD = 4, scalar = 1 per instr).
    pub flops: u64,
}

impl CoreStats {
    pub fn retired_total(&self) -> u64 {
        self.retired_arr.iter().sum()
    }

    pub fn count(&self, class: Class) -> u64 {
        self.retired_arr[class_idx(class)]
    }

    #[inline]
    pub fn bump(&mut self, class: Class) {
        self.retired_arr[class_idx(class)] += 1;
    }

    /// Iterate (class, count) pairs with non-zero counts.
    pub fn retired(&self) -> impl Iterator<Item = (Class, u64)> + '_ {
        CLASSES.iter().zip(self.retired_arr.iter())
            .filter(|(_, &n)| n > 0)
            .map(|(c, &n)| (*c, n))
    }

    /// Fraction of cycles with an FPU instruction retiring (the paper's
    /// "FPU utilization" metric).
    pub fn fpu_utilization(&self) -> f64 {
        let fp: u64 = [
            Class::FpScalarH,
            Class::FpScalarD,
            Class::FpSimd,
            Class::FpExp,
            Class::FpDivH,
        ]
        .iter()
        .map(|c| self.count(*c))
        .sum();
        if self.cycles == 0 {
            0.0
        } else {
            fp as f64 / self.cycles as f64
        }
    }

    /// Merge another core's stats (used for cluster aggregation).
    pub fn merge(&mut self, other: &CoreStats) {
        self.cycles = self.cycles.max(other.cycles);
        for i in 0..12 {
            self.retired_arr[i] += other.retired_arr[i];
        }
        self.ssr_beats += other.ssr_beats;
        self.mem_bytes += other.mem_bytes;
        self.exp_ops += other.exp_ops;
        self.flops += other.flops;
    }
}

/// A cluster-level run: per-core stats plus DMA traffic.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    pub per_core: Vec<CoreStats>,
    /// Cluster makespan in cycles (max over cores, incl. DMA overlap).
    pub cycles: u64,
    /// Bytes moved by the DMA engine (HBM <-> SPM).
    pub dma_bytes: u64,
    /// Cycles the DMA engine was busy.
    pub dma_cycles: u64,
}

impl ClusterStats {
    /// Sum of per-core stats (cycles = max, counters summed).
    pub fn combined(&self) -> CoreStats {
        let mut acc = CoreStats::default();
        for c in &self.per_core {
            acc.merge(c);
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_and_count() {
        let mut s = CoreStats::default();
        s.bump(Class::FpSimd);
        s.bump(Class::FpSimd);
        s.bump(Class::IntAlu);
        assert_eq!(s.count(Class::FpSimd), 2);
        assert_eq!(s.retired_total(), 3);
    }

    #[test]
    fn utilization() {
        let mut s = CoreStats::default();
        s.cycles = 10;
        for _ in 0..8 {
            s.bump(Class::FpSimd);
        }
        assert!((s.fpu_utilization() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn merge_takes_max_cycles_sums_counters() {
        let mut a = CoreStats { cycles: 5, ..Default::default() };
        a.bump(Class::FpExp);
        a.exp_ops = 4;
        let mut b = CoreStats { cycles: 9, ..Default::default() };
        b.bump(Class::FpExp);
        b.exp_ops = 4;
        a.merge(&b);
        assert_eq!(a.cycles, 9);
        assert_eq!(a.count(Class::FpExp), 2);
        assert_eq!(a.exp_ops, 8);
    }
}
