//! Execution statistics collected by the core model; input to the energy
//! model and the benchmark reports.

use crate::isa::Class;

/// All instruction classes, in the order of the flat counter array.
pub const CLASSES: [Class; 12] = [
    Class::IntAlu, Class::Branch, Class::FpLoad, Class::FpStore,
    Class::FpScalarH, Class::FpScalarD, Class::FpDivH, Class::FpSimd,
    Class::FpExp, Class::Ssr, Class::Frep, Class::Misc,
];

/// Flat counter index of a class (decode pre-resolves this for FP ops).
#[inline]
pub(crate) fn class_idx(c: Class) -> usize {
    match c {
        Class::IntAlu => 0, Class::Branch => 1, Class::FpLoad => 2,
        Class::FpStore => 3, Class::FpScalarH => 4, Class::FpScalarD => 5,
        Class::FpDivH => 6, Class::FpSimd => 7, Class::FpExp => 8,
        Class::Ssr => 9, Class::Frep => 10, Class::Misc => 11,
    }
}

/// Per-core run statistics.
#[derive(Clone, Debug, Default)]
pub struct CoreStats {
    /// Total cycles from first issue to last retire.
    pub cycles: u64,
    /// Retired instruction count per class (FREP bodies counted per
    /// dynamic iteration), indexed by [`CLASSES`] order.
    retired_arr: [u64; 12],
    /// 64-bit SSR beats streamed (reads + writes).
    pub ssr_beats: u64,
    /// Bytes moved by explicit FP loads/stores.
    pub mem_bytes: u64,
    /// BF16 exponentials computed (scalar = 1, SIMD = 4 per instr).
    pub exp_ops: u64,
    /// BF16 FLOPs (SIMD MAC = 8, SIMD = 4, scalar = 1 per instr).
    pub flops: u64,
}

impl CoreStats {
    pub fn retired_total(&self) -> u64 {
        self.retired_arr.iter().sum()
    }

    pub fn count(&self, class: Class) -> u64 {
        self.retired_arr[class_idx(class)]
    }

    #[inline]
    pub fn bump(&mut self, class: Class) {
        self.retired_arr[class_idx(class)] += 1;
    }

    /// Bump by pre-resolved counter index (the decoded fast path).
    #[inline]
    pub(crate) fn bump_idx(&mut self, idx: usize) {
        self.retired_arr[idx] += 1;
    }

    /// Bump a pre-resolved counter by `n` at once (the batched fast path
    /// accounts a whole steady-state chunk with one call per op).
    #[inline]
    pub(crate) fn bump_idx_n(&mut self, idx: usize, n: u64) {
        self.retired_arr[idx] += n;
    }

    /// These stats repeated back-to-back `k` times on the same core:
    /// cycles and every counter scale by `k` (sampled-mode extrapolation).
    pub fn scaled(&self, k: u64) -> CoreStats {
        let mut out = self.clone();
        out.cycles *= k;
        for c in out.retired_arr.iter_mut() {
            *c *= k;
        }
        out.ssr_beats *= k;
        out.mem_bytes *= k;
        out.exp_ops *= k;
        out.flops *= k;
        out
    }

    /// Iterate (class, count) pairs with non-zero counts.
    pub fn retired(&self) -> impl Iterator<Item = (Class, u64)> + '_ {
        CLASSES.iter().zip(self.retired_arr.iter())
            .filter(|(_, &n)| n > 0)
            .map(|(c, &n)| (*c, n))
    }

    /// Fraction of cycles with an FPU instruction retiring (the paper's
    /// "FPU utilization" metric).
    pub fn fpu_utilization(&self) -> f64 {
        let fp: u64 = [
            Class::FpScalarH,
            Class::FpScalarD,
            Class::FpSimd,
            Class::FpExp,
            Class::FpDivH,
        ]
        .iter()
        .map(|c| self.count(*c))
        .sum();
        if self.cycles == 0 {
            0.0
        } else {
            fp as f64 / self.cycles as f64
        }
    }

    /// Sum the event counters of `other` into `self` (cycles excluded —
    /// the two composition modes below disagree on those).
    fn add_counters(&mut self, other: &CoreStats) {
        for (mine, theirs) in self.retired_arr.iter_mut().zip(&other.retired_arr) {
            *mine += theirs;
        }
        self.ssr_beats += other.ssr_beats;
        self.mem_bytes += other.mem_bytes;
        self.exp_ops += other.exp_ops;
        self.flops += other.flops;
    }

    /// Merge another core's stats (used for cluster aggregation):
    /// parallel in time, so cycles take the max.
    pub fn merge(&mut self, other: &CoreStats) {
        self.cycles = self.cycles.max(other.cycles);
        self.add_counters(other);
    }

    /// Compose a run executed *after* this one on the same core:
    /// cycles add (sequential in time), counters add.
    pub fn append_sequential(&mut self, other: &CoreStats) {
        self.cycles += other.cycles;
        self.add_counters(other);
    }
}

/// A cluster-level run: per-core stats plus DMA traffic.
#[derive(Clone, Debug, Default)]
pub struct ClusterStats {
    pub per_core: Vec<CoreStats>,
    /// Cluster makespan in cycles (max over cores, incl. DMA overlap).
    pub cycles: u64,
    /// Bytes moved by the DMA engine (HBM <-> SPM).
    pub dma_bytes: u64,
    /// Cycles the DMA engine was busy.
    pub dma_cycles: u64,
    /// Upper bound on the cycle error introduced by sampled-mode
    /// extrapolation (0 for fully simulated runs; DESIGN.md §11).
    pub sampled_error_cycles: u64,
    /// Repetitions whose effect was extrapolated rather than simulated.
    pub sampled_reps: u64,
    /// The cluster's job transiently failed this run (SPM corrupted by
    /// an injected fault; the result must not be trusted).
    pub failed: bool,
    /// The cluster was offline and executed nothing.
    pub offline: bool,
    /// Extra cycles the fault layer added (slowdown + stall), i.e.
    /// `cycles` minus what the fault-free run would have cost.
    pub injected_cycles: u64,
    /// Number of effective faults injected into this cluster's run.
    pub faults_injected: u32,
}

impl ClusterStats {
    /// Sum of per-core stats (cycles = max, counters summed).
    pub fn combined(&self) -> CoreStats {
        let mut acc = CoreStats::default();
        for c in &self.per_core {
            acc.merge(c);
        }
        acc
    }

    /// Compose a cluster run executed *after* this one (e.g. the next
    /// program of a multi-program [`crate::sim::system::ClusterJob`]):
    /// makespans and DMA traffic add, per-core counters accumulate.
    pub fn append_sequential(&mut self, other: &ClusterStats) {
        if self.per_core.len() < other.per_core.len() {
            self.per_core.resize(other.per_core.len(), CoreStats::default());
        }
        for (mine, theirs) in self.per_core.iter_mut().zip(&other.per_core) {
            mine.append_sequential(theirs);
        }
        self.cycles += other.cycles;
        self.dma_bytes += other.dma_bytes;
        self.dma_cycles += other.dma_cycles;
        self.sampled_error_cycles += other.sampled_error_cycles;
        self.sampled_reps += other.sampled_reps;
        self.failed |= other.failed;
        self.offline |= other.offline;
        self.injected_cycles += other.injected_cycles;
        self.faults_injected += other.faults_injected;
    }

    /// This cluster run repeated back-to-back `k` times: everything
    /// scales linearly (sampled-mode extrapolation of skipped reps).
    pub fn scaled(&self, k: u64) -> ClusterStats {
        ClusterStats {
            per_core: self.per_core.iter().map(|c| c.scaled(k)).collect(),
            cycles: self.cycles * k,
            dma_bytes: self.dma_bytes * k,
            dma_cycles: self.dma_cycles * k,
            sampled_error_cycles: self.sampled_error_cycles * k,
            sampled_reps: self.sampled_reps * k,
            failed: self.failed,
            offline: self.offline,
            injected_cycles: self.injected_cycles * k,
            faults_injected: self.faults_injected * k as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_and_count() {
        let mut s = CoreStats::default();
        s.bump(Class::FpSimd);
        s.bump(Class::FpSimd);
        s.bump(Class::IntAlu);
        assert_eq!(s.count(Class::FpSimd), 2);
        assert_eq!(s.retired_total(), 3);
    }

    #[test]
    fn utilization() {
        let mut s = CoreStats::default();
        s.cycles = 10;
        for _ in 0..8 {
            s.bump(Class::FpSimd);
        }
        assert!((s.fpu_utilization() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn append_sequential_sums_cycles_and_counters() {
        let mut a = CoreStats { cycles: 5, ..Default::default() };
        a.bump(Class::FpSimd);
        let mut b = CoreStats { cycles: 9, ..Default::default() };
        b.bump(Class::FpSimd);
        a.append_sequential(&b);
        assert_eq!(a.cycles, 14);
        assert_eq!(a.count(Class::FpSimd), 2);

        let mut ca = ClusterStats {
            per_core: vec![a.clone()],
            cycles: 14,
            dma_bytes: 10,
            dma_cycles: 3,
            ..Default::default()
        };
        let cb = ClusterStats {
            per_core: vec![b.clone(), b],
            cycles: 9,
            dma_bytes: 1,
            dma_cycles: 2,
            ..Default::default()
        };
        ca.append_sequential(&cb);
        assert_eq!(ca.cycles, 23);
        assert_eq!(ca.dma_bytes, 11);
        assert_eq!(ca.dma_cycles, 5);
        assert_eq!(ca.per_core.len(), 2);
        assert_eq!(ca.per_core[0].cycles, 23);
        assert_eq!(ca.per_core[1].cycles, 9);
    }

    #[test]
    fn merge_takes_max_cycles_sums_counters() {
        let mut a = CoreStats { cycles: 5, ..Default::default() };
        a.bump(Class::FpExp);
        a.exp_ops = 4;
        let mut b = CoreStats { cycles: 9, ..Default::default() };
        b.bump(Class::FpExp);
        b.exp_ops = 4;
        a.merge(&b);
        assert_eq!(a.cycles, 9);
        assert_eq!(a.count(Class::FpExp), 2);
        assert_eq!(a.exp_ops, 8);
    }

    #[test]
    fn scaled_matches_repeated_append() {
        let mut core = CoreStats { cycles: 7, ssr_beats: 3, flops: 12, ..Default::default() };
        core.bump(Class::FpSimd);
        let one = ClusterStats {
            per_core: vec![core],
            cycles: 7,
            dma_bytes: 64,
            dma_cycles: 2,
            ..Default::default()
        };
        let mut appended = one.clone();
        for _ in 0..4 {
            appended.append_sequential(&one);
        }
        let scaled = one.scaled(5);
        assert_eq!(scaled.cycles, appended.cycles);
        assert_eq!(scaled.dma_bytes, appended.dma_bytes);
        assert_eq!(scaled.dma_cycles, appended.dma_cycles);
        assert_eq!(scaled.per_core[0].cycles, appended.per_core[0].cycles);
        assert_eq!(scaled.per_core[0].flops, appended.per_core[0].flops);
        assert_eq!(scaled.per_core[0].ssr_beats, appended.per_core[0].ssr_beats);
        assert_eq!(
            scaled.per_core[0].count(Class::FpSimd),
            appended.per_core[0].count(Class::FpSimd)
        );
    }
}
