//! DMA engine timing model (paper §III-A: dedicated DMA core, up to
//! 512 bit/cycle between SPM and HBM/other clusters).

/// DMA transfer parameters.
#[derive(Clone, Copy, Debug)]
pub struct DmaModel {
    /// Peak bandwidth in bytes per cycle (512 bit = 64 B).
    pub bytes_per_cycle: u32,
    /// Fixed startup latency per transfer (descriptor + HBM access).
    pub startup: u32,
}

impl Default for DmaModel {
    fn default() -> Self {
        DmaModel { bytes_per_cycle: 64, startup: 100 }
    }
}

impl DmaModel {
    /// Cycles to move `bytes` in one contiguous transfer.
    pub fn cycles(&self, bytes: u64) -> u64 {
        if bytes == 0 {
            return 0;
        }
        self.startup as u64 + bytes.div_ceil(self.bytes_per_cycle as u64)
    }

    /// Makespan of a double-buffered pipeline: per-iteration compute
    /// cycles overlapped with the next iteration's transfer cycles
    /// (paper §III-C: double buffering masks data marshalling).
    ///
    /// `tiles` iterations, each needing `dma` cycles of transfer before
    /// `compute` cycles of work.
    pub fn double_buffered(&self, tiles: &[(u64, u64)]) -> u64 {
        // fill: first transfer is exposed
        let mut t = match tiles.first() {
            Some(&(dma, _)) => dma,
            None => return 0,
        };
        for i in 0..tiles.len() {
            let compute = tiles[i].1;
            let next_dma = if i + 1 < tiles.len() { tiles[i + 1].0 } else { 0 };
            t += compute.max(next_dma);
        }
        t
    }
}

/// Aggregate HBM bandwidth ceiling for a group of clusters (paper Fig. 7:
/// eight HBM channels per group through a wide crossbar).
#[derive(Clone, Copy, Debug)]
pub struct HbmModel {
    /// Total bytes per cycle across all channels of a group.
    pub bytes_per_cycle: u64,
}

impl Default for HbmModel {
    fn default() -> Self {
        // 8 channels x 64 B/cycle
        HbmModel { bytes_per_cycle: 512 }
    }
}

impl HbmModel {
    /// Scale per-cluster DMA time when `clusters` stream concurrently:
    /// below the ceiling there is no slowdown, above it bandwidth shares
    /// proportionally.
    pub fn contention_factor(&self, clusters: usize, per_cluster_bpc: u32) -> f64 {
        let demand = clusters as u64 * per_cluster_bpc as u64;
        if demand <= self.bytes_per_cycle {
            1.0
        } else {
            demand as f64 / self.bytes_per_cycle as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_math() {
        let d = DmaModel::default();
        assert_eq!(d.cycles(0), 0);
        assert_eq!(d.cycles(64), 100 + 1);
        assert_eq!(d.cycles(65), 100 + 2);
        assert_eq!(d.cycles(64 * 1000), 100 + 1000);
    }

    #[test]
    fn double_buffering_hides_transfers_when_compute_bound() {
        let d = DmaModel::default();
        // dma 100, compute 1000 per tile, 4 tiles: only first dma exposed
        let tiles = vec![(100, 1000); 4];
        assert_eq!(d.double_buffered(&tiles), 100 + 4 * 1000);
    }

    #[test]
    fn double_buffering_exposes_dma_when_memory_bound() {
        let d = DmaModel::default();
        // dma 1000, compute 100: pipeline is transfer-limited
        let tiles = vec![(1000, 100); 4];
        assert_eq!(d.double_buffered(&tiles), 1000 + 3 * 1000 + 100);
    }

    #[test]
    fn hbm_contention_kicks_in_past_ceiling() {
        let h = HbmModel::default();
        assert_eq!(h.contention_factor(8, 64), 1.0);
        assert!((h.contention_factor(16, 64) - 2.0).abs() < 1e-9);
    }
}
