//! Snitch core model: functional execution + cycle-approximate timing.
//!
//! Models the pseudo dual-issue structure of Snitch [1]: the integer core
//! issues at most one instruction per cycle and hands FP instructions to
//! the FPU sequencer (offload handshake); the FPU is an in-order,
//! fully-pipelined unit with per-class result latencies and a register
//! scoreboard. FREP bodies are issued by the sequencer at one FP
//! instruction per cycle subject only to data dependencies — which is
//! exactly why the paper's FREP+SSR kernels reach ~1 instr/cycle while
//! the scalar baseline pays core-issue, load-use and branch overheads.

use super::fpu::{latency, BRANCH_TAKEN_PENALTY, FDIV_OCCUPANCY, FP_OFFLOAD_OVERHEAD};
use super::mem::Mem;
use super::ssr::SsrState;
use super::stats::CoreStats;
use crate::bf16::{pack4, simd2, unpack4, Bf16};
use crate::isa::instr::{Class, Instr};
use crate::isa::regs::{FReg, IReg};
use crate::vexp::{exp_unit, vfexp};

/// One Snitch core (integer registers + 64-bit FP register file).
pub struct Core {
    pub iregs: [i64; 32],
    pub fregs: [u64; 32],
    freg_ready: [u64; 32],
    ssr: [Option<SsrState>; 3],
    ssr_enabled: bool,
    core_cycle: u64,
    fpu_free: u64,
    last_retire: u64,
    stats: CoreStats,
}

impl Default for Core {
    fn default() -> Self {
        Self::new()
    }
}

impl Core {
    pub fn new() -> Self {
        Core {
            iregs: [0; 32],
            fregs: [0; 32],
            freg_ready: [0; 32],
            ssr: [None, None, None],
            ssr_enabled: false,
            core_cycle: 0,
            fpu_free: 0,
            last_retire: 0,
            stats: CoreStats::default(),
        }
    }

    /// Run a program to completion against `spm`; returns the stats.
    pub fn run(&mut self, spm: &mut Mem, prog: &[Instr]) -> CoreStats {
        let mut pc = 0usize;
        let mut guard = 0u64;
        while pc < prog.len() {
            guard += 1;
            assert!(guard < 500_000_000, "runaway program");
            pc = self.step(spm, prog, pc);
        }
        let mut s = self.stats.clone();
        s.cycles = self.core_cycle.max(self.last_retire);
        s
    }

    fn ireg(&self, r: IReg) -> i64 {
        if r.0 == 0 {
            0
        } else {
            self.iregs[r.idx()]
        }
    }

    fn set_ireg(&mut self, r: IReg, v: i64) {
        if r.0 != 0 {
            self.iregs[r.idx()] = v;
        }
    }

    /// Read an FP operand, popping from an SSR stream when mapped.
    /// Returns (value, ready_cycle).
    fn read_freg(&mut self, spm: &mut Mem, r: FReg) -> (u64, u64) {
        if self.ssr_enabled && r.0 < 3 {
            if let Some(st) = self.ssr[r.idx()].as_mut() {
                if !st.pat.write {
                    let addr = st.next_addr();
                    self.stats.ssr_beats += 1;
                    return (spm.read_u64(addr), 0);
                }
            }
        }
        (self.fregs[r.idx()], self.freg_ready[r.idx()])
    }

    /// Write an FP destination, pushing to an SSR write stream when mapped.
    fn write_freg(&mut self, spm: &mut Mem, r: FReg, v: u64, ready: u64) {
        if self.ssr_enabled && r.0 < 3 {
            if let Some(st) = self.ssr[r.idx()].as_mut() {
                if st.pat.write {
                    let addr = st.next_addr();
                    self.stats.ssr_beats += 1;
                    spm.write_u64(addr, v);
                    self.last_retire = self.last_retire.max(ready);
                    return;
                }
            }
        }
        self.fregs[r.idx()] = v;
        self.freg_ready[r.idx()] = ready;
        self.last_retire = self.last_retire.max(ready);
    }

    /// Execute one FP instruction on the FPU timeline.
    ///
    /// `seq` = true when issued from the FREP sequencer (no core-issue
    /// cost); false when offloaded from the integer pipeline.
    fn exec_fp(&mut self, spm: &mut Mem, i: &Instr, seq: bool) {
        let class = i.class();
        if !seq {
            self.core_cycle += 1 + FP_OFFLOAD_OVERHEAD as u64;
        }
        let (result, dest, ready_in) = self.compute_fp(spm, i);
        let issue = self
            .fpu_free
            .max(ready_in)
            .max(if seq { 0 } else { self.core_cycle });
        self.fpu_free = issue
            + if class == Class::FpDivH {
                FDIV_OCCUPANCY as u64
            } else {
                1
            };
        let done = issue + latency(class) as u64;
        if let Some(d) = dest {
            self.write_freg(spm, d, result, done);
        }
        self.last_retire = self.last_retire.max(done);
        self.stats.bump(class);
        self.count_work(i);
    }

    /// Pure-function part of an FP instruction: operand reads (with SSR
    /// pops), the arithmetic itself, and the max operand-ready cycle.
    fn compute_fp(&mut self, spm: &mut Mem, i: &Instr) -> (u64, Option<FReg>, u64) {
        use Instr::*;
        let h = |v: u64| Bf16(v as u16);
        let d = |v: u64| f64::from_bits(v);
        macro_rules! bin_h {
            ($fd:expr, $a:expr, $b:expr, $op:expr) => {{
                let (va, ra) = self.read_freg(spm, *$a);
                let (vb, rb) = self.read_freg(spm, *$b);
                let r = $op(h(va), h(vb)).0 as u64 | (va & !0xFFFF);
                (r, Some(*$fd), ra.max(rb))
            }};
        }
        macro_rules! bin_d {
            ($fd:expr, $a:expr, $b:expr, $op:expr) => {{
                let (va, ra) = self.read_freg(spm, *$a);
                let (vb, rb) = self.read_freg(spm, *$b);
                let r: f64 = $op(d(va), d(vb));
                (r.to_bits(), Some(*$fd), ra.max(rb))
            }};
        }
        macro_rules! simd {
            ($fd:expr, $a:expr, $b:expr, $op:expr) => {{
                let (va, ra) = self.read_freg(spm, *$a);
                let (vb, rb) = self.read_freg(spm, *$b);
                (simd2(va, vb, $op), Some(*$fd), ra.max(rb))
            }};
        }
        match i {
            FaddH { fd, fs1, fs2 } => bin_h!(fd, fs1, fs2, Bf16::add),
            FsubH { fd, fs1, fs2 } => bin_h!(fd, fs1, fs2, Bf16::sub),
            FmulH { fd, fs1, fs2 } => bin_h!(fd, fs1, fs2, Bf16::mul),
            FmaxH { fd, fs1, fs2 } => bin_h!(fd, fs1, fs2, Bf16::max),
            FdivH { fd, fs1, fs2 } => bin_h!(fd, fs1, fs2, Bf16::div),
            FmaddH { fd, fs1, fs2, fs3 } => {
                let (va, ra) = self.read_freg(spm, *fs1);
                let (vb, rb) = self.read_freg(spm, *fs2);
                let (vc, rc) = self.read_freg(spm, *fs3);
                let r = h(va).fma(h(vb), h(vc)).0 as u64;
                (r, Some(*fd), ra.max(rb).max(rc))
            }
            FaddD { fd, fs1, fs2 } => bin_d!(fd, fs1, fs2, |a, b| a + b),
            FsubD { fd, fs1, fs2 } => bin_d!(fd, fs1, fs2, |a, b| a - b),
            FmulD { fd, fs1, fs2 } => bin_d!(fd, fs1, fs2, |a, b| a * b),
            FmaddD { fd, fs1, fs2, fs3 } => {
                let (va, ra) = self.read_freg(spm, *fs1);
                let (vb, rb) = self.read_freg(spm, *fs2);
                let (vc, rc) = self.read_freg(spm, *fs3);
                let r = f64::mul_add(d(va), d(vb), d(vc));
                (r.to_bits(), Some(*fd), ra.max(rb).max(rc))
            }
            FcvtDH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                ((h(v).to_f32() as f64).to_bits(), Some(*fd), r)
            }
            FcvtHD { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                (Bf16::from_f32(d(v) as f32).0 as u64, Some(*fd), r)
            }
            FcvtSH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                (h(v).to_f32().to_bits() as u64, Some(*fd), r)
            }
            FcvtDS { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                ((f32::from_bits(v as u32) as f64).to_bits(), Some(*fd), r)
            }
            FcvtSD { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                ((d(v) as f32).to_bits() as u64, Some(*fd), r)
            }
            FcvtHS { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                (Bf16::from_f32(f32::from_bits(v as u32)).0 as u64, Some(*fd), r)
            }
            VfaddH { fd, fs1, fs2 } => simd!(fd, fs1, fs2, Bf16::add),
            VfsubH { fd, fs1, fs2 } => simd!(fd, fs1, fs2, Bf16::sub),
            VfmulH { fd, fs1, fs2 } => simd!(fd, fs1, fs2, Bf16::mul),
            VfmaxH { fd, fs1, fs2 } => simd!(fd, fs1, fs2, Bf16::max),
            VfsgnjH { fd, fs1, fs2 } => {
                let (va, ra) = self.read_freg(spm, *fs1);
                let (vb, rb) = self.read_freg(spm, *fs2);
                let sgn = 0x8000_8000_8000_8000u64;
                ((va & !sgn) | (vb & sgn), Some(*fd), ra.max(rb))
            }
            VfmacH { fd, fs1, fs2 } => {
                let (va, ra) = self.read_freg(spm, *fs1);
                let (vb, rb) = self.read_freg(spm, *fs2);
                let (vc, rc) = self.read_freg(spm, *fd); // accumulator
                let la = unpack4(va);
                let lb = unpack4(vb);
                let lc = unpack4(vc);
                let r = pack4([
                    la[0].fma(lb[0], lc[0]),
                    la[1].fma(lb[1], lc[1]),
                    la[2].fma(lb[2], lc[2]),
                    la[3].fma(lb[3], lc[3]),
                ]);
                (r, Some(*fd), ra.max(rb).max(rc))
            }
            VfsumH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                let l = unpack4(v);
                let s = l[0].add(l[1]).add(l[2].add(l[3]));
                (s.0 as u64, Some(*fd), r)
            }
            VfmaxRedH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                let l = unpack4(v);
                let s = l[0].max(l[1]).max(l[2].max(l[3]));
                (s.0 as u64, Some(*fd), r)
            }
            VfrepH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                let lane = v & 0xFFFF;
                (lane | (lane << 16) | (lane << 32) | (lane << 48), Some(*fd), r)
            }
            FmvWX { fd, rs1 } => (((self.ireg(*rs1)) as u64) & 0xFFFF_FFFF, Some(*fd), 0),
            FmvDX { fd, rs1 } => (self.ireg(*rs1) as u64, Some(*fd), 0),
            FexpH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                self.stats.exp_ops += 1;
                (exp_unit(h(v)).0 as u64, Some(*fd), r)
            }
            VfexpH { fd, fs1 } => {
                let (v, r) = self.read_freg(spm, *fs1);
                self.stats.exp_ops += 4;
                (vfexp(v), Some(*fd), r)
            }
            other => unreachable!("not an FPU instruction: {other:?}"),
        }
    }

    fn count_work(&mut self, i: &Instr) {
        use Instr::*;
        self.stats.flops += match i {
            VfmacH { .. } => 8,
            VfaddH { .. } | VfsubH { .. } | VfmulH { .. } | VfmaxH { .. } => 4,
            VfsumH { .. } => 3,
            FmaddH { .. } | FmaddD { .. } => 2,
            FaddH { .. } | FsubH { .. } | FmulH { .. } | FmaxH { .. } | FdivH { .. }
            | FaddD { .. } | FmulD { .. } => 1,
            _ => 0,
        };
    }

    /// Execute the instruction at `pc`; return the next pc.
    fn step(&mut self, spm: &mut Mem, prog: &[Instr], pc: usize) -> usize {
        use Instr::*;
        let i = &prog[pc];
        match i {
            // ---- integer core ----------------------------------------
            Addi { rd, rs1, imm } => {
                let v = self.ireg(*rs1) + *imm as i64;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Add { rd, rs1, rs2 } => {
                let v = self.ireg(*rs1) + self.ireg(*rs2);
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Sub { rd, rs1, rs2 } => {
                let v = self.ireg(*rs1) - self.ireg(*rs2);
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Slli { rd, rs1, imm } => {
                let v = self.ireg(*rs1) << imm;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Srli { rd, rs1, imm } => {
                let v = ((self.ireg(*rs1) as u64) >> imm) as i64;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Srai { rd, rs1, imm } => {
                let v = self.ireg(*rs1) >> imm;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            J { target } => {
                self.core_cycle += 1 + BRANCH_TAKEN_PENALTY as u64;
                self.stats.bump(Class::Branch);
                return *target;
            }
            Andi { rd, rs1, imm } => {
                let v = self.ireg(*rs1) & *imm as i64;
                self.set_ireg(*rd, v);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Li { rd, imm } => {
                self.set_ireg(*rd, *imm);
                self.core_cycle += 1;
                self.stats.bump(Class::IntAlu);
            }
            Bnez { rs1, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if self.ireg(*rs1) != 0 {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target;
                }
            }
            Bgeu { rs1, rs2, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if (self.ireg(*rs1) as u64) >= (self.ireg(*rs2) as u64) {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target;
                }
            }
            Blt { rs1, rs2, target } => {
                self.core_cycle += 1;
                self.stats.bump(Class::Branch);
                if self.ireg(*rs1) < self.ireg(*rs2) {
                    self.core_cycle += BRANCH_TAKEN_PENALTY as u64;
                    return *target;
                }
            }
            FmvXW { rd, fs1 } => {
                // int pipeline consumes an FP value: wait for the scoreboard
                self.core_cycle = self.core_cycle.max(self.freg_ready[fs1.idx()]) + 1;
                self.set_ireg(*rd, self.fregs[fs1.idx()] as u32 as i32 as i64);
                self.stats.bump(Class::FpScalarD);
            }
            FmvXD { rd, fs1 } => {
                self.core_cycle = self.core_cycle.max(self.freg_ready[fs1.idx()]) + 1;
                self.set_ireg(*rd, self.fregs[fs1.idx()] as i64);
                self.stats.bump(Class::FpScalarD);
            }

            // ---- FP loads / stores ------------------------------------
            Flh { fd, base, offset } => {
                let addr = (self.ireg(*base) + *offset as i64) as u32;
                let v = spm.read_u16(addr) as u64;
                self.core_cycle += 1;
                let ready = self.core_cycle + latency(Class::FpLoad) as u64;
                self.write_freg(spm, *fd, v, ready);
                self.stats.bump(Class::FpLoad);
                self.stats.mem_bytes += 2;
            }
            Fld { fd, base, offset } => {
                let addr = (self.ireg(*base) + *offset as i64) as u32;
                let v = spm.read_u64(addr);
                self.core_cycle += 1;
                let ready = self.core_cycle + latency(Class::FpLoad) as u64;
                self.write_freg(spm, *fd, v, ready);
                self.stats.bump(Class::FpLoad);
                self.stats.mem_bytes += 8;
            }
            Fsh { fs, base, offset } => {
                let addr = (self.ireg(*base) + *offset as i64) as u32;
                self.core_cycle = self.core_cycle.max(self.freg_ready[fs.idx()]) + 1;
                spm.write_u16(addr, self.fregs[fs.idx()] as u16);
                self.stats.bump(Class::FpStore);
                self.stats.mem_bytes += 2;
            }
            Fsd { fs, base, offset } => {
                let addr = (self.ireg(*base) + *offset as i64) as u32;
                self.core_cycle = self.core_cycle.max(self.freg_ready[fs.idx()]) + 1;
                spm.write_u64(addr, self.fregs[fs.idx()]);
                self.stats.bump(Class::FpStore);
                self.stats.mem_bytes += 8;
            }

            // ---- FREP hardware loop -------------------------------------
            Frep { n_iter, n_instr } => {
                let iters = self.ireg(*n_iter).max(0) as u64;
                let body = &prog[pc + 1..pc + 1 + *n_instr as usize];
                self.core_cycle += 1; // frep issue
                self.stats.bump(Class::Frep);
                // sequencer start: body instructions already offloaded
                self.fpu_free = self.fpu_free.max(self.core_cycle);
                for _ in 0..iters {
                    for b in body {
                        self.exec_fp(spm, b, true);
                    }
                }
                // the core does not stall on the sequencer, but our kernels
                // always need the results, so join the timelines here
                self.core_cycle = self.core_cycle.max(self.last_retire);
                return pc + 1 + *n_instr as usize;
            }

            // ---- SSR ------------------------------------------------------
            SsrCfg { ssr, cfg } => {
                self.ssr[*ssr as usize] = Some(SsrState::new(*cfg));
                // a handful of CSR writes on real hardware
                self.core_cycle += 3;
                self.stats.bump(Class::Ssr);
            }
            SsrEnable => {
                self.ssr_enabled = true;
                self.core_cycle += 1;
                self.stats.bump(Class::Ssr);
            }
            SsrDisable => {
                self.ssr_enabled = false;
                // wait for in-flight FP work before handing regs back
                self.core_cycle = self.core_cycle.max(self.last_retire) + 1;
                self.stats.bump(Class::Ssr);
            }

            Nop => {
                self.core_cycle += 1;
                self.stats.bump(Class::Misc);
            }

            // ---- FPU instructions outside FREP ---------------------------
            fp => {
                debug_assert!(fp.is_fp(), "unhandled instruction {fp:?}");
                self.exec_fp(spm, fp, false);
            }
        }
        pc + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::*;
    use crate::isa::{Asm, SsrPattern};

    fn run(prog: Vec<Instr>, setup: impl FnOnce(&mut Mem)) -> (Core, Mem, CoreStats) {
        let mut core = Core::new();
        let mut spm = Mem::spm();
        setup(&mut spm);
        let stats = core.run(&mut spm, &prog);
        (core, spm, stats)
    }

    #[test]
    fn integer_loop_counts_down() {
        let mut a = Asm::new();
        a.li(A0, 10);
        let top = a.label();
        a.bind(top);
        a.addi(A0, A0, -1);
        a.bnez(A0, top);
        let (core, _, stats) = run(a.finish(), |_| {});
        assert_eq!(core.iregs[10], 0);
        // 1 li + 10*(addi+bnez) retired
        assert_eq!(stats.retired_total(), 21);
        // 9 taken branches pay the refetch penalty
        assert_eq!(stats.cycles, 1 + 20 + 9 * BRANCH_TAKEN_PENALTY as u64);
    }

    #[test]
    fn scalar_bf16_add_through_memory() {
        let mut a = Asm::new();
        a.li(A0, 0x100);
        a.flh(FT3, A0, 0);
        a.flh(FT4, A0, 2);
        a.fadd_h(FT5, FT3, FT4);
        a.fsh(FT5, A0, 4);
        let (_, spm, _) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0x100, &[1.5, 2.25]);
        });
        assert_eq!(Bf16(spm.read_u16(0x104)).to_f32(), 3.75);
    }

    #[test]
    fn vfexp_functional_and_counted() {
        let mut a = Asm::new();
        a.li(A0, 0x200);
        a.fld(FT3, A0, 0);
        a.vfexp_h(FT4, FT3);
        a.fsd(FT4, A0, 8);
        let (_, spm, stats) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0x200, &[0.0, 1.0, -1.0, 2.0]);
        });
        let out = spm.read_bf16_as_f32(0x208, 4);
        assert_eq!(out[0], 1.0);
        assert!((out[1] - std::f32::consts::E).abs() < 0.05);
        assert!((out[3] - 7.389).abs() < 0.1);
        assert_eq!(stats.exp_ops, 4);
    }

    #[test]
    fn frep_ssr_vector_sum() {
        // sum 32 bf16 values via SSR read stream + FREP accumulate
        let n = 32u32;
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x300, n / 4));
        a.ssr_enable();
        a.li(A1, (n / 4) as i64);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        a.ssr_disable();
        a.vfsum_h(FT4, FT3);
        a.li(A0, 0x800);
        a.fsh(FT4, A0, 0);
        let (_, spm, stats) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0x300, &vec![0.25f32; 32]);
        });
        let s = Bf16(spm.read_u16(0x800)).to_f32();
        assert_eq!(s, 8.0);
        assert_eq!(stats.ssr_beats, (n / 4) as u64);
    }

    #[test]
    fn frep_reaches_one_instr_per_cycle() {
        // independent accumulators -> issue-limited: ~1 instr/cycle
        let iters = 256i64;
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x0, 4 * iters as u32));
        a.ssr_enable();
        a.li(A1, iters);
        a.frep(A1, 4);
        a.vfmax_h(FT3, FT3, FT0);
        a.vfmax_h(FT4, FT4, FT0);
        a.vfmax_h(FT5, FT5, FT0);
        a.vfmax_h(FT6, FT6, FT0);
        a.ssr_disable();
        let (_, _, stats) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0, &vec![1.0f32; 16 * iters as usize]);
        });
        let fp_instrs = 4 * iters as u64;
        // within 2% of 1 instr/cycle (fill + setup amortized)
        assert!(
            stats.cycles < fp_instrs + fp_instrs / 50 + 16,
            "cycles {} for {} fp instrs",
            stats.cycles,
            fp_instrs
        );
    }

    #[test]
    fn dependency_stall_shows_up() {
        // serial dependent chain: each op waits for the previous result
        let iters = 64i64;
        let mut a = Asm::new();
        a.li(A1, iters);
        a.frep(A1, 1);
        a.vfmul_h(FT3, FT3, FT3); // self-dependent
        let (_, _, stats) = run(a.finish(), |_| {});
        // latency-2 chain -> ~2 cycles per instr
        assert!(stats.cycles >= 2 * iters as u64 - 2);
    }

    #[test]
    fn ssr_write_stream_stores_results() {
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x400, 4));
        a.ssr_cfg(1, SsrPattern::write1d(0x500, 4));
        a.ssr_enable();
        a.li(A1, 4);
        a.frep(A1, 1);
        a.vfexp_h(FT1, FT0);
        a.ssr_disable();
        let (_, spm, _) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0x400, &vec![0.0f32; 16]);
        });
        let out = spm.read_bf16_as_f32(0x500, 16);
        assert!(out.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn fdiv_occupies_divider() {
        let mut a = Asm::new();
        a.li(A0, 0x100);
        a.flh(FT3, A0, 0);
        a.flh(FT4, A0, 2);
        for _ in 0..4 {
            a.fdiv_h(FT5, FT3, FT4);
        }
        let (_, _, stats) = run(a.finish(), |m| {
            m.write_f32_as_bf16(0x100, &[1.0, 3.0]);
        });
        // 4 divisions serialized on the DIVSQRT block
        assert!(stats.cycles >= 3 * FDIV_OCCUPANCY as u64);
    }

    #[test]
    #[should_panic(expected = "SSR stream exhausted")]
    fn ssr_overrun_panics() {
        let mut a = Asm::new();
        a.ssr_cfg(0, SsrPattern::read1d(0x0, 1));
        a.ssr_enable();
        a.li(A1, 2);
        a.frep(A1, 1);
        a.vfadd_h(FT3, FT3, FT0);
        let prog = a.finish();
        let mut core = Core::new();
        let mut spm = Mem::spm();
        core.run(&mut spm, &prog);
    }
}
