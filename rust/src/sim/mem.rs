//! Byte-addressable memories: the cluster SPM (TCDM) and a simple HBM
//! model. Functional only — timing lives in the core/DMA models.

/// A byte-addressable scratchpad/main memory.
#[derive(Clone)]
pub struct Mem {
    bytes: Vec<u8>,
}

/// Snitch cluster TCDM capacity (paper §III-A: 128 KiB, 32 banks).
pub const SPM_BYTES: usize = 128 * 1024;

/// Number of TCDM banks (used by the interconnect conflict model).
pub const SPM_BANKS: usize = 32;

impl Mem {
    pub fn new(size: usize) -> Self {
        Mem { bytes: vec![0; size] }
    }

    /// A cluster scratchpad of the architectural size.
    pub fn spm() -> Self {
        Self::new(SPM_BYTES)
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    #[inline]
    pub fn read_u16(&self, addr: u32) -> u16 {
        let a = addr as usize;
        u16::from_le_bytes([self.bytes[a], self.bytes[a + 1]])
    }

    #[inline]
    pub fn write_u16(&mut self, addr: u32, v: u16) {
        let a = addr as usize;
        self.bytes[a..a + 2].copy_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn read_u64(&self, addr: u32) -> u64 {
        let a = addr as usize;
        u64::from_le_bytes(self.bytes[a..a + 8].try_into().unwrap())
    }

    #[inline]
    pub fn write_u64(&mut self, addr: u32, v: u64) {
        let a = addr as usize;
        self.bytes[a..a + 8].copy_from_slice(&v.to_le_bytes());
    }

    /// Bulk copy in (the functional half of a DMA transfer).
    pub fn load_bytes(&mut self, addr: u32, data: &[u8]) {
        let a = addr as usize;
        self.bytes[a..a + data.len()].copy_from_slice(data);
    }

    pub fn read_bytes(&self, addr: u32, len: usize) -> &[u8] {
        &self.bytes[addr as usize..addr as usize + len]
    }

    // --- BF16 array helpers (the simulator's native element type) ---------

    pub fn write_bf16_slice(&mut self, addr: u32, xs: &[crate::bf16::Bf16]) {
        for (i, x) in xs.iter().enumerate() {
            self.write_u16(addr + 2 * i as u32, x.0);
        }
    }

    pub fn read_bf16_slice(&self, addr: u32, n: usize) -> Vec<crate::bf16::Bf16> {
        (0..n).map(|i| crate::bf16::Bf16(self.read_u16(addr + 2 * i as u32))).collect()
    }

    pub fn write_f32_as_bf16(&mut self, addr: u32, xs: &[f32]) {
        for (i, &x) in xs.iter().enumerate() {
            self.write_u16(addr + 2 * i as u32, crate::bf16::Bf16::from_f32(x).0);
        }
    }

    pub fn read_bf16_as_f32(&self, addr: u32, n: usize) -> Vec<f32> {
        (0..n).map(|i| crate::bf16::Bf16(self.read_u16(addr + 2 * i as u32)).to_f32()).collect()
    }

    pub fn write_f64(&mut self, addr: u32, x: f64) {
        self.write_u64(addr, x.to_bits());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bf16::Bf16;

    #[test]
    fn u16_u64_roundtrip() {
        let mut m = Mem::new(64);
        m.write_u16(0, 0xBEEF);
        m.write_u64(8, 0x0123_4567_89AB_CDEF);
        assert_eq!(m.read_u16(0), 0xBEEF);
        assert_eq!(m.read_u64(8), 0x0123_4567_89AB_CDEF);
    }

    #[test]
    fn u64_sees_packed_u16() {
        let mut m = Mem::new(16);
        for i in 0..4 {
            m.write_u16(2 * i, 0x1000 + i as u16);
        }
        let v = m.read_u64(0);
        assert_eq!(v & 0xFFFF, 0x1000);
        assert_eq!((v >> 48) & 0xFFFF, 0x1003);
    }

    #[test]
    fn bf16_slice_roundtrip() {
        let mut m = Mem::spm();
        let xs: Vec<Bf16> = (0..10).map(|i| Bf16::from_f32(i as f32 * 0.5)).collect();
        m.write_bf16_slice(0x100, &xs);
        assert_eq!(m.read_bf16_slice(0x100, 10), xs);
    }

    #[test]
    fn spm_is_architectural_size() {
        assert_eq!(Mem::spm().len(), 128 * 1024);
    }
}
