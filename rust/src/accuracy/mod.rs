//! Error statistics for the VEXP approximation (paper §V-A, Table IV).

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

use crate::bf16::Bf16;
use crate::kernels::gelu::{gelu_ref, run_gelu, GeluVariant};
use crate::kernels::layernorm::{layernorm_ref, run_layernorm, LayerNormVariant};
use crate::vexp::exp_unit;

/// Relative-error summary of an approximation against a reference.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorStats {
    pub mean_rel: f64,
    pub max_rel: f64,
    pub mse: f64,
    pub n: u64,
}

/// Exhaustive sweep of the ExpUnit over every BF16 input whose exact
/// exponential is a normal BF16 (the paper's §V-A protocol).
pub fn exp_error_exhaustive() -> ErrorStats {
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for bits in 0..=u16::MAX {
        let x = Bf16(bits);
        if x.is_nan() || x.is_inf() {
            continue;
        }
        let t = (x.to_f32() as f64).exp();
        if !t.is_finite() || !(1e-38..=3.38e38).contains(&t) {
            continue;
        }
        let y = exp_unit(x).to_f32() as f64;
        let rel = (y - t).abs() / t;
        sum += rel;
        max = max.max(rel);
        mse += (y - t) * (y - t);
        n += 1;
    }
    ErrorStats { mean_rel: sum / n as f64, max_rel: max, mse: mse / n as f64, n }
}

/// Error stats restricted to a value range (e.g. the softmax domain
/// `[-20, 0]` used for the Table IV MSE row).
///
/// Edge cases are well-defined: a NaN endpoint **panics** (a silent
/// `n = 0` hid real bugs here — `(lo..=hi).contains` never matches a
/// NaN bound); an empty or inverted range (`lo > hi`) returns
/// [`ErrorStats::default`] with `n = 0`; infinite endpoints are legal
/// and cover every finite BF16 input on that side. Inputs whose exact
/// exponential overflows `f64` are excluded (they sit far past every
/// normal BF16 target anyway).
pub fn exp_error_in_range(lo: f32, hi: f32) -> ErrorStats {
    assert!(
        !lo.is_nan() && !hi.is_nan(),
        "exp_error_in_range: NaN endpoint (lo={lo}, hi={hi})"
    );
    if lo > hi {
        return ErrorStats::default();
    }
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for bits in 0..=u16::MAX {
        let x = Bf16(bits);
        let xf = x.to_f32();
        if !xf.is_finite() || !(lo..=hi).contains(&xf) {
            continue;
        }
        let t = (xf as f64).exp();
        if !t.is_finite() {
            continue;
        }
        let y = exp_unit(x).to_f32() as f64;
        let rel = (y - t).abs() / t.max(1e-300);
        sum += rel;
        max = max.max(rel);
        mse += (y - t) * (y - t);
        n += 1;
    }
    ErrorStats { mean_rel: sum / n.max(1) as f64, max_rel: max, mse: mse / n.max(1) as f64, n }
}

/// Relative-error denominator floor for the GELU sweeps: below this
/// output magnitude the reported error is effectively absolute, which
/// keeps the deep saturation tail (`gelu(x) → 0⁻` as `x → −∞`) from
/// dominating the statistics with meaningless huge ratios.
pub const GELU_REL_FLOOR: f64 = 0.0625;

/// Exhaustive GELU error sweep: every finite BF16 input, executed on
/// the real cluster kernel in 8-row × 512 chunks, against the f64
/// oracle [`gelu_ref`]. In this sweep `mse` is the mean *squared
/// relative* error (the absolute output scale spans the whole BF16
/// range, so an absolute MSE would be meaningless).
pub fn gelu_error_exhaustive(variant: GeluVariant) -> ErrorStats {
    let inputs: Vec<f32> = (0..=u16::MAX)
        .map(Bf16)
        .filter(|x| !x.is_nan() && !x.is_inf())
        .map(|x| x.to_f32())
        .collect();
    gelu_error_on(variant, &inputs)
}

/// GELU error stats over an explicit input set, executed on the real
/// cluster kernel (inputs are padded to full SIMD rows with zeros; the
/// padding is excluded from the statistics). See
/// [`gelu_error_exhaustive`] for the error conventions.
pub fn gelu_error_on(variant: GeluVariant, inputs: &[f32]) -> ErrorStats {
    const N: usize = 512;
    const ROWS: usize = 8;
    let form = variant.form();
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for chunk in inputs.chunks(ROWS * N) {
        let mut rows: Vec<Vec<f32>> = chunk.chunks(N).map(|c| c.to_vec()).collect();
        for row in &mut rows {
            row.resize(N, 0.0);
        }
        let run = run_gelu(variant, &rows);
        let mut idx = 0usize;
        'chunk: for (r, row) in rows.iter().enumerate() {
            for (c, &x) in row.iter().enumerate() {
                if idx >= chunk.len() {
                    break 'chunk;
                }
                idx += 1;
                let t = gelu_ref(form, x as f64);
                let y = run.out[r][c] as f64;
                let rel = (y - t).abs() / t.abs().max(GELU_REL_FLOOR);
                sum += rel;
                max = max.max(rel);
                mse += rel * rel;
                n += 1;
            }
        }
    }
    ErrorStats { mean_rel: sum / n.max(1) as f64, max_rel: max, mse: mse / n.max(1) as f64, n }
}

/// LayerNorm error stats on explicit rows vs the f64 two-pass oracle
/// [`layernorm_ref`]. Rows are BF16-quantized first so the oracle sees
/// exactly what the kernel reads. Outputs are standardized (O(1)), so
/// the relative denominator floors at 1.
pub fn layernorm_error_on(variant: LayerNormVariant, rows: &[Vec<f32>]) -> ErrorStats {
    let q: Vec<Vec<f32>> = rows
        .iter()
        .map(|r| r.iter().map(|&v| Bf16::from_f32(v).to_f32()).collect())
        .collect();
    let run = run_layernorm(variant, &q);
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for (i, row) in q.iter().enumerate() {
        let want = layernorm_ref(row);
        for (&got, &w) in run.out[i].iter().zip(&want) {
            let (y, t) = (got as f64, w as f64);
            let rel = (y - t).abs() / t.abs().max(1.0);
            sum += rel;
            max = max.max(rel);
            mse += (y - t) * (y - t);
            n += 1;
        }
    }
    ErrorStats { mean_rel: sum / n.max(1) as f64, max_rel: max, mse: mse / n.max(1) as f64, n }
}

/// Softmax-output MSE of an approximate row softmax vs the f32 oracle.
pub fn softmax_mse(rows: &[Vec<f32>], outs: &[Vec<f32>]) -> f64 {
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for (row, out) in rows.iter().zip(outs) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f64> = row.iter().map(|&x| ((x - m) as f64).exp()).collect();
        let s: f64 = e.iter().sum();
        for (w, &g) in e.iter().map(|v| v / s).zip(out.iter()) {
            mse += (g as f64 - w) * (g as f64 - w);
            n += 1;
        }
    }
    mse / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_matches_design_spec() {
        let s = exp_error_exhaustive();
        // DESIGN.md §6 locked figures (paper: 0.14% / 0.78%)
        assert!(s.mean_rel < 0.002, "mean {:.5}", s.mean_rel);
        assert!(s.max_rel < 0.011, "max {:.5}", s.max_rel);
        assert!(s.n > 30_000);
    }

    #[test]
    fn softmax_domain_mse_is_tiny() {
        let s = exp_error_in_range(-20.0, 0.0);
        // outputs in (0, 1]: absolute MSE far below 1e-5
        assert!(s.mse < 1e-5, "mse {:.3e}", s.mse);
        assert!(s.max_rel < 0.011);
    }

    #[test]
    fn error_grows_with_magnitude() {
        // relative error amplifies ~linearly in |x| past the fraction
        // quantization, so wide ranges must dominate narrow ones
        let narrow = exp_error_in_range(-1.0, 1.0);
        let wide = exp_error_in_range(-60.0, 60.0);
        assert!(wide.max_rel >= narrow.max_rel);
    }

    #[test]
    fn softmax_mse_zero_for_oracle() {
        let rows = vec![vec![0.0f32, 1.0, 2.0, 3.0]];
        let m = 3.0f32;
        let e: Vec<f64> = rows[0].iter().map(|&x| ((x - m) as f64).exp()).collect();
        let s: f64 = e.iter().sum();
        let outs = vec![e.iter().map(|v| (v / s) as f32).collect::<Vec<_>>()];
        assert!(softmax_mse(&rows, &outs) < 1e-14);
    }

    // ---- exp_error_in_range edge-case table -------------------------------

    #[test]
    fn in_range_inverted_is_empty() {
        let s = exp_error_in_range(1.0, -1.0);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean_rel, 0.0);
        assert_eq!(s.max_rel, 0.0);
        assert_eq!(s.mse, 0.0);
    }

    #[test]
    #[should_panic(expected = "NaN endpoint")]
    fn in_range_nan_lo_panics() {
        exp_error_in_range(f32::NAN, 1.0);
    }

    #[test]
    #[should_panic(expected = "NaN endpoint")]
    fn in_range_nan_hi_panics() {
        exp_error_in_range(-1.0, f32::NAN);
    }

    #[test]
    fn in_range_infinite_endpoints_cover_all_finite_inputs() {
        let s = exp_error_in_range(f32::NEG_INFINITY, f32::INFINITY);
        // every finite BF16 whose exact exp fits in f64 (±inf inputs and
        // overflowing targets excluded)
        assert!(s.n > 60_000, "n = {}", s.n);
        assert!(s.max_rel.is_finite());
    }

    #[test]
    fn in_range_single_point_counts_both_zeros() {
        // lo == hi == 0.0 matches +0 and -0; exp(0) = 1 exactly
        let s = exp_error_in_range(0.0, 0.0);
        assert_eq!(s.n, 2);
        assert!(s.max_rel < 0.011, "max {:.5}", s.max_rel);
    }

    // ---- GELU sweeps ------------------------------------------------------

    use crate::kernels::gelu::GeluForm;

    /// Every finite BF16 value inside [lo, hi].
    fn bf16_inputs_in(lo: f32, hi: f32) -> Vec<f32> {
        (0..=u16::MAX)
            .map(Bf16)
            .filter(|x| !x.is_nan() && !x.is_inf())
            .map(|x| x.to_f32())
            .filter(|&v| (lo..=hi).contains(&v))
            .collect()
    }

    #[test]
    fn gelu_hw_exhaustive_within_bounds() {
        // the SIMD VFEXP kernel is fast enough to sweep every finite
        // BF16 input for all three forms
        for form in GeluForm::ALL {
            let s = gelu_error_exhaustive(GeluVariant::Hw(form));
            assert!(s.n > 60_000, "{form:?}: n = {}", s.n);
            assert!(s.max_rel < 0.10, "{form:?}: max {:.4}", s.max_rel);
            assert!(s.mean_rel < 0.01, "{form:?}: mean {:.5}", s.mean_rel);
        }
    }

    #[test]
    fn gelu_sw_schraudolph_nontrivial_range_within_bounds() {
        // scalar-software sweeps are slow in the simulator, so the unit
        // test covers the nontrivial range; the table2_accuracy bench
        // gate sweeps all variants exhaustively in release mode
        let inputs = bf16_inputs_in(-8.0, 8.0);
        let s = gelu_error_on(GeluVariant::Sw(GeluForm::Tanh), &inputs);
        assert!(s.n as usize == inputs.len());
        assert!(s.max_rel < 0.20, "max {:.4}", s.max_rel);
    }

    #[test]
    fn gelu_sw_horner_nontrivial_range_beats_schraudolph_bound() {
        let inputs = bf16_inputs_in(-8.0, 8.0);
        let s = gelu_error_on(GeluVariant::SwHorner(GeluForm::Tanh), &inputs);
        assert!(s.max_rel < 0.10, "max {:.4}", s.max_rel);
    }

    // ---- LayerNorm adversarial rows ---------------------------------------

    #[test]
    fn layernorm_high_variance_rows_within_bounds() {
        let mut rng = crate::testkit::Rng::new(0xAD5E);
        let rows: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..256).map(|_| rng.f32(-200.0, 200.0)).collect())
            .collect();
        for variant in LayerNormVariant::ALL {
            let s = layernorm_error_on(variant, &rows);
            assert!(s.max_rel < 0.10, "{variant:?}: max {:.4}", s.max_rel);
        }
    }

    #[test]
    fn layernorm_denormal_rows_within_bounds() {
        // magnitudes at the bottom of the BF16 normal range: the
        // variance underflows to zero, epsilon takes over, outputs are
        // ~0 for both kernel and oracle
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| {
                (0..64)
                    .map(|i| if (i + r) % 2 == 0 { 1.2e-38 } else { -1.2e-38 })
                    .collect()
            })
            .collect();
        for variant in LayerNormVariant::ALL {
            let s = layernorm_error_on(variant, &rows);
            assert!(s.max_rel < 0.01, "{variant:?}: max {:.4}", s.max_rel);
        }
    }

    #[test]
    fn layernorm_random_rows_property() {
        crate::testkit::forall(25, |rng| {
            let row: Vec<f32> = (0..128).map(|_| rng.f32(-8.0, 8.0)).collect();
            let s = layernorm_error_on(LayerNormVariant::Optimized, &[row]);
            if s.max_rel < 0.08 {
                Ok(())
            } else {
                Err(format!("max_rel {:.4}", s.max_rel))
            }
        });
    }

    // ---- softmax-backward Jacobian property -------------------------------

    #[test]
    fn softmax_bwd_one_hot_matches_jacobian_forall() {
        use crate::kernels::softmax::{run_softmax_bwd, softmax_ref, SoftmaxBwdVariant};
        crate::testkit::forall(50, |rng| {
            let n = 32usize;
            let logits: Vec<f32> = (0..n).map(|_| rng.f32(-4.0, 4.0)).collect();
            let y = softmax_ref(&logits);
            let yq: Vec<f32> = y.iter().map(|&v| Bf16::from_f32(v).to_f32()).collect();
            let k = rng.range(0, n as u64) as usize;
            let mut g = vec![0.0f32; n];
            g[k] = 1.0;
            let run = run_softmax_bwd(SoftmaxBwdVariant::Optimized, &[y], &[g]);
            for (j, &got) in run.dx[0].iter().enumerate() {
                let delta = if j == k { 1.0 } else { 0.0 };
                let want = yq[j] as f64 * (delta - yq[k] as f64);
                // ~4 BF16 ULP: two exactly-representable operands, one
                // rounded subtract, one rounded multiply
                let tol = 0.02 * want.abs().max(1e-3);
                if (got as f64 - want).abs() >= tol {
                    return Err(format!(
                        "k={k} j={j}: got {got}, want {want:.6}"
                    ));
                }
            }
            Ok(())
        });
    }
}
