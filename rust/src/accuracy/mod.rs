//! Error statistics for the VEXP approximation (paper §V-A, Table IV).

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

use crate::bf16::Bf16;
use crate::vexp::exp_unit;

/// Relative-error summary of an approximation against a reference.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorStats {
    pub mean_rel: f64,
    pub max_rel: f64,
    pub mse: f64,
    pub n: u64,
}

/// Exhaustive sweep of the ExpUnit over every BF16 input whose exact
/// exponential is a normal BF16 (the paper's §V-A protocol).
pub fn exp_error_exhaustive() -> ErrorStats {
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for bits in 0..=u16::MAX {
        let x = Bf16(bits);
        if x.is_nan() || x.is_inf() {
            continue;
        }
        let t = (x.to_f32() as f64).exp();
        if !t.is_finite() || !(1e-38..=3.38e38).contains(&t) {
            continue;
        }
        let y = exp_unit(x).to_f32() as f64;
        let rel = (y - t).abs() / t;
        sum += rel;
        max = max.max(rel);
        mse += (y - t) * (y - t);
        n += 1;
    }
    ErrorStats { mean_rel: sum / n as f64, max_rel: max, mse: mse / n as f64, n }
}

/// Error stats restricted to a value range (e.g. the softmax domain
/// `[-20, 0]` used for the Table IV MSE row).
pub fn exp_error_in_range(lo: f32, hi: f32) -> ErrorStats {
    let mut sum = 0.0f64;
    let mut max = 0.0f64;
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for bits in 0..=u16::MAX {
        let x = Bf16(bits);
        let xf = x.to_f32();
        if x.is_nan() || !(lo..=hi).contains(&xf) {
            continue;
        }
        let t = (xf as f64).exp();
        let y = exp_unit(x).to_f32() as f64;
        let rel = (y - t).abs() / t.max(1e-300);
        sum += rel;
        max = max.max(rel);
        mse += (y - t) * (y - t);
        n += 1;
    }
    ErrorStats { mean_rel: sum / n.max(1) as f64, max_rel: max, mse: mse / n.max(1) as f64, n }
}

/// Softmax-output MSE of an approximate row softmax vs the f32 oracle.
pub fn softmax_mse(rows: &[Vec<f32>], outs: &[Vec<f32>]) -> f64 {
    let mut mse = 0.0f64;
    let mut n = 0u64;
    for (row, out) in rows.iter().zip(outs) {
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f64> = row.iter().map(|&x| ((x - m) as f64).exp()).collect();
        let s: f64 = e.iter().sum();
        for (w, &g) in e.iter().map(|v| v / s).zip(out.iter()) {
            mse += (g as f64 - w) * (g as f64 - w);
            n += 1;
        }
    }
    mse / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exhaustive_matches_design_spec() {
        let s = exp_error_exhaustive();
        // DESIGN.md §6 locked figures (paper: 0.14% / 0.78%)
        assert!(s.mean_rel < 0.002, "mean {:.5}", s.mean_rel);
        assert!(s.max_rel < 0.011, "max {:.5}", s.max_rel);
        assert!(s.n > 30_000);
    }

    #[test]
    fn softmax_domain_mse_is_tiny() {
        let s = exp_error_in_range(-20.0, 0.0);
        // outputs in (0, 1]: absolute MSE far below 1e-5
        assert!(s.mse < 1e-5, "mse {:.3e}", s.mse);
        assert!(s.max_rel < 0.011);
    }

    #[test]
    fn error_grows_with_magnitude() {
        // relative error amplifies ~linearly in |x| past the fraction
        // quantization, so wide ranges must dominate narrow ones
        let narrow = exp_error_in_range(-1.0, 1.0);
        let wide = exp_error_in_range(-60.0, 60.0);
        assert!(wide.max_rel >= narrow.max_rel);
    }

    #[test]
    fn softmax_mse_zero_for_oracle() {
        let rows = vec![vec![0.0f32, 1.0, 2.0, 3.0]];
        let m = 3.0f32;
        let e: Vec<f64> = rows[0].iter().map(|&x| ((x - m) as f64).exp()).collect();
        let s: f64 = e.iter().sum();
        let outs = vec![e.iter().map(|v| (v / s) as f32).collect::<Vec<_>>()];
        assert!(softmax_mse(&rows, &outs) < 1e-14);
    }
}
