//! The paper's four Softmax kernel configurations (§V-C, Fig. 4/Fig. 6):
//!
//! | variant      | MAX/NORM              | EXP                        |
//! |--------------|----------------------|----------------------------|
//! | `Baseline`   | scalar loops          | libm (`math.h`, ~319 cyc)  |
//! | `SwOptim`    | FREP+SSR+SIMD         | libm                       |
//! | `SwExpSw`    | FREP+SSR+SIMD         | Schraudolph in software    |
//! | `SwExpHw`    | FREP+SSR+SIMD         | **VFEXP** (this paper)     |
//!
//! Rows are partitioned over the eight cluster cores; each kernel builder
//! emits one program per core. Row length must be a multiple of 16 for
//! the SIMD variants (the paper's sequence lengths all are).

use super::softexp::{emit_libm_exp, emit_schraudolph_sw_hoisted, write_exp_pool};
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// The four evaluated configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SoftmaxVariant {
    Baseline,
    SwOptim,
    SwExpSw,
    SwExpHw,
    /// Ablation: the EXP block reached through the *scalar* FEXP
    /// instruction only (no packed SIMD) — isolates the contribution of
    /// the 4-lane ExpOpGroup from the instruction itself.
    SwExpHwScalar,
}

impl SoftmaxVariant {
    pub const ALL: [SoftmaxVariant; 4] = [
        SoftmaxVariant::Baseline,
        SoftmaxVariant::SwOptim,
        SoftmaxVariant::SwExpSw,
        SoftmaxVariant::SwExpHw,
    ];

    pub fn label(self) -> &'static str {
        match self {
            SoftmaxVariant::Baseline => "Baseline",
            SoftmaxVariant::SwOptim => "SW Optim",
            SoftmaxVariant::SwExpSw => "SW & EXP SW Optim",
            SoftmaxVariant::SwExpHw => "SW & EXP HW Optim",
            SoftmaxVariant::SwExpHwScalar => "SW & EXP HW (scalar FEXP)",
        }
    }
}

/// SPM layout for the softmax kernels.
pub struct SoftmaxLayout {
    pub pool: u32,
    pub input: u32,
    pub output: u32,
}

pub const DEFAULT_LAYOUT: SoftmaxLayout =
    SoftmaxLayout { pool: 0x1000, input: 0x2000, output: 0x2000 + 48 * 1024 };

/// Result of a cluster softmax run.
pub struct SoftmaxRun {
    pub out: Vec<Vec<f32>>,
    pub stats: ClusterStats,
    /// Cluster cycles per output element (the paper's headline metric).
    pub cycles_per_output: f64,
}

/// Compile the cluster softmax kernel for `rows` rows of length `n`
/// (multiple of 16), statically partitioned over the eight cores, into a
/// cacheable [`Program`]. Inputs are read from [`DEFAULT_LAYOUT`]
/// addresses — see [`seed_softmax_inputs`] / [`run_softmax`] for the
/// data side.
pub fn build_softmax_program(variant: SoftmaxVariant, rows: u32, n: u32) -> Program {
    assert!(rows > 0 && n > 0);
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let per_core = rows.div_ceil(CORES_PER_CLUSTER as u32);
    let per_core_streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(rows);
            let hi = ((c + 1) * per_core).min(rows);
            if lo == hi {
                return vec![];
            }
            build_rows_program(variant, &lay, lo, hi, n)
        })
        .collect();
    Program::new(KernelKind::Softmax(variant), per_core_streams)
}

/// Write the constant pool plus `rows` deterministic pseudo-random input
/// rows at the [`DEFAULT_LAYOUT`] addresses — the data side of a cached
/// softmax [`Program`] when no caller-supplied rows exist (calibration
/// and batched-serving runs).
pub fn seed_softmax_inputs(spm: &mut Mem, rows: u32, n: u32, seed: u64) {
    let lay = DEFAULT_LAYOUT;
    write_exp_pool(spm, lay.pool);
    let mut rng = crate::testkit::Rng::new(seed);
    for r in 0..rows {
        let row: Vec<f32> = (0..n).map(|_| rng.f32(-8.0, 8.0)).collect();
        spm.write_f32_as_bf16(lay.input + r * 2 * n, &row);
    }
}

/// Execute `rows` (each of equal length, multiple of 16) on one cluster.
pub fn run_softmax(variant: SoftmaxVariant, rows: &[Vec<f32>]) -> SoftmaxRun {
    let n = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(n > 0 && rows.iter().all(|r| r.len() == n), "ragged rows");
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let bytes = 2 * n as u32;
    assert!(
        lay.output + rows.len() as u32 * bytes <= 128 * 1024,
        "workload does not fit the 128 KiB SPM; tile it at the coordinator"
    );

    let mut cluster = Cluster::new();
    write_exp_pool(&mut cluster.spm, lay.pool);
    for (i, row) in rows.iter().enumerate() {
        cluster.spm.write_f32_as_bf16(lay.input + i as u32 * bytes, row);
    }

    let program = build_softmax_program(variant, rows.len() as u32, n as u32);
    let stats = cluster.run_program(&program);

    let out = (0..rows.len())
        .map(|i| cluster.spm.read_bf16_as_f32(lay.output + i as u32 * bytes, n))
        .collect();
    // per-core latency metric (the paper's cycles/output): the makespan
    // divided by the elements the busiest core processed
    let cores_used = rows.len().min(CORES_PER_CLUSTER);
    let rows_on_busiest = rows.len().div_ceil(cores_used.max(1));
    let per_core_outputs = (rows_on_busiest * n) as f64;
    SoftmaxRun { cycles_per_output: stats.cycles as f64 / per_core_outputs, out, stats }
}

/// Build one core's program covering rows [lo, hi).
fn build_rows_program(
    variant: SoftmaxVariant,
    lay: &SoftmaxLayout,
    lo: u32,
    hi: u32,
    n: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    for r in lo..hi {
        let in_addr = lay.input + r * 2 * n;
        let out_addr = lay.output + r * 2 * n;
        match variant {
            SoftmaxVariant::Baseline => emit_row_baseline(&mut a, in_addr, out_addr, n),
            SoftmaxVariant::SwOptim => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::Libm),
            SoftmaxVariant::SwExpSw => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::SchraudolphSw),
            SoftmaxVariant::SwExpHw => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::Vfexp),
            SoftmaxVariant::SwExpHwScalar => {
                emit_row_optim(&mut a, in_addr, out_addr, n, Exp::FexpScalar)
            }
        }
    }
    a.finish()
}

enum Exp {
    Libm,
    SchraudolphSw,
    Vfexp,
    FexpScalar,
}

/// Fig. 4 left column: the plain-C baseline (no FREP/SSR/SIMD).
fn emit_row_baseline(a: &mut Asm, input: u32, output: u32, n: u32) {
    // ---- MAX loop over N ------------------------------------------------
    a.li(A0, input as i64);
    a.li(A3, n as i64);
    a.flh(FT3, A0, 0); // max := x[0]
    let max_loop = a.label();
    a.bind(max_loop);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT3, FT3, FT4);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, max_loop);

    // ---- EXP loop: y[i] = exp(x[i] - max); sum += y[i] ---------------------
    a.li(A0, input as i64);
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    a.fmv_w_x(FT5, ZERO); // sum := 0 (bf16 +0)
    let exp_loop = a.label();
    a.bind(exp_loop);
    a.flh(FT4, A0, 0);
    a.fsub_h(FT6, FT4, FT3);
    emit_libm_exp(a, FT7, FT6);
    a.fsh(FT7, A1, 0);
    a.fadd_h(FT5, FT5, FT7);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, exp_loop);

    // ---- NORM loop: y[i] /= sum (one division per element!) -----------------
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    let norm_loop = a.label();
    a.bind(norm_loop);
    a.flh(FT4, A1, 0);
    a.fdiv_h(FT6, FT4, FT5);
    a.fsh(FT6, A1, 0);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, norm_loop);
}

/// Fig. 4 right column: FREP + SSR + SIMD, with the EXP step in one of
/// three technologies.
fn emit_row_optim(a: &mut Asm, input: u32, output: u32, n: u32, exp: Exp) {
    // ---- MAX: 4 SIMD accumulators, SSR-streamed, FREP N/16 ----------------
    a.ssr_cfg(0, SsrPattern::read1d(input, n / 4));
    a.fld(FT3, ZERO, input as i32); // seed accumulators with first beats
    a.vfsgnj_h(FT4, FT3, FT3);
    a.vfsgnj_h(FT5, FT3, FT3);
    a.vfsgnj_h(FT6, FT3, FT3);
    a.ssr_enable();
    a.li(A3, (n / 16) as i64);
    a.frep(A3, 4);
    a.vfmax_h(FT3, FT3, FT0);
    a.vfmax_h(FT4, FT4, FT0);
    a.vfmax_h(FT5, FT5, FT0);
    a.vfmax_h(FT6, FT6, FT0);
    a.ssr_disable();
    // tree-reduce the 16 lanes to a broadcast max in FT7
    a.vfmax_h(FT3, FT3, FT4);
    a.vfmax_h(FT5, FT5, FT6);
    a.vfmax_h(FT3, FT3, FT5);
    a.vfmaxred_h(FT3, FT3);
    a.vfrep_h(FT7, FT3);

    // ---- EXP + SUM --------------------------------------------------------
    match exp {
        Exp::Vfexp => {
            // the Fig. 4 optimized loop: 8 instructions per 8 elements
            a.ssr_cfg(1, SsrPattern::read1d(input, n / 4));
            a.ssr_cfg(2, SsrPattern::write1d(output, n / 4));
            a.vfsub_h(FS0, FS0, FS0); // sum accumulators := 0
            a.vfsub_h(FS1, FS1, FS1);
            a.ssr_enable();
            a.li(A3, (n / 8) as i64);
            a.frep(A3, 8);
            a.vfsub_h(FT3, FT1, FT7);
            a.vfsub_h(FT4, FT1, FT7);
            a.vfexp_h(FT3, FT3);
            a.vfexp_h(FT4, FT4);
            a.vfsgnj_h(FT2, FT3, FT3); // store y via the write stream
            a.vfsgnj_h(FT2, FT4, FT4);
            a.vfadd_h(FS0, FS0, FT3);
            a.vfadd_h(FS1, FS1, FT4);
            a.ssr_disable();
            a.vfadd_h(FS0, FS0, FS1);
            a.vfsum_h(FS0, FS0); // scalar sum in FS0 low lane
        }
        Exp::FexpScalar => {
            // scalar loop, but the exponential is the 2-cycle FEXP
            a.li(A0, input as i64);
            a.li(A1, output as i64);
            a.li(A3, n as i64);
            a.fmv_w_x(FS0, ZERO);
            let exp_loop = a.label();
            a.bind(exp_loop);
            a.flh(FT4, A0, 0);
            a.fsub_h(FT5, FT4, FT7);
            a.fexp_h(FT6, FT5);
            a.fsh(FT6, A1, 0);
            a.fadd_h(FS0, FS0, FT6);
            a.addi(A0, A0, 2);
            a.addi(A1, A1, 2);
            a.addi(A3, A3, -1);
            a.bnez(A3, exp_loop);
        }
        Exp::Libm | Exp::SchraudolphSw => {
            // exponential stays scalar software: SSR/FREP cannot wrap a
            // branchy multi-instruction routine, so this is a plain loop.
            if matches!(exp, Exp::SchraudolphSw) {
                a.fld(FS2, A4, 576); // SCHRAU_SCALE (see softexp.rs pool)
                a.fld(FS3, A4, 584); // SCHRAU_BIAS
            }
            a.li(A0, input as i64);
            a.li(A1, output as i64);
            a.li(A3, n as i64);
            a.fmv_w_x(FS0, ZERO); // sum := 0
            let exp_loop = a.label();
            a.bind(exp_loop);
            a.flh(FT4, A0, 0);
            // NB: ft8..ft11 are clobbered by the libm ABI-spill model, so
            // the loop state lives in ft4..ft6 (free after the MAX phase).
            a.fsub_h(FT5, FT4, FT7);
            match exp {
                Exp::Libm => emit_libm_exp(a, FT6, FT5),
                Exp::SchraudolphSw => emit_schraudolph_sw_hoisted(a, FT6, FT5, FS2, FS3),
                Exp::Vfexp | Exp::FexpScalar => unreachable!(),
            }
            a.fsh(FT6, A1, 0);
            a.fadd_h(FS0, FS0, FT6);
            a.addi(A0, A0, 2);
            a.addi(A1, A1, 2);
            a.addi(A3, A3, -1);
            a.bnez(A3, exp_loop);
        }
    }

    // ---- NORM: one division, then a VFMUL stream ----------------------------
    a.li(T0, 0x3F80); // 1.0 in BF16
    a.fmv_w_x(FS1, T0);
    a.fdiv_h(FS1, FS1, FS0); // 1/sum
    a.vfrep_h(FS1, FS1);
    a.ssr_cfg(0, SsrPattern::read1d(output, n / 4));
    a.ssr_cfg(1, SsrPattern::write1d(output, n / 4));
    a.ssr_enable();
    a.li(A3, (n / 16) as i64);
    a.frep(A3, 4);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.ssr_disable();
}

/// Host-side f32 oracle for functional checks.
pub fn softmax_ref(row: &[f32]) -> Vec<f32> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = row.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = e.iter().sum();
    e.iter().map(|&x| x / s).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(r: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut state = seed | 1;
        (0..r)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((state >> 33) as f64 / 2f64.powi(31) * 16.0 - 8.0) as f32
                    })
                    .collect()
            })
            .collect()
    }

    fn check_correct(variant: SoftmaxVariant, tol: f32) {
        let data = rows(8, 64, 42);
        let run = run_softmax(variant, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (j, (&got, &w)) in run.out[i].iter().zip(&want).enumerate() {
                assert!(
                    (got - w).abs() < tol,
                    "{variant:?} row {i} col {j}: got {got}, want {w}"
                );
            }
            let s: f32 = run.out[i].iter().sum();
            assert!((s - 1.0).abs() < 0.05, "{variant:?} row {i} sums to {s}");
        }
    }

    #[test]
    fn baseline_correct() {
        check_correct(SoftmaxVariant::Baseline, 0.01);
    }

    #[test]
    fn sw_optim_correct() {
        check_correct(SoftmaxVariant::SwOptim, 0.01);
    }

    #[test]
    fn sw_exp_sw_correct() {
        // plain Schraudolph: ~4% exp error shows up in softmax
        check_correct(SoftmaxVariant::SwExpSw, 0.05);
    }

    #[test]
    fn sw_exp_hw_correct() {
        check_correct(SoftmaxVariant::SwExpHw, 0.01);
    }

    #[test]
    fn hw_optim_hits_paper_cycles_per_output() {
        // paper §IV-C: 1.5 instr/output, ~2.125 cycles/output
        let data = rows(8, 1024, 7);
        let run = run_softmax(SoftmaxVariant::SwExpHw, &data);
        assert!(
            run.cycles_per_output < 2.5,
            "optimized kernel at {} cycles/output",
            run.cycles_per_output
        );
        let combined = run.stats.combined();
        let instr_per_out = (combined.retired_total() as f64) / (8.0 * 1024.0);
        // combined counts all 8 cores; outputs likewise 8 rows x 1024
        assert!(
            instr_per_out < 2.0,
            "instr/output {instr_per_out} (paper: 1.5)"
        );
    }

    #[test]
    fn baseline_matches_paper_anchor() {
        // paper: 56 instr/output, ~360 cycles/output
        let data = rows(8, 64, 9);
        let run = run_softmax(SoftmaxVariant::Baseline, &data);
        assert!(
            (250.0..500.0).contains(&run.cycles_per_output),
            "baseline at {} cycles/output, paper anchor 360",
            run.cycles_per_output
        );
    }

    #[test]
    fn speedup_order_matches_fig6a() {
        let data = rows(8, 256, 3);
        let cpo: Vec<f64> = SoftmaxVariant::ALL
            .iter()
            .map(|v| run_softmax(*v, &data).cycles_per_output)
            .collect();
        // Baseline > SwOptim > SwExpSw > SwExpHw, strictly
        assert!(cpo[0] > cpo[1] && cpo[1] > cpo[2] && cpo[2] > cpo[3], "{cpo:?}");
        // headline: two-orders-of-magnitude speedup of the full stack
        let speedup = cpo[0] / cpo[3];
        assert!(
            speedup > 80.0,
            "HW-optimized speedup {speedup:.1}x (paper: 162.7x)"
        );
        // software-only optimization barely helps (paper: 1.1x)
        assert!(cpo[0] / cpo[1] < 2.0, "SW-only speedup too large");
    }

    #[test]
    fn uneven_rows_still_correct() {
        // 5 rows on 8 cores: three cores idle
        let data = rows(5, 32, 11);
        let run = run_softmax(SoftmaxVariant::SwExpHw, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (got, w) in run.out[i].iter().zip(&want) {
                assert!((got - w).abs() < 0.01);
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 16")]
    fn ragged_simd_length_panics() {
        run_softmax(SoftmaxVariant::SwExpHw, &rows(2, 17, 1));
    }

    #[test]
    fn scalar_fexp_correct_but_slower_than_simd() {
        let data = rows(8, 256, 21);
        let scalar = run_softmax(SoftmaxVariant::SwExpHwScalar, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (got, w) in scalar.out[i].iter().zip(&want) {
                assert!((got - w).abs() < 0.01);
            }
        }
        let simd = run_softmax(SoftmaxVariant::SwExpHw, &data);
        let ratio = scalar.cycles_per_output / simd.cycles_per_output;
        // the ExpOpGroup's SIMD path is the majority of the win over a
        // scalar-FEXP design (ablation for DESIGN.md)
        assert!(ratio > 4.0, "scalar/simd ratio {ratio:.1}");
        // but scalar FEXP still crushes the software exponentials
        let sw = run_softmax(SoftmaxVariant::SwExpSw, &data);
        assert!(sw.cycles_per_output / scalar.cycles_per_output > 1.5);
    }

    #[test]
    fn deterministic_across_runs() {
        let data = rows(4, 64, 33);
        let a = run_softmax(SoftmaxVariant::SwExpHw, &data);
        let b = run_softmax(SoftmaxVariant::SwExpHw, &data);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.out, b.out);
    }
}
