//! The paper's four Softmax kernel configurations (§V-C, Fig. 4/Fig. 6):
//!
//! | variant      | MAX/NORM              | EXP                        |
//! |--------------|----------------------|----------------------------|
//! | `Baseline`   | scalar loops          | libm (`math.h`, ~319 cyc)  |
//! | `SwOptim`    | FREP+SSR+SIMD         | libm                       |
//! | `SwExpSw`    | FREP+SSR+SIMD         | Schraudolph in software    |
//! | `SwExpHw`    | FREP+SSR+SIMD         | **VFEXP** (this paper)     |
//!
//! Rows are partitioned over the eight cluster cores; each kernel builder
//! emits one program per core. Row length must be a multiple of 16 for
//! the SIMD variants (the paper's sequence lengths all are).

use super::softexp::{
    emit_horner6_exp, emit_libm_exp, emit_schraudolph_sw_hoisted, write_exp_pool,
};
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// The four evaluated configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SoftmaxVariant {
    Baseline,
    SwOptim,
    SwExpSw,
    SwExpHw,
    /// Ablation: the EXP block reached through the *scalar* FEXP
    /// instruction only (no packed SIMD) — isolates the contribution of
    /// the 4-lane ExpOpGroup from the instruction itself.
    SwExpHwScalar,
    /// Ablation: the EXP block uses the degree-6 Horner polynomial
    /// (`emit_horner6_exp`) — accurate to below bf16 resolution but far
    /// more instructions than Schraudolph, anchoring the software end of
    /// the speed/accuracy frontier in `table2_accuracy`.
    SwExpHorner,
}

impl SoftmaxVariant {
    pub const ALL: [SoftmaxVariant; 4] = [
        SoftmaxVariant::Baseline,
        SoftmaxVariant::SwOptim,
        SoftmaxVariant::SwExpSw,
        SoftmaxVariant::SwExpHw,
    ];

    pub fn label(self) -> &'static str {
        match self {
            SoftmaxVariant::Baseline => "Baseline",
            SoftmaxVariant::SwOptim => "SW Optim",
            SoftmaxVariant::SwExpSw => "SW & EXP SW Optim",
            SoftmaxVariant::SwExpHw => "SW & EXP HW Optim",
            SoftmaxVariant::SwExpHwScalar => "SW & EXP HW (scalar FEXP)",
            SoftmaxVariant::SwExpHorner => "SW & EXP Horner-6",
        }
    }
}

/// SPM layout for the softmax kernels.
pub struct SoftmaxLayout {
    pub pool: u32,
    pub input: u32,
    pub output: u32,
}

pub const DEFAULT_LAYOUT: SoftmaxLayout =
    SoftmaxLayout { pool: 0x1000, input: 0x2000, output: 0x2000 + 48 * 1024 };

/// Result of a cluster softmax run.
pub struct SoftmaxRun {
    pub out: Vec<Vec<f32>>,
    pub stats: ClusterStats,
    /// Cluster cycles per output element (the paper's headline metric).
    pub cycles_per_output: f64,
}

/// Compile the cluster softmax kernel for `rows` rows of length `n`
/// (multiple of 16), statically partitioned over the eight cores, into a
/// cacheable [`Program`]. Inputs are read from [`DEFAULT_LAYOUT`]
/// addresses — see [`seed_softmax_inputs`] / [`run_softmax`] for the
/// data side.
pub fn build_softmax_program(variant: SoftmaxVariant, rows: u32, n: u32) -> Program {
    assert!(rows > 0 && n > 0);
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let per_core = rows.div_ceil(CORES_PER_CLUSTER as u32);
    let per_core_streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(rows);
            let hi = ((c + 1) * per_core).min(rows);
            if lo == hi {
                return vec![];
            }
            build_rows_program(variant, &lay, lo, hi, n)
        })
        .collect();
    Program::new(KernelKind::Softmax(variant), per_core_streams)
}

/// Write the constant pool plus `rows` deterministic pseudo-random input
/// rows at the [`DEFAULT_LAYOUT`] addresses — the data side of a cached
/// softmax [`Program`] when no caller-supplied rows exist (calibration
/// and batched-serving runs).
pub fn seed_softmax_inputs(spm: &mut Mem, rows: u32, n: u32, seed: u64) {
    let lay = DEFAULT_LAYOUT;
    write_exp_pool(spm, lay.pool);
    let mut rng = crate::testkit::Rng::new(seed);
    for r in 0..rows {
        let row: Vec<f32> = (0..n).map(|_| rng.f32(-8.0, 8.0)).collect();
        spm.write_f32_as_bf16(lay.input + r * 2 * n, &row);
    }
}

/// Execute `rows` (each of equal length, multiple of 16) on one cluster.
pub fn run_softmax(variant: SoftmaxVariant, rows: &[Vec<f32>]) -> SoftmaxRun {
    let n = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(n > 0 && rows.iter().all(|r| r.len() == n), "ragged rows");
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let bytes = 2 * n as u32;
    assert!(
        lay.output + rows.len() as u32 * bytes <= 128 * 1024,
        "workload does not fit the 128 KiB SPM; tile it at the coordinator"
    );

    let mut cluster = Cluster::new();
    write_exp_pool(&mut cluster.spm, lay.pool);
    for (i, row) in rows.iter().enumerate() {
        cluster.spm.write_f32_as_bf16(lay.input + i as u32 * bytes, row);
    }

    let program = build_softmax_program(variant, rows.len() as u32, n as u32);
    let stats = cluster.run_program(&program);

    let out = (0..rows.len())
        .map(|i| cluster.spm.read_bf16_as_f32(lay.output + i as u32 * bytes, n))
        .collect();
    // per-core latency metric (the paper's cycles/output): the makespan
    // divided by the elements the busiest core processed
    let cores_used = rows.len().min(CORES_PER_CLUSTER);
    let rows_on_busiest = rows.len().div_ceil(cores_used.max(1));
    let per_core_outputs = (rows_on_busiest * n) as f64;
    SoftmaxRun { cycles_per_output: stats.cycles as f64 / per_core_outputs, out, stats }
}

/// Build one core's program covering rows [lo, hi).
fn build_rows_program(
    variant: SoftmaxVariant,
    lay: &SoftmaxLayout,
    lo: u32,
    hi: u32,
    n: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    for r in lo..hi {
        let in_addr = lay.input + r * 2 * n;
        let out_addr = lay.output + r * 2 * n;
        match variant {
            SoftmaxVariant::Baseline => emit_row_baseline(&mut a, in_addr, out_addr, n),
            SoftmaxVariant::SwOptim => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::Libm),
            SoftmaxVariant::SwExpSw => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::SchraudolphSw),
            SoftmaxVariant::SwExpHw => emit_row_optim(&mut a, in_addr, out_addr, n, Exp::Vfexp),
            SoftmaxVariant::SwExpHwScalar => {
                emit_row_optim(&mut a, in_addr, out_addr, n, Exp::FexpScalar)
            }
            SoftmaxVariant::SwExpHorner => {
                emit_row_optim(&mut a, in_addr, out_addr, n, Exp::Horner6)
            }
        }
    }
    a.finish()
}

enum Exp {
    Libm,
    SchraudolphSw,
    Vfexp,
    FexpScalar,
    Horner6,
}

/// Fig. 4 left column: the plain-C baseline (no FREP/SSR/SIMD).
fn emit_row_baseline(a: &mut Asm, input: u32, output: u32, n: u32) {
    // ---- MAX loop over N ------------------------------------------------
    a.li(A0, input as i64);
    a.li(A3, n as i64);
    a.flh(FT3, A0, 0); // max := x[0]
    let max_loop = a.label();
    a.bind(max_loop);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT3, FT3, FT4);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, max_loop);

    // ---- EXP loop: y[i] = exp(x[i] - max); sum += y[i] ---------------------
    a.li(A0, input as i64);
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    a.fmv_w_x(FT5, ZERO); // sum := 0 (bf16 +0)
    let exp_loop = a.label();
    a.bind(exp_loop);
    a.flh(FT4, A0, 0);
    a.fsub_h(FT6, FT4, FT3);
    emit_libm_exp(a, FT7, FT6);
    a.fsh(FT7, A1, 0);
    a.fadd_h(FT5, FT5, FT7);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, exp_loop);

    // ---- NORM loop: y[i] /= sum (one division per element!) -----------------
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    let norm_loop = a.label();
    a.bind(norm_loop);
    a.flh(FT4, A1, 0);
    a.fdiv_h(FT6, FT4, FT5);
    a.fsh(FT6, A1, 0);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, norm_loop);
}

/// Fig. 4 right column: FREP + SSR + SIMD, with the EXP step in one of
/// three technologies.
fn emit_row_optim(a: &mut Asm, input: u32, output: u32, n: u32, exp: Exp) {
    // ---- MAX: 4 SIMD accumulators, SSR-streamed, FREP N/16 ----------------
    a.ssr_cfg(0, SsrPattern::read1d(input, n / 4));
    a.fld(FT3, ZERO, input as i32); // seed accumulators with first beats
    a.vfsgnj_h(FT4, FT3, FT3);
    a.vfsgnj_h(FT5, FT3, FT3);
    a.vfsgnj_h(FT6, FT3, FT3);
    a.ssr_enable();
    a.li(A3, (n / 16) as i64);
    a.frep(A3, 4);
    a.vfmax_h(FT3, FT3, FT0);
    a.vfmax_h(FT4, FT4, FT0);
    a.vfmax_h(FT5, FT5, FT0);
    a.vfmax_h(FT6, FT6, FT0);
    a.ssr_disable();
    // tree-reduce the 16 lanes to a broadcast max in FT7
    a.vfmax_h(FT3, FT3, FT4);
    a.vfmax_h(FT5, FT5, FT6);
    a.vfmax_h(FT3, FT3, FT5);
    a.vfmaxred_h(FT3, FT3);
    a.vfrep_h(FT7, FT3);

    // ---- EXP + SUM --------------------------------------------------------
    match exp {
        Exp::Vfexp => {
            // the Fig. 4 optimized loop: 8 instructions per 8 elements
            a.ssr_cfg(1, SsrPattern::read1d(input, n / 4));
            a.ssr_cfg(2, SsrPattern::write1d(output, n / 4));
            a.vfsub_h(FS0, FS0, FS0); // sum accumulators := 0
            a.vfsub_h(FS1, FS1, FS1);
            a.ssr_enable();
            a.li(A3, (n / 8) as i64);
            a.frep(A3, 8);
            a.vfsub_h(FT3, FT1, FT7);
            a.vfsub_h(FT4, FT1, FT7);
            a.vfexp_h(FT3, FT3);
            a.vfexp_h(FT4, FT4);
            a.vfsgnj_h(FT2, FT3, FT3); // store y via the write stream
            a.vfsgnj_h(FT2, FT4, FT4);
            a.vfadd_h(FS0, FS0, FT3);
            a.vfadd_h(FS1, FS1, FT4);
            a.ssr_disable();
            a.vfadd_h(FS0, FS0, FS1);
            a.vfsum_h(FS0, FS0); // scalar sum in FS0 low lane
        }
        Exp::FexpScalar => {
            // scalar loop, but the exponential is the 2-cycle FEXP
            a.li(A0, input as i64);
            a.li(A1, output as i64);
            a.li(A3, n as i64);
            a.fmv_w_x(FS0, ZERO);
            let exp_loop = a.label();
            a.bind(exp_loop);
            a.flh(FT4, A0, 0);
            a.fsub_h(FT5, FT4, FT7);
            a.fexp_h(FT6, FT5);
            a.fsh(FT6, A1, 0);
            a.fadd_h(FS0, FS0, FT6);
            a.addi(A0, A0, 2);
            a.addi(A1, A1, 2);
            a.addi(A3, A3, -1);
            a.bnez(A3, exp_loop);
        }
        Exp::Libm | Exp::SchraudolphSw | Exp::Horner6 => {
            // exponential stays scalar software: SSR/FREP cannot wrap a
            // branchy multi-instruction routine, so this is a plain loop.
            if matches!(exp, Exp::SchraudolphSw) {
                a.fld(FS2, A4, 576); // SCHRAU_SCALE (see softexp.rs pool)
                a.fld(FS3, A4, 584); // SCHRAU_BIAS
            }
            a.li(A0, input as i64);
            a.li(A1, output as i64);
            a.li(A3, n as i64);
            a.fmv_w_x(FS0, ZERO); // sum := 0
            let exp_loop = a.label();
            a.bind(exp_loop);
            a.flh(FT4, A0, 0);
            // NB: ft8..ft11 are clobbered by the libm ABI-spill model, so
            // the loop state lives in ft4..ft6 (free after the MAX phase).
            a.fsub_h(FT5, FT4, FT7);
            match exp {
                Exp::Libm => emit_libm_exp(a, FT6, FT5),
                Exp::SchraudolphSw => emit_schraudolph_sw_hoisted(a, FT6, FT5, FS2, FS3),
                Exp::Horner6 => emit_horner6_exp(a, FT6, FT5),
                Exp::Vfexp | Exp::FexpScalar => unreachable!(),
            }
            a.fsh(FT6, A1, 0);
            a.fadd_h(FS0, FS0, FT6);
            a.addi(A0, A0, 2);
            a.addi(A1, A1, 2);
            a.addi(A3, A3, -1);
            a.bnez(A3, exp_loop);
        }
    }

    // ---- NORM: one division, then a VFMUL stream ----------------------------
    a.li(T0, 0x3F80); // 1.0 in BF16
    a.fmv_w_x(FS1, T0);
    a.fdiv_h(FS1, FS1, FS0); // 1/sum
    a.vfrep_h(FS1, FS1);
    a.ssr_cfg(0, SsrPattern::read1d(output, n / 4));
    a.ssr_cfg(1, SsrPattern::write1d(output, n / 4));
    a.ssr_enable();
    a.li(A3, (n / 16) as i64);
    a.frep(A3, 4);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.ssr_disable();
}

/// Host-side f32 oracle for functional checks.
pub fn softmax_ref(row: &[f32]) -> Vec<f32> {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = row.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = e.iter().sum();
    e.iter().map(|&x| x / s).collect()
}

// ---------------------------------------------------------------------------
// Softmax backward (the training step)
// ---------------------------------------------------------------------------
//
// Given the forward output `y = softmax(x)` and the upstream gradient `g`,
// the input gradient is
//
//     dx_i = y_i * (g_i - s),   s = Σ_j g_j * y_j
//
// i.e. a dot product followed by an axpy-like pass — no exponentials, so
// the interesting axis here is FREP/SSR/SIMD vs the scalar baseline.

/// Softmax-backward kernel configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SoftmaxBwdVariant {
    /// Scalar loops, no FREP/SSR/SIMD.
    Baseline,
    /// FREP + SSR + packed-SIMD, mirroring the forward `SwOptim` shape.
    Optimized,
}

impl SoftmaxBwdVariant {
    /// Both configurations, baseline first.
    pub const ALL: [SoftmaxBwdVariant; 2] =
        [SoftmaxBwdVariant::Baseline, SoftmaxBwdVariant::Optimized];

    /// Human-readable name for tables and reports.
    pub fn label(self) -> &'static str {
        match self {
            SoftmaxBwdVariant::Baseline => "Baseline",
            SoftmaxBwdVariant::Optimized => "FREP+SSR+SIMD",
        }
    }
}

/// SPM layout for the softmax-backward kernel: forward output `y`,
/// upstream gradient `g`, and the produced input gradient `dx`.
pub struct SoftmaxBwdLayout {
    /// Constant pool base (unused by the kernel itself; kept so the
    /// fault-injection suite can checksum a uniform region set).
    pub pool: u32,
    /// Forward softmax output rows.
    pub y: u32,
    /// Upstream gradient rows.
    pub g: u32,
    /// Output: input-gradient rows.
    pub dx: u32,
}

/// Default [`SoftmaxBwdLayout`]: 36 KiB per region, all inside the
/// 128 KiB SPM.
pub const DEFAULT_BWD_LAYOUT: SoftmaxBwdLayout = SoftmaxBwdLayout {
    pool: 0x1000,
    y: 0x2000,
    g: 0x2000 + 0x9000,
    dx: 0x2000 + 0x12000,
};

/// Result of a cluster softmax-backward run.
pub struct SoftmaxBwdRun {
    /// Input-gradient rows read back from SPM.
    pub dx: Vec<Vec<f32>>,
    /// Cluster-level execution stats.
    pub stats: ClusterStats,
    /// Cluster cycles per produced gradient element.
    pub cycles_per_output: f64,
}

/// Compile the softmax-backward kernel for `rows` rows of length `n`
/// (multiple of 16), statically partitioned over the eight cores.
pub fn build_softmax_bwd_program(variant: SoftmaxBwdVariant, rows: u32, n: u32) -> Program {
    assert!(rows > 0 && n > 0);
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_BWD_LAYOUT;
    let per_core = rows.div_ceil(CORES_PER_CLUSTER as u32);
    let per_core_streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(rows);
            let hi = ((c + 1) * per_core).min(rows);
            if lo == hi {
                return vec![];
            }
            let mut a = Asm::new();
            for r in lo..hi {
                let y = lay.y + r * 2 * n;
                let g = lay.g + r * 2 * n;
                let dx = lay.dx + r * 2 * n;
                match variant {
                    SoftmaxBwdVariant::Baseline => emit_bwd_row_baseline(&mut a, y, g, dx, n),
                    SoftmaxBwdVariant::Optimized => emit_bwd_row_optim(&mut a, y, g, dx, n),
                }
            }
            a.finish()
        })
        .collect();
    Program::new(KernelKind::SoftmaxBwd(variant), per_core_streams)
}

/// Write deterministic pseudo-random inputs for a cached backward
/// [`Program`]: `y` rows are genuine softmax distributions (host
/// computed), `g` rows uniform in (-1, 1).
pub fn seed_softmax_bwd_inputs(spm: &mut Mem, rows: u32, n: u32, seed: u64) {
    let lay = DEFAULT_BWD_LAYOUT;
    let mut rng = crate::testkit::Rng::new(seed);
    for r in 0..rows {
        let logits: Vec<f32> = (0..n).map(|_| rng.f32(-4.0, 4.0)).collect();
        spm.write_f32_as_bf16(lay.y + r * 2 * n, &softmax_ref(&logits));
        let g: Vec<f32> = (0..n).map(|_| rng.f32(-1.0, 1.0)).collect();
        spm.write_f32_as_bf16(lay.g + r * 2 * n, &g);
    }
}

/// Execute softmax-backward for matching `y`/`g` rows on one cluster.
pub fn run_softmax_bwd(
    variant: SoftmaxBwdVariant,
    y_rows: &[Vec<f32>],
    g_rows: &[Vec<f32>],
) -> SoftmaxBwdRun {
    let n = y_rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(n > 0 && y_rows.iter().all(|r| r.len() == n), "ragged rows");
    assert_eq!(y_rows.len(), g_rows.len(), "y/g row count mismatch");
    assert!(g_rows.iter().all(|r| r.len() == n), "ragged g rows");
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_BWD_LAYOUT;
    let bytes = 2 * n as u32;
    assert!(
        lay.dx + y_rows.len() as u32 * bytes <= 128 * 1024,
        "workload does not fit the 128 KiB SPM; tile it at the coordinator"
    );

    let mut cluster = Cluster::new();
    for (i, (y, g)) in y_rows.iter().zip(g_rows).enumerate() {
        cluster.spm.write_f32_as_bf16(lay.y + i as u32 * bytes, y);
        cluster.spm.write_f32_as_bf16(lay.g + i as u32 * bytes, g);
    }

    let program = build_softmax_bwd_program(variant, y_rows.len() as u32, n as u32);
    let stats = cluster.run_program(&program);

    let dx = (0..y_rows.len())
        .map(|i| cluster.spm.read_bf16_as_f32(lay.dx + i as u32 * bytes, n))
        .collect();
    let cores_used = y_rows.len().min(CORES_PER_CLUSTER);
    let rows_on_busiest = y_rows.len().div_ceil(cores_used.max(1));
    let per_core_outputs = (rows_on_busiest * n) as f64;
    SoftmaxBwdRun { cycles_per_output: stats.cycles as f64 / per_core_outputs, dx, stats }
}

/// Scalar backward row: fused-multiply-add dot product, then the axpy
/// pass, both as plain loops.
fn emit_bwd_row_baseline(a: &mut Asm, y: u32, g: u32, dx: u32, n: u32) {
    // ---- s = Σ g·y --------------------------------------------------------
    a.li(A0, g as i64);
    a.li(A1, y as i64);
    a.li(A3, n as i64);
    a.fmv_w_x(FT5, ZERO); // s := 0
    let dot_loop = a.label();
    a.bind(dot_loop);
    a.flh(FT3, A0, 0);
    a.flh(FT4, A1, 0);
    a.fmadd_h(FT5, FT3, FT4, FT5);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, dot_loop);

    // ---- dx_i = y_i · (g_i − s) ------------------------------------------
    a.li(A0, g as i64);
    a.li(A1, y as i64);
    a.li(A2, dx as i64);
    a.li(A3, n as i64);
    let axpy_loop = a.label();
    a.bind(axpy_loop);
    a.flh(FT3, A0, 0);
    a.flh(FT4, A1, 0);
    a.fsub_h(FT6, FT3, FT5);
    a.fmul_h(FT6, FT6, FT4);
    a.fsh(FT6, A2, 0);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A2, A2, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, axpy_loop);
}

/// FREP+SSR+SIMD backward row: a VFMAC dot-product pass, a lane
/// reduction, then a streamed `(g − s)·y` pass writing `dx`.
fn emit_bwd_row_optim(a: &mut Asm, y: u32, g: u32, dx: u32, n: u32) {
    // ---- pass 1: s = Σ g·y across two SIMD accumulators -------------------
    a.ssr_cfg(0, SsrPattern::read1d(g, n / 4));
    a.ssr_cfg(1, SsrPattern::read1d(y, n / 4));
    a.fmv_d_x(FS0, ZERO); // all four lanes exactly +0
    a.fmv_d_x(FS1, ZERO);
    a.ssr_enable();
    a.li(A3, (n / 8) as i64);
    a.frep(A3, 2);
    a.vfmac_h(FS0, FT0, FT1);
    a.vfmac_h(FS1, FT0, FT1);
    a.ssr_disable();
    a.vfadd_h(FS0, FS0, FS1);
    a.vfsum_h(FS0, FS0); // scalar s in the low lane
    a.vfrep_h(FS2, FS0); // broadcast s to all lanes

    // ---- pass 2: dx = (g − s) ⊙ y, streamed -------------------------------
    a.ssr_cfg(0, SsrPattern::read1d(g, n / 4));
    a.ssr_cfg(1, SsrPattern::read1d(y, n / 4));
    a.ssr_cfg(2, SsrPattern::write1d(dx, n / 4));
    a.ssr_enable();
    a.li(A3, (n / 8) as i64);
    a.frep(A3, 4);
    a.vfsub_h(FT3, FT0, FS2);
    a.vfmul_h(FT2, FT3, FT1);
    a.vfsub_h(FT4, FT0, FS2);
    a.vfmul_h(FT2, FT4, FT1);
    a.ssr_disable();
}

/// Host-side f64 oracle: `dx_i = y_i * (g_i - Σ_j g_j*y_j)`.
pub fn softmax_bwd_ref(y: &[f32], g: &[f32]) -> Vec<f32> {
    assert_eq!(y.len(), g.len());
    let s: f64 = y.iter().zip(g).map(|(&yi, &gi)| yi as f64 * gi as f64).sum();
    y.iter().zip(g).map(|(&yi, &gi)| (yi as f64 * (gi as f64 - s)) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(r: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut state = seed | 1;
        (0..r)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                        ((state >> 33) as f64 / 2f64.powi(31) * 16.0 - 8.0) as f32
                    })
                    .collect()
            })
            .collect()
    }

    fn check_correct(variant: SoftmaxVariant, tol: f32) {
        let data = rows(8, 64, 42);
        let run = run_softmax(variant, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (j, (&got, &w)) in run.out[i].iter().zip(&want).enumerate() {
                assert!(
                    (got - w).abs() < tol,
                    "{variant:?} row {i} col {j}: got {got}, want {w}"
                );
            }
            let s: f32 = run.out[i].iter().sum();
            assert!((s - 1.0).abs() < 0.05, "{variant:?} row {i} sums to {s}");
        }
    }

    #[test]
    fn baseline_correct() {
        check_correct(SoftmaxVariant::Baseline, 0.01);
    }

    #[test]
    fn sw_optim_correct() {
        check_correct(SoftmaxVariant::SwOptim, 0.01);
    }

    #[test]
    fn sw_exp_sw_correct() {
        // plain Schraudolph: ~4% exp error shows up in softmax
        check_correct(SoftmaxVariant::SwExpSw, 0.05);
    }

    #[test]
    fn sw_exp_hw_correct() {
        check_correct(SoftmaxVariant::SwExpHw, 0.01);
    }

    #[test]
    fn hw_optim_hits_paper_cycles_per_output() {
        // paper §IV-C: 1.5 instr/output, ~2.125 cycles/output
        let data = rows(8, 1024, 7);
        let run = run_softmax(SoftmaxVariant::SwExpHw, &data);
        assert!(
            run.cycles_per_output < 2.5,
            "optimized kernel at {} cycles/output",
            run.cycles_per_output
        );
        let combined = run.stats.combined();
        let instr_per_out = (combined.retired_total() as f64) / (8.0 * 1024.0);
        // combined counts all 8 cores; outputs likewise 8 rows x 1024
        assert!(
            instr_per_out < 2.0,
            "instr/output {instr_per_out} (paper: 1.5)"
        );
    }

    #[test]
    fn baseline_matches_paper_anchor() {
        // paper: 56 instr/output, ~360 cycles/output
        let data = rows(8, 64, 9);
        let run = run_softmax(SoftmaxVariant::Baseline, &data);
        assert!(
            (250.0..500.0).contains(&run.cycles_per_output),
            "baseline at {} cycles/output, paper anchor 360",
            run.cycles_per_output
        );
    }

    #[test]
    fn speedup_order_matches_fig6a() {
        let data = rows(8, 256, 3);
        let cpo: Vec<f64> = SoftmaxVariant::ALL
            .iter()
            .map(|v| run_softmax(*v, &data).cycles_per_output)
            .collect();
        // Baseline > SwOptim > SwExpSw > SwExpHw, strictly
        assert!(cpo[0] > cpo[1] && cpo[1] > cpo[2] && cpo[2] > cpo[3], "{cpo:?}");
        // headline: two-orders-of-magnitude speedup of the full stack
        let speedup = cpo[0] / cpo[3];
        assert!(
            speedup > 80.0,
            "HW-optimized speedup {speedup:.1}x (paper: 162.7x)"
        );
        // software-only optimization barely helps (paper: 1.1x)
        assert!(cpo[0] / cpo[1] < 2.0, "SW-only speedup too large");
    }

    #[test]
    fn uneven_rows_still_correct() {
        // 5 rows on 8 cores: three cores idle
        let data = rows(5, 32, 11);
        let run = run_softmax(SoftmaxVariant::SwExpHw, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (got, w) in run.out[i].iter().zip(&want) {
                assert!((got - w).abs() < 0.01);
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 16")]
    fn ragged_simd_length_panics() {
        run_softmax(SoftmaxVariant::SwExpHw, &rows(2, 17, 1));
    }

    #[test]
    fn scalar_fexp_correct_but_slower_than_simd() {
        let data = rows(8, 256, 21);
        let scalar = run_softmax(SoftmaxVariant::SwExpHwScalar, &data);
        for (i, row) in data.iter().enumerate() {
            let want = softmax_ref(row);
            for (got, w) in scalar.out[i].iter().zip(&want) {
                assert!((got - w).abs() < 0.01);
            }
        }
        let simd = run_softmax(SoftmaxVariant::SwExpHw, &data);
        let ratio = scalar.cycles_per_output / simd.cycles_per_output;
        // the ExpOpGroup's SIMD path is the majority of the win over a
        // scalar-FEXP design (ablation for DESIGN.md)
        assert!(ratio > 4.0, "scalar/simd ratio {ratio:.1}");
        // but scalar FEXP still crushes the software exponentials
        let sw = run_softmax(SoftmaxVariant::SwExpSw, &data);
        assert!(sw.cycles_per_output / scalar.cycles_per_output > 1.5);
    }

    #[test]
    fn deterministic_across_runs() {
        let data = rows(4, 64, 33);
        let a = run_softmax(SoftmaxVariant::SwExpHw, &data);
        let b = run_softmax(SoftmaxVariant::SwExpHw, &data);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.out, b.out);
    }

    #[test]
    fn sw_exp_horner_correct() {
        // degree-6 Horner exp is libm-grade at bf16 resolution
        check_correct(SoftmaxVariant::SwExpHorner, 0.01);
    }

    #[test]
    fn horner_sits_between_schraudolph_and_libm_in_softmax() {
        let data = rows(8, 256, 5);
        let schrau = run_softmax(SoftmaxVariant::SwExpSw, &data).cycles_per_output;
        let horner = run_softmax(SoftmaxVariant::SwExpHorner, &data).cycles_per_output;
        let libm = run_softmax(SoftmaxVariant::SwOptim, &data).cycles_per_output;
        assert!(
            schrau < horner && horner < libm,
            "schraudolph {schrau:.1} / horner {horner:.1} / libm {libm:.1}"
        );
    }

    // ---- softmax backward -------------------------------------------------

    /// Quantize a host row the way the SPM stores it, so oracle
    /// comparisons see the same inputs as the kernel.
    fn quantize(row: &[f32]) -> Vec<f32> {
        row.iter().map(|&v| crate::bf16::Bf16::from_f32(v).to_f32()).collect()
    }

    fn bwd_inputs(r: usize, n: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut rng = crate::testkit::Rng::new(seed);
        let mut ys = Vec::new();
        let mut gs = Vec::new();
        for _ in 0..r {
            let logits: Vec<f32> = (0..n).map(|_| rng.f32(-4.0, 4.0)).collect();
            ys.push(softmax_ref(&logits));
            gs.push((0..n).map(|_| rng.f32(-1.0, 1.0)).collect());
        }
        (ys, gs)
    }

    fn check_bwd_correct(variant: SoftmaxBwdVariant, tol: f32) {
        let (ys, gs) = bwd_inputs(8, 64, 17);
        let run = run_softmax_bwd(variant, &ys, &gs);
        for i in 0..ys.len() {
            let want = softmax_bwd_ref(&quantize(&ys[i]), &quantize(&gs[i]));
            for (j, (&got, &w)) in run.dx[i].iter().zip(&want).enumerate() {
                assert!(
                    (got - w).abs() < tol,
                    "{variant:?} row {i} col {j}: got {got}, want {w}"
                );
            }
        }
    }

    #[test]
    fn bwd_baseline_matches_reference() {
        check_bwd_correct(SoftmaxBwdVariant::Baseline, 0.05);
    }

    #[test]
    fn bwd_optimized_matches_reference() {
        check_bwd_correct(SoftmaxBwdVariant::Optimized, 0.05);
    }

    #[test]
    fn bwd_one_hot_matches_jacobian_row() {
        // With a one-hot upstream gradient e_k, softmax backward reduces to
        // the k-th Jacobian row: dx_i = y_i (δ_ik − y_k). The dot product
        // s = y_k is exact in bf16 (all other terms are exact zeros), so
        // the kernel must land within a couple of ULP of the analytic row.
        let (ys, _) = bwd_inputs(4, 32, 23);
        for k in [0usize, 7, 31] {
            let mut gs = Vec::new();
            for _ in 0..ys.len() {
                let mut g = vec![0.0f32; 32];
                g[k] = 1.0;
                gs.push(g);
            }
            for variant in SoftmaxBwdVariant::ALL {
                let run = run_softmax_bwd(variant, &ys, &gs);
                for (i, y) in ys.iter().enumerate() {
                    let yq = quantize(y);
                    for (j, &got) in run.dx[i].iter().enumerate() {
                        let delta = if j == k { 1.0 } else { 0.0 };
                        let want = yq[j] as f64 * (delta - yq[k] as f64);
                        let tol = 0.02 * want.abs().max(1e-3);
                        assert!(
                            (got as f64 - want).abs() < tol,
                            "{variant:?} one-hot k={k} row {i} col {j}: got {got}, want {want}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bwd_optimized_much_faster_than_baseline() {
        let (ys, gs) = bwd_inputs(8, 512, 29);
        let base = run_softmax_bwd(SoftmaxBwdVariant::Baseline, &ys, &gs);
        let opt = run_softmax_bwd(SoftmaxBwdVariant::Optimized, &ys, &gs);
        assert!(
            opt.cycles_per_output * 3.0 < base.cycles_per_output,
            "baseline {:.2} vs optimized {:.2} cycles/output",
            base.cycles_per_output,
            opt.cycles_per_output
        );
    }

    #[test]
    fn bwd_uneven_rows_still_correct() {
        let (ys, gs) = bwd_inputs(5, 32, 31);
        let run = run_softmax_bwd(SoftmaxBwdVariant::Optimized, &ys, &gs);
        for i in 0..ys.len() {
            let want = softmax_bwd_ref(&quantize(&ys[i]), &quantize(&gs[i]));
            for (&got, &w) in run.dx[i].iter().zip(&want) {
                assert!((got - w).abs() < 0.05);
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 16")]
    fn bwd_ragged_simd_length_panics() {
        let ys = [vec![0.5f32; 17], vec![0.5f32; 17]];
        let gs = [vec![0.0f32; 17], vec![0.0f32; 17]];
        run_softmax_bwd(SoftmaxBwdVariant::Optimized, &ys, &gs);
    }

    #[test]
    fn bwd_deterministic_across_runs() {
        let (ys, gs) = bwd_inputs(4, 64, 37);
        let a = run_softmax_bwd(SoftmaxBwdVariant::Optimized, &ys, &gs);
        let b = run_softmax_bwd(SoftmaxBwdVariant::Optimized, &ys, &gs);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.dx, b.dx);
    }
}
