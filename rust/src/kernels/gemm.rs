//! BF16 GEMM on the Snitch cluster: dot-product formulation with SSR
//! streams and FREP (the [5]-style instruction-level optimized kernel
//! that all GEMM operations in this work build on).
//!
//! `C[M,N] = A[M,K] · B[K,N]` with **B stored transposed** (`BT[N,K]`),
//! so every output is a K-deep dot product between two contiguous rows —
//! QK^T and P·V in FlashAttention-2 both have this shape once V is kept
//! transposed in SPM (the DMA performs the strided transpose at load).
//!
//! Inner loop: 8 SIMD MAC accumulators over 8 output columns, SSR0
//! streaming the A row (each beat repeated 8×, 3D pattern), SSR1
//! streaming 8 BT rows interleaved. Issue-limited at ~1 MAC/cycle; the
//! paper's Table III measures this kernel at 85 % FPU utilization.

use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, CORES_PER_CLUSTER};

/// Column-group width (accumulators per FREP body).
const JG: u32 = 8;

/// SPM layout of one GEMM call.
#[derive(Clone, Copy, Debug)]
pub struct GemmLayout {
    pub a: u32,  // A[M,K] row-major BF16
    pub bt: u32, // BT[N,K] row-major BF16
    pub c: u32,  // C[M,N] row-major BF16
}

/// Emit one core's share of the GEMM: output rows [i_lo, i_hi).
///
/// Requires K % 4 == 0 and N % 8 == 0.
pub fn emit_gemm_rows(
    a: &mut Asm,
    lay: GemmLayout,
    i_lo: u32,
    i_hi: u32,
    k: u32,
    n: u32,
) {
    emit_gemm_rows_strided(a, lay.a, lay.bt, 2 * k, lay.c, i_lo, i_hi, k, n);
}

/// Strided GEMM emitter: BT rows may live `bt_stride` bytes apart (e.g.
/// a column slice of a wider transposed matrix — the P·V case in
/// FlashAttention-2, where BT is a tile of V^T).
#[allow(clippy::too_many_arguments)]
pub fn emit_gemm_rows_strided(
    a: &mut Asm,
    a_base: u32,
    bt_base: u32,
    bt_stride: u32,
    c_base: u32,
    i_lo: u32,
    i_hi: u32,
    k: u32,
    n: u32,
) {
    assert!(k % 4 == 0 && n % JG == 0, "K%4==0 and N%{JG}==0 required");
    let kb = k / 4; // beats per row
    for i in i_lo..i_hi {
        // SSR0: A row i, each beat repeated JG times, re-walked per group:
        //   i0: repeat beat (stride 0) x JG
        //   i1: walk the row (stride 8) x kb
        //   i2: next column group restarts the row (stride 0) x n/JG
        a.ssr_cfg(
            0,
            SsrPattern::read3d(a_base + i * 2 * k, 0, JG, 8, kb, 0, n / JG),
        );
        // SSR1: BT rows j..j+7 interleaved per k-beat, then next group:
        //   i0: row hop (bt_stride) x JG
        //   i1: beat hop (stride 8) x kb
        //   i2: group hop (JG*bt_stride) x n/JG
        a.ssr_cfg(
            1,
            SsrPattern::read3d(bt_base, bt_stride as i32, JG, 8, kb, (JG * bt_stride) as i32, n / JG),
        );
        a.ssr_enable();
        a.li(A0, (c_base + i * 2 * n) as i64);
        a.li(A1, (n / JG) as i64);
        a.li(A2, kb as i64);
        let jloop = a.label();
        a.bind(jloop);
        // zero the 8 accumulators (x - x = 0 on finite values)
        for acc in 0..JG as u8 {
            let r = FReg(3 + acc);
            a.push(Instr::VfsubH { fd: r, fs1: r, fs2: r });
        }
        a.frep(A2, JG);
        for acc in 0..JG as u8 {
            let r = FReg(3 + acc);
            a.push(Instr::VfmacH { fd: r, fs1: FT0, fs2: FT1 });
        }
        // horizontal-reduce each accumulator and store C[i, j..j+8]
        for acc in 0..JG as u8 {
            let r = FReg(3 + acc);
            a.push(Instr::VfsumH { fd: r, fs1: r });
            a.fsh(r, A0, 2 * acc as i32);
        }
        a.addi(A0, A0, 2 * JG as i32);
        a.addi(A1, A1, -1);
        a.bnez(A1, jloop);
        a.ssr_disable();
    }
}

/// Result of a cluster GEMM run.
pub struct GemmRun {
    pub c: Vec<f32>, // row-major M x N
    pub stats: ClusterStats,
    pub flops: u64,
}

/// Compile the `M×K×N` cluster GEMM (rows split over 8 cores) into its
/// deterministic [`GemmLayout`] plus a cacheable [`Program`].
pub fn build_gemm_program(m: u32, k: u32, n: u32) -> (GemmLayout, Program) {
    let lay = GemmLayout { a: 0x2000, bt: 0x2000 + 2 * m * k, c: 0x2000 + 2 * m * k + 2 * n * k };
    assert!(lay.c + 2 * m * n <= 128 * 1024, "GEMM tile too large for SPM");
    let per_core = m.div_ceil(CORES_PER_CLUSTER as u32);
    let streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(m);
            let hi = ((c + 1) * per_core).min(m);
            if lo == hi {
                return vec![];
            }
            let mut asm = Asm::new();
            emit_gemm_rows(&mut asm, lay, lo, hi, k, n);
            asm.finish()
        })
        .collect();
    (lay, Program::new(KernelKind::Gemm, streams))
}

/// Run `C = A · BT^T` on one cluster (rows split over 8 cores).
pub fn run_gemm(a_mat: &[f32], bt_mat: &[f32], m: u32, k: u32, n: u32) -> GemmRun {
    assert_eq!(a_mat.len(), (m * k) as usize);
    assert_eq!(bt_mat.len(), (n * k) as usize);
    let (lay, program) = build_gemm_program(m, k, n);

    let mut cluster = Cluster::new();
    cluster.spm.write_f32_as_bf16(lay.a, a_mat);
    cluster.spm.write_f32_as_bf16(lay.bt, bt_mat);

    let stats = cluster.run_program(&program);
    let c = cluster.spm.read_bf16_as_f32(lay.c, (m * n) as usize);
    GemmRun { c, stats, flops: 2 * m as u64 * n as u64 * k as u64 }
}

/// Host-side f32 oracle (with bf16 input quantization).
pub fn gemm_ref(a: &[f32], bt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let q = |x: f32| crate::bf16::Bf16::from_f32(x).to_f32();
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for kk in 0..k {
                s += q(a[i * k + kk]) * q(bt[j * k + kk]);
            }
            c[i * n + j] = s;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..rows * cols)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32
            })
            .collect()
    }

    #[test]
    fn small_gemm_matches_reference() {
        let (m, k, n) = (8, 16, 8);
        let a = mat(m, k, 1);
        let bt = mat(n, k, 2);
        let run = run_gemm(&a, &bt, m as u32, k as u32, n as u32);
        let want = gemm_ref(&a, &bt, m, k, n);
        for (i, (&got, &w)) in run.c.iter().zip(&want).enumerate() {
            // bf16 accumulate in 4 lanes + pairwise reduce: ~1% on K=16
            assert!(
                (got - w).abs() < 0.05 + 0.02 * w.abs(),
                "elem {i}: got {got}, want {w}"
            );
        }
    }

    #[test]
    fn identity_gemm() {
        // A · I^T = A (I is symmetric so BT = I works)
        let (m, k) = (4usize, 8usize);
        let a = mat(m, k, 3);
        let mut id = vec![0.0f32; k * k];
        for i in 0..k {
            id[i * k + i] = 1.0;
        }
        let run = run_gemm(&a, &id, m as u32, k as u32, k as u32);
        for i in 0..m * k {
            let w = crate::bf16::Bf16::from_f32(a[i]).to_f32();
            assert!((run.c[i] - w).abs() < 1e-3, "elem {i}");
        }
    }

    #[test]
    fn fpu_utilization_near_paper_anchor() {
        // Table III context: 48x48 GEMM at 85% FPU utilization
        let (m, k, n) = (48u32, 48u32, 48u32);
        let a = mat(m as usize, k as usize, 4);
        let bt = mat(n as usize, k as usize, 5);
        let run = run_gemm(&a, &bt, m, k, n);
        let combined = run.stats.combined();
        // combined sums all 8 cores' retired FP ops over the makespan
        let util = combined.fpu_utilization() / 8.0;
        assert!(
            util > 0.35,
            "FPU utilization {util:.2} too low (paper: 0.85; our dot-product
             formulation pays a per-8-outputs reduce epilogue)"
        );
        // energy model consumes flops; make sure they're counted
        assert!(combined.flops >= run.flops, "flops undercounted");
    }

    #[test]
    fn rectangular_shapes() {
        let (m, k, n) = (16, 64, 24);
        let a = mat(m, k, 6);
        let bt = mat(n, k, 7);
        let run = run_gemm(&a, &bt, m as u32, k as u32, n as u32);
        let want = gemm_ref(&a, &bt, m, k, n);
        let mut max_err = 0.0f32;
        for (&got, &w) in run.c.iter().zip(&want) {
            max_err = max_err.max((got - w).abs() / (1.0 + w.abs()));
        }
        assert!(max_err < 0.05, "max rel err {max_err}");
    }
}
