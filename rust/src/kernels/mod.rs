//! The paper's software kernels as simulator instruction streams:
//! the four softmax configurations (Fig. 4/6), the [5]-style GEMM, the
//! FlashAttention-2 forward, and the software exponentials they build on.
pub mod flash_attention;
pub mod gemm;
pub mod softexp;
pub mod softmax;
