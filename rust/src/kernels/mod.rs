//! The paper's software kernels as simulator instruction streams:
//! the four softmax configurations (Fig. 4/6), the softmax backward
//! (training) step, GELU and LayerNorm nonlinearities, the [5]-style
//! GEMM, the FlashAttention-2 forward, and the software exponentials
//! they build on.

// Item-level docs in this module are a tracked gap (ISSUE 3 scopes the
// missing_docs gate to exec/coordinator/model); module docs above are
// the contract. Remove this allow as the gap closes.
#![allow(missing_docs)]

pub mod flash_attention;
pub mod gelu;
pub mod gemm;
pub mod layernorm;
pub mod softexp;
pub mod softmax;
