//! GELU activation kernels (Belano et al. show the VEXP exp block pays
//! beyond softmax — the FFN activation is the next largest exp consumer).
//!
//! Three mathematical forms share one evaluation scheme, `x · σ(inner(x))`:
//!
//! | form      | `inner(x)`                | note                          |
//! |-----------|---------------------------|-------------------------------|
//! | `Tanh`    | `c1·x + c3·x³`            | tanh-form GELU via `tanh(u) = 2σ(2u) − 1` |
//! | `Sigmoid` | `1.702·x`                 | the sigmoid-form approximation |
//! | `Silu`    | `x`                       | SiLU / swish                   |
//!
//! and three exp technologies implement the sigmoid:
//!
//! - `Sw`: scalar loop, Schraudolph software exp, one real BF16 divide —
//!   the honest C-compiler baseline.
//! - `SwHorner`: scalar loop, degree-6 Horner polynomial exp (table-free
//!   libm-grade accuracy) — the middle of the speed/accuracy frontier.
//! - `Hw`: FREP+SSR+SIMD with VFEXP. The DIVSQRT block has no SIMD
//!   divide, so the reciprocal of `d = 1 + e^{−|z|} ∈ (1, 2]` is three
//!   Newton–Raphson steps from `r₀ = 0.7` (error 0.4^8 ≈ 6.5e-4, below
//!   BF16 resolution) — the whole body stays FREP-legal.
//!
//! σ is evaluated division-safely as `σ(z) = e^{min(z,0)} / (1 + e^{−|z|})`,
//! which never overflows the exponential for any BF16 input.

use super::softexp::{emit_horner6_exp, emit_schraudolph_sw_hoisted, write_exp_pool};
use crate::bf16::Bf16;
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// Mathematical form of the activation (what `inner(x)` is).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GeluForm {
    Tanh,
    Sigmoid,
    Silu,
}

impl GeluForm {
    pub const ALL: [GeluForm; 3] = [GeluForm::Tanh, GeluForm::Sigmoid, GeluForm::Silu];

    pub fn label(self) -> &'static str {
        match self {
            GeluForm::Tanh => "tanh",
            GeluForm::Sigmoid => "sigmoid",
            GeluForm::Silu => "silu",
        }
    }
}

/// Exp technology × mathematical form of a GELU kernel configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GeluVariant {
    /// Scalar loop + Schraudolph software exp (baseline).
    Sw(GeluForm),
    /// Scalar loop + degree-6 Horner polynomial exp (frontier midpoint).
    SwHorner(GeluForm),
    /// FREP + SSR + SIMD with VFEXP (this paper's extension).
    Hw(GeluForm),
}

impl GeluVariant {
    pub const ALL: [GeluVariant; 9] = [
        GeluVariant::Sw(GeluForm::Tanh),
        GeluVariant::Sw(GeluForm::Sigmoid),
        GeluVariant::Sw(GeluForm::Silu),
        GeluVariant::SwHorner(GeluForm::Tanh),
        GeluVariant::SwHorner(GeluForm::Sigmoid),
        GeluVariant::SwHorner(GeluForm::Silu),
        GeluVariant::Hw(GeluForm::Tanh),
        GeluVariant::Hw(GeluForm::Sigmoid),
        GeluVariant::Hw(GeluForm::Silu),
    ];

    pub fn form(self) -> GeluForm {
        match self {
            GeluVariant::Sw(f) | GeluVariant::SwHorner(f) | GeluVariant::Hw(f) => f,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            GeluVariant::Sw(GeluForm::Tanh) => "SW Schraudolph (tanh)",
            GeluVariant::Sw(GeluForm::Sigmoid) => "SW Schraudolph (sigmoid)",
            GeluVariant::Sw(GeluForm::Silu) => "SW Schraudolph (silu)",
            GeluVariant::SwHorner(GeluForm::Tanh) => "SW Horner-6 (tanh)",
            GeluVariant::SwHorner(GeluForm::Sigmoid) => "SW Horner-6 (sigmoid)",
            GeluVariant::SwHorner(GeluForm::Silu) => "SW Horner-6 (silu)",
            GeluVariant::Hw(GeluForm::Tanh) => "HW VFEXP (tanh)",
            GeluVariant::Hw(GeluForm::Sigmoid) => "HW VFEXP (sigmoid)",
            GeluVariant::Hw(GeluForm::Silu) => "HW VFEXP (silu)",
        }
    }
}

/// SPM layout for the GELU kernels (same shape as the softmax layout:
/// exp constant pool, then input rows, then output rows 48 KiB later).
pub struct GeluLayout {
    pub pool: u32,
    pub input: u32,
    pub output: u32,
}

pub const DEFAULT_LAYOUT: GeluLayout =
    GeluLayout { pool: 0x1000, input: 0x2000, output: 0x2000 + 48 * 1024 };

/// Result of a cluster GELU run.
pub struct GeluRun {
    pub out: Vec<Vec<f32>>,
    pub stats: ClusterStats,
    /// Cluster cycles per output element.
    pub cycles_per_output: f64,
}

// tanh-form coefficients for x·σ(c1·x + c3·x³): c1 = 2·√(2/π),
// c3 = c1·0.044715 (via tanh(u) = 2σ(2u) − 1)
fn tanh_c1() -> f32 {
    (2.0 * (2.0 / std::f64::consts::PI).sqrt()) as f32
}
fn tanh_c3() -> f32 {
    (2.0 * (2.0 / std::f64::consts::PI).sqrt() * 0.044715) as f32
}
/// Sigmoid-form slope (Hendrycks & Gimpel's 1.702).
const SIGMOID_C: f32 = 1.702;

fn bits(v: f32) -> i64 {
    Bf16::from_f32(v).0 as i64
}

/// Compile the cluster GELU kernel for `rows` rows of length `n`
/// (multiple of 16), statically partitioned over the eight cores, into
/// a cacheable [`Program`]. Inputs are read from [`DEFAULT_LAYOUT`]
/// addresses — see [`seed_gelu_inputs`] / [`run_gelu`] for the data side.
pub fn build_gelu_program(variant: GeluVariant, rows: u32, n: u32) -> Program {
    assert!(rows > 0 && n > 0);
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let per_core = rows.div_ceil(CORES_PER_CLUSTER as u32);
    let per_core_streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(rows);
            let hi = ((c + 1) * per_core).min(rows);
            if lo == hi {
                return vec![];
            }
            build_rows_program(variant, &lay, lo, hi, n)
        })
        .collect();
    Program::new(KernelKind::Gelu(variant), per_core_streams)
}

/// Write the constant pool plus `rows` deterministic pseudo-random input
/// rows at the [`DEFAULT_LAYOUT`] addresses — the data side of a cached
/// GELU [`Program`] (calibration and batched-serving runs).
pub fn seed_gelu_inputs(spm: &mut Mem, rows: u32, n: u32, seed: u64) {
    let lay = DEFAULT_LAYOUT;
    write_exp_pool(spm, lay.pool);
    let mut rng = crate::testkit::Rng::new(seed);
    for r in 0..rows {
        let row: Vec<f32> = (0..n).map(|_| rng.f32(-4.0, 4.0)).collect();
        spm.write_f32_as_bf16(lay.input + r * 2 * n, &row);
    }
}

/// Execute `rows` (each of equal length, multiple of 16) on one cluster.
pub fn run_gelu(variant: GeluVariant, rows: &[Vec<f32>]) -> GeluRun {
    let n = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(n > 0 && rows.iter().all(|r| r.len() == n), "ragged rows");
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let bytes = 2 * n as u32;
    assert!(
        lay.output + rows.len() as u32 * bytes <= 128 * 1024,
        "workload does not fit the 128 KiB SPM; tile it at the coordinator"
    );

    let mut cluster = Cluster::new();
    write_exp_pool(&mut cluster.spm, lay.pool);
    for (i, row) in rows.iter().enumerate() {
        cluster.spm.write_f32_as_bf16(lay.input + i as u32 * bytes, row);
    }

    let program = build_gelu_program(variant, rows.len() as u32, n as u32);
    let stats = cluster.run_program(&program);

    let out = (0..rows.len())
        .map(|i| cluster.spm.read_bf16_as_f32(lay.output + i as u32 * bytes, n))
        .collect();
    let cores_used = rows.len().min(CORES_PER_CLUSTER);
    let rows_on_busiest = rows.len().div_ceil(cores_used.max(1));
    let per_core_outputs = (rows_on_busiest * n) as f64;
    GeluRun { cycles_per_output: stats.cycles as f64 / per_core_outputs, out, stats }
}

/// Build one core's program covering rows [lo, hi).
fn build_rows_program(
    variant: GeluVariant,
    lay: &GeluLayout,
    lo: u32,
    hi: u32,
    n: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    match variant {
        GeluVariant::Hw(form) => {
            emit_hw_constants(&mut a, form);
            for r in lo..hi {
                emit_row_hw(&mut a, lay.input + r * 2 * n, lay.output + r * 2 * n, n, form);
            }
        }
        GeluVariant::Sw(form) | GeluVariant::SwHorner(form) => {
            emit_sw_constants(&mut a, variant, form);
            for r in lo..hi {
                emit_row_sw(&mut a, lay.input + r * 2 * n, lay.output + r * 2 * n, n, variant);
            }
        }
    }
    a.finish()
}

/// Hoist the broadcast SIMD constants: FS0 = 0, FS1 = 1, FS2 = 2,
/// FS3 = r₀ = 0.7, FS4 = c1 (form slope), FS5 = c3 (tanh cubic term).
fn emit_hw_constants(a: &mut Asm, form: GeluForm) {
    let bcast = |a: &mut Asm, fd: FReg, v: f32| {
        a.li(T0, bits(v));
        a.fmv_w_x(fd, T0);
        a.vfrep_h(fd, fd);
    };
    a.fmv_d_x(FS0, ZERO); // all four lanes +0
    bcast(a, FS1, 1.0);
    bcast(a, FS2, 2.0);
    bcast(a, FS3, 0.7);
    match form {
        GeluForm::Tanh => {
            bcast(a, FS4, tanh_c1());
            bcast(a, FS5, tanh_c3());
        }
        GeluForm::Sigmoid => bcast(a, FS4, SIGMOID_C),
        GeluForm::Silu => {}
    }
}

/// Hoist the scalar constants: FS0 = 0, FS1 = 1, FS4/FS5 as for SIMD,
/// FS2/FS3 = Schraudolph scale/bias (Sw only; Horner reads its pool
/// constants through A4 directly).
fn emit_sw_constants(a: &mut Asm, variant: GeluVariant, form: GeluForm) {
    let scalar = |a: &mut Asm, fd: FReg, v: f32| {
        a.li(T0, bits(v));
        a.fmv_w_x(fd, T0);
    };
    a.fmv_w_x(FS0, ZERO);
    scalar(a, FS1, 1.0);
    match form {
        GeluForm::Tanh => {
            scalar(a, FS4, tanh_c1());
            scalar(a, FS5, tanh_c3());
        }
        GeluForm::Sigmoid => scalar(a, FS4, SIGMOID_C),
        GeluForm::Silu => {}
    }
    if matches!(variant, GeluVariant::Sw(_)) {
        a.fld(FS2, A4, 576); // SCHRAU_SCALE (see softexp.rs pool)
        a.fld(FS3, A4, 584); // SCHRAU_BIAS
    }
}

/// One row, FREP+SSR+SIMD with VFEXP: ft0 streams the input, ft2 the
/// output; the body is a single straight-line SIMD chain per 4-lane
/// beat (all FP, FREP-legal — the reciprocal is NR, not a divide).
fn emit_row_hw(a: &mut Asm, input: u32, output: u32, n: u32, form: GeluForm) {
    a.ssr_cfg(0, SsrPattern::read1d(input, n / 4));
    a.ssr_cfg(2, SsrPattern::write1d(output, n / 4));
    a.ssr_enable();
    a.li(A3, (n / 4) as i64);
    let body = match form {
        GeluForm::Tanh => 25,
        GeluForm::Sigmoid => 22,
        GeluForm::Silu => 22,
    };
    a.frep(A3, body);
    // xv = x + 0: ft0 is SSR-mapped and pops per *operand read*, so the
    // copy must read it exactly once (vfsgnj ft3,ft0,ft0 would pop two
    // stream elements)
    a.vfadd_h(FT3, FT0, FS0);
    // z = inner(x)
    match form {
        GeluForm::Tanh => {
            a.vfmul_h(FT4, FT3, FT3); // x²
            a.vfsgnj_h(FT5, FS4, FS4); // t := c1
            a.vfmac_h(FT5, FT4, FS5); // t += x²·c3
            a.vfmul_h(FT4, FT3, FT5); // z = x·t
        }
        GeluForm::Sigmoid => {
            a.vfmul_h(FT4, FT3, FS4); // z = 1.702·x
        }
        GeluForm::Silu => {
            a.vfsgnj_h(FT4, FT3, FT3); // z = x
        }
    }
    // σ(z) = e^{min(z,0)} / (1 + e^{−|z|}), division-free
    a.vfsub_h(FT5, FS0, FT4); // −z
    a.vfmax_h(FT6, FT4, FT5); // |z|
    a.vfsub_h(FT6, FS0, FT6); // −|z|
    a.vfmax_h(FT5, FT5, FS0); // max(−z, 0)
    a.vfsub_h(FT5, FS0, FT5); // min(z, 0)
    a.vfexp_h(FT6, FT6); // e^{−|z|}
    a.vfexp_h(FT5, FT5); // e^{min(z,0)}
    a.vfadd_h(FT6, FT6, FS1); // d = 1 + e^{−|z|} ∈ (1, 2]
    a.vfsgnj_h(FT7, FS3, FS3); // r := r₀ = 0.7
    for _ in 0..3 {
        // r ← r·(2 − d·r)
        a.vfmul_h(FA0, FT6, FT7);
        a.vfsub_h(FA0, FS2, FA0);
        a.vfmul_h(FT7, FT7, FA0);
    }
    a.vfmul_h(FT5, FT5, FT7); // σ = e^{min(z,0)}·(1/d)
    a.vfmul_h(FT2, FT3, FT5); // out = x·σ (pushes the write stream)
    a.ssr_disable();
}

/// One row, scalar loop: per element, `inner(x)`, the division-safe σ
/// with two software exponentials, one real BF16 divide, and the final
/// multiply — the shape a C compiler gives the baseline.
fn emit_row_sw(a: &mut Asm, input: u32, output: u32, n: u32, variant: GeluVariant) {
    a.li(A0, input as i64);
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    let body = a.label();
    a.bind(body);
    a.flh(FT3, A0, 0); // x
    match variant.form() {
        GeluForm::Tanh => {
            a.fmul_h(FT4, FT3, FT3); // x²
            a.fmadd_h(FT4, FT4, FS5, FS4); // c1 + x²·c3
            a.fmul_h(FT4, FT3, FT4); // z
        }
        GeluForm::Sigmoid => {
            a.fmul_h(FT4, FT3, FS4);
        }
        GeluForm::Silu => {
            a.fadd_h(FT4, FT3, FS0); // z = x (+0 keeps it a pure copy)
        }
    }
    a.fsub_h(FT5, FS0, FT4); // −z
    a.fmax_h(FT6, FT4, FT5); // |z|
    a.fsub_h(FT6, FS0, FT6); // −|z|
    a.fmax_h(FT5, FT5, FS0); // max(−z, 0)
    a.fsub_h(FT5, FS0, FT5); // min(z, 0)
    match variant {
        GeluVariant::Sw(_) => {
            emit_schraudolph_sw_hoisted(a, FT7, FT6, FS2, FS3); // e^{−|z|}
            emit_schraudolph_sw_hoisted(a, FT5, FT5, FS2, FS3); // e^{min(z,0)}
        }
        GeluVariant::SwHorner(_) => {
            emit_horner6_exp(a, FT7, FT6);
            emit_horner6_exp(a, FT5, FT5);
        }
        GeluVariant::Hw(_) => unreachable!(),
    }
    a.fadd_h(FT7, FT7, FS1); // d = 1 + e^{−|z|}
    a.fdiv_h(FT5, FT5, FT7); // σ = e^{min(z,0)} / d
    a.fmul_h(FT5, FT3, FT5); // out = x·σ
    a.fsh(FT5, A1, 0);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, body);
}

/// Host-side f64 oracle: the same mathematical function each form
/// approximates, evaluated in double precision.
pub fn gelu_ref(form: GeluForm, x: f64) -> f64 {
    fn sigmoid(z: f64) -> f64 {
        if z >= 0.0 {
            1.0 / (1.0 + (-z).exp())
        } else {
            let e = z.exp();
            e / (1.0 + e)
        }
    }
    match form {
        GeluForm::Tanh => {
            let c = (2.0 / std::f64::consts::PI).sqrt();
            0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
        }
        GeluForm::Sigmoid => x * sigmoid(1.702 * x),
        GeluForm::Silu => x * sigmoid(x),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(r: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::testkit::Rng::new(seed);
        (0..r).map(|_| (0..n).map(|_| rng.f32(-4.0, 4.0)).collect()).collect()
    }

    fn check_correct(variant: GeluVariant, tol: f64) {
        let data = rows(8, 64, 42);
        let run = run_gelu(variant, &data);
        for (i, row) in data.iter().enumerate() {
            for (j, (&x, &got)) in row.iter().zip(&run.out[i]).enumerate() {
                let xq = Bf16::from_f32(x).to_f32() as f64;
                let want = gelu_ref(variant.form(), xq);
                let err = (got as f64 - want).abs();
                let rel = err / want.abs().max(0.25);
                assert!(
                    rel < tol,
                    "{variant:?} row {i} col {j}: gelu({xq}) = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn sw_schraudolph_correct_within_its_exp_error() {
        for form in GeluForm::ALL {
            // Schraudolph's ~4 % exp error reaches the output roughly
            // doubled (numerator and denominator err independently)
            check_correct(GeluVariant::Sw(form), 0.10);
        }
    }

    #[test]
    fn sw_horner_correct_to_bf16_chain() {
        for form in GeluForm::ALL {
            // exp is libm-grade; error is the BF16 rounding of ~8 chained
            // ops (≈ 8 × 0.4 %)
            check_correct(GeluVariant::SwHorner(form), 0.04);
        }
    }

    #[test]
    fn hw_vfexp_correct_within_exp_unit_error() {
        for form in GeluForm::ALL {
            // VFEXP ≤1.1 % per exp + NR reciprocal ≈ BF16 resolution
            check_correct(GeluVariant::Hw(form), 0.05);
        }
    }

    #[test]
    fn large_magnitude_inputs_saturate_correctly() {
        // gelu(x) → x for large +x, → ∓0 for large −x, all forms/techs
        let data = [vec![
            30.0f32, -30.0, 100.0, -100.0, 1000.0, -1000.0, 0.0, -0.0, 8.5, -8.5, 2.25, -2.25,
            0.125, -0.125, 16.0, -16.0,
        ]];
        for v in GeluVariant::ALL {
            let run = run_gelu(v, &data);
            for (&x, &got) in data[0].iter().zip(&run.out[0]) {
                let xq = Bf16::from_f32(x).to_f32() as f64;
                let want = gelu_ref(v.form(), xq);
                let err = (got as f64 - want).abs();
                assert!(
                    err < 0.12 * want.abs().max(0.3),
                    "{v:?}: gelu({xq}) = {got}, want {want}"
                );
            }
        }
    }

    #[test]
    fn hw_much_faster_than_scalar_and_horner_slowest() {
        let data = rows(8, 256, 7);
        let hw = run_gelu(GeluVariant::Hw(GeluForm::Tanh), &data).cycles_per_output;
        let sw = run_gelu(GeluVariant::Sw(GeluForm::Tanh), &data).cycles_per_output;
        let horner = run_gelu(GeluVariant::SwHorner(GeluForm::Tanh), &data).cycles_per_output;
        assert!(hw * 5.0 < sw, "hw {hw:.1} vs sw {sw:.1} cycles/output");
        assert!(sw < horner, "sw {sw:.1} vs horner {horner:.1} cycles/output");
    }

    #[test]
    fn uneven_rows_still_correct() {
        let data = rows(5, 32, 11);
        let run = run_gelu(GeluVariant::Hw(GeluForm::Silu), &data);
        for (i, row) in data.iter().enumerate() {
            for (&x, &got) in row.iter().zip(&run.out[i]) {
                let xq = Bf16::from_f32(x).to_f32() as f64;
                let want = gelu_ref(GeluForm::Silu, xq);
                assert!((got as f64 - want).abs() < 0.05 * want.abs().max(0.25));
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiple of 16")]
    fn ragged_simd_length_panics() {
        run_gelu(GeluVariant::Hw(GeluForm::Tanh), &rows(2, 17, 1));
    }

    #[test]
    fn deterministic_across_runs() {
        let data = rows(4, 64, 33);
        let a = run_gelu(GeluVariant::Hw(GeluForm::Tanh), &data);
        let b = run_gelu(GeluVariant::Hw(GeluForm::Tanh), &data);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.out, b.out);
    }
}
