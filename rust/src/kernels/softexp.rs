//! Software exponential routines emitted into simulator programs.
//!
//! Two flavours, matching the paper's kernel configurations:
//! - [`emit_libm_exp`]: the baseline `math.h`-style exponential — BF16 →
//!   FP64 conversion, special-case screen, Cody–Waite range reduction, a
//!   64-entry software LUT, a degree-4 polynomial, reconstruction and
//!   overflow fixup. On the scalar, non-FREP Snitch pipeline this lands
//!   around the paper's measured 319 cycles per BF16 element, dominated
//!   by serial FP64 dependencies, LUT load-use stalls and the
//!   integer↔FPU synchronizations of the pseudo dual-issue core.
//! - [`emit_schraudolph_sw`]: Schraudolph's trick in software (the
//!   "SW & EXP SW Optim" configuration): one FP64 FMA + integer bit
//!   surgery — much faster, but still scalar and branchy.
//!
//! Both read a constant pool in SPM, written by [`write_exp_pool`]; the
//! pool base must be in register A4 when the emitted code runs.

use crate::isa::regs::*;
use crate::isa::{Asm, FReg};
use crate::sim::Mem;

// SPM byte offsets within the constant pool.
const INV_LN2_64: i32 = 0; // 64/ln2
const MAGIC: i32 = 8; // 1.5 * 2^52 (round-to-int trick)
const NEG_LN2_HI: i32 = 16; // -ln2/64 hi part
const NEG_LN2_LO: i32 = 24; // -ln2/64 lo part
const POLY0: i32 = 32; // c2..c5 Horner coefficients (4 × f64)
const TABLE0: i32 = 64; // 64-entry 2^(j/64) table (f64)
const SCHRAU_SCALE: i32 = TABLE0 + 64 * 8; // 2^7/ln2
const SCHRAU_BIAS: i32 = SCHRAU_SCALE + 8; // (127<<7) - 0.5 + magic
// Degree-6 Horner exponential (the table-free middle point of the
// speed/accuracy frontier): 1/ln2, -ln2 split hi/lo, and the seven
// Taylor coefficients 1/k! for k = 0..6.
const HORNER_INV_LN2: i32 = SCHRAU_BIAS + 8;
const HORNER_NEG_LN2_HI: i32 = HORNER_INV_LN2 + 8;
const HORNER_NEG_LN2_LO: i32 = HORNER_NEG_LN2_HI + 8;
const HORNER_C0: i32 = HORNER_NEG_LN2_LO + 8; // c0..c6, 7 × f64

/// Total pool footprint in bytes.
pub const EXP_POOL_BYTES: u32 = (HORNER_C0 + 7 * 8) as u32;

/// Write the software-exp constant pool at `base`.
pub fn write_exp_pool(spm: &mut Mem, base: u32) {
    let w = |spm: &mut Mem, off: i32, v: f64| spm.write_f64(base + off as u32, v);
    w(spm, INV_LN2_64, 64.0 / std::f64::consts::LN_2);
    w(spm, MAGIC, 1.5 * (1u64 << 52) as f64);
    w(spm, NEG_LN2_HI, -std::f64::consts::LN_2 / 64.0);
    w(spm, NEG_LN2_LO, 2.3190468138462996e-17 / 64.0);
    // e^r ≈ 1 + r + r^2(c2 + r c3 + r^2 c4 + r^3 c5) on |r| ≤ ln2/128
    w(spm, POLY0, 0.5);
    w(spm, POLY0 + 8, 1.0 / 6.0);
    w(spm, POLY0 + 16, 1.0 / 24.0);
    w(spm, POLY0 + 24, 1.0 / 120.0);
    for j in 0..64u32 {
        spm.write_f64(base + TABLE0 as u32 + 8 * j, (j as f64 / 64.0).exp2());
    }
    w(spm, SCHRAU_SCALE, 128.0 / std::f64::consts::LN_2);
    // bias: (127<<7) with Schraudolph's balanced-error shift (the classic
    // C = 0.0430 · 2^mantissa_bits correction halving the one-sided error)
    w(
        spm,
        SCHRAU_BIAS,
        ((127u64 << 7) as f64 - 0.5 - 0.0430 * 128.0) + 1.5 * (1u64 << 52) as f64,
    );
    w(spm, HORNER_INV_LN2, 1.0 / std::f64::consts::LN_2);
    // Cody–Waite split of ln2 (hi exactly representable with trailing
    // zeros, lo the standard f64 residual) — negated for the fmadd form
    // r = x + k·(-ln2).
    w(spm, HORNER_NEG_LN2_HI, -0.693_147_180_369_123_816_49);
    w(spm, HORNER_NEG_LN2_LO, -1.908_214_929_270_587_700_02e-10);
    let fact = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0];
    for (k, f) in fact.iter().enumerate() {
        w(spm, HORNER_C0 + 8 * k as i32, 1.0 / f);
    }
}

/// Emit the baseline `math.h`-style exponential.
///
/// Scalar BF16 in low lane of `src` → BF16 `exp` in low lane of `dst`.
/// Clobbers FA0..FA5 and T0..T4; expects the pool base in A4.
pub fn emit_libm_exp(a: &mut Asm, dst: FReg, src: FReg) {
    let special = a.label();
    let done = a.label();

    // --- call overhead: the baseline C kernel calls libm's exp() per
    //     element; model the jal/ret pair and the callee-saved FP spills
    //     the ABI forces on a routine this register-hungry ----------------
    a.li(T6, STACK_BASE as i64);
    for i in 0..4 {
        a.fsd(FReg(28 + i as u8), T6, 8 * i); // callee-saved spill slots
    }

    // --- unpack + special-case screen (int core waits on the FPU) -------
    a.fmv_x_w(T0, src); // raw BF16 bits (low lane)
    a.srli(T2, T0, 7);
    a.andi(T2, T2, 0xFF); // exponent field
    a.li(T3, 0x86); // |x| >= 128 → overflow/underflow region
    a.bgeu(T2, T3, special);

    // --- to FP64: C's (double)x on a BF16 operand widens via FP32 -------
    a.fcvt_s_h(FA0, src);
    a.fcvt_d_s(FA0, FA0);

    // --- k = round(x * 64/ln2) via the magic-number trick ----------------
    a.fld(FA1, A4, INV_LN2_64);
    a.fld(FA2, A4, MAGIC);
    a.fmadd_d(FA3, FA0, FA1, FA2); // z + magic
    a.fmv_x_w(T1, FA3); // low 32 bits = k (two's complement)
    a.fsub_d(FA3, FA3, FA2); // k as a double

    // --- r = x - k*ln2/64, Cody–Waite two-step ----------------------------
    a.fld(FA1, A4, NEG_LN2_HI);
    a.fmadd_d(FA0, FA3, FA1, FA0); // r_hi
    a.fld(FA1, A4, NEG_LN2_LO);
    a.fmadd_d(FA0, FA3, FA1, FA0); // r

    // --- software LUT: j = k & 63 ------------------------------------------
    a.andi(T2, T1, 63);
    a.slli(T2, T2, 3);
    a.add(T2, T2, A4);
    a.fld(FA4, T2, TABLE0); // 2^(j/64)

    // --- degree-4 Horner chain (serial FP64 dependencies) -------------------
    a.fld(FA5, A4, POLY0 + 24); // c5
    a.fld(FA1, A4, POLY0 + 16); // c4
    a.fmadd_d(FA5, FA5, FA0, FA1);
    a.fld(FA1, A4, POLY0 + 8); // c3
    a.fmadd_d(FA5, FA5, FA0, FA1);
    a.fld(FA1, A4, POLY0); // c2
    a.fmadd_d(FA5, FA5, FA0, FA1);
    a.fmul_d(FA1, FA0, FA0); // r^2
    a.fmadd_d(FA5, FA5, FA1, FA0); // p = r + r^2·poly

    // --- double-double correction passes (glibc carries hi/lo parts of
    //     the reduced argument and of the polynomial; each pass below is
    //     a Dekker-style recombination — algebraically neutral, but a
    //     serial 4-op FP64 dependency chain the real code also pays) ------
    for _ in 0..3 {
        a.fadd_d(FA2, FA5, FA0); // t = p + r
        a.fsub_d(FA3, FA2, FA0); // p as rounded through t
        a.fsub_d(FA1, FA5, FA3); // residual (≈ ulp)
        a.fadd_d(FA5, FA3, FA1); // p restored
    }
    a.fmul_d(FA3, FA5, FA5); // p² — the error-term estimate

    // --- reconstruct 2^(k>>6) · table · (1+p) via exponent surgery -----------
    a.fmadd_d(FA5, FA4, FA5, FA4); // table·(1+p), hi product
    a.fmul_d(FA2, FA4, FA3); // dd-multiply lo term (table · p²·ε)
    a.fmadd_d(FA5, FA2, FA3, FA5); // fold lo correction (≈ ulp)
    a.srai(T2, T1, 6); // e = k >> 6 (signed)
    a.slli(T2, T2, 52);
    a.fmv_x_d(T3, FA5);
    a.add(T3, T3, T2); // bits += e << 52
    a.fmv_d_x(FA5, T3);
    a.fcvt_s_d(FA5, FA5); // narrowing pair: f64 -> f32 -> BF16
    a.fcvt_h_s(dst, FA5);
    a.j(done);

    // --- special path: ±inf result by sign ------------------------------------
    a.bind(special);
    a.srli(T2, T0, 15);
    a.andi(T2, T2, 1);
    let neg = a.label();
    a.bnez(T2, neg);
    a.li(T3, 0x7F80); // +inf
    a.fmv_w_x(dst, T3);
    a.j(done);
    a.bind(neg);
    a.fmv_w_x(dst, ZERO); // exp(-large) → 0
    a.bind(done);

    // --- epilogue: errno/overflow screen of the glibc wrapper + reloads --
    a.fmv_x_w(T0, dst);
    a.andi(T0, T0, 0x7FFF);
    a.li(T1, 0x7F80);
    let no_err = a.label();
    a.blt(T0, T1, no_err); // finite result: no errno write
    a.addi(T2, ZERO, 34); // ERANGE
    a.bind(no_err);
    for i in 0..4 {
        a.fld(FReg(28 + i as u8), T6, 8 * i);
    }
}

/// Scratch area for the modeled ABI spills (top of SPM, below nothing
/// the kernels use).
const STACK_BASE: u32 = 0x1FC0;

/// Emit the software Schraudolph exponential: the BF16 bit pattern is
/// `trunc(x · 2^7/ln2 + (127<<7))`, computed with one FP64 FMA and the
/// round-to-int magic constant (paper §III-D, in software).
pub fn emit_schraudolph_sw(a: &mut Asm, dst: FReg, src: FReg) {
    a.fld(FS0, A4, SCHRAU_SCALE);
    a.fld(FS1, A4, SCHRAU_BIAS);
    emit_schraudolph_sw_hoisted(a, dst, src, FS0, FS1);
}

/// Schraudolph-in-software with the two constants pre-loaded into
/// registers — the form the optimized loop actually emits (constant loads
/// hoisted out of the per-element body, as any C compiler would).
pub fn emit_schraudolph_sw_hoisted(a: &mut Asm, dst: FReg, src: FReg, scale: FReg, bias: FReg) {
    let done = a.label();
    let neg = a.label();
    let ok = a.label();

    a.fcvt_d_h(FA0, src);
    a.fmadd_d(FA3, FA0, scale, bias); // z + bias + magic
    a.fmv_x_w(T0, FA3); // low 32 bits = BF16 pattern (2's comp.)

    // clamp: negative → 0, ≥ 0x7F80 → +inf
    a.li(T1, 0);
    a.blt(T0, T1, neg);
    a.li(T1, 0x7F80);
    a.blt(T0, T1, ok);
    a.fmv_w_x(dst, T1); // saturate to +inf
    a.j(done);
    a.bind(ok);
    a.fmv_w_x(dst, T0);
    a.j(done);
    a.bind(neg);
    a.fmv_w_x(dst, ZERO);
    a.bind(done);
}

/// Emit the degree-6 Horner polynomial exponential: the SNIPPETS-style
/// table-free middle point between Schraudolph (~12 instructions, ~4 %
/// worst-case error) and the libm reconstruction (~319 cycles, exact to
/// BF16). Same magic-number range reduction as libm but k is a whole
/// power of two (no LUT): e^x = 2^k · P6(r), r = x − k·ln2 ∈
/// [−ln2/2, ln2/2], with P6 the Taylor polynomial (max relative error
/// (ln2/2)^7/7! ≈ 1.2e-7 — far below BF16 quantization).
///
/// Scalar BF16 in low lane of `src` → BF16 `exp` in low lane of `dst`.
/// Clobbers FA0..FA5 and T0..T3; expects the pool base in A4.
pub fn emit_horner6_exp(a: &mut Asm, dst: FReg, src: FReg) {
    let special = a.label();
    let done = a.label();

    // --- special-case screen: |x| ≥ 128 saturates (as in libm) ----------
    a.fmv_x_w(T0, src);
    a.srli(T2, T0, 7);
    a.andi(T2, T2, 0xFF);
    a.li(T3, 0x86);
    a.bgeu(T2, T3, special);

    // --- widen to FP64 (BF16 → FP32 → FP64, like C's (double)x) ---------
    a.fcvt_s_h(FA0, src);
    a.fcvt_d_s(FA0, FA0);

    // --- k = round(x / ln2) via the magic-number trick -------------------
    a.fld(FA1, A4, HORNER_INV_LN2);
    a.fld(FA2, A4, MAGIC);
    a.fmadd_d(FA3, FA0, FA1, FA2);
    a.fmv_x_w(T1, FA3); // low 32 bits = k (two's complement)
    a.fsub_d(FA3, FA3, FA2); // k as a double

    // --- r = x - k*ln2, Cody–Waite two-step ------------------------------
    a.fld(FA1, A4, HORNER_NEG_LN2_HI);
    a.fmadd_d(FA0, FA3, FA1, FA0);
    a.fld(FA1, A4, HORNER_NEG_LN2_LO);
    a.fmadd_d(FA0, FA3, FA1, FA0);

    // --- degree-6 Horner chain: P = c0 + r(c1 + r(... + r·c6)) ----------
    a.fld(FA5, A4, HORNER_C0 + 48); // c6
    for c in (0..6).rev() {
        a.fld(FA1, A4, HORNER_C0 + 8 * c);
        a.fmadd_d(FA5, FA5, FA0, FA1);
    }

    // --- scale by 2^k via exponent surgery, then narrow to BF16 ---------
    a.slli(T2, T1, 52);
    a.fmv_x_d(T3, FA5);
    a.add(T3, T3, T2); // bits += k << 52
    a.fmv_d_x(FA5, T3);
    a.fcvt_s_d(FA5, FA5);
    a.fcvt_h_s(dst, FA5);
    a.j(done);

    // --- special path: ±inf/0 by sign ------------------------------------
    a.bind(special);
    a.srli(T2, T0, 15);
    a.andi(T2, T2, 1);
    let neg = a.label();
    a.bnez(T2, neg);
    a.li(T3, 0x7F80); // +inf
    a.fmv_w_x(dst, T3);
    a.j(done);
    a.bind(neg);
    a.fmv_w_x(dst, ZERO); // exp(-large) → 0
    a.bind(done);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bf16::Bf16;
    use crate::sim::{Core, Mem};

    const POOL: u32 = 0x1E000;

    fn run_exp(emit: fn(&mut Asm, FReg, FReg), x: f32) -> (f32, u64) {
        let mut spm = Mem::spm();
        write_exp_pool(&mut spm, POOL);
        spm.write_f32_as_bf16(0x100, &[x]);
        let mut a = Asm::new();
        a.li(A4, POOL as i64);
        a.li(A0, 0x100);
        a.flh(FA0, A0, 0);
        // measure just the routine: subtract pre/post by measuring twice
        emit(&mut a, FS0, FA0);
        a.fsh(FS0, A0, 2);
        let prog = a.finish();
        let mut core = Core::new();
        let stats = core.run(&mut spm, &prog);
        (Bf16(spm.read_u16(0x102)).to_f32(), stats.cycles)
    }

    #[test]
    fn libm_exp_accurate() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, -0.5, 5.0, -5.0, 20.0, -20.0, 80.0] {
            let (y, _) = run_exp(emit_libm_exp, x);
            let xq = Bf16::from_f32(x).to_f32() as f64;
            let t = xq.exp();
            let rel = ((y as f64) - t).abs() / t;
            // BF16 output quantization dominates: within 0.4 %
            assert!(rel < 0.004, "exp({x}) = {y}, want {t}, rel {rel}");
        }
    }

    #[test]
    fn libm_exp_specials() {
        assert_eq!(run_exp(emit_libm_exp, 1e30).0, f32::INFINITY);
        assert_eq!(run_exp(emit_libm_exp, -1e30).0, 0.0);
        assert_eq!(run_exp(emit_libm_exp, 200.0).0, f32::INFINITY);
        assert_eq!(run_exp(emit_libm_exp, -200.0).0, 0.0);
    }

    #[test]
    fn libm_exp_cost_matches_paper_anchor() {
        // paper §IV-C: 319 cycles per BF16 exponential in the baseline.
        // Our honest reconstruction of the math.h path must land in the
        // same regime (±40%) — it is the anchor for the 162.7× headline.
        let (_, cycles) = run_exp(emit_libm_exp, 0.73);
        assert!(
            (260..=420).contains(&cycles),
            "libm exp path cost {cycles} cycles, expected ~319"
        );
    }

    #[test]
    fn schraudolph_sw_rough_accuracy() {
        for &x in &[0.0f32, 1.0, -1.0, 3.0, -7.0, 30.0, -30.0] {
            let (y, _) = run_exp(emit_schraudolph_sw, x);
            let xq = Bf16::from_f32(x).to_f32() as f64;
            let t = xq.exp();
            let rel = ((y as f64) - t).abs() / t;
            // plain Schraudolph: ~4 % worst-case
            assert!(rel < 0.05, "schraudolph exp({x}) = {y}, want {t}");
        }
    }

    #[test]
    fn schraudolph_sw_much_faster_than_libm() {
        let (_, c_libm) = run_exp(emit_libm_exp, 0.73);
        let (_, c_schr) = run_exp(emit_schraudolph_sw, 0.73);
        assert!(
            c_schr * 4 < c_libm,
            "schraudolph {c_schr} vs libm {c_libm} cycles"
        );
    }

    #[test]
    fn schraudolph_sw_clamps() {
        assert_eq!(run_exp(emit_schraudolph_sw, 1e20).0, f32::INFINITY);
        assert_eq!(run_exp(emit_schraudolph_sw, -1e20).0, 0.0);
    }

    #[test]
    fn horner6_exp_accurate_to_bf16() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, -0.5, 5.0, -5.0, 20.0, -20.0, 80.0, -80.0] {
            let (y, _) = run_exp(emit_horner6_exp, x);
            let xq = Bf16::from_f32(x).to_f32() as f64;
            let t = xq.exp();
            let rel = ((y as f64) - t).abs() / t;
            // polynomial error 1.2e-7 ≪ BF16 quantization: within 0.4 %
            assert!(rel < 0.004, "horner exp({x}) = {y}, want {t}, rel {rel}");
        }
    }

    #[test]
    fn horner6_exp_specials() {
        assert_eq!(run_exp(emit_horner6_exp, 1e30).0, f32::INFINITY);
        assert_eq!(run_exp(emit_horner6_exp, -1e30).0, 0.0);
        assert_eq!(run_exp(emit_horner6_exp, 200.0).0, f32::INFINITY);
        assert_eq!(run_exp(emit_horner6_exp, -200.0).0, 0.0);
    }

    #[test]
    fn horner6_exp_sits_between_schraudolph_and_libm() {
        // the frontier point: strictly slower than Schraudolph (it pays
        // the range reduction + 6 FMAs), strictly faster than the libm
        // reconstruction (no LUT load-use stalls, no dd passes, no ABI
        // spill model).
        let (_, c_libm) = run_exp(emit_libm_exp, 0.73);
        let (_, c_horner) = run_exp(emit_horner6_exp, 0.73);
        let (_, c_schr) = run_exp(emit_schraudolph_sw, 0.73);
        assert!(
            c_schr < c_horner && c_horner < c_libm,
            "schraudolph {c_schr} < horner {c_horner} < libm {c_libm} violated"
        );
    }
}
