//! LayerNorm kernels (SOLE co-designs softmax with LayerNorm; pricing it
//! is what makes the e2e model honest about non-attention work).
//!
//! Normalization is `y = (x − mean) / sqrt(var + eps)` with γ = 1, β = 0
//! (the affine pair folds into the adjacent projection GEMM on this
//! dataflow, so the kernel cost is the normalization itself).
//!
//! Algorithm choice (see DESIGN.md §13): the classic **two-pass**
//! mean/variance — pass A sums x, pass B computes `t = x − mean`, stores
//! it, and accumulates `t²`. Welford's online form needs a divide per
//! element (not FREP-able on the shared DIVSQRT block) and the naive
//! `E[x²] − E[x]²` form cancels catastrophically in BF16; two-pass costs
//! one extra stream but keeps every FREP body divide-free.
//!
//! The Snitch FPU has no square root, so `1/sqrt(v)` is the classic
//! integer bit-trick seeded Newton–Raphson — valid on BF16 directly
//! because BF16 is truncated FP32: magic `0x5F37` is the top half of the
//! FP32 magic `0x5F3759DF`. Two NR steps land below BF16 resolution.
//!
//! Variants: `Baseline` is the honest scalar three-loop C shape;
//! `Optimized` streams all three passes through FREP + SSR + SIMD.

use super::softexp::write_exp_pool;
use crate::bf16::Bf16;
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// The two evaluated configurations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerNormVariant {
    /// Scalar three-loop C shape (no FREP/SSR/SIMD).
    Baseline,
    /// FREP + SSR + SIMD streaming on all three passes.
    Optimized,
}

impl LayerNormVariant {
    pub const ALL: [LayerNormVariant; 2] =
        [LayerNormVariant::Baseline, LayerNormVariant::Optimized];

    pub fn label(self) -> &'static str {
        match self {
            LayerNormVariant::Baseline => "Baseline",
            LayerNormVariant::Optimized => "FREP+SSR+SIMD",
        }
    }
}

/// SPM layout for the LayerNorm kernels (softmax-shaped: pool, input
/// rows, output rows 48 KiB later).
pub struct LayerNormLayout {
    pub pool: u32,
    pub input: u32,
    pub output: u32,
}

pub const DEFAULT_LAYOUT: LayerNormLayout =
    LayerNormLayout { pool: 0x1000, input: 0x2000, output: 0x2000 + 48 * 1024 };

/// The ε inside the square root (the common 1e-5 default).
pub const LN_EPS: f32 = 1e-5;

/// Result of a cluster LayerNorm run.
pub struct LayerNormRun {
    pub out: Vec<Vec<f32>>,
    pub stats: ClusterStats,
    /// Cluster cycles per output element.
    pub cycles_per_output: f64,
}

fn bits(v: f32) -> i64 {
    Bf16::from_f32(v).0 as i64
}

/// Compile the cluster LayerNorm kernel for `rows` rows of length `n`
/// (multiple of 16), statically partitioned over the eight cores, into
/// a cacheable [`Program`].
pub fn build_layernorm_program(variant: LayerNormVariant, rows: u32, n: u32) -> Program {
    assert!(rows > 0 && n > 0);
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let per_core = rows.div_ceil(CORES_PER_CLUSTER as u32);
    let per_core_streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(rows);
            let hi = ((c + 1) * per_core).min(rows);
            if lo == hi {
                return vec![];
            }
            build_rows_program(variant, &lay, lo, hi, n)
        })
        .collect();
    Program::new(KernelKind::LayerNorm(variant), per_core_streams)
}

/// Write the constant pool plus `rows` deterministic pseudo-random input
/// rows at the [`DEFAULT_LAYOUT`] addresses.
pub fn seed_layernorm_inputs(spm: &mut Mem, rows: u32, n: u32, seed: u64) {
    let lay = DEFAULT_LAYOUT;
    write_exp_pool(spm, lay.pool);
    let mut rng = crate::testkit::Rng::new(seed);
    for r in 0..rows {
        let row: Vec<f32> = (0..n).map(|_| rng.f32(-8.0, 8.0)).collect();
        spm.write_f32_as_bf16(lay.input + r * 2 * n, &row);
    }
}

/// Execute `rows` (each of equal length, multiple of 16) on one cluster.
pub fn run_layernorm(variant: LayerNormVariant, rows: &[Vec<f32>]) -> LayerNormRun {
    let n = rows.first().map(|r| r.len()).unwrap_or(0);
    assert!(n > 0 && rows.iter().all(|r| r.len() == n), "ragged rows");
    assert!(n % 16 == 0, "row length {n} must be a multiple of 16");
    let lay = DEFAULT_LAYOUT;
    let bytes = 2 * n as u32;
    assert!(
        lay.output + rows.len() as u32 * bytes <= 128 * 1024,
        "workload does not fit the 128 KiB SPM; tile it at the coordinator"
    );

    let mut cluster = Cluster::new();
    write_exp_pool(&mut cluster.spm, lay.pool);
    for (i, row) in rows.iter().enumerate() {
        cluster.spm.write_f32_as_bf16(lay.input + i as u32 * bytes, row);
    }

    let program = build_layernorm_program(variant, rows.len() as u32, n as u32);
    let stats = cluster.run_program(&program);

    let out = (0..rows.len())
        .map(|i| cluster.spm.read_bf16_as_f32(lay.output + i as u32 * bytes, n))
        .collect();
    let cores_used = rows.len().min(CORES_PER_CLUSTER);
    let rows_on_busiest = rows.len().div_ceil(cores_used.max(1));
    let per_core_outputs = (rows_on_busiest * n) as f64;
    LayerNormRun { cycles_per_output: stats.cycles as f64 / per_core_outputs, out, stats }
}

/// Build one core's program covering rows [lo, hi).
fn build_rows_program(
    variant: LayerNormVariant,
    lay: &LayerNormLayout,
    lo: u32,
    hi: u32,
    n: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    // hoisted scalar constants: 1.5 / 0.5 (NR), 1/n, eps
    let scalar = |a: &mut Asm, fd: FReg, v: f32| {
        a.li(T0, bits(v));
        a.fmv_w_x(fd, T0);
    };
    scalar(&mut a, FS2, 1.5);
    scalar(&mut a, FS3, 0.5);
    scalar(&mut a, FS4, 1.0 / n as f32);
    scalar(&mut a, FS5, LN_EPS);
    for r in lo..hi {
        let in_addr = lay.input + r * 2 * n;
        let out_addr = lay.output + r * 2 * n;
        match variant {
            LayerNormVariant::Baseline => emit_row_baseline(&mut a, in_addr, out_addr, n),
            LayerNormVariant::Optimized => emit_row_optim(&mut a, in_addr, out_addr, n),
        }
    }
    a.finish()
}

/// Scalar `1/sqrt(v)`: BF16 bit-trick seed (magic `0x5F37`) plus two
/// Newton–Raphson steps `y ← y·(1.5 − 0.5·v·y²)`. Reads `src` (low
/// lane), writes `dst`; clobbers T0, T1, FA0; wants 1.5 in FS2 and 0.5
/// in FS3. The `andi` mask strips both the sign bit and whatever junk
/// the preceding BF16 ops left in bits 16..31 of the register.
fn emit_rsqrt(a: &mut Asm, dst: FReg, src: FReg) {
    a.fmv_x_w(T0, src);
    a.andi(T0, T0, 0x7FFF);
    a.srli(T0, T0, 1);
    a.li(T1, 0x5F37);
    a.sub(T1, T1, T0);
    a.fmv_w_x(dst, T1);
    for _ in 0..2 {
        a.fmul_h(FA0, dst, dst); // y²
        a.fmul_h(FA0, FA0, src); // v·y²
        a.fmul_h(FA0, FA0, FS3); // 0.5·v·y²
        a.fsub_h(FA0, FS2, FA0); // 1.5 − …
        a.fmul_h(dst, dst, FA0);
    }
}

/// The plain-C three-loop shape: sum, center+square-accumulate (writes
/// the centered row), scale by rsqrt.
fn emit_row_baseline(a: &mut Asm, input: u32, output: u32, n: u32) {
    // ---- pass A: sum ----------------------------------------------------
    a.li(A0, input as i64);
    a.li(A3, n as i64);
    a.fmv_w_x(FT5, ZERO); // sum := 0
    let sum_loop = a.label();
    a.bind(sum_loop);
    a.flh(FT3, A0, 0);
    a.fadd_h(FT5, FT5, FT3);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, sum_loop);
    a.fmul_h(FT5, FT5, FS4); // mean = sum/n

    // ---- pass B: t = x − mean → out; varsum += t² -----------------------
    a.li(A0, input as i64);
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    a.fmv_w_x(FT6, ZERO); // varsum := 0
    let center_loop = a.label();
    a.bind(center_loop);
    a.flh(FT3, A0, 0);
    a.fsub_h(FT4, FT3, FT5);
    a.fsh(FT4, A1, 0);
    a.fmadd_h(FT6, FT4, FT4, FT6);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, center_loop);
    a.fmul_h(FT6, FT6, FS4); // var = varsum/n (biased)
    a.fadd_h(FT6, FT6, FS5); // + eps
    emit_rsqrt(a, FT7, FT6); // rstd

    // ---- pass C: out *= rstd -------------------------------------------
    a.li(A1, output as i64);
    a.li(A3, n as i64);
    let scale_loop = a.label();
    a.bind(scale_loop);
    a.flh(FT4, A1, 0);
    a.fmul_h(FT4, FT4, FT7);
    a.fsh(FT4, A1, 0);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, scale_loop);
}

/// FREP + SSR + SIMD: pass A streams the row into two vector
/// accumulators; pass B re-streams it, pushes the centered row through
/// the write stream while two VFMAC accumulators square-accumulate; the
/// scalar rsqrt bridges to pass C, a broadcast VFMUL stream (the softmax
/// NORM shape).
fn emit_row_optim(a: &mut Asm, input: u32, output: u32, n: u32) {
    // ---- pass A: sum → mean broadcast in FT5 ----------------------------
    a.ssr_cfg(0, SsrPattern::read1d(input, n / 4));
    a.fmv_d_x(FT3, ZERO); // accumulators := 0 (all lanes)
    a.fmv_d_x(FT4, ZERO);
    a.ssr_enable();
    a.li(A3, (n / 8) as i64);
    a.frep(A3, 2);
    a.vfadd_h(FT3, FT3, FT0);
    a.vfadd_h(FT4, FT4, FT0);
    a.ssr_disable();
    a.vfadd_h(FT3, FT3, FT4);
    a.vfsum_h(FT3, FT3); // row sum in low lane
    a.fmul_h(FT3, FT3, FS4); // mean
    a.vfrep_h(FT5, FT3); // broadcast

    // ---- pass B: centered row out, t² accumulated -----------------------
    a.ssr_cfg(0, SsrPattern::read1d(input, n / 4));
    a.ssr_cfg(2, SsrPattern::write1d(output, n / 4));
    a.fmv_d_x(FT3, ZERO);
    a.fmv_d_x(FT4, ZERO);
    a.ssr_enable();
    a.li(A3, (n / 8) as i64);
    a.frep(A3, 6);
    a.vfsub_h(FT6, FT0, FT5); // t = x − mean
    a.vfsgnj_h(FT2, FT6, FT6); // push t
    a.vfmac_h(FT3, FT6, FT6); // varsum += t²
    a.vfsub_h(FT7, FT0, FT5);
    a.vfsgnj_h(FT2, FT7, FT7);
    a.vfmac_h(FT4, FT7, FT7);
    a.ssr_disable();
    a.vfadd_h(FT3, FT3, FT4);
    a.vfsum_h(FT3, FT3);
    a.fmul_h(FT3, FT3, FS4); // var
    a.fadd_h(FT3, FT3, FS5); // + eps
    emit_rsqrt(a, FT6, FT3);
    a.vfrep_h(FT6, FT6); // rstd broadcast

    // ---- pass C: out *= rstd (softmax NORM shape) -----------------------
    a.ssr_cfg(0, SsrPattern::read1d(output, n / 4));
    a.ssr_cfg(1, SsrPattern::write1d(output, n / 4));
    a.ssr_enable();
    a.li(A3, (n / 16) as i64);
    a.frep(A3, 4);
    a.vfmul_h(FT1, FT6, FT0);
    a.vfmul_h(FT1, FT6, FT0);
    a.vfmul_h(FT1, FT6, FT0);
    a.vfmul_h(FT1, FT6, FT0);
    a.ssr_disable();
}

/// Host-side f64 oracle (γ = 1, β = 0, biased variance, same ε).
pub fn layernorm_ref(row: &[f32]) -> Vec<f32> {
    let n = row.len() as f64;
    let mean = row.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = row.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / n;
    let rstd = 1.0 / (var + LN_EPS as f64).sqrt();
    row.iter().map(|&x| ((x as f64 - mean) * rstd) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quantized_rows(r: usize, n: usize, lo: f32, hi: f32, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = crate::testkit::Rng::new(seed);
        (0..r)
            .map(|_| (0..n).map(|_| Bf16::from_f32(rng.f32(lo, hi)).to_f32()).collect())
            .collect()
    }

    fn check_elementwise(variant: LayerNormVariant, data: &[Vec<f32>], abs: f64, rel: f64) {
        let run = run_layernorm(variant, data);
        for (i, row) in data.iter().enumerate() {
            let want = layernorm_ref(row);
            for (j, (&got, &w)) in run.out[i].iter().zip(&want).enumerate() {
                let err = (got as f64 - w as f64).abs();
                assert!(
                    err < abs + rel * (w as f64).abs(),
                    "{variant:?} row {i} col {j}: got {got}, want {w}"
                );
            }
        }
    }

    #[test]
    fn baseline_matches_reference_on_random_rows() {
        check_elementwise(LayerNormVariant::Baseline, &quantized_rows(8, 64, -8.0, 8.0, 42), 0.06, 0.03);
    }

    #[test]
    fn optimized_matches_reference_on_random_rows() {
        check_elementwise(LayerNormVariant::Optimized, &quantized_rows(8, 64, -8.0, 8.0, 42), 0.06, 0.03);
    }

    #[test]
    fn output_is_standardized() {
        // mean ≈ 0, var ≈ 1 of the kernel's own output, both variants
        let data = quantized_rows(8, 512, -8.0, 8.0, 7);
        for v in LayerNormVariant::ALL {
            let run = run_layernorm(v, &data);
            for out in &run.out {
                let n = out.len() as f64;
                let mean = out.iter().map(|&x| x as f64).sum::<f64>() / n;
                let var = out.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / n;
                assert!(mean.abs() < 0.05, "{v:?}: output mean {mean}");
                assert!((var - 1.0).abs() < 0.12, "{v:?}: output var {var}");
            }
        }
    }

    #[test]
    fn exactly_constant_row_normalizes_to_zero() {
        // n = 64 and x = 1.0: every partial sum and 1/n are exact in
        // BF16, so mean is exact, t ≡ 0, var = 0, and ε keeps the rsqrt
        // finite — the output must be exactly zero.
        let data = [vec![1.0f32; 64], vec![1.0f32; 64]];
        for v in LayerNormVariant::ALL {
            let run = run_layernorm(v, &data);
            for out in &run.out {
                assert!(out.iter().all(|&x| x == 0.0), "{v:?}: {out:?}");
            }
        }
    }

    #[test]
    fn near_constant_rows_stay_bounded() {
        // BF16 summation error on a near-constant row can make the
        // centered values pure rounding noise; the normalization then
        // amplifies that noise to O(1) — but never beyond the algebraic
        // bound |out| ≤ √n (var ≥ t²/n for any single t).
        let mut rng = crate::testkit::Rng::new(11);
        let data: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..256).map(|_| 5.0 + rng.f32(-1e-3, 1e-3)).collect())
            .collect();
        for v in LayerNormVariant::ALL {
            let run = run_layernorm(v, &data);
            for out in &run.out {
                for &x in out {
                    assert!(x.is_finite(), "{v:?} produced {x}");
                    assert!(x.abs() <= 1.1 * (256.0f32).sqrt(), "{v:?} out {x}");
                }
            }
        }
    }

    #[test]
    fn denormal_rows_flush_to_zero_like_reference() {
        // inputs at the bottom of the BF16 range: var underflows to 0 in
        // BF16, ε dominates, outputs are ~0 — and so is the reference
        let data = [vec![1e-38f32; 64], vec![-1e-38f32; 64]];
        for v in LayerNormVariant::ALL {
            let run = run_layernorm(v, &data);
            for out in &run.out {
                for &x in out {
                    assert!(x.is_finite());
                    assert!(x.abs() < 1e-3, "{v:?} out {x}");
                }
            }
        }
    }

    #[test]
    fn high_variance_rows_match_reference() {
        // adversarial spread: values across ±200 — the variance is ~1e4,
        // well inside BF16 range, and the normalized outputs must still
        // track the f64 reference
        check_elementwise(
            LayerNormVariant::Optimized,
            &quantized_rows(4, 128, -200.0, 200.0, 13),
            0.06,
            0.04,
        );
        check_elementwise(
            LayerNormVariant::Baseline,
            &quantized_rows(4, 128, -200.0, 200.0, 13),
            0.06,
            0.04,
        );
    }

    #[test]
    fn optimized_much_faster_than_baseline() {
        let data = quantized_rows(8, 256, -8.0, 8.0, 21);
        let base = run_layernorm(LayerNormVariant::Baseline, &data).cycles_per_output;
        let opt = run_layernorm(LayerNormVariant::Optimized, &data).cycles_per_output;
        assert!(
            opt * 4.0 < base,
            "optimized {opt:.1} vs baseline {base:.1} cycles/output"
        );
    }

    #[test]
    fn uneven_rows_still_correct() {
        let data = quantized_rows(5, 32, -8.0, 8.0, 31);
        check_elementwise(LayerNormVariant::Optimized, &data, 0.08, 0.05);
    }

    #[test]
    #[should_panic(expected = "multiple of 16")]
    fn ragged_simd_length_panics() {
        run_layernorm(LayerNormVariant::Optimized, &[vec![0.0f32; 17], vec![0.0f32; 17]]);
    }

    #[test]
    fn deterministic_across_runs() {
        let data = quantized_rows(4, 64, -8.0, 8.0, 33);
        let a = run_layernorm(LayerNormVariant::Optimized, &data);
        let b = run_layernorm(LayerNormVariant::Optimized, &data);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.out, b.out);
    }
}
