//! FlashAttention-2 forward on one Snitch cluster (paper §III-B/§IV-D):
//! K/V tiling with running row statistics (max `m`, exp-sum `l`), the
//! partial softmax executed per tile, and both GEMMs (QK^T and P·V) on
//! the dot-product kernel from [`super::gemm`].
//!
//! Two configurations, matching Fig. 6d-f:
//! - `Baseline`: GEMMs optimized (as in [5]), partial softmax in plain
//!   scalar C with the libm exponential — softmax dominates latency;
//! - `Optimized`: partial softmax with FREP + SSR + SIMD + **VFEXP** —
//!   softmax drops to a few percent of the kernel.
//!
//! Query rows are partitioned over the eight cores; every phase of every
//! tile is row-independent, so each core runs its rows start-to-finish
//! without synchronization (the paper's "multiple row statistics
//! simultaneously" parallelization).

use super::gemm::emit_gemm_rows_strided;
use super::softexp::{emit_libm_exp, write_exp_pool};
use crate::bf16::Bf16;
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// FlashAttention-2 kernel configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaVariant {
    Baseline,
    Optimized,
}

/// SPM layout for the single-head FA-2 kernel. Derived deterministically
/// from the problem shape by [`FaLayout::new`], so a cached [`Program`]
/// and a separately-seeded SPM always agree on addresses.
pub struct FaLayout {
    pool: u32,
    q: u32,   // Q[Sq,d], pre-scaled by 1/sqrt(d)
    k: u32,   // K[Sk,d]
    vt: u32,  // V^T[d,Sk] (DMA transposes at load)
    s: u32,   // S/P tile [Sq,bk]
    t: u32,   // P·V tile [Sq,d]
    o: u32,   // O[Sq,d] accumulator
    m: u32,   // running max per row
    l: u32,   // running exp-sum per row
    corr: u32, // per-row rescale factor for the current tile
}

impl FaLayout {
    /// Allocate the SPM layout for an `sq × sk` head at dimension `d`
    /// with K/V tile length `bk`. Panics when the working set exceeds
    /// the 128 KiB SPM.
    pub fn new(sq: u32, sk: u32, d: u32, bk: u32) -> Self {
        assert!(sk % bk == 0 && bk % 16 == 0 && d % 8 == 0);
        let mut at = 0x1400u32;
        let mut alloc = |bytes: u32| {
            let r = at;
            at += (bytes + 7) & !7;
            r
        };
        let lay = FaLayout {
            pool: 0x1000,
            q: alloc(2 * sq * d),
            k: alloc(2 * sk * d),
            vt: alloc(2 * sk * d),
            s: alloc(2 * sq * bk),
            t: alloc(2 * sq * d),
            o: alloc(2 * sq * d),
            m: alloc(2 * sq),
            l: alloc(2 * sq),
            corr: alloc(2 * sq),
        };
        assert!(at <= 128 * 1024, "FA-2 working set {at} bytes exceeds SPM");
        lay
    }

    /// Byte address of the O[Sq,d] output accumulator.
    pub fn o_addr(&self) -> u32 {
        self.o
    }
}

/// Result of a cluster FlashAttention-2 run.
pub struct FaRun {
    pub out: Vec<f32>, // row-major Sq x d
    pub stats: ClusterStats,
}

/// Run single-head FlashAttention-2 on one cluster.
///
/// `q`: Sq x d, `k`: Sk x d, `v`: Sk x d (row-major f32; quantized to
/// BF16 on the way into SPM). `bk` is the K/V tile length.
#[allow(clippy::too_many_arguments)]
pub fn run_flash_attention(
    variant: FaVariant,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sq: u32,
    sk: u32,
    d: u32,
    bk: u32,
) -> FaRun {
    let lay = FaLayout::new(sq, sk, d, bk);
    let mut cluster = Cluster::new();
    write_fa_data(&mut cluster.spm, &lay, q, k_mat, v, sq, sk, d);
    let program = build_fa_program(variant, sq, sk, d, bk);
    let stats = cluster.run_program(&program);
    let out = cluster.spm.read_bf16_as_f32(lay.o, (sq * d) as usize);
    FaRun { out, stats }
}

/// Compile the single-head FA-2 kernel (query rows partitioned over the
/// eight cores) into a cacheable [`Program`]. The stream addresses come
/// from [`FaLayout::new`] for the same shape, so any SPM seeded through
/// [`seed_fa_inputs`] or [`run_flash_attention`]'s data path matches.
pub fn build_fa_program(variant: FaVariant, sq: u32, sk: u32, d: u32, bk: u32) -> Program {
    let lay = FaLayout::new(sq, sk, d, bk);
    let per_core = sq.div_ceil(CORES_PER_CLUSTER as u32);
    let streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(sq);
            let hi = ((c + 1) * per_core).min(sq);
            if lo == hi {
                return vec![];
            }
            build_fa_core_program(variant, &lay, lo, hi, sq, sk, d, bk)
        })
        .collect();
    Program::new(KernelKind::FlashAttention(variant), streams)
}

/// Write Q/K/V and the running statistics into `spm` at the layout of
/// the given shape.
#[allow(clippy::too_many_arguments)]
fn write_fa_data(
    spm: &mut Mem,
    lay: &FaLayout,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sq: u32,
    sk: u32,
    d: u32,
) {
    assert_eq!(q.len(), (sq * d) as usize);
    assert_eq!(k_mat.len(), (sk * d) as usize);
    assert_eq!(v.len(), (sk * d) as usize);
    write_exp_pool(spm, lay.pool);
    let scale = 1.0 / (d as f32).sqrt();
    let qs: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    spm.write_f32_as_bf16(lay.q, &qs);
    spm.write_f32_as_bf16(lay.k, k_mat);
    // transpose V into VT[d, Sk]
    let mut vt = vec![0.0f32; (sk * d) as usize];
    for r in 0..sk as usize {
        for c in 0..d as usize {
            vt[c * sk as usize + r] = v[r * d as usize + c];
        }
    }
    spm.write_f32_as_bf16(lay.vt, &vt);
    // init stats: m = -inf, l = 0, O = 0
    spm.write_bf16_slice(lay.m, &vec![crate::bf16::NEG_INF; sq as usize]);
    spm.write_bf16_slice(lay.l, &vec![Bf16(0); sq as usize]);
    spm.write_bf16_slice(lay.o, &vec![Bf16(0); (sq * d) as usize]);
}

/// Seed `spm` with deterministic pseudo-random Q/K/V plus initialized
/// statistics for an `sq × sk` head — the data side of a cached FA-2
/// [`Program`] in calibration and batched-serving runs, where the
/// attention inputs are synthetic.
pub fn seed_fa_inputs(spm: &mut Mem, sq: u32, sk: u32, d: u32, bk: u32, seed: u64) {
    let lay = FaLayout::new(sq, sk, d, bk);
    let mut rng = crate::testkit::Rng::new(seed);
    let mut mat = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f32(-1.0, 1.0)).collect() };
    let q = mat((sq * d) as usize);
    let k = mat((sk * d) as usize);
    let v = mat((sk * d) as usize);
    write_fa_data(spm, &lay, &q, &k, &v, sq, sk, d);
}

#[allow(clippy::too_many_arguments)]
fn build_fa_core_program(
    variant: FaVariant,
    lay: &FaLayout,
    lo: u32,
    hi: u32,
    _sq: u32,
    sk: u32,
    d: u32,
    bk: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    for tile in 0..sk / bk {
        // ---- S = Q · K_tile^T (K rows are the BT rows; tile offsets rows)
        emit_gemm_rows_strided(
            &mut a,
            lay.q,
            lay.k + tile * bk * 2 * d, // K rows of this tile
            2 * d,
            lay.s,
            lo,
            hi,
            d,
            bk,
        );
        // ---- partial softmax on S rows + stats update ------------------
        for i in lo..hi {
            match variant {
                FaVariant::Optimized => emit_partial_softmax_opt(&mut a, lay, i, bk),
                FaVariant::Baseline => emit_partial_softmax_base(&mut a, lay, i, bk),
            }
        }
        // ---- T = P · V_tile  (BT rows are VT rows, sliced at tile*bk) ---
        emit_gemm_rows_strided(
            &mut a,
            lay.s,
            lay.vt + tile * bk * 2, // VT row slice for this tile
            2 * sk,
            lay.t,
            lo,
            hi,
            bk,
            d,
        );
        // ---- O = O * corr + T -------------------------------------------
        for i in lo..hi {
            match variant {
                FaVariant::Optimized => emit_rescale_opt(&mut a, lay, i, d),
                FaVariant::Baseline => emit_rescale_base(&mut a, lay, i, d),
            }
        }
    }
    // ---- final NORM: O[i,:] /= l[i] -------------------------------------
    for i in lo..hi {
        match variant {
            FaVariant::Optimized => emit_norm_opt(&mut a, lay, i, d),
            FaVariant::Baseline => emit_norm_base(&mut a, lay, i, d),
        }
    }
    a.finish()
}

// --------------------------------------------------------------------------
// Optimized (FREP + SSR + SIMD + VFEXP) phases
// --------------------------------------------------------------------------
fn emit_partial_softmax_opt(a: &mut Asm, lay: &FaLayout, i: u32, bk: u32) {
    let s_row = lay.s + i * 2 * bk;
    // row max of the S tile
    a.ssr_cfg(0, SsrPattern::read1d(s_row, bk / 4));
    a.fld(FT3, ZERO, s_row as i32);
    a.vfsgnj_h(FT4, FT3, FT3);
    a.vfsgnj_h(FT5, FT3, FT3);
    a.vfsgnj_h(FT6, FT3, FT3);
    a.ssr_enable();
    a.li(A3, (bk / 16) as i64);
    a.frep(A3, 4);
    a.vfmax_h(FT3, FT3, FT0);
    a.vfmax_h(FT4, FT4, FT0);
    a.vfmax_h(FT5, FT5, FT0);
    a.vfmax_h(FT6, FT6, FT0);
    a.ssr_disable();
    a.vfmax_h(FT3, FT3, FT4);
    a.vfmax_h(FT5, FT5, FT6);
    a.vfmax_h(FT3, FT3, FT5);
    a.vfmaxred_h(FT3, FT3); // m_tile

    // m_new = max(m_old, m_tile); corr = exp(m_old - m_new)
    a.li(A0, (lay.m + 2 * i) as i64);
    a.flh(FT4, A0, 0); // m_old
    a.fmax_h(FT5, FT4, FT3); // m_new
    a.fsh(FT5, A0, 0);
    a.fsub_h(FT6, FT4, FT5);
    a.fexp_h(FT6, FT6); // corr via the scalar FEXP instruction
    a.li(A0, (lay.corr + 2 * i) as i64);
    a.fsh(FT6, A0, 0);

    // P = exp(S - m_new) streamed; partial sum in FS0/FS1
    a.vfrep_h(FT7, FT5);
    a.ssr_cfg(1, SsrPattern::read1d(s_row, bk / 4));
    a.ssr_cfg(2, SsrPattern::write1d(s_row, bk / 4));
    a.vfsub_h(FS0, FS0, FS0);
    a.vfsub_h(FS1, FS1, FS1);
    a.ssr_enable();
    a.li(A3, (bk / 8) as i64);
    a.frep(A3, 8);
    a.vfsub_h(FT3, FT1, FT7);
    a.vfsub_h(FT4, FT1, FT7);
    a.vfexp_h(FT3, FT3);
    a.vfexp_h(FT4, FT4);
    a.vfsgnj_h(FT2, FT3, FT3);
    a.vfsgnj_h(FT2, FT4, FT4);
    a.vfadd_h(FS0, FS0, FT3);
    a.vfadd_h(FS1, FS1, FT4);
    a.ssr_disable();
    a.vfadd_h(FS0, FS0, FS1);
    a.vfsum_h(FS0, FS0); // row partial sum

    // l = l * corr + ps
    a.li(A0, (lay.l + 2 * i) as i64);
    a.flh(FT4, A0, 0);
    a.fmul_h(FT4, FT4, FT6);
    a.fadd_h(FT4, FT4, FS0);
    a.fsh(FT4, A0, 0);
}

fn emit_rescale_opt(a: &mut Asm, lay: &FaLayout, i: u32, d: u32) {
    let o_row = lay.o + i * 2 * d;
    let t_row = lay.t + i * 2 * d;
    a.li(A0, (lay.corr + 2 * i) as i64);
    a.flh(FT7, A0, 0);
    a.vfrep_h(FT7, FT7);
    a.ssr_cfg(0, SsrPattern::read1d(o_row, d / 4));
    a.ssr_cfg(1, SsrPattern::read1d(t_row, d / 4));
    a.ssr_cfg(2, SsrPattern::write1d(o_row, d / 4));
    a.ssr_enable();
    a.li(A3, (d / 8) as i64);
    a.frep(A3, 6);
    a.vfmul_h(FT3, FT0, FT7);
    a.vfmul_h(FT4, FT0, FT7);
    a.vfadd_h(FT3, FT3, FT1);
    a.vfadd_h(FT4, FT4, FT1);
    a.vfsgnj_h(FT2, FT3, FT3);
    a.vfsgnj_h(FT2, FT4, FT4);
    a.ssr_disable();
}

fn emit_norm_opt(a: &mut Asm, lay: &FaLayout, i: u32, d: u32) {
    let o_row = lay.o + i * 2 * d;
    a.li(A0, (lay.l + 2 * i) as i64);
    a.li(T0, 0x3F80);
    a.fmv_w_x(FS1, T0);
    a.flh(FT4, A0, 0);
    a.fdiv_h(FS1, FS1, FT4); // 1/l
    a.vfrep_h(FS1, FS1);
    a.ssr_cfg(0, SsrPattern::read1d(o_row, d / 4));
    a.ssr_cfg(1, SsrPattern::write1d(o_row, d / 4));
    a.ssr_enable();
    a.li(A3, (d / 16) as i64);
    a.frep(A3, 4);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.ssr_disable();
}

// --------------------------------------------------------------------------
// Baseline (scalar C, libm exponential) phases
// --------------------------------------------------------------------------
fn emit_partial_softmax_base(a: &mut Asm, lay: &FaLayout, i: u32, bk: u32) {
    let s_row = lay.s + i * 2 * bk;
    // scalar row max
    a.li(A0, s_row as i64);
    a.li(A3, bk as i64);
    a.flh(FT3, A0, 0);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT3, FT3, FT4);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);

    // stats + corr (libm exp)
    a.li(A0, (lay.m + 2 * i) as i64);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT5, FT4, FT3);
    a.fsh(FT5, A0, 0);
    a.fsub_h(FT6, FT4, FT5);
    emit_libm_exp(a, FT6, FT6);
    a.li(A0, (lay.corr + 2 * i) as i64);
    a.fsh(FT6, A0, 0);

    // P = exp(S - m_new), scalar loop, sum in FS0
    a.li(A0, s_row as i64);
    a.li(A3, bk as i64);
    a.fmv_w_x(FS0, ZERO);
    let lp2 = a.label();
    a.bind(lp2);
    a.flh(FT4, A0, 0);
    a.fsub_h(FT4, FT4, FT5);
    emit_libm_exp(a, FT3, FT4);
    a.fsh(FT3, A0, 0);
    a.fadd_h(FS0, FS0, FT3);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp2);

    // l = l * corr + ps
    a.li(A0, (lay.l + 2 * i) as i64);
    a.flh(FT4, A0, 0);
    a.fmul_h(FT4, FT4, FT6);
    a.fadd_h(FT4, FT4, FS0);
    a.fsh(FT4, A0, 0);
}

fn emit_rescale_base(a: &mut Asm, lay: &FaLayout, i: u32, d: u32) {
    a.li(A0, (lay.corr + 2 * i) as i64);
    a.flh(FT7, A0, 0);
    a.li(A0, (lay.o + i * 2 * d) as i64);
    a.li(A1, (lay.t + i * 2 * d) as i64);
    a.li(A3, d as i64);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT3, A0, 0);
    a.fmul_h(FT3, FT3, FT7);
    a.flh(FT4, A1, 0);
    a.fadd_h(FT3, FT3, FT4);
    a.fsh(FT3, A0, 0);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);
}

fn emit_norm_base(a: &mut Asm, lay: &FaLayout, i: u32, d: u32) {
    a.li(A0, (lay.l + 2 * i) as i64);
    a.flh(FT5, A0, 0);
    a.li(A0, (lay.o + i * 2 * d) as i64);
    a.li(A3, d as i64);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT3, A0, 0);
    a.fdiv_h(FT3, FT3, FT5);
    a.fsh(FT3, A0, 0);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);
}

/// Host-side exact attention oracle (f32, with bf16 input quantization).
pub fn attention_ref(q: &[f32], k: &[f32], v: &[f32], sq: usize, sk: usize, d: usize) -> Vec<f32> {
    let qz = |x: f32| Bf16::from_f32(x).to_f32();
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; sq * d];
    for i in 0..sq {
        let mut s = vec![0.0f32; sk];
        for j in 0..sk {
            let mut acc = 0.0f32;
            for c in 0..d {
                acc += qz(q[i * d + c] * scale) * qz(k[j * d + c]);
            }
            s[j] = acc;
        }
        let m = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = s.iter().map(|&x| (x - m).exp()).collect();
        let l: f32 = e.iter().sum();
        for c in 0..d {
            let mut acc = 0.0f32;
            for j in 0..sk {
                acc += e[j] * qz(v[j * d + c]);
            }
            out[i * d + c] = acc / l;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..rows * cols)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32
            })
            .collect()
    }

    fn check(variant: FaVariant, sq: u32, sk: u32, d: u32, bk: u32, tol: f32) {
        let q = mat(sq as usize, d as usize, 1);
        let k = mat(sk as usize, d as usize, 2);
        let v = mat(sk as usize, d as usize, 3);
        let run = run_flash_attention(variant, &q, &k, &v, sq, sk, d, bk);
        let want = attention_ref(&q, &k, &v, sq as usize, sk as usize, d as usize);
        let mut max_err = 0.0f32;
        for (&got, &w) in run.out.iter().zip(&want) {
            max_err = max_err.max((got - w).abs());
        }
        assert!(max_err < tol, "{variant:?} max abs err {max_err}");
    }

    #[test]
    fn optimized_matches_attention() {
        check(FaVariant::Optimized, 16, 64, 16, 32, 0.06);
    }

    #[test]
    fn cached_program_runs_on_seeded_spm() {
        // the exec-engine path: build once, seed data separately, run
        let (sq, sk, d, bk) = (16u32, 64, 64, 32);
        let program = build_fa_program(FaVariant::Optimized, sq, sk, d, bk);
        let clone = program.clone();
        assert!(program.shares_storage_with(&clone));
        let mut cluster = Cluster::new();
        seed_fa_inputs(&mut cluster.spm, sq, sk, d, bk, 99);
        let stats = cluster.run(clone.per_core());
        assert!(stats.cycles > 0);
        assert!(stats.combined().exp_ops > 0);
        // deterministic: a second run of the same handle costs the same
        let mut cluster2 = Cluster::new();
        seed_fa_inputs(&mut cluster2.spm, sq, sk, d, bk, 99);
        let stats2 = cluster2.run(program.per_core());
        assert_eq!(stats.cycles, stats2.cycles);
    }

    #[test]
    fn baseline_matches_attention() {
        check(FaVariant::Baseline, 16, 64, 16, 32, 0.06);
    }

    #[test]
    fn single_tile_equals_plain_softmax_attention() {
        check(FaVariant::Optimized, 8, 32, 16, 32, 0.06);
    }

    #[test]
    fn optimized_speedup_matches_fig6d() {
        // GPT-2 head dim 64; paper: up to 8.2x FA-2 throughput gain
        let (sq, sk, d, bk) = (32u32, 128u32, 64u32, 32u32);
        let q = mat(sq as usize, d as usize, 4);
        let k = mat(sk as usize, d as usize, 5);
        let v = mat(sk as usize, d as usize, 6);
        let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
        let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
        let speedup = base.stats.cycles as f64 / opt.stats.cycles as f64;
        assert!(
            (2.0..20.0).contains(&speedup),
            "FA-2 speedup {speedup:.2}x (paper: up to 8.2x)"
        );
    }

    #[test]
    fn softmax_share_shrinks_when_optimized() {
        // Fig. 6e: softmax dominates the baseline, ~6% when optimized.
        // Proxy: exp-class instructions exist only in the optimized
        // variant; the baseline burns its cycles in FP64 libm code.
        let (sq, sk, d, bk) = (16u32, 64u32, 64u32, 32u32);
        let q = mat(sq as usize, d as usize, 7);
        let k = mat(sk as usize, d as usize, 8);
        let v = mat(sk as usize, d as usize, 9);
        let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
        let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
        let base_c = base.stats.combined();
        let opt_c = opt.stats.combined();
        use crate::isa::Class;
        // baseline: huge FP64 share from libm
        assert!(base_c.count(Class::FpScalarD) > 10 * opt_c.count(Class::FpScalarD));
        // optimized: hardware exponentials
        assert!(opt_c.exp_ops > 0 && base_c.exp_ops == 0);
    }
}
