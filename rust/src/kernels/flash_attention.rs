//! FlashAttention-2 forward on one Snitch cluster (paper §III-B/§IV-D):
//! K/V tiling with running row statistics (max `m`, exp-sum `l`), the
//! partial softmax executed per tile, and both GEMMs (QK^T and P·V) on
//! the dot-product kernel from [`super::gemm`].
//!
//! Two configurations, matching Fig. 6d-f:
//! - `Baseline`: GEMMs optimized (as in [5]), partial softmax in plain
//!   scalar C with the libm exponential — softmax dominates latency;
//! - `Optimized`: partial softmax with FREP + SSR + SIMD + **VFEXP** —
//!   softmax drops to a few percent of the kernel.
//!
//! Two phases (DESIGN.md §10):
//! - **Prefill** ([`build_fa_program`]): query rows are partitioned over
//!   the eight cores; every phase of every tile is row-independent, so
//!   each core runs its rows start-to-finish without synchronization
//!   (the paper's "multiple row statistics simultaneously").
//! - **Decode** ([`build_fa_decode_program`]): a *single* query row
//!   against a KV window — the autoregressive serving slice. One row
//!   cannot be row-partitioned, so the kernel splits the *KV tiles*
//!   across the cores (flash-decoding style): each core keeps its own
//!   running statistics (mᶜ, lᶜ) and partial output Oᶜ over its tile
//!   range, and the last active core merges the partials
//!   (`out = Σ exp(mᶜ − m*)·Oᶜ / Σ exp(mᶜ − m*)·lᶜ`). Functional core
//!   execution is sequential against the shared SPM (see
//!   `sim/cluster.rs`), which stands in for the cluster barrier the
//!   real hardware would run before the merge — logged as a §2
//!   substitution in DESIGN.md.

use super::gemm::emit_gemm_rows_strided;
use super::softexp::{emit_libm_exp, write_exp_pool};
use crate::bf16::Bf16;
use crate::exec::program::{KernelKind, Program};
use crate::isa::regs::*;
use crate::isa::{Asm, Instr, SsrPattern};
use crate::sim::{Cluster, ClusterStats, Mem, CORES_PER_CLUSTER};

/// FlashAttention-2 kernel configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FaVariant {
    /// Optimized GEMMs, scalar libm partial softmax.
    Baseline,
    /// FREP + SSR + SIMD partial softmax with the VFEXP extension.
    Optimized,
}

/// SPM layout for the single-head FA-2 kernel. Derived deterministically
/// from the problem shape by [`FaLayout::new`], so a cached [`Program`]
/// and a separately-seeded SPM always agree on addresses.
pub struct FaLayout {
    pool: u32,
    q: u32,   // Q[Sq,d], pre-scaled by 1/sqrt(d)
    k: u32,   // K[Sk,d]
    vt: u32,  // V^T[d,Sk] (DMA transposes at load)
    s: u32,   // S/P tile [Sq,bk]
    t: u32,   // P·V tile [Sq,d]
    o: u32,   // O[Sq,d] accumulator
    m: u32,   // running max per row
    l: u32,   // running exp-sum per row
    corr: u32, // per-row rescale factor for the current tile
}

impl FaLayout {
    /// Allocate the SPM layout for an `sq × sk` head at dimension `d`
    /// with K/V tile length `bk`. Panics when the working set exceeds
    /// the 128 KiB SPM.
    pub fn new(sq: u32, sk: u32, d: u32, bk: u32) -> Self {
        assert!(sk % bk == 0 && bk % 16 == 0 && d % 8 == 0);
        let mut at = 0x1400u32;
        let mut alloc = |bytes: u32| {
            let r = at;
            at += (bytes + 7) & !7;
            r
        };
        let lay = FaLayout {
            pool: 0x1000,
            q: alloc(2 * sq * d),
            k: alloc(2 * sk * d),
            vt: alloc(2 * sk * d),
            s: alloc(2 * sq * bk),
            t: alloc(2 * sq * d),
            o: alloc(2 * sq * d),
            m: alloc(2 * sq),
            l: alloc(2 * sq),
            corr: alloc(2 * sq),
        };
        assert!(at <= 128 * 1024, "FA-2 working set {at} bytes exceeds SPM");
        lay
    }

    /// Byte address of the O[Sq,d] output accumulator.
    pub fn o_addr(&self) -> u32 {
        self.o
    }
}

/// SPM layout of the single-query decode slice (DESIGN.md §10): one
/// query row, a KV window of `sk` positions tiled at `bk`, per-core
/// partial statistics/output, and the merged output row.
pub struct FaDecodeLayout {
    pool: u32,
    q: u32,     // q[1,d], pre-scaled by 1/sqrt(d)
    k: u32,     // K[sk,d] window
    vt: u32,    // V^T[d,sk]
    s: u32,     // per-core S/P rows [CORES][bk]
    t: u32,     // per-core p·V rows [CORES][d]
    opart: u32, // per-core partial outputs [CORES][d]
    m: u32,     // per-core running max
    l: u32,     // per-core running exp-sum
    corr: u32,  // per-core rescale scratch (re-used as merge weights)
    mg: u32,    // global max (merge scratch)
    lg: u32,    // global exp-sum (merge scratch)
    out: u32,   // merged output row [d]
    end: u32,   // first byte past the working set
}

impl FaDecodeLayout {
    /// Allocate the decode-slice layout. Panics when the working set
    /// exceeds the 128 KiB SPM; use [`fa_decode_footprint`] to size a
    /// window without panicking.
    pub fn new(sk: u32, d: u32, bk: u32) -> Self {
        let lay = Self::build(sk, d, bk);
        assert!(
            lay.end <= 128 * 1024,
            "FA-decode working set {} bytes exceeds SPM",
            lay.end
        );
        lay
    }

    fn build(sk: u32, d: u32, bk: u32) -> Self {
        assert!(sk % bk == 0 && bk % 16 == 0 && d % 16 == 0);
        let cores = CORES_PER_CLUSTER as u32;
        // data starts at 0x2000: [0x1400, 0x2000) stays free scratch so
        // the baseline variant's modeled libm ABI spills (softexp.rs
        // STACK_BASE) can never alias layout data
        let mut at = 0x2000u32;
        let mut alloc = |bytes: u32| {
            let r = at;
            at += (bytes + 7) & !7;
            r
        };
        FaDecodeLayout {
            pool: 0x1000,
            q: alloc(2 * d),
            k: alloc(2 * sk * d),
            vt: alloc(2 * sk * d),
            s: alloc(cores * 2 * bk),
            t: alloc(cores * 2 * d),
            opart: alloc(cores * 2 * d),
            m: alloc(2 * cores),
            l: alloc(2 * cores),
            corr: alloc(2 * cores),
            mg: alloc(2),
            lg: alloc(2),
            out: alloc(2 * d),
            end: at,
        }
    }

    /// Byte address of the merged output row.
    pub fn out_addr(&self) -> u32 {
        self.out
    }
}

/// SPM bytes the decode slice occupies for a `sk × d` KV window at tile
/// length `bk` (layout end address, constant pool included). The
/// coordinator's decode planner sizes the slice window against this.
pub fn fa_decode_footprint(sk: u32, d: u32, bk: u32) -> u32 {
    FaDecodeLayout::build(sk, d, bk).end
}

/// Result of a cluster FlashAttention-2 run.
pub struct FaRun {
    /// Output rows (row-major `Sq × d`; `1 × d` for decode).
    pub out: Vec<f32>,
    /// Cluster statistics of the run.
    pub stats: ClusterStats,
}

/// Run single-head FlashAttention-2 on one cluster.
///
/// `q`: Sq x d, `k`: Sk x d, `v`: Sk x d (row-major f32; quantized to
/// BF16 on the way into SPM). `bk` is the K/V tile length.
#[allow(clippy::too_many_arguments)]
pub fn run_flash_attention(
    variant: FaVariant,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sq: u32,
    sk: u32,
    d: u32,
    bk: u32,
) -> FaRun {
    let lay = FaLayout::new(sq, sk, d, bk);
    let mut cluster = Cluster::new();
    write_fa_data(&mut cluster.spm, &lay, q, k_mat, v, sq, sk, d);
    let program = build_fa_program(variant, sq, sk, d, bk);
    let stats = cluster.run_program(&program);
    let out = cluster.spm.read_bf16_as_f32(lay.o, (sq * d) as usize);
    FaRun { out, stats }
}

/// Run the single-query decode slice on one cluster: one query row
/// against `sk` cached KV positions (`k`/`v`: Sk x d row-major f32).
pub fn run_flash_decode(
    variant: FaVariant,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sk: u32,
    d: u32,
    bk: u32,
) -> FaRun {
    let lay = FaDecodeLayout::new(sk, d, bk);
    let mut cluster = Cluster::new();
    write_fa_decode_data(&mut cluster.spm, &lay, q, k_mat, v, sk, d);
    let program = build_fa_decode_program(variant, sk, d, bk);
    let stats = cluster.run_program(&program);
    let out = cluster.spm.read_bf16_as_f32(lay.out, d as usize);
    FaRun { out, stats }
}

/// Compile the single-head FA-2 kernel (query rows partitioned over the
/// eight cores) into a cacheable [`Program`]. The stream addresses come
/// from [`FaLayout::new`] for the same shape, so any SPM seeded through
/// [`seed_fa_inputs`] or [`run_flash_attention`]'s data path matches.
pub fn build_fa_program(variant: FaVariant, sq: u32, sk: u32, d: u32, bk: u32) -> Program {
    let lay = FaLayout::new(sq, sk, d, bk);
    let per_core = sq.div_ceil(CORES_PER_CLUSTER as u32);
    let streams: Vec<Vec<Instr>> = (0..CORES_PER_CLUSTER as u32)
        .map(|c| {
            let lo = (c * per_core).min(sq);
            let hi = ((c + 1) * per_core).min(sq);
            if lo == hi {
                return vec![];
            }
            build_fa_core_program(variant, &lay, lo, hi, sq, sk, d, bk)
        })
        .collect();
    Program::new(KernelKind::FlashAttention(variant), streams)
}

/// Compile the single-query decode slice into a cacheable [`Program`]:
/// the `sk/bk` KV tiles are split across the eight cores, each core
/// accumulates its own partial statistics and output, and the last
/// active core merges them into the final output row. Seed the SPM with
/// [`seed_fa_decode_inputs`] (or [`run_flash_decode`]'s data path).
pub fn build_fa_decode_program(variant: FaVariant, sk: u32, d: u32, bk: u32) -> Program {
    let lay = FaDecodeLayout::new(sk, d, bk);
    let cores = CORES_PER_CLUSTER as u32;
    let tiles = sk / bk;
    let per_core = tiles.div_ceil(cores);
    let active = tiles.div_ceil(per_core);
    let streams: Vec<Vec<Instr>> = (0..cores)
        .map(|c| {
            let lo = (c * per_core).min(tiles);
            let hi = ((c + 1) * per_core).min(tiles);
            if lo == hi {
                return vec![];
            }
            build_fa_decode_core_program(variant, &lay, c, lo, hi, active, sk, d, bk)
        })
        .collect();
    Program::new(KernelKind::FlashDecode(variant), streams)
}

/// Write Q/K/V and the running statistics into `spm` at the layout of
/// the given shape.
#[allow(clippy::too_many_arguments)]
fn write_fa_data(
    spm: &mut Mem,
    lay: &FaLayout,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sq: u32,
    sk: u32,
    d: u32,
) {
    assert_eq!(q.len(), (sq * d) as usize);
    assert_eq!(k_mat.len(), (sk * d) as usize);
    assert_eq!(v.len(), (sk * d) as usize);
    write_exp_pool(spm, lay.pool);
    let scale = 1.0 / (d as f32).sqrt();
    let qs: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    spm.write_f32_as_bf16(lay.q, &qs);
    spm.write_f32_as_bf16(lay.k, k_mat);
    // transpose V into VT[d, Sk]
    let mut vt = vec![0.0f32; (sk * d) as usize];
    for r in 0..sk as usize {
        for c in 0..d as usize {
            vt[c * sk as usize + r] = v[r * d as usize + c];
        }
    }
    spm.write_f32_as_bf16(lay.vt, &vt);
    // init stats: m = -inf, l = 0, O = 0
    spm.write_bf16_slice(lay.m, &vec![crate::bf16::NEG_INF; sq as usize]);
    spm.write_bf16_slice(lay.l, &vec![Bf16(0); sq as usize]);
    spm.write_bf16_slice(lay.o, &vec![Bf16(0); (sq * d) as usize]);
}

/// Write q/K/V plus zeroed per-core statistics and output for the
/// decode slice at the layout of the given shape.
fn write_fa_decode_data(
    spm: &mut Mem,
    lay: &FaDecodeLayout,
    q: &[f32],
    k_mat: &[f32],
    v: &[f32],
    sk: u32,
    d: u32,
) {
    assert_eq!(q.len(), d as usize);
    assert_eq!(k_mat.len(), (sk * d) as usize);
    assert_eq!(v.len(), (sk * d) as usize);
    let cores = CORES_PER_CLUSTER;
    write_exp_pool(spm, lay.pool);
    let scale = 1.0 / (d as f32).sqrt();
    let qs: Vec<f32> = q.iter().map(|&x| x * scale).collect();
    spm.write_f32_as_bf16(lay.q, &qs);
    spm.write_f32_as_bf16(lay.k, k_mat);
    let mut vt = vec![0.0f32; (sk * d) as usize];
    for r in 0..sk as usize {
        for c in 0..d as usize {
            vt[c * sk as usize + r] = v[r * d as usize + c];
        }
    }
    spm.write_f32_as_bf16(lay.vt, &vt);
    // per-core stats: m = -inf, l = 0, corr = 0; partial and merged
    // outputs zeroed (the merge accumulates into `out`)
    spm.write_bf16_slice(lay.m, &vec![crate::bf16::NEG_INF; cores]);
    spm.write_bf16_slice(lay.l, &vec![Bf16(0); cores]);
    spm.write_bf16_slice(lay.corr, &vec![Bf16(0); cores]);
    spm.write_bf16_slice(lay.opart, &vec![Bf16(0); cores * d as usize]);
    spm.write_bf16_slice(lay.mg, &[Bf16(0), Bf16(0)]);
    spm.write_bf16_slice(lay.out, &vec![Bf16(0); d as usize]);
}

/// Seed `spm` with deterministic pseudo-random Q/K/V plus initialized
/// statistics for an `sq × sk` head — the data side of a cached FA-2
/// [`Program`] in calibration and batched-serving runs, where the
/// attention inputs are synthetic.
pub fn seed_fa_inputs(spm: &mut Mem, sq: u32, sk: u32, d: u32, bk: u32, seed: u64) {
    let lay = FaLayout::new(sq, sk, d, bk);
    let mut rng = crate::testkit::Rng::new(seed);
    let mut mat = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f32(-1.0, 1.0)).collect() };
    let q = mat((sq * d) as usize);
    let k = mat((sk * d) as usize);
    let v = mat((sk * d) as usize);
    write_fa_data(spm, &lay, &q, &k, &v, sq, sk, d);
}

/// Seed `spm` with deterministic pseudo-random q/K/V plus initialized
/// per-core statistics for a decode slice — the data side of a cached
/// decode [`Program`] in the continuous-batching path.
pub fn seed_fa_decode_inputs(spm: &mut Mem, sk: u32, d: u32, bk: u32, seed: u64) {
    let lay = FaDecodeLayout::new(sk, d, bk);
    let mut rng = crate::testkit::Rng::new(seed);
    let mut mat = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.f32(-1.0, 1.0)).collect() };
    let q = mat(d as usize);
    let k = mat((sk * d) as usize);
    let v = mat((sk * d) as usize);
    write_fa_decode_data(spm, &lay, &q, &k, &v, sk, d);
}

#[allow(clippy::too_many_arguments)]
fn build_fa_core_program(
    variant: FaVariant,
    lay: &FaLayout,
    lo: u32,
    hi: u32,
    _sq: u32,
    sk: u32,
    d: u32,
    bk: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    for tile in 0..sk / bk {
        // ---- S = Q · K_tile^T (K rows are the BT rows; tile offsets rows)
        emit_gemm_rows_strided(
            &mut a,
            lay.q,
            lay.k + tile * bk * 2 * d, // K rows of this tile
            2 * d,
            lay.s,
            lo,
            hi,
            d,
            bk,
        );
        // ---- partial softmax on S rows + stats update ------------------
        for i in lo..hi {
            let s_row = lay.s + i * 2 * bk;
            let (m_addr, l_addr, corr_addr) = (lay.m + 2 * i, lay.l + 2 * i, lay.corr + 2 * i);
            match variant {
                FaVariant::Optimized => {
                    emit_partial_softmax_opt(&mut a, s_row, m_addr, l_addr, corr_addr, bk)
                }
                FaVariant::Baseline => {
                    emit_partial_softmax_base(&mut a, s_row, m_addr, l_addr, corr_addr, bk)
                }
            }
        }
        // ---- T = P · V_tile  (BT rows are VT rows, sliced at tile*bk) ---
        emit_gemm_rows_strided(
            &mut a,
            lay.s,
            lay.vt + tile * bk * 2, // VT row slice for this tile
            2 * sk,
            lay.t,
            lo,
            hi,
            bk,
            d,
        );
        // ---- O = O * corr + T -------------------------------------------
        for i in lo..hi {
            let (o_row, t_row) = (lay.o + i * 2 * d, lay.t + i * 2 * d);
            match variant {
                FaVariant::Optimized => {
                    emit_scale_add_opt(&mut a, o_row, t_row, o_row, lay.corr + 2 * i, d)
                }
                FaVariant::Baseline => emit_rescale_base(&mut a, o_row, t_row, lay.corr + 2 * i, d),
            }
        }
    }
    // ---- final NORM: O[i,:] /= l[i] -------------------------------------
    for i in lo..hi {
        let o_row = lay.o + i * 2 * d;
        match variant {
            FaVariant::Optimized => emit_norm_opt(&mut a, o_row, lay.l + 2 * i, d),
            FaVariant::Baseline => emit_norm_base(&mut a, o_row, lay.l + 2 * i, d),
        }
    }
    a.finish()
}

/// One core's share of the decode slice: tiles `[tile_lo, tile_hi)` of
/// the KV window, accumulated into the core's private partials; the
/// last active core appends the merge.
#[allow(clippy::too_many_arguments)]
fn build_fa_decode_core_program(
    variant: FaVariant,
    lay: &FaDecodeLayout,
    core: u32,
    tile_lo: u32,
    tile_hi: u32,
    active: u32,
    sk: u32,
    d: u32,
    bk: u32,
) -> Vec<Instr> {
    let mut a = Asm::new();
    a.li(A4, lay.pool as i64);
    let s_row = lay.s + core * 2 * bk;
    let t_row = lay.t + core * 2 * d;
    let o_row = lay.opart + core * 2 * d;
    let (m_addr, l_addr, corr_addr) = (lay.m + 2 * core, lay.l + 2 * core, lay.corr + 2 * core);
    for tile in tile_lo..tile_hi {
        // ---- s = q · K_tile^T (a 1×bk GEMV on the dot-product kernel) ---
        emit_gemm_rows_strided(&mut a, lay.q, lay.k + tile * bk * 2 * d, 2 * d, s_row, 0, 1, d, bk);
        // ---- partial softmax on the 1×bk score row, private stats ------
        match variant {
            FaVariant::Optimized => emit_partial_softmax_opt(&mut a, s_row, m_addr, l_addr, corr_addr, bk),
            FaVariant::Baseline => emit_partial_softmax_base(&mut a, s_row, m_addr, l_addr, corr_addr, bk),
        }
        // ---- t = p · V_tile (1×d GEMV) ----------------------------------
        emit_gemm_rows_strided(&mut a, s_row, lay.vt + tile * bk * 2, 2 * sk, t_row, 0, 1, bk, d);
        // ---- Oᶜ = Oᶜ · corr + t ------------------------------------------
        match variant {
            FaVariant::Optimized => emit_scale_add_opt(&mut a, o_row, t_row, o_row, corr_addr, d),
            FaVariant::Baseline => emit_rescale_base(&mut a, o_row, t_row, corr_addr, d),
        }
    }
    if core + 1 == active {
        emit_decode_merge(&mut a, variant, lay, active, d);
    }
    a.finish()
}

/// Merge the per-core decode partials into `lay.out`:
/// `m* = max mᶜ`, `wᶜ = exp(mᶜ − m*)`, `out = Σ wᶜ·Oᶜ / Σ wᶜ·lᶜ`.
///
/// Runs on the last active core after its own tile loop. Functional
/// core execution is sequential against the shared SPM, so every
/// partial is already written when the merge reads it; the timing
/// makespan does not serialize the merge behind the other cores — the
/// unmodeled cluster barrier is logged in DESIGN.md §2/§10.
fn emit_decode_merge(a: &mut Asm, variant: FaVariant, lay: &FaDecodeLayout, active: u32, d: u32) {
    // ---- m* = max over active cores, parked at lay.mg -------------------
    a.li(A5, lay.m as i64);
    a.flh(FT3, A5, 0);
    for c in 1..active {
        a.flh(FT4, A5, (2 * c) as i32);
        a.fmax_h(FT3, FT3, FT4);
    }
    a.li(A0, lay.mg as i64);
    a.fsh(FT3, A0, 0);

    // ---- l* accumulator in FS2 ------------------------------------------
    a.fmv_w_x(FS2, ZERO);
    for c in 0..active {
        // wᶜ = exp(mᶜ − m*)
        a.li(A0, lay.mg as i64);
        a.flh(FT4, A0, 0);
        a.li(A0, (lay.m + 2 * c) as i64);
        a.flh(FT5, A0, 0);
        a.fsub_h(FT5, FT5, FT4);
        match variant {
            FaVariant::Optimized => {
                a.fexp_h(FT5, FT5);
            }
            FaVariant::Baseline => emit_libm_exp(a, FT5, FT5),
        }
        // park wᶜ in the (now free) corr slot for the SSR broadcast
        a.li(A0, (lay.corr + 2 * c) as i64);
        a.fsh(FT5, A0, 0);
        // l* += wᶜ · lᶜ
        a.li(A0, (lay.l + 2 * c) as i64);
        a.flh(FT6, A0, 0);
        a.fmul_h(FT6, FT6, FT5);
        a.fadd_h(FS2, FS2, FT6);
        // out += wᶜ · Oᶜ
        let o_row = lay.opart + c * 2 * d;
        match variant {
            FaVariant::Optimized => {
                emit_scale_add_opt(a, o_row, lay.out, lay.out, lay.corr + 2 * c, d)
            }
            FaVariant::Baseline => {
                emit_scale_add_base(a, o_row, lay.out, lay.out, lay.corr + 2 * c, d)
            }
        }
    }
    a.li(A0, lay.lg as i64);
    a.fsh(FS2, A0, 0);

    // ---- out /= l* --------------------------------------------------------
    match variant {
        FaVariant::Optimized => emit_norm_opt(a, lay.out, lay.lg, d),
        FaVariant::Baseline => emit_norm_base(a, lay.out, lay.lg, d),
    }
}

// --------------------------------------------------------------------------
// Optimized (FREP + SSR + SIMD + VFEXP) phases
// --------------------------------------------------------------------------
fn emit_partial_softmax_opt(
    a: &mut Asm,
    s_row: u32,
    m_addr: u32,
    l_addr: u32,
    corr_addr: u32,
    bk: u32,
) {
    // row max of the S tile
    a.ssr_cfg(0, SsrPattern::read1d(s_row, bk / 4));
    a.fld(FT3, ZERO, s_row as i32);
    a.vfsgnj_h(FT4, FT3, FT3);
    a.vfsgnj_h(FT5, FT3, FT3);
    a.vfsgnj_h(FT6, FT3, FT3);
    a.ssr_enable();
    a.li(A3, (bk / 16) as i64);
    a.frep(A3, 4);
    a.vfmax_h(FT3, FT3, FT0);
    a.vfmax_h(FT4, FT4, FT0);
    a.vfmax_h(FT5, FT5, FT0);
    a.vfmax_h(FT6, FT6, FT0);
    a.ssr_disable();
    a.vfmax_h(FT3, FT3, FT4);
    a.vfmax_h(FT5, FT5, FT6);
    a.vfmax_h(FT3, FT3, FT5);
    a.vfmaxred_h(FT3, FT3); // m_tile

    // m_new = max(m_old, m_tile); corr = exp(m_old - m_new)
    a.li(A0, m_addr as i64);
    a.flh(FT4, A0, 0); // m_old
    a.fmax_h(FT5, FT4, FT3); // m_new
    a.fsh(FT5, A0, 0);
    a.fsub_h(FT6, FT4, FT5);
    a.fexp_h(FT6, FT6); // corr via the scalar FEXP instruction
    a.li(A0, corr_addr as i64);
    a.fsh(FT6, A0, 0);

    // P = exp(S - m_new) streamed; partial sum in FS0/FS1
    a.vfrep_h(FT7, FT5);
    a.ssr_cfg(1, SsrPattern::read1d(s_row, bk / 4));
    a.ssr_cfg(2, SsrPattern::write1d(s_row, bk / 4));
    a.vfsub_h(FS0, FS0, FS0);
    a.vfsub_h(FS1, FS1, FS1);
    a.ssr_enable();
    a.li(A3, (bk / 8) as i64);
    a.frep(A3, 8);
    a.vfsub_h(FT3, FT1, FT7);
    a.vfsub_h(FT4, FT1, FT7);
    a.vfexp_h(FT3, FT3);
    a.vfexp_h(FT4, FT4);
    a.vfsgnj_h(FT2, FT3, FT3);
    a.vfsgnj_h(FT2, FT4, FT4);
    a.vfadd_h(FS0, FS0, FT3);
    a.vfadd_h(FS1, FS1, FT4);
    a.ssr_disable();
    a.vfadd_h(FS0, FS0, FS1);
    a.vfsum_h(FS0, FS0); // row partial sum

    // l = l * corr + ps
    a.li(A0, l_addr as i64);
    a.flh(FT4, A0, 0);
    a.fmul_h(FT4, FT4, FT6);
    a.fadd_h(FT4, FT4, FS0);
    a.fsh(FT4, A0, 0);
}

/// `dst[0..d] = src[0..d] · w + add[0..d]` streamed (SSR + FREP). The
/// prefill rescale is the aliased case `dst == src` (O = O·corr + T);
/// the decode merge accumulates with `dst == add` (out += w·Oᶜ).
fn emit_scale_add_opt(a: &mut Asm, src: u32, add: u32, dst: u32, w_addr: u32, d: u32) {
    a.li(A0, w_addr as i64);
    a.flh(FT7, A0, 0);
    a.vfrep_h(FT7, FT7);
    a.ssr_cfg(0, SsrPattern::read1d(src, d / 4));
    a.ssr_cfg(1, SsrPattern::read1d(add, d / 4));
    a.ssr_cfg(2, SsrPattern::write1d(dst, d / 4));
    a.ssr_enable();
    a.li(A3, (d / 8) as i64);
    a.frep(A3, 6);
    a.vfmul_h(FT3, FT0, FT7);
    a.vfmul_h(FT4, FT0, FT7);
    a.vfadd_h(FT3, FT3, FT1);
    a.vfadd_h(FT4, FT4, FT1);
    a.vfsgnj_h(FT2, FT3, FT3);
    a.vfsgnj_h(FT2, FT4, FT4);
    a.ssr_disable();
}

fn emit_norm_opt(a: &mut Asm, o_row: u32, l_addr: u32, d: u32) {
    a.li(A0, l_addr as i64);
    a.li(T0, 0x3F80);
    a.fmv_w_x(FS1, T0);
    a.flh(FT4, A0, 0);
    a.fdiv_h(FS1, FS1, FT4); // 1/l
    a.vfrep_h(FS1, FS1);
    a.ssr_cfg(0, SsrPattern::read1d(o_row, d / 4));
    a.ssr_cfg(1, SsrPattern::write1d(o_row, d / 4));
    a.ssr_enable();
    a.li(A3, (d / 16) as i64);
    a.frep(A3, 4);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.vfmul_h(FT1, FS1, FT0);
    a.ssr_disable();
}

// --------------------------------------------------------------------------
// Baseline (scalar C, libm exponential) phases
// --------------------------------------------------------------------------
fn emit_partial_softmax_base(
    a: &mut Asm,
    s_row: u32,
    m_addr: u32,
    l_addr: u32,
    corr_addr: u32,
    bk: u32,
) {
    // scalar row max
    a.li(A0, s_row as i64);
    a.li(A3, bk as i64);
    a.flh(FT3, A0, 0);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT3, FT3, FT4);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);

    // stats + corr (libm exp)
    a.li(A0, m_addr as i64);
    a.flh(FT4, A0, 0);
    a.fmax_h(FT5, FT4, FT3);
    a.fsh(FT5, A0, 0);
    a.fsub_h(FT6, FT4, FT5);
    emit_libm_exp(a, FT6, FT6);
    a.li(A0, corr_addr as i64);
    a.fsh(FT6, A0, 0);

    // P = exp(S - m_new), scalar loop, sum in FS0
    a.li(A0, s_row as i64);
    a.li(A3, bk as i64);
    a.fmv_w_x(FS0, ZERO);
    let lp2 = a.label();
    a.bind(lp2);
    a.flh(FT4, A0, 0);
    a.fsub_h(FT4, FT4, FT5);
    emit_libm_exp(a, FT3, FT4);
    a.fsh(FT3, A0, 0);
    a.fadd_h(FS0, FS0, FT3);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp2);

    // l = l * corr + ps
    a.li(A0, l_addr as i64);
    a.flh(FT4, A0, 0);
    a.fmul_h(FT4, FT4, FT6);
    a.fadd_h(FT4, FT4, FS0);
    a.fsh(FT4, A0, 0);
}

fn emit_rescale_base(a: &mut Asm, o_row: u32, t_row: u32, corr_addr: u32, d: u32) {
    a.li(A0, corr_addr as i64);
    a.flh(FT7, A0, 0);
    a.li(A0, o_row as i64);
    a.li(A1, t_row as i64);
    a.li(A3, d as i64);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT3, A0, 0);
    a.fmul_h(FT3, FT3, FT7);
    a.flh(FT4, A1, 0);
    a.fadd_h(FT3, FT3, FT4);
    a.fsh(FT3, A0, 0);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);
}

/// Scalar `dst = src · w + add` walk (decode-merge accumulate, baseline
/// variant; `dst` may differ from `src`, unlike [`emit_rescale_base`]).
fn emit_scale_add_base(a: &mut Asm, src: u32, add: u32, dst: u32, w_addr: u32, d: u32) {
    a.li(A0, w_addr as i64);
    a.flh(FT7, A0, 0);
    a.li(A0, src as i64);
    a.li(A1, add as i64);
    a.li(A2, dst as i64);
    a.li(A3, d as i64);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT3, A0, 0);
    a.fmul_h(FT3, FT3, FT7);
    a.flh(FT4, A1, 0);
    a.fadd_h(FT3, FT3, FT4);
    a.fsh(FT3, A2, 0);
    a.addi(A0, A0, 2);
    a.addi(A1, A1, 2);
    a.addi(A2, A2, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);
}

fn emit_norm_base(a: &mut Asm, o_row: u32, l_addr: u32, d: u32) {
    a.li(A0, l_addr as i64);
    a.flh(FT5, A0, 0);
    a.li(A0, o_row as i64);
    a.li(A3, d as i64);
    let lp = a.label();
    a.bind(lp);
    a.flh(FT3, A0, 0);
    a.fdiv_h(FT3, FT3, FT5);
    a.fsh(FT3, A0, 0);
    a.addi(A0, A0, 2);
    a.addi(A3, A3, -1);
    a.bnez(A3, lp);
}

/// Host-side exact attention oracle (f32, with bf16 input quantization).
pub fn attention_ref(q: &[f32], k: &[f32], v: &[f32], sq: usize, sk: usize, d: usize) -> Vec<f32> {
    let qz = |x: f32| Bf16::from_f32(x).to_f32();
    let scale = 1.0 / (d as f32).sqrt();
    let mut out = vec![0.0f32; sq * d];
    for i in 0..sq {
        let mut s = vec![0.0f32; sk];
        for j in 0..sk {
            let mut acc = 0.0f32;
            for c in 0..d {
                acc += qz(q[i * d + c] * scale) * qz(k[j * d + c]);
            }
            s[j] = acc;
        }
        let m = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let e: Vec<f32> = s.iter().map(|&x| (x - m).exp()).collect();
        let l: f32 = e.iter().sum();
        for c in 0..d {
            let mut acc = 0.0f32;
            for j in 0..sk {
                acc += e[j] * qz(v[j * d + c]);
            }
            out[i * d + c] = acc / l;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: u64) -> Vec<f32> {
        let mut state = seed | 1;
        (0..rows * cols)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
                ((state >> 33) as f64 / 2f64.powi(31) * 2.0 - 1.0) as f32
            })
            .collect()
    }

    fn check(variant: FaVariant, sq: u32, sk: u32, d: u32, bk: u32, tol: f32) {
        let q = mat(sq as usize, d as usize, 1);
        let k = mat(sk as usize, d as usize, 2);
        let v = mat(sk as usize, d as usize, 3);
        let run = run_flash_attention(variant, &q, &k, &v, sq, sk, d, bk);
        let want = attention_ref(&q, &k, &v, sq as usize, sk as usize, d as usize);
        let mut max_err = 0.0f32;
        for (&got, &w) in run.out.iter().zip(&want) {
            max_err = max_err.max((got - w).abs());
        }
        assert!(max_err < tol, "{variant:?} max abs err {max_err}");
    }

    fn check_decode(variant: FaVariant, sk: u32, d: u32, bk: u32, tol: f32) {
        let q = mat(1, d as usize, 11);
        let k = mat(sk as usize, d as usize, 12);
        let v = mat(sk as usize, d as usize, 13);
        let run = run_flash_decode(variant, &q, &k, &v, sk, d, bk);
        let want = attention_ref(&q, &k, &v, 1, sk as usize, d as usize);
        let mut max_err = 0.0f32;
        for (&got, &w) in run.out.iter().zip(&want) {
            max_err = max_err.max((got - w).abs());
        }
        assert!(max_err < tol, "decode {variant:?} sk={sk} max abs err {max_err}");
    }

    #[test]
    fn optimized_matches_attention() {
        check(FaVariant::Optimized, 16, 64, 16, 32, 0.06);
    }

    #[test]
    fn cached_program_runs_on_seeded_spm() {
        // the exec-engine path: build once, seed data separately, run
        let (sq, sk, d, bk) = (16u32, 64, 64, 32);
        let program = build_fa_program(FaVariant::Optimized, sq, sk, d, bk);
        let clone = program.clone();
        assert!(program.shares_storage_with(&clone));
        let mut cluster = Cluster::new();
        seed_fa_inputs(&mut cluster.spm, sq, sk, d, bk, 99);
        let stats = cluster.run(clone.per_core());
        assert!(stats.cycles > 0);
        assert!(stats.combined().exp_ops > 0);
        // deterministic: a second run of the same handle costs the same
        let mut cluster2 = Cluster::new();
        seed_fa_inputs(&mut cluster2.spm, sq, sk, d, bk, 99);
        let stats2 = cluster2.run(program.per_core());
        assert_eq!(stats.cycles, stats2.cycles);
    }

    #[test]
    fn baseline_matches_attention() {
        check(FaVariant::Baseline, 16, 64, 16, 32, 0.06);
    }

    #[test]
    fn single_tile_equals_plain_softmax_attention() {
        check(FaVariant::Optimized, 8, 32, 16, 32, 0.06);
    }

    #[test]
    fn decode_matches_attention_single_query() {
        // 4 tiles over 4 active cores (split-KV), one merge
        check_decode(FaVariant::Optimized, 64, 16, 16, 0.08);
        check_decode(FaVariant::Baseline, 64, 16, 16, 0.08);
    }

    #[test]
    fn decode_handles_more_tiles_than_cores() {
        // 16 tiles over 8 cores: two tiles per core, running stats per core
        check_decode(FaVariant::Optimized, 256, 16, 16, 0.08);
    }

    #[test]
    fn decode_single_tile_degenerates_to_softmax_row() {
        // one tile → one active core, merge over a single partial
        check_decode(FaVariant::Optimized, 16, 16, 16, 0.08);
        check_decode(FaVariant::Baseline, 16, 16, 16, 0.08);
    }

    #[test]
    fn decode_gpt2_head_dim() {
        check_decode(FaVariant::Optimized, 128, 64, 16, 0.08);
    }

    #[test]
    fn decode_cached_program_runs_on_seeded_spm() {
        let (sk, d, bk) = (128u32, 64u32, 16u32);
        let program = build_fa_decode_program(FaVariant::Optimized, sk, d, bk);
        assert!(program.active_cores() == 8, "8 tiles over 8 cores");
        let mut cluster = Cluster::new();
        seed_fa_decode_inputs(&mut cluster.spm, sk, d, bk, 7);
        let stats = cluster.run_program(&program);
        assert!(stats.cycles > 0);
        assert!(stats.combined().exp_ops > 0, "VFEXP partial softmax ran");
        // deterministic repetition — the steady-state scaling contract
        let mut cluster2 = Cluster::new();
        seed_fa_decode_inputs(&mut cluster2.spm, sk, d, bk, 7);
        let stats2 = cluster2.run_program(&program);
        assert_eq!(stats.cycles, stats2.cycles);
    }

    #[test]
    fn decode_optimized_beats_baseline() {
        let (sk, d, bk) = (128u32, 64u32, 16u32);
        let q = mat(1, d as usize, 21);
        let k = mat(sk as usize, d as usize, 22);
        let v = mat(sk as usize, d as usize, 23);
        let base = run_flash_decode(FaVariant::Baseline, &q, &k, &v, sk, d, bk);
        let opt = run_flash_decode(FaVariant::Optimized, &q, &k, &v, sk, d, bk);
        let speedup = base.stats.cycles as f64 / opt.stats.cycles as f64;
        assert!(speedup > 2.0, "decode speedup {speedup:.2}x");
    }

    #[test]
    fn decode_footprint_matches_layout() {
        for (sk, d, bk) in [(64u32, 16u32, 16u32), (256, 64, 16), (128, 128, 16)] {
            let lay = FaDecodeLayout::new(sk, d, bk);
            assert_eq!(fa_decode_footprint(sk, d, bk), lay.end);
            assert!(lay.out_addr() < lay.end);
        }
    }

    #[test]
    fn optimized_speedup_matches_fig6d() {
        // GPT-2 head dim 64; paper: up to 8.2x FA-2 throughput gain
        let (sq, sk, d, bk) = (32u32, 128u32, 64u32, 32u32);
        let q = mat(sq as usize, d as usize, 4);
        let k = mat(sk as usize, d as usize, 5);
        let v = mat(sk as usize, d as usize, 6);
        let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
        let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
        let speedup = base.stats.cycles as f64 / opt.stats.cycles as f64;
        assert!(
            (2.0..20.0).contains(&speedup),
            "FA-2 speedup {speedup:.2}x (paper: up to 8.2x)"
        );
    }

    #[test]
    fn softmax_share_shrinks_when_optimized() {
        // Fig. 6e: softmax dominates the baseline, ~6% when optimized.
        // Proxy: exp-class instructions exist only in the optimized
        // variant; the baseline burns its cycles in FP64 libm code.
        let (sq, sk, d, bk) = (16u32, 64u32, 64u32, 32u32);
        let q = mat(sq as usize, d as usize, 7);
        let k = mat(sk as usize, d as usize, 8);
        let v = mat(sk as usize, d as usize, 9);
        let base = run_flash_attention(FaVariant::Baseline, &q, &k, &v, sq, sk, d, bk);
        let opt = run_flash_attention(FaVariant::Optimized, &q, &k, &v, sq, sk, d, bk);
        let base_c = base.stats.combined();
        let opt_c = opt.stats.combined();
        use crate::isa::Class;
        // baseline: huge FP64 share from libm
        assert!(base_c.count(Class::FpScalarD) > 10 * opt_c.count(Class::FpScalarD));
        // optimized: hardware exponentials
        assert!(opt_c.exp_ops > 0 && base_c.exp_ops == 0);
    }
}
