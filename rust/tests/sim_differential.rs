//! Differential tests: the decoded micro-op fast path must be
//! bit-identical to the reference `Instr` interpreter — same cycles,
//! same retired-instruction counters, same FLOPs/EXPs/SSR beats/memory
//! traffic, and byte-identical SPM contents — for every kernel the crate
//! ships, and at system level for multi-cluster jobs.

use vexp::coordinator::{DecodePlan, TilePlan};
use vexp::exec::batch::CalShape;
use vexp::exec::program::Program;
use vexp::kernels::flash_attention::{
    build_fa_decode_program, build_fa_program, seed_fa_decode_inputs, seed_fa_inputs, FaVariant,
};
use vexp::kernels::gelu::{build_gelu_program, seed_gelu_inputs, GeluForm, GeluVariant};
use vexp::kernels::gemm::build_gemm_program;
use vexp::kernels::layernorm::{build_layernorm_program, seed_layernorm_inputs, LayerNormVariant};
use vexp::kernels::softmax::{
    build_softmax_bwd_program, build_softmax_program, seed_softmax_bwd_inputs,
    seed_softmax_inputs, SoftmaxBwdVariant, SoftmaxVariant,
};
use vexp::model::config::{ALL_MODELS, GPT2_SMALL, GPT3_XL};
use vexp::sim::stats::CLASSES;
use vexp::sim::{
    shared_memo, Cluster, ClusterJob, ClusterStats, CoreStats, Mem, SamplePolicy, System,
};
use vexp::testkit::forall;

fn assert_core_stats_eq(reference: &CoreStats, fast: &CoreStats, what: &str) {
    assert_eq!(reference.cycles, fast.cycles, "{what}: cycles");
    assert_eq!(reference.flops, fast.flops, "{what}: flops");
    assert_eq!(reference.mem_bytes, fast.mem_bytes, "{what}: mem_bytes");
    assert_eq!(reference.exp_ops, fast.exp_ops, "{what}: exp_ops");
    assert_eq!(reference.ssr_beats, fast.ssr_beats, "{what}: ssr_beats");
    for c in CLASSES {
        assert_eq!(reference.count(c), fast.count(c), "{what}: retired {c:?}");
    }
}

fn assert_cluster_stats_eq(reference: &ClusterStats, fast: &ClusterStats, what: &str) {
    assert_eq!(reference.cycles, fast.cycles, "{what}: cluster cycles");
    assert_eq!(reference.dma_bytes, fast.dma_bytes, "{what}: dma_bytes");
    assert_eq!(reference.dma_cycles, fast.dma_cycles, "{what}: dma_cycles");
    assert_eq!(reference.per_core.len(), fast.per_core.len(), "{what}: core count");
    for (i, (r, f)) in reference.per_core.iter().zip(&fast.per_core).enumerate() {
        assert_core_stats_eq(r, f, &format!("{what} core {i}"));
    }
}

fn assert_mem_eq(reference: &Mem, fast: &Mem, what: &str) {
    assert_eq!(
        reference.read_bytes(0, reference.len()),
        fast.read_bytes(0, fast.len()),
        "{what}: SPM contents diverge"
    );
}

/// Run `program` on two identically-seeded clusters, once per executor,
/// and require bit-identical stats and memory.
fn differential_cluster(program: &Program, seed: impl Fn(&mut Mem), what: &str) {
    let mut reference = Cluster::new();
    seed(&mut reference.spm);
    let mut fast = Cluster::new();
    seed(&mut fast.spm);
    let r = reference.run(program.per_core());
    let f = fast.run_decoded(program.decoded());
    assert_cluster_stats_eq(&r, &f, what);
    assert_mem_eq(&reference.spm, &fast.spm, what);
}

#[test]
fn softmax_all_variants_two_lengths_bit_identical() {
    const ROWS: u32 = 8;
    for variant in [
        SoftmaxVariant::Baseline,
        SoftmaxVariant::SwOptim,
        SoftmaxVariant::SwExpSw,
        SoftmaxVariant::SwExpHw,
    ] {
        for n in [64u32, 1024] {
            let program = build_softmax_program(variant, ROWS, n);
            differential_cluster(
                &program,
                |spm| seed_softmax_inputs(spm, ROWS, n, 0xD1FF ^ n as u64),
                &format!("softmax {variant:?} n={n}"),
            );
        }
    }
}

#[test]
fn softmax_scalar_fexp_ablation_bit_identical() {
    let program = build_softmax_program(SoftmaxVariant::SwExpHwScalar, 8, 128);
    differential_cluster(
        &program,
        |spm| seed_softmax_inputs(spm, 8, 128, 0xAB1A),
        "softmax SwExpHwScalar n=128",
    );
}

/// The Horner-6 polynomial-exp ablation variant (ISSUE 8: the accurate
/// end of the software speed/accuracy frontier) holds the same contract
/// as the shipped softmax variants.
#[test]
fn softmax_sw_exp_horner_bit_identical() {
    for n in [64u32, 256] {
        let program = build_softmax_program(SoftmaxVariant::SwExpHorner, 8, n);
        differential_cluster(
            &program,
            |spm| seed_softmax_inputs(spm, 8, n, 0x60E ^ n as u64),
            &format!("softmax SwExpHorner n={n}"),
        );
    }
}

/// Every GELU variant on the speed/accuracy frontier — three exp
/// technologies x three functional forms — must be bit-identical on the
/// decoded fast path before the accuracy wall can trust either executor.
#[test]
fn gelu_all_variants_bit_identical() {
    const ROWS: u32 = 4;
    for variant in GeluVariant::ALL {
        for n in [64u32, 256] {
            let program = build_gelu_program(variant, ROWS, n);
            differential_cluster(
                &program,
                |spm| seed_gelu_inputs(spm, ROWS, n, 0x6E1 ^ n as u64),
                &format!("gelu {variant:?} n={n}"),
            );
        }
    }
}

#[test]
fn layernorm_both_variants_two_lengths_bit_identical() {
    const ROWS: u32 = 8;
    for variant in LayerNormVariant::ALL {
        for n in [64u32, 512] {
            let program = build_layernorm_program(variant, ROWS, n);
            differential_cluster(
                &program,
                |spm| seed_layernorm_inputs(spm, ROWS, n, 0x1A ^ n as u64),
                &format!("layernorm {variant:?} n={n}"),
            );
        }
    }
}

#[test]
fn softmax_bwd_both_variants_two_lengths_bit_identical() {
    const ROWS: u32 = 8;
    for variant in SoftmaxBwdVariant::ALL {
        for n in [64u32, 256] {
            let program = build_softmax_bwd_program(variant, ROWS, n);
            differential_cluster(
                &program,
                |spm| seed_softmax_bwd_inputs(spm, ROWS, n, 0xB4D ^ n as u64),
                &format!("softmax-bwd {variant:?} n={n}"),
            );
        }
    }
}

#[test]
fn flash_attention_both_variants_two_lengths_bit_identical() {
    for variant in [FaVariant::Baseline, FaVariant::Optimized] {
        for (sq, sk, d, bk) in [(16u32, 64u32, 64u32, 32u32), (32, 128, 64, 32)] {
            let program = build_fa_program(variant, sq, sk, d, bk);
            differential_cluster(
                &program,
                |spm| seed_fa_inputs(spm, sq, sk, d, bk, 0xFA ^ sk as u64),
                &format!("fa {variant:?} sq={sq} sk={sk}"),
            );
        }
    }
}

/// The single-query decode slice (split-KV + merge, DESIGN.md §10) must
/// hold the same bit-identity contract as every other shipped kernel —
/// the acceptance gate for running it on the fast path in serving.
#[test]
fn flash_decode_both_variants_two_windows_bit_identical() {
    for variant in [FaVariant::Baseline, FaVariant::Optimized] {
        for (sk, d, bk) in [(64u32, 64u32, 16u32), (256, 64, 16)] {
            let program = build_fa_decode_program(variant, sk, d, bk);
            differential_cluster(
                &program,
                |spm| seed_fa_decode_inputs(spm, sk, d, bk, 0xDEC ^ sk as u64),
                &format!("fa-decode {variant:?} sk={sk}"),
            );
        }
    }
}

#[test]
fn gemm_bit_identical() {
    let (lay, program) = build_gemm_program(32, 64, 32);
    differential_cluster(
        &program,
        |spm| {
            let a: Vec<f32> = (0..32 * 64).map(|i| ((i * 7) % 83) as f32 * 0.02 - 0.8).collect();
            let bt: Vec<f32> = (0..32 * 64).map(|i| ((i * 5) % 71) as f32 * 0.02 - 0.7).collect();
            spm.write_f32_as_bf16(lay.a, &a);
            spm.write_f32_as_bf16(lay.bt, &bt);
        },
        "gemm 32x64x32",
    );
}

/// System level: threaded fast path vs serial reference interpreter must
/// produce bit-identical `SystemStats` (cycles, per-cluster stats,
/// flops, mem_bytes) and identical SPM contents on every cluster.
#[test]
fn system_run_jobs_bit_identical_across_paths() {
    let jobs = || -> Vec<ClusterJob> {
        let sm = build_softmax_program(SoftmaxVariant::SwExpHw, 8, 256);
        let base = build_softmax_program(SoftmaxVariant::Baseline, 8, 64);
        let fa = build_fa_program(FaVariant::Optimized, 16, 64, 64, 32);
        let gelu = build_gelu_program(GeluVariant::Hw(GeluForm::Tanh), 4, 128);
        let ln = build_layernorm_program(LayerNormVariant::Optimized, 8, 128);
        let bwd = build_softmax_bwd_program(SoftmaxBwdVariant::Optimized, 8, 128);
        vec![
            ClusterJob::new(vec![sm.clone(), sm.clone()], 64 * 1024),
            ClusterJob::new(vec![base], 16 * 1024),
            ClusterJob::idle(),
            ClusterJob::new(vec![fa], 128 * 1024),
            ClusterJob::new(vec![gelu, ln], 32 * 1024),
            ClusterJob::new(vec![bwd], 32 * 1024),
        ]
    };
    let seed_sys = |sys: &mut System| {
        seed_softmax_inputs(&mut sys.clusters[0].spm, 8, 256, 1);
        seed_softmax_inputs(&mut sys.clusters[1].spm, 8, 64, 2);
        seed_fa_inputs(&mut sys.clusters[3].spm, 16, 64, 64, 32, 3);
        // the gelu and layernorm programs on cluster 4 share the input
        // region; the gelu seeder also writes the exp constant pool
        seed_gelu_inputs(&mut sys.clusters[4].spm, 8, 128, 4);
        seed_softmax_bwd_inputs(&mut sys.clusters[5].spm, 8, 128, 5);
    };

    let mut fast_sys = System::new(6);
    fast_sys.reference_interp = false;
    seed_sys(&mut fast_sys);
    let fast = fast_sys.run_jobs(jobs());

    let mut ref_sys = System::new(6);
    ref_sys.reference_interp = true;
    seed_sys(&mut ref_sys);
    let reference = ref_sys.run_jobs(jobs());

    assert_eq!(reference.cycles, fast.cycles, "system makespan");
    assert_eq!(reference.hbm_bytes, fast.hbm_bytes);
    assert_eq!(reference.per_cluster.len(), fast.per_cluster.len());
    for (i, (r, f)) in reference.per_cluster.iter().zip(&fast.per_cluster).enumerate() {
        assert_cluster_stats_eq(r, f, &format!("cluster {i}"));
        let rc = r.combined();
        let fc = f.combined();
        assert_eq!(rc.flops, fc.flops, "cluster {i} flops");
        assert_eq!(rc.mem_bytes, fc.mem_bytes, "cluster {i} mem_bytes");
    }
    for (i, (rc, fc)) in ref_sys.clusters.iter().zip(&fast_sys.clusters).enumerate() {
        assert_mem_eq(&rc.spm, &fc.spm, &format!("cluster {i}"));
    }
}

/// Run `program` through the tile memo twice (a recording miss, then a
/// replaying hit) and through the plain fast path, all on identically
/// seeded clusters: stats and SPM bytes must be bit-identical across
/// the three, and the hit/miss counters must prove the second memoized
/// run actually replayed instead of re-executing.
fn differential_memo(program: &Program, seed: impl Fn(&mut Mem), what: &str) {
    let mut plain = Cluster::new();
    seed(&mut plain.spm);
    let p = plain.run_decoded_memo(program, None);

    let memo = shared_memo();
    let mut first = Cluster::new();
    seed(&mut first.spm);
    let f1 = first.run_decoded_memo(program, Some(&memo));
    let mut second = Cluster::new();
    seed(&mut second.spm);
    let f2 = second.run_decoded_memo(program, Some(&memo));

    assert_cluster_stats_eq(&p, &f1, &format!("{what} (memo miss)"));
    assert_cluster_stats_eq(&p, &f2, &format!("{what} (memo hit)"));
    assert_mem_eq(&plain.spm, &first.spm, &format!("{what} (memo miss)"));
    assert_mem_eq(&plain.spm, &second.spm, &format!("{what} (memo hit)"));
    let m = memo.lock().unwrap();
    assert_eq!(m.misses, 1, "{what}: first run must record");
    assert_eq!(m.hits, 1, "{what}: second run must replay");
}

/// Memo-on vs memo-off must be bit-identical — stats *and* SPM bytes —
/// for every kernel the crate ships (ISSUE 6 satellite: the raw-speed
/// tier's correctness gate).
#[test]
fn memo_replay_bit_identical_all_kernels() {
    const N: u32 = 128;
    for variant in SoftmaxVariant::ALL {
        let program = build_softmax_program(variant, 8, N);
        differential_memo(
            &program,
            |spm| seed_softmax_inputs(spm, 8, N, 0x3E30 ^ N as u64),
            &format!("memo softmax {variant:?}"),
        );
    }
    let program = build_softmax_program(SoftmaxVariant::SwExpHwScalar, 8, 64);
    differential_memo(
        &program,
        |spm| seed_softmax_inputs(spm, 8, 64, 0x3E3A),
        "memo softmax SwExpHwScalar",
    );
    for variant in [FaVariant::Baseline, FaVariant::Optimized] {
        let program = build_fa_program(variant, 16, 64, 64, 32);
        differential_memo(
            &program,
            |spm| seed_fa_inputs(spm, 16, 64, 64, 32, 0x3E31),
            &format!("memo fa {variant:?}"),
        );
    }
    for variant in [FaVariant::Baseline, FaVariant::Optimized] {
        let program = build_fa_decode_program(variant, 64, 64, 16);
        differential_memo(
            &program,
            |spm| seed_fa_decode_inputs(spm, 64, 64, 16, 0x3E32),
            &format!("memo fa-decode {variant:?}"),
        );
    }
    let (lay, program) = build_gemm_program(32, 64, 32);
    differential_memo(
        &program,
        |spm| {
            let a: Vec<f32> = (0..32 * 64).map(|i| ((i * 7) % 83) as f32 * 0.02 - 0.8).collect();
            let bt: Vec<f32> = (0..32 * 64).map(|i| ((i * 5) % 71) as f32 * 0.02 - 0.7).collect();
            spm.write_f32_as_bf16(lay.a, &a);
            spm.write_f32_as_bf16(lay.bt, &bt);
        },
        "memo gemm",
    );
    let program = build_softmax_program(SoftmaxVariant::SwExpHorner, 8, 64);
    differential_memo(
        &program,
        |spm| seed_softmax_inputs(spm, 8, 64, 0x3E33),
        "memo softmax SwExpHorner",
    );
    for variant in [GeluVariant::Hw(GeluForm::Tanh), GeluVariant::Sw(GeluForm::Silu)] {
        let program = build_gelu_program(variant, 4, 64);
        differential_memo(
            &program,
            |spm| seed_gelu_inputs(spm, 4, 64, 0x3E34),
            &format!("memo gelu {variant:?}"),
        );
    }
    for variant in LayerNormVariant::ALL {
        let program = build_layernorm_program(variant, 8, 64);
        differential_memo(
            &program,
            |spm| seed_layernorm_inputs(spm, 8, 64, 0x3E35),
            &format!("memo layernorm {variant:?}"),
        );
    }
    for variant in SoftmaxBwdVariant::ALL {
        let program = build_softmax_bwd_program(variant, 8, 64);
        differential_memo(
            &program,
            |spm| seed_softmax_bwd_inputs(spm, 8, 64, 0x3E36),
            &format!("memo softmax-bwd {variant:?}"),
        );
    }
}

/// The memo key is (program identity, tile *values*): the same program
/// over different input bytes must miss and recompute correctly, and a
/// rebuilt (not cache-cloned) program must not alias a recorded entry.
#[test]
fn memo_invalidates_on_values_and_program_identity() {
    let program = build_softmax_program(SoftmaxVariant::SwExpHw, 8, 64);
    let memo = shared_memo();
    let mut a = Cluster::new();
    seed_softmax_inputs(&mut a.spm, 8, 64, 111);
    let ra = a.run_decoded_memo(&program, Some(&memo));

    // same program, different tile values: miss, and the recompute is
    // exactly the unmemoized result
    let mut b = Cluster::new();
    seed_softmax_inputs(&mut b.spm, 8, 64, 222);
    let rb = b.run_decoded_memo(&program, Some(&memo));
    {
        let m = memo.lock().unwrap();
        assert_eq!(m.hits, 0, "different values must not replay");
        assert_eq!(m.misses, 2);
    }
    let mut b2 = Cluster::new();
    seed_softmax_inputs(&mut b2.spm, 8, 64, 222);
    let rb2 = b2.run_decoded_memo(&program, None);
    assert_cluster_stats_eq(&rb2, &rb, "memo value invalidation");
    assert_mem_eq(&b2.spm, &b.spm, "memo value invalidation");

    // a rebuilt program is a different tile even over identical bytes
    let rebuilt = build_softmax_program(SoftmaxVariant::SwExpHw, 8, 64);
    let mut c = Cluster::new();
    seed_softmax_inputs(&mut c.spm, 8, 64, 111);
    let rc = c.run_decoded_memo(&rebuilt, Some(&memo));
    assert_cluster_stats_eq(&ra, &rc, "rebuilt program identity");
    assert_eq!(memo.lock().unwrap().hits, 0, "pointer-identity keys must not alias");
}

/// Run a repeated job fully simulated and sampled (identical seeding)
/// and check sampled mode's contract: the clock differs from the fully
/// simulated fast path by at most the bound it reports, and counters
/// extrapolate exactly for cycle-identical repetitions.
fn check_sampled_bound(
    program: &Program,
    seed: &dyn Fn(&mut Mem),
    reps: u64,
    policy: SamplePolicy,
    what: &str,
) -> Result<(), String> {
    let mut full_sys = System::new(1);
    seed(&mut full_sys.clusters[0].spm);
    let full = full_sys.run_jobs(vec![ClusterJob::repeated(program.clone(), reps, 0)]);
    if full.error_bound_cycles != 0 {
        return Err(format!("{what}: full run reported a nonzero bound"));
    }

    let mut s_sys = System::new(1);
    s_sys.sampling = Some(policy);
    seed(&mut s_sys.clusters[0].spm);
    let sampled = s_sys.run_jobs(vec![ClusterJob::repeated(program.clone(), reps, 0)]);

    let diff = sampled.cycles.abs_diff(full.cycles);
    let bound = sampled.error_bound_cycles;
    if diff > bound {
        return Err(format!("{what}: cycle diff {diff} exceeds reported bound {bound}"));
    }
    if sampled.per_cluster[0].sampled_reps > 0 && bound == 0 {
        return Err(format!("{what}: skipped repetitions but claimed a zero bound"));
    }
    let fr = full.per_cluster[0].combined().retired_total();
    let sr = sampled.per_cluster[0].combined().retired_total();
    if fr != sr {
        return Err(format!("{what}: retired {sr} vs fully simulated {fr}"));
    }
    Ok(())
}

/// Property: the sampled-simulation error bound is honored on every
/// fig6 configuration (softmax variants, FlashAttention slices) and
/// every fig8 configuration (each model's prefill slice and the GPT
/// decode slices), across randomized repetition counts and policies.
#[test]
fn sampled_bound_holds_on_fig6_and_fig8_configs() {
    type Seeder = Box<dyn Fn(&mut Mem)>;
    let mut configs: Vec<(Program, Seeder, String)> = Vec::new();

    // fig6: the four softmax kernels + both FA variants
    for variant in SoftmaxVariant::ALL {
        let program = build_softmax_program(variant, 8, 64);
        configs.push((
            program,
            Box::new(|spm| seed_softmax_inputs(spm, 8, 64, 0x516)),
            format!("fig6 softmax {variant:?}"),
        ));
    }
    for variant in [FaVariant::Baseline, FaVariant::Optimized] {
        let program = build_fa_program(variant, 16, 64, 64, 32);
        configs.push((
            program,
            Box::new(|spm| seed_fa_inputs(spm, 16, 64, 64, 32, 0x517)),
            format!("fig6 fa {variant:?}"),
        ));
    }
    // fig8: each model's prefill calibration slice…
    for cfg in ALL_MODELS {
        let plan = TilePlan::plan(&cfg);
        let cal = CalShape::for_plan(&plan);
        let program = build_fa_program(FaVariant::Optimized, cal.sq, cal.sk, cal.d, cal.bk);
        configs.push((
            program,
            Box::new(move |spm| {
                seed_fa_inputs(spm, cal.sq, cal.sk, cal.d, cal.bk, 0x518)
            }),
            format!("fig8 prefill slice {}", cfg.name),
        ));
    }
    // …and the autoregressive models' decode slices
    for cfg in [GPT2_SMALL, GPT3_XL] {
        let plan = DecodePlan::plan(&cfg);
        let cal = CalShape::for_decode(&plan);
        let program = build_fa_decode_program(FaVariant::Optimized, cal.sk, cal.d, cal.bk);
        configs.push((
            program,
            Box::new(move |spm| seed_fa_decode_inputs(spm, cal.sk, cal.d, cal.bk, 0x519)),
            format!("fig8 decode slice {}", cfg.name),
        ));
    }

    forall(3, |rng| {
        let policy = SamplePolicy {
            warmup: rng.range(1, 4) as u32,
            stride: rng.range(2, 8) as u32,
            max_samples: rng.range(2, 6) as u32,
        };
        let reps = rng.range(policy.warmup as u64 + 2, 24);
        for (program, seed, what) in &configs {
            check_sampled_bound(program, seed.as_ref(), reps, policy, what)?;
        }
        Ok(())
    });
}

/// The fast path must stay deterministic run-to-run (threads only
/// parallelize clusters; merge order is fixed).
#[test]
fn fast_path_is_deterministic() {
    let run_once = || {
        let mut sys = System::new(3);
        for c in 0..3 {
            seed_softmax_inputs(&mut sys.clusters[c].spm, 8, 128, c as u64);
        }
        let sm = build_softmax_program(SoftmaxVariant::SwExpHw, 8, 128);
        sys.run_jobs(vec![
            ClusterJob::new(vec![sm.clone()], 1000),
            ClusterJob::new(vec![sm.clone()], 2000),
            ClusterJob::new(vec![sm], 3000),
        ])
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.hbm_bytes, b.hbm_bytes);
    for (x, y) in a.per_cluster.iter().zip(&b.per_cluster) {
        assert_eq!(x.cycles, y.cycles);
        assert_eq!(x.combined().retired_total(), y.combined().retired_total());
    }
}
