//! Integration wall for the paged KV-cache subsystem (DESIGN.md §14).
//!
//! Four layers of evidence, mirroring the tier-1 differential style of
//! the kernel tests:
//!
//! 1. **Pool books** — property test: under random alloc / append /
//!    release / evict / fork traffic the refcounted block pool never
//!    double-frees, never leaks, and its three state populations always
//!    tile the capacity exactly.
//! 2. **Prefix index vs naive oracle** — the radix tree's lookup /
//!    first-insert-wins / subtree-prune semantics match a brute-force
//!    prefix-map reference on random chunk paths.
//! 3. **Giant-block bit-identity** — serving a trace through the paged
//!    tier with one effectively unbounded block is bit-identical to the
//!    legacy unpaged loop (cycles, SPM checksums, per-request books) on
//!    BOTH simulator paths (decoded fast path and reference
//!    interpreter). The legacy loop is the differential oracle.
//! 4. **Pressure semantics** — a tight pool forces real preemptions
//!    that resume and complete with the same token books as an
//!    unbounded run, and a shared-prefix burst trace shows nonzero
//!    evictions and nonzero prefix-hit savings with per-policy SLO
//!    attainment.

use vexp::exec::{
    AnalyticBackend, AppendNeed, BlockPool, BlockTable, CycleSimBackend, Engine, Outcome,
    PagedKvOptions, PrefixIndex, Request, SchedPolicy, ServeOptions, ServeReport, TraceSpec,
};
use vexp::model::GPT2_SMALL;
use vexp::sim::spm_checksum;
use vexp::testkit::{forall, Rng};

// ---------------------------------------------------------------------------
// 1. block-pool books under random traffic
// ---------------------------------------------------------------------------

/// Drive a pool with random table traffic, checking the books after
/// every single operation. Each table owns exactly one reference per
/// entry of its block vector, so releasing each entry once at teardown
/// must balance the books to the empty-pool state.
#[test]
fn pool_books_balance_under_random_alloc_release_evict_fork() {
    forall(60, |rng| {
        let cap = rng.range(2, 12) as usize;
        let block_tokens = rng.range(1, 6) as u32;
        let mut pool = BlockPool::new(cap);
        let mut tables: Vec<BlockTable> = Vec::new();

        let steps = rng.range(30, 150);
        for _ in 0..steps {
            match rng.range(0, 100) {
                // start a new table with one freshly allocated block
                0..=24 => {
                    if let Some(id) = pool.try_alloc() {
                        let mut t = BlockTable::new(block_tokens);
                        pool.push_tail(&mut t, id);
                        tables.push(t);
                    }
                }
                // append one token to a random table, honoring the
                // pool's own append classification
                25..=54 => {
                    if !tables.is_empty() {
                        let i = rng.range(0, tables.len() as u64) as usize;
                        match pool.append_need(&tables[i]) {
                            AppendNeed::InPlace => pool.append_in_place(&mut tables[i]),
                            AppendNeed::NewBlock => {
                                if let Some(id) = pool.try_alloc() {
                                    pool.push_tail(&mut tables[i], id);
                                }
                            }
                            AppendNeed::CopyOnWrite => {
                                if let Some(id) = pool.try_alloc() {
                                    pool.cow_tail(&mut tables[i], id, rng.bool());
                                }
                            }
                        }
                    }
                }
                // drop a random table, releasing each block exactly once
                55..=74 => {
                    if !tables.is_empty() {
                        let i = rng.range(0, tables.len() as u64) as usize;
                        let t = tables.swap_remove(i);
                        let cacheable = rng.bool();
                        for &b in &t.blocks {
                            pool.release(b, cacheable);
                        }
                    }
                }
                // reclaim the LRU cached block (may be a no-op)
                75..=84 => {
                    let _ = pool.evict_lru();
                }
                // fork a random table (refcounts rise, no allocation)
                _ => {
                    if !tables.is_empty() {
                        let i = rng.range(0, tables.len() as u64) as usize;
                        let forked = pool.fork(&tables[i]);
                        tables.push(forked);
                    }
                }
            }

            pool.assert_books();
            for t in &tables {
                for &b in &t.blocks {
                    if pool.refs(b) == 0 {
                        return Err(format!("live table references zero-ref block {b}"));
                    }
                }
            }
        }

        // teardown: drop every table, then drain the cached list; the
        // pool must return to its pristine all-free state with
        // perfectly balanced lifetime counters.
        for t in tables.drain(..) {
            for &b in &t.blocks {
                pool.release(b, false);
            }
        }
        while pool.evict_lru().is_some() {}
        pool.assert_books();
        if pool.free_count() != cap {
            return Err(format!("teardown left {} of {cap} blocks free", pool.free_count()));
        }
        if pool.stats.allocated != pool.stats.freed {
            return Err(format!(
                "lifetime books unbalanced: {} allocated vs {} freed",
                pool.stats.allocated, pool.stats.freed
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 2. prefix index vs a naive prefix-map oracle
// ---------------------------------------------------------------------------

/// Brute-force reference for [`PrefixIndex`]: a map from every chunk
/// path-prefix to its canonical block. Insert registers all prefixes
/// first-insert-wins; remove deletes every path with a prefix homed on
/// the removed block (the subtree); lookup walks prefixes in order.
#[derive(Default)]
struct NaivePrefix {
    paths: std::collections::HashMap<Vec<u64>, u32>,
}

impl NaivePrefix {
    fn insert(&mut self, fps: &[u64], blocks: &[u32]) -> Vec<u32> {
        let mut canonical = Vec::with_capacity(fps.len());
        for i in 1..=fps.len() {
            let entry = self.paths.entry(fps[..i].to_vec()).or_insert(blocks[i - 1]);
            canonical.push(*entry);
        }
        canonical
    }

    fn lookup(&self, fps: &[u64]) -> Vec<u32> {
        let mut out = Vec::new();
        for i in 1..=fps.len() {
            match self.paths.get(&fps[..i]) {
                Some(&b) => out.push(b),
                None => break,
            }
        }
        out
    }

    fn remove_block(&mut self, block: u32) {
        // O(n^2): a path dies if ANY of its prefixes is homed on `block`
        let doomed: Vec<Vec<u64>> = self
            .paths
            .keys()
            .filter(|path| {
                (1..=path.len()).any(|j| self.paths.get(&path[..j]) == Some(&block))
            })
            .cloned()
            .collect();
        for path in doomed {
            self.paths.remove(&path);
        }
    }

    fn contains_block(&self, block: u32) -> bool {
        self.paths.values().any(|&b| b == block)
    }
}

#[test]
fn prefix_index_matches_the_naive_oracle_on_random_paths() {
    forall(80, |rng| {
        let mut idx = PrefixIndex::new();
        let mut oracle = NaivePrefix::default();
        let mut next_block: u32 = 0;

        // small fingerprint alphabet to force heavy path sharing
        let rand_path = |rng: &mut Rng| -> Vec<u64> {
            let len = rng.range(1, 5) as usize;
            (0..len).map(|_| rng.range(0, 5)).collect()
        };

        for _ in 0..rng.range(20, 80) {
            match rng.range(0, 10) {
                // insert a random path with fresh blocks
                0..=5 => {
                    let fps = rand_path(rng);
                    let blocks: Vec<u32> =
                        (0..fps.len()).map(|_| { next_block += 1; next_block }).collect();
                    let got = idx.insert(&fps, &blocks);
                    let want = oracle.insert(&fps, &blocks);
                    if got != want {
                        return Err(format!("insert canonical {got:?} != oracle {want:?}"));
                    }
                }
                // remove a (possibly absent) block, pruning its subtree
                6..=7 => {
                    let b = rng.range(0, (next_block as u64).max(1)) as u32;
                    idx.remove_block(b);
                    oracle.remove_block(b);
                }
                // probe lookup on a random path
                _ => {
                    let fps = rand_path(rng);
                    let got = idx.lookup(&fps);
                    let want = oracle.lookup(&fps);
                    if got != want {
                        return Err(format!("lookup({fps:?}) {got:?} != oracle {want:?}"));
                    }
                }
            }

            if idx.len() != oracle.paths.len() {
                return Err(format!(
                    "node count {} != oracle path count {}",
                    idx.len(),
                    oracle.paths.len()
                ));
            }
            let probe = rng.range(0, (next_block as u64).max(1)) as u32;
            if idx.contains_block(probe) != oracle.contains_block(probe) {
                return Err(format!("contains_block({probe}) disagrees with oracle"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 3. giant-block differential: paged tier vs legacy unpaged loop
// ---------------------------------------------------------------------------

/// Serve the same mixed burst trace through `Engine::serve`, with or
/// without paging, on the cycle simulator, and hand back the report
/// plus every cluster's SPM checksum.
fn serve_burst_trace(
    paging: Option<PagedKvOptions>,
    reference: bool,
) -> (ServeReport, Vec<u64>) {
    let spec = TraceSpec::bursty(6, 40_000.0, 5);
    let mut engine = Engine::with_clusters(4);
    for r in spec.mixed_traffic(32, 3, None) {
        engine.submit_request(r);
    }
    let mut backend = CycleSimBackend::new(4);
    backend.system.reference_interp = reference;
    let opts = ServeOptions { max_iters: 256, paging, ..ServeOptions::default() };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();
    let sums = backend.system.clusters.iter().map(|c| spm_checksum(&c.spm)).collect();
    (report, sums)
}

/// With one effectively unbounded block per table, the paged tier must
/// reduce *bit-exactly* to the legacy loop: same iteration count, same
/// total cycles, same SPM bytes, same per-request books — on both the
/// decoded fast path and the reference interpreter. The legacy loop is
/// the subsystem's differential oracle.
#[test]
fn giant_block_paged_serve_is_bit_identical_to_legacy_on_both_sim_paths() {
    for reference in [false, true] {
        let (legacy, legacy_sums) = serve_burst_trace(None, reference);
        let (paged, paged_sums) =
            serve_burst_trace(Some(PagedKvOptions::unbounded()), reference);

        assert!(legacy.pool.is_none(), "legacy run must not carry a pool report");
        assert_eq!(
            legacy.iterations, paged.iterations,
            "iteration count diverged (reference_interp={reference})"
        );
        assert_eq!(
            legacy.total_cycles, paged.total_cycles,
            "total cycles diverged (reference_interp={reference})"
        );
        assert_eq!(legacy_sums, paged_sums, "SPM bytes diverged (reference_interp={reference})");

        assert_eq!(legacy.per_request.len(), paged.per_request.len());
        for (l, p) in legacy.per_request.iter().zip(&paged.per_request) {
            assert_eq!(l.request_id, p.request_id);
            assert_eq!(l.outcome, p.outcome, "request {} outcome", l.request_id);
            assert_eq!(l.tokens, p.tokens, "request {} tokens", l.request_id);
            assert_eq!(
                l.cycles.to_bits(),
                p.cycles.to_bits(),
                "request {} cycles diverged bitwise",
                l.request_id
            );
            assert_eq!(
                l.ttft_cycles.to_bits(),
                p.ttft_cycles.to_bits(),
                "request {} TTFT diverged bitwise",
                l.request_id
            );
            assert_eq!(
                l.energy_pj.to_bits(),
                p.energy_pj.to_bits(),
                "request {} energy diverged bitwise",
                l.request_id
            );
        }

        // the unbounded pool must have been pure bookkeeping: no
        // pressure events of any kind
        let pool = paged.pool.as_ref().expect("paged run must carry a pool report");
        assert_eq!(pool.evictions, 0, "unbounded pool must never evict");
        assert_eq!(pool.preemptions, 0, "unbounded pool must never preempt");
        assert_eq!(pool.deferrals, 0, "unbounded pool must never defer");
        assert_eq!(pool.shed_unfittable, 0, "unbounded pool must never shed");
        assert_eq!(pool.cow_copies, 0, "no speculation configured, so no fork ever CoWs");
    }
}

// ---------------------------------------------------------------------------
// 4a. preempt-then-resume with identical token books
// ---------------------------------------------------------------------------

/// Four decode-heavy requests against a pool sized so every request's
/// lifetime fits alone but concurrent decode growth cannot: appends
/// must preempt victims (no cached blocks exist — prefix sharing is
/// off), and every preempted request must resume and still complete
/// with exactly its token target, matching an unbounded-pool run.
#[test]
fn preemption_resumes_and_completes_with_identical_token_books() {
    // GPT-2 Small KV is 36 864 B/token: a 128 KiB block holds 3 tokens.
    // seq=8 admits at 3 blocks; lifetime 8+30 tokens = 13 of 14 blocks.
    let run = |paging: PagedKvOptions| -> ServeReport {
        let mut engine = Engine::with_clusters(4);
        for i in 0..4u64 {
            let mut cfg = GPT2_SMALL;
            cfg.seq = 8;
            engine.submit_request(Request::new(i, cfg).with_tokens(30));
        }
        let mut backend = AnalyticBackend::new();
        let opts =
            ServeOptions { max_iters: 2048, paging: Some(paging), ..ServeOptions::default() };
        let report = engine.serve(&mut backend, None, &opts);
        report.assert_consistent();
        report
    };

    let tight = run(PagedKvOptions {
        block_bytes: 128 * 1024,
        pool_bytes: 14 * 128 * 1024,
        share_prefix: false,
    });
    let roomy = run(PagedKvOptions::unbounded());

    let pool = tight.pool.as_ref().expect("paged run must carry a pool report");
    assert!(pool.preemptions > 0, "tight pool must force preemption");
    assert!(pool.resumes > 0, "preempted requests must resume");
    assert!(pool.resumes <= pool.preemptions);
    assert_eq!(pool.shed_unfittable, 0, "every lifetime fits the pool");

    assert_eq!(tight.per_request.len(), roomy.per_request.len());
    for (t, r) in tight.per_request.iter().zip(&roomy.per_request) {
        assert_eq!(t.request_id, r.request_id);
        assert_eq!(t.outcome, Outcome::Completed, "request {}", t.request_id);
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
        assert_eq!(
            (t.tokens, t.token_target),
            (r.tokens, r.token_target),
            "token books must survive preemption (request {})",
            t.request_id
        );
        assert_eq!(t.tokens, 30, "completion means the full target");
    }
    let preempted_reqs =
        tight.per_request.iter().filter(|r| r.preemptions > 0).count();
    assert!(preempted_reqs > 0, "per-request books must attribute the preemptions");
    assert!(roomy.per_request.iter().all(|r| r.preemptions == 0));
}

/// Regression: a request that just produced its final token must apply
/// no allocation pressure. GPT-2 Small KV is 36 864 B/token, so a
/// 147 456 B block holds exactly 4 tokens. Request A (seq=8, target 2)
/// admits at 2 full blocks, request B (seq=4, target 4) at 1 full
/// block; the 4-block pool leaves one block free. On their shared
/// second iteration A produces its final token and B's tail is full, so
/// B needs a fresh block while nothing is cached and the only other
/// block-holder (A) has just completed. A's table must be released (and
/// its dead tail append skipped) before B's append lands — previously
/// this configuration panicked inside `acquire_block` because
/// completed requests held their blocks until retirement yet were
/// excluded from victim selection.
#[test]
fn completed_requests_release_blocks_before_appends_under_pressure() {
    let mut engine = Engine::with_clusters(4);
    let mut a = GPT2_SMALL;
    a.seq = 8;
    let mut b = GPT2_SMALL;
    b.seq = 4;
    engine.submit_request(Request::new(0, a).with_tokens(2));
    engine.submit_request(Request::new(1, b).with_tokens(4));
    let mut backend = AnalyticBackend::new();
    let opts = ServeOptions {
        max_iters: 64,
        paging: Some(PagedKvOptions {
            block_bytes: 4 * 36_864,
            pool_bytes: 16 * 36_864,
            share_prefix: false,
        }),
        ..ServeOptions::default()
    };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();
    for r in &report.per_request {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
    }
    let pool = report.pool.as_ref().expect("paged run must carry a pool report");
    // releasing the completed request's table absorbs the pressure;
    // nothing live ever needed to be preempted or deferred
    assert_eq!(pool.preemptions, 0, "done-release must absorb the pressure");
    assert_eq!(pool.deferrals, 0);
    assert_eq!(pool.resident, 0, "all blocks return once both requests retire");
}

// ---------------------------------------------------------------------------
// 4b. memory pressure: evictions, prefix hits, per-policy attainment
// ---------------------------------------------------------------------------

/// A shared-prefix burst trace against a 16-block pool: completed
/// requests park their indexed prompt blocks on the LRU cached list,
/// and more distinct indexed blocks are created over the run than the
/// pool can hold — so allocation pressure MUST evict; same-class
/// requests admitted after a class-mate's prefill MUST hit the prefix
/// index and skip whole prompt blocks.
#[test]
fn pressure_trace_shows_evictions_prefix_hits_and_policy_attainment() {
    let spec = TraceSpec::bursty(6, 50_000.0, 9);
    let mut engine = Engine::with_clusters(4);
    let traffic = spec.mixed_traffic_paged(32, 4, None, 4);
    assert!(
        traffic.iter().any(|r| r.policy == SchedPolicy::Latency),
        "trace must carry a latency-class request"
    );
    for r in traffic {
        engine.submit_request(r);
    }
    let mut backend = AnalyticBackend::new();
    let opts = ServeOptions {
        max_iters: 1024,
        paging: Some(PagedKvOptions {
            block_bytes: 256 * 1024, // 7 GPT-2 tokens per block
            pool_bytes: 4 * 1024 * 1024, // 16 blocks
            share_prefix: true,
        }),
        ..ServeOptions::default()
    };
    let report = engine.serve(&mut backend, None, &opts);
    report.assert_consistent();

    let pool = report.pool.as_ref().expect("paged run must carry a pool report");
    assert_eq!(pool.capacity_blocks, 16);
    assert_eq!(pool.block_bytes, 256 * 1024);
    assert!(pool.evictions > 0, "pressure trace must evict cached blocks");
    assert!(pool.prefix_hits > 0, "same-class prompts must hit the prefix index");
    assert!(pool.prefix_hit_tokens > 0, "prefix hits must skip real prompt tokens");
    // whole-block sharing: every hit skips a multiple of 7 tokens
    assert_eq!(pool.prefix_hit_tokens % 7, 0, "hits are whole blocks only");
    assert_eq!(pool.shed_unfittable, 0, "every request lifetime fits 16 blocks");

    // no deadline, fittable lifetimes, ample iteration budget: the
    // loop must finish everything despite the churn
    for r in &report.per_request {
        assert_eq!(r.outcome, Outcome::Completed, "request {}", r.request_id);
    }
    let hit_reqs = report.per_request.iter().filter(|r| r.prefix_hit_tokens > 0).count();
    assert!(hit_reqs > 0, "per-request books must attribute the prefix savings");

    // both policy classes are present and fully attained (no deadline
    // and no SLO bound means completion is the only criterion)
    assert!(report.per_request.iter().any(|r| r.policy == SchedPolicy::Latency));
    assert!(report.per_request.iter().any(|r| r.policy == SchedPolicy::Throughput));
    assert_eq!(report.slo.attainment_throughput, 1.0);
    assert_eq!(report.slo.attainment_latency, 1.0);
}
